"""Scaling + load-balance benchmark (paper Fig. 9/10, DESIGN.md §6).

Two phases:

  A. Straggler analysis (host-only, deterministic, ENFORCED): on a
     skewed synthetic dataset, compare the per-step straggler ratio
     (max/mean predicted shard cost — the step-time multiplier the
     slowest shard imposes on a synchronous mesh) of three DP sharders:
       - naive: random global batch, contiguous equal-count split
         (DefaultSampler — the seed behaviour);
       - pair:  the paper's Fig. 4 smallest+largest pairing
         (LoadBalanceSampler);
       - lpt:   cost-model LPT bin packing (CostBalanceSampler).
     The bar ``mean straggler(lpt) < mean straggler(naive)`` must hold
     for every device count (exit code 1 otherwise) — CI runs this on
     every push.

  B. Throughput sweep (subprocess per device count, report-only on CPU
     where host "devices" share cores): atoms/s of the balanced
     StepPlan path vs the naive iterator across mesh sizes, via
     ``XLA_FLAGS=--xla_force_host_platform_device_count``.

    PYTHONPATH=src python benchmarks/bench_scaling.py --quick \
        --json bench_scaling.json
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import textwrap

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.batching.balance import (  # noqa: E402
    crystal_slots_for, shard_cost_totals, straggler_ratio,
)
from repro.batching.cost import DEFAULT_COST_MODEL  # noqa: E402
from repro.data import SyntheticConfig, make_dataset  # noqa: E402
from repro.data.sampler import (  # noqa: E402
    CostBalanceSampler, DefaultSampler, LoadBalanceSampler,
)

# skewed size distribution: heavier lognormal tail than the MPtrj-like
# default (sigma 0.7), so equal-count shards are badly cost-imbalanced
SKEW_SIGMA = 1.1


def _hist(ratios: np.ndarray, edges=(1.0, 1.1, 1.25, 1.5, 2.0, 3.0)):
    """Straggler-ratio histogram: counts per [edge_i, edge_i+1) bin
    (last bin is open-ended)."""
    counts = np.histogram(ratios, bins=list(edges) + [np.inf])[0]
    return {f">={lo:g}": int(c) for lo, c in zip(edges, counts)}


def run_straggler_analysis(
    device_counts=(2, 4, 8),
    *,
    num_crystals: int = 256,
    global_batch: int = 32,
    seed: int = 0,
) -> dict:
    """Phase A: per-step straggler ratios of the three sharders."""
    ds = make_dataset(SyntheticConfig(
        num_crystals=num_crystals, lognormal_sigma=SKEW_SIGMA, seed=seed))
    costs = DEFAULT_COST_MODEL.predict_dataset(ds)
    out: dict = {}
    for n_dev in device_counts:
        slots = crystal_slots_for(global_batch, n_dev)
        samplers = {
            "naive": DefaultSampler(costs, seed),
            "pair": LoadBalanceSampler(costs, seed),
            "lpt": CostBalanceSampler(costs, seed, max_items=slots),
        }
        per = {}
        for name, sampler in samplers.items():
            ratios = []
            for _idx, shards in sampler.epoch(global_batch, n_dev):
                ratios.append(straggler_ratio(
                    shard_cost_totals(costs, shards)))
            ratios = np.asarray(ratios)
            per[name] = {
                "mean": float(ratios.mean()),
                "max": float(ratios.max()),
                "p90": float(np.quantile(ratios, 0.9)),
                "hist": _hist(ratios),
            }
        out[str(n_dev)] = per
    return out


_WORKER = textwrap.dedent("""
    import os, sys, json, time, itertools
    n = int(sys.argv[1]); batch = int(sys.argv[2])
    steps = int(sys.argv[3]); mode = sys.argv[4]; quick = int(sys.argv[5])
    os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    os.environ.setdefault("JAX_PLATFORM_NAME", "cpu")
    import numpy as np, jax
    from jax.sharding import Mesh
    from repro.core.chgnet import CHGNetConfig
    from repro.batching import ladder_for
    from repro.data import (BalancedBatchIterator, BatchIterator,
                            SyntheticConfig, make_dataset)
    from repro.train import TrainConfig, Trainer

    ds = make_dataset(SyntheticConfig(
        num_crystals=64 if quick else 128, max_atoms=20 if quick else 32,
        lognormal_sigma=1.1, seed=0))
    caps = ladder_for(ds, -(-batch // n))
    mesh = Mesh(np.array(jax.devices()), ("data",)) if n > 1 else None
    cfg = (CHGNetConfig(dim=16, num_blocks=1) if quick
           else CHGNetConfig(readout="direct"))
    tr = Trainer(cfg, TrainConfig(global_batch=batch), mesh=mesh)
    stack = mesh is not None
    if mode == "balanced":
        it = BalancedBatchIterator(ds, batch, n, caps, num_micro=1,
                                   stack=stack)
    else:
        it = BatchIterator(ds, batch, n, caps, load_balance=False,
                           stack=stack)
    cyc = itertools.cycle(iter(it))
    tr.train(itertools.islice(cyc, 2))  # warmup/compile
    t0 = time.perf_counter()
    tr.train(itertools.islice(cyc, steps))
    dt = (time.perf_counter() - t0) / steps
    atoms_step = batch * float(np.mean(
        [c.num_atoms for c in ds.crystals]))
    print(json.dumps({"n": n, "mode": mode, "batch": batch,
                      "step_s": dt, "atoms_per_s": atoms_step / dt}))
""")


def _run_worker(n, batch, steps, mode, quick):
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    out = subprocess.run(
        [sys.executable, "-c", _WORKER, str(n), str(batch), str(steps),
         mode, str(int(quick))],
        capture_output=True, text=True, env=env, timeout=1800)
    if out.returncode != 0:
        raise RuntimeError(out.stderr[-1500:])
    return json.loads(out.stdout.strip().splitlines()[-1])


def run_throughput_sweep(device_counts=(1, 2, 4), *, batch=16, steps=4,
                         quick=False) -> list[dict]:
    """Phase B: atoms/s vs mesh size, balanced vs naive (report-only on
    CPU — forced host devices share the same cores)."""
    rows = []
    for n in device_counts:
        for mode in ("naive", "balanced"):
            rows.append(_run_worker(n, batch, steps, mode, quick))
    return rows


def run(device_counts=(1, 2, 4), strong_batch: int = 32,
        weak_per_dev: int = 8):
    """Legacy Fig. 10 entry point (kept for bench-suite callers): rows of
    (name, usec, note) from the throughput sweep."""
    rows = []
    for r in run_throughput_sweep(device_counts, batch=strong_batch,
                                  steps=2, quick=True):
        rows.append((f"fig10_{r['mode']}_n{r['n']}", r["step_s"] * 1e6,
                     f"atoms/s={r['atoms_per_s']:.0f}"))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small model/dataset + fewer device counts (CI)")
    ap.add_argument("--json", default=None, help="write results to file")
    ap.add_argument("--devices", default=None,
                    help="comma-separated device counts (straggler phase)")
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--skip-throughput", action="store_true",
                    help="phase A only (no subprocess jax runs)")
    args = ap.parse_args()

    if args.devices:
        dev_a = tuple(int(x) for x in args.devices.split(","))
    else:
        dev_a = (2, 4) if args.quick else (2, 4, 8)
    batch = args.batch or (16 if args.quick else 32)
    steps = args.steps or (2 if args.quick else 4)

    straggler = run_straggler_analysis(
        dev_a, num_crystals=128 if args.quick else 256, global_batch=batch)
    for n_dev, per in straggler.items():
        print(f"devices={n_dev}: " + "  ".join(
            f"{k}: mean={v['mean']:.3f} max={v['max']:.3f}"
            for k, v in per.items()))

    # ENFORCED bar: LPT balanced beats naive even-count sharding on the
    # skewed dataset at every device count
    violations = [
        n_dev for n_dev, per in straggler.items()
        if not per["lpt"]["mean"] < per["naive"]["mean"]
    ]

    throughput = []
    if not args.skip_throughput:
        dev_b = (1, 2) if args.quick else (1, 2, 4)
        throughput = run_throughput_sweep(dev_b, batch=batch, steps=steps,
                                          quick=args.quick)
        for r in throughput:
            print(f"n={r['n']} mode={r['mode']}: "
                  f"step={r['step_s'] * 1e3:.1f}ms "
                  f"atoms/s={r['atoms_per_s']:.0f}")

    result = {
        "straggler": straggler,
        "throughput": throughput,
        "enforced": {"lpt_mean_lt_naive_mean": not violations},
    }
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(result, fh, indent=2)
        print(f"wrote {args.json}")

    if violations:
        print(f"FAIL: lpt straggler >= naive at device counts "
              f"{violations}", file=sys.stderr)
        return 1
    print("straggler bar OK: lpt < naive at every device count")
    return 0


if __name__ == "__main__":
    sys.exit(main())
