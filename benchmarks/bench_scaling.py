"""Fig. 10 reproduction: strong/weak scaling of DP training over host
devices (subprocess per device count; CPU cores stand in for GPUs — the
paper's 66-91% efficiencies are the reference points).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

_WORKER = textwrap.dedent("""
    import os, sys, json, time, itertools
    n = int(sys.argv[1]); batch = int(sys.argv[2]); steps = int(sys.argv[3])
    os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    import jax
    from repro.core.chgnet import CHGNetConfig
    from repro.batching import capacity_for
    from repro.data import BatchIterator, SyntheticConfig, make_dataset
    from repro.train import TrainConfig, Trainer

    ds = make_dataset(SyntheticConfig(num_crystals=128, max_atoms=20, seed=0))
    caps = capacity_for(ds, batch // n)
    mesh = jax.make_mesh((n,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    tr = Trainer(CHGNetConfig(readout="direct"),
                 TrainConfig(global_batch=batch), mesh=mesh)
    it = itertools.cycle(iter(BatchIterator(ds, batch, n, caps, stack=True)))
    tr.train(itertools.islice(it, 2))  # warmup/compile
    t0 = time.perf_counter()
    tr.train(itertools.islice(it, steps))
    dt = (time.perf_counter() - t0) / steps
    print(json.dumps({"n": n, "batch": batch, "step_s": dt}))
""")


def _run(n, batch, steps=4):
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run(
        [sys.executable, "-c", _WORKER, str(n), str(batch), str(steps)],
        capture_output=True, text=True, env=env, timeout=900)
    if out.returncode != 0:
        raise RuntimeError(out.stderr[-1500:])
    return json.loads(out.stdout.strip().splitlines()[-1])


def run(device_counts=(1, 2, 4), strong_batch: int = 32,
        weak_per_dev: int = 8):
    import os

    cores = os.cpu_count() or 1
    sim = ";SIMULATED(1-core-host)" if cores < max(device_counts) else ""
    rows = []
    # strong scaling: fixed global batch
    base = None
    for n in device_counts:
        r = _run(n, strong_batch)
        if base is None:
            base = r["step_s"]
        speedup = base / r["step_s"]
        eff = speedup / (n / device_counts[0])
        rows.append((f"fig10_strong_n{n}", r["step_s"] * 1e6,
                     f"speedup={speedup:.2f}x;eff={eff * 100:.0f}%{sim}"))
    # weak scaling: fixed per-device batch
    base = None
    for n in device_counts:
        r = _run(n, weak_per_dev * n)
        if base is None:
            base = r["step_s"]
        eff = base / r["step_s"]
        rows.append((f"fig10_weak_n{n}", r["step_s"] * 1e6,
                     f"eff={eff * 100:.0f}%{sim}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
