"""Fig. 8 reproduction: iteration time & memory under step-by-step
optimizations (CPU wall-clock; the RATIOS are the paper's claim).

Stages (cumulative, mirroring the paper):
  ref          : serial per-crystal basis (Alg. 1 style: one jitted call
                 per crystal in a Python loop), reference blocks,
                 unpacked GatedMLP, reference envelope, autodiff F/sigma
  par_basis    : + parallel batched basis (Alg. 2 == padded batch, 1 call)
  fusion       : + packed GatedMLP + factored envelope + dependency elim.
  decoupled    : + direct Force/Stress heads (no 2nd-order derivatives)
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.batching import BatchCapacities, batch_crystals
from repro.core.chgnet import CHGNetConfig, chgnet_apply, chgnet_init
from repro.core.losses import LossWeights, chgnet_loss
from repro.data import SyntheticConfig, make_dataset
from repro.train.trainer import chgnet_loss_fn


def _time(fn, *args, iters=3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def run(batch_size: int = 16, iters: int = 3):
    ds = make_dataset(SyntheticConfig(num_crystals=batch_size, max_atoms=24,
                                      seed=0))
    crystals, graphs = ds.crystals, ds.graphs
    caps_one = BatchCapacities(
        atoms=64, bonds=max(g.num_bonds for g in graphs) + 8,
        angles=max(g.num_angles for g in graphs) + 8)
    caps_all = BatchCapacities(
        atoms=sum(c.num_atoms for c in crystals) + 8,
        bonds=sum(g.num_bonds for g in graphs) + 8,
        angles=sum(g.num_angles for g in graphs) + 8)

    w = LossWeights()
    results = {}

    # --- stage 1: reference (serial basis loop) ---------------------------
    cfg = CHGNetConfig(readout="autodiff", block_variant="reference",
                       mlp_impl="ref", envelope_impl="reference")
    params = chgnet_init(jax.random.PRNGKey(0), cfg)
    grad_one = jax.jit(jax.grad(
        lambda p, b: chgnet_loss_fn(p, cfg, b, w)[0]))
    batches_one = [batch_crystals([c], [g], caps_one)
                   for c, g in zip(crystals, graphs)]

    def serial_step():
        outs = [grad_one(params, b) for b in batches_one]
        return outs[-1]

    results["ref_serial"] = _time(serial_step, iters=iters)

    # --- stage 2: + parallel batched basis ---------------------------------
    batch = batch_crystals(crystals, graphs, caps_all)
    grad_all = jax.jit(jax.grad(
        lambda p, b: chgnet_loss_fn(p, cfg, b, w)[0]))
    results["par_basis"] = _time(grad_all, params, batch, iters=iters)

    # --- stage 3: + kernel fusion + redundancy bypass + dep. elimination ---
    cfg3 = CHGNetConfig(readout="autodiff", block_variant="fast",
                        mlp_impl="packed", envelope_impl="factored")
    grad3 = jax.jit(jax.grad(
        lambda p, b: chgnet_loss_fn(p, cfg3, b, w)[0]))
    results["fusion"] = _time(grad3, params, batch, iters=iters)

    # --- stage 4: + decoupled Force/Stress heads ---------------------------
    cfg4 = cfg3.with_(readout="direct")
    params4 = chgnet_init(jax.random.PRNGKey(0), cfg4)
    grad4 = jax.jit(jax.grad(
        lambda p, b: chgnet_loss_fn(p, cfg4, b, w)[0]))
    results["decoupled"] = _time(grad4, params4, batch, iters=iters)

    rows = []
    base = results["ref_serial"]
    for name, t in results.items():
        rows.append((f"fig8_iter_{name}", t * 1e6,
                     f"speedup_vs_ref={base / t:.2f}x"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
