"""Fig. 8 reproduction + fused-conv memory trajectory.

Part 1 (``run``): iteration time under step-by-step optimizations (CPU
wall-clock; the RATIOS are the paper's claim).

Stages (cumulative, mirroring the paper):
  ref          : serial per-crystal basis (Alg. 1 style: one jitted call
                 per crystal in a Python loop), reference blocks,
                 unpacked GatedMLP, reference envelope, autodiff F/sigma
  par_basis    : + parallel batched basis (Alg. 2 == padded batch, 1 call)
  fusion       : + packed GatedMLP + factored envelope + dependency elim.
  decoupled    : + direct Force/Stress heads (no 2nd-order derivatives)

Part 3 (``run_precision_sweep``, ``--precision f32,mixed,bf16``): the
DESIGN.md §4 memory claim as a tracked trajectory — one jitted train step
per precision policy at identical capacities, recording atoms/s and the
compiled peak temp memory; ``"mixed"`` must undercut ``"f32"`` (bf16
activations) or the bench step fails.

Part 2 (``run_conv_sweep``): the paper's 3.59x memory-footprint claim as a
*tracked trajectory* instead of prose — sweeps ``conv_impl`` x ``agg_impl``
on one jitted train step at fixed batch capacities and records, per combo,

  - compiled peak temp memory (``.lower().compile().memory_analysis()``,
    ``temp_size_in_bytes`` — the activation/workspace footprint; argument
    and output sizes are identical across combos by construction), and
  - step wall time + atoms/s throughput (the tokens/s of this workload).

``--json PATH`` dumps ``{"stages": [...], "sweep": [...]}``; CI uploads it
as an artifact next to bench_kernels.json (``--sweep-only`` skips the slow
Fig. 8 stage loop there).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.batching import BatchCapacities, batch_crystals
from repro.core.chgnet import CHGNetConfig, chgnet_apply, chgnet_init
from repro.core.losses import LossWeights, chgnet_loss
from repro.data import SyntheticConfig, make_dataset
from repro.train.trainer import chgnet_loss_fn


def _time(fn, *args, iters=3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def _bench_batch(batch_size: int):
    """The one synthetic workload every sweep in this file measures:
    whole-dataset capacities (+8 headroom) so all combos see identical
    padded shapes.  Returns (ds, caps, batch)."""
    ds = make_dataset(SyntheticConfig(num_crystals=batch_size, max_atoms=24,
                                      seed=0))
    caps = BatchCapacities(
        atoms=sum(c.num_atoms for c in ds.crystals) + 8,
        bonds=sum(g.num_bonds for g in ds.graphs) + 8,
        angles=sum(g.num_angles for g in ds.graphs) + 8)
    return ds, caps, batch_crystals(ds.crystals, ds.graphs, caps)


def run(batch_size: int = 16, iters: int = 3):
    ds, caps_all, batch_all = _bench_batch(batch_size)
    crystals, graphs = ds.crystals, ds.graphs
    caps_one = BatchCapacities(
        atoms=64, bonds=max(g.num_bonds for g in graphs) + 8,
        angles=max(g.num_angles for g in graphs) + 8)

    w = LossWeights()
    results = {}

    # --- stage 1: reference (serial basis loop) ---------------------------
    cfg = CHGNetConfig(readout="autodiff", block_variant="reference",
                       mlp_impl="ref", envelope_impl="reference")
    params = chgnet_init(jax.random.PRNGKey(0), cfg)
    grad_one = jax.jit(jax.grad(
        lambda p, b: chgnet_loss_fn(p, cfg, b, w)[0]))
    batches_one = [batch_crystals([c], [g], caps_one)
                   for c, g in zip(crystals, graphs)]

    def serial_step():
        outs = [grad_one(params, b) for b in batches_one]
        return outs[-1]

    results["ref_serial"] = _time(serial_step, iters=iters)

    # --- stage 2: + parallel batched basis ---------------------------------
    batch = batch_all
    grad_all = jax.jit(jax.grad(
        lambda p, b: chgnet_loss_fn(p, cfg, b, w)[0]))
    results["par_basis"] = _time(grad_all, params, batch, iters=iters)

    # --- stage 3: + kernel fusion + redundancy bypass + dep. elimination ---
    cfg3 = CHGNetConfig(readout="autodiff", block_variant="fast",
                        mlp_impl="packed", envelope_impl="factored")
    grad3 = jax.jit(jax.grad(
        lambda p, b: chgnet_loss_fn(p, cfg3, b, w)[0]))
    results["fusion"] = _time(grad3, params, batch, iters=iters)

    # --- stage 4: + decoupled Force/Stress heads ---------------------------
    cfg4 = cfg3.with_(readout="direct")
    params4 = chgnet_init(jax.random.PRNGKey(0), cfg4)
    grad4 = jax.jit(jax.grad(
        lambda p, b: chgnet_loss_fn(p, cfg4, b, w)[0]))
    results["decoupled"] = _time(grad4, params4, batch, iters=iters)

    rows = []
    base = results["ref_serial"]
    for name, t in results.items():
        rows.append((f"fig8_iter_{name}", t * 1e6,
                     f"speedup_vs_ref={base / t:.2f}x"))
    return rows


def run_conv_sweep(
    batch_size: int = 16,
    iters: int = 3,
    conv_impls: tuple = ("unfused", "fused"),
    agg_impls: tuple = ("scatter", "sorted", "pallas"),
    fused_agg_impls: tuple | None = None,
    check: bool = True,
):
    """conv_impl x agg_impl sweep of one train step at FIXED capacities.

    Returns dict rows with step time, atoms/s, and compiled peak temp
    memory.  The acceptance bar for DESIGN.md §3 is that every "fused" row
    has strictly lower ``peak_temp_bytes`` than its "unfused" counterpart
    (messages are recomputed in the backward instead of saved).  Off-TPU
    the fused rows' *wall time* measures the Pallas interpreter, not
    Mosaic — only the memory column is meaningful there (same caveat as
    bench_kernels).

    ``fused_agg_impls`` restricts the fused half of the sweep (with
    conv_impl="fused" the conv reductions live inside the megakernels, so
    agg_impl barely moves the row — CI trims the near-duplicate, expensive
    interpret-mode rows to one).
    """
    ds, caps, batch = _bench_batch(batch_size)
    real_atoms = int(sum(c.num_atoms for c in ds.crystals))

    w = LossWeights()
    params = chgnet_init(jax.random.PRNGKey(0), CHGNetConfig())
    rows = []
    for conv in conv_impls:
        aggs = agg_impls if conv != "fused" or fused_agg_impls is None \
            else fused_agg_impls
        for agg in aggs:
            cfg = CHGNetConfig(readout="direct", conv_impl=conv,
                               agg_impl=agg)
            grad_fn = jax.jit(jax.grad(
                lambda p, b, cfg=cfg: chgnet_loss_fn(p, cfg, b, w)[0]))
            compiled = grad_fn.lower(params, batch).compile()
            mem = compiled.memory_analysis()
            step_s = _time(grad_fn, params, batch, iters=iters)
            rows.append({
                "name": f"iter_conv_{conv}_agg_{agg}",
                "conv_impl": conv,
                "agg_impl": agg,
                "step_us": step_s * 1e6,
                "atoms_per_s": real_atoms / step_s,
                "peak_temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "argument_bytes": getattr(mem, "argument_size_in_bytes",
                                          None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "note": (f"B={batch_size} atoms={real_atoms} "
                         f"caps=({caps.atoms},{caps.bonds},{caps.angles})"),
            })
    if check:
        _check_memory_bar(rows)
    return rows


def run_bond_store_sweep(
    batch_size: int = 16,
    iters: int = 3,
    bond_stores: tuple = ("directed", "undirected"),
    conv_impls: tuple = ("unfused", "fused"),
    agg_impl: str = "scatter",
    check: bool = True,
):
    """bond_store x conv_impl sweep of one train step at FIXED capacities.

    The DESIGN.md §5 claim as a tracked trajectory: per combo, step wall
    time, atoms/s, compiled peak temp memory, and the bond-level tensor
    accounting — ``eu_ratio`` (real undirected / real directed bonds; 0.5
    for pair-symmetric graphs) and ``bond_level_bytes`` (the f32 bytes of
    the per-bond basis + envelope tensors at that store's granularity:
    rows x (num_rbf + 2*dim) x 4).  Acceptance bars (enforced in
    interpret mode / CPU too — everything here is f32, no emulation
    caveat): every "undirected" row must undercut its "directed"
    counterpart's peak temp memory, and the bond-level bytes reduction
    must be >= 25%.  atoms/s is recorded for the no-regression check
    (reported, not enforced: CI wall clock is too noisy to gate on).
    """
    ds, caps, batch = _bench_batch(batch_size)
    real_atoms = int(sum(c.num_atoms for c in ds.crystals))
    real_bonds = int(sum(g.num_bonds for g in ds.graphs))
    real_und = int(sum(g.num_undirected for g in ds.graphs))

    w = LossWeights()
    params = chgnet_init(jax.random.PRNGKey(0), CHGNetConfig())
    rows = []
    for store in bond_stores:
        for conv in conv_impls:
            cfg = CHGNetConfig(readout="direct", bond_store=store,
                               conv_impl=conv, agg_impl=agg_impl)
            # bond-level tensors at this store's granularity: rbf basis
            # (num_rbf lanes) + the e^a/e^b envelope tables (dim each)
            basis_rows = caps.und_cap if store == "undirected" \
                else caps.bonds
            bond_bytes = basis_rows * (cfg.num_rbf + 2 * cfg.dim) * 4
            grad_fn = jax.jit(jax.grad(
                lambda p, b, cfg=cfg: chgnet_loss_fn(p, cfg, b, w)[0]))
            compiled = grad_fn.lower(params, batch).compile()
            mem = compiled.memory_analysis()
            step_s = _time(grad_fn, params, batch, iters=iters)
            rows.append({
                "name": f"iter_store_{store}_conv_{conv}",
                "bond_store": store,
                "conv_impl": conv,
                "agg_impl": agg_impl,
                "step_us": step_s * 1e6,
                "atoms_per_s": real_atoms / step_s,
                "peak_temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "bond_level_bytes": bond_bytes,
                "eu_ratio": real_und / real_bonds,
                "note": (f"B={batch_size} atoms={real_atoms} "
                         f"bonds={real_bonds} und={real_und} "
                         f"caps=({caps.atoms},{caps.bonds},{caps.angles},"
                         f"und={caps.und_cap})"),
            })
    if check:
        _check_bond_store_bar(rows)
    return rows


def _check_bond_store_bar(rows):
    """DESIGN.md §5 bars, enforced so a regression FAILS the CI bench step:
    undirected must show (a) strictly lower compiled peak temp memory than
    directed per conv_impl and (b) >= 25% lower bond-level tensor bytes."""
    by = {(r["bond_store"], r["conv_impl"]): r for r in rows}
    for (store, conv), r in by.items():
        if store != "undirected":
            continue
        d = by.get(("directed", conv))
        if d is None:
            continue
        db, ub = d["bond_level_bytes"], r["bond_level_bytes"]
        if ub > 0.75 * db:
            raise RuntimeError(
                f"undirected bond-level tensor bytes not >=25% below "
                f"directed: {ub:,} vs {db:,} (conv_impl={conv!r}, "
                f"Eu/E={r['eu_ratio']:.3f}) — DESIGN.md §5")
        peak, d_peak = r["peak_temp_bytes"], d["peak_temp_bytes"]
        if peak is None or d_peak is None:
            print(f"WARNING: no memory_analysis on this backend "
                  f"(conv={conv}); §5 memory bar not checked")
            continue
        if peak >= d_peak:
            raise RuntimeError(
                f"bond_store='undirected' peak temp memory not below "
                f"directed: {peak:,} >= {d_peak:,} bytes "
                f"(conv_impl={conv!r}) — DESIGN.md §5 requires strictly "
                f"lower")
        slow = r["atoms_per_s"] < 0.9 * d["atoms_per_s"]
        print(f"bond-store bar OK (conv={conv}): peak {peak:,} < "
              f"{d_peak:,}; bond bytes {ub:,} vs {db:,} "
              f"(Eu/E={r['eu_ratio']:.3f})"
              + (f"; NOTE atoms/s regressed: {r['atoms_per_s']:.0f} vs "
                 f"{d['atoms_per_s']:.0f} (interpret-mode wall clock is "
                 f"not the §5 claim)" if slow else ""))


def run_bond_features_sweep(
    batch_size: int = 16,
    iters: int = 3,
    bond_features: tuple = ("directed", "undirected"),
    conv_impls: tuple = ("unfused", "fused"),
    agg_impl: str = "scatter",
    check: bool = True,
):
    """bond_features x conv_impl sweep of one train step at FIXED capacities.

    The DESIGN.md §10 claim as a tracked trajectory: both rows keep the
    §5 undirected bond STORE; only the trunk's compute representation
    differs.  ``bond_features="directed"`` expands e to directed rows and
    runs bond_conv/angle_update over E/A rows; ``"undirected"`` keeps e
    at Eu and runs the swap-symmetrized forms over Eu/Au rows.  Per
    combo: step wall time, atoms/s, compiled peak temp memory, and the
    analytic bond+angle-level GEMM FLOP count per interaction block
    (``trunk_gemm_flops`` — the bond_mlp/bond_out/angle_mlp GEMMs at
    that tier's row granularity; row counts are the REAL bond/angle
    totals, so the number is exact, not a padded-capacity bound).

    Acceptance bars, both ENFORCED everywhere (the whole path is f32
    and the FLOP count is analytic — no interpret-mode caveat):

      - every "undirected" row's ``trunk_gemm_flops`` must be >= 40%
        below its "directed" counterpart (pair-symmetric graphs give
        exactly 50%: Eu == E/2 and Au == A/2 halve every GEMM's rows);
      - every "undirected" row's compiled peak temp memory must not
        exceed its "directed" counterpart's (undirected<=directed).

    atoms/s is recorded for the no-regression trajectory (reported, not
    enforced: interpret-mode wall clock measures the Pallas interpreter).
    """
    ds, caps, batch = _bench_batch(batch_size)
    real_atoms = int(sum(c.num_atoms for c in ds.crystals))
    real_bonds = int(sum(g.num_bonds for g in ds.graphs))
    real_und = int(sum(g.num_undirected for g in ds.graphs))
    real_angles = int(sum(g.num_angles for g in ds.graphs))
    real_uangles = int(sum(g.und_angle_rep.shape[0] for g in ds.graphs))

    w = LossWeights()
    params = chgnet_init(jax.random.PRNGKey(0), CHGNetConfig())
    d = CHGNetConfig().dim
    rows = []
    for feat in bond_features:
        for conv in conv_impls:
            cfg = CHGNetConfig(readout="direct", bond_store="undirected",
                               bond_features=feat, conv_impl=conv,
                               agg_impl=agg_impl)
            # bond+angle-level GEMMs per interaction block at this tier's
            # row granularity: bond_mlp (4d -> 2d packed) + angle_mlp
            # (4d -> 2d packed) per angle row, bond_out (d -> d) per
            # bond row; 2*m*n FLOPs per row for an (m, n) GEMM
            a_rows = real_angles if feat == "directed" else real_uangles
            e_rows = real_bonds if feat == "directed" else real_und
            flops = (a_rows * 2 * (4 * d) * (2 * d)      # bond_mlp phi
                     + e_rows * 2 * d * d                # bond_out
                     + a_rows * 2 * (4 * d) * (2 * d))   # angle_mlp f_a
            grad_fn = jax.jit(jax.grad(
                lambda p, b, cfg=cfg: chgnet_loss_fn(p, cfg, b, w)[0]))
            compiled = grad_fn.lower(params, batch).compile()
            mem = compiled.memory_analysis()
            step_s = _time(grad_fn, params, batch, iters=iters)
            rows.append({
                "name": f"iter_feat_{feat}_conv_{conv}",
                "bond_features": feat,
                "conv_impl": conv,
                "agg_impl": agg_impl,
                "step_us": step_s * 1e6,
                "atoms_per_s": real_atoms / step_s,
                "peak_temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "trunk_gemm_flops": flops,
                "angle_rows": a_rows,
                "bond_rows": e_rows,
                "note": (f"B={batch_size} atoms={real_atoms} "
                         f"bonds={real_bonds}/und={real_und} "
                         f"angles={real_angles}/und={real_uangles} "
                         f"caps=({caps.atoms},{caps.bonds},{caps.angles})"),
            })
    if check:
        _check_bond_features_bar(rows)
    return rows


def _check_bond_features_bar(rows):
    """DESIGN.md §10 bars, enforced so a regression FAILS the CI bench
    step: per conv_impl, the undirected trunk must show (a) >= 40% fewer
    bond+angle-level GEMM FLOPs and (b) compiled peak temp memory no
    higher than the directed trunk at identical capacities."""
    by = {(r["bond_features"], r["conv_impl"]): r for r in rows}
    for (feat, conv), r in by.items():
        if feat != "undirected":
            continue
        drow = by.get(("directed", conv))
        if drow is None:
            continue
        df, uf = drow["trunk_gemm_flops"], r["trunk_gemm_flops"]
        if uf > 0.6 * df:
            raise RuntimeError(
                f"undirected trunk bond+angle GEMM FLOPs not >=40% below "
                f"directed: {uf:,} vs {df:,} (conv_impl={conv!r}, "
                f"Au/A={r['angle_rows']}/{drow['angle_rows']}) — "
                f"DESIGN.md §10")
        peak, d_peak = r["peak_temp_bytes"], drow["peak_temp_bytes"]
        if peak is None or d_peak is None:
            print(f"WARNING: no memory_analysis on this backend "
                  f"(conv={conv}); §10 memory bar not checked")
            continue
        if peak > d_peak:
            raise RuntimeError(
                f"bond_features='undirected' peak temp memory above "
                f"directed: {peak:,} > {d_peak:,} bytes "
                f"(conv_impl={conv!r}) — DESIGN.md §10 requires "
                f"undirected <= directed")
        slow = r["atoms_per_s"] < 0.9 * drow["atoms_per_s"]
        print(f"bond-features bar OK (conv={conv}): GEMM FLOPs {uf:,} vs "
              f"{df:,} (-{100 * (1 - uf / df):.0f}%); peak {peak:,} <= "
              f"{d_peak:,}"
              + (f"; NOTE atoms/s regressed: {r['atoms_per_s']:.0f} vs "
                 f"{drow['atoms_per_s']:.0f} (interpret-mode wall clock "
                 f"is not the §10 claim)" if slow else ""))


def run_donation_probe(batch_size: int = 16):
    """Compiled peak-memory delta from donating params/opt_state into the
    train step (the compile-cache step builders donate by default; this
    probe compiles the same step WITHOUT donation to track the delta).

    Reports per variant the compiled argument/output/temp/alias bytes;
    ``donation_saved_bytes`` is the aliased-buffer total XLA can reuse
    in place (0 without donation).
    """
    from repro.optim.adam import adam_init
    from repro.train.trainer import TrainConfig, make_chgnet_step_fns

    _, _, batch = _bench_batch(batch_size)
    cfg = CHGNetConfig(readout="direct")
    tcfg = TrainConfig(global_batch=batch_size)
    params = chgnet_init(jax.random.PRNGKey(0), cfg)
    opt = adam_init(params)

    rows = []
    for name, donate in (("donated", True), ("undonated", False)):
        fn, _, _ = make_chgnet_step_fns(cfg, tcfg, donate=donate)
        mem = fn.lower(params, opt, batch,
                       jnp.asarray(0)).compile().memory_analysis()
        alias = getattr(mem, "alias_size_in_bytes", None)
        rows.append({
            "name": f"iter_donation_{name}",
            "peak_temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "donation_saved_bytes": alias,
        })
    return rows


def run_precision_sweep(
    batch_size: int = 16,
    iters: int = 3,
    precisions: tuple = ("f32", "mixed", "bf16"),
    conv_impl: str = "unfused",
    check: bool = True,
):
    """Precision-policy sweep of one train step at FIXED capacities.

    Per policy: step wall time, atoms/s, and compiled peak temp memory.
    The DESIGN.md §4 acceptance bar: ``"mixed"`` must report strictly
    lower ``peak_temp_bytes`` than ``"f32"`` at equal capacities (bf16
    activation/workspace tiles).  The bar is ENFORCED on TPU only: XLA
    *CPU* emulates bf16 dots by upcasting both operands into f32
    conversion buffers, so on CPU the mixed row's peak temp is expected
    to sit ~10-15% ABOVE f32 — the sweep still records both rows there
    (trajectory tracking), it just reports instead of failing.  Wall time
    off-TPU measures the same emulation and is equally non-indicative.
    """
    ds, caps, batch = _bench_batch(batch_size)
    real_atoms = int(sum(c.num_atoms for c in ds.crystals))

    w = LossWeights()
    rows = []
    for prec in precisions:
        cfg = CHGNetConfig(readout="direct", conv_impl=conv_impl,
                           precision=prec)
        # params in the policy's param_dtype (f32 for f32/mixed — the
        # master-weight layout the Trainer uses)
        params = chgnet_init(jax.random.PRNGKey(0), cfg)
        grad_fn = jax.jit(jax.grad(
            lambda p, b, cfg=cfg: chgnet_loss_fn(p, cfg, b, w)[0]))
        compiled = grad_fn.lower(params, batch).compile()
        mem = compiled.memory_analysis()
        step_s = _time(grad_fn, params, batch, iters=iters)
        rows.append({
            "name": f"iter_precision_{prec}",
            "precision": prec,
            "conv_impl": conv_impl,
            "step_us": step_s * 1e6,
            "atoms_per_s": real_atoms / step_s,
            "peak_temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "note": (f"B={batch_size} atoms={real_atoms} "
                     f"caps=({caps.atoms},{caps.bonds},{caps.angles})"),
        })
    if check:
        _check_precision_bar(rows)
    return rows


def _check_precision_bar(rows, enforce: bool | None = None):
    """DESIGN.md §4 bar: mixed must show lower compiled peak temp memory
    than f32 at identical capacities.  Enforced (bench FAILS) on TPU,
    where bf16 operands are native MXU inputs; reported on CPU, where
    XLA's bf16 emulation upcasts GEMM operands into f32 conversion
    buffers and the comparison measures the emulator, not the policy."""
    if enforce is None:
        enforce = jax.default_backend() == "tpu"
    by = {r["precision"]: r["peak_temp_bytes"] for r in rows}
    f32_peak, mixed_peak = by.get("f32"), by.get("mixed")
    if f32_peak is None or mixed_peak is None:
        if "f32" in by and "mixed" in by:
            print("WARNING: no memory_analysis on this backend; "
                  "§4 precision memory bar not checked")
        return
    if mixed_peak >= f32_peak:
        msg = (f'precision="mixed" peak temp memory not below f32: '
               f"{mixed_peak:,} >= {f32_peak:,} bytes at equal "
               f"capacities (DESIGN.md §4 requires strictly lower on "
               f"TPU)")
        if enforce:
            raise RuntimeError(msg)
        print(f"NOTE ({jax.default_backend()} backend, bar not enforced): "
              + msg)
    else:
        print(f"precision bar OK: mixed {mixed_peak:,} < f32 "
              f"{f32_peak:,} peak temp bytes")


def run_stress_mode_sweep(
    batch_size: int = 16,
    iters: int = 3,
    stress_modes: tuple = ("mlp", "bond_virial"),
    conv_impls: tuple = ("unfused", "fused"),
    check: bool = True,
):
    """stress_mode x conv_impl sweep of one train step at FIXED capacities.

    The DESIGN.md §7 claim as a tracked trajectory: per combo, step wall
    time, atoms/s, and compiled peak temp memory for the mlp stress head
    vs the unfused bond-virial reference vs the fused-epilogue bond
    virial.  Acceptance bars:

      - ENFORCED everywhere (interpret mode / CPU too — the whole path is
        f32, no emulation caveat): the fused bond-virial row must not
        exceed the unfused bond-virial row's peak temp memory — the
        epilogue reuses the force readout's VMEM-resident operands, so
        the (E, 3, 3) outer-product workspace must never appear;
      - atoms/s vs the mlp head is a <= 5% regression bar, enforced on
        TPU only (interpret-mode wall clock measures the Pallas
        interpreter, not Mosaic) and reported elsewhere.
    """
    ds, caps, batch = _bench_batch(batch_size)
    real_atoms = int(sum(c.num_atoms for c in ds.crystals))

    w = LossWeights()
    rows = []
    for conv in conv_impls:
        for mode in stress_modes:
            cfg = CHGNetConfig(readout="direct", conv_impl=conv,
                               stress_mode=mode)
            params = chgnet_init(jax.random.PRNGKey(0), cfg)
            grad_fn = jax.jit(jax.grad(
                lambda p, b, cfg=cfg: chgnet_loss_fn(p, cfg, b, w)[0]))
            compiled = grad_fn.lower(params, batch).compile()
            mem = compiled.memory_analysis()
            step_s = _time(grad_fn, params, batch, iters=iters)
            rows.append({
                "name": f"iter_stress_{mode}_conv_{conv}",
                "stress_mode": mode,
                "conv_impl": conv,
                "step_us": step_s * 1e6,
                "atoms_per_s": real_atoms / step_s,
                "peak_temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "argument_bytes": getattr(mem, "argument_size_in_bytes",
                                          None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "note": (f"B={batch_size} atoms={real_atoms} "
                         f"caps=({caps.atoms},{caps.bonds},{caps.angles})"),
            })
    if check:
        _check_stress_mode_bar(rows)
    return rows


def _check_stress_mode_bar(rows, enforce_throughput: bool | None = None):
    """DESIGN.md §7 bars (see run_stress_mode_sweep docstring): the memory
    bar FAILS the bench step on every backend; the atoms/s bar fails on
    TPU and reports elsewhere."""
    if enforce_throughput is None:
        enforce_throughput = jax.default_backend() == "tpu"
    by = {(r["stress_mode"], r["conv_impl"]): r for r in rows}
    fused = by.get(("bond_virial", "fused"))
    unfused = by.get(("bond_virial", "unfused"))
    if fused is not None and unfused is not None:
        fp, up = fused["peak_temp_bytes"], unfused["peak_temp_bytes"]
        if fp is None or up is None:
            print("WARNING: no memory_analysis on this backend; "
                  "§7 memory bar not checked")
        elif fp > up:
            raise RuntimeError(
                f"fused bond-virial peak temp memory exceeds the unfused "
                f"reference: {fp:,} > {up:,} bytes — DESIGN.md §7 requires "
                f"the epilogue to add no workspace (the (E,3,3) outer-"
                f"product tensor must never materialize)")
        else:
            print(f"stress-mode memory bar OK: fused virial {fp:,} <= "
                  f"unfused virial {up:,} peak temp bytes")
    for conv in ("unfused", "fused"):
        vir, mlp = by.get(("bond_virial", conv)), by.get(("mlp", conv))
        if vir is None or mlp is None:
            continue
        if vir["atoms_per_s"] < 0.95 * mlp["atoms_per_s"]:
            msg = (f"bond_virial atoms/s regressed >5% vs the mlp stress "
                   f"head: {vir['atoms_per_s']:.0f} vs "
                   f"{mlp['atoms_per_s']:.0f} (conv_impl={conv!r}) — "
                   f"DESIGN.md §7")
            if enforce_throughput:
                raise RuntimeError(msg)
            print(f"NOTE ({jax.default_backend()} backend, throughput bar "
                  f"not enforced): " + msg)
        else:
            print(f"stress-mode throughput OK (conv={conv}): virial "
                  f"{vir['atoms_per_s']:.0f} vs mlp "
                  f"{mlp['atoms_per_s']:.0f} atoms/s")


def _check_memory_bar(rows):
    """Enforce the §3 bar so a regression FAILS the CI bench step instead
    of silently landing in the artifact: every fused row must undercut its
    unfused counterpart's peak temp memory at identical capacities."""
    by = {(r["conv_impl"], r["agg_impl"]): r["peak_temp_bytes"]
          for r in rows}
    for (conv, agg), peak in by.items():
        if conv != "fused":
            continue
        unfused = by.get(("unfused", agg))
        if peak is None or unfused is None:
            print(f"WARNING: no memory_analysis on this backend "
                  f"(agg={agg}); §3 memory bar not checked")
            continue
        if peak >= unfused:
            raise RuntimeError(
                f"conv_impl='fused' peak temp memory regressed: "
                f"{peak:,} >= {unfused:,} bytes (agg_impl={agg!r}) — "
                f"DESIGN.md §3 requires strictly lower")


def run_residency_sweep(
    batch_size: int = 16,
    iters: int = 3,
    residencies: tuple = ("vmem", "hbm"),
    conv_impls: tuple = ("unfused", "fused"),
    rungs: tuple = (1, 2, 4),
    check: bool = True,
):
    """table_residency x conv_impl x capacity-rung sweep (DESIGN.md §9).

    One jitted train step per combo; capacity rungs pack the SAME real
    crystals at k-scaled padded capacities, walking the batch toward the
    ladder shapes a 10k-atom structure lands on.  ``agg_impl="pallas"``
    keeps a residency-sensitive kernel in the unfused rows too.  Per row:
    atoms/s, compiled peak temp bytes (informational off-TPU), the padded
    operand-table bytes, and the DETERMINISTIC resident-VMEM estimate
    (``repro.kernels.ops.resident_vmem_estimate``) — interpret mode has
    no physical VMEM, so the enforced bar compares the same closed form
    the auto-selection heuristic trusts (kept honest against the wrapper
    padding math by tests/test_hbm_residency.py).

    ENFORCED bar (``_check_residency_bar``): at the LARGEST rung whose
    vmem-tier operand tables still fit the budget, every hbm row must
    show strictly lower resident VMEM than its vmem counterpart at the
    same (conv_impl, rung).
    """
    from repro.kernels.ops import (
        estimate_table_bytes,
        resident_vmem_estimate,
        vmem_budget_bytes,
    )

    ds, base_caps, _ = _bench_batch(batch_size)
    real_atoms = int(sum(c.num_atoms for c in ds.crystals))
    w = LossWeights()
    params = chgnet_init(jax.random.PRNGKey(0), CHGNetConfig())
    budget = vmem_budget_bytes()
    rows = []
    for k in rungs:
        caps = base_caps.scaled(k)
        batch = batch_crystals(ds.crystals, ds.graphs, caps)
        dim = CHGNetConfig().dim
        table_bytes = estimate_table_bytes(caps.atoms, caps.bonds,
                                           caps.angles, dim)
        for conv in conv_impls:
            for resid in residencies:
                cfg = CHGNetConfig(readout="direct", conv_impl=conv,
                                   agg_impl="pallas",
                                   table_residency=resid)
                grad_fn = jax.jit(jax.grad(
                    lambda p, b, cfg=cfg: chgnet_loss_fn(p, cfg, b, w)[0]))
                compiled = grad_fn.lower(params, batch).compile()
                mem = compiled.memory_analysis()
                step_s = _time(grad_fn, params, batch, iters=iters)
                rows.append({
                    "name": f"iter_resid_{resid}_conv_{conv}_x{k}",
                    "table_residency": resid,
                    "conv_impl": conv,
                    "rung": k,
                    "step_us": step_s * 1e6,
                    "atoms_per_s": real_atoms / step_s,
                    "peak_temp_bytes": getattr(mem, "temp_size_in_bytes",
                                               None),
                    "table_bytes": table_bytes,
                    "fits_vmem": table_bytes <= budget,
                    "resident_vmem_bytes": resident_vmem_estimate(
                        resid, caps.atoms, caps.bonds, caps.angles, dim),
                    "note": (f"B={batch_size} atoms={real_atoms} caps="
                             f"({caps.atoms},{caps.bonds},{caps.angles}) "
                             f"budget={budget}"),
                })
    if check:
        _check_residency_bar(rows)
    return rows


def _check_residency_bar(rows):
    """DESIGN.md §9 bar, enforced so a regression FAILS the CI bench step:
    at the largest capacity rung the vmem tier still fits, the hbm tier's
    resident VMEM (double-buffered scratch only) must be strictly below
    the vmem tier's (whole operand tables)."""
    fitting = [r["rung"] for r in rows
               if r["table_residency"] == "vmem" and r["fits_vmem"]]
    if not fitting:
        rung = min(r["rung"] for r in rows)
        print(f"WARNING: no rung fits the vmem budget; §9 bar checked at "
              f"the smallest rung x{rung} instead")
    else:
        rung = max(fitting)
    by = {(r["table_residency"], r["conv_impl"]): r
          for r in rows if r["rung"] == rung}
    for (resid, conv), r in by.items():
        if resid != "hbm":
            continue
        v = by.get(("vmem", conv))
        if v is None:
            continue
        hb, vb = r["resident_vmem_bytes"], v["resident_vmem_bytes"]
        if hb >= vb:
            raise RuntimeError(
                f"table_residency='hbm' resident VMEM not below vmem tier "
                f"at rung x{rung}: {hb:,} >= {vb:,} bytes "
                f"(conv_impl={conv!r}) — DESIGN.md §9 requires strictly "
                f"lower")
        print(f"residency bar OK (conv={conv}, rung x{rung}): "
              f"hbm {hb:,} < vmem {vb:,} resident bytes "
              f"(tables {r['table_bytes']:,})")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--sweep-only", action="store_true",
                    help="skip the Fig. 8 stage loop (CI artifact mode)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write results as JSON (CI artifact)")
    ap.add_argument("--precision", default=None, metavar="POLICIES",
                    help="comma-separated precision policies to sweep "
                         "(e.g. f32,mixed,bf16); atoms/s + compiled "
                         "peak memory per policy (DESIGN.md §4)")
    ap.add_argument("--bond-store", default=None, metavar="STORES",
                    help="comma-separated bond stores to sweep (e.g. "
                         "directed,undirected); atoms/s + compiled peak "
                         "memory + Eu/E bond-tensor bytes per store x "
                         "conv_impl, with the undirected<directed bars "
                         "enforced (DESIGN.md §5)")
    ap.add_argument("--bond-features", default=None, metavar="FEATURES",
                    help="comma-separated trunk compute representations to "
                         "sweep (e.g. directed,undirected); atoms/s + "
                         "compiled peak memory + bond+angle GEMM FLOPs per "
                         "representation x conv_impl on the undirected "
                         "store, with the >=40%% FLOP reduction and "
                         "undirected<=directed peak-temp bars enforced "
                         "(DESIGN.md §10)")
    ap.add_argument("--table-residency", default=None, metavar="TIERS",
                    help="comma-separated residency tiers to sweep (e.g. "
                         "vmem,hbm); atoms/s + table bytes + resident-VMEM "
                         "estimate per tier x conv_impl x capacity rung, "
                         "with the hbm<vmem resident-VMEM bar enforced at "
                         "the largest vmem-feasible rung (DESIGN.md §9)")
    ap.add_argument("--stress-mode", default=None, metavar="MODES",
                    help="comma-separated stress modes to sweep (e.g. "
                         "mlp,bond_virial); atoms/s + compiled peak memory "
                         "per mode x conv_impl, with the fused-virial <= "
                         "unfused-virial memory bar enforced (DESIGN.md §7)")
    args = ap.parse_args()
    bs, iters = (8, 1) if args.quick else (16, 3)
    stage_rows = [] if args.sweep_only else run(batch_size=bs, iters=iters)
    sweep_rows = run_conv_sweep(
        batch_size=bs, iters=iters,
        fused_agg_impls=("scatter",) if args.quick else None)
    precision_rows = [] if args.precision is None else run_precision_sweep(
        batch_size=bs, iters=iters,
        precisions=tuple(args.precision.split(",")))
    store_rows = [] if args.bond_store is None else run_bond_store_sweep(
        batch_size=bs, iters=iters,
        bond_stores=tuple(args.bond_store.split(",")),
        conv_impls=("unfused",) if args.quick else ("unfused", "fused"))
    feat_rows = [] if args.bond_features is None else \
        run_bond_features_sweep(
            batch_size=bs, iters=iters,
            bond_features=tuple(args.bond_features.split(",")),
            conv_impls=("unfused",) if args.quick else ("unfused", "fused"))
    stress_rows = [] if args.stress_mode is None else run_stress_mode_sweep(
        batch_size=bs, iters=iters,
        stress_modes=tuple(args.stress_mode.split(",")))
    resid_rows = [] if args.table_residency is None else run_residency_sweep(
        batch_size=bs, iters=iters,
        residencies=tuple(args.table_residency.split(",")),
        conv_impls=("fused",) if args.quick else ("unfused", "fused"),
        rungs=(1, 2) if args.quick else (1, 2, 4))
    # the probe's two extra train-step compiles only pay off when the
    # numbers land in the artifact
    donation_rows = run_donation_probe(batch_size=bs) if args.json else []
    for r in stage_rows:
        print(",".join(map(str, r)))
    for r in sweep_rows + precision_rows + store_rows + feat_rows \
            + stress_rows + resid_rows:
        print(f"{r['name']},{r['step_us']},peak_temp={r['peak_temp_bytes']}"
              f",atoms_per_s={r['atoms_per_s']:.0f}")
    for r in donation_rows:
        print(f"{r['name']},peak_temp={r['peak_temp_bytes']},"
              f"donation_saved={r['donation_saved_bytes']}")
    if args.json:
        payload = {
            "stages": [{"name": n, "us_per_iter": t, "note": note}
                       for n, t, note in stage_rows],
            "sweep": sweep_rows,
            "precision": precision_rows,
            "bond_store": store_rows,
            "bond_features": feat_rows,
            "stress_mode": stress_rows,
            "table_residency": resid_rows,
            "donation": donation_rows,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
