"""Table I / Fig. 6 reproduction: convergence on the synthetic dataset.

Three model versions (reference / FastCHGNet w-o head / F-S head) trained
for a few hundred steps; final E/F/S/M MAEs reported. Plus the Fig. 6
LR-scaling ablation: large batch with default LR vs Eq. 14-scaled LR.
"""
from __future__ import annotations

import itertools
import time

import numpy as np

from repro.configs import chgnet_mptrj as C
from repro.batching import capacity_for
from repro.data import BatchIterator, SyntheticConfig, make_dataset
from repro.train import TrainConfig, Trainer


def _train(model_cfg, ds, caps, *, steps, batch, lr_k=128, seed=0):
    tcfg = TrainConfig(global_batch=batch, total_steps=steps, lr_k=lr_k,
                       loss=C.LOSS)
    tr = Trainer(model_cfg, tcfg, seed=seed)
    batches = itertools.islice(
        itertools.cycle(iter(BatchIterator(ds, batch, 1, caps, seed=seed))),
        steps)
    t0 = time.perf_counter()
    hist = tr.train(batches)
    dt = (time.perf_counter() - t0) / max(len(hist), 1)
    tail = hist[-10:]
    return dt, {k: float(np.mean([h[k] for h in tail]))
                for k in ("mae_e_per_atom", "mae_f", "mae_s", "mae_m")}


def run(steps: int = 120, batch: int = 16, n_crystals: int = 128):
    ds = make_dataset(SyntheticConfig(num_crystals=n_crystals, max_atoms=24,
                                      seed=0))
    # size capacities for the LARGEST batch used (the Fig. 6 ablation
    # quadruples the batch on a single device)
    caps = capacity_for(ds, batch * 4)
    rows = []
    for name, cfg in [("reference", C.REFERENCE),
                      ("fast_wo_head", C.FAST_WO_HEAD),
                      ("fast_fs_head", C.FAST_FS_HEAD)]:
        dt, mae = _train(cfg, ds, caps, steps=steps, batch=batch)
        rows.append((f"tab1_{name}", dt * 1e6,
                     f"maeE={mae['mae_e_per_atom'] * 1e3:.1f}meV/atom;"
                     f"maeF={mae['mae_f'] * 1e3:.0f}meV/A;"
                     f"maeS={mae['mae_s']:.3f}GPa;"
                     f"maeM={mae['mae_m'] * 1e3:.0f}mmuB"))

    # Fig. 6: large-batch LR scaling (Eq. 14) vs default LR
    big = batch * 4
    dt_d, mae_d = _train(C.FAST_FS_HEAD, ds, caps, steps=steps, batch=big,
                         lr_k=big)   # k = batch => LR stays 3e-4 (default)
    dt_s, mae_s = _train(C.FAST_FS_HEAD, ds, caps, steps=steps, batch=big,
                         lr_k=128)   # Eq. 14 scaling
    rows.append((f"fig6_default_lr_b{big}", dt_d * 1e6,
                 f"maeE={mae_d['mae_e_per_atom'] * 1e3:.1f}meV/atom"))
    rows.append((f"fig6_scaled_lr_b{big}", dt_s * 1e6,
                 f"maeE={mae_s['mae_e_per_atom'] * 1e3:.1f}meV/atom"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
