"""MD serving benchmark: ``repro.serve`` engine (Verlet skin reuse +
bucketed compile cache + multi-replica batching) vs the naive serve loop
(full neighbor-list rebuild every step, one serve call per replica — the
seed's ``examples/serve_md.py``).

Reports replica-steps/sec and the padding-waste ratio of each path.
"""
from __future__ import annotations

import copy
import time

import jax
import numpy as np

from repro.batching import BatchCapacities, batch_crystals, padding_waste
from repro.configs import chgnet_mptrj as C
from repro.core.chgnet import chgnet_apply, chgnet_init
from repro.core.neighbors import Crystal, build_graph
from repro.serve import BatchedMD, ServeEngine


def _make_crystals(replicas: int, atoms: int) -> list[Crystal]:
    crystals = []
    for i in range(replicas):
        rng = np.random.default_rng(i)
        n = atoms + 2 * (i % 3)
        a = (n * 14.0) ** (1 / 3)
        crystals.append(Crystal(
            lattice=np.eye(3) * a,
            frac_coords=rng.random((n, 3)),
            atomic_numbers=rng.integers(1, 60, n),
        ))
    return crystals


def _naive_loop(params, cfg, crystals: list[Crystal], steps: int, dt: float):
    """Rebuild-every-step baseline: per replica, per step, build the full
    periodic neighbor list in host Python and run one serve call."""
    serve = jax.jit(lambda p, b: chgnet_apply(p, cfg, b))
    states = []
    for c in crystals:
        g = build_graph(c)
        caps = BatchCapacities(c.num_atoms + 4,
                               int(g.num_bonds * 1.5) + 64,
                               int(g.num_angles * 2.0) + 64)
        states.append({
            "crystal": c, "caps": caps,
            "vel": np.zeros((c.num_atoms, 3)),
            "inv_lat": np.linalg.inv(c.lattice),
        })
        # warm the per-shape compile before timing (both paths are timed hot)
        jax.block_until_ready(
            serve(params, batch_crystals([c], [g], caps))["forces"])

    waste = []
    t0 = time.perf_counter()
    for _ in range(steps):
        for st in states:
            c = st["crystal"]
            g = build_graph(c)
            batch = batch_crystals([c], [g], st["caps"])
            waste.append(padding_waste(batch))
            out = serve(params, batch)
            jax.block_until_ready(out["forces"])
            f = np.asarray(out["forces"])[: c.num_atoms]
            st["vel"] += f * dt
            cart = c.cart_coords() + st["vel"] * dt
            c.frac_coords = (cart @ st["inv_lat"]) % 1.0
    elapsed = time.perf_counter() - t0
    return elapsed, float(np.mean(waste))


def _engine_loop(params, cfg, crystals: list[Crystal], steps: int, dt: float,
                 skin: float):
    serve = ServeEngine.for_structures(params, cfg, crystals)
    md = BatchedMD(serve, crystals, dt=dt, skin=skin)
    md.step(1)  # warm the compile cache before timing
    t0 = time.perf_counter()
    md.step(steps)
    elapsed = time.perf_counter() - t0
    return elapsed, md.stats()


def run(steps: int = 25, replicas: int = 4, atoms: int = 14,
        dt: float = 1e-3, skin: float = 0.5):
    cfg = C.FAST_FS_HEAD
    params = chgnet_init(jax.random.PRNGKey(0), cfg)
    base = _make_crystals(replicas, atoms)

    t_naive, waste_naive = _naive_loop(
        params, cfg, copy.deepcopy(base), steps, dt)
    t_engine, stats = _engine_loop(
        params, cfg, copy.deepcopy(base), steps, dt, skin)

    n_work = steps * replicas
    rate_naive = n_work / t_naive
    rate_engine = n_work / t_engine
    rebuild_frac = stats["nlist_rebuilds"] / max(1, stats["nlist_updates"])
    return [
        ("serve_naive", t_naive / n_work * 1e6,
         f"steps_per_s={rate_naive:.1f};waste={waste_naive:.3f}"),
        ("serve_engine", t_engine / n_work * 1e6,
         f"steps_per_s={rate_engine:.1f};"
         f"waste={stats['mean_padding_waste']:.3f};"
         f"rebuild_frac={rebuild_frac:.3f};"
         f"compiled={stats['compile_cache_entries']};"
         f"speedup={rate_engine / rate_naive:.2f}x"),
    ]


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
