"""Benchmark harness — one entry per paper table/figure + the roofline.

Prints ``name,us_per_call,derived`` CSV (one line per measurement).
    PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: iteration,sampler,md,serve,"
                         "convergence,scaling,roofline,kernels,fault")
    ap.add_argument("--quick", action="store_true",
                    help="smaller sizes / fewer iters")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    from benchmarks import (
        bench_convergence, bench_fault, bench_iteration, bench_kernels,
        bench_md, bench_sampler, bench_scaling, bench_serve, roofline,
    )

    suites = {
        "fault": lambda: bench_fault.run(quick=args.quick),
        "sampler": lambda: bench_sampler.run(),
        "kernels": lambda: bench_kernels.run(quick=args.quick),
        "md": lambda: bench_md.run(iters=3 if args.quick else 5),
        "serve": lambda: bench_serve.run(steps=10 if args.quick else 25),
        "iteration": lambda: bench_iteration.run(
            batch_size=8 if args.quick else 16),
        "convergence": lambda: bench_convergence.run(
            steps=40 if args.quick else 60),  # 60: ~15 min on 1 CPU core
        "scaling": lambda: bench_scaling.run(
            device_counts=(1, 2) if args.quick else (1, 2, 4)),
        "roofline": lambda: roofline.run(),
    }

    print("name,us_per_call,derived")
    failures = 0
    for name, fn in suites.items():
        if only and name not in only:
            continue
        try:
            for row in fn():
                print(",".join(str(x) for x in row), flush=True)
        except Exception:  # noqa: BLE001 — report and continue
            failures += 1
            print(f"{name}_FAILED,0,error", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
