"""§Roofline: build the three-term roofline table from the dry-run records
(benchmarks/results/dryrun.json) and write markdown + CSV artifacts."""
from __future__ import annotations

import os

RESULTS = os.path.join(os.path.dirname(__file__), "results")


def run():
    from repro.analysis.roofline import load_and_build, to_markdown

    path = os.path.join(RESULTS, "dryrun.json")
    if not os.path.exists(path):
        return [("roofline_missing", 0.0,
                 "run `python -m repro.launch.dryrun --all` first")]
    rows, recs = load_and_build(path)
    md = to_markdown(rows)
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, "roofline.md"), "w") as f:
        f.write(md + "\n")

    out = []
    for r in rows:
        if r.mesh != "16x16":
            continue  # roofline table is single-pod per the brief
        bound = max(r.compute_s, r.memory_s, r.collective_s)
        frac = r.compute_s / bound if bound else 0.0
        out.append((
            f"roofline_{r.arch}_{r.shape}",
            bound * 1e6,  # bound time per step-chip, us
            f"dominant={r.dominant};frac={frac:.2f};"
            f"useful={r.useful_frac:.2f};mem={r.mem_gib:.1f}GiB",
        ))
    skips = sum(1 for rec in recs if str(rec["status"]).startswith("skip"))
    out.append(("roofline_cells", float(len(rows)), f"skips={skips}"))
    return out


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
