"""Fig. 9 reproduction: load-imbalance CoV, default vs load-balance sampler
(paper: 0.186 -> 0.064 at minibatch 32 on 4 GPUs)."""
from __future__ import annotations

import time

import numpy as np

from repro.data import (
    DefaultSampler, LoadBalanceSampler, SyntheticConfig,
    cov_of_device_loads, device_loads, make_dataset,
)


def run(num_crystals: int = 512, batch: int = 32, devices: int = 4):
    ds = make_dataset(SyntheticConfig(num_crystals=num_crystals, seed=0))
    counts = ds.feature_counts()
    t0 = time.perf_counter()
    cov_d, cov_lb = [], []
    for (_, sd), (_, slb) in zip(
        DefaultSampler(counts, 0).epoch(batch, devices),
        LoadBalanceSampler(counts, 0).epoch(batch, devices),
    ):
        cov_d.append(cov_of_device_loads(device_loads(counts, sd)))
        cov_lb.append(cov_of_device_loads(device_loads(counts, slb)))
    dt = (time.perf_counter() - t0) * 1e6 / max(len(cov_d), 1)
    return [
        ("fig9_cov_default", dt, f"cov={np.mean(cov_d):.3f}"),
        ("fig9_cov_balanced", dt, f"cov={np.mean(cov_lb):.3f}"),
        ("fig9_cov_reduction", dt,
         f"ratio={np.mean(cov_d) / max(np.mean(cov_lb), 1e-9):.2f}x"),
    ]


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
