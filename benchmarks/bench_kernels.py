"""Kernel microbenchmarks: fused Pallas (interpret on CPU) vs jnp oracle.

On CPU the *absolute* numbers reflect the interpreter, not Mosaic — the
purpose here is regression coverage of wrapper overhead + the oracle
path's wall time. HLO-level fusion quality is covered by the roofline.

The aggregation sweep (scatter vs matmul vs sorted vs pallas) runs on a
*realistic* bond/angle distribution — a packed synthetic-dataset batch, so
segment sizes follow the long-tailed per-atom coordination / per-bond
angle-count statistics the model actually sees, not uniform random ids.

``--json PATH`` dumps the rows as JSON (uploaded as a CI artifact).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _time(fn, *args, iters=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run(quick: bool = False):
    rng = np.random.default_rng(0)
    n = 4096 if quick else 16384
    rows = []

    d = jnp.asarray(rng.uniform(0.2, 6.0, (n,)), jnp.float32)
    freqs = jnp.arange(1, 32, dtype=jnp.float32) * jnp.pi
    jit_ref = jax.jit(lambda dd: ref.fused_rbf_ref(dd, freqs, 6.0, 8))
    rows.append(("kern_rbf_oracle_jit", _time(jit_ref, d), f"n={n}"))

    th = jnp.asarray(rng.uniform(0, np.pi, (n,)), jnp.float32)
    jit_f = jax.jit(lambda tt: ref.fused_fourier_ref(tt, 31))
    rows.append(("kern_fourier_oracle_jit", _time(jit_f, th), f"n={n}"))

    m = 2048 if quick else 8192
    x = jnp.asarray(rng.normal(0, 1, (m, 256)), jnp.float32)
    wc = jnp.asarray(rng.normal(0, .1, (256, 64)), jnp.float32)
    wg = jnp.asarray(rng.normal(0, .1, (256, 64)), jnp.float32)
    z = jnp.zeros(64)
    o = jnp.ones(64)
    ref_two = jax.jit(lambda xx: ref.fused_gated_mlp_ref(
        xx, wc, z, wg, z, o, z, o, z))
    rows.append(("kern_gatedmlp_oracle_jit", _time(ref_two, x), f"m={m}"))

    rows.extend(run_aggregation(quick=quick))
    return rows


def run_aggregation(quick: bool = False, dim: int = 64):
    """scatter vs matmul vs sorted vs pallas on a packed real-graph batch."""
    from repro.core.interaction import segment_aggregate
    from repro.data import BatchIterator, SyntheticConfig, capacity_for, \
        make_dataset

    ds = make_dataset(SyntheticConfig(
        num_crystals=16 if quick else 64,
        max_atoms=24 if quick else 48, seed=0,
    ))
    per_batch = 4 if quick else 16
    caps = capacity_for(ds, per_batch, align=64)
    batch = next(iter(BatchIterator(ds, per_batch, 1, caps)))

    rng = np.random.default_rng(1)
    rows = []
    for name, ids, n_seg, mask, offs in (
        ("bond", batch.bond_center, batch.atom_cap, batch.bond_mask,
         batch.bond_offsets),
        ("angle", batch.angle_ij, batch.bond_cap, batch.angle_mask,
         batch.angle_offsets),
    ):
        v = jnp.asarray(rng.normal(0, 1, (ids.shape[0], dim)), jnp.float32)
        note = (f"E={int(mask.sum())}/{ids.shape[0]} S={n_seg} D={dim}")
        for impl in ("scatter", "matmul", "sorted", "pallas"):
            fn = jax.jit(lambda vv, impl=impl, ids=ids, n_seg=n_seg,
                         mask=mask, offs=offs: segment_aggregate(
                             vv, ids, n_seg, mask, impl, offsets=offs))
            rows.append((f"agg_{name}_{impl}", _time(fn, v), note))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as JSON (CI artifact)")
    args = ap.parse_args()
    rows = run(quick=args.quick)
    for r in rows:
        print(",".join(map(str, r)))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(
                [{"name": n, "us_per_call": t, "note": note}
                 for n, t, note in rows], f, indent=2)
