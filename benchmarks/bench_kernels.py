"""Kernel microbenchmarks: fused Pallas (interpret on CPU) vs jnp oracle.

On CPU the *absolute* numbers reflect the interpreter, not Mosaic — the
purpose here is regression coverage of wrapper overhead + the oracle
path's wall time. HLO-level fusion quality is covered by the roofline.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _time(fn, *args, iters=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run(quick: bool = False):
    rng = np.random.default_rng(0)
    n = 4096 if quick else 16384
    rows = []

    d = jnp.asarray(rng.uniform(0.2, 6.0, (n,)), jnp.float32)
    freqs = jnp.arange(1, 32, dtype=jnp.float32) * jnp.pi
    jit_ref = jax.jit(lambda dd: ref.fused_rbf_ref(dd, freqs, 6.0, 8))
    rows.append(("kern_rbf_oracle_jit", _time(jit_ref, d), f"n={n}"))

    th = jnp.asarray(rng.uniform(0, np.pi, (n,)), jnp.float32)
    jit_f = jax.jit(lambda tt: ref.fused_fourier_ref(tt, 31))
    rows.append(("kern_fourier_oracle_jit", _time(jit_f, th), f"n={n}"))

    m = 2048 if quick else 8192
    x = jnp.asarray(rng.normal(0, 1, (m, 256)), jnp.float32)
    wc = jnp.asarray(rng.normal(0, .1, (256, 64)), jnp.float32)
    wg = jnp.asarray(rng.normal(0, .1, (256, 64)), jnp.float32)
    z = jnp.zeros(64)
    o = jnp.ones(64)
    ref_two = jax.jit(lambda xx: ref.fused_gated_mlp_ref(
        xx, wc, z, wg, z, o, z, o, z))
    rows.append(("kern_gatedmlp_oracle_jit", _time(ref_two, x), f"m={m}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
