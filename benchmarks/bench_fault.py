"""Fault-injection benchmark (DESIGN.md §8).

Two phases, both driven by the ``repro.runtime.chaos`` harness against a
tiny CHGNet so the numbers isolate the resilience machinery, not the
model:

  A. Checkpoint overhead: wall time of the same training run with no
     checkpoints, sync checkpoints, and async checkpoints at
     ``ckpt_every=1`` (worst case), plus an equivalence check — the sync
     and async runs must restore to bit-identical params (the async
     writer snapshots on the loop thread and serializes the same bytes).
     Report-only: CPU timing noise makes an async<sync bar flaky, but
     the JSON artifact tracks the trajectory.

  B. Recovery matrix (ENFORCED): for each scenario — step-loop crash,
     corrupt-newest-checkpoint fallback, NaN-streak rollback, SIGTERM
     preemption — run to completion through the restart/rollback/resume
     machinery and measure

       rework = (optimizer steps executed) - (final step)

     i.e. how many steps were replayed or wasted.  The bar
     ``rework <= budget`` (budget = ckpt_every, doubled when the newest
     checkpoint was corrupted, + the injected streak length for the NaN
     scenario) is ENFORCED: exit code 1 on violation.  This is the
     at-least-once-with-bounded-rework contract the checkpoint cadence
     promises.

    PYTHONPATH=src python benchmarks/bench_fault.py --quick \
        --json bench_fault.json
"""
from __future__ import annotations

import argparse
import itertools
import json
import os
import shutil
import sys
import tempfile
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

from repro.batching import capacity_for  # noqa: E402
from repro.core.chgnet import CHGNetConfig  # noqa: E402
from repro.data import (  # noqa: E402
    BatchIterator, SyntheticConfig, make_dataset,
)
from repro.runtime import (  # noqa: E402
    ChaosMonkey, ChaosSchedule, GracefulShutdown, PreemptionError,
    restore_checkpoint,
)
from repro.train import TrainConfig, Trainer  # noqa: E402

BATCH = 4


def _setup(quick: bool):
    ds = make_dataset(SyntheticConfig(
        num_crystals=16, max_atoms=10 if quick else 16, seed=0))
    caps = capacity_for(ds, BATCH)
    model_cfg = CHGNetConfig(dim=16, num_blocks=1)
    return ds, caps, model_cfg


def _trainer(model_cfg, *, steps, ckpt_dir, ckpt_every, async_ckpt=False,
             rollback=False, shutdown=None):
    train_cfg = TrainConfig(
        global_batch=BATCH, total_steps=steps,
        rollback_on_divergence=rollback, divergence_nan_streak=2)
    return Trainer(model_cfg, train_cfg, ckpt_dir=ckpt_dir,
                   ckpt_every=ckpt_every, async_ckpt=async_ckpt,
                   shutdown=shutdown)


def _run_to_completion(ds, caps, model_cfg, *, steps, ckpt_dir, ckpt_every,
                       chaos=None, rollback=False, async_ckpt=False,
                       max_attempts=10):
    """Drive a run through faults until it reaches ``steps`` optimizer
    steps, replicating the launcher's restart loop but counting every
    executed step so rework is measurable."""
    monkey = ChaosMonkey(ChaosSchedule.parse(chaos or ""),
                         ckpt_dir=ckpt_dir)
    shutdown = GracefulShutdown().install()
    executed = attempts = 0
    recovery_s = 0.0
    t0 = time.perf_counter()
    try:
        while True:
            attempts += 1
            if attempts > max_attempts:
                raise RuntimeError(
                    f"no completion after {max_attempts} attempts")
            r0 = time.perf_counter()
            tr = _trainer(model_cfg, steps=steps, ckpt_dir=ckpt_dir,
                          ckpt_every=ckpt_every, async_ckpt=async_ckpt,
                          rollback=rollback, shutdown=shutdown)
            tr.maybe_restore()
            if attempts > 1:
                recovery_s += time.perf_counter() - r0
            it = BatchIterator(ds, BATCH, 1, caps, seed=0,
                               tag_indices=rollback)
            tr.on_quarantine = it.add_quarantine
            stream = monkey.wrap_batches(
                itertools.islice(itertools.cycle(iter(it)),
                                 max(steps - tr.step, 0)),
                start_step=tr.step)
            try:
                hist = tr.train(stream, fault_injector=monkey)
                executed += len(hist)
            except PreemptionError as exc:
                executed += len(getattr(exc, "partial_history", []))
                shutdown.requested = False  # "scheduler relaunch"
                continue
            except Exception as exc:  # noqa: BLE001 — injected faults
                executed += len(getattr(exc, "partial_history", []))
                tr.close()  # land any queued async write before restore
                continue
            finally:
                # trip steps execute a train step but never reach history
                if tr.sentinel is not None:
                    executed += tr.sentinel.trips
            if tr.step >= steps:
                tr.save(wait=True)
                tr.close()
                break
            # rollback consumed stream batches: new attempt, fresh stream
    finally:
        shutdown.uninstall()
    return {
        "final_step": tr.step,
        "executed": executed,
        "rework": executed - tr.step,
        "attempts": attempts,
        "recovery_s": round(recovery_s, 4),
        "wall_s": round(time.perf_counter() - t0, 4),
        "chaos_fired": [f"{k}@{s}" for k, s in monkey.log_events],
    }


# ---------------------------------------------------------------------------
# Phase A: checkpoint overhead + sync/async equivalence
# ---------------------------------------------------------------------------

def run_overhead(ds, caps, model_cfg, *, steps, workdir) -> dict:
    # warm the shared compile cache first so the "none" baseline measures
    # steps, not the one-time trace
    warm = _trainer(model_cfg, steps=2, ckpt_dir=None, ckpt_every=1)
    warm.train(itertools.islice(
        itertools.cycle(iter(BatchIterator(ds, BATCH, 1, caps, seed=0))), 2))

    def one(mode):
        ckpt_dir = (None if mode == "none"
                    else os.path.join(workdir, f"ovh_{mode}"))
        tr = _trainer(model_cfg, steps=steps, ckpt_dir=ckpt_dir,
                      ckpt_every=1, async_ckpt=mode == "async")
        it = BatchIterator(ds, BATCH, 1, caps, seed=0)
        stream = itertools.islice(itertools.cycle(iter(it)), steps)
        t0 = time.perf_counter()
        tr.train(stream)
        loop_s = time.perf_counter() - t0
        tr.flush_checkpoints()
        total_s = time.perf_counter() - t0
        tr.close()
        return {"loop_s": round(loop_s, 4), "total_s": round(total_s, 4),
                "ckpt_dir": ckpt_dir}

    out = {m: one(m) for m in ("none", "sync", "async")}
    # equivalence: same seed + same data => the sync and async runs end in
    # the same state, and the async files restore to the same bytes
    template = _trainer(model_cfg, steps=steps, ckpt_dir=None,
                        ckpt_every=1).state()
    sync_state, sync_step, _ = restore_checkpoint(
        out["sync"]["ckpt_dir"], template)
    async_state, async_step, _ = restore_checkpoint(
        out["async"]["ckpt_dir"], template)
    leaves_s = jax.tree.leaves(sync_state)
    leaves_a = jax.tree.leaves(async_state)
    identical = sync_step == async_step and all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(leaves_s, leaves_a))
    base = out["none"]["loop_s"]
    return {
        "steps": steps,
        "none_s": out["none"]["loop_s"],
        "sync_s": out["sync"]["total_s"],
        "async_loop_s": out["async"]["loop_s"],
        "async_total_s": out["async"]["total_s"],
        "sync_overhead": round(out["sync"]["total_s"] - base, 4),
        "async_overhead": round(out["async"]["loop_s"] - base, 4),
        "sync_async_identical": bool(identical),
    }


# ---------------------------------------------------------------------------
# Phase B: recovery matrix (ENFORCED rework bars)
# ---------------------------------------------------------------------------

def run_recovery(ds, caps, model_cfg, *, steps, ckpt_every,
                 workdir) -> list[dict]:
    mid = (steps // 2) | 1  # odd: never aligned with the ckpt cadence
    scenarios = [
        # (name, chaos spec, rollback?, rework budget)
        ("crash", f"crash@{mid}", False, ckpt_every),
        ("ckpt_corrupt", f"ckpt_truncate@{mid},crash@{mid}", False,
         2 * ckpt_every),
        ("nan_rollback", f"nan@{mid},nan@{mid + 1}", True,
         ckpt_every + 2),  # +2: the injected NaN steps themselves
        ("sigterm", f"sigterm@{mid}", False, ckpt_every),
    ]
    rows = []
    for name, spec, rollback, budget in scenarios:
        ckpt_dir = os.path.join(workdir, f"rec_{name}")
        res = _run_to_completion(
            ds, caps, model_cfg, steps=steps, ckpt_dir=ckpt_dir,
            ckpt_every=ckpt_every, chaos=spec, rollback=rollback)
        res.update(scenario=name, chaos=spec, budget=budget,
                   ok=res["rework"] <= budget and res["final_step"] >= steps)
        rows.append(res)
    return rows


def run(quick: bool = True):
    """Bench-suite entry point: (name, us, note) rows from Phase B."""
    ds, caps, model_cfg = _setup(quick)
    workdir = tempfile.mkdtemp(prefix="bench_fault_")
    try:
        rows = run_recovery(ds, caps, model_cfg, steps=8 if quick else 16,
                            ckpt_every=2, workdir=workdir)
    finally:
        shutil.rmtree(workdir, ignore_errors=True)
    return [(f"fault_{r['scenario']}", r["wall_s"] * 1e6,
             f"rework={r['rework']}/{r['budget']} ok={r['ok']}")
            for r in rows]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default=None, help="write results to file")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--ckpt-every", type=int, default=2)
    ap.add_argument("--skip-overhead", action="store_true",
                    help="phase B only")
    args = ap.parse_args()
    steps = args.steps or (8 if args.quick else 16)

    ds, caps, model_cfg = _setup(args.quick)
    workdir = tempfile.mkdtemp(prefix="bench_fault_")
    try:
        overhead = None
        if not args.skip_overhead:
            overhead = run_overhead(ds, caps, model_cfg, steps=steps,
                                    workdir=workdir)
            print(f"overhead: none={overhead['none_s']:.2f}s "
                  f"sync={overhead['sync_s']:.2f}s "
                  f"async(loop)={overhead['async_loop_s']:.2f}s "
                  f"identical={overhead['sync_async_identical']}")
        recovery = run_recovery(ds, caps, model_cfg, steps=steps,
                                ckpt_every=args.ckpt_every, workdir=workdir)
        for r in recovery:
            print(f"{r['scenario']}: rework={r['rework']}/{r['budget']} "
                  f"attempts={r['attempts']} wall={r['wall_s']:.2f}s "
                  f"fired={r['chaos_fired']} "
                  f"{'OK' if r['ok'] else 'FAIL'}")
    finally:
        shutil.rmtree(workdir, ignore_errors=True)

    violations = [r["scenario"] for r in recovery if not r["ok"]]
    equiv_ok = overhead is None or overhead["sync_async_identical"]
    result = {
        "overhead": overhead,
        "recovery": recovery,
        "enforced": {
            "rework_within_budget": not violations,
            "sync_async_identical": equiv_ok,
        },
    }
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(result, fh, indent=2)
        print(f"wrote {args.json}")

    if violations or not equiv_ok:
        if violations:
            print(f"FAIL: rework over budget in {violations}",
                  file=sys.stderr)
        if not equiv_ok:
            print("FAIL: sync and async checkpoints restored different "
                  "states", file=sys.stderr)
        return 1
    print("recovery bars OK: rework <= budget in every scenario"
          + ("" if overhead is None else "; sync == async restore"))
    return 0


if __name__ == "__main__":
    sys.exit(main())
