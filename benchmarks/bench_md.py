"""Table II reproduction: one-step MD inference time, CHGNet (reference
readout/blocks) vs FastCHGNet (fused + direct heads), on three synthetic
systems sized like the paper's LiMnO2 / LiTiPO5 / Li9Co7O16 (feature
numbers ~1k / ~3.5k / ~10k)."""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.batching import BatchCapacities, batch_crystals
from repro.core.chgnet import CHGNetConfig, chgnet_apply, chgnet_init
from repro.core.neighbors import Crystal, build_graph


def _system(target_features: int, seed: int):
    """Grow a crystal until its feature count is near the target."""
    rng = np.random.default_rng(seed)
    for n in range(4, 96, 2):
        a = (n * 14.0) ** (1 / 3)
        c = Crystal(lattice=np.eye(3) * a + rng.normal(0, .02 * a, (3, 3)),
                    frac_coords=rng.random((n, 3)),
                    atomic_numbers=rng.integers(1, 60, n))
        g = build_graph(c)
        if g.feature_count(n) >= target_features:
            return c, g
    return c, g


def _time(fn, *args, iters=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def run(iters: int = 5):
    cfg_ref = CHGNetConfig(readout="autodiff", block_variant="reference",
                           mlp_impl="ref", envelope_impl="reference")
    cfg_fast = CHGNetConfig(readout="direct", block_variant="fast",
                            mlp_impl="packed", envelope_impl="factored")
    p_ref = chgnet_init(jax.random.PRNGKey(0), cfg_ref)
    p_fast = chgnet_init(jax.random.PRNGKey(0), cfg_fast)
    serve_ref = jax.jit(lambda p, b: chgnet_apply(p, cfg_ref, b))
    serve_fast = jax.jit(lambda p, b: chgnet_apply(p, cfg_fast, b))

    rows = []
    for name, target in [("sysA_1k", 1088), ("sysB_3.5k", 3582),
                         ("sysC_10k", 10188)]:
        c, g = _system(target, seed=hash(name) % 2**31)
        caps = BatchCapacities(c.num_atoms + 4, g.num_bonds + 8,
                               g.num_angles + 8)
        batch = batch_crystals([c], [g], caps)
        t_ref = _time(serve_ref, p_ref, batch, iters=iters)
        t_fast = _time(serve_fast, p_fast, batch, iters=iters)
        feats = g.feature_count(c.num_atoms)
        rows.append((f"tab2_md_ref_{name}", t_ref * 1e6, f"features={feats}"))
        rows.append((f"tab2_md_fast_{name}", t_fast * 1e6,
                     f"speedup={t_ref / t_fast:.2f}x"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
