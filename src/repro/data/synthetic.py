"""Synthetic MPtrj-like dataset (offline stand-in for the licensed MPtrj).

Generates random inorganic-crystal-shaped structures whose size statistics
match the paper's Fig. 5 (long-tail lognormal over atoms/bonds/angles), and
labels them with a smooth analytic potential:

    E = sum_{i<j} Morse(r_ij) + sum_z mu_z            (pair + element offset)
    F_i = -dE/dr_i                 (exact analytic derivative)
    sigma = (1/V) sum_bonds phi'(r)/r * (r_vec ⊗ r_vec)  (exact virial)
    m_i = softplus(rho_i) * w_{z_i}                   (smooth "magmom")

Exactness of the labels is unit-tested against finite differences, so the
reference (autodiff) and direct readouts train against a *physically
consistent* target — energy conservation holds for the label generator.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.neighbors import Crystal, GraphIndices, build_graph

# Morse parameters (eV, 1/A, A)
_DE, _A, _R0 = 0.5, 1.3, 2.6
EV_A3_TO_GPA = 160.21766


def _morse(r):
    e = np.exp(-_A * (r - _R0))
    return _DE * (e * e - 2.0 * e)


def _morse_dr(r):
    e = np.exp(-_A * (r - _R0))
    return _DE * (-2.0 * _A * e * e + 2.0 * _A * e)


@dataclasses.dataclass
class SyntheticConfig:
    num_crystals: int = 256
    min_atoms: int = 2
    max_atoms: int = 64
    lognormal_mu: float = 2.2     # matches MPtrj long tail (Fig. 5)
    lognormal_sigma: float = 0.7
    vol_per_atom: float = 14.0    # A^3
    num_elements: int = 89
    r_cut_atom: float = 6.0
    r_cut_bond: float = 3.0
    seed: int = 0


def generate_crystal(rng: np.random.Generator, cfg: SyntheticConfig) -> Crystal:
    n = int(np.clip(rng.lognormal(cfg.lognormal_mu, cfg.lognormal_sigma),
                    cfg.min_atoms, cfg.max_atoms))
    a = (n * cfg.vol_per_atom) ** (1.0 / 3.0)
    lat = np.eye(3) * a + rng.normal(0.0, 0.03 * a, (3, 3))
    frac = rng.random((n, 3))
    z = rng.integers(1, cfg.num_elements + 1, n)
    return Crystal(lattice=lat, frac_coords=frac, atomic_numbers=z)


def label_crystal(crystal: Crystal, graph: GraphIndices,
                  element_offsets: np.ndarray,
                  magmom_weights: np.ndarray) -> None:
    """Attach analytic labels in-place (exact E/F/sigma consistency)."""
    lat = crystal.lattice
    cart = crystal.cart_coords()
    i = graph.bond_center
    j = graph.bond_nbr
    shift = graph.bond_image.astype(np.float64) @ lat
    vec = cart[j] + shift - cart[i]          # (Nb, 3) r_ij = r_j - r_i
    dist = np.linalg.norm(vec, axis=-1)
    n = crystal.num_atoms

    # energy: directed bonds double-count pairs -> 0.5 factor
    e_pair = 0.5 * np.sum(_morse(dist))
    e_off = float(np.sum(element_offsets[crystal.atomic_numbers]))
    crystal.energy = float(e_pair + e_off)

    # forces: F_i = sum_j phi'(r_ij) * (r_j - r_i)/r_ij
    dphi = _morse_dr(dist)
    f = np.zeros((n, 3))
    np.add.at(f, i, dphi[:, None] * vec / dist[:, None])
    crystal.forces = f

    # virial stress: sigma = (1/2V) sum_directed phi'(r)/r * (vec ⊗ vec)
    vol = abs(np.linalg.det(lat))
    outer = vec[:, :, None] * vec[:, None, :]
    sigma = 0.5 * np.sum((dphi / dist)[:, None, None] * outer, axis=0) / vol
    crystal.stress = sigma * EV_A3_TO_GPA

    # magmom: smooth function of local density rho_i = sum_j exp(-r_ij)
    rho = np.zeros(n)
    np.add.at(rho, i, np.exp(-dist))
    w = magmom_weights[crystal.atomic_numbers]
    crystal.magmoms = np.log1p(np.exp(rho)) * w  # softplus(rho) * w_z


@dataclasses.dataclass
class SyntheticDataset:
    crystals: list[Crystal]
    graphs: list[GraphIndices]
    cfg: SyntheticConfig

    def __len__(self) -> int:
        return len(self.crystals)

    def feature_counts(self) -> np.ndarray:
        """Paper's load metric per sample: atoms + bonds + angles."""
        return np.array([
            g.feature_count(c.num_atoms)
            for c, g in zip(self.crystals, self.graphs)
        ])


def make_dataset(cfg: SyntheticConfig) -> SyntheticDataset:
    rng = np.random.default_rng(cfg.seed)
    element_offsets = rng.normal(-3.0, 1.0, cfg.num_elements + 1)
    magmom_weights = np.abs(rng.normal(0.5, 0.3, cfg.num_elements + 1))
    crystals, graphs = [], []
    for _ in range(cfg.num_crystals):
        c = generate_crystal(rng, cfg)
        g = build_graph(c, cfg.r_cut_atom, cfg.r_cut_bond)
        label_crystal(c, g, element_offsets, magmom_weights)
        crystals.append(c)
        graphs.append(g)
    return SyntheticDataset(crystals=crystals, graphs=graphs, cfg=cfg)
