"""Batch samplers, including the paper's Load Balance Sampler (C6, Fig. 4).

The load metric of a sample is its feature count = atoms + bonds + angles
(paper Fig. 9). Imbalance across the per-device shards of a global batch is
measured by the coefficient of variation (CoV) of per-device totals —
the paper reports CoV 0.186 (default) -> 0.064 (balanced) at minibatch 32
on 4 GPUs.

LoadBalanceSampler: sort the global batch by feature count ascending, then
repeatedly pair the smallest remaining with the largest remaining sample
and deal the pairs to devices round-robin — each device gets an equal
number of samples whose (small+large) pair sums are nearly constant.

CostBalanceSampler (DESIGN.md §6): LPT bin packing over a per-crystal
*cost model* (``repro.batching.cost``) instead of equal counts — shards
may hold different numbers of samples, but their predicted step costs are
tight, which is what actually sets the synchronous step time.
"""
from __future__ import annotations

import numpy as np

from repro.batching.balance import lpt_pack


def _validate_batch(batch_size: int, num_devices: int) -> None:
    if num_devices < 1:
        raise ValueError(f"num_devices must be >= 1, got {num_devices}")
    if batch_size < num_devices:
        raise ValueError(
            f"batch_size {batch_size} < num_devices {num_devices}: "
            "every device needs at least one sample"
        )


def _epoch_slices(n: int, batch_size: int, num_devices: int,
                  drop_last: bool):
    """Start/stop of each global batch; optionally the tail remainder.

    The tail is only yielded when it can give every device at least one
    sample (downstream packing pads every shard to a fixed number of
    crystal slots, so the short batch still stacks); a tail smaller than
    ``num_devices`` is dropped even with ``drop_last=False``.
    """
    full_end = (n // batch_size) * batch_size
    for s in range(0, full_end, batch_size):
        yield s, s + batch_size
    if not drop_last and n - full_end >= num_devices:
        yield full_end, n


def cov_of_device_loads(loads: np.ndarray) -> float:
    """Coefficient of variation of per-device load totals."""
    mu = float(np.mean(loads))
    if mu == 0.0:
        return 0.0
    return float(np.std(loads) / mu)


class DefaultSampler:
    """Random global batches, contiguous split across devices (reference)."""

    def __init__(self, feature_counts: np.ndarray, seed: int = 0):
        self.counts = np.asarray(feature_counts)
        self.rng = np.random.default_rng(seed)

    def epoch(self, batch_size: int, num_devices: int, *,
              drop_last: bool = True):
        """Yields (global_indices, per_device_index_lists).

        When ``batch_size % num_devices != 0`` the remainder is distributed
        so shard lengths differ by at most one (no sample is dropped);
        downstream packing pads every shard to a fixed number of crystal
        slots so the shards still stack.  With ``drop_last=False`` the tail
        partial batch (``n % batch_size`` samples) is yielded too instead
        of being silently dropped (see ``_epoch_slices``).
        """
        _validate_batch(batch_size, num_devices)
        n = self.counts.shape[0]
        perm = self.rng.permutation(n)
        for s, e in _epoch_slices(n, batch_size, num_devices, drop_last):
            idx = perm[s:e]
            yield idx, np.array_split(idx, num_devices)


class LoadBalanceSampler:
    """Paper Fig. 4: smallest+largest pairing, dealt round-robin."""

    def __init__(self, feature_counts: np.ndarray, seed: int = 0):
        self.counts = np.asarray(feature_counts)
        self.rng = np.random.default_rng(seed)

    def assign(self, idx: np.ndarray, num_devices: int) -> list[np.ndarray]:
        """Split one global batch's indices across devices, balanced.

        Every shard gets exactly ``floor`` or ``ceil`` of
        ``len(idx) / num_devices`` samples (never empty, never more than
        ceil), so downstream packing can pad every shard to a fixed slot
        count and no device trains on an all-padding batch.
        """
        order = np.argsort(self.counts[idx], kind="stable")
        sorted_idx = idx[order]
        base, rem = divmod(len(sorted_idx), num_devices)
        targets = [base + (1 if d < rem else 0) for d in range(num_devices)]
        lo, hi = 0, len(sorted_idx) - 1
        shards: list[list[int]] = [[] for _ in range(num_devices)]
        d = 0
        while lo <= hi:
            while len(shards[d]) >= targets[d]:
                d = (d + 1) % num_devices
            shards[d].append(sorted_idx[lo])
            lo += 1
            if lo <= hi and len(shards[d]) < targets[d]:
                shards[d].append(sorted_idx[hi])
                hi -= 1
            d = (d + 1) % num_devices
        return [np.asarray(s, dtype=np.int64) for s in shards]

    def epoch(self, batch_size: int, num_devices: int, *,
              drop_last: bool = True):
        """Like ``DefaultSampler.epoch`` (incl. ``drop_last``), balanced."""
        _validate_batch(batch_size, num_devices)
        n = self.counts.shape[0]
        perm = self.rng.permutation(n)
        for s, e in _epoch_slices(n, batch_size, num_devices, drop_last):
            idx = perm[s:e]
            yield idx, self.assign(idx, num_devices)


class CostBalanceSampler:
    """LPT bin packing over predicted per-crystal costs (DESIGN.md §6).

    Unlike :class:`LoadBalanceSampler` (equal counts, paired magnitudes),
    shards may hold *different sample counts* — a device can take one
    giant crystal while another takes three small ones.  ``max_items``
    caps the per-shard count so downstream packing can pad every shard to
    a static number of crystal slots
    (``repro.batching.balance.crystal_slots_for``).
    """

    def __init__(self, costs: np.ndarray, seed: int = 0,
                 max_items: int | None = None):
        self.counts = np.asarray(costs, np.float64)  # sampler-API name
        self.rng = np.random.default_rng(seed)
        self.max_items = max_items

    def assign(self, idx: np.ndarray, num_devices: int) -> list[np.ndarray]:
        shards = lpt_pack(self.counts[idx], num_devices,
                          max_items=self.max_items)
        return [np.asarray(idx)[s] for s in shards]

    def epoch(self, batch_size: int, num_devices: int, *,
              drop_last: bool = True):
        """Same contract as the other samplers: (global_idx, shards)."""
        _validate_batch(batch_size, num_devices)
        n = self.counts.shape[0]
        perm = self.rng.permutation(n)
        for s, e in _epoch_slices(n, batch_size, num_devices, drop_last):
            idx = perm[s:e]
            yield idx, self.assign(idx, num_devices)


def device_loads(counts: np.ndarray, shards: list[np.ndarray]) -> np.ndarray:
    return np.array([counts[s].sum() for s in shards], dtype=np.float64)
