"""Batch samplers, including the paper's Load Balance Sampler (C6, Fig. 4).

The load metric of a sample is its feature count = atoms + bonds + angles
(paper Fig. 9). Imbalance across the per-device shards of a global batch is
measured by the coefficient of variation (CoV) of per-device totals —
the paper reports CoV 0.186 (default) -> 0.064 (balanced) at minibatch 32
on 4 GPUs.

LoadBalanceSampler: sort the global batch by feature count ascending, then
repeatedly pair the smallest remaining with the largest remaining sample
and deal the pairs to devices round-robin — each device gets an equal
number of samples whose (small+large) pair sums are nearly constant.
"""
from __future__ import annotations

import numpy as np


def cov_of_device_loads(loads: np.ndarray) -> float:
    """Coefficient of variation of per-device load totals."""
    mu = float(np.mean(loads))
    if mu == 0.0:
        return 0.0
    return float(np.std(loads) / mu)


class DefaultSampler:
    """Random global batches, contiguous split across devices (reference)."""

    def __init__(self, feature_counts: np.ndarray, seed: int = 0):
        self.counts = np.asarray(feature_counts)
        self.rng = np.random.default_rng(seed)

    def epoch(self, batch_size: int, num_devices: int):
        """Yields (global_indices, per_device_index_lists)."""
        n = self.counts.shape[0]
        perm = self.rng.permutation(n)
        per_dev = batch_size // num_devices
        for s in range(0, n - batch_size + 1, batch_size):
            idx = perm[s:s + batch_size]
            shards = [
                idx[d * per_dev:(d + 1) * per_dev] for d in range(num_devices)
            ]
            yield idx, shards


class LoadBalanceSampler:
    """Paper Fig. 4: smallest+largest pairing, dealt round-robin."""

    def __init__(self, feature_counts: np.ndarray, seed: int = 0):
        self.counts = np.asarray(feature_counts)
        self.rng = np.random.default_rng(seed)

    def assign(self, idx: np.ndarray, num_devices: int) -> list[np.ndarray]:
        """Split one global batch's indices across devices, balanced."""
        order = np.argsort(self.counts[idx], kind="stable")
        sorted_idx = idx[order]
        lo, hi = 0, len(sorted_idx) - 1
        shards: list[list[int]] = [[] for _ in range(num_devices)]
        d = 0
        while lo <= hi:
            shards[d].append(sorted_idx[lo])
            lo += 1
            if lo <= hi:
                shards[d].append(sorted_idx[hi])
                hi -= 1
            d = (d + 1) % num_devices
        return [np.asarray(s, dtype=np.int64) for s in shards]

    def epoch(self, batch_size: int, num_devices: int):
        n = self.counts.shape[0]
        perm = self.rng.permutation(n)
        for s in range(0, n - batch_size + 1, batch_size):
            idx = perm[s:s + batch_size]
            yield idx, self.assign(idx, num_devices)


def device_loads(counts: np.ndarray, shards: list[np.ndarray]) -> np.ndarray:
    return np.array([counts[s].sum() for s in shards], dtype=np.float64)
