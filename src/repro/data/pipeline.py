"""Batching + capacity sizing + asynchronous prefetch (paper C8).

Capacities: XLA needs static shapes, so per-device graph batches are padded
to fixed (atom, bond, angle) capacities derived from dataset statistics —
``capacity_for`` sizes them at quantile + safety margin of the *per-shard*
totals, which the LoadBalanceSampler keeps tight (low CoV -> low padding
waste; the paper's C6 doubles as our padding-efficiency lever).

Prefetch: a background thread builds + device_puts the next batch while the
current step runs (JAX dispatch is async) — the JAX analogue of the paper's
separate CUDA copy stream.
"""
from __future__ import annotations

import queue
import threading

import jax
import numpy as np

from repro.core.graph import BatchCapacities, CrystalGraphBatch, batch_crystals
from .sampler import DefaultSampler, LoadBalanceSampler
from .synthetic import SyntheticDataset


def capacity_for(
    ds: SyntheticDataset,
    per_device_batch: int,
    *,
    quantile: float = 0.99,
    margin: float = 1.3,
    align: int = 256,
) -> BatchCapacities:
    """Size per-device capacities from dataset statistics."""
    atoms = np.array([c.num_atoms for c in ds.crystals])
    bonds = np.array([g.num_bonds for g in ds.graphs])
    angles = np.array([g.num_angles for g in ds.graphs])

    def cap(x):
        q = float(np.quantile(x, quantile))
        raw = int(q * per_device_batch * margin)
        return max(align, ((raw + align - 1) // align) * align)

    return BatchCapacities(atoms=cap(atoms), bonds=cap(bonds), angles=cap(angles))


def build_device_batch(
    ds: SyntheticDataset, indices: np.ndarray, caps: BatchCapacities
) -> CrystalGraphBatch:
    return batch_crystals(
        [ds.crystals[i] for i in indices],
        [ds.graphs[i] for i in indices],
        caps,
    )


def stack_device_batches(batches: list[CrystalGraphBatch]) -> CrystalGraphBatch:
    """Stack per-device batches along a new leading axis (for shard_map)."""
    return jax.tree.map(lambda *xs: np.stack(xs, axis=0), *batches)


class BatchIterator:
    """Epoch iterator producing stacked per-device padded batches."""

    def __init__(
        self,
        ds: SyntheticDataset,
        global_batch: int,
        num_devices: int,
        caps: BatchCapacities,
        *,
        load_balance: bool = True,
        seed: int = 0,
        stack: bool | None = None,
    ):
        self.ds = ds
        self.global_batch = global_batch
        self.num_devices = num_devices
        self.caps = caps
        # stacked (num_devices, ...) leaves for shard_map; plain batch else
        self.stack = (num_devices > 1) if stack is None else stack
        counts = ds.feature_counts()
        self.sampler = (
            LoadBalanceSampler(counts, seed)
            if load_balance
            else DefaultSampler(counts, seed)
        )

    def __iter__(self):
        for _idx, shards in self.sampler.epoch(self.global_batch, self.num_devices):
            batches = [build_device_batch(self.ds, s, self.caps) for s in shards]
            if self.stack:
                yield stack_device_batches(batches)
            else:
                assert len(batches) == 1
                yield batches[0]


class Prefetcher:
    """Background-thread prefetch of up to ``depth`` device-put batches."""

    _STOP = object()

    def __init__(self, iterator, depth: int = 2, device=None):
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.device = device

        def worker():
            try:
                for item in iterator:
                    if self.device is not None:
                        item = jax.device_put(item, self.device)
                    self.q.put(item)
            finally:
                self.q.put(self._STOP)

        self.thread = threading.Thread(target=worker, daemon=True)
        self.thread.start()

    def __iter__(self):
        while True:
            item = self.q.get()
            if item is self._STOP:
                return
            yield item
