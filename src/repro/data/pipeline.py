"""Training-side batch iteration + asynchronous prefetch (paper C8).

All capacity/packing policy lives in ``repro.batching`` (bucketed capacity
ladders, padded packing, compile cache); this module is the glue between a
dataset, the samplers (paper C6) and that engine:

  - ``BatchIterator`` accepts either one fixed ``BatchCapacities`` or a
    ``CapacityLadder`` — with a ladder each global batch is packed into the
    smallest bucket that fits its largest shard, so typical batches stop
    paying the worst-case pad (the LoadBalanceSampler keeps shard totals
    tight, which is what makes small buckets hit often);
  - non-divisible global batches (``batch_size % num_devices != 0``) are
    handled by padding every shard to a fixed number of *crystal slots*,
    so per-device batches always stack to one shape.

Prefetch: a background thread builds + device_puts the next batch while the
current step runs (JAX dispatch is async) — the JAX analogue of the paper's
separate CUDA copy stream.  Worker exceptions are captured and re-raised in
the consumer, not swallowed.
"""
from __future__ import annotations

import logging
import math
import queue
import threading
import time
from typing import Any, NamedTuple

import jax
import numpy as np

from repro.batching import (
    BatchCapacities,
    CapacityLadder,
    batch_crystals,
    capacity_for,
    ladder_for,
    stack_device_batches,
)
from repro.batching.balance import (
    StepPlan,
    crystal_slots_for,
    plan_microbatches,
    shard_cost_totals,
)
from repro.batching.cost import DEFAULT_COST_MODEL, CostModel
from repro.core.graph import CrystalGraphBatch
from repro.core.losses import global_denominators
from repro.runtime.fault import TransientSampleError
from .sampler import CostBalanceSampler, DefaultSampler, LoadBalanceSampler
from .synthetic import SyntheticDataset

__all__ = [
    "BatchIterator", "BalancedBatchIterator", "Prefetcher", "TaggedBatch",
    "TransientSampleError", "build_device_batch", "stack_device_batches",
    "capacity_for", "ladder_for",
]

log = logging.getLogger("repro.data")


class TaggedBatch(NamedTuple):
    """A packed batch plus the dataset indices it was built from.

    The Trainer unwraps it before the jitted step and keeps the indices
    in a ring buffer, so a divergence rollback can quarantine the streak's
    source samples (DESIGN.md §8).  Being a NamedTuple it is a pytree —
    ``jax.device_put`` in the Prefetcher passes through it fine.
    """

    indices: np.ndarray
    batch: Any


def build_device_batch(
    ds: SyntheticDataset,
    indices: np.ndarray,
    caps: BatchCapacities,
    *,
    num_crystal_slots: int | None = None,
    validate: bool = True,
) -> CrystalGraphBatch:
    return batch_crystals(
        [ds.crystals[i] for i in indices],
        [ds.graphs[i] for i in indices],
        caps,
        num_crystal_slots=num_crystal_slots,
        validate=validate,
    )


class BatchIterator:
    """Epoch iterator producing stacked per-device padded batches."""

    def __init__(
        self,
        ds: SyntheticDataset,
        global_batch: int,
        num_devices: int,
        caps: BatchCapacities | CapacityLadder,
        *,
        load_balance: bool | str = True,
        seed: int = 0,
        stack: bool | None = None,
        drop_last: bool = True,
        validate_layout: bool = True,
        cost_model: CostModel | None = None,
        tag_indices: bool = False,
    ):
        if num_devices < 1:
            raise ValueError(f"num_devices must be >= 1, got {num_devices}")
        if global_batch < num_devices:
            raise ValueError(
                f"global_batch {global_batch} < num_devices {num_devices}"
            )
        self.ds = ds
        self.global_batch = global_batch
        self.num_devices = num_devices
        self.caps = caps
        self.drop_last = drop_last
        # quarantine (DESIGN.md §8): indices here are dropped from every
        # subsequent batch (the crystal-slot pad absorbs the shorter
        # shards); tag_indices wraps each yield in a TaggedBatch so the
        # Trainer can trace a divergence back to its source samples
        self.tag_indices = tag_indices
        self.quarantine: set[int] = set()
        # per-batch sorted-segment layout check (DESIGN.md §1); steady-state
        # epoch loops over a trusted dataset can turn it off — packing
        # establishes the invariant either way
        self.validate_layout = validate_layout
        # stacked (num_devices, ...) leaves for shard_map; plain batch else
        self.stack = (num_devices > 1) if stack is None else stack
        if load_balance == "cost":
            # LPT bin packing over a cost model (DESIGN.md §6): shards may
            # hold unequal sample counts, so the static crystal-slot pad
            # needs LPT's 2x headroom (crystal_slots_for) instead of
            # ceil(batch / devices)
            model = cost_model if cost_model is not None \
                else DEFAULT_COST_MODEL
            self.crystal_slots = crystal_slots_for(global_batch, num_devices)
            self.sampler = CostBalanceSampler(
                model.predict_dataset(ds), seed,
                max_items=self.crystal_slots)
        else:
            # every shard is padded to this many crystal slots so that
            # shards of unequal length (non-divisible global batch) stack
            self.crystal_slots = math.ceil(global_batch / num_devices)
            counts = ds.feature_counts()
            self.sampler = (
                LoadBalanceSampler(counts, seed)
                if load_balance
                else DefaultSampler(counts, seed)
            )

    def _caps_for(self, shards: list[np.ndarray]) -> BatchCapacities:
        """One capacity for all shards of this step (shapes must match)."""
        if isinstance(self.caps, BatchCapacities):
            return self.caps
        na = nb = ng = 0
        for s in shards:
            na = max(na, sum(self.ds.crystals[i].num_atoms for i in s))
            nb = max(nb, sum(self.ds.graphs[i].num_bonds for i in s))
            ng = max(ng, sum(self.ds.graphs[i].num_angles for i in s))
        return self.caps.bucket_for(na, nb, ng)

    def add_quarantine(self, indices) -> None:
        """Exclude dataset indices from all future batches (the Trainer's
        ``on_quarantine`` hook points here)."""
        self.quarantine.update(int(i) for i in np.asarray(indices).ravel())

    def _filter_quarantined(self, shards: list[np.ndarray]):
        """Drop quarantined indices; None if any shard would go empty
        (skip the step — shapes must stay stackable)."""
        if not self.quarantine:
            return shards
        q = np.fromiter(self.quarantine, dtype=np.int64)
        out = [s[~np.isin(s, q)] for s in shards]
        if any(len(s) == 0 for s in out):
            return None
        return out

    def __iter__(self):
        for _idx, shards in self.sampler.epoch(
            self.global_batch, self.num_devices, drop_last=self.drop_last
        ):
            shards = self._filter_quarantined(shards)
            if shards is None:
                continue
            caps = self._caps_for(shards)
            batches = [
                build_device_batch(
                    self.ds, s, caps, num_crystal_slots=self.crystal_slots,
                    validate=self.validate_layout,
                )
                for s in shards
            ]
            if self.stack:
                out = stack_device_batches(batches)
            else:
                assert len(batches) == 1
                out = batches[0]
            if self.tag_indices:
                yield TaggedBatch(np.concatenate(shards), out)
            else:
                yield out


class BalancedBatchIterator:
    """Epoch iterator producing :class:`StepPlan` s (DESIGN.md §6).

    One yielded plan = one optimizer step = ``num_micro`` microbatches,
    each LPT-packed across devices by predicted cost and packed into its
    OWN smallest-fitting capacity bucket.  The Trainer's accumulation
    path (``repro.train.trainer.make_chgnet_accum_step_fns``) sums the
    per-microbatch grads, whose global-denominator losses make the summed
    update exactly equal a single big-batch step.

    Compared to :class:`BatchIterator` this trades one big compiled step
    for ``num_micro`` smaller ones: the big-crystal microbatch pays the
    big bucket, the rest don't — padded-slot waste and the straggler gap
    both drop (``benchmarks/bench_scaling`` measures the latter).
    """

    def __init__(
        self,
        ds: SyntheticDataset,
        global_batch: int,
        num_devices: int,
        caps: BatchCapacities | CapacityLadder,
        *,
        num_micro: int = 1,
        cost_model: CostModel | None = None,
        seed: int = 0,
        stack: bool | None = None,
        drop_last: bool = True,
        validate_layout: bool = True,
    ):
        if num_devices < 1:
            raise ValueError(f"num_devices must be >= 1, got {num_devices}")
        if global_batch < num_devices:
            raise ValueError(
                f"global_batch {global_batch} < num_devices {num_devices}")
        self.ds = ds
        self.global_batch = global_batch
        self.num_devices = num_devices
        self.caps = caps
        self.num_micro = max(1, num_micro)
        self.cost_model = cost_model if cost_model is not None \
            else DEFAULT_COST_MODEL
        self.costs = self.cost_model.predict_dataset(ds)
        self.atoms = np.array([c.num_atoms for c in ds.crystals])
        self.rng = np.random.default_rng(seed)
        self.stack = (num_devices > 1) if stack is None else stack
        self.drop_last = drop_last
        self.validate_layout = validate_layout
        # static per-shard crystal-slot pad: fixed per (global_batch,
        # num_micro, num_devices), so the jit cache sees ONE crystal-axis
        # shape per bucket regardless of how LPT splits a given step
        self.crystal_slots = crystal_slots_for(
            global_batch, num_devices, self.num_micro)
        self.quarantine: set[int] = set()

    def add_quarantine(self, indices) -> None:
        """Exclude dataset indices from all future StepPlans."""
        self.quarantine.update(int(i) for i in np.asarray(indices).ravel())

    def _caps_for(self, shards: list[np.ndarray]) -> BatchCapacities:
        """Smallest bucket fitting this microbatch's largest shard."""
        if isinstance(self.caps, BatchCapacities):
            return self.caps
        na = nb = ng = 0
        for s in shards:
            na = max(na, sum(self.ds.crystals[i].num_atoms for i in s))
            nb = max(nb, sum(self.ds.graphs[i].num_bonds for i in s))
            ng = max(ng, sum(self.ds.graphs[i].num_angles for i in s))
        return self.caps.bucket_for(na, nb, ng)

    def update_cost_model(self, model: CostModel) -> None:
        """Swap in a refit cost model (live refits, DESIGN.md §6).

        Called between steps by ``Trainer`` (via ``on_cost_model``) after
        it refits the model from measured per-microbatch wall times; every
        subsequent ``plan_step`` LPT-packs with the new coefficients.
        Cheap and host-side only (one predict over the dataset).
        """
        self.cost_model = model
        self.costs = model.predict_dataset(self.ds)

    def plan_step(self, idx: np.ndarray) -> StepPlan:
        """Pack one global batch's indices into a balanced StepPlan."""
        idx = np.asarray(idx)
        plan = plan_microbatches(
            self.costs[idx], self.num_devices, self.num_micro,
            max_items=self.crystal_slots)
        micro_batches = []
        shard_costs = np.zeros((len(plan), self.num_devices), np.float64)
        micro_sizes = np.zeros((len(plan), 3), np.float64)
        for m, shards_pos in enumerate(plan):
            shards = [idx[pos] for pos in shards_pos]
            caps = self._caps_for(shards)
            batches = [
                build_device_batch(
                    self.ds, s, caps,
                    num_crystal_slots=self.crystal_slots,
                    validate=self.validate_layout,
                )
                for s in shards
            ]
            shard_costs[m] = shard_cost_totals(self.costs, shards)
            # real feature totals, host-side (no device syncs): the live
            # cost-model refit pairs these with measured micro wall times
            flat = np.concatenate(shards)
            micro_sizes[m] = (
                sum(self.ds.crystals[i].num_atoms for i in flat),
                sum(self.ds.graphs[i].num_bonds for i in flat),
                sum(self.ds.graphs[i].num_angles for i in flat),
            )
            if self.stack:
                micro_batches.append(stack_device_batches(batches))
            else:
                assert len(batches) == 1
                micro_batches.append(batches[0])
        denoms = global_denominators(
            len(idx), int(self.atoms[idx].sum()))
        return StepPlan(micro=micro_batches, denoms=denoms,
                        shard_costs=shard_costs, num_real=len(idx),
                        micro_sizes=micro_sizes)

    def __iter__(self):
        n = len(self.ds)
        perm = self.rng.permutation(n)
        from .sampler import _epoch_slices
        for s, e in _epoch_slices(n, self.global_batch, self.num_devices,
                                  self.drop_last):
            idx = perm[s:e]
            if self.quarantine:
                q = np.fromiter(self.quarantine, dtype=np.int64)
                idx = idx[~np.isin(idx, q)]
                if len(idx) < self.num_devices:
                    continue  # too few survivors to fill every shard
            yield self.plan_step(idx)


class Prefetcher:
    """Background-thread prefetch of up to ``depth`` device-put batches.

    A worker-thread exception is captured and re-raised in the consumer at
    the point of failure — a bad batch must fail the epoch loudly, not
    silently truncate it.  Two exceptions (DESIGN.md §8):

      - :class:`~repro.runtime.fault.TransientSampleError` from the source
        is retried with bounded exponential backoff: the offending index
        is logged + recorded in ``self.quarantined`` and the stream moves
        on (the source must be resumable across the raise — e.g. the
        chaos wrapper; a plain generator dies on its first raise).  Only
        ``max_retries`` CONSECUTIVE transient failures escalate to the
        consumer.
      - Early consumer exit: breaking out of the ``for`` loop (or any
        ``close()``) unblocks a worker stuck on the full queue and joins
        it with a timeout — the old implementation leaked a thread
        blocked on ``q.put`` forever.
    """

    _STOP = object()

    def __init__(self, iterator, depth: int = 2, device=None, *,
                 max_retries: int = 3, backoff: float = 0.02):
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.device = device
        self._error: BaseException | None = None
        self.max_retries = max_retries
        self.backoff = backoff
        self.quarantined: list[int | None] = []
        self._closed = threading.Event()
        self._source = iter(iterator)
        self.thread = threading.Thread(target=self._worker, daemon=True)
        self.thread.start()

    def _put(self, item) -> bool:
        """put that gives up when the consumer closed us."""
        while not self._closed.is_set():
            try:
                self.q.put(item, timeout=0.05)
                return True
            except queue.Full:
                continue
        return False

    def _worker(self):
        retries = 0
        try:
            while not self._closed.is_set():
                try:
                    item = next(self._source)
                except StopIteration:
                    break
                except TransientSampleError as exc:
                    retries += 1
                    self.quarantined.append(exc.index)
                    log.warning(
                        "prefetch: transient sample failure (index=%s), "
                        "quarantined; retry %d/%d", exc.index, retries,
                        self.max_retries)
                    if retries > self.max_retries:
                        self._error = exc
                        break
                    time.sleep(self.backoff * (2 ** (retries - 1)))
                    continue
                retries = 0
                if self.device is not None:
                    item = jax.device_put(item, self.device)
                if not self._put(item):
                    return  # closed mid-put: consumer is gone
        except BaseException as e:  # re-raised in the consumer
            self._error = e
        self._put(self._STOP)

    def close(self, timeout: float = 5.0) -> None:
        """Stop the worker: signal, drain the queue (unblocking a full
        ``put``), join with ``timeout``.  Idempotent; called automatically
        when the consumer's iteration ends for ANY reason."""
        self._closed.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        self.thread.join(timeout)

    def __iter__(self):
        try:
            while True:
                try:
                    item = self.q.get(timeout=0.1)
                except queue.Empty:
                    if self._closed.is_set() or not self.thread.is_alive():
                        break  # worker gone without a sentinel
                    continue
                if item is self._STOP:
                    break
                yield item
            if self._error is not None:
                raise self._error
        finally:
            self.close()
