"""Training-side batch iteration + asynchronous prefetch (paper C8).

All capacity/packing policy lives in ``repro.batching`` (bucketed capacity
ladders, padded packing, compile cache); this module is the glue between a
dataset, the samplers (paper C6) and that engine:

  - ``BatchIterator`` accepts either one fixed ``BatchCapacities`` or a
    ``CapacityLadder`` — with a ladder each global batch is packed into the
    smallest bucket that fits its largest shard, so typical batches stop
    paying the worst-case pad (the LoadBalanceSampler keeps shard totals
    tight, which is what makes small buckets hit often);
  - non-divisible global batches (``batch_size % num_devices != 0``) are
    handled by padding every shard to a fixed number of *crystal slots*,
    so per-device batches always stack to one shape.

Prefetch: a background thread builds + device_puts the next batch while the
current step runs (JAX dispatch is async) — the JAX analogue of the paper's
separate CUDA copy stream.  Worker exceptions are captured and re-raised in
the consumer, not swallowed.
"""
from __future__ import annotations

import math
import queue
import threading

import jax
import numpy as np

from repro.batching import (
    BatchCapacities,
    CapacityLadder,
    batch_crystals,
    capacity_for,
    ladder_for,
    stack_device_batches,
)
from repro.batching.balance import (
    StepPlan,
    crystal_slots_for,
    plan_microbatches,
    shard_cost_totals,
)
from repro.batching.cost import DEFAULT_COST_MODEL, CostModel
from repro.core.graph import CrystalGraphBatch
from repro.core.losses import global_denominators
from .sampler import CostBalanceSampler, DefaultSampler, LoadBalanceSampler
from .synthetic import SyntheticDataset

__all__ = [
    "BatchIterator", "BalancedBatchIterator", "Prefetcher",
    "build_device_batch", "stack_device_batches", "capacity_for",
    "ladder_for",
]


def build_device_batch(
    ds: SyntheticDataset,
    indices: np.ndarray,
    caps: BatchCapacities,
    *,
    num_crystal_slots: int | None = None,
    validate: bool = True,
) -> CrystalGraphBatch:
    return batch_crystals(
        [ds.crystals[i] for i in indices],
        [ds.graphs[i] for i in indices],
        caps,
        num_crystal_slots=num_crystal_slots,
        validate=validate,
    )


class BatchIterator:
    """Epoch iterator producing stacked per-device padded batches."""

    def __init__(
        self,
        ds: SyntheticDataset,
        global_batch: int,
        num_devices: int,
        caps: BatchCapacities | CapacityLadder,
        *,
        load_balance: bool | str = True,
        seed: int = 0,
        stack: bool | None = None,
        drop_last: bool = True,
        validate_layout: bool = True,
        cost_model: CostModel | None = None,
    ):
        if num_devices < 1:
            raise ValueError(f"num_devices must be >= 1, got {num_devices}")
        if global_batch < num_devices:
            raise ValueError(
                f"global_batch {global_batch} < num_devices {num_devices}"
            )
        self.ds = ds
        self.global_batch = global_batch
        self.num_devices = num_devices
        self.caps = caps
        self.drop_last = drop_last
        # per-batch sorted-segment layout check (DESIGN.md §1); steady-state
        # epoch loops over a trusted dataset can turn it off — packing
        # establishes the invariant either way
        self.validate_layout = validate_layout
        # stacked (num_devices, ...) leaves for shard_map; plain batch else
        self.stack = (num_devices > 1) if stack is None else stack
        if load_balance == "cost":
            # LPT bin packing over a cost model (DESIGN.md §6): shards may
            # hold unequal sample counts, so the static crystal-slot pad
            # needs LPT's 2x headroom (crystal_slots_for) instead of
            # ceil(batch / devices)
            model = cost_model if cost_model is not None \
                else DEFAULT_COST_MODEL
            self.crystal_slots = crystal_slots_for(global_batch, num_devices)
            self.sampler = CostBalanceSampler(
                model.predict_dataset(ds), seed,
                max_items=self.crystal_slots)
        else:
            # every shard is padded to this many crystal slots so that
            # shards of unequal length (non-divisible global batch) stack
            self.crystal_slots = math.ceil(global_batch / num_devices)
            counts = ds.feature_counts()
            self.sampler = (
                LoadBalanceSampler(counts, seed)
                if load_balance
                else DefaultSampler(counts, seed)
            )

    def _caps_for(self, shards: list[np.ndarray]) -> BatchCapacities:
        """One capacity for all shards of this step (shapes must match)."""
        if isinstance(self.caps, BatchCapacities):
            return self.caps
        na = nb = ng = 0
        for s in shards:
            na = max(na, sum(self.ds.crystals[i].num_atoms for i in s))
            nb = max(nb, sum(self.ds.graphs[i].num_bonds for i in s))
            ng = max(ng, sum(self.ds.graphs[i].num_angles for i in s))
        return self.caps.bucket_for(na, nb, ng)

    def __iter__(self):
        for _idx, shards in self.sampler.epoch(
            self.global_batch, self.num_devices, drop_last=self.drop_last
        ):
            caps = self._caps_for(shards)
            batches = [
                build_device_batch(
                    self.ds, s, caps, num_crystal_slots=self.crystal_slots,
                    validate=self.validate_layout,
                )
                for s in shards
            ]
            if self.stack:
                yield stack_device_batches(batches)
            else:
                assert len(batches) == 1
                yield batches[0]


class BalancedBatchIterator:
    """Epoch iterator producing :class:`StepPlan` s (DESIGN.md §6).

    One yielded plan = one optimizer step = ``num_micro`` microbatches,
    each LPT-packed across devices by predicted cost and packed into its
    OWN smallest-fitting capacity bucket.  The Trainer's accumulation
    path (``repro.train.trainer.make_chgnet_accum_step_fns``) sums the
    per-microbatch grads, whose global-denominator losses make the summed
    update exactly equal a single big-batch step.

    Compared to :class:`BatchIterator` this trades one big compiled step
    for ``num_micro`` smaller ones: the big-crystal microbatch pays the
    big bucket, the rest don't — padded-slot waste and the straggler gap
    both drop (``benchmarks/bench_scaling`` measures the latter).
    """

    def __init__(
        self,
        ds: SyntheticDataset,
        global_batch: int,
        num_devices: int,
        caps: BatchCapacities | CapacityLadder,
        *,
        num_micro: int = 1,
        cost_model: CostModel | None = None,
        seed: int = 0,
        stack: bool | None = None,
        drop_last: bool = True,
        validate_layout: bool = True,
    ):
        if num_devices < 1:
            raise ValueError(f"num_devices must be >= 1, got {num_devices}")
        if global_batch < num_devices:
            raise ValueError(
                f"global_batch {global_batch} < num_devices {num_devices}")
        self.ds = ds
        self.global_batch = global_batch
        self.num_devices = num_devices
        self.caps = caps
        self.num_micro = max(1, num_micro)
        self.cost_model = cost_model if cost_model is not None \
            else DEFAULT_COST_MODEL
        self.costs = self.cost_model.predict_dataset(ds)
        self.atoms = np.array([c.num_atoms for c in ds.crystals])
        self.rng = np.random.default_rng(seed)
        self.stack = (num_devices > 1) if stack is None else stack
        self.drop_last = drop_last
        self.validate_layout = validate_layout
        # static per-shard crystal-slot pad: fixed per (global_batch,
        # num_micro, num_devices), so the jit cache sees ONE crystal-axis
        # shape per bucket regardless of how LPT splits a given step
        self.crystal_slots = crystal_slots_for(
            global_batch, num_devices, self.num_micro)

    def _caps_for(self, shards: list[np.ndarray]) -> BatchCapacities:
        """Smallest bucket fitting this microbatch's largest shard."""
        if isinstance(self.caps, BatchCapacities):
            return self.caps
        na = nb = ng = 0
        for s in shards:
            na = max(na, sum(self.ds.crystals[i].num_atoms for i in s))
            nb = max(nb, sum(self.ds.graphs[i].num_bonds for i in s))
            ng = max(ng, sum(self.ds.graphs[i].num_angles for i in s))
        return self.caps.bucket_for(na, nb, ng)

    def update_cost_model(self, model: CostModel) -> None:
        """Swap in a refit cost model (live refits, DESIGN.md §6).

        Called between steps by ``Trainer`` (via ``on_cost_model``) after
        it refits the model from measured per-microbatch wall times; every
        subsequent ``plan_step`` LPT-packs with the new coefficients.
        Cheap and host-side only (one predict over the dataset).
        """
        self.cost_model = model
        self.costs = model.predict_dataset(self.ds)

    def plan_step(self, idx: np.ndarray) -> StepPlan:
        """Pack one global batch's indices into a balanced StepPlan."""
        idx = np.asarray(idx)
        plan = plan_microbatches(
            self.costs[idx], self.num_devices, self.num_micro,
            max_items=self.crystal_slots)
        micro_batches = []
        shard_costs = np.zeros((len(plan), self.num_devices), np.float64)
        micro_sizes = np.zeros((len(plan), 3), np.float64)
        for m, shards_pos in enumerate(plan):
            shards = [idx[pos] for pos in shards_pos]
            caps = self._caps_for(shards)
            batches = [
                build_device_batch(
                    self.ds, s, caps,
                    num_crystal_slots=self.crystal_slots,
                    validate=self.validate_layout,
                )
                for s in shards
            ]
            shard_costs[m] = shard_cost_totals(self.costs, shards)
            # real feature totals, host-side (no device syncs): the live
            # cost-model refit pairs these with measured micro wall times
            flat = np.concatenate(shards)
            micro_sizes[m] = (
                sum(self.ds.crystals[i].num_atoms for i in flat),
                sum(self.ds.graphs[i].num_bonds for i in flat),
                sum(self.ds.graphs[i].num_angles for i in flat),
            )
            if self.stack:
                micro_batches.append(stack_device_batches(batches))
            else:
                assert len(batches) == 1
                micro_batches.append(batches[0])
        denoms = global_denominators(
            len(idx), int(self.atoms[idx].sum()))
        return StepPlan(micro=micro_batches, denoms=denoms,
                        shard_costs=shard_costs, num_real=len(idx),
                        micro_sizes=micro_sizes)

    def __iter__(self):
        n = len(self.ds)
        perm = self.rng.permutation(n)
        from .sampler import _epoch_slices
        for s, e in _epoch_slices(n, self.global_batch, self.num_devices,
                                  self.drop_last):
            yield self.plan_step(perm[s:e])


class Prefetcher:
    """Background-thread prefetch of up to ``depth`` device-put batches.

    A worker-thread exception is captured and re-raised in the consumer at
    the point of failure — a bad batch must fail the epoch loudly, not
    silently truncate it.
    """

    _STOP = object()

    def __init__(self, iterator, depth: int = 2, device=None):
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.device = device
        self._error: BaseException | None = None

        def worker():
            try:
                for item in iterator:
                    if self.device is not None:
                        item = jax.device_put(item, self.device)
                    self.q.put(item)
            except BaseException as e:  # re-raised in the consumer
                self._error = e
            finally:
                self.q.put(self._STOP)

        self.thread = threading.Thread(target=worker, daemon=True)
        self.thread.start()

    def __iter__(self):
        while True:
            item = self.q.get()
            if item is self._STOP:
                if self._error is not None:
                    raise self._error
                return
            yield item
