"""Training-side batch iteration + asynchronous prefetch (paper C8).

All capacity/packing policy lives in ``repro.batching`` (bucketed capacity
ladders, padded packing, compile cache); this module is the glue between a
dataset, the samplers (paper C6) and that engine:

  - ``BatchIterator`` accepts either one fixed ``BatchCapacities`` or a
    ``CapacityLadder`` — with a ladder each global batch is packed into the
    smallest bucket that fits its largest shard, so typical batches stop
    paying the worst-case pad (the LoadBalanceSampler keeps shard totals
    tight, which is what makes small buckets hit often);
  - non-divisible global batches (``batch_size % num_devices != 0``) are
    handled by padding every shard to a fixed number of *crystal slots*,
    so per-device batches always stack to one shape.

Prefetch: a background thread builds + device_puts the next batch while the
current step runs (JAX dispatch is async) — the JAX analogue of the paper's
separate CUDA copy stream.  Worker exceptions are captured and re-raised in
the consumer, not swallowed.
"""
from __future__ import annotations

import math
import queue
import threading

import jax
import numpy as np

from repro.batching import (
    BatchCapacities,
    CapacityLadder,
    batch_crystals,
    capacity_for,
    ladder_for,
    stack_device_batches,
)
from repro.core.graph import CrystalGraphBatch
from .sampler import DefaultSampler, LoadBalanceSampler
from .synthetic import SyntheticDataset

__all__ = [
    "BatchIterator", "Prefetcher", "build_device_batch",
    "stack_device_batches", "capacity_for", "ladder_for",
]


def build_device_batch(
    ds: SyntheticDataset,
    indices: np.ndarray,
    caps: BatchCapacities,
    *,
    num_crystal_slots: int | None = None,
    validate: bool = True,
) -> CrystalGraphBatch:
    return batch_crystals(
        [ds.crystals[i] for i in indices],
        [ds.graphs[i] for i in indices],
        caps,
        num_crystal_slots=num_crystal_slots,
        validate=validate,
    )


class BatchIterator:
    """Epoch iterator producing stacked per-device padded batches."""

    def __init__(
        self,
        ds: SyntheticDataset,
        global_batch: int,
        num_devices: int,
        caps: BatchCapacities | CapacityLadder,
        *,
        load_balance: bool = True,
        seed: int = 0,
        stack: bool | None = None,
        drop_last: bool = True,
        validate_layout: bool = True,
    ):
        if num_devices < 1:
            raise ValueError(f"num_devices must be >= 1, got {num_devices}")
        if global_batch < num_devices:
            raise ValueError(
                f"global_batch {global_batch} < num_devices {num_devices}"
            )
        self.ds = ds
        self.global_batch = global_batch
        self.num_devices = num_devices
        self.caps = caps
        self.drop_last = drop_last
        # per-batch sorted-segment layout check (DESIGN.md §1); steady-state
        # epoch loops over a trusted dataset can turn it off — packing
        # establishes the invariant either way
        self.validate_layout = validate_layout
        # every shard is padded to this many crystal slots so that shards of
        # unequal length (non-divisible global batch) stack to one shape
        self.crystal_slots = math.ceil(global_batch / num_devices)
        # stacked (num_devices, ...) leaves for shard_map; plain batch else
        self.stack = (num_devices > 1) if stack is None else stack
        counts = ds.feature_counts()
        self.sampler = (
            LoadBalanceSampler(counts, seed)
            if load_balance
            else DefaultSampler(counts, seed)
        )

    def _caps_for(self, shards: list[np.ndarray]) -> BatchCapacities:
        """One capacity for all shards of this step (shapes must match)."""
        if isinstance(self.caps, BatchCapacities):
            return self.caps
        na = nb = ng = 0
        for s in shards:
            na = max(na, sum(self.ds.crystals[i].num_atoms for i in s))
            nb = max(nb, sum(self.ds.graphs[i].num_bonds for i in s))
            ng = max(ng, sum(self.ds.graphs[i].num_angles for i in s))
        return self.caps.bucket_for(na, nb, ng)

    def __iter__(self):
        for _idx, shards in self.sampler.epoch(
            self.global_batch, self.num_devices, drop_last=self.drop_last
        ):
            caps = self._caps_for(shards)
            batches = [
                build_device_batch(
                    self.ds, s, caps, num_crystal_slots=self.crystal_slots,
                    validate=self.validate_layout,
                )
                for s in shards
            ]
            if self.stack:
                yield stack_device_batches(batches)
            else:
                assert len(batches) == 1
                yield batches[0]


class Prefetcher:
    """Background-thread prefetch of up to ``depth`` device-put batches.

    A worker-thread exception is captured and re-raised in the consumer at
    the point of failure — a bad batch must fail the epoch loudly, not
    silently truncate it.
    """

    _STOP = object()

    def __init__(self, iterator, depth: int = 2, device=None):
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.device = device
        self._error: BaseException | None = None

        def worker():
            try:
                for item in iterator:
                    if self.device is not None:
                        item = jax.device_put(item, self.device)
                    self.q.put(item)
            except BaseException as e:  # re-raised in the consumer
                self._error = e
            finally:
                self.q.put(self._STOP)

        self.thread = threading.Thread(target=worker, daemon=True)
        self.thread.start()

    def __iter__(self):
        while True:
            item = self.q.get()
            if item is self._STOP:
                if self._error is not None:
                    raise self._error
                return
            yield item
