"""Data substrate: synthetic MPtrj-like dataset, samplers, prefetch.

Capacity sizing / packing policy lives in ``repro.batching``;
``capacity_for`` / ``ladder_for`` are re-exported here for convenience.
"""
from .pipeline import (
    BalancedBatchIterator, BatchIterator, Prefetcher, TaggedBatch,
    TransientSampleError, build_device_batch, capacity_for, ladder_for,
    stack_device_batches,
)
from .sampler import (
    CostBalanceSampler, DefaultSampler, LoadBalanceSampler,
    cov_of_device_loads, device_loads,
)
from .synthetic import SyntheticConfig, SyntheticDataset, make_dataset

__all__ = [
    "BalancedBatchIterator", "BatchIterator", "Prefetcher",
    "TaggedBatch", "TransientSampleError",
    "build_device_batch", "capacity_for", "ladder_for",
    "stack_device_batches", "CostBalanceSampler", "DefaultSampler",
    "LoadBalanceSampler", "cov_of_device_loads", "device_loads",
    "SyntheticConfig", "SyntheticDataset", "make_dataset",
]
