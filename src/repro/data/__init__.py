"""Data substrate: synthetic MPtrj-like dataset, samplers, prefetch."""
from .pipeline import BatchIterator, Prefetcher, capacity_for
from .sampler import DefaultSampler, LoadBalanceSampler, cov_of_device_loads, device_loads
from .synthetic import SyntheticConfig, SyntheticDataset, make_dataset

__all__ = [
    "BatchIterator", "Prefetcher", "capacity_for", "DefaultSampler",
    "LoadBalanceSampler", "cov_of_device_loads", "device_loads",
    "SyntheticConfig", "SyntheticDataset", "make_dataset",
]
