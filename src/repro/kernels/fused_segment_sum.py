"""Fused sorted-segment reduction (the GNN scatter bottleneck, C2).

``atom_conv`` / ``bond_conv`` / the direct force head all reduce edge
messages into node rows: ``out[s] = sum_{e : seg(e)=s} values[e]``.  The
reference lowering is an unsorted scatter-add (atomics on GPU,
serialization on TPU); the one-hot matmul fallback is deterministic but
O(E*S) FLOPs.  This kernel exploits the sorted-segment batch layout
(DESIGN.md §1) instead:

  - the grid walks *segment-row tiles* (``block_rows`` rows per program);
  - CSR row pointers arrive via scalar prefetch, so each program knows its
    edge range ``[offsets[r0], offsets[r0 + block_rows])`` before it runs;
  - edges are consumed in ``chunk``-aligned slices; each slice builds a
    *windowed* one-hot ``(chunk, block_rows)`` — bounded because sorted
    edges of a row tile can only name segments inside that tile — and one
    MXU contraction accumulates ``(block_rows, D)`` partial sums in VMEM.

Every row is owned by exactly one program, so the reduction is
deterministic (fixed chunk order, no atomics, no cross-tile carries) and
the padded edge tail is never touched (``offsets[-1]`` == real edges).

Precision (DESIGN.md §4): ``values`` may be bf16 — the windowed one-hot
is built at the operand dtype, the MXU contraction accumulates f32
(``preferred_element_type``), and the output buffer is f32; the ``ops``
wrapper casts the sliced result back to the operand dtype.

Residency tiers (DESIGN.md §9): with ``residency="vmem"`` values/segment
ids are kept whole-array resident — fine for interpret mode (CI) and for
CHGNet-scale bond tensors on TPU (~bond_cap x dim f32).
``residency="hbm"`` leaves both in HBM (``pltpu.ANY``) and streams each
chunk through ping/pong VMEM scratch with double-buffered async copies
(``fused_message_passing._stream_loop``), so edge tensors that outgrow
VMEM — 10k+-atom structures — reduce without whole-array residency.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(offs_ref, seg_ref, val_ref, out_ref, *, block_rows: int,
            chunk: int):
    # windowed one-hot shared with the message-passing megakernels, which
    # generalize this reduction (DESIGN.md §3)
    from .fused_message_passing import _window_onehot

    i = pl.program_id(0)
    r0 = i * block_rows
    start = offs_ref[r0]
    end = offs_ref[r0 + block_rows]
    out_ref[...] = jnp.zeros(out_ref.shape, out_ref.dtype)

    def body(k, carry):
        base = k * chunk  # chunk-aligned, so slices never straddle the cap
        v = val_ref[pl.ds(base, chunk), :]                     # (chunk, D)
        s = seg_ref[pl.ds(base, chunk), :]                     # (chunk, 1)
        onehot = _window_onehot(s, r0, start, end, base, chunk,
                                block_rows).astype(v.dtype)
        out_ref[...] += jax.lax.dot_general(
            onehot, v, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        ).astype(out_ref.dtype)
        return carry

    jax.lax.fori_loop(start // chunk, pl.cdiv(end, chunk), body, 0)


def _kernel_hbm(offs_ref, seg_ref, val_ref, out_ref, seg_scr, val_scr,
                seg_sem, val_sem, *, block_rows: int, chunk: int):
    """HBM-residency tier (DESIGN.md §9): ids/values stream through
    ping/pong scratch, each next chunk's DMA overlapping the current
    chunk's windowed-one-hot contraction."""
    from .fused_message_passing import _stream_loop, _window_onehot

    i = pl.program_id(0)
    r0 = i * block_rows
    start = offs_ref[r0]
    end = offs_ref[r0 + block_rows]
    out_ref[...] = jnp.zeros(out_ref.shape, out_ref.dtype)
    streams = ((seg_ref, seg_scr, seg_sem), (val_ref, val_scr, val_sem))

    def body(k, slot):
        v = val_scr[slot]                                      # (chunk, D)
        s = seg_scr[slot]                                      # (chunk, 1)
        onehot = _window_onehot(s, r0, start, end, k * chunk, chunk,
                                block_rows).astype(v.dtype)
        out_ref[...] += jax.lax.dot_general(
            onehot, v, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        ).astype(out_ref.dtype)

    _stream_loop(start // chunk, pl.cdiv(end, chunk), chunk, streams, body)


def fused_segment_sum_pallas(
    values: jnp.ndarray,   # (E, D) f32/bf16, E % chunk == 0, D % 128 == 0
    seg_ids: jnp.ndarray,  # (E, 1) int32, sorted over the real prefix
    offsets: jnp.ndarray,  # (S + 1,) int32 CSR row pointers, S % block_rows == 0
    *,
    block_rows: int = 8,
    chunk: int = 256,
    residency: str = "vmem",
    interpret: bool = True,
) -> jnp.ndarray:
    from .fused_message_passing import _any_spec, _check_residency

    e, d = values.shape
    s = offsets.shape[0] - 1
    hbm = _check_residency(residency)
    assert e % chunk == 0, (e, chunk)
    assert s % block_rows == 0, (s, block_rows)
    grid = (s // block_rows,)
    if hbm:
        in_specs = [_any_spec(), _any_spec()]
        scratch_shapes = [
            pltpu.VMEM((2, chunk, 1), jnp.int32),
            pltpu.VMEM((2, chunk, d), values.dtype),
        ] + [pltpu.SemaphoreType.DMA((2,))] * 2
        kernel = functools.partial(_kernel_hbm, block_rows=block_rows,
                                   chunk=chunk)
    else:
        in_specs = [
            pl.BlockSpec((e, 1), lambda i, offs: (0, 0)),
            pl.BlockSpec((e, d), lambda i, offs: (0, 0)),
        ]
        scratch_shapes = []
        kernel = functools.partial(_kernel, block_rows=block_rows,
                                   chunk=chunk)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_rows, d), lambda i, offs: (i, 0)),
        scratch_shapes=scratch_shapes,
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((s, d), jnp.float32),
        interpret=interpret,
    )(offsets, seg_ids, values)
