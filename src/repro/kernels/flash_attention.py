"""Flash attention (online softmax) Pallas kernel — beyond-paper addition
for the LM substrate's prefill path (EXPERIMENTS.md §Perf).

Chunked attention with running (max, sum) renormalization so the (Sq x Sk)
logit matrix never materializes in HBM. Grid (B*H, Sq/bq, Sk/bk); the KV
axis is the innermost (accumulation) dimension. Causal blocks that are
fully masked are skipped via @pl.when on the block indices.

Scratch (VMEM): acc (bq, D) f32, m/l (bq, 128) f32 running statistics.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
            *, scale: float, causal: bool, block_q: int, block_k: int,
            num_k_blocks: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    def _body():
        q = q_ref[0]                      # (bq, D)
        k = k_ref[0]                      # (bk, D)
        v = v_ref[0]                      # (bk, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                          # (bq, bk)
        if causal:
            rows = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, s.shape, 0)
            cols = kj * block_k + jax.lax.broadcasted_iota(
                jnp.int32, s.shape, 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_ref[:, :1]                         # (bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)    # (bq, 1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                        # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)               # (bq, 1)
        l_new = alpha * l_ref[:, :1] + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    if causal:
        # skip blocks strictly above the diagonal
        pl.when(qi * block_q + block_q - 1 >= kj * block_k)(_body)
    else:
        _body()

    @pl.when(kj == num_k_blocks - 1)
    def _finish():
        l = l_ref[:, :1]
        o_ref[0] = (acc_ref[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jnp.ndarray,  # (BH, Sq, D)
    k: jnp.ndarray,  # (BH, Sk, D)
    v: jnp.ndarray,  # (BH, Sk, D)
    *,
    causal: bool = True,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    bh, sq, d = q.shape
    sk = k.shape[1]
    assert sq % block_q == 0 and sk % block_k == 0
    if scale is None:
        scale = float(1.0 / (d ** 0.5))
    grid = (bh, sq // block_q, sk // block_k)
    return pl.pallas_call(
        functools.partial(
            _kernel, scale=scale, causal=causal, block_q=block_q,
            block_k=block_k, num_k_blocks=sk // block_k,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),   # acc
            pltpu.VMEM((block_q, 128), jnp.float32),  # running max
            pltpu.VMEM((block_q, 128), jnp.float32),  # running sum
        ],
        interpret=interpret,
    )(q, k, v)
