"""Fused gather -> GatedMLP -> reduce message-passing megakernels (C2+C4).

The unfused hot path materializes, per interaction block and per layer, the
gathered concat tensors (``(E, 3D)`` for atom_conv, ``(A_ang, 4D)`` for
bond_conv) and the ``(E, D)`` message tensors in HBM — and autodiff then
*saves all of them* for the backward pass.  These kernels fuse the whole
message path over the sorted-CSR rows (DESIGN.md §1, §3) so none of those
intermediates ever exists outside VMEM:

  - the grid walks *destination-row tiles* (``block_rows`` rows per
    program); CSR row pointers arrive via scalar prefetch, so each program
    knows its edge range before it runs (same ownership model as
    ``fused_segment_sum``: every row belongs to exactly one program, the
    reduction is deterministic, the padded tail is never touched);
  - edges are consumed in ``chunk``-aligned slices.  Per slice, operand
    rows are gathered on the MXU: the *destination-side* operand (``v`` of
    the center atom for atom_conv; ``e``/``e_b`` of the center bond for
    bond_conv) via a windowed one-hot against the row tile — bounded
    because sorted edges of a tile only name segments inside it — and the
    *remote* operands (``v[bond_nbr]``, ``v[center]``/``e[angle_ik]``) via
    a full one-hot against the VMEM-resident feature table;
  - the concat-GEMM is algebraically split per operand
    (``concat(xs) @ W == sum_k xs[k] @ W_k``), so even in VMEM the packed
    concat row is never built; the packed ``[Wc ‖ Wg]`` GEMM halves share
    one masked-LayerNorm + sigmoid epilogue (paper Fig. 3);
  - with the undirected bond store (``mirror=True``, DESIGN.md §5) the
    envelope operands join a fourth, *mirror-indirected* class: ``e_a`` /
    ``e_b`` live in Eu-row undirected tables and are gathered per edge
    chunk through the ``bond_pair`` mirror-map ids with the same tiled
    one-hot mechanism as remote operands — the directed (E, D) envelope
    expansions never exist in HBM or VMEM;
  - envelope weights are applied in-register and the weighted messages are
    accumulated straight into the destination tile with the transposed
    windowed one-hot (one more MXU contraction).

Feature lanes are padded to 128 by the ``ops`` wrappers; LayerNorm masks
the padded lanes (static ``d_real``), so padding never biases statistics.

VMEM note: like ``fused_segment_sum``, the feature tables (``v``, ``e``,
``e_b``, edge payloads) are whole-array VMEM-resident — fine for interpret
mode (CI) and CHGNet-scale batches on TPU; an HBM + double-buffered DMA
variant is the follow-up for tables that outgrow VMEM.

The backward story (recompute-in-kernel, "redundancy bypass") lives in the
``ops`` custom VJPs: the forward saves *only the operands*, never the
messages, and the backward rematerializes the message path (DESIGN.md §3).

Precision (DESIGN.md §4): feature/weight tables may be bf16 (halving
their VMEM residency — the binding constraint called out above).  Every
MXU contraction accumulates f32 (``_mm``/``_mm_t``), one-hot gather
matrices are cast to the table dtype (lossless 0/1), LayerNorm statistics
and envelope products are evaluated in f32, and the f32 destination
accumulator is cast back to the operand dtype only by the ``ops`` wrapper
slice.  The recompute-in-backward loops accumulate cotangents in f32 and
cast to the operand dtypes at the end.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mm(a, b):
    """a @ b on the MXU with f32 in-register accumulation.

    ``a`` is cast to ``b``'s dtype first (DESIGN.md §4): the right operand
    is the VMEM feature/weight table whose dtype the policy picked, and
    the left operand is either a 0/1 one-hot (exact at any float dtype) or
    a gather result that *holds* values of ``b``'s dtype — so the cast is
    lossless while keeping both MXU inputs at one dtype."""
    return jax.lax.dot_general(
        a.astype(b.dtype), b, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def _mm_t(a, b):
    """a.T @ b (contract rows) on the MXU with f32 accumulation."""
    return jax.lax.dot_general(
        a.astype(b.dtype), b, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def _masked_ln(x, scale, bias, d_real: int, eps=1e-5):
    """LayerNorm over the first ``d_real`` lanes; padded lanes stay zero.

    ``x`` arrives f32 from the accumulating GEMM; statistics stay f32."""
    scale = scale.astype(jnp.float32)
    bias = bias.astype(jnp.float32)
    cols = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    m = (cols < d_real).astype(x.dtype)
    cnt = jnp.float32(d_real)
    mu = jnp.sum(x * m, axis=-1, keepdims=True) / cnt
    var = jnp.sum(jnp.square(x - mu) * m, axis=-1, keepdims=True) / cnt
    return ((x - mu) * jax.lax.rsqrt(var + eps) * scale + bias) * m


def _gated_epilogue(y, lns, lnb, hp: int, d_real: int):
    """Packed-GEMM epilogue: both LNs + silu/sigmoid gating (Fig. 3b)."""
    core = _masked_ln(y[:, :hp], lns[0, :hp], lnb[0, :hp], d_real)
    gate = _masked_ln(y[:, hp:], lns[0, hp:], lnb[0, hp:], d_real)
    # silu(core) = core * sigmoid(core): one kind of sigmoid evaluation
    return (core * jax.nn.sigmoid(core)) * jax.nn.sigmoid(gate)


def _window_onehot(seg, r0, start, end, base, chunk: int, block_rows: int):
    """(chunk, block_rows) one-hot of edge->tile-row, zero outside [start, end)."""
    e_ids = base + jax.lax.broadcasted_iota(jnp.int32, (chunk, 1), 0)
    valid = (e_ids >= start) & (e_ids < end)
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, block_rows), 1)
    return ((seg - r0 == rows) & valid).astype(jnp.float32)


def _gather_rows(ids, table_refs, tile: int):
    """MXU row gather: ``[table[ids] for table in table_refs]``.

    Walks the table in ``tile``-row windows (table rows must be a ``tile``
    multiple — the ops wrappers pad) so the one-hot never exceeds
    ``(chunk, tile)`` — a full-table one-hot would put an O(chunk x rows)
    temp in VMEM.  Tables sharing the same ids (e/e_b in bond_conv) reuse
    one one-hot per window.  Flops are O(chunk x rows x D): the classic
    TPU gather-by-matmul trade; the HBM-DMA row fetch is the follow-up for
    tables that outgrow VMEM (module docstring).
    """
    n_rows = table_refs[0].shape[0]
    n = ids.shape[0]

    def body(t, accs):
        t0 = t * tile
        cols = t0 + jax.lax.broadcasted_iota(jnp.int32, (n, tile), 1)
        oh = (ids == cols).astype(jnp.float32)
        return tuple(
            acc + _mm(oh, ref[pl.ds(t0, tile), :])
            for acc, ref in zip(accs, table_refs)
        )

    init = tuple(
        jnp.zeros((n, ref.shape[1]), jnp.float32) for ref in table_refs)
    return jax.lax.fori_loop(0, n_rows // tile, body, init)


# ---------------------------------------------------------------------------
# atom_conv megakernel: bonds -> atoms (Eq. 4 message path)
# ---------------------------------------------------------------------------

def _atom_conv_kernel(offs_ref, seg_ref, nbr_ref, pair_ref, v_full_ref,
                      v_tile_ref, e_ref, ea_ref, w1_ref, w2_ref, w3_ref,
                      b_ref, lns_ref, lnb_ref, out_ref, *, block_rows: int,
                      chunk: int, d_real: int, gather_tile: int,
                      mirror: bool):
    i = pl.program_id(0)
    r0 = i * block_rows
    start = offs_ref[r0]
    end = offs_ref[r0 + block_rows]
    out_ref[...] = jnp.zeros(out_ref.shape, out_ref.dtype)
    hp = b_ref.shape[-1] // 2

    def body(k, carry):
        base = k * chunk  # chunk-aligned, so slices never straddle the cap
        seg = seg_ref[pl.ds(base, chunk), :]                   # (chunk, 1)
        oh_w = _window_onehot(seg, r0, start, end, base, chunk, block_rows)
        v_c = _mm(oh_w, v_tile_ref[...])          # gather v[bond_center]
        (v_n,) = _gather_rows(                    # gather v[bond_nbr]
            nbr_ref[pl.ds(base, chunk), :], (v_full_ref,), gather_tile)
        e_c = e_ref[pl.ds(base, chunk), :]        # edge-contiguous slice
        # split concat-GEMM: [v_c ‖ v_n ‖ e] @ [Wc ‖ Wg] without the concat
        y = _mm(v_c, w1_ref[...]) + _mm(v_n, w2_ref[...]) \
            + _mm(e_c, w3_ref[...]) + b_ref[...].astype(jnp.float32)
        msg = _gated_epilogue(y, lns_ref, lnb_ref, hp, d_real)
        # envelope e^a_ij applied in-register at f32 (accum rule, §4).
        # Mirror-indirected operand class (DESIGN.md §5): with the
        # undirected store, e^a lives in an Eu-row table and is gathered
        # through bond_pair — the directed (E, D) expansion never exists
        # in HBM or VMEM.
        if mirror:
            (ea_c,) = _gather_rows(
                pair_ref[pl.ds(base, chunk), :], (ea_ref,), gather_tile)
        else:
            ea_c = ea_ref[pl.ds(base, chunk), :].astype(jnp.float32)
        msg = msg * ea_c
        out_ref[...] += _mm_t(oh_w, msg).astype(out_ref.dtype)
        return carry

    jax.lax.fori_loop(start // chunk, pl.cdiv(end, chunk), body, 0)


def fused_atom_conv_pallas(
    v: jnp.ndarray,        # (A, DP) f32, A % block_rows == 0, DP % 128 == 0
    e: jnp.ndarray,        # (E, DP) f32, E % chunk == 0
    e_a: jnp.ndarray,      # (E, HP) envelope — or (EU, HP) table (mirror)
    seg: jnp.ndarray,      # (E, 1) int32 bond_center, sorted over real prefix
    nbr: jnp.ndarray,      # (E, 1) int32 bond_nbr
    pair: jnp.ndarray,     # (E, 1) int32 bond_pair (mirror; else any dummy)
    offsets: jnp.ndarray,  # (A + 1,) int32 CSR row pointers
    w1: jnp.ndarray, w2: jnp.ndarray, w3: jnp.ndarray,  # (DP, 2*HP) each
    b: jnp.ndarray,        # (1, 2*HP)
    ln_scale: jnp.ndarray, ln_bias: jnp.ndarray,        # (1, 2*HP)
    *,
    d_real: int,
    block_rows: int = 8,
    chunk: int = 256,
    gather_tile: int = 256,
    mirror: bool = False,
    interpret: bool = True,
) -> jnp.ndarray:
    a_rows, dp = v.shape
    e_rows = e.shape[0]
    ea_rows = e_a.shape[0]
    hp2 = b.shape[-1]
    assert e_rows % chunk == 0, (e_rows, chunk)
    assert a_rows % block_rows == 0, (a_rows, block_rows)
    assert a_rows % gather_tile == 0, (a_rows, gather_tile)
    if mirror:  # the e^a table is walked in gather_tile windows
        assert ea_rows % gather_tile == 0, (ea_rows, gather_tile)
    else:
        assert ea_rows == e_rows, (ea_rows, e_rows)
    grid = (a_rows // block_rows,)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((e_rows, 1), lambda i, offs: (0, 0)),
            pl.BlockSpec((e_rows, 1), lambda i, offs: (0, 0)),
            pl.BlockSpec((e_rows, 1), lambda i, offs: (0, 0)),
            pl.BlockSpec((a_rows, dp), lambda i, offs: (0, 0)),
            pl.BlockSpec((block_rows, dp), lambda i, offs: (i, 0)),
            pl.BlockSpec((e_rows, dp), lambda i, offs: (0, 0)),
            pl.BlockSpec((ea_rows, hp2 // 2), lambda i, offs: (0, 0)),
            pl.BlockSpec((dp, hp2), lambda i, offs: (0, 0)),
            pl.BlockSpec((dp, hp2), lambda i, offs: (0, 0)),
            pl.BlockSpec((dp, hp2), lambda i, offs: (0, 0)),
            pl.BlockSpec((1, hp2), lambda i, offs: (0, 0)),
            pl.BlockSpec((1, hp2), lambda i, offs: (0, 0)),
            pl.BlockSpec((1, hp2), lambda i, offs: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, hp2 // 2),
                               lambda i, offs: (i, 0)),
    )
    return pl.pallas_call(
        functools.partial(_atom_conv_kernel, block_rows=block_rows,
                          chunk=chunk, d_real=d_real,
                          gather_tile=gather_tile, mirror=mirror),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((a_rows, hp2 // 2), jnp.float32),
        interpret=interpret,
    )(offsets, seg, nbr, pair, v, v, e, e_a, w1, w2, w3, b, ln_scale,
      ln_bias)


# ---------------------------------------------------------------------------
# bond_conv megakernel: angles -> bonds (Eq. 5 message path)
# ---------------------------------------------------------------------------

def _bond_conv_kernel(offs_ref, seg_ref, ik_ref, ctr_ref, pij_ref, pik_ref,
                      v_ref, e_full_ref, e_tile_ref, eb_full_ref,
                      eb_tile_ref, a_ref, w1_ref, w2_ref, w3_ref, w4_ref,
                      b_ref, lns_ref, lnb_ref, out_ref, *, block_rows: int,
                      chunk: int, d_real: int, gather_tile: int,
                      mirror: bool):
    i = pl.program_id(0)
    r0 = i * block_rows
    start = offs_ref[r0]
    end = offs_ref[r0 + block_rows]
    out_ref[...] = jnp.zeros(out_ref.shape, out_ref.dtype)
    hp = b_ref.shape[-1] // 2

    def body(k, carry):
        base = k * chunk
        seg = seg_ref[pl.ds(base, chunk), :]                   # angle_ij
        oh_w = _window_onehot(seg, r0, start, end, base, chunk, block_rows)
        e_ij = _mm(oh_w, e_tile_ref[...])        # gather e[angle_ij]
        if mirror:
            # mirror-indirected operand class (DESIGN.md §5): e^b lives in
            # an Eu-row table; BOTH envelope factors gather through the
            # precomputed bond_pair[angle_*] ids — the windowed one-hot no
            # longer applies because pair ids are not tile-local.
            (e_ik,) = _gather_rows(
                ik_ref[pl.ds(base, chunk), :], (e_full_ref,), gather_tile)
            (eb_ij,) = _gather_rows(
                pij_ref[pl.ds(base, chunk), :], (eb_full_ref,), gather_tile)
            (eb_ik,) = _gather_rows(
                pik_ref[pl.ds(base, chunk), :], (eb_full_ref,), gather_tile)
        else:
            eb_ij = _mm(oh_w, eb_tile_ref[...])  # gather e_b[angle_ij]
            # e / e_b share angle_ik: one tiled one-hot gathers both
            e_ik, eb_ik = _gather_rows(
                ik_ref[pl.ds(base, chunk), :], (e_full_ref, eb_full_ref),
                gather_tile)
        (v_c,) = _gather_rows(                   # gather v[center]
            ctr_ref[pl.ds(base, chunk), :], (v_ref,), gather_tile)
        a_c = a_ref[pl.ds(base, chunk), :]       # edge-contiguous slice
        y = _mm(v_c, w1_ref[...]) + _mm(e_ij, w2_ref[...]) \
            + _mm(e_ik, w3_ref[...]) + _mm(a_c, w4_ref[...]) \
            + b_ref[...].astype(jnp.float32)
        msg = _gated_epilogue(y, lns_ref, lnb_ref, hp, d_real)
        msg = msg * eb_ij * eb_ik  # envelopes are f32 gather results (§4)
        out_ref[...] += _mm_t(oh_w, msg).astype(out_ref.dtype)
        return carry

    jax.lax.fori_loop(start // chunk, pl.cdiv(end, chunk), body, 0)


def fused_bond_conv_pallas(
    v: jnp.ndarray,        # (A, DP) f32 atom features
    e: jnp.ndarray,        # (B, DP) f32 bond features, B % block_rows == 0
    a: jnp.ndarray,        # (E, DP) f32 angle features, E % chunk == 0
    e_b: jnp.ndarray,      # (B, HP) envelope — or (EU, HP) table (mirror)
    seg: jnp.ndarray,      # (E, 1) int32 angle_ij, sorted over real prefix
    ik: jnp.ndarray,       # (E, 1) int32 angle_ik
    ctr: jnp.ndarray,      # (E, 1) int32 bond_center[angle_ij]
    pij: jnp.ndarray,      # (E, 1) int32 bond_pair[angle_ij] (mirror; else dummy)
    pik: jnp.ndarray,      # (E, 1) int32 bond_pair[angle_ik] (mirror; else dummy)
    offsets: jnp.ndarray,  # (B + 1,) int32 CSR row pointers
    w1: jnp.ndarray, w2: jnp.ndarray, w3: jnp.ndarray, w4: jnp.ndarray,
    b: jnp.ndarray,        # (1, 2*HP)
    ln_scale: jnp.ndarray, ln_bias: jnp.ndarray,        # (1, 2*HP)
    *,
    d_real: int,
    block_rows: int = 8,
    chunk: int = 256,
    gather_tile: int = 256,
    mirror: bool = False,
    interpret: bool = True,
) -> jnp.ndarray:
    a_rows, dp = v.shape
    b_rows = e.shape[0]
    e_rows = a.shape[0]
    eb_rows = e_b.shape[0]
    hp2 = b.shape[-1]
    hp = hp2 // 2
    assert e_rows % chunk == 0, (e_rows, chunk)
    assert b_rows % block_rows == 0, (b_rows, block_rows)
    assert b_rows % gather_tile == 0, (b_rows, gather_tile)
    assert a_rows % gather_tile == 0, (a_rows, gather_tile)
    if mirror:
        # the e^b table is walked in gather_tile windows; its unused tile
        # view (pinned at block 0 below) still needs one whole block
        assert eb_rows % gather_tile == 0, (eb_rows, gather_tile)
        assert eb_rows >= block_rows, (eb_rows, block_rows)
    else:
        assert eb_rows == b_rows, (eb_rows, b_rows)
    grid = (b_rows // block_rows,)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((e_rows, 1), lambda i, offs: (0, 0)),
            pl.BlockSpec((e_rows, 1), lambda i, offs: (0, 0)),
            pl.BlockSpec((e_rows, 1), lambda i, offs: (0, 0)),
            pl.BlockSpec((e_rows, 1), lambda i, offs: (0, 0)),
            pl.BlockSpec((e_rows, 1), lambda i, offs: (0, 0)),
            pl.BlockSpec((a_rows, dp), lambda i, offs: (0, 0)),
            pl.BlockSpec((b_rows, dp), lambda i, offs: (0, 0)),
            pl.BlockSpec((block_rows, dp), lambda i, offs: (i, 0)),
            pl.BlockSpec((eb_rows, hp), lambda i, offs: (0, 0)),
            pl.BlockSpec((block_rows, hp),
                         (lambda i, offs: (i, 0)) if not mirror
                         else (lambda i, offs: (0, 0))),
            pl.BlockSpec((e_rows, dp), lambda i, offs: (0, 0)),
            pl.BlockSpec((dp, hp2), lambda i, offs: (0, 0)),
            pl.BlockSpec((dp, hp2), lambda i, offs: (0, 0)),
            pl.BlockSpec((dp, hp2), lambda i, offs: (0, 0)),
            pl.BlockSpec((dp, hp2), lambda i, offs: (0, 0)),
            pl.BlockSpec((1, hp2), lambda i, offs: (0, 0)),
            pl.BlockSpec((1, hp2), lambda i, offs: (0, 0)),
            pl.BlockSpec((1, hp2), lambda i, offs: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, hp), lambda i, offs: (i, 0)),
    )
    return pl.pallas_call(
        functools.partial(_bond_conv_kernel, block_rows=block_rows,
                          chunk=chunk, d_real=d_real,
                          gather_tile=gather_tile, mirror=mirror),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b_rows, hp), jnp.float32),
        interpret=interpret,
    )(offsets, seg, ik, ctr, pij, pik, v, e, e, e_b, e_b, a,
      w1, w2, w3, w4, b, ln_scale, ln_bias)


# ---------------------------------------------------------------------------
# direct-force readout megakernel: bonds -> atoms (Eq. 7)
# + optional bond-virial stress epilogue: bonds -> crystals (DESIGN.md §7)
# ---------------------------------------------------------------------------

def _bond_scalar_mlp(e_c, w1_ref, b1_ref, w2_ref, b2_ref):
    """(chunk, DP) bond features -> (chunk, 1) per-bond scalars n_ij."""
    h = jax.nn.silu(_mm(e_c, w1_ref[...])
                    + b1_ref[...].astype(jnp.float32))         # (chunk, DP)
    # n_ij is a SCALAR per bond (Eq. 8 equivariance proof): a lane
    # reduction instead of a 1-column matmul; f32 accumulation (§4)
    return jnp.sum(h * w2_ref[...].astype(jnp.float32), axis=-1,
                   keepdims=True) + b2_ref[0, 0].astype(jnp.float32)


def _force_kernel(offs_ref, seg_ref, e_ref, xhat_ref, w1_ref, b1_ref,
                  w2_ref, b2_ref, out_ref, *, block_rows: int, chunk: int):
    i = pl.program_id(0)
    r0 = i * block_rows
    start = offs_ref[r0]
    end = offs_ref[r0 + block_rows]
    out_ref[...] = jnp.zeros(out_ref.shape, out_ref.dtype)

    def body(k, carry):
        base = k * chunk
        seg = seg_ref[pl.ds(base, chunk), :]
        oh_w = _window_onehot(seg, r0, start, end, base, chunk, block_rows)
        e_c = e_ref[pl.ds(base, chunk), :]
        n = _bond_scalar_mlp(e_c, w1_ref, b1_ref, w2_ref, b2_ref)
        contrib = n * xhat_ref[pl.ds(base, chunk), :].astype(jnp.float32)
        out_ref[...] += _mm_t(oh_w, contrib).astype(out_ref.dtype)
        return carry

    jax.lax.fori_loop(start // chunk, pl.cdiv(end, chunk), body, 0)


def _force_virial_kernel(offs_ref, seg_ref, cry_ref, e_ref, xhat_ref,
                         dist_ref, w1_ref, b1_ref, w2_ref, b2_ref, out_ref,
                         sig_ref, *, block_rows: int, chunk: int):
    """Force readout + fused per-crystal virial epilogue (DESIGN.md §7).

    The force tile walk is identical to ``_force_kernel``; while n_ij and
    x_hat sit in registers, the epilogue also accumulates

        sig[c] += sum_{edges of this tile in crystal c} n d x_hat⊗x_hat

    into the SHARED (Bp, 3*128) accumulator block.  Its index_map is
    constant, so the block stays resident across the (sequential) grid and
    the per-program partials sum in place — the classic Pallas reduction
    pattern (init at program 0 via ``pl.when``).  Each real edge belongs
    to exactly one row tile (the same [start, end) CSR ownership as the
    force path), so nothing double-counts; the padded tail is past every
    row's end and never contributes.  Outer products are built as three
    MXU contractions per chunk — sig[m, :] += (oh_c ⊙ w)ᵀ @ (x_hat ⊙
    x_hat_m) — so the (E, 3, 3) tensor never exists, not even tiled.
    """
    i = pl.program_id(0)
    r0 = i * block_rows
    start = offs_ref[r0]
    end = offs_ref[r0 + block_rows]
    out_ref[...] = jnp.zeros(out_ref.shape, out_ref.dtype)
    bp = sig_ref.shape[0]

    @pl.when(i == 0)
    def _init():
        sig_ref[...] = jnp.zeros(sig_ref.shape, sig_ref.dtype)

    def body(k, carry):
        base = k * chunk
        seg = seg_ref[pl.ds(base, chunk), :]
        oh_w = _window_onehot(seg, r0, start, end, base, chunk, block_rows)
        e_c = e_ref[pl.ds(base, chunk), :]
        n = _bond_scalar_mlp(e_c, w1_ref, b1_ref, w2_ref, b2_ref)
        xh = xhat_ref[pl.ds(base, chunk), :].astype(jnp.float32)
        out_ref[...] += _mm_t(oh_w, n * xh).astype(out_ref.dtype)
        # --- virial epilogue: everything below reuses n / xh from above
        # ownership mask: same [start, end) window as the force one-hot
        e_ids = base + jax.lax.broadcasted_iota(jnp.int32, (chunk, 1), 0)
        valid = ((e_ids >= start) & (e_ids < end)).astype(jnp.float32)
        w = n * dist_ref[pl.ds(base, chunk), :].astype(jnp.float32) * valid
        cry = cry_ref[pl.ds(base, chunk), :]
        rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, bp), 1)
        oh_c = (cry == rows).astype(jnp.float32) * w       # (chunk, Bp)
        for m in range(3):
            sig_ref[:, m * 128:(m + 1) * 128] += _mm_t(
                oh_c, xh * xh[:, m:m + 1])
        return carry

    jax.lax.fori_loop(start // chunk, pl.cdiv(end, chunk), body, 0)


def fused_force_readout_pallas(
    e: jnp.ndarray,        # (E, DP) f32 final bond features
    x_hat: jnp.ndarray,    # (E, XP) f32 unit bond vectors, lanes 3..XP zero
    seg: jnp.ndarray,      # (E, 1) int32 bond_center, sorted over real prefix
    offsets: jnp.ndarray,  # (A + 1,) int32 CSR row pointers
    w1: jnp.ndarray,       # (DP, DP)
    b1: jnp.ndarray,       # (1, DP)
    w2: jnp.ndarray,       # (1, DP) row vector (the (D, 1) head transposed)
    b2: jnp.ndarray,       # (1, XP) scalar bias broadcast, read at [0, 0]
    *,
    cry: jnp.ndarray | None = None,   # (E, 1) int32 bond_crystal (virial)
    dist: jnp.ndarray | None = None,  # (E, 1) f32 bond distances (virial)
    num_crystals: int = 0,            # Bp, a block_rows multiple (virial)
    virial: bool = False,
    block_rows: int = 8,
    chunk: int = 256,
    interpret: bool = True,
):
    """Fused Eq. 7 force readout; with ``virial=True`` the SAME launch also
    returns the (Bp, 3*128) per-crystal virial accumulator (lanes
    ``m*128 + n`` hold sum n d x_hat_m x_hat_n; DESIGN.md §7)."""
    e_rows, dp = e.shape
    xp = x_hat.shape[1]
    a_rows = offsets.shape[0] - 1
    assert e_rows % chunk == 0, (e_rows, chunk)
    assert a_rows % block_rows == 0, (a_rows, block_rows)
    grid = (a_rows // block_rows,)
    in_specs = [
        pl.BlockSpec((e_rows, 1), lambda i, offs: (0, 0)),
    ]
    operands = [offsets, seg]
    if virial:
        assert cry is not None and dist is not None
        assert num_crystals % block_rows == 0, (num_crystals, block_rows)
        in_specs.append(pl.BlockSpec((e_rows, 1), lambda i, offs: (0, 0)))
        operands.append(cry)
    in_specs += [
        pl.BlockSpec((e_rows, dp), lambda i, offs: (0, 0)),
        pl.BlockSpec((e_rows, xp), lambda i, offs: (0, 0)),
    ]
    operands += [e, x_hat]
    if virial:
        in_specs.append(pl.BlockSpec((e_rows, 1), lambda i, offs: (0, 0)))
        operands.append(dist)
    in_specs += [
        pl.BlockSpec((dp, dp), lambda i, offs: (0, 0)),
        pl.BlockSpec((1, dp), lambda i, offs: (0, 0)),
        pl.BlockSpec((1, dp), lambda i, offs: (0, 0)),
        pl.BlockSpec((1, xp), lambda i, offs: (0, 0)),
    ]
    operands += [w1, b1, w2, b2]
    out_specs = pl.BlockSpec((block_rows, xp), lambda i, offs: (i, 0))
    out_shape = jax.ShapeDtypeStruct((a_rows, xp), jnp.float32)
    if virial:
        # constant index_map: one VMEM-resident accumulator block shared
        # by every grid step (sequential on TPU -> race-free reduction)
        out_specs = (out_specs,
                     pl.BlockSpec((num_crystals, 3 * 128),
                                  lambda i, offs: (0, 0)))
        out_shape = (out_shape,
                     jax.ShapeDtypeStruct((num_crystals, 3 * 128),
                                          jnp.float32))
        kernel = functools.partial(_force_virial_kernel,
                                   block_rows=block_rows, chunk=chunk)
    else:
        kernel = functools.partial(_force_kernel, block_rows=block_rows,
                                   chunk=chunk)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(*operands)
