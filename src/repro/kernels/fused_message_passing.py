"""Fused gather -> GatedMLP -> reduce message-passing megakernels (C2+C4).

The unfused hot path materializes, per interaction block and per layer, the
gathered concat tensors (``(E, 3D)`` for atom_conv, ``(A_ang, 4D)`` for
bond_conv) and the ``(E, D)`` message tensors in HBM — and autodiff then
*saves all of them* for the backward pass.  These kernels fuse the whole
message path over the sorted-CSR rows (DESIGN.md §1, §3) so none of those
intermediates ever exists outside VMEM:

  - the grid walks *destination-row tiles* (``block_rows`` rows per
    program); CSR row pointers arrive via scalar prefetch, so each program
    knows its edge range before it runs (same ownership model as
    ``fused_segment_sum``: every row belongs to exactly one program, the
    reduction is deterministic, the padded tail is never touched);
  - edges are consumed in ``chunk``-aligned slices.  Per slice, operand
    rows are gathered on the MXU: the *destination-side* operand (``v`` of
    the center atom for atom_conv; ``e``/``e_b`` of the center bond for
    bond_conv) via a windowed one-hot against the row tile — bounded
    because sorted edges of a tile only name segments inside it — and the
    *remote* operands (``v[bond_nbr]``, ``v[center]``/``e[angle_ik]``) via
    a full one-hot against the VMEM-resident feature table;
  - the concat-GEMM is algebraically split per operand
    (``concat(xs) @ W == sum_k xs[k] @ W_k``), so even in VMEM the packed
    concat row is never built; the packed ``[Wc ‖ Wg]`` GEMM halves share
    one masked-LayerNorm + sigmoid epilogue (paper Fig. 3);
  - with the undirected bond store (``mirror=True``, DESIGN.md §5) the
    envelope operands join a fourth, *mirror-indirected* class: ``e_a`` /
    ``e_b`` live in Eu-row undirected tables and are gathered per edge
    chunk through the ``bond_pair`` mirror-map ids with the same tiled
    one-hot mechanism as remote operands — the directed (E, D) envelope
    expansions never exist in HBM or VMEM;
  - envelope weights are applied in-register and the weighted messages are
    accumulated straight into the destination tile with the transposed
    windowed one-hot (one more MXU contraction).

Feature lanes are padded to 128 by the ``ops`` wrappers; LayerNorm masks
the padded lanes (static ``d_real``), so padding never biases statistics.

Residency tiers (DESIGN.md §9): with ``residency="vmem"`` the feature
tables (``v``, ``e``, ``e_b``, edge payloads) are whole-array
VMEM-resident — fine for interpret mode (CI) and CHGNet-scale batches on
TPU.  ``residency="hbm"`` leaves them in HBM (``pltpu.ANY`` memory space)
and streams them through ping/pong VMEM scratch with double-buffered
``pltpu.make_async_copy`` DMAs keyed off the scalar-prefetched CSR
offsets: edge-contiguous operands move in ``chunk``-row slices
(``_stream_loop``) and gathered tables in ``gather_tile``-row windows
(``_gather_rows_hbm``), each next block's DMA overlapping the current
block's one-hot-gather + GEMM + epilogue — batch capacity is then bounded
by HBM, not the ~16 MiB of VMEM (10k+-atom structures).

The backward story (recompute-in-kernel, "redundancy bypass") lives in the
``ops`` custom VJPs: the forward saves *only the operands*, never the
messages, and the backward rematerializes the message path (DESIGN.md §3).

Precision (DESIGN.md §4): feature/weight tables may be bf16 (halving
their VMEM residency — the binding constraint called out above).  Every
MXU contraction accumulates f32 (``_mm``/``_mm_t``), one-hot gather
matrices are cast to the table dtype (lossless 0/1), LayerNorm statistics
and envelope products are evaluated in f32, and the f32 destination
accumulator is cast back to the operand dtype only by the ``ops`` wrapper
slice.  The recompute-in-backward loops accumulate cotangents in f32 and
cast to the operand dtypes at the end.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mm(a, b):
    """a @ b on the MXU with f32 in-register accumulation.

    ``a`` is cast to ``b``'s dtype first (DESIGN.md §4): the right operand
    is the VMEM feature/weight table whose dtype the policy picked, and
    the left operand is either a 0/1 one-hot (exact at any float dtype) or
    a gather result that *holds* values of ``b``'s dtype — so the cast is
    lossless while keeping both MXU inputs at one dtype."""
    return jax.lax.dot_general(
        a.astype(b.dtype), b, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def _mm_t(a, b):
    """a.T @ b (contract rows) on the MXU with f32 accumulation."""
    return jax.lax.dot_general(
        a.astype(b.dtype), b, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def _masked_ln(x, scale, bias, d_real: int, eps=1e-5):
    """LayerNorm over the first ``d_real`` lanes; padded lanes stay zero.

    ``x`` arrives f32 from the accumulating GEMM; statistics stay f32."""
    scale = scale.astype(jnp.float32)
    bias = bias.astype(jnp.float32)
    cols = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    m = (cols < d_real).astype(x.dtype)
    cnt = jnp.float32(d_real)
    mu = jnp.sum(x * m, axis=-1, keepdims=True) / cnt
    var = jnp.sum(jnp.square(x - mu) * m, axis=-1, keepdims=True) / cnt
    return ((x - mu) * jax.lax.rsqrt(var + eps) * scale + bias) * m


def _gated_epilogue(y, lns, lnb, hp: int, d_real: int):
    """Packed-GEMM epilogue: both LNs + silu/sigmoid gating (Fig. 3b)."""
    core = _masked_ln(y[:, :hp], lns[0, :hp], lnb[0, :hp], d_real)
    gate = _masked_ln(y[:, hp:], lns[0, hp:], lnb[0, hp:], d_real)
    # silu(core) = core * sigmoid(core): one kind of sigmoid evaluation
    return (core * jax.nn.sigmoid(core)) * jax.nn.sigmoid(gate)


def _window_onehot(seg, r0, start, end, base, chunk: int, block_rows: int):
    """(chunk, block_rows) one-hot of edge->tile-row, zero outside [start, end)."""
    e_ids = base + jax.lax.broadcasted_iota(jnp.int32, (chunk, 1), 0)
    valid = (e_ids >= start) & (e_ids < end)
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, block_rows), 1)
    return ((seg - r0 == rows) & valid).astype(jnp.float32)


def _gather_rows(ids, table_refs, tile: int):
    """MXU row gather: ``[table[ids] for table in table_refs]``.

    Walks the table in ``tile``-row windows (table rows must be a ``tile``
    multiple — the ops wrappers pad) so the one-hot never exceeds
    ``(chunk, tile)`` — a full-table one-hot would put an O(chunk x rows)
    temp in VMEM.  Tables sharing the same ids (e/e_b in bond_conv) reuse
    one one-hot per window.  Flops are O(chunk x rows x D): the classic
    TPU gather-by-matmul trade; the HBM-DMA row fetch is the follow-up for
    tables that outgrow VMEM (module docstring).
    """
    n_rows = table_refs[0].shape[0]
    n = ids.shape[0]

    def body(t, accs):
        t0 = t * tile
        cols = t0 + jax.lax.broadcasted_iota(jnp.int32, (n, tile), 1)
        oh = (ids == cols).astype(jnp.float32)
        return tuple(
            acc + _mm(oh, ref[pl.ds(t0, tile), :])
            for acc, ref in zip(accs, table_refs)
        )

    init = tuple(
        jnp.zeros((n, ref.shape[1]), jnp.float32) for ref in table_refs)
    return jax.lax.fori_loop(0, n_rows // tile, body, init)


# ---------------------------------------------------------------------------
# HBM residency tier: double-buffered DMA streaming (DESIGN.md §9)
# ---------------------------------------------------------------------------
#
# With ``residency="hbm"`` the operand tables stay in HBM (``pltpu.ANY``
# in_specs) and move through ping/pong VMEM scratch slots.  A "stream" is
# the triple (hbm_ref, scratch_ref, sem_ref) where scratch/sem carry a
# leading dim of 2 (the ping/pong slots).  Block k always lands in slot
# ``k % 2``, so starting block k+1 before waiting on block k overlaps the
# next DMA with the current compute without ever racing a live slot: the
# slot k+1 targets was consumed one iteration ago.

def _stream_copies(streams, idx, size):
    """DMA descriptors moving rows [idx*size, (idx+1)*size) of each
    stream's HBM ref into its slot ``idx % 2`` scratch buffer."""
    slot = jax.lax.rem(idx, 2)
    return [
        pltpu.make_async_copy(hbm.at[pl.ds(idx * size, size)],
                              scr.at[slot], sem.at[slot])
        for hbm, scr, sem in streams
    ]


def _stream_loop(k0, k1, size, streams, body):
    """Double-buffered walk of blocks [k0, k1): warm-up starts block k0,
    then each iteration starts block k+1's DMA, waits on block k, and runs
    ``body(k, slot)`` — compute on slot k overlaps the k+1 transfer."""
    @pl.when(k0 < k1)
    def _warmup():
        for c in _stream_copies(streams, k0, size):
            c.start()

    def step(k, carry):
        @pl.when(k + 1 < k1)
        def _prefetch_next():
            for c in _stream_copies(streams, k + 1, size):
                c.start()
        for c in _stream_copies(streams, k, size):
            c.wait()
        body(k, jax.lax.rem(k, 2))
        return carry

    jax.lax.fori_loop(k0, k1, step, 0)


def _gather_rows_hbm(ids_list, tables, tile: int):
    """MXU row gather from HBM-resident tables (the ``residency="hbm"``
    counterpart of ``_gather_rows``).

    ``tables`` holds (hbm_ref, scratch_ref, sem_ref) streams sharing one
    row count; ``tile``-row windows flow through the ping/pong scratch
    double-buffered, the next window's DMA overlapping this window's
    one-hot contraction.  Returns ``[[table_j[ids_i] for j] for i]`` so
    callers with shared ids (e/e_b via angle_ik) or a shared table (the
    Eu e^b mirror table via pij/pik) pay for one table walk.
    """
    n_rows = tables[0][0].shape[0]
    n = ids_list[0].shape[0]
    nwin = n_rows // tile

    for c in _stream_copies(tables, 0, tile):
        c.start()

    def step(t, accs):
        @pl.when(t + 1 < nwin)
        def _prefetch_next():
            for c in _stream_copies(tables, t + 1, tile):
                c.start()
        slot = jax.lax.rem(t, 2)
        for c in _stream_copies(tables, t, tile):
            c.wait()
        cols = t * tile + jax.lax.broadcasted_iota(jnp.int32, (n, tile), 1)
        return tuple(
            tuple(acc + _mm((ids == cols).astype(jnp.float32),
                            tables[j][1][slot])
                  for j, acc in enumerate(row))
            for ids, row in zip(ids_list, accs))

    init = tuple(
        tuple(jnp.zeros((n, t[0].shape[1]), jnp.float32) for t in tables)
        for _ in ids_list)
    return jax.lax.fori_loop(0, nwin, step, init)


def _any_spec():
    """HBM-resident operand: no block shape, kernels DMA rows on demand."""
    return pl.BlockSpec(memory_space=pltpu.ANY)


def _check_residency(residency: str) -> bool:
    if residency not in ("vmem", "hbm"):
        raise ValueError(f"residency must be 'vmem' or 'hbm', "
                         f"got {residency!r}")
    return residency == "hbm"


# ---------------------------------------------------------------------------
# atom_conv megakernel: bonds -> atoms (Eq. 4 message path)
# ---------------------------------------------------------------------------

def _atom_conv_kernel(offs_ref, seg_ref, nbr_ref, pair_ref, v_full_ref,
                      v_tile_ref, e_ref, ea_ref, w1_ref, w2_ref, w3_ref,
                      b_ref, lns_ref, lnb_ref, out_ref, *, block_rows: int,
                      chunk: int, d_real: int, gather_tile: int,
                      mirror: bool, und: bool):
    i = pl.program_id(0)
    r0 = i * block_rows
    start = offs_ref[r0]
    end = offs_ref[r0 + block_rows]
    out_ref[...] = jnp.zeros(out_ref.shape, out_ref.dtype)
    hp = b_ref.shape[-1] // 2

    def body(k, carry):
        base = k * chunk  # chunk-aligned, so slices never straddle the cap
        seg = seg_ref[pl.ds(base, chunk), :]                   # (chunk, 1)
        oh_w = _window_onehot(seg, r0, start, end, base, chunk, block_rows)
        v_c = _mm(oh_w, v_tile_ref[...])          # gather v[bond_center]
        (v_n,) = _gather_rows(                    # gather v[bond_nbr]
            nbr_ref[pl.ds(base, chunk), :], (v_full_ref,), gather_tile)
        # Mirror-indirected operand class (DESIGN.md §5): with the
        # undirected store, e^a lives in an Eu-row table and is gathered
        # through bond_pair — the directed (E, D) expansion never exists
        # in HBM or VMEM.  With the symmetric trunk (``und``, DESIGN.md
        # §10) ``e`` joins it: both tables share ONE window walk.
        if mirror and und:
            e_c, ea_c = _gather_rows(
                pair_ref[pl.ds(base, chunk), :], (e_ref, ea_ref),
                gather_tile)
        else:
            e_c = e_ref[pl.ds(base, chunk), :]    # edge-contiguous slice
            if mirror:
                (ea_c,) = _gather_rows(
                    pair_ref[pl.ds(base, chunk), :], (ea_ref,), gather_tile)
            else:
                ea_c = ea_ref[pl.ds(base, chunk), :].astype(jnp.float32)
        # split concat-GEMM: [v_c ‖ v_n ‖ e] @ [Wc ‖ Wg] without the concat
        y = _mm(v_c, w1_ref[...]) + _mm(v_n, w2_ref[...]) \
            + _mm(e_c, w3_ref[...]) + b_ref[...].astype(jnp.float32)
        msg = _gated_epilogue(y, lns_ref, lnb_ref, hp, d_real)
        # envelope e^a_ij applied in-register at f32 (accum rule, §4)
        msg = msg * ea_c
        out_ref[...] += _mm_t(oh_w, msg).astype(out_ref.dtype)
        return carry

    jax.lax.fori_loop(start // chunk, pl.cdiv(end, chunk), body, 0)


def _atom_conv_kernel_hbm(offs_ref, seg_ref, nbr_ref, pair_ref, v_full_ref,
                          v_tile_ref, e_ref, ea_ref, w1_ref, w2_ref, w3_ref,
                          b_ref, lns_ref, lnb_ref, out_ref, *scratch,
                          block_rows: int, chunk: int, d_real: int,
                          gather_tile: int, mirror: bool, und: bool):
    """HBM-residency atom_conv (DESIGN.md §9): same math as
    ``_atom_conv_kernel`` but every large operand lives in HBM and streams
    through ping/pong scratch — edge payloads (seg/nbr/pair ids, ``e``,
    directed ``e_a``) in chunk slices, the ``v`` table (and the Eu-row
    ``e_a`` — plus ``e`` under ``und`` — mirror tables) in gather_tile
    windows."""
    i = pl.program_id(0)
    r0 = i * block_rows
    start = offs_ref[r0]
    end = offs_ref[r0 + block_rows]
    out_ref[...] = jnp.zeros(out_ref.shape, out_ref.dtype)
    hp = b_ref.shape[-1] // 2
    if mirror and und:
        (seg_scr, nbr_scr, pair_scr, v_gscr, e_gscr, ea_gscr,
         seg_sem, nbr_sem, pair_sem, v_gsem, e_gsem, ea_gsem) = scratch
        edge_streams = ((seg_ref, seg_scr, seg_sem),
                        (nbr_ref, nbr_scr, nbr_sem),
                        (pair_ref, pair_scr, pair_sem))
    elif mirror:
        (seg_scr, nbr_scr, pair_scr, e_scr, v_gscr, ea_gscr,
         seg_sem, nbr_sem, pair_sem, e_sem, v_gsem, ea_gsem) = scratch
        edge_streams = ((seg_ref, seg_scr, seg_sem),
                        (nbr_ref, nbr_scr, nbr_sem),
                        (pair_ref, pair_scr, pair_sem),
                        (e_ref, e_scr, e_sem))
    else:
        (seg_scr, nbr_scr, e_scr, ea_scr, v_gscr,
         seg_sem, nbr_sem, e_sem, ea_sem, v_gsem) = scratch
        edge_streams = ((seg_ref, seg_scr, seg_sem),
                        (nbr_ref, nbr_scr, nbr_sem),
                        (e_ref, e_scr, e_sem),
                        (ea_ref, ea_scr, ea_sem))

    def body(k, slot):
        seg = seg_scr[slot]                                    # (chunk, 1)
        oh_w = _window_onehot(seg, r0, start, end, k * chunk, chunk,
                              block_rows)
        v_c = _mm(oh_w, v_tile_ref[...])          # gather v[bond_center]
        ((v_n,),) = _gather_rows_hbm(             # gather v[bond_nbr]
            (nbr_scr[slot],), ((v_full_ref, v_gscr, v_gsem),), gather_tile)
        if mirror and und:
            # §10: Eu-resident e and e^a share one streamed window walk
            ((e_c, ea_c),) = _gather_rows_hbm(
                (pair_scr[slot],),
                ((e_ref, e_gscr, e_gsem), (ea_ref, ea_gscr, ea_gsem)),
                gather_tile)
        else:
            e_c = e_scr[slot]
        y = _mm(v_c, w1_ref[...]) + _mm(v_n, w2_ref[...]) \
            + _mm(e_c, w3_ref[...]) + b_ref[...].astype(jnp.float32)
        msg = _gated_epilogue(y, lns_ref, lnb_ref, hp, d_real)
        if mirror and not und:
            ((ea_c,),) = _gather_rows_hbm(
                (pair_scr[slot],), ((ea_ref, ea_gscr, ea_gsem),),
                gather_tile)
        elif not mirror:
            ea_c = ea_scr[slot].astype(jnp.float32)
        msg = msg * ea_c
        out_ref[...] += _mm_t(oh_w, msg).astype(out_ref.dtype)

    _stream_loop(start // chunk, pl.cdiv(end, chunk), chunk, edge_streams,
                 body)


def fused_atom_conv_pallas(
    v: jnp.ndarray,        # (A, DP) f32, A % block_rows == 0, DP % 128 == 0
    e: jnp.ndarray,        # (E, DP) f32 — or (EU, DP) table (und)
    e_a: jnp.ndarray,      # (E, HP) envelope — or (EU, HP) table (mirror)
    seg: jnp.ndarray,      # (E, 1) int32 bond_center, sorted over real prefix
    nbr: jnp.ndarray,      # (E, 1) int32 bond_nbr
    pair: jnp.ndarray,     # (E, 1) int32 bond_pair (mirror; else any dummy)
    offsets: jnp.ndarray,  # (A + 1,) int32 CSR row pointers
    w1: jnp.ndarray, w2: jnp.ndarray, w3: jnp.ndarray,  # (DP, 2*HP) each
    b: jnp.ndarray,        # (1, 2*HP)
    ln_scale: jnp.ndarray, ln_bias: jnp.ndarray,        # (1, 2*HP)
    *,
    d_real: int,
    block_rows: int = 8,
    chunk: int = 256,
    gather_tile: int = 256,
    mirror: bool = False,
    und: bool = False,
    residency: str = "vmem",
    interpret: bool = True,
) -> jnp.ndarray:
    a_rows, dp = v.shape
    n_edges = seg.shape[0]     # directed bond rows driving the chunk walk
    e_rows = e.shape[0]        # == n_edges, or the Eu table rows under und
    ea_rows = e_a.shape[0]
    hp2 = b.shape[-1]
    hbm = _check_residency(residency)
    assert n_edges % chunk == 0, (n_edges, chunk)
    assert a_rows % block_rows == 0, (a_rows, block_rows)
    assert a_rows % gather_tile == 0, (a_rows, gather_tile)
    if und:  # §10: e is an Eu-row table gathered through bond_pair
        assert mirror, "und requires the mirror operand class"
        assert e_rows % gather_tile == 0, (e_rows, gather_tile)
    else:
        assert e_rows == n_edges, (e_rows, n_edges)
    if mirror:  # the e^a table is walked in gather_tile windows
        assert ea_rows % gather_tile == 0, (ea_rows, gather_tile)
    else:
        assert ea_rows == n_edges, (ea_rows, n_edges)
    grid = (a_rows // block_rows,)
    if hbm:
        # streamed operands stay in HBM; only the destination tile, the
        # weights, and the ping/pong scratch live in VMEM (DESIGN.md §9)
        table_specs = [
            _any_spec(), _any_spec(), _any_spec(), _any_spec(),
            pl.BlockSpec((block_rows, dp), lambda i, offs: (i, 0)),
            _any_spec(), _any_spec(),
        ]
        hp = hp2 // 2
        if mirror and und:
            scratch_shapes = [
                pltpu.VMEM((2, chunk, 1), jnp.int32),       # seg
                pltpu.VMEM((2, chunk, 1), jnp.int32),       # nbr
                pltpu.VMEM((2, chunk, 1), jnp.int32),       # pair
                pltpu.VMEM((2, gather_tile, dp), v.dtype),  # v windows
                pltpu.VMEM((2, gather_tile, dp), e.dtype),  # e windows
                pltpu.VMEM((2, gather_tile, hp), e_a.dtype),  # e^a windows
            ] + [pltpu.SemaphoreType.DMA((2,))] * 6
        elif mirror:
            scratch_shapes = [
                pltpu.VMEM((2, chunk, 1), jnp.int32),       # seg
                pltpu.VMEM((2, chunk, 1), jnp.int32),       # nbr
                pltpu.VMEM((2, chunk, 1), jnp.int32),       # pair
                pltpu.VMEM((2, chunk, dp), e.dtype),        # e slices
                pltpu.VMEM((2, gather_tile, dp), v.dtype),  # v windows
                pltpu.VMEM((2, gather_tile, hp), e_a.dtype),  # e^a windows
            ] + [pltpu.SemaphoreType.DMA((2,))] * 6
        else:
            scratch_shapes = [
                pltpu.VMEM((2, chunk, 1), jnp.int32),       # seg
                pltpu.VMEM((2, chunk, 1), jnp.int32),       # nbr
                pltpu.VMEM((2, chunk, dp), e.dtype),        # e slices
                pltpu.VMEM((2, chunk, hp), e_a.dtype),      # e^a slices
                pltpu.VMEM((2, gather_tile, dp), v.dtype),  # v windows
            ] + [pltpu.SemaphoreType.DMA((2,))] * 5
        kernel = functools.partial(
            _atom_conv_kernel_hbm, block_rows=block_rows, chunk=chunk,
            d_real=d_real, gather_tile=gather_tile, mirror=mirror, und=und)
    else:
        table_specs = [
            pl.BlockSpec((n_edges, 1), lambda i, offs: (0, 0)),
            pl.BlockSpec((n_edges, 1), lambda i, offs: (0, 0)),
            pl.BlockSpec((n_edges, 1), lambda i, offs: (0, 0)),
            pl.BlockSpec((a_rows, dp), lambda i, offs: (0, 0)),
            pl.BlockSpec((block_rows, dp), lambda i, offs: (i, 0)),
            pl.BlockSpec((e_rows, dp), lambda i, offs: (0, 0)),
            pl.BlockSpec((ea_rows, hp2 // 2), lambda i, offs: (0, 0)),
        ]
        scratch_shapes = []
        kernel = functools.partial(
            _atom_conv_kernel, block_rows=block_rows, chunk=chunk,
            d_real=d_real, gather_tile=gather_tile, mirror=mirror, und=und)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=table_specs + [
            pl.BlockSpec((dp, hp2), lambda i, offs: (0, 0)),
            pl.BlockSpec((dp, hp2), lambda i, offs: (0, 0)),
            pl.BlockSpec((dp, hp2), lambda i, offs: (0, 0)),
            pl.BlockSpec((1, hp2), lambda i, offs: (0, 0)),
            pl.BlockSpec((1, hp2), lambda i, offs: (0, 0)),
            pl.BlockSpec((1, hp2), lambda i, offs: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, hp2 // 2),
                               lambda i, offs: (i, 0)),
        scratch_shapes=scratch_shapes,
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((a_rows, hp2 // 2), jnp.float32),
        interpret=interpret,
    )(offsets, seg, nbr, pair, v, v, e, e_a, w1, w2, w3, b, ln_scale,
      ln_bias)


# ---------------------------------------------------------------------------
# bond_conv megakernel: angles -> bonds (Eq. 5 message path)
# ---------------------------------------------------------------------------

def _bond_conv_kernel(offs_ref, seg_ref, ik_ref, ctr_ref, pij_ref, pik_ref,
                      v_ref, e_full_ref, e_tile_ref, eb_full_ref,
                      eb_tile_ref, a_ref, w1_ref, w2_ref, w3_ref, w4_ref,
                      b_ref, lns_ref, lnb_ref, out_ref, *, block_rows: int,
                      chunk: int, d_real: int, gather_tile: int,
                      mirror: bool):
    i = pl.program_id(0)
    r0 = i * block_rows
    start = offs_ref[r0]
    end = offs_ref[r0 + block_rows]
    out_ref[...] = jnp.zeros(out_ref.shape, out_ref.dtype)
    hp = b_ref.shape[-1] // 2

    def body(k, carry):
        base = k * chunk
        seg = seg_ref[pl.ds(base, chunk), :]                   # angle_ij
        oh_w = _window_onehot(seg, r0, start, end, base, chunk, block_rows)
        e_ij = _mm(oh_w, e_tile_ref[...])        # gather e[angle_ij]
        if mirror:
            # mirror-indirected operand class (DESIGN.md §5): e^b lives in
            # an Eu-row table; BOTH envelope factors gather through the
            # precomputed bond_pair[angle_*] ids — the windowed one-hot no
            # longer applies because pair ids are not tile-local.
            (e_ik,) = _gather_rows(
                ik_ref[pl.ds(base, chunk), :], (e_full_ref,), gather_tile)
            (eb_ij,) = _gather_rows(
                pij_ref[pl.ds(base, chunk), :], (eb_full_ref,), gather_tile)
            (eb_ik,) = _gather_rows(
                pik_ref[pl.ds(base, chunk), :], (eb_full_ref,), gather_tile)
        else:
            eb_ij = _mm(oh_w, eb_tile_ref[...])  # gather e_b[angle_ij]
            # e / e_b share angle_ik: one tiled one-hot gathers both
            e_ik, eb_ik = _gather_rows(
                ik_ref[pl.ds(base, chunk), :], (e_full_ref, eb_full_ref),
                gather_tile)
        (v_c,) = _gather_rows(                   # gather v[center]
            ctr_ref[pl.ds(base, chunk), :], (v_ref,), gather_tile)
        a_c = a_ref[pl.ds(base, chunk), :]       # edge-contiguous slice
        y = _mm(v_c, w1_ref[...]) + _mm(e_ij, w2_ref[...]) \
            + _mm(e_ik, w3_ref[...]) + _mm(a_c, w4_ref[...]) \
            + b_ref[...].astype(jnp.float32)
        msg = _gated_epilogue(y, lns_ref, lnb_ref, hp, d_real)
        msg = msg * eb_ij * eb_ik  # envelopes are f32 gather results (§4)
        out_ref[...] += _mm_t(oh_w, msg).astype(out_ref.dtype)
        return carry

    jax.lax.fori_loop(start // chunk, pl.cdiv(end, chunk), body, 0)


def _bond_conv_kernel_hbm(offs_ref, seg_ref, ik_ref, ctr_ref, pij_ref,
                          pik_ref, v_ref, e_full_ref, e_tile_ref,
                          eb_full_ref, eb_tile_ref, a_ref, w1_ref, w2_ref,
                          w3_ref, w4_ref, b_ref, lns_ref, lnb_ref, out_ref,
                          *scratch, block_rows: int, chunk: int,
                          d_real: int, gather_tile: int, mirror: bool):
    """HBM-residency bond_conv (DESIGN.md §9): angle payloads (ids + ``a``)
    stream in chunk slices; the ``v``/``e`` tables (and the Eu-row ``e^b``
    mirror table — its pij/pik gathers share ONE window walk) stream in
    gather_tile windows.  The destination e-tile (and the non-mirror
    eb-tile, both ``block_rows`` rows) stay VMEM block operands."""
    i = pl.program_id(0)
    r0 = i * block_rows
    start = offs_ref[r0]
    end = offs_ref[r0 + block_rows]
    out_ref[...] = jnp.zeros(out_ref.shape, out_ref.dtype)
    hp = b_ref.shape[-1] // 2
    if mirror:
        (seg_scr, ik_scr, ctr_scr, pij_scr, pik_scr, a_scr,
         v_gscr, e_gscr, eb_gscr,
         seg_sem, ik_sem, ctr_sem, pij_sem, pik_sem, a_sem,
         v_gsem, e_gsem, eb_gsem) = scratch
        edge_streams = ((seg_ref, seg_scr, seg_sem),
                        (ik_ref, ik_scr, ik_sem),
                        (ctr_ref, ctr_scr, ctr_sem),
                        (pij_ref, pij_scr, pij_sem),
                        (pik_ref, pik_scr, pik_sem),
                        (a_ref, a_scr, a_sem))
    else:
        (seg_scr, ik_scr, ctr_scr, a_scr, v_gscr, e_gscr, eb_gscr,
         seg_sem, ik_sem, ctr_sem, a_sem,
         v_gsem, e_gsem, eb_gsem) = scratch
        edge_streams = ((seg_ref, seg_scr, seg_sem),
                        (ik_ref, ik_scr, ik_sem),
                        (ctr_ref, ctr_scr, ctr_sem),
                        (a_ref, a_scr, a_sem))

    def body(k, slot):
        seg = seg_scr[slot]                                    # angle_ij
        oh_w = _window_onehot(seg, r0, start, end, k * chunk, chunk,
                              block_rows)
        e_ij = _mm(oh_w, e_tile_ref[...])        # gather e[angle_ij]
        if mirror:
            ((e_ik,),) = _gather_rows_hbm(
                (ik_scr[slot],), ((e_full_ref, e_gscr, e_gsem),),
                gather_tile)
            # both Eu envelope factors share one walk of the mirror table
            ((eb_ij,), (eb_ik,)) = _gather_rows_hbm(
                (pij_scr[slot], pik_scr[slot]),
                ((eb_full_ref, eb_gscr, eb_gsem),), gather_tile)
        else:
            eb_ij = _mm(oh_w, eb_tile_ref[...])  # gather e_b[angle_ij]
            # e / e_b share angle_ik: one window walk gathers both
            ((e_ik, eb_ik),) = _gather_rows_hbm(
                (ik_scr[slot],),
                ((e_full_ref, e_gscr, e_gsem),
                 (eb_full_ref, eb_gscr, eb_gsem)), gather_tile)
        ((v_c,),) = _gather_rows_hbm(             # gather v[center]
            (ctr_scr[slot],), ((v_ref, v_gscr, v_gsem),), gather_tile)
        a_c = a_scr[slot]
        y = _mm(v_c, w1_ref[...]) + _mm(e_ij, w2_ref[...]) \
            + _mm(e_ik, w3_ref[...]) + _mm(a_c, w4_ref[...]) \
            + b_ref[...].astype(jnp.float32)
        msg = _gated_epilogue(y, lns_ref, lnb_ref, hp, d_real)
        msg = msg * eb_ij * eb_ik
        out_ref[...] += _mm_t(oh_w, msg).astype(out_ref.dtype)

    _stream_loop(start // chunk, pl.cdiv(end, chunk), chunk, edge_streams,
                 body)


def fused_bond_conv_pallas(
    v: jnp.ndarray,        # (A, DP) f32 atom features
    e: jnp.ndarray,        # (B, DP) f32 bond features, B % block_rows == 0
    a: jnp.ndarray,        # (E, DP) f32 angle features, E % chunk == 0
    e_b: jnp.ndarray,      # (B, HP) envelope — or (EU, HP) table (mirror)
    seg: jnp.ndarray,      # (E, 1) int32 angle_ij, sorted over real prefix
    ik: jnp.ndarray,       # (E, 1) int32 angle_ik
    ctr: jnp.ndarray,      # (E, 1) int32 bond_center[angle_ij]
    pij: jnp.ndarray,      # (E, 1) int32 bond_pair[angle_ij] (mirror; else dummy)
    pik: jnp.ndarray,      # (E, 1) int32 bond_pair[angle_ik] (mirror; else dummy)
    offsets: jnp.ndarray,  # (B + 1,) int32 CSR row pointers
    w1: jnp.ndarray, w2: jnp.ndarray, w3: jnp.ndarray, w4: jnp.ndarray,
    b: jnp.ndarray,        # (1, 2*HP)
    ln_scale: jnp.ndarray, ln_bias: jnp.ndarray,        # (1, 2*HP)
    *,
    d_real: int,
    block_rows: int = 8,
    chunk: int = 256,
    gather_tile: int = 256,
    mirror: bool = False,
    residency: str = "vmem",
    interpret: bool = True,
) -> jnp.ndarray:
    a_rows, dp = v.shape
    b_rows = e.shape[0]
    e_rows = a.shape[0]
    eb_rows = e_b.shape[0]
    hp2 = b.shape[-1]
    hp = hp2 // 2
    hbm = _check_residency(residency)
    assert e_rows % chunk == 0, (e_rows, chunk)
    assert b_rows % block_rows == 0, (b_rows, block_rows)
    assert b_rows % gather_tile == 0, (b_rows, gather_tile)
    assert a_rows % gather_tile == 0, (a_rows, gather_tile)
    if mirror:
        # the e^b table is walked in gather_tile windows; its unused tile
        # view (pinned at block 0 below) still needs one whole block
        assert eb_rows % gather_tile == 0, (eb_rows, gather_tile)
        assert eb_rows >= block_rows, (eb_rows, block_rows)
    else:
        assert eb_rows == b_rows, (eb_rows, b_rows)
    grid = (b_rows // block_rows,)
    if hbm:
        # ids + angle features + all three gather tables stay in HBM;
        # only the block_rows-row destination tiles remain VMEM operands
        table_specs = [
            _any_spec(), _any_spec(), _any_spec(), _any_spec(),
            _any_spec(), _any_spec(), _any_spec(),
            pl.BlockSpec((block_rows, dp), lambda i, offs: (i, 0)),
            _any_spec(),
            pl.BlockSpec((block_rows, hp),
                         (lambda i, offs: (i, 0)) if not mirror
                         else (lambda i, offs: (0, 0))),
            _any_spec(),
        ]
        int_scr = pltpu.VMEM((2, chunk, 1), jnp.int32)
        gather_scrs = [
            pltpu.VMEM((2, gather_tile, dp), v.dtype),    # v windows
            pltpu.VMEM((2, gather_tile, dp), e.dtype),    # e windows
            pltpu.VMEM((2, gather_tile, hp), e_b.dtype),  # e^b windows
        ]
        n_ids = 5 if mirror else 3  # seg/ik/ctr (+pij/pik under mirror)
        scratch_shapes = (
            [int_scr] * n_ids
            + [pltpu.VMEM((2, chunk, dp), a.dtype)]       # a slices
            + gather_scrs
            + [pltpu.SemaphoreType.DMA((2,))] * (n_ids + 4))
        kernel = functools.partial(
            _bond_conv_kernel_hbm, block_rows=block_rows, chunk=chunk,
            d_real=d_real, gather_tile=gather_tile, mirror=mirror)
    else:
        table_specs = [
            pl.BlockSpec((e_rows, 1), lambda i, offs: (0, 0)),
            pl.BlockSpec((e_rows, 1), lambda i, offs: (0, 0)),
            pl.BlockSpec((e_rows, 1), lambda i, offs: (0, 0)),
            pl.BlockSpec((e_rows, 1), lambda i, offs: (0, 0)),
            pl.BlockSpec((e_rows, 1), lambda i, offs: (0, 0)),
            pl.BlockSpec((a_rows, dp), lambda i, offs: (0, 0)),
            pl.BlockSpec((b_rows, dp), lambda i, offs: (0, 0)),
            pl.BlockSpec((block_rows, dp), lambda i, offs: (i, 0)),
            pl.BlockSpec((eb_rows, hp), lambda i, offs: (0, 0)),
            pl.BlockSpec((block_rows, hp),
                         (lambda i, offs: (i, 0)) if not mirror
                         else (lambda i, offs: (0, 0))),
            pl.BlockSpec((e_rows, dp), lambda i, offs: (0, 0)),
        ]
        scratch_shapes = []
        kernel = functools.partial(
            _bond_conv_kernel, block_rows=block_rows, chunk=chunk,
            d_real=d_real, gather_tile=gather_tile, mirror=mirror)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=table_specs + [
            pl.BlockSpec((dp, hp2), lambda i, offs: (0, 0)),
            pl.BlockSpec((dp, hp2), lambda i, offs: (0, 0)),
            pl.BlockSpec((dp, hp2), lambda i, offs: (0, 0)),
            pl.BlockSpec((dp, hp2), lambda i, offs: (0, 0)),
            pl.BlockSpec((1, hp2), lambda i, offs: (0, 0)),
            pl.BlockSpec((1, hp2), lambda i, offs: (0, 0)),
            pl.BlockSpec((1, hp2), lambda i, offs: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, hp), lambda i, offs: (i, 0)),
        scratch_shapes=scratch_shapes,
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b_rows, hp), jnp.float32),
        interpret=interpret,
    )(offsets, seg, ik, ctr, pij, pik, v, e, e, e_b, e_b, a,
      w1, w2, w3, w4, b, ln_scale, ln_bias)


# ---------------------------------------------------------------------------
# direct-force readout megakernel: bonds -> atoms (Eq. 7)
# + optional bond-virial stress epilogue: bonds -> crystals (DESIGN.md §7)
# ---------------------------------------------------------------------------

def _bond_scalar_mlp(e_c, w1_ref, b1_ref, w2_ref, b2_ref):
    """(chunk, DP) bond features -> (chunk, 1) per-bond scalars n_ij."""
    h = jax.nn.silu(_mm(e_c, w1_ref[...])
                    + b1_ref[...].astype(jnp.float32))         # (chunk, DP)
    # n_ij is a SCALAR per bond (Eq. 8 equivariance proof): a lane
    # reduction instead of a 1-column matmul; f32 accumulation (§4)
    return jnp.sum(h * w2_ref[...].astype(jnp.float32), axis=-1,
                   keepdims=True) + b2_ref[0, 0].astype(jnp.float32)


def _force_kernel(offs_ref, seg_ref, e_ref, xhat_ref, w1_ref, b1_ref,
                  w2_ref, b2_ref, out_ref, *, block_rows: int, chunk: int):
    i = pl.program_id(0)
    r0 = i * block_rows
    start = offs_ref[r0]
    end = offs_ref[r0 + block_rows]
    out_ref[...] = jnp.zeros(out_ref.shape, out_ref.dtype)

    def body(k, carry):
        base = k * chunk
        seg = seg_ref[pl.ds(base, chunk), :]
        oh_w = _window_onehot(seg, r0, start, end, base, chunk, block_rows)
        e_c = e_ref[pl.ds(base, chunk), :]
        n = _bond_scalar_mlp(e_c, w1_ref, b1_ref, w2_ref, b2_ref)
        contrib = n * xhat_ref[pl.ds(base, chunk), :].astype(jnp.float32)
        out_ref[...] += _mm_t(oh_w, contrib).astype(out_ref.dtype)
        return carry

    jax.lax.fori_loop(start // chunk, pl.cdiv(end, chunk), body, 0)


def _force_virial_kernel(offs_ref, seg_ref, cry_ref, e_ref, xhat_ref,
                         dist_ref, w1_ref, b1_ref, w2_ref, b2_ref, out_ref,
                         sig_ref, *, block_rows: int, chunk: int):
    """Force readout + fused per-crystal virial epilogue (DESIGN.md §7).

    The force tile walk is identical to ``_force_kernel``; while n_ij and
    x_hat sit in registers, the epilogue also accumulates

        sig[c] += sum_{edges of this tile in crystal c} n d x_hat⊗x_hat

    into the SHARED (Bp, 3*128) accumulator block.  Its index_map is
    constant, so the block stays resident across the (sequential) grid and
    the per-program partials sum in place — the classic Pallas reduction
    pattern (init at program 0 via ``pl.when``).  Each real edge belongs
    to exactly one row tile (the same [start, end) CSR ownership as the
    force path), so nothing double-counts; the padded tail is past every
    row's end and never contributes.  Outer products are built as three
    MXU contractions per chunk — sig[m, :] += (oh_c ⊙ w)ᵀ @ (x_hat ⊙
    x_hat_m) — so the (E, 3, 3) tensor never exists, not even tiled.
    """
    i = pl.program_id(0)
    r0 = i * block_rows
    start = offs_ref[r0]
    end = offs_ref[r0 + block_rows]
    out_ref[...] = jnp.zeros(out_ref.shape, out_ref.dtype)
    bp = sig_ref.shape[0]

    @pl.when(i == 0)
    def _init():
        sig_ref[...] = jnp.zeros(sig_ref.shape, sig_ref.dtype)

    def body(k, carry):
        base = k * chunk
        seg = seg_ref[pl.ds(base, chunk), :]
        oh_w = _window_onehot(seg, r0, start, end, base, chunk, block_rows)
        e_c = e_ref[pl.ds(base, chunk), :]
        n = _bond_scalar_mlp(e_c, w1_ref, b1_ref, w2_ref, b2_ref)
        xh = xhat_ref[pl.ds(base, chunk), :].astype(jnp.float32)
        out_ref[...] += _mm_t(oh_w, n * xh).astype(out_ref.dtype)
        # --- virial epilogue: everything below reuses n / xh from above
        # ownership mask: same [start, end) window as the force one-hot
        e_ids = base + jax.lax.broadcasted_iota(jnp.int32, (chunk, 1), 0)
        valid = ((e_ids >= start) & (e_ids < end)).astype(jnp.float32)
        w = n * dist_ref[pl.ds(base, chunk), :].astype(jnp.float32) * valid
        cry = cry_ref[pl.ds(base, chunk), :]
        rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, bp), 1)
        oh_c = (cry == rows).astype(jnp.float32) * w       # (chunk, Bp)
        for m in range(3):
            sig_ref[:, m * 128:(m + 1) * 128] += _mm_t(
                oh_c, xh * xh[:, m:m + 1])
        return carry

    jax.lax.fori_loop(start // chunk, pl.cdiv(end, chunk), body, 0)


def _force_kernel_hbm(offs_ref, seg_ref, e_ref, xhat_ref, w1_ref, b1_ref,
                      w2_ref, b2_ref, out_ref, seg_scr, e_scr, xh_scr,
                      seg_sem, e_sem, xh_sem, *, block_rows: int,
                      chunk: int):
    """HBM-residency force readout (DESIGN.md §9): the bond payloads
    (``seg``, ``e``, ``x_hat``) stream in double-buffered chunk slices."""
    i = pl.program_id(0)
    r0 = i * block_rows
    start = offs_ref[r0]
    end = offs_ref[r0 + block_rows]
    out_ref[...] = jnp.zeros(out_ref.shape, out_ref.dtype)
    streams = ((seg_ref, seg_scr, seg_sem), (e_ref, e_scr, e_sem),
               (xhat_ref, xh_scr, xh_sem))

    def body(k, slot):
        seg = seg_scr[slot]
        oh_w = _window_onehot(seg, r0, start, end, k * chunk, chunk,
                              block_rows)
        n = _bond_scalar_mlp(e_scr[slot], w1_ref, b1_ref, w2_ref, b2_ref)
        contrib = n * xh_scr[slot].astype(jnp.float32)
        out_ref[...] += _mm_t(oh_w, contrib).astype(out_ref.dtype)

    _stream_loop(start // chunk, pl.cdiv(end, chunk), chunk, streams, body)


def _force_virial_kernel_hbm(offs_ref, seg_ref, cry_ref, e_ref, xhat_ref,
                             dist_ref, w1_ref, b1_ref, w2_ref, b2_ref,
                             out_ref, sig_ref, seg_scr, cry_scr, e_scr,
                             xh_scr, dist_scr, seg_sem, cry_sem, e_sem,
                             xh_sem, dist_sem, *, block_rows: int,
                             chunk: int):
    """HBM-residency force + virial readout: the ``_force_virial_kernel``
    epilogue on streamed bond payloads (DESIGN.md §7/§9).  The virial
    accumulator keeps its constant-index-map VMEM residency — it is
    (Bp, 3*128), crystal-count sized, never the binding constraint."""
    i = pl.program_id(0)
    r0 = i * block_rows
    start = offs_ref[r0]
    end = offs_ref[r0 + block_rows]
    out_ref[...] = jnp.zeros(out_ref.shape, out_ref.dtype)
    bp = sig_ref.shape[0]

    @pl.when(i == 0)
    def _init():
        sig_ref[...] = jnp.zeros(sig_ref.shape, sig_ref.dtype)

    streams = ((seg_ref, seg_scr, seg_sem), (cry_ref, cry_scr, cry_sem),
               (e_ref, e_scr, e_sem), (xhat_ref, xh_scr, xh_sem),
               (dist_ref, dist_scr, dist_sem))

    def body(k, slot):
        base = k * chunk
        seg = seg_scr[slot]
        oh_w = _window_onehot(seg, r0, start, end, base, chunk, block_rows)
        n = _bond_scalar_mlp(e_scr[slot], w1_ref, b1_ref, w2_ref, b2_ref)
        xh = xh_scr[slot].astype(jnp.float32)
        out_ref[...] += _mm_t(oh_w, n * xh).astype(out_ref.dtype)
        # --- virial epilogue (identical to the VMEM tier's)
        e_ids = base + jax.lax.broadcasted_iota(jnp.int32, (chunk, 1), 0)
        valid = ((e_ids >= start) & (e_ids < end)).astype(jnp.float32)
        w = n * dist_scr[slot].astype(jnp.float32) * valid
        cry = cry_scr[slot]
        rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, bp), 1)
        oh_c = (cry == rows).astype(jnp.float32) * w       # (chunk, Bp)
        for m in range(3):
            sig_ref[:, m * 128:(m + 1) * 128] += _mm_t(
                oh_c, xh * xh[:, m:m + 1])

    _stream_loop(start // chunk, pl.cdiv(end, chunk), chunk, streams, body)


def fused_force_readout_pallas(
    e: jnp.ndarray,        # (E, DP) f32 final bond features
    x_hat: jnp.ndarray,    # (E, XP) f32 unit bond vectors, lanes 3..XP zero
    seg: jnp.ndarray,      # (E, 1) int32 bond_center, sorted over real prefix
    offsets: jnp.ndarray,  # (A + 1,) int32 CSR row pointers
    w1: jnp.ndarray,       # (DP, DP)
    b1: jnp.ndarray,       # (1, DP)
    w2: jnp.ndarray,       # (1, DP) row vector (the (D, 1) head transposed)
    b2: jnp.ndarray,       # (1, XP) scalar bias broadcast, read at [0, 0]
    *,
    cry: jnp.ndarray | None = None,   # (E, 1) int32 bond_crystal (virial)
    dist: jnp.ndarray | None = None,  # (E, 1) f32 bond distances (virial)
    num_crystals: int = 0,            # Bp, a block_rows multiple (virial)
    virial: bool = False,
    block_rows: int = 8,
    chunk: int = 256,
    residency: str = "vmem",
    interpret: bool = True,
):
    """Fused Eq. 7 force readout; with ``virial=True`` the SAME launch also
    returns the (Bp, 3*128) per-crystal virial accumulator (lanes
    ``m*128 + n`` hold sum n d x_hat_m x_hat_n; DESIGN.md §7)."""
    e_rows, dp = e.shape
    xp = x_hat.shape[1]
    a_rows = offsets.shape[0] - 1
    hbm = _check_residency(residency)
    assert e_rows % chunk == 0, (e_rows, chunk)
    assert a_rows % block_rows == 0, (a_rows, block_rows)
    grid = (a_rows // block_rows,)

    def _payload_spec(width):
        if hbm:
            return _any_spec()
        return pl.BlockSpec((e_rows, width), lambda i, offs: (0, 0))

    in_specs = [_payload_spec(1)]
    operands = [offsets, seg]
    if virial:
        assert cry is not None and dist is not None
        assert num_crystals % block_rows == 0, (num_crystals, block_rows)
        in_specs.append(_payload_spec(1))
        operands.append(cry)
    in_specs += [_payload_spec(dp), _payload_spec(xp)]
    operands += [e, x_hat]
    if virial:
        in_specs.append(_payload_spec(1))
        operands.append(dist)
    in_specs += [
        pl.BlockSpec((dp, dp), lambda i, offs: (0, 0)),
        pl.BlockSpec((1, dp), lambda i, offs: (0, 0)),
        pl.BlockSpec((1, dp), lambda i, offs: (0, 0)),
        pl.BlockSpec((1, xp), lambda i, offs: (0, 0)),
    ]
    operands += [w1, b1, w2, b2]
    out_specs = pl.BlockSpec((block_rows, xp), lambda i, offs: (i, 0))
    out_shape = jax.ShapeDtypeStruct((a_rows, xp), jnp.float32)
    scratch_shapes = []
    if virial:
        # constant index_map: one VMEM-resident accumulator block shared
        # by every grid step (sequential on TPU -> race-free reduction)
        out_specs = (out_specs,
                     pl.BlockSpec((num_crystals, 3 * 128),
                                  lambda i, offs: (0, 0)))
        out_shape = (out_shape,
                     jax.ShapeDtypeStruct((num_crystals, 3 * 128),
                                          jnp.float32))
        if hbm:
            scratch_shapes = [
                pltpu.VMEM((2, chunk, 1), jnp.int32),       # seg
                pltpu.VMEM((2, chunk, 1), jnp.int32),       # cry
                pltpu.VMEM((2, chunk, dp), e.dtype),        # e slices
                pltpu.VMEM((2, chunk, xp), x_hat.dtype),    # x_hat slices
                pltpu.VMEM((2, chunk, 1), dist.dtype),      # dist slices
            ] + [pltpu.SemaphoreType.DMA((2,))] * 5
            kernel = functools.partial(_force_virial_kernel_hbm,
                                       block_rows=block_rows, chunk=chunk)
        else:
            kernel = functools.partial(_force_virial_kernel,
                                       block_rows=block_rows, chunk=chunk)
    elif hbm:
        scratch_shapes = [
            pltpu.VMEM((2, chunk, 1), jnp.int32),           # seg
            pltpu.VMEM((2, chunk, dp), e.dtype),            # e slices
            pltpu.VMEM((2, chunk, xp), x_hat.dtype),        # x_hat slices
        ] + [pltpu.SemaphoreType.DMA((2,))] * 3
        kernel = functools.partial(_force_kernel_hbm, block_rows=block_rows,
                                   chunk=chunk)
    else:
        kernel = functools.partial(_force_kernel, block_rows=block_rows,
                                   chunk=chunk)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=scratch_shapes,
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(*operands)


# ---------------------------------------------------------------------------
# symmetric-trunk bond_conv megakernel pair (DESIGN.md §10):
#   phase A — one gated-MLP message per dedup angle (Au rows)
#   phase B — destination-tiled accumulation into Eu bond rows through the
#             sym-incidence store (each real Au message lands on BOTH
#             undirected bonds of its pair)
# Splitting at the Au->Eu scatter is what realizes the FLOP halving: a
# single destination-tiled kernel would recompute phi once per incidence
# (twice per angle), giving back most of the savings.  The (Au, HP) f32
# message buffer between the launches is the price — half the size of the
# directed angle table it replaces.
# ---------------------------------------------------------------------------

def _sym_msg_kernel(ctr_ref, du1_ref, du2_ref, v_ref, e_ref, eb_ref, a_ref,
                    w1_ref, w23_ref, w4_ref, b_ref, lns_ref, lnb_ref,
                    out_ref, *, d_real: int, gather_tile: int):
    """Phase A: msg[w] = phi([v[ctr], e_s, e_s, a_u]) * e_b[du1] * e_b[du2]
    with e_s = e[du1] + e[du2].  The swap-symmetric e_s feeds both e slots
    of the directed bond MLP, so the w2/w3 GEMMs collapse into one GEMM
    against the precombined w23 = w2 + w3.  Padded Au rows produce finite
    garbage that phase B's CSR ownership never references."""
    hp = b_ref.shape[-1] // 2
    (v_c,) = _gather_rows(ctr_ref[...], (v_ref,), gather_tile)
    e1, eb1 = _gather_rows(du1_ref[...], (e_ref, eb_ref), gather_tile)
    e2, eb2 = _gather_rows(du2_ref[...], (e_ref, eb_ref), gather_tile)
    a_c = a_ref[...]
    y = _mm(v_c, w1_ref[...]) + _mm(e1 + e2, w23_ref[...]) \
        + _mm(a_c, w4_ref[...]) + b_ref[...].astype(jnp.float32)
    msg = _gated_epilogue(y, lns_ref, lnb_ref, hp, d_real)
    out_ref[...] = (msg * eb1 * eb2).astype(out_ref.dtype)


def _sym_msg_kernel_hbm(ctr_ref, du1_ref, du2_ref, v_ref, e_ref, eb_ref,
                        a_ref, w1_ref, w23_ref, w4_ref, b_ref, lns_ref,
                        lnb_ref, out_ref, v_gscr, e_gscr, eb_gscr, v_gsem,
                        e_gsem, eb_gsem, *, d_real: int, gather_tile: int):
    """HBM-residency phase A: the v/e/e^b tables stay in HBM and stream in
    gather_tile windows; both du gathers share one walk of (e, e^b).  The
    Au-blocked ids and a_u remain VMEM block operands."""
    hp = b_ref.shape[-1] // 2
    ((v_c,),) = _gather_rows_hbm(
        (ctr_ref[...],), ((v_ref, v_gscr, v_gsem),), gather_tile)
    ((e1, eb1), (e2, eb2)) = _gather_rows_hbm(
        (du1_ref[...], du2_ref[...]),
        ((e_ref, e_gscr, e_gsem), (eb_ref, eb_gscr, eb_gsem)), gather_tile)
    a_c = a_ref[...]
    y = _mm(v_c, w1_ref[...]) + _mm(e1 + e2, w23_ref[...]) \
        + _mm(a_c, w4_ref[...]) + b_ref[...].astype(jnp.float32)
    msg = _gated_epilogue(y, lns_ref, lnb_ref, hp, d_real)
    out_ref[...] = (msg * eb1 * eb2).astype(out_ref.dtype)


def fused_sym_msg_pallas(
    v: jnp.ndarray,        # (A, DP) f32 atom features
    e: jnp.ndarray,        # (EU, DP) f32 undirected bond table
    a_u: jnp.ndarray,      # (UA, DP) f32 dedup angle features
    e_b: jnp.ndarray,      # (EU, HP) undirected bond envelope table
    ctr: jnp.ndarray,      # (UA, 1) int32 bond_center[und_angle_ij]
    du1: jnp.ndarray,      # (UA, 1) int32 bond_pair[und_angle_ij]
    du2: jnp.ndarray,      # (UA, 1) int32 bond_pair[und_angle_ik]
    w1: jnp.ndarray, w23: jnp.ndarray, w4: jnp.ndarray,  # (DP, 2*HP) each
    b: jnp.ndarray,        # (1, 2*HP)
    ln_scale: jnp.ndarray, ln_bias: jnp.ndarray,         # (1, 2*HP)
    *,
    d_real: int,
    msg_block: int = 256,
    gather_tile: int = 256,
    residency: str = "vmem",
    interpret: bool = True,
) -> jnp.ndarray:
    a_rows, dp = v.shape
    eu_rows = e.shape[0]
    ua_rows = a_u.shape[0]
    hp2 = b.shape[-1]
    hp = hp2 // 2
    hbm = _check_residency(residency)
    assert ua_rows % msg_block == 0, (ua_rows, msg_block)
    assert a_rows % gather_tile == 0, (a_rows, gather_tile)
    assert eu_rows % gather_tile == 0, (eu_rows, gather_tile)
    assert e_b.shape[0] == eu_rows, (e_b.shape, eu_rows)
    grid = (ua_rows // msg_block,)
    id_spec = pl.BlockSpec((msg_block, 1), lambda i: (i, 0))
    if hbm:
        table_specs = [_any_spec(), _any_spec(), _any_spec()]
        scratch_shapes = [
            pltpu.VMEM((2, gather_tile, dp), v.dtype),    # v windows
            pltpu.VMEM((2, gather_tile, dp), e.dtype),    # e windows
            pltpu.VMEM((2, gather_tile, hp), e_b.dtype),  # e^b windows
        ] + [pltpu.SemaphoreType.DMA((2,))] * 3
        kernel = functools.partial(_sym_msg_kernel_hbm, d_real=d_real,
                                   gather_tile=gather_tile)
    else:
        table_specs = [
            pl.BlockSpec((a_rows, dp), lambda i: (0, 0)),
            pl.BlockSpec((eu_rows, dp), lambda i: (0, 0)),
            pl.BlockSpec((eu_rows, hp), lambda i: (0, 0)),
        ]
        scratch_shapes = []
        kernel = functools.partial(_sym_msg_kernel, d_real=d_real,
                                   gather_tile=gather_tile)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=0,
        grid=grid,
        in_specs=[id_spec, id_spec, id_spec] + table_specs + [
            pl.BlockSpec((msg_block, dp), lambda i: (i, 0)),  # a_u blocks
            pl.BlockSpec((dp, hp2), lambda i: (0, 0)),
            pl.BlockSpec((dp, hp2), lambda i: (0, 0)),
            pl.BlockSpec((dp, hp2), lambda i: (0, 0)),
            pl.BlockSpec((1, hp2), lambda i: (0, 0)),
            pl.BlockSpec((1, hp2), lambda i: (0, 0)),
            pl.BlockSpec((1, hp2), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((msg_block, hp), lambda i: (i, 0)),
        scratch_shapes=scratch_shapes,
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((ua_rows, hp), jnp.float32),
        interpret=interpret,
    )(ctr, du1, du2, v, e, e_b, a_u, w1, w23, w4, b, ln_scale, ln_bias)


def _sym_accum_kernel(offs_ref, dest_ref, rep_ref, msg_ref, out_ref, *,
                      block_rows: int, chunk: int, gather_tile: int):
    """Phase B: agg[u] = sum over this block's CSR incidence range of
    msg[rep] — the same destination-tiled window-one-hot walk as every
    other aggregation kernel, with the message rows gathered through the
    duplicate-pointer ``rep`` map."""
    i = pl.program_id(0)
    r0 = i * block_rows
    start = offs_ref[r0]
    end = offs_ref[r0 + block_rows]
    out_ref[...] = jnp.zeros(out_ref.shape, out_ref.dtype)

    def body(k, carry):
        base = k * chunk
        dest = dest_ref[pl.ds(base, chunk), :]
        oh_w = _window_onehot(dest, r0, start, end, base, chunk, block_rows)
        (m_c,) = _gather_rows(
            rep_ref[pl.ds(base, chunk), :], (msg_ref,), gather_tile)
        out_ref[...] += _mm_t(oh_w, m_c).astype(out_ref.dtype)
        return carry

    jax.lax.fori_loop(start // chunk, pl.cdiv(end, chunk), body, 0)


def _sym_accum_kernel_hbm(offs_ref, dest_ref, rep_ref, msg_ref, out_ref,
                          dest_scr, rep_scr, m_gscr, dest_sem, rep_sem,
                          m_gsem, *, block_rows: int, chunk: int,
                          gather_tile: int):
    """HBM-residency phase B: dest/rep ids stream in chunk slices; the
    (Au, HP) message buffer stays in HBM and is walked in gather_tile
    windows."""
    i = pl.program_id(0)
    r0 = i * block_rows
    start = offs_ref[r0]
    end = offs_ref[r0 + block_rows]
    out_ref[...] = jnp.zeros(out_ref.shape, out_ref.dtype)
    edge_streams = ((dest_ref, dest_scr, dest_sem),
                    (rep_ref, rep_scr, rep_sem))

    def body(k, slot):
        dest = dest_scr[slot]
        oh_w = _window_onehot(dest, r0, start, end, k * chunk, chunk,
                              block_rows)
        ((m_c,),) = _gather_rows_hbm(
            (rep_scr[slot],), ((msg_ref, m_gscr, m_gsem),), gather_tile)
        out_ref[...] += _mm_t(oh_w, m_c).astype(out_ref.dtype)

    _stream_loop(start // chunk, pl.cdiv(end, chunk), chunk, edge_streams,
                 body)


def fused_sym_accum_pallas(
    msg: jnp.ndarray,      # (UA, HP) f32 phase-A messages
    dest: jnp.ndarray,     # (IC, 1) int32 sym_dest, sorted over real prefix
    rep: jnp.ndarray,      # (IC, 1) int32 sym_rep
    offsets: jnp.ndarray,  # (EU + 1,) int32 CSR incidence row pointers
    *,
    eu_rows: int,
    block_rows: int = 8,
    chunk: int = 256,
    gather_tile: int = 256,
    residency: str = "vmem",
    interpret: bool = True,
) -> jnp.ndarray:
    ua_rows, hp = msg.shape
    ic_rows = dest.shape[0]
    hbm = _check_residency(residency)
    assert ic_rows % chunk == 0, (ic_rows, chunk)
    assert eu_rows % block_rows == 0, (eu_rows, block_rows)
    assert ua_rows % gather_tile == 0, (ua_rows, gather_tile)
    assert offsets.shape[0] == eu_rows + 1, (offsets.shape, eu_rows)
    grid = (eu_rows // block_rows,)
    if hbm:
        in_specs = [_any_spec(), _any_spec(), _any_spec()]
        scratch_shapes = [
            pltpu.VMEM((2, chunk, 1), jnp.int32),         # dest
            pltpu.VMEM((2, chunk, 1), jnp.int32),         # rep
            pltpu.VMEM((2, gather_tile, hp), msg.dtype),  # msg windows
        ] + [pltpu.SemaphoreType.DMA((2,))] * 3
        kernel = functools.partial(
            _sym_accum_kernel_hbm, block_rows=block_rows, chunk=chunk,
            gather_tile=gather_tile)
    else:
        in_specs = [
            pl.BlockSpec((ic_rows, 1), lambda i, offs: (0, 0)),
            pl.BlockSpec((ic_rows, 1), lambda i, offs: (0, 0)),
            pl.BlockSpec((ua_rows, hp), lambda i, offs: (0, 0)),
        ]
        scratch_shapes = []
        kernel = functools.partial(
            _sym_accum_kernel, block_rows=block_rows, chunk=chunk,
            gather_tile=gather_tile)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_rows, hp), lambda i, offs: (i, 0)),
        scratch_shapes=scratch_shapes,
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((eu_rows, hp), jnp.float32),
        interpret=interpret,
    )(offsets, dest, rep, msg)
