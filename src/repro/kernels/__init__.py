"""Pallas TPU kernels for the compute hot-spots (paper C4 kernel fusion).

Each kernel has a pure-jnp oracle in ref.py; tests sweep shapes/dtypes and
assert_allclose kernel-vs-oracle in interpret mode (CPU CI) — the same
pallas_call lowers to Mosaic on real TPUs.
"""
from . import ops, ref

__all__ = ["ops", "ref"]
