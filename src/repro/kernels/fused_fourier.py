"""Fused-Fourier Pallas kernel (paper 'Fused-Fourier', C4).

Computes the angle basis [1/sqrt(2), cos(n*t), sin(n*t)] / sqrt(pi) for
n = 1..L in one VMEM pass using a lane-index select instead of a concat:
lane 0 is the DC term, lanes 1..L are cosines, lanes L+1..2L are sines.
Lanes >= num_basis (alignment padding) carry zeros and are sliced off by
the ops.py wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(theta_ref, out_ref, *, harmonics: int, num_basis: int):
    t = theta_ref[...]  # (bm, 1)
    bm, k = out_ref.shape
    lane = jax.lax.broadcasted_iota(jnp.int32, (bm, k), 1)
    # harmonic index per lane: cos lanes use n = lane, sin lanes n = lane - L
    n_cos = lane.astype(t.dtype)
    n_sin = (lane - harmonics).astype(t.dtype)
    is_dc = lane == 0
    is_cos = (lane >= 1) & (lane <= harmonics)
    is_sin = (lane > harmonics) & (lane < num_basis)
    ang_cos = t * n_cos
    ang_sin = t * n_sin
    inv_sqrt_pi = 1.0 / jnp.sqrt(jnp.pi)
    val = jnp.where(
        is_dc,
        1.0 / jnp.sqrt(2.0),
        jnp.where(is_cos, jnp.cos(ang_cos), jnp.sin(ang_sin)),
    )
    out_ref[...] = jnp.where(is_dc | is_cos | is_sin, val * inv_sqrt_pi, 0.0)


def fused_fourier_pallas(
    theta: jnp.ndarray,  # (N,) f32, N % block_m == 0
    num_basis: int,
    *,
    k_pad: int = 128,
    block_m: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    n = theta.shape[0]
    assert n % block_m == 0, (n, block_m)
    assert num_basis % 2 == 1 and num_basis <= k_pad
    harmonics = (num_basis - 1) // 2
    grid = (n // block_m,)
    return pl.pallas_call(
        functools.partial(_kernel, harmonics=harmonics, num_basis=num_basis),
        grid=grid,
        in_specs=[pl.BlockSpec((block_m, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_m, k_pad), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, k_pad), theta.dtype),
        interpret=interpret,
    )(theta[:, None])
