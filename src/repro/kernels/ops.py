"""Jit'd public wrappers around the Pallas kernels.

Responsibilities:
  - pad inputs to kernel-aligned shapes (rows -> block multiple, basis
    lanes -> 128) and slice the outputs back;
  - select interpret mode automatically (interpret=True off-TPU so the
    same code paths run in CI; compiled Mosaic on TPU);
  - expose the packed-parameter calling convention used by
    repro.core.interaction.gated_mlp_apply(impl="pallas");
  - preserve operand dtypes (DESIGN.md §4): bf16 inputs reach the kernels
    as bf16 VMEM tiles (the kernels accumulate f32 in-register) and the
    sliced outputs are cast back to the operand dtype.  The custom-VJP
    backwards below upcast their recompute to f32 and accumulate
    cotangents in f32 regardless of the operand dtype.

Every op here is differentiable: the basis kernels (fused_rbf /
fused_fourier), the GatedMLP, and the message-passing megakernels all
carry chunked recompute custom VJPs (the DESIGN.md §3 pattern), so
``mlp_impl="pallas"`` trains end to end — the seed-era forward-only
caveat is gone.  The conv wrappers additionally accept the DESIGN.md §5
``pair`` mirror maps for the undirected bond store.
"""
from __future__ import annotations

import functools
import math
import os

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from .flash_attention import flash_attention_pallas
from .fused_fourier import fused_fourier_pallas
from .fused_gated_mlp import fused_gated_mlp_pallas
from .fused_message_passing import (
    fused_atom_conv_pallas,
    fused_bond_conv_pallas,
    fused_force_readout_pallas,
    fused_sym_accum_pallas,
    fused_sym_msg_pallas,
)
from .fused_rbf import fused_rbf_pallas
from .fused_segment_sum import fused_segment_sum_pallas
from .fused_swiglu import fused_swiglu_pallas


@functools.cache
def _interpret() -> bool:
    # REPRO_KERNELS_INTERPRET=1 forces interpret mode regardless of backend
    # (CI sets it so the kernel paths are exercised without a TPU).
    if os.environ.get("REPRO_KERNELS_INTERPRET", "") not in ("", "0"):
        return True
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# Table residency (DESIGN.md §9): "vmem" | "hbm" | "auto"
# ---------------------------------------------------------------------------
#
# Under "vmem" the megakernels keep their operand tables whole-array
# VMEM-resident; "hbm" leaves them in HBM and streams double-buffered DMA
# slices/windows through ping/pong scratch.  "auto" (the config default)
# resolves per launch from the padded operand-table bytes vs the VMEM
# budget, so CI-small shapes keep the exact vmem lowering while oversized
# batches transparently stream.

_VMEM_BUDGET_ENV = "REPRO_VMEM_BUDGET_MB"
_DEFAULT_VMEM_BUDGET_MB = 16.0

RESIDENCY_TIERS = ("vmem", "hbm")


def vmem_budget_bytes() -> int:
    """Byte budget the "auto" residency heuristic compares operand-table
    bytes against (DESIGN.md §9).  Default ~16 MiB (a TPU core's VMEM);
    override with REPRO_VMEM_BUDGET_MB (tests set it tiny to force the
    hbm tier on small shapes)."""
    return int(float(os.environ.get(_VMEM_BUDGET_ENV,
                                    _DEFAULT_VMEM_BUDGET_MB)) * 2 ** 20)


def _resolve_residency(residency: str, table_bytes: int) -> str:
    if residency == "auto":
        return "vmem" if table_bytes <= vmem_budget_bytes() else "hbm"
    if residency not in RESIDENCY_TIERS:
        raise ValueError(
            f"table_residency must be 'auto', 'vmem' or 'hbm', "
            f"got {residency!r}")
    return residency


def _itemsize(dtype) -> int:
    return np.dtype(dtype).itemsize


def estimate_table_bytes(num_atoms: int, num_bonds: int, num_angles: int,
                         dim: int, *, num_und: int | None = None,
                         itemsize: int = 4) -> int:
    """Analytic operand-table bytes the §3 megakernels keep VMEM-resident
    under ``table_residency="vmem"`` — the max over the atom_conv /
    bond_conv / force-readout launches, mirroring the ops wrappers'
    padding math (ids included).  Model-level twin of the per-launch
    resolution inside each op: serve admission, the bench_iteration
    residency bar, and the oversized-structure tests use it to decide
    whether a batch is VMEM-feasible without tracing a kernel.

    ``num_und``: Eu rows of the §5 mirror tables (``bond_store=
    "undirected"``); None means the directed store.
    """
    dp = _round_up(max(dim, 1), _LANE)
    hp = dp
    mirror = num_und is not None
    # atom_conv: ids (seg/nbr/pair) + v table + e payload + e^a
    ep = _round_up(max(num_bonds, 1), 256)
    ap = _round_up(max(num_atoms, 1), math.lcm(8, 256))
    ea_rows = _round_up(max(num_und, 1), 256) if mirror else ep
    atom = (3 * ep * 4 + ap * dp * itemsize + ep * dp * itemsize
            + ea_rows * hp * itemsize)
    # bond_conv: ids (seg/ik/ctr/pij/pik) + v/e tables + a payload + e^b
    epa = _round_up(max(num_angles, 1), 256)
    bp = _round_up(max(num_bonds, 1), math.lcm(32, 512))
    apg = _round_up(max(num_atoms, 1), 512)
    eb_rows = _round_up(max(num_und, 1), 512) if mirror else bp
    bond = (5 * epa * 4 + apg * dp * itemsize + bp * dp * itemsize
            + epa * dp * itemsize + eb_rows * hp * itemsize)
    # force readout: ids + e + x_hat (+ tiny virial extras)
    force = ep * 4 * 3 + ep * dp * itemsize + ep * _LANE * itemsize
    return max(atom, bond, force)


def resident_vmem_estimate(residency: str, num_atoms: int, num_bonds: int,
                           num_angles: int, dim: int, *,
                           num_und: int | None = None,
                           itemsize: int = 4, chunk: int = 256,
                           gather_tile: int = 512) -> int:
    """Deterministic resident-VMEM estimate per residency tier: the vmem
    tier holds the full operand tables (``estimate_table_bytes``); the hbm
    tier holds only the ping/pong scratch — 2 slots x (chunk rows per edge
    stream + gather_tile rows per table walk).  Backend-independent, so
    the bench_iteration residency bar can be ENFORCED in interpret mode."""
    if residency == "vmem":
        return estimate_table_bytes(num_atoms, num_bonds, num_angles, dim,
                                    num_und=num_und, itemsize=itemsize)
    dp = _round_up(max(dim, 1), _LANE)
    # worst launch is bond_conv: 6 edge streams + 3 gather-table walks
    edge = 2 * chunk * (5 * 4 + dp * itemsize)
    gather = 2 * gather_tile * 3 * dp * itemsize
    return edge + gather


def _pad_rows(x: jnp.ndarray, mult: int) -> tuple[jnp.ndarray, int]:
    n = x.shape[0]
    pad = (-n) % mult
    if pad:
        x = jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1))
    return x, n


# ---------------------------------------------------------------------------
# Basis + GatedMLP kernels with chunked recompute backwards
# ---------------------------------------------------------------------------
#
# These three ops were forward-only in the seed (no VJP on a pallas_call),
# which pinned mlp_impl="pallas" to inference.  Each now carries a custom
# VJP in the §3 recompute style: the forward saves only its (tiny) primal
# operands, and the backward re-derives the basis/MLP chunk-by-chunk with
# a chunk-local jax.vjp of the analytic reference math (kernels/ref.py) —
# f32 accumulation, one (chunk, K) transient tile, nothing stored across
# forward/backward.

def _row_chunks(n_padded: int, chunk: int):
    return n_padded // chunk


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def _fused_rbf(dist, freqs, r_cut, p, block_m):
    k = freqs.shape[0]
    k_pad = (-k) % 128
    freqs_p = jnp.pad(freqs, (0, k_pad)) if k_pad else freqs
    dist_p, n = _pad_rows(dist, block_m)
    out = fused_rbf_pallas(
        dist_p, freqs_p, r_cut, p, block_m=block_m, interpret=_interpret()
    )
    return out[:n, :k]


def _fused_rbf_fwd(dist, freqs, r_cut, p, block_m):
    return _fused_rbf(dist, freqs, r_cut, p, block_m), (dist, freqs)


def _fused_rbf_bwd(r_cut, p, block_m, res, g):
    """Chunked analytic backward: d(sRBF)/d(dist, freqs) via a per-chunk
    jax.vjp of the reference basis (no saved intermediates)."""
    dist, freqs = res
    n = dist.shape[0]
    np_rows = _round_up(max(n, 1), block_m)
    dist_p = jnp.pad(dist.astype(jnp.float32), (0, np_rows - n))
    # padded rows carry zero cotangents, so they contribute nothing
    g_p = jnp.pad(g.astype(jnp.float32),
                  ((0, np_rows - n), (0, 0)))
    freqs32 = freqs.astype(jnp.float32)

    def body(i, carry):
        dd, df = carry
        i0 = i * block_m
        dist_c = jax.lax.dynamic_slice(dist_p, (i0,), (block_m,))
        g_c = jax.lax.dynamic_slice(g_p, (i0, 0), (block_m, g_p.shape[1]))
        _, vjp = jax.vjp(
            lambda dc, fr: ref.fused_rbf_ref(dc, fr, r_cut, p),
            dist_c, freqs32)
        dd_c, df_c = vjp(g_c)
        return (jax.lax.dynamic_update_slice(dd, dd_c, (i0,)), df + df_c)

    dd, df = jax.lax.fori_loop(
        0, _row_chunks(np_rows, block_m), body,
        (jnp.zeros_like(dist_p), jnp.zeros_like(freqs32)))
    return dd[:n].astype(dist.dtype), df.astype(freqs.dtype)


_fused_rbf.defvjp(_fused_rbf_fwd, _fused_rbf_bwd)


def fused_rbf(dist, freqs, r_cut: float, p: int = 8, *, block_m: int = 512):
    """(N,) x (K,) -> (N, K) fused smooth-RBF basis.

    Differentiable w.r.t. distances AND the trainable frequencies (chunked
    recompute custom VJP — the forces/stress autodiff readout and training
    with ``mlp_impl="pallas"`` both pass through here).
    """
    return _fused_rbf(dist, freqs, r_cut, p, block_m)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def _fused_fourier(theta, num_basis, block_m):
    theta_p, n = _pad_rows(theta, block_m)
    out = fused_fourier_pallas(
        theta_p, num_basis, block_m=block_m, interpret=_interpret()
    )
    return out[:n, :num_basis]


def _fused_fourier_fwd(theta, num_basis, block_m):
    return _fused_fourier(theta, num_basis, block_m), theta


def _fused_fourier_bwd(num_basis, block_m, theta, g):
    """Chunked analytic backward: d(FT)/d(theta) per chunk."""
    n = theta.shape[0]
    np_rows = _round_up(max(n, 1), block_m)
    theta_p = jnp.pad(theta.astype(jnp.float32), (0, np_rows - n))
    g_p = jnp.pad(g.astype(jnp.float32), ((0, np_rows - n), (0, 0)))

    def body(i, dt):
        i0 = i * block_m
        theta_c = jax.lax.dynamic_slice(theta_p, (i0,), (block_m,))
        g_c = jax.lax.dynamic_slice(g_p, (i0, 0), (block_m, g_p.shape[1]))
        _, vjp = jax.vjp(
            lambda tc: ref.fused_fourier_ref(tc, num_basis), theta_c)
        (dt_c,) = vjp(g_c)
        return jax.lax.dynamic_update_slice(dt, dt_c, (i0,))

    dt = jax.lax.fori_loop(0, _row_chunks(np_rows, block_m), body,
                           jnp.zeros_like(theta_p))
    return (dt[:n].astype(theta.dtype),)


_fused_fourier.defvjp(_fused_fourier_fwd, _fused_fourier_bwd)


def fused_fourier(theta, num_basis: int, *, block_m: int = 512):
    """(N,) -> (N, num_basis) fused Fourier angle basis (differentiable
    w.r.t. theta via a chunked recompute custom VJP)."""
    return _fused_fourier(theta, num_basis, block_m)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def _fused_gated_mlp_packed(x, w, b, ln_scale, ln_bias, block_m):
    x_p, m = _pad_rows(x, block_m)
    # GEMM operands share x's dtype (cast-to-compute view, DESIGN.md §4);
    # LN params stay as given — the kernel evaluates LN in f32 regardless
    out = fused_gated_mlp_pallas(
        x_p, w.astype(x.dtype), b.astype(x.dtype), ln_scale, ln_bias,
        block_m=block_m, interpret=_interpret(),
    )
    return out[:m]


def _fused_gated_mlp_packed_fwd(x, w, b, ln_scale, ln_bias, block_m):
    out = _fused_gated_mlp_packed(x, w, b, ln_scale, ln_bias, block_m)
    return out, (x, w, b, ln_scale, ln_bias)


def _fused_gated_mlp_packed_bwd(block_m, res, g):
    """Chunked recompute backward over row chunks of x (the §3 pattern):
    each iteration re-derives its (chunk, 2D) GatedMLP with a chunk-local
    jax.vjp of the packed reference — no LN statistics or activations are
    saved anywhere."""
    x, w, b, ln_scale, ln_bias = res
    m = x.shape[0]
    mp = _round_up(max(m, 1), block_m)
    x_p = _pad_rows_f32(x, mp)
    g_p = _pad_rows_f32(g, mp)
    f32 = lambda t: t.astype(jnp.float32)
    w32, b32, s32, o32 = f32(w), f32(b), f32(ln_scale), f32(ln_bias)

    def body(i, carry):
        dx, dw, db, ds, do = carry
        i0 = i * block_m
        x_c = _chunk_of(x_p, i0, block_m)
        g_c = _chunk_of(g_p, i0, block_m)
        _, vjp = jax.vjp(ref.gated_mlp_packed_ref, x_c, w32, b32, s32, o32)
        dx_c, dw_c, db_c, ds_c, do_c = vjp(g_c)
        return (jax.lax.dynamic_update_slice(dx, dx_c, (i0, 0)),
                dw + dw_c, db + db_c, ds + ds_c, do + do_c)

    init = (jnp.zeros_like(x_p), jnp.zeros_like(w32), jnp.zeros_like(b32),
            jnp.zeros_like(s32), jnp.zeros_like(o32))
    dx, dw, db, ds, do = jax.lax.fori_loop(
        0, _row_chunks(mp, block_m), body, init)
    return (dx[:m].astype(x.dtype), dw.astype(w.dtype), db.astype(b.dtype),
            ds.astype(ln_scale.dtype), do.astype(ln_bias.dtype))


_fused_gated_mlp_packed.defvjp(_fused_gated_mlp_packed_fwd,
                               _fused_gated_mlp_packed_bwd)


def fused_gated_mlp_packed(x, w, b, ln_scale, ln_bias, *, block_m: int = 256):
    """CHGNet GatedMLP from pre-packed parameters (w = [Wc ‖ Wg], packed
    once at init — repro.core.interaction.gated_mlp_init); no per-step
    parameter concat inside the jitted step.  Differentiable via a chunked
    recompute custom VJP, so ``mlp_impl="pallas"`` trains end to end."""
    return _fused_gated_mlp_packed(x, w, b, ln_scale, ln_bias, block_m)


def fused_gated_mlp(x, wc, bc, wg, bg, sc, oc, sg, og, *, block_m: int = 256):
    """CHGNet GatedMLP from separate core/gate weights (legacy calling
    convention; packs on the fly — prefer ``fused_gated_mlp_packed``)."""
    return fused_gated_mlp_packed(
        x,
        jnp.concatenate([wc, wg], axis=1),
        jnp.concatenate([bc, bg], axis=0),
        jnp.concatenate([sc, sg], axis=0),
        jnp.concatenate([oc, og], axis=0),
        block_m=block_m,
    )


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _fused_segment_sum(values, segment_ids, offsets, num_segments,
                       block_rows, chunk, residency):
    e, d = values.shape
    ep = _round_up(e, chunk)
    dp = _round_up(d, 128)
    sp = _round_up(num_segments, block_rows)
    values_p = jnp.pad(values, ((0, ep - e), (0, dp - d)))
    seg_p = _pad_ids(segment_ids, ep)
    offs_p = _pad_offsets(offsets, sp)
    # auto resolves from the padded operand bytes (pure function of static
    # shapes, so forward and grad-of-forward pick the same tier)
    residency = _resolve_residency(
        residency, ep * 4 + ep * dp * _itemsize(values.dtype))
    out = fused_segment_sum_pallas(
        values_p, seg_p, offs_p,
        block_rows=block_rows, chunk=chunk, residency=residency,
        interpret=_interpret(),
    )
    return out[:num_segments, :d].astype(values.dtype)


def _fused_segment_sum_fwd(values, segment_ids, offsets, num_segments,
                           block_rows, chunk, residency):
    out = _fused_segment_sum(values, segment_ids, offsets, num_segments,
                             block_rows, chunk, residency)
    return out, (segment_ids, offsets)


def _fused_segment_sum_bwd(num_segments, block_rows, chunk, residency,
                           res, g):
    # d/dv[e] of sum-into-rows is a gather: g[seg[e]] on real edges, 0 on
    # the padded tail — no scatter in the backward pass either.
    segment_ids, offsets = res
    valid = jnp.arange(segment_ids.shape[0]) < offsets[num_segments]
    dv = jnp.where(valid[:, None], g[segment_ids], 0.0).astype(g.dtype)
    f0 = jax.dtypes.float0  # integer primals take symbolic-zero cotangents
    return (dv, np.zeros(segment_ids.shape, f0), np.zeros(offsets.shape, f0))


_fused_segment_sum.defvjp(_fused_segment_sum_fwd, _fused_segment_sum_bwd)


def fused_segment_sum(values, segment_ids, offsets, num_segments: int,
                      *, block_rows: int = 8, chunk: int = 256,
                      table_residency: str = "auto"):
    """Sorted-segment reduction: (E, D) edges -> (num_segments, D) rows.

    Requires the sorted-segment layout (DESIGN.md §1): real edges sorted by
    ``segment_ids`` with CSR ``offsets`` of shape (num_segments + 1,),
    ``offsets[-1]`` == number of real edges.  Pads edges to a ``chunk``
    multiple, lanes to 128, and rows to a ``block_rows`` multiple, then
    slices back.  Differentiable (custom VJP: the backward is a gather).

    ``table_residency`` (DESIGN.md §9): "vmem" keeps values/ids whole-array
    resident, "hbm" streams them with double-buffered DMA, "auto" picks by
    operand bytes vs the VMEM budget.
    """
    return _fused_segment_sum(values, segment_ids, offsets, num_segments,
                              block_rows, chunk, table_residency)


# ---------------------------------------------------------------------------
# Fused message passing (gather -> GatedMLP -> envelope -> reduce, DESIGN §3)
# ---------------------------------------------------------------------------
#
# The forward runs the megakernels in fused_message_passing.py: no (E, kD)
# concat and no (E, D) message tensor ever reaches HBM.  The custom VJPs
# implement the redundancy bypass on the backward side: the forward saves
# ONLY the operands (which are live layer inputs anyway), and the backward
# recomputes the message path chunk-by-chunk inside a fori_loop — a
# per-chunk jax.vjp whose transient working set is one (chunk, kD) tile,
# never the full edge set.  Message activations therefore exist nowhere:
# not in the forward, not across forward/backward, and not whole-array in
# the backward.

_LANE = 128  # TPU lane width: feature dims and packed halves pad to this


def _pad2(x, rows, cols):
    # dtype-preserving: bf16 operands stay bf16 VMEM tiles (DESIGN.md §4)
    return jnp.pad(x, ((0, rows - x.shape[0]), (0, cols - x.shape[1])))


def _round_up(n: int, m: int) -> int:
    return n + (-n) % m


def _pad_rows_i32(x, rows):
    return jnp.pad(x.astype(jnp.int32), (0, rows - x.shape[0]))


def _pad_rows_f32(x, rows):
    return jnp.pad(x.astype(jnp.float32), ((0, rows - x.shape[0]), (0, 0)))


def _chunk_of(x, i0, chunk: int):
    if x.ndim == 1:
        return jax.lax.dynamic_slice(x, (i0,), (chunk,))
    return jax.lax.dynamic_slice(x, (i0, 0), (chunk, x.shape[1]))


def _pad_ids(ids, rows):
    return _pad_rows_i32(ids, rows)[:, None]


def _pack_lanes_vec(vec, d, hp):
    """(2d,) packed [core ‖ gate] -> (1, 2*hp) with halves lane-padded
    (dtype-preserving)."""
    out = jnp.zeros((2 * hp,), vec.dtype)
    out = out.at[:d].set(vec[:d])
    out = out.at[hp:hp + d].set(vec[d:])
    return out[None, :]


def _pack_lanes_w(wk, dp, d, hp):
    """(d_in_k, 2d) weight block -> (dp, 2*hp) with halves lane-padded
    (dtype-preserving)."""
    out = jnp.zeros((dp, 2 * hp), wk.dtype)
    out = out.at[:wk.shape[0], :d].set(wk[:, :d])
    out = out.at[:wk.shape[0], hp:hp + d].set(wk[:, d:])
    return out


def _pad_offsets(offsets, num_rows_padded):
    # padded rows are empty: their pointers repeat offsets[-1] (= real edges)
    pad = num_rows_padded + 1 - offsets.shape[0]
    return jnp.pad(offsets.astype(jnp.int32), (0, pad), mode="edge")


@functools.partial(jax.custom_vjp, nondiff_argnums=(11, 12, 13, 14, 15))
def _fused_atom_conv(v, e, e_a, w, b, ln_scale, ln_bias,
                     bond_center, bond_nbr, offsets, pair,
                     und, block_rows, chunk, gather_tile, residency):
    a_rows, dim = v.shape
    de = e.shape[1]
    n_edges = bond_center.shape[0]  # directed bond rows (chunk walk)
    d = w.shape[1] // 2
    # the wrapper splits w rows as [v_center | v_nbr | e] — fail loudly if
    # the caller's operand widths disagree with that partition
    assert w.shape[0] == 2 * dim + de, (w.shape, dim, de)
    dp = _round_up(max(dim, de), _LANE)
    hp = _round_up(d, _LANE)
    # atoms are both the output rows (block_rows tiles) and the in-kernel
    # nbr-gather table (gather_tile windows): pad to a common multiple
    ap = _round_up(a_rows, math.lcm(block_rows, gather_tile))
    ep = _round_up(n_edges, chunk)
    mirror = pair is not None
    assert mirror or not und, "und requires the pair mirror map"
    if und:
        # symmetric trunk (DESIGN.md §10): e itself is an Eu-row table
        # gathered in-kernel through bond_pair, like the e_a envelope
        e_p = _pad2(e, _round_up(e.shape[0], gather_tile), dp)
    else:
        assert e.shape[0] == n_edges, (e.shape, n_edges)
        e_p = _pad2(e, ep, dp)
    if mirror:
        # undirected store (DESIGN.md §5): e_a is an Eu-row table gathered
        # in-kernel through bond_pair — pad its rows to gather_tile windows
        ea_p = _pad2(e_a, _round_up(e_a.shape[0], gather_tile), hp)
        pair_ids = _pad_ids(pair, ep)
    else:
        ea_p = _pad2(e_a, ep, hp)
        pair_ids = _pad_ids(bond_center, ep)  # unused dummy, aliases seg
    # auto: padded table bytes (ids + v + e + e^a) vs the VMEM budget —
    # pure function of static shapes, so fwd and grad-of-fwd agree
    residency = _resolve_residency(
        residency,
        3 * ep * 4 + ap * dp * _itemsize(v.dtype)
        + e_p.shape[0] * dp * _itemsize(e.dtype)
        + ea_p.shape[0] * hp * _itemsize(e_a.dtype))
    out = fused_atom_conv_pallas(
        _pad2(v, ap, dp), e_p, ea_p,
        _pad_ids(bond_center, ep), _pad_ids(bond_nbr, ep), pair_ids,
        _pad_offsets(offsets, ap),
        _pack_lanes_w(w[:dim], dp, d, hp),
        _pack_lanes_w(w[dim:2 * dim], dp, d, hp),
        _pack_lanes_w(w[2 * dim:], dp, d, hp),
        _pack_lanes_vec(b, d, hp),
        _pack_lanes_vec(ln_scale, d, hp), _pack_lanes_vec(ln_bias, d, hp),
        d_real=d, block_rows=block_rows, chunk=chunk,
        gather_tile=gather_tile, mirror=mirror, und=und,
        residency=residency, interpret=_interpret(),
    )
    return out[:a_rows, :d].astype(v.dtype)


def _fused_atom_conv_fwd(v, e, e_a, w, b, ln_scale, ln_bias,
                         bond_center, bond_nbr, offsets, pair,
                         und, block_rows, chunk, gather_tile, residency):
    out = _fused_atom_conv(v, e, e_a, w, b, ln_scale, ln_bias,
                           bond_center, bond_nbr, offsets, pair,
                           und, block_rows, chunk, gather_tile, residency)
    # operands only — messages are rematerialized in the backward
    return out, (v, e, e_a, w, b, ln_scale, ln_bias,
                 bond_center, bond_nbr, offsets, pair)


def _fused_atom_conv_bwd(und, block_rows, chunk, gather_tile, residency,
                         res, g):
    """Tile-wise recompute backward: a fori_loop over edge chunks, each
    iteration re-deriving its (chunk, D) messages with a chunk-local
    jax.vjp — no full-edge concat/message tensor exists here either.
    With the mirror maps (``pair`` set), e_a cotangents accumulate into
    the Eu-row table (the chunk-local vjp's gather transposes to a
    table-shaped scatter-add).

    Residency-agnostic (DESIGN.md §9): the loop body touches one chunk of
    every edge operand via dynamic_slice and writes cotangents back with
    dynamic_update_slice, so XLA already streams HBM<->working-set chunk
    by chunk — exactly the semantics the hbm forward tier gets from its
    explicit DMA, with the Eu-table accumulation as the write stream."""
    (v, e, e_a, w, b, ln_scale, ln_bias, bond_center, bond_nbr, offsets,
     pair) = res
    n_edges = bond_center.shape[0]
    ep = _round_up(n_edges, chunk)
    seg_p = _pad_rows_i32(bond_center, ep)
    nbr_p = _pad_rows_i32(bond_nbr, ep)
    f32 = lambda x: x.astype(jnp.float32)
    v32, w32, b32 = f32(v), f32(w), f32(b)
    lns32, lnb32 = f32(ln_scale), f32(ln_bias)
    g32 = f32(g)
    n_real = offsets[-1].astype(jnp.int32)
    mirror = pair is not None
    if und:
        e_full = f32(e)     # (Eu, D) table — cotangents accumulate whole
    else:
        e_p = _pad_rows_f32(e, ep)
    if mirror:
        ea_full = f32(e_a)  # (Eu, D) table — cotangents accumulate whole
        pair_p = _pad_rows_i32(pair, ep)
    else:
        ea_p = _pad_rows_f32(e_a, ep)

    def body(k, carry):
        dv, dep_, dea, dw, db, dls, dlb = carry
        i0 = k * chunk
        seg_c = _chunk_of(seg_p, i0, chunk)
        nbr_c = _chunk_of(nbr_p, i0, chunk)
        if mirror:
            pair_c = _chunk_of(pair_p, i0, chunk)

        if und:
            def msgs(vv, e_t, ea_t, ww, bb, ss, oo):
                x = jnp.concatenate([vv[seg_c], vv[nbr_c], e_t[pair_c]],
                                    axis=-1)
                return ref.gated_mlp_packed_ref(x, ww, bb, ss, oo) \
                    * ea_t[pair_c]

            e_arg, ea_arg = e_full, ea_full
        elif mirror:
            def msgs(vv, ec, ea_t, ww, bb, ss, oo):
                x = jnp.concatenate([vv[seg_c], vv[nbr_c], ec], axis=-1)
                return ref.gated_mlp_packed_ref(x, ww, bb, ss, oo) \
                    * ea_t[pair_c]

            e_arg, ea_arg = _chunk_of(e_p, i0, chunk), ea_full
        else:
            def msgs(vv, ec, eac, ww, bb, ss, oo):
                x = jnp.concatenate([vv[seg_c], vv[nbr_c], ec], axis=-1)
                return ref.gated_mlp_packed_ref(x, ww, bb, ss, oo) * eac

            e_arg, ea_arg = _chunk_of(e_p, i0, chunk), \
                _chunk_of(ea_p, i0, chunk)

        _, vjp = jax.vjp(msgs, v32, e_arg, ea_arg, w32, b32, lns32, lnb32)
        valid = (i0 + jnp.arange(chunk)) < n_real
        gm = jnp.where(valid[:, None], g32[seg_c], 0.0)
        dvc, dec, deac, dwc, dbc, dlsc, dlbc = vjp(gm)
        dea = dea + deac if mirror else \
            jax.lax.dynamic_update_slice(dea, deac, (i0, 0))
        dep_ = dep_ + dec if und else \
            jax.lax.dynamic_update_slice(dep_, dec, (i0, 0))
        return (dv + dvc, dep_,
                dea, dw + dwc, db + dbc, dls + dlsc, dlb + dlbc)

    init = (jnp.zeros_like(v32),
            jnp.zeros_like(e_full) if und else jnp.zeros_like(e_p),
            jnp.zeros_like(ea_full) if mirror else jnp.zeros_like(ea_p),
            jnp.zeros_like(w32), jnp.zeros_like(b32),
            jnp.zeros_like(lns32), jnp.zeros_like(lnb32))
    # static trip count (padded chunks contribute masked zeros): the loop
    # lowers to scan, so the bwd itself stays reverse-differentiable — the
    # autodiff readout can run on top of the fused convs (forces need one
    # more reverse pass through this function)
    dv, dep_, dea, dw, db, dls, dlb = jax.lax.fori_loop(
        0, ep // chunk, body, init)
    dea = dea.astype(e_a.dtype) if mirror \
        else dea[:e.shape[0]].astype(e_a.dtype)
    de = dep_.astype(e.dtype) if und else dep_[:e.shape[0]].astype(e.dtype)
    f0 = jax.dtypes.float0
    return (dv.astype(v.dtype), de,
            dea, dw.astype(w.dtype),
            db.astype(b.dtype), dls.astype(ln_scale.dtype),
            dlb.astype(ln_bias.dtype),
            np.zeros(bond_center.shape, f0), np.zeros(bond_nbr.shape, f0),
            np.zeros(offsets.shape, f0),
            None if pair is None else np.zeros(pair.shape, f0))


_fused_atom_conv.defvjp(_fused_atom_conv_fwd, _fused_atom_conv_bwd)


def fused_atom_conv(v, e, e_a, w, b, ln_scale, ln_bias,
                    bond_center, bond_nbr, bond_offsets,
                    *, pair=None, und_features: bool = False,
                    block_rows: int = 8, chunk: int = 256,
                    gather_tile: int = 256, table_residency: str = "auto"):
    # block_rows=8: ~tens of bonds per atom, so 8 rows ~ one edge chunk
    """Fused Eq. 4 message path: sum_j e^a_ij * phi(v_i, v_j, e_ij) -> (A, D).

    Requires the sorted-segment layout (DESIGN.md §1): bonds sorted by
    ``bond_center`` with CSR ``bond_offsets``.  Forward is one Pallas
    megakernel (no HBM concat/message tensors); differentiable via a
    chunked recompute-in-backward custom VJP (DESIGN.md §3).

    ``pair`` (DESIGN.md §5): directed->undirected mirror map.  When set,
    ``e_a`` is the (Eu, D) undirected envelope table and the kernel
    gathers it per edge chunk in-register (mirror-indirected operand
    class) — the directed (E, D) expansion never exists in HBM.

    ``und_features`` (DESIGN.md §10): symmetric trunk — ``e`` is itself
    the (Eu, D) undirected bond table and gathers in-kernel through
    ``pair`` alongside ``e_a`` (requires ``pair``); the directed (E, D)
    expansion of the bond features never exists in HBM.

    ``table_residency`` (DESIGN.md §9): "vmem" keeps v/e/e^a whole-array
    resident; "hbm" leaves them in HBM and streams double-buffered DMA
    chunks/windows; "auto" picks by operand-table bytes vs the budget.
    """
    return _fused_atom_conv(v, e, e_a, w, b, ln_scale, ln_bias,
                            bond_center, bond_nbr, bond_offsets, pair,
                            und_features, block_rows, chunk, gather_tile,
                            table_residency)


@functools.partial(jax.custom_vjp, nondiff_argnums=(13, 14, 15, 16))
def _fused_bond_conv(v, e, a, e_b, w, b, ln_scale, ln_bias,
                     angle_ij, angle_ik, center_ids, offsets, pair,
                     block_rows, chunk, gather_tile, residency):
    a_rows, dim = v.shape
    b_rows = e.shape[0]
    e_rows = a.shape[0]
    d = w.shape[1] // 2
    # the wrapper splits w rows into four equal dim-wide blocks
    # [v_c | e_ij | e_ik | a]: all operand widths must equal dim
    assert e.shape[1] == dim and a.shape[1] == dim, \
        (v.shape, e.shape, a.shape)
    assert w.shape[0] == 4 * dim, (w.shape, dim)
    dp = _round_up(max(dim, e.shape[1], a.shape[1]), _LANE)
    hp = _round_up(d, _LANE)
    # bonds are output rows AND the ik-gather table; atoms the ctr-gather
    bp = _round_up(b_rows, math.lcm(block_rows, gather_tile))
    ap = _round_up(a_rows, gather_tile)
    ep = _round_up(e_rows, chunk)
    mirror = pair is not None
    if mirror:
        # undirected store (DESIGN.md §5): e_b is an Eu-row table; both
        # envelope gathers run in-kernel through bond_pair[angle_*] (cheap
        # int gathers here — no float tensor is expanded for them)
        eb_p = _pad2(e_b, _round_up(e_b.shape[0], gather_tile), hp)
        pij = _pad_ids(pair[angle_ij], ep)
        pik = _pad_ids(pair[angle_ik], ep)
    else:
        eb_p = _pad2(e_b, bp, hp)
        pij = _pad_ids(angle_ij, ep)   # unused dummies, alias seg/ik
        pik = _pad_ids(angle_ik, ep)
    residency = _resolve_residency(
        residency,
        5 * ep * 4 + ap * dp * _itemsize(v.dtype)
        + bp * dp * _itemsize(e.dtype) + ep * dp * _itemsize(a.dtype)
        + eb_p.shape[0] * hp * _itemsize(e_b.dtype))
    out = fused_bond_conv_pallas(
        _pad2(v, ap, dp), _pad2(e, bp, dp), _pad2(a, ep, dp), eb_p,
        _pad_ids(angle_ij, ep), _pad_ids(angle_ik, ep),
        _pad_ids(center_ids, ep), pij, pik, _pad_offsets(offsets, bp),
        _pack_lanes_w(w[:dim], dp, d, hp),
        _pack_lanes_w(w[dim:2 * dim], dp, d, hp),
        _pack_lanes_w(w[2 * dim:3 * dim], dp, d, hp),
        _pack_lanes_w(w[3 * dim:], dp, d, hp),
        _pack_lanes_vec(b, d, hp),
        _pack_lanes_vec(ln_scale, d, hp), _pack_lanes_vec(ln_bias, d, hp),
        d_real=d, block_rows=block_rows, chunk=chunk,
        gather_tile=gather_tile, mirror=mirror, residency=residency,
        interpret=_interpret(),
    )
    return out[:b_rows, :d].astype(e.dtype)


def _fused_bond_conv_fwd(v, e, a, e_b, w, b, ln_scale, ln_bias,
                         angle_ij, angle_ik, center_ids, offsets, pair,
                         block_rows, chunk, gather_tile, residency):
    out = _fused_bond_conv(v, e, a, e_b, w, b, ln_scale, ln_bias,
                           angle_ij, angle_ik, center_ids, offsets, pair,
                           block_rows, chunk, gather_tile, residency)
    return out, (v, e, a, e_b, w, b, ln_scale, ln_bias,
                 angle_ij, angle_ik, center_ids, offsets, pair)


def _fused_bond_conv_bwd(block_rows, chunk, gather_tile, residency, res, g):
    """Tile-wise recompute backward over angle chunks (see atom_conv).
    With the mirror maps, the envelope factors gather from the Eu-row
    table and their cotangents accumulate into it.  Residency-agnostic:
    chunk-local dynamic slices already stream (DESIGN.md §9)."""
    (v, e, a, e_b, w, b, ln_scale, ln_bias,
     angle_ij, angle_ik, center_ids, offsets, pair) = res
    e_rows = a.shape[0]
    ep = _round_up(e_rows, chunk)
    ij_p = _pad_rows_i32(angle_ij, ep)
    ik_p = _pad_rows_i32(angle_ik, ep)
    ctr_p = _pad_rows_i32(center_ids, ep)
    a_p = _pad_rows_f32(a, ep)
    f32 = lambda x: x.astype(jnp.float32)
    v32, e32, eb32, w32, b32 = f32(v), f32(e), f32(e_b), f32(w), f32(b)
    lns32, lnb32 = f32(ln_scale), f32(ln_bias)
    g32 = f32(g)
    n_real = offsets[-1].astype(jnp.int32)
    mirror = pair is not None
    if mirror:
        pij_p = _pad_rows_i32(pair[angle_ij], ep)
        pik_p = _pad_rows_i32(pair[angle_ik], ep)

    def body(k, carry):
        dv, de, dap, deb, dw, db, dls, dlb = carry
        i0 = k * chunk
        ij_c = _chunk_of(ij_p, i0, chunk)
        ik_c = _chunk_of(ik_p, i0, chunk)
        ctr_c = _chunk_of(ctr_p, i0, chunk)
        if mirror:
            pij_c = _chunk_of(pij_p, i0, chunk)
            pik_c = _chunk_of(pik_p, i0, chunk)
        else:
            pij_c, pik_c = ij_c, ik_c

        def msgs(vv, ee, ac, eb, ww, bb, ss, oo):
            x = jnp.concatenate([vv[ctr_c], ee[ij_c], ee[ik_c], ac], axis=-1)
            phi = ref.gated_mlp_packed_ref(x, ww, bb, ss, oo)
            return phi * eb[pij_c] * eb[pik_c]

        _, vjp = jax.vjp(msgs, v32, e32, _chunk_of(a_p, i0, chunk), eb32,
                         w32, b32, lns32, lnb32)
        valid = (i0 + jnp.arange(chunk)) < n_real
        gm = jnp.where(valid[:, None], g32[ij_c], 0.0)
        dvc, dec, dac, debc, dwc, dbc, dlsc, dlbc = vjp(gm)
        return (dv + dvc, de + dec,
                jax.lax.dynamic_update_slice(dap, dac, (i0, 0)),
                deb + debc, dw + dwc, db + dbc, dls + dlsc, dlb + dlbc)

    init = (jnp.zeros_like(v32), jnp.zeros_like(e32), jnp.zeros_like(a_p),
            jnp.zeros_like(eb32), jnp.zeros_like(w32), jnp.zeros_like(b32),
            jnp.zeros_like(lns32), jnp.zeros_like(lnb32))
    # static trip count -> scan -> reverse-differentiable (see atom_conv)
    dv, de, dap, deb, dw, db, dls, dlb = jax.lax.fori_loop(
        0, ep // chunk, body, init)
    f0 = jax.dtypes.float0
    return (dv.astype(v.dtype), de.astype(e.dtype),
            dap[:e_rows].astype(a.dtype), deb.astype(e_b.dtype),
            dw.astype(w.dtype), db.astype(b.dtype),
            dls.astype(ln_scale.dtype), dlb.astype(ln_bias.dtype),
            np.zeros(angle_ij.shape, f0), np.zeros(angle_ik.shape, f0),
            np.zeros(center_ids.shape, f0), np.zeros(offsets.shape, f0),
            None if pair is None else np.zeros(pair.shape, f0))


_fused_bond_conv.defvjp(_fused_bond_conv_fwd, _fused_bond_conv_bwd)


def fused_bond_conv(v, e, a, e_b, w, b, ln_scale, ln_bias,
                    angle_ij, angle_ik, center_ids, angle_offsets,
                    *, pair=None, block_rows: int = 32, chunk: int = 256,
                    gather_tile: int = 512, table_residency: str = "auto"):
    # block_rows=32: angles-per-bond is small (~1-5), so a wider row tile
    # keeps each program's edge range near one chunk instead of paying the
    # per-program gather-loop overhead for a handful of edges
    """Fused Eq. 5 message path:
    sum_k e^b_ij e^b_ik phi(v_c, e_ij, e_ik, a_ijk) -> (B, D).

    ``center_ids = bond_center[angle_ij]`` (a cheap int gather the caller
    performs; no float tensor is materialized for it).  Requires angles
    sorted by ``angle_ij`` with CSR ``angle_offsets`` (DESIGN.md §1).

    ``pair`` (DESIGN.md §5): directed->undirected mirror map.  When set,
    ``e_b`` is the (Eu, D) undirected envelope table; both envelope
    factors gather through ``pair[angle_*]`` inside the kernel.

    ``table_residency`` (DESIGN.md §9): "vmem" | "hbm" | "auto" as in
    ``fused_atom_conv`` — here the streamed tables are v/e/e^b plus the
    angle payload.
    """
    return _fused_bond_conv(v, e, a, e_b, w, b, ln_scale, ln_bias,
                            angle_ij, angle_ik, center_ids, angle_offsets,
                            pair, block_rows, chunk, gather_tile,
                            table_residency)


@functools.partial(jax.custom_vjp, nondiff_argnums=(14, 15, 16, 17, 18))
def _fused_sym_bond_conv(v, e, a_u, e_b, w, b, ln_scale, ln_bias,
                         ctr, du1, du2, rep, dest, offsets,
                         msg_block, block_rows, chunk, gather_tile,
                         residency):
    a_rows, dim = v.shape
    eu_rows = e.shape[0]
    ua_rows = a_u.shape[0]
    d = w.shape[1] // 2
    # the wrapper splits w rows into four equal dim-wide blocks
    # [v_c | e_ij | e_ik | a]; both e slots read the swap-symmetric e_s,
    # so w2 and w3 precombine into one GEMM block (DESIGN.md §10)
    assert e.shape[1] == dim and a_u.shape[1] == dim, \
        (v.shape, e.shape, a_u.shape)
    assert w.shape[0] == 4 * dim, (w.shape, dim)
    assert e_b.shape[0] == eu_rows, (e_b.shape, eu_rows)
    dp = _round_up(dim, _LANE)
    hp = _round_up(d, _LANE)
    ap = _round_up(a_rows, gather_tile)
    # Eu bonds are phase-B output rows AND a phase-A gather table; dedup
    # angles are phase-A output rows AND the phase-B msg-gather table
    eup = _round_up(eu_rows, math.lcm(block_rows, gather_tile))
    uap = _round_up(ua_rows, math.lcm(msg_block, gather_tile))
    icp = _round_up(dest.shape[0], chunk)
    # residency resolves per phase: A holds the v/e/e^b gather tables, B
    # the incidence ids plus the f32 message buffer
    res_a = _resolve_residency(
        residency,
        ap * dp * _itemsize(v.dtype) + eup * dp * _itemsize(e.dtype)
        + eup * hp * _itemsize(e_b.dtype))
    res_b = _resolve_residency(residency, 2 * icp * 4 + uap * hp * 4)
    msg = fused_sym_msg_pallas(
        _pad2(v, ap, dp), _pad2(e, eup, dp), _pad2(a_u, uap, dp),
        _pad2(e_b, eup, hp),
        _pad_ids(ctr, uap), _pad_ids(du1, uap), _pad_ids(du2, uap),
        _pack_lanes_w(w[:dim], dp, d, hp),
        _pack_lanes_w(w[dim:2 * dim] + w[2 * dim:3 * dim], dp, d, hp),
        _pack_lanes_w(w[3 * dim:], dp, d, hp),
        _pack_lanes_vec(b, d, hp),
        _pack_lanes_vec(ln_scale, d, hp), _pack_lanes_vec(ln_bias, d, hp),
        d_real=d, msg_block=msg_block, gather_tile=gather_tile,
        residency=res_a, interpret=_interpret(),
    )
    agg = fused_sym_accum_pallas(
        msg, _pad_ids(dest, icp), _pad_ids(rep, icp),
        _pad_offsets(offsets, eup), eu_rows=eup, block_rows=block_rows,
        chunk=chunk, gather_tile=gather_tile, residency=res_b,
        interpret=_interpret(),
    )
    return agg[:eu_rows, :d].astype(e.dtype)


def _fused_sym_bond_conv_fwd(v, e, a_u, e_b, w, b, ln_scale, ln_bias,
                             ctr, du1, du2, rep, dest, offsets,
                             msg_block, block_rows, chunk, gather_tile,
                             residency):
    out = _fused_sym_bond_conv(v, e, a_u, e_b, w, b, ln_scale, ln_bias,
                               ctr, du1, du2, rep, dest, offsets,
                               msg_block, block_rows, chunk, gather_tile,
                               residency)
    return out, (v, e, a_u, e_b, w, b, ln_scale, ln_bias,
                 ctr, du1, du2, rep, dest, offsets)


def _fused_sym_bond_conv_bwd(msg_block, block_rows, chunk, gather_tile,
                             residency, res, g):
    """Tile-wise recompute backward over dedup-angle chunks (see
    atom_conv).  The incidence store is not walked here: each real Au row
    lands on exactly its two pair destinations, so the message cotangent
    is gm = g[du1] + g[du2] directly (self-image rows du1 == du2 read 2g,
    which is exactly their forward double-count)."""
    (v, e, a_u, e_b, w, b, ln_scale, ln_bias,
     ctr, du1, du2, rep, dest, offsets) = res
    ua_rows = a_u.shape[0]
    uap = _round_up(ua_rows, chunk)
    ctr_p = _pad_rows_i32(ctr, uap)
    du1_p = _pad_rows_i32(du1, uap)
    du2_p = _pad_rows_i32(du2, uap)
    a_p = _pad_rows_f32(a_u, uap)
    f32 = lambda x: x.astype(jnp.float32)
    v32, e32, eb32, w32, b32 = f32(v), f32(e), f32(e_b), f32(w), f32(b)
    lns32, lnb32 = f32(ln_scale), f32(ln_bias)
    g32 = f32(g)
    # each real dedup angle owns exactly TWO incidences (DESIGN.md §10)
    n_real = (offsets[-1] // 2).astype(jnp.int32)

    def body(k, carry):
        dv, de, dap, deb, dw, db, dls, dlb = carry
        i0 = k * chunk
        ctr_c = _chunk_of(ctr_p, i0, chunk)
        du1_c = _chunk_of(du1_p, i0, chunk)
        du2_c = _chunk_of(du2_p, i0, chunk)

        def msgs(vv, ee, ac, eb, ww, bb, ss, oo):
            es = ee[du1_c] + ee[du2_c]
            x = jnp.concatenate([vv[ctr_c], es, es, ac], axis=-1)
            phi = ref.gated_mlp_packed_ref(x, ww, bb, ss, oo)
            return phi * eb[du1_c] * eb[du2_c]

        _, vjp = jax.vjp(msgs, v32, e32, _chunk_of(a_p, i0, chunk), eb32,
                         w32, b32, lns32, lnb32)
        valid = (i0 + jnp.arange(chunk)) < n_real
        gm = jnp.where(valid[:, None], g32[du1_c] + g32[du2_c], 0.0)
        dvc, dec, dac, debc, dwc, dbc, dlsc, dlbc = vjp(gm)
        return (dv + dvc, de + dec,
                jax.lax.dynamic_update_slice(dap, dac, (i0, 0)),
                deb + debc, dw + dwc, db + dbc, dls + dlsc, dlb + dlbc)

    init = (jnp.zeros_like(v32), jnp.zeros_like(e32), jnp.zeros_like(a_p),
            jnp.zeros_like(eb32), jnp.zeros_like(w32), jnp.zeros_like(b32),
            jnp.zeros_like(lns32), jnp.zeros_like(lnb32))
    # static trip count -> scan -> reverse-differentiable (see atom_conv)
    dv, de, dap, deb, dw, db, dls, dlb = jax.lax.fori_loop(
        0, uap // chunk, body, init)
    f0 = jax.dtypes.float0
    return (dv.astype(v.dtype), de.astype(e.dtype),
            dap[:ua_rows].astype(a_u.dtype), deb.astype(e_b.dtype),
            dw.astype(w.dtype), db.astype(b.dtype),
            dls.astype(ln_scale.dtype), dlb.astype(ln_bias.dtype),
            np.zeros(ctr.shape, f0), np.zeros(du1.shape, f0),
            np.zeros(du2.shape, f0), np.zeros(rep.shape, f0),
            np.zeros(dest.shape, f0), np.zeros(offsets.shape, f0))


_fused_sym_bond_conv.defvjp(_fused_sym_bond_conv_fwd,
                            _fused_sym_bond_conv_bwd)


def fused_sym_bond_conv(v, e, a_u, e_b, w, b, ln_scale, ln_bias,
                        ctr, du1, du2, rep, dest, offsets,
                        *, msg_block: int = 256, block_rows: int = 32,
                        chunk: int = 256, gather_tile: int = 512,
                        table_residency: str = "auto"):
    """Fused symmetric-trunk Eq. 5 message path (DESIGN.md §10):

        msg_w  = e^b[du1] e^b[du2] phi(v_c, e_s, e_s, a_w),
        e_s    = e[du1] + e[du2],
        agg[u] = sum over incidences (u, w) of msg_w        -> (Eu, D)

    over the dedup angle rows, with one gated-MLP evaluation per
    UNDIRECTED angle — half the directed count — scattered to BOTH
    undirected bonds of its pair through the sym-incidence store
    (``dest``/``rep`` sorted by destination, CSR ``offsets``).  Two
    launches: a phase-A message kernel over Au blocks and a phase-B
    destination-tiled accumulator over Eu blocks; splitting at the
    scatter is what keeps phi evaluated once per angle.

    ``ctr = bond_center[und_angle_ij]``, ``du1/du2 = bond_pair[
    und_angle_ij/ik]`` (cheap int gathers the caller performs).

    ``table_residency`` (DESIGN.md §9): "vmem" | "hbm" | "auto",
    resolved independently for each phase.
    """
    return _fused_sym_bond_conv(v, e, a_u, e_b, w, b, ln_scale, ln_bias,
                                ctr, du1, du2, rep, dest, offsets,
                                msg_block, block_rows, chunk, gather_tile,
                                table_residency)


@functools.partial(jax.custom_vjp, nondiff_argnums=(8, 9, 10, 11))
def _fused_force_readout(e, x_hat, w1, b1, w2, b2, bond_center, offsets,
                         num_atoms, block_rows, chunk, residency):
    e_rows, dim = e.shape
    dp = _round_up(dim, _LANE)
    xp = _LANE
    ap = _round_up(num_atoms, block_rows)
    ep = _round_up(e_rows, chunk)
    residency = _resolve_residency(
        residency, ep * 4 + ep * dp * _itemsize(e.dtype)
        + ep * xp * _itemsize(x_hat.dtype))
    out = fused_force_readout_pallas(
        _pad2(e, ep, dp), _pad2(x_hat, ep, xp),
        _pad_ids(bond_center, ep), _pad_offsets(offsets, ap),
        _pad2(w1, dp, dp), _pad2(b1[None, :], 1, dp),
        _pad2(w2.T, 1, dp), jnp.full((1, xp), b2[0], b2.dtype),
        block_rows=block_rows, chunk=chunk, residency=residency,
        interpret=_interpret(),
    )
    return out[:num_atoms, :x_hat.shape[1]].astype(e.dtype)


def _fused_force_readout_fwd(e, x_hat, w1, b1, w2, b2, bond_center, offsets,
                             num_atoms, block_rows, chunk, residency):
    out = _fused_force_readout(e, x_hat, w1, b1, w2, b2, bond_center,
                               offsets, num_atoms, block_rows, chunk,
                               residency)
    return out, (e, x_hat, w1, b1, w2, b2, bond_center, offsets)


def _fused_force_readout_bwd(num_atoms, block_rows, chunk, residency,
                             res, g):
    """Tile-wise recompute backward over bond chunks (see atom_conv).
    Residency-agnostic: chunk-local dynamic slices already stream."""
    e, x_hat, w1, b1, w2, b2, bond_center, offsets = res
    e_rows = e.shape[0]
    ep = _round_up(e_rows, chunk)
    seg_p = _pad_rows_i32(bond_center, ep)
    e_p = _pad_rows_f32(e, ep)
    xh_p = _pad_rows_f32(x_hat, ep)
    f32 = lambda x: x.astype(jnp.float32)
    w1_32, b1_32, w2_32, b2_32 = f32(w1), f32(b1), f32(w2), f32(b2)
    g32 = f32(g)
    n_real = offsets[-1].astype(jnp.int32)

    def body(k, carry):
        dep_, dxhp, dw1, db1, dw2, db2 = carry
        i0 = k * chunk
        seg_c = _chunk_of(seg_p, i0, chunk)

        def contribs(ec, xc, w1_, b1_, w2_, b2_):
            h = jax.nn.silu(ec @ w1_ + b1_)
            return (h @ w2_ + b2_) * xc

        _, vjp = jax.vjp(contribs, _chunk_of(e_p, i0, chunk),
                         _chunk_of(xh_p, i0, chunk),
                         w1_32, b1_32, w2_32, b2_32)
        valid = (i0 + jnp.arange(chunk)) < n_real
        gm = jnp.where(valid[:, None], g32[seg_c], 0.0)
        dec, dxc, dw1c, db1c, dw2c, db2c = vjp(gm)
        return (jax.lax.dynamic_update_slice(dep_, dec, (i0, 0)),
                jax.lax.dynamic_update_slice(dxhp, dxc, (i0, 0)),
                dw1 + dw1c, db1 + db1c, dw2 + dw2c, db2 + db2c)

    init = (jnp.zeros_like(e_p), jnp.zeros_like(xh_p),
            jnp.zeros_like(w1_32), jnp.zeros_like(b1_32),
            jnp.zeros_like(w2_32), jnp.zeros_like(b2_32))
    # static trip count -> scan -> reverse-differentiable (see atom_conv)
    dep_, dxhp, dw1, db1, dw2, db2 = jax.lax.fori_loop(
        0, ep // chunk, body, init)
    f0 = jax.dtypes.float0
    return (dep_[:e_rows].astype(e.dtype), dxhp[:e_rows].astype(x_hat.dtype),
            dw1.astype(w1.dtype), db1.astype(b1.dtype),
            dw2.astype(w2.dtype), db2.astype(b2.dtype),
            np.zeros(bond_center.shape, f0), np.zeros(offsets.shape, f0))


_fused_force_readout.defvjp(_fused_force_readout_fwd,
                            _fused_force_readout_bwd)


def fused_force_readout(e, x_hat, w1, b1, w2, b2, bond_center, bond_offsets,
                        num_atoms: int, *, block_rows: int = 8,
                        chunk: int = 256, table_residency: str = "auto"):
    """Fused Eq. 7 direct-force readout: F_i = sum_j n_ij x_hat_ij -> (A, 3).

    The per-bond scalar MLP (w1/b1 -> silu -> w2/b2), the x_hat weighting,
    and the per-atom reduction run in one megakernel over the sorted CSR
    rows; ``n_ij`` never exists in HBM.  Rotation equivariance (Eq. 8) is
    preserved because ``n_ij`` stays a scalar per bond.

    ``table_residency`` (DESIGN.md §9): "vmem" | "hbm" | "auto" — the
    streamed operands here are the bond features and x_hat payload.
    """
    return _fused_force_readout(e, x_hat, w1, b1, w2, b2, bond_center,
                                bond_offsets, num_atoms, block_rows, chunk,
                                table_residency)


@functools.partial(jax.custom_vjp, nondiff_argnums=(10, 11, 12, 13, 14))
def _fused_force_virial_readout(e, x_hat, dist, w1, b1, w2, b2, bond_center,
                                bond_crystal, offsets, num_atoms,
                                num_crystals, block_rows, chunk, residency):
    e_rows, dim = e.shape
    dp = _round_up(dim, _LANE)
    xp = _LANE
    ap = _round_up(num_atoms, block_rows)
    bp = _round_up(num_crystals, block_rows)
    ep = _round_up(e_rows, chunk)
    dist_p = jnp.pad(dist.astype(jnp.float32), (0, ep - e_rows))[:, None]
    residency = _resolve_residency(
        residency, 2 * ep * 4 + ep * dp * _itemsize(e.dtype)
        + ep * xp * _itemsize(x_hat.dtype) + ep * 4)
    out, sig = fused_force_readout_pallas(
        _pad2(e, ep, dp), _pad2(x_hat, ep, xp),
        _pad_ids(bond_center, ep), _pad_offsets(offsets, ap),
        _pad2(w1, dp, dp), _pad2(b1[None, :], 1, dp),
        _pad2(w2.T, 1, dp), jnp.full((1, xp), b2[0], b2.dtype),
        cry=_pad_ids(bond_crystal, ep), dist=dist_p, num_crystals=bp,
        virial=True, block_rows=block_rows, chunk=chunk,
        residency=residency, interpret=_interpret(),
    )
    forces = out[:num_atoms, :x_hat.shape[1]].astype(e.dtype)
    # accumulator lanes are [m*128 + n] (DESIGN.md §7); stays f32 (§4)
    raw = sig[:num_crystals].reshape(num_crystals, 3, _LANE)[:, :, :3]
    return forces, raw


def _fused_force_virial_readout_fwd(e, x_hat, dist, w1, b1, w2, b2,
                                    bond_center, bond_crystal, offsets,
                                    num_atoms, num_crystals, block_rows,
                                    chunk, residency):
    out = _fused_force_virial_readout(e, x_hat, dist, w1, b1, w2, b2,
                                      bond_center, bond_crystal, offsets,
                                      num_atoms, num_crystals, block_rows,
                                      chunk, residency)
    return out, (e, x_hat, dist, w1, b1, w2, b2, bond_center, bond_crystal,
                 offsets)


def _fused_force_virial_readout_bwd(num_atoms, num_crystals, block_rows,
                                    chunk, residency, res, g):
    """Tile-wise recompute backward over bond chunks with DUAL cotangents:
    each chunk re-derives its (chunk, 3) force and (chunk, 9) virial
    contributions with one chunk-local jax.vjp, gathers the force
    cotangent through bond_center and the stress cotangent through
    bond_crystal, and masks both by edge validity (DESIGN.md §7)."""
    (e, x_hat, dist, w1, b1, w2, b2, bond_center, bond_crystal,
     offsets) = res
    g_f, g_s = g
    e_rows = e.shape[0]
    ep = _round_up(e_rows, chunk)
    seg_p = _pad_rows_i32(bond_center, ep)
    cry_p = _pad_rows_i32(bond_crystal, ep)
    e_p = _pad_rows_f32(e, ep)
    xh_p = _pad_rows_f32(x_hat, ep)
    dist_p = jnp.pad(dist.astype(jnp.float32), (0, ep - e_rows))
    f32 = lambda x: x.astype(jnp.float32)
    w1_32, b1_32, w2_32, b2_32 = f32(w1), f32(b1), f32(w2), f32(b2)
    gf32 = f32(g_f)
    gs32 = f32(g_s).reshape(num_crystals, 9)
    n_real = offsets[-1].astype(jnp.int32)

    def body(k, carry):
        dep_, dxhp, ddp, dw1, db1, dw2, db2 = carry
        i0 = k * chunk
        seg_c = _chunk_of(seg_p, i0, chunk)
        cry_c = _chunk_of(cry_p, i0, chunk)

        def contribs(ec, xc, dc, w1_, b1_, w2_, b2_):
            h = jax.nn.silu(ec @ w1_ + b1_)
            n = h @ w2_ + b2_                       # (chunk, 1)
            outer = (xc[:, :, None] * xc[:, None, :]).reshape(chunk, 9)
            return n * xc, (n * dc[:, None]) * outer

        _, vjp = jax.vjp(contribs, _chunk_of(e_p, i0, chunk),
                         _chunk_of(xh_p, i0, chunk),
                         _chunk_of(dist_p, i0, chunk),
                         w1_32, b1_32, w2_32, b2_32)
        valid = (i0 + jnp.arange(chunk)) < n_real
        gm_f = jnp.where(valid[:, None], gf32[seg_c], 0.0)
        gm_s = jnp.where(valid[:, None], gs32[cry_c], 0.0)
        dec, dxc, ddc, dw1c, db1c, dw2c, db2c = vjp((gm_f, gm_s))
        return (jax.lax.dynamic_update_slice(dep_, dec, (i0, 0)),
                jax.lax.dynamic_update_slice(dxhp, dxc, (i0, 0)),
                jax.lax.dynamic_update_slice(ddp, ddc, (i0,)),
                dw1 + dw1c, db1 + db1c, dw2 + dw2c, db2 + db2c)

    init = (jnp.zeros_like(e_p), jnp.zeros_like(xh_p),
            jnp.zeros_like(dist_p),
            jnp.zeros_like(w1_32), jnp.zeros_like(b1_32),
            jnp.zeros_like(w2_32), jnp.zeros_like(b2_32))
    # static trip count -> scan -> reverse-differentiable (see atom_conv)
    dep_, dxhp, ddp, dw1, db1, dw2, db2 = jax.lax.fori_loop(
        0, ep // chunk, body, init)
    f0 = jax.dtypes.float0
    return (dep_[:e_rows].astype(e.dtype), dxhp[:e_rows].astype(x_hat.dtype),
            ddp[:e_rows].astype(dist.dtype),
            dw1.astype(w1.dtype), db1.astype(b1.dtype),
            dw2.astype(w2.dtype), db2.astype(b2.dtype),
            np.zeros(bond_center.shape, f0),
            np.zeros(bond_crystal.shape, f0),
            np.zeros(offsets.shape, f0))


_fused_force_virial_readout.defvjp(_fused_force_virial_readout_fwd,
                                   _fused_force_virial_readout_bwd)


def fused_force_virial_readout(e, x_hat, dist, w1, b1, w2, b2, bond_center,
                               bond_crystal, bond_offsets, num_atoms: int,
                               num_crystals: int, *, block_rows: int = 8,
                               chunk: int = 256,
                               table_residency: str = "auto"):
    """Single-pass Eq. 7 force readout + per-bond virial stress epilogue.

    One kernel launch produces BOTH outputs (DESIGN.md §7): the (A, 3)
    forces of ``fused_force_readout`` and the raw (B, 3, 3) f32 per-crystal
    virial partials ``sum n_ij d_ij x_hat ⊗ x_hat`` — accumulated in the
    same tile walk while ``n_ij``/``x_hat`` are VMEM-resident, so the
    stress path costs zero extra HBM reads of ``e``/``vec`` and the
    (E, 3, 3) outer-product tensor never materializes.  Volume
    normalization / unit conversion live in ``core.heads`` (the kernel
    boundary carries raw sums only).  Differentiable via a chunked
    recompute custom VJP emitting cotangents for both outputs.

    ``table_residency`` (DESIGN.md §9): as in ``fused_force_readout``,
    with the crystal ids and per-bond distances as extra streams.
    """
    return _fused_force_virial_readout(e, x_hat, dist, w1, b1, w2, b2,
                                       bond_center, bond_crystal,
                                       bond_offsets, num_atoms, num_crystals,
                                       block_rows, chunk, table_residency)


def fused_swiglu(x, w_gate, w_up, w_down, *, activation: str = "silu",
                 block_m: int = 128, block_f: int = 256):
    """LM gated MLP: (M, D) -> (M, D), whole MLP in one kernel."""
    x_p, m = _pad_rows(x, block_m)
    out = fused_swiglu_pallas(
        x_p, w_gate, w_up, w_down, activation=activation,
        block_m=block_m, block_f=block_f, interpret=_interpret(),
    )
    return out[:m]


def flash_attention(q, k, v, *, causal: bool = True, scale=None,
                    block_q: int = 128, block_k: int = 128):
    """(B, H, S, D) flash attention; folds B,H into the grid."""
    b, h, sq, d = q.shape
    sk = k.shape[2]
    qf = q.reshape(b * h, sq, d)
    kf = k.reshape(b * h, sk, d)
    vf = v.reshape(b * h, sk, d)
    out = flash_attention_pallas(
        qf, kf, vf, causal=causal, scale=scale,
        block_q=block_q, block_k=block_k, interpret=_interpret(),
    )
    return out.reshape(b, h, sq, d)
