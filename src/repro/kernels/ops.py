"""Jit'd public wrappers around the Pallas kernels.

Responsibilities:
  - pad inputs to kernel-aligned shapes (rows -> block multiple, basis
    lanes -> 128) and slice the outputs back;
  - select interpret mode automatically (interpret=True off-TPU so the
    same code paths run in CI; compiled Mosaic on TPU);
  - expose the packed-parameter calling convention used by
    repro.core.interaction.gated_mlp_apply(impl="pallas").
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
import numpy as np

from .flash_attention import flash_attention_pallas
from .fused_fourier import fused_fourier_pallas
from .fused_gated_mlp import fused_gated_mlp_pallas
from .fused_rbf import fused_rbf_pallas
from .fused_segment_sum import fused_segment_sum_pallas
from .fused_swiglu import fused_swiglu_pallas


@functools.cache
def _interpret() -> bool:
    # REPRO_KERNELS_INTERPRET=1 forces interpret mode regardless of backend
    # (CI sets it so the kernel paths are exercised without a TPU).
    if os.environ.get("REPRO_KERNELS_INTERPRET", "") not in ("", "0"):
        return True
    return jax.default_backend() != "tpu"


def _pad_rows(x: jnp.ndarray, mult: int) -> tuple[jnp.ndarray, int]:
    n = x.shape[0]
    pad = (-n) % mult
    if pad:
        x = jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1))
    return x, n


def fused_rbf(dist, freqs, r_cut: float, p: int = 8, *, block_m: int = 512):
    """(N,) x (K,) -> (N, K) fused smooth-RBF basis."""
    k = freqs.shape[0]
    k_pad = (-k) % 128
    freqs_p = jnp.pad(freqs, (0, k_pad)) if k_pad else freqs
    dist_p, n = _pad_rows(dist, block_m)
    out = fused_rbf_pallas(
        dist_p, freqs_p, r_cut, p, block_m=block_m, interpret=_interpret()
    )
    return out[:n, :k]


def fused_fourier(theta, num_basis: int, *, block_m: int = 512):
    """(N,) -> (N, num_basis) fused Fourier angle basis."""
    theta_p, n = _pad_rows(theta, block_m)
    out = fused_fourier_pallas(
        theta_p, num_basis, block_m=block_m, interpret=_interpret()
    )
    return out[:n, :num_basis]


def fused_gated_mlp(x, wc, bc, wg, bg, sc, oc, sg, og, *, block_m: int = 256):
    """CHGNet GatedMLP with packed weights; x: (M, d_in) -> (M, d_out)."""
    w_packed = jnp.concatenate([wc, wg], axis=1)
    b_packed = jnp.concatenate([bc, bg], axis=0)
    ln_scale = jnp.concatenate([sc, sg], axis=0)
    ln_bias = jnp.concatenate([oc, og], axis=0)
    x_p, m = _pad_rows(x, block_m)
    out = fused_gated_mlp_pallas(
        x_p, w_packed, b_packed, ln_scale, ln_bias,
        block_m=block_m, interpret=_interpret(),
    )
    return out[:m]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _fused_segment_sum(values, segment_ids, offsets, num_segments,
                       block_rows, chunk):
    e, d = values.shape
    ep = e + (-e) % chunk
    dp = d + (-d) % 128
    sp = num_segments + (-num_segments) % block_rows
    values_p = jnp.pad(values, ((0, ep - e), (0, dp - d)))
    seg_p = jnp.pad(segment_ids.astype(jnp.int32), (0, ep - e))[:, None]
    # padded rows are empty: their pointers repeat offsets[-1] (= real edges)
    offs_p = jnp.pad(offsets.astype(jnp.int32), (0, sp - num_segments),
                     mode="edge")
    out = fused_segment_sum_pallas(
        values_p, seg_p, offs_p,
        block_rows=block_rows, chunk=chunk, interpret=_interpret(),
    )
    return out[:num_segments, :d].astype(values.dtype)


def _fused_segment_sum_fwd(values, segment_ids, offsets, num_segments,
                           block_rows, chunk):
    out = _fused_segment_sum(values, segment_ids, offsets, num_segments,
                             block_rows, chunk)
    return out, (segment_ids, offsets)


def _fused_segment_sum_bwd(num_segments, block_rows, chunk, res, g):
    # d/dv[e] of sum-into-rows is a gather: g[seg[e]] on real edges, 0 on
    # the padded tail — no scatter in the backward pass either.
    segment_ids, offsets = res
    valid = jnp.arange(segment_ids.shape[0]) < offsets[num_segments]
    dv = jnp.where(valid[:, None], g[segment_ids], 0.0).astype(g.dtype)
    f0 = jax.dtypes.float0  # integer primals take symbolic-zero cotangents
    return (dv, np.zeros(segment_ids.shape, f0), np.zeros(offsets.shape, f0))


_fused_segment_sum.defvjp(_fused_segment_sum_fwd, _fused_segment_sum_bwd)


def fused_segment_sum(values, segment_ids, offsets, num_segments: int,
                      *, block_rows: int = 8, chunk: int = 256):
    """Sorted-segment reduction: (E, D) edges -> (num_segments, D) rows.

    Requires the sorted-segment layout (DESIGN.md §1): real edges sorted by
    ``segment_ids`` with CSR ``offsets`` of shape (num_segments + 1,),
    ``offsets[-1]`` == number of real edges.  Pads edges to a ``chunk``
    multiple, lanes to 128, and rows to a ``block_rows`` multiple, then
    slices back.  Differentiable (custom VJP: the backward is a gather).
    """
    return _fused_segment_sum(values, segment_ids, offsets, num_segments,
                              block_rows, chunk)


def fused_swiglu(x, w_gate, w_up, w_down, *, activation: str = "silu",
                 block_m: int = 128, block_f: int = 256):
    """LM gated MLP: (M, D) -> (M, D), whole MLP in one kernel."""
    x_p, m = _pad_rows(x, block_m)
    out = fused_swiglu_pallas(
        x_p, w_gate, w_up, w_down, activation=activation,
        block_m=block_m, block_f=block_f, interpret=_interpret(),
    )
    return out[:m]


def flash_attention(q, k, v, *, causal: bool = True, scale=None,
                    block_q: int = 128, block_k: int = 128):
    """(B, H, S, D) flash attention; folds B,H into the grid."""
    b, h, sq, d = q.shape
    sk = k.shape[2]
    qf = q.reshape(b * h, sq, d)
    kf = k.reshape(b * h, sk, d)
    vf = v.reshape(b * h, sk, d)
    out = flash_attention_pallas(
        qf, kf, vf, causal=causal, scale=scale,
        block_q=block_q, block_k=block_k, interpret=_interpret(),
    )
    return out.reshape(b, h, sq, d)
