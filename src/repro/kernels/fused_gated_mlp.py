"""Fused GatedMLP Pallas kernel (paper Fig. 3, C4).

Implements phi(x) = silu(LN(x@Wc+bc)) * sigmoid(LN(x@Wg+bg)) with:
  - ONE packed GEMM against [Wc ‖ Wg] (Fig. 3a) hitting the MXU once,
  - shared epilogue in VMEM: both LayerNorms + gating (Fig. 3b),
  - silu(x) = x * sigmoid(x): a single kind of sigmoid evaluation.

Layout: CHGNet dims are d_in ∈ {192, 256}, d_out = 64 — the packed output
is exactly 128 lanes (core ‖ gate), the native TPU lane width. Rows are
tiled by ``block_m``; weights are small enough to stay fully VMEM-resident
(256 x 128 x 4 B = 128 KiB).

Precision (DESIGN.md §4): operands may be bf16 (halving the VMEM tiles) —
the GEMM accumulates f32 on the MXU (``preferred_element_type``), the
LayerNorm statistics and the gating epilogue are evaluated in f32, and
only the final write casts back to the operand dtype.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ln(x, scale, bias, eps=1e-5):
    # f32 statistics (x arrives f32 from the accumulating GEMM)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * scale + bias


def _kernel(x_ref, w_ref, b_ref, lns_ref, lno_ref, out_ref, *, d_out: int):
    x = x_ref[...]                       # (bm, d_in), f32 or bf16
    w = w_ref[...]                       # (d_in, 2*d_out), same dtype
    # bf16 x bf16 -> f32 on the MXU: in-register accumulation stays f32
    y = jnp.dot(x, w, preferred_element_type=jnp.float32) \
        + b_ref[...].astype(jnp.float32)
    core = y[:, :d_out]
    gate = y[:, d_out:]
    core = _ln(core, lns_ref[0, :d_out].astype(jnp.float32),
               lno_ref[0, :d_out].astype(jnp.float32))
    gate = _ln(gate, lns_ref[0, d_out:].astype(jnp.float32),
               lno_ref[0, d_out:].astype(jnp.float32))
    sig_core = jax.nn.sigmoid(core)
    sig_gate = jax.nn.sigmoid(gate)
    # silu(core) = core * sigmoid(core): sigmoid reuse (Fig. 3b dashed line)
    out_ref[...] = ((core * sig_core) * sig_gate).astype(out_ref.dtype)


def fused_gated_mlp_pallas(
    x: jnp.ndarray,        # (M, d_in), M % block_m == 0
    w_packed: jnp.ndarray,  # (d_in, 2*d_out) = [Wc ‖ Wg]
    b_packed: jnp.ndarray,  # (2*d_out,)
    ln_scale: jnp.ndarray,  # (2*d_out,) = [core_scale ‖ gate_scale]
    ln_bias: jnp.ndarray,   # (2*d_out,)
    *,
    block_m: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:
    m, d_in = x.shape
    two_d = w_packed.shape[1]
    d_out = two_d // 2
    assert m % block_m == 0, (m, block_m)
    grid = (m // block_m,)
    return pl.pallas_call(
        functools.partial(_kernel, d_out=d_out),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, d_in), lambda i: (i, 0)),
            pl.BlockSpec((d_in, two_d), lambda i: (0, 0)),
            pl.BlockSpec((1, two_d), lambda i: (0, 0)),
            pl.BlockSpec((1, two_d), lambda i: (0, 0)),
            pl.BlockSpec((1, two_d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, d_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, d_out), x.dtype),
        interpret=interpret,
    )(x, w_packed, b_packed[None, :], ln_scale[None, :], ln_bias[None, :])
