"""Pure-jnp oracles for every Pallas kernel (ground truth in tests).

These mirror the *reference* math (repro.core.basis / interaction) but are
kept dependency-free so kernel tests read as kernel-vs-oracle only.

Precision (DESIGN.md §4): the oracles follow the kernels' accumulator
rules — LayerNorm statistics in f32 — so an oracle fed bf16 operands
models the kernel's semantics (bf16 GEMM inputs, f32 accumulation), not
a fully-bf16 computation.  The custom-VJP backwards in ``kernels.ops``
call these with f32-upcast operands either way.

Table residency (DESIGN.md §9): every oracle here is residency-FREE —
``table_residency="vmem"`` and ``"hbm"`` are two lowerings of the same
math, so kernel tests compare both tiers against one oracle.  The only
residency-specific math is the hbm tier's windowed-one-hot table walk
(``fused_message_passing._gather_rows_hbm``), whose ground truth is
``streamed_gather_ref`` below: a plain-jnp replay of the per-window
accumulation, property-equal to a whole-array ``take``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def envelope(xi, p: int = 8):
    inner = (p + 1.0) * (p + 2.0) + xi * (
        -2.0 * p * (p + 2.0) + xi * (p * (p + 1.0)))
    return 1.0 - 0.5 * xi**p * inner


def fused_rbf_ref(dist, freqs, r_cut: float, p: int = 8):
    """(N,) x (K,) -> (N, K) smooth radial Bessel basis."""
    xi = dist / r_cut
    u = envelope(xi, p)
    r_safe = jnp.where(dist > 1e-8, dist, 1.0)
    # phase = freq * r / r_cut, matching core.basis.smooth_rbf exactly
    val = jnp.sqrt(2.0 / r_cut) * jnp.sin(xi[:, None] * freqs[None, :])
    return val / r_safe[:, None] * u[:, None]


def fused_fourier_ref(theta, num_basis: int):
    """(N,) -> (N, num_basis): [1/sqrt(2), cos(n t), sin(n t)] / sqrt(pi)."""
    harmonics = (num_basis - 1) // 2
    n = jnp.arange(1, harmonics + 1, dtype=theta.dtype)
    ang = theta[:, None] * n
    dc = jnp.full((theta.shape[0], 1), 1.0 / jnp.sqrt(2.0), theta.dtype)
    out = jnp.concatenate([dc, jnp.cos(ang), jnp.sin(ang)], axis=-1)
    return out / jnp.sqrt(jnp.pi).astype(theta.dtype)


def sorted_segment_sum_ref(values, seg_ids, offsets, num_segments):
    """(E, D) x (E,) x (S+1,) -> (S, D) sorted-segment reduction oracle.

    ``offsets[-1]`` delimits the real edges; the padded tail (whatever its
    segment ids) must contribute nothing, which the oracle enforces by
    zeroing it before the reference scatter-add.
    """
    valid = jnp.arange(values.shape[0]) < offsets[num_segments]
    v = jnp.where(valid[:, None], values, 0.0)
    return jax.ops.segment_sum(v, seg_ids, num_segments=num_segments)


def streamed_gather_ref(ids, table, tile: int):
    """(N,) x (R, D) -> (N, D): the hbm tier's windowed table gather.

    Replays ``_gather_rows_hbm``'s math in plain jnp: the table is walked
    in ``tile``-row windows (the ping/pong DMA slots) and each window
    contributes its one-hot-selected rows to a running f32 accumulator —
    ``sum_t onehot(ids in window t) @ table[window t]``.  Every id hits
    exactly one window, so the result equals ``table[ids]`` exactly for
    f32 tables; kernel tests use this to pin the streaming decomposition
    itself, independent of the megakernels around it.  Requires
    ``R % tile == 0`` (the wrappers pad tables to the tile multiple).
    """
    r, d = table.shape
    assert r % tile == 0, (r, tile)
    out = jnp.zeros((ids.shape[0], d), jnp.float32)
    for t in range(r // tile):
        cols = t * tile + jnp.arange(tile)[None, :]
        onehot = (ids[:, None] == cols).astype(jnp.float32)
        out = out + onehot @ table[t * tile:(t + 1) * tile].astype(
            jnp.float32)
    return out.astype(table.dtype)


def _layer_norm(x, scale, bias, eps=1e-5):
    # f32-pinned statistics, mirroring the kernels (DESIGN.md §4)
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32) \
        + bias.astype(jnp.float32)
    return y.astype(x.dtype)


def fused_gated_mlp_ref(x, wc, bc, wg, bg, sc, oc, sg, og):
    """CHGNet GatedMLP: silu(LN(x@wc+bc)) * sigmoid(LN(x@wg+bg))."""
    core = _layer_norm(x @ wc + bc, sc, oc)
    gate = _layer_norm(x @ wg + bg, sg, og)
    return jax.nn.silu(core) * jax.nn.sigmoid(gate)


def gated_mlp_packed_ref(x, w, b, ln_scale, ln_bias):
    """Packed-parameter GatedMLP: w = [Wc ‖ Wg], b/ln_* = [core ‖ gate].

    The GEMM accumulates f32 (kernel accumulator rule, DESIGN.md §4) —
    identical math for f32 operands, kernel-faithful for bf16."""
    d = w.shape[1] // 2
    y = jax.lax.dot_general(
        x, w, (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32) + b.astype(jnp.float32)
    core = _layer_norm(y[..., :d], ln_scale[:d], ln_bias[:d])
    gate = _layer_norm(y[..., d:], ln_scale[d:], ln_bias[d:])
    return jax.nn.silu(core) * jax.nn.sigmoid(gate)


def _mask_real_edges(msg, offsets):
    """Zero everything past offsets[-1] (the real-edge count, DESIGN.md §1)."""
    valid = jnp.arange(msg.shape[0]) < offsets[-1]
    return jnp.where(valid[:, None], msg, 0.0)


def fused_atom_conv_ref(v, e, e_a, w, b, ln_scale, ln_bias,
                        bond_center, bond_nbr, offsets, pair=None,
                        und_features=False):
    """Unfused Eq. 4 message path: gather-concat -> GatedMLP -> envelope ->
    segment reduce.  Ground truth for the atom_conv megakernel; also the
    recompute the custom VJP differentiates in the backward (DESIGN.md §3).

    ``pair`` (DESIGN.md §5): when set, ``e_a`` is the undirected (Eu, D)
    envelope table and is expanded through the mirror map.
    ``und_features`` (DESIGN.md §10): ``e`` too is an (Eu, D) table
    expanded through ``pair`` (requires ``pair``).
    """
    e_dir = e[pair] if und_features else e
    x = jnp.concatenate([v[bond_center], v[bond_nbr], e_dir], axis=-1)
    env = e_a if pair is None else e_a[pair]
    msg = gated_mlp_packed_ref(x, w, b, ln_scale, ln_bias) * env
    msg = _mask_real_edges(msg, offsets)
    return jax.ops.segment_sum(msg, bond_center, num_segments=v.shape[0])


def fused_bond_conv_ref(v, e, a, e_b, w, b, ln_scale, ln_bias,
                        angle_ij, angle_ik, center_ids, offsets, pair=None):
    """Unfused Eq. 5 message path (``center_ids = bond_center[angle_ij]``,
    precomputed by the caller so the op itself carries no graph coupling).

    ``pair`` (DESIGN.md §5): when set, ``e_b`` is the undirected (Eu, D)
    envelope table; both factors gather through ``pair[angle_*]``.
    """
    x = jnp.concatenate(
        [v[center_ids], e[angle_ij], e[angle_ik], a], axis=-1)
    msg = gated_mlp_packed_ref(x, w, b, ln_scale, ln_bias)
    if pair is None:
        msg = msg * e_b[angle_ij] * e_b[angle_ik]
    else:
        msg = msg * e_b[pair[angle_ij]] * e_b[pair[angle_ik]]
    msg = _mask_real_edges(msg, offsets)
    return jax.ops.segment_sum(msg, angle_ij, num_segments=e.shape[0])


def fused_sym_bond_conv_ref(v, e, a_u, e_b, w, b, ln_scale, ln_bias,
                            ctr, du1, du2, rep, dest, offsets):
    """Symmetrized Eq. 5 message path (DESIGN.md §10) -> (Eu, D) agg.

    One swap-invariant message per Au row — e_s = e[du1] + e[du2] fed
    into BOTH e slots of the packed 4D-wide GatedMLP, scaled by the
    pair's two envelopes — scattered through the dest-sorted incidence
    store (rep/dest/offsets): every real Au row lands in both its
    undirected destinations (which may coincide for self-image bonds).
    Ground truth for the two-launch §10 megakernel; also the recompute
    its custom VJP differentiates.
    """
    e_s = e[du1] + e[du2]
    x = jnp.concatenate([v[ctr], e_s, e_s, a_u], axis=-1)
    msg = gated_mlp_packed_ref(x, w, b, ln_scale, ln_bias) \
        * e_b[du1] * e_b[du2]
    incid = _mask_real_edges(msg[rep], offsets)
    return jax.ops.segment_sum(incid, dest, num_segments=e.shape[0])


def fused_force_readout_ref(e, x_hat, w1, b1, w2, b2, bond_center, offsets,
                            num_atoms):
    """Unfused Eq. 7: per-bond scalar MLP -> n_ij * x_hat_ij -> atom reduce."""
    h = jax.nn.silu(e @ w1 + b1)
    n = (h @ w2 + b2)[..., 0]
    contrib = _mask_real_edges(n[:, None] * x_hat, offsets)
    return jax.ops.segment_sum(contrib, bond_center, num_segments=num_atoms)


def fused_force_virial_readout_ref(e, x_hat, dist, w1, b1, w2, b2,
                                   bond_center, bond_crystal, offsets,
                                   num_atoms, num_crystals):
    """Unfused single-pass force + bond-virial stress (DESIGN.md §7).

    Same per-bond scalar MLP as ``fused_force_readout_ref``; the second
    output accumulates the per-crystal virial partials

        raw_c = sum_{ij in c} n_ij d_ij x_hat_ij ⊗ x_hat_ij   (B, 3, 3)

    (== sum (n/d) vec⊗vec — the kernel reuses the VMEM-resident x_hat and
    the scalar d instead of reading vec).  Volume normalization and unit
    conversion happen in ``core.heads``, outside the kernel boundary.
    """
    h = jax.nn.silu(e @ w1 + b1)
    n = (h @ w2 + b2)[..., 0]
    contrib = _mask_real_edges(n[:, None] * x_hat, offsets)
    forces = jax.ops.segment_sum(contrib, bond_center,
                                 num_segments=num_atoms)
    outer = (x_hat[:, :, None] * x_hat[:, None, :]).reshape(-1, 9)
    s_contrib = _mask_real_edges((n * dist)[:, None] * outer, offsets)
    raw = jax.ops.segment_sum(s_contrib, bond_crystal,
                              num_segments=num_crystals)
    return forces, raw.reshape(-1, 3, 3)


def fused_swiglu_ref(x, w_gate, w_up, w_down):
    """LM SwiGLU MLP: (silu(x@w_gate) * (x@w_up)) @ w_down."""
    return (jax.nn.silu(x @ w_gate) * (x @ w_up)) @ w_down


def flash_attention_ref(q, k, v, *, causal: bool, scale: float | None = None):
    """(B, H, S, D) attention oracle with optional causal mask."""
    if scale is None:
        scale = 1.0 / jnp.sqrt(q.shape[-1]).astype(q.dtype)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    if causal:
        s_q, s_k = logits.shape[-2], logits.shape[-1]
        mask = jnp.tril(jnp.ones((s_q, s_k), bool), k=s_k - s_q)
        logits = jnp.where(mask, logits, jnp.finfo(logits.dtype).min)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)
