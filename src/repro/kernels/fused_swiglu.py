"""Fused SwiGLU/GeGLU MLP Pallas kernel — paper C4 generalized to the LM
substrate (DESIGN.md §4 "transfers directly").

Computes the ENTIRE gated MLP in one kernel:
    y = (act(x @ W_gate) * (x @ W_up)) @ W_down
act = silu (SwiGLU, llama-family) or gelu (GeGLU, gemma).

Grid is (M / bm, F / bf): the ff dimension is the reduction axis of the
second GEMM, so the output block index map ignores j and the kernel
accumulates into out_ref across j steps (initialized at j == 0). The
gate/up activations for the (i, j) tile never leave VMEM — this removes
the (M x F) activation HBM round-trip that an unfused MLP pays twice.

VMEM budget per step (f32): x (bm x D) + wg/wu (D x bf) * 2 + wd (bf x D)
+ out (bm x D). With bm=256, bf=512, D=4096: 4+8+8+8+4 = 32 MiB/2... use
bm=128, bf=256 for 16 MiB-class VMEM (defaults below are CI-small; the
TPU launcher picks per-arch tiles).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, wg_ref, wu_ref, wd_ref, out_ref, *, activation: str):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...]                     # (bm, D)
    g = jnp.dot(x, wg_ref[...], preferred_element_type=jnp.float32)
    u = jnp.dot(x, wu_ref[...], preferred_element_type=jnp.float32)
    if activation == "silu":
        act = g * jax.nn.sigmoid(g)
    elif activation == "gelu":
        act = jax.nn.gelu(g, approximate=True)
    else:
        raise ValueError(activation)
    h = (act * u).astype(x.dtype)      # (bm, bf) stays in VMEM
    out_ref[...] += jnp.dot(h, wd_ref[...], preferred_element_type=jnp.float32
                            ).astype(out_ref.dtype)


def fused_swiglu_pallas(
    x: jnp.ndarray,       # (M, D)
    w_gate: jnp.ndarray,  # (D, F)
    w_up: jnp.ndarray,    # (D, F)
    w_down: jnp.ndarray,  # (F, D)
    *,
    activation: str = "silu",
    block_m: int = 128,
    block_f: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:
    m, d = x.shape
    f = w_gate.shape[1]
    assert m % block_m == 0 and f % block_f == 0, (m, f, block_m, block_f)
    grid = (m // block_m, f // block_f)
    return pl.pallas_call(
        functools.partial(_kernel, activation=activation),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, d), lambda i, j: (i, 0)),
            pl.BlockSpec((d, block_f), lambda i, j: (0, j)),
            pl.BlockSpec((d, block_f), lambda i, j: (0, j)),
            pl.BlockSpec((block_f, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, d), x.dtype),
        interpret=interpret,
    )(x, w_gate, w_up, w_down)
