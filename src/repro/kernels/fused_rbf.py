"""Fused-sRBF Pallas kernel (paper 'Fused-sRBF', C4 + C5).

One VMEM-resident kernel computes, per bond distance:
    xi = r / r_cut
    u(xi)        -- factored Horner envelope (Eq. 13, C5)
    sin(f_n xi)  -- trainable-frequency Bessel numerators
    out[n] = sqrt(2/rc) * sin(f_n xi) / r * u(xi)

The reference implementation materializes 4+ HBM-round-trip intermediates
(xi, powers, envelope, phases); here everything stays in VMEM. Distances
are carried as an (N, 1) column so the block layout is TPU-native
(8x128-aligned); the basis axis is padded to a multiple of 128 lanes by the
ops.py wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(dist_ref, freq_ref, out_ref, *, r_cut: float, p: int):
    r = dist_ref[...]  # (bm, 1)
    xi = r / r_cut
    # factored envelope (Eq. 13 corrected), Horner: one pow, two fma
    inner = (p + 1.0) * (p + 2.0) + xi * (
        -2.0 * p * (p + 2.0) + xi * (p * (p + 1.0)))
    u = 1.0 - 0.5 * xi**p * inner
    r_safe = jnp.where(r > 1e-8, r, 1.0)
    phases = xi * freq_ref[...]  # (bm, 1) * (1, K) -> (bm, K)
    out_ref[...] = (jnp.sqrt(2.0 / r_cut) * jnp.sin(phases) / r_safe) * u


def fused_rbf_pallas(
    dist: jnp.ndarray,   # (N,) f32, N % block_m == 0
    freqs: jnp.ndarray,  # (K,) f32, K % 128 == 0 (padded by wrapper)
    r_cut: float,
    p: int = 8,
    *,
    block_m: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    n = dist.shape[0]
    k = freqs.shape[0]
    assert n % block_m == 0, (n, block_m)
    grid = (n // block_m,)
    return pl.pallas_call(
        functools.partial(_kernel, r_cut=r_cut, p=p),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, k), dist.dtype),
        interpret=interpret,
    )(dist[:, None], freqs[None, :])
