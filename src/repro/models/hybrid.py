"""Zamba2-style hybrid: a Mamba2 backbone with one SHARED-weight attention
block applied every ``attn_every`` layers (weights shared, KV cache per
application site).

The layer stack is a lax.scan over mamba layers; the shared attention
block is applied inside the scan via lax.cond on (i % attn_every ==
attn_every - 1), with a dynamic cache-site index i // attn_every.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .config import LMConfig
from .layers import (
    Maker, attention_chunked, attention_full, attn_init, attn_qkv,
    cast_floats, constrain_batch, constrain_logits, embed_lookup,
    gated_mlp_apply, gated_mlp_init, rms_norm,
)
from .ssm import (
    mamba_decode_step, mamba_fwd, mamba_init, mamba_init_state,
)
from .transformer import _prepend_none, _stack


def num_attn_sites(cfg: LMConfig) -> int:
    return cfg.num_layers // cfg.attn_every


def zamba_init(cfg: LMConfig, key, mesh_sizes: dict | None = None):
    dtype = jnp.dtype(cfg.param_dtype)
    mk = Maker(key, mesh_sizes, dtype)
    d, v = cfg.d_model, cfg.padded_vocab

    def mamba_layer(m):
        return {"ln": m.make((d,), P(None), init="ones"), "mamba": mamba_init(m, cfg)}

    if mk.abstract:
        layers = _prepend_none(mamba_layer(mk))
    else:
        layers = _stack([mamba_layer(mk) for _ in range(cfg.num_layers)])
    shared = {
        "ln1": mk.make((d,), P(None), init="ones"),
        "attn": attn_init(mk, d, cfg.num_heads, cfg.num_kv_heads,
                          cfg.resolved_head_dim),
        "ln2": mk.make((d,), P(None), init="ones"),
        "mlp": gated_mlp_init(mk, d, cfg.d_ff),
    }
    return {
        "embed": mk.make((v, d), P(mk.first_ax(v), None), scale=0.02),
        "unembed": mk.make((d, v), P(None, mk.ax("model", v) or mk.first_ax(v)), scale=d**-0.5),
        "final_norm": mk.make((d,), P(None), init="ones"),
        "layers": layers,
        "shared": shared,
    }


def zamba_specs(cfg: LMConfig, mesh_sizes: dict):
    return zamba_init(cfg, None, mesh_sizes)


def _shared_attn_fwd(cfg, sp, x, positions, *, attn_mode, chunk):
    h = rms_norm(x, sp["ln1"])
    q, k, v = attn_qkv(sp["attn"], h, cfg, positions)
    if attn_mode == "chunked":
        out = attention_chunked(q, k, v, causal=True, chunk=chunk)
    else:
        out = attention_full(q, k, v, causal=True)
    b, s, _, _ = out.shape
    x = x + out.reshape(b, s, -1) @ sp["attn"]["wo"]
    h2 = rms_norm(x, sp["ln2"])
    return x + gated_mlp_apply(sp["mlp"], h2, "silu")


def forward_train(cfg: LMConfig, params, tokens, positions, *,
                  attn_mode: str = "full", chunk: int = 1024,
                  ssd_chunk: int = 128, remat: bool = True,
                  batch_axes=None, **_unused):
    params = cast_floats(params, cfg.compute_dtype)
    x = params["embed"][tokens].astype(jnp.dtype(cfg.compute_dtype))
    x = constrain_batch(x, batch_axes)
    shared = params["shared"]
    every = cfg.attn_every

    def body(x, inp):
        i, lp = inp
        x = x + mamba_fwd(lp["mamba"], rms_norm(x, lp["ln"]), cfg,
                          chunk=ssd_chunk)
        x = jax.lax.cond(
            (i % every) == every - 1,
            lambda xx: _shared_attn_fwd(cfg, shared, xx, positions,
                                        attn_mode=attn_mode, chunk=chunk),
            lambda xx: xx,
            x,
        )
        return constrain_batch(x, batch_axes), None

    fn = jax.checkpoint(body) if remat else body
    idx = jnp.arange(cfg.num_layers)
    x, _ = jax.lax.scan(fn, x, (idx, params["layers"]))
    x = rms_norm(x, params["final_norm"])
    return x @ params["unembed"].astype(x.dtype)


def lm_loss(cfg: LMConfig, params, tokens, labels, positions, **fw):
    vocab_axis = fw.pop("vocab_axis", None)
    logits = forward_train(cfg, params, tokens, positions, **fw).astype(jnp.float32)
    logits = constrain_logits(logits, fw.get("batch_axes"), vocab_axis)
    lse = jax.nn.logsumexp(logits, axis=-1)
    # vocab-parallel CE: one-hot dot stays sharded over V (take_along_axis
    # would all-gather the full logits on vocab-sharded meshes)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    gold = jnp.sum(logits * onehot, axis=-1)
    return jnp.mean(lse - gold)


# ---------------------------------------------------------------------------
# decode: per-layer mamba states + per-site attention KV caches
# ---------------------------------------------------------------------------

def init_state(cfg: LMConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16):
    one = mamba_init_state(cfg, batch, dtype)
    mamba_states = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (cfg.num_layers,) + x.shape), one
    )
    sites = num_attn_sites(cfg)
    hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    return {
        "mamba": mamba_states,
        "k": jnp.zeros((sites, batch, max_len, hkv, hd), dtype),
        "v": jnp.zeros((sites, batch, max_len, hkv, hd), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def state_specs(cfg: LMConfig, mesh_sizes: dict, *, batch_axes,
                seq_axis: str | None):
    mk = Maker(None, mesh_sizes)
    head_ax = mk.head_ax(cfg.num_kv_heads)
    seq = seq_axis if head_ax is None else None
    kv = P(None, batch_axes, seq, head_ax, None)
    return {
        "mamba": {
            "ssm": P(None, batch_axes, None, None, None),
            "conv": P(None, batch_axes, None, None),
        },
        "k": kv, "v": kv, "pos": P(),
    }


def prefill(cfg: LMConfig, params, tokens, positions, max_len: int, *,
            chunk: int = 1024, ssd_chunk: int = 128,
            cache_dtype=jnp.bfloat16, batch_axes=None):
    """Run the prompt; return (last logits, decode state): per-layer mamba
    states + per-site attention KV caches (padded to max_len)."""
    params = cast_floats(params, cfg.compute_dtype)
    x = params["embed"][tokens].astype(jnp.dtype(cfg.compute_dtype))
    shared = params["shared"]
    every = cfg.attn_every
    b, s = tokens.shape
    sites = num_attn_sites(cfg)
    hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    k_all = jnp.zeros((sites, b, max_len, hkv, hd), cache_dtype)
    v_all = jnp.zeros((sites, b, max_len, hkv, hd), cache_dtype)

    def body(carry, inp):
        x, k_all, v_all = carry
        i, lp = inp
        y, mst = mamba_fwd(lp["mamba"], rms_norm(x, lp["ln"]), cfg,
                           chunk=ssd_chunk, return_state=True)
        x = x + y
        site = i // every

        def do_attn(args):
            x, k_all, v_all = args
            h = rms_norm(x, shared["ln1"])
            q, k, v = attn_qkv(shared["attn"], h, cfg, positions)
            out = attention_chunked(q, k, v, causal=True, chunk=chunk)
            xx = x + out.reshape(b, s, -1) @ shared["attn"]["wo"]
            h2 = rms_norm(xx, shared["ln2"])
            xx = xx + gated_mlp_apply(shared["mlp"], h2, "silu")
            pad = max_len - s
            kp = jnp.pad(k.astype(cache_dtype),
                         ((0, 0), (0, pad), (0, 0), (0, 0)))
            vp = jnp.pad(v.astype(cache_dtype),
                         ((0, 0), (0, pad), (0, 0), (0, 0)))
            k_all2 = jax.lax.dynamic_update_index_in_dim(k_all, kp, site, 0)
            v_all2 = jax.lax.dynamic_update_index_in_dim(v_all, vp, site, 0)
            return xx, k_all2, v_all2

        x, k_all, v_all = jax.lax.cond(
            (i % every) == every - 1, do_attn, lambda a: a, (x, k_all, v_all)
        )
        return (constrain_batch(x, batch_axes), k_all, v_all), mst

    idx = jnp.arange(cfg.num_layers)
    (x, k_all, v_all), mamba_states = jax.lax.scan(
        body, (x, k_all, v_all), (idx, params["layers"]))
    x = rms_norm(x[:, -1:, :], params["final_norm"])
    logits = x @ params["unembed"].astype(x.dtype)
    state = {"mamba": mamba_states, "k": k_all, "v": v_all,
             "pos": jnp.asarray(s, jnp.int32)}
    return logits, state


def _shared_attn_decode(cfg, sp, x, k_cache, v_cache, pos, positions):
    h = rms_norm(x, sp["ln1"])
    q, k, v = attn_qkv(sp["attn"], h, cfg, positions)
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        k_cache, k.astype(k_cache.dtype), pos, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        v_cache, v.astype(v_cache.dtype), pos, axis=1)
    kv_len = jnp.full((x.shape[0],), pos + 1, jnp.int32)
    out = attention_full(q, k_cache.astype(q.dtype), v_cache.astype(q.dtype),
                         causal=False, kv_len=kv_len)
    b, s, _, _ = out.shape
    x = x + out.reshape(b, s, -1) @ sp["attn"]["wo"]
    h2 = rms_norm(x, sp["ln2"])
    x = x + gated_mlp_apply(sp["mlp"], h2, "silu")
    return x, k_cache, v_cache


def decode_step(cfg: LMConfig, params, tokens, state, positions):
    """tokens (B,1) -> (logits, new state). Scan over mamba layers with the
    shared-attention cond applied at its sites."""
    params = cast_floats(params, cfg.compute_dtype)
    x = params["embed"][tokens].astype(jnp.dtype(cfg.compute_dtype))
    shared = params["shared"]
    every = cfg.attn_every
    pos = state["pos"]

    def body(carry, inp):
        x, k_all, v_all = carry
        i, lp, mst = inp
        y, new_mst = mamba_decode_step(
            lp["mamba"], rms_norm(x, lp["ln"]), mst, cfg)
        x = x + y
        site = i // every

        def do_attn(args):
            x, k_all, v_all = args
            kc = jax.lax.dynamic_index_in_dim(k_all, site, 0, keepdims=False)
            vc = jax.lax.dynamic_index_in_dim(v_all, site, 0, keepdims=False)
            x, kc, vc = _shared_attn_decode(cfg, shared, x, kc, vc, pos,
                                            positions)
            k_all = jax.lax.dynamic_update_index_in_dim(k_all, kc, site, 0)
            v_all = jax.lax.dynamic_update_index_in_dim(v_all, vc, site, 0)
            return x, k_all, v_all

        x, k_all, v_all = jax.lax.cond(
            (i % every) == every - 1, do_attn, lambda a: a, (x, k_all, v_all)
        )
        return (x, k_all, v_all), new_mst

    idx = jnp.arange(cfg.num_layers)
    (x, k_all, v_all), new_mamba = jax.lax.scan(
        body, (x, state["k"], state["v"]),
        (idx, params["layers"], state["mamba"]),
    )
    x = rms_norm(x, params["final_norm"])
    logits = x @ params["unembed"].astype(x.dtype)
    new_state = {"mamba": new_mamba, "k": k_all, "v": v_all, "pos": pos + 1}
    return logits, new_state
