"""Unified LM architecture config covering all 10 assigned families.

One dataclass; family-specific fields are ignored by other families.
``configs/<arch>.py`` instantiates these with the exact assigned values and
provides a ``smoke()`` reduction for CPU tests.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0          # routed experts (0 => dense)
    top_k: int = 2
    num_shared: int = 0           # always-on shared experts (deepseek)
    d_ff_expert: int = 0          # ff dim per (routed/shared) expert
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    family: str                   # dense | moe | encdec | vlm | hybrid | rwkv
    num_layers: int = 12
    d_model: int = 1024
    num_heads: int = 8
    num_kv_heads: int = 8
    head_dim: int = 0             # 0 => d_model // num_heads
    d_ff: int = 4096
    vocab_size: int = 32000
    activation: str = "silu"      # silu (SwiGLU) | gelu (GeGLU)
    qk_norm: bool = False         # qwen3
    qkv_bias: bool = False        # qwen1.5
    rope_theta: float = 10000.0
    mrope_sections: tuple[int, ...] = ()  # qwen2-vl M-RoPE (t, h, w) splits
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    moe: MoEConfig = MoEConfig()
    # encoder-decoder (whisper)
    num_decoder_layers: int = 0   # >0 => enc-dec; num_layers = encoder layers
    # SSM / hybrid (zamba2, rwkv6)
    ssm_state: int = 0            # mamba2 state size per head
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    attn_every: int = 0           # hybrid: a (shared) attention block every N
    rwkv_head_dim: int = 64
    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # embedding tables padded up so the vocab dim shards on the mesh
    # (odd vocabs like whisper's 51865 otherwise force replicated logits)
    vocab_pad_multiple: int = 256

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def is_moe(self) -> bool:
        return self.moe.num_experts > 0

    @property
    def is_encdec(self) -> bool:
        return self.num_decoder_layers > 0

    def with_(self, **kw) -> "LMConfig":
        return dataclasses.replace(self, **kw)

    # ---- analytic parameter / FLOP model (for roofline §Roofline) --------
    def param_count(self) -> int:
        d, v = self.d_model, self.vocab_size
        hd = self.resolved_head_dim
        attn = d * hd * self.num_heads + 2 * d * hd * self.num_kv_heads \
            + self.num_heads * hd * d
        if self.family == "rwkv":
            # r,k,v,g,w projections + output + channel-mix
            blk = 6 * d * d + 3 * d * self.d_ff
        elif self.family == "hybrid":
            d_in = self.ssm_expand * d
            nheads = d_in // self.ssm_head_dim
            mamba = d * (2 * d_in + 2 * self.ssm_state + nheads) + d_in * d \
                + self.ssm_conv * (d_in + 2 * self.ssm_state)
            blk = mamba + 3 * d * self.d_ff
        elif self.is_moe:
            m = self.moe
            routed = m.num_experts * 3 * d * m.d_ff_expert
            shared = m.num_shared * 3 * d * m.d_ff_expert
            blk = attn + routed + shared + d * m.num_experts
        else:
            blk = attn + 3 * d * self.d_ff
        layers = self.num_layers + self.num_decoder_layers
        n = layers * blk + v * d * (1 if self.tie_embeddings else 2)
        if self.is_encdec:  # cross-attention in decoder
            n += self.num_decoder_layers * attn
        return n

    def active_param_count(self) -> int:
        """MoE: params touched per token (for MODEL_FLOPS = 6 N_active D)."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        m = self.moe
        hd = self.resolved_head_dim
        attn = d * hd * self.num_heads + 2 * d * hd * self.num_kv_heads \
            + self.num_heads * hd * d
        blk = attn + (m.top_k + m.num_shared) * 3 * d * m.d_ff_expert \
            + d * m.num_experts
        return self.num_layers * blk + self.vocab_size * d * 2
