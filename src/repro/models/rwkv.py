"""RWKV6 ("Finch") full model: attention-free LM with data-dependent decay.

Decode is O(1) in context length — the long_500k cell's decode step is
byte-identical to decode at any other length (the state is fixed-size);
this is the whole point of running the long-context shape on this family.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .config import LMConfig
from .layers import Maker, cast_floats, constrain_batch, constrain_logits, embed_lookup, rms_norm
from .ssm import rwkv_init_state, rwkv_layer_fwd, rwkv_layer_init
from .transformer import _prepend_none, _stack


def rwkv_init(cfg: LMConfig, key, mesh_sizes: dict | None = None):
    dtype = jnp.dtype(cfg.param_dtype)
    mk = Maker(key, mesh_sizes, dtype)
    d, v = cfg.d_model, cfg.padded_vocab
    if mk.abstract:
        layer = _prepend_none(rwkv_layer_init(mk, cfg))
    else:
        layer = _stack([rwkv_layer_init(mk, cfg) for _ in range(cfg.num_layers)])
    return {
        "embed": mk.make((v, d), P(mk.first_ax(v), None), scale=0.02),
        "unembed": mk.make((d, v), P(None, mk.ax("model", v) or mk.first_ax(v)), scale=d**-0.5),
        "final_norm": mk.make((d,), P(None), init="ones"),
        "layers": layer,
    }


def rwkv_specs(cfg: LMConfig, mesh_sizes: dict):
    return rwkv_init(cfg, None, mesh_sizes)


def rwkv_init_states(cfg: LMConfig, batch: int, dtype=jnp.float32):
    one = rwkv_init_state(cfg, batch, dtype)
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x, (cfg.num_layers,) + x.shape), one
    )


def state_specs(cfg: LMConfig, batch_axes):
    return {
        "wkv": P(None, batch_axes, None, None, None),
        "tm_prev": P(None, batch_axes, None, None),
        "cm_prev": P(None, batch_axes, None, None),
    }


def forward_train(cfg: LMConfig, params, tokens, positions=None, *,
                  remat: bool = True, batch_axes=None, **_unused):
    params = cast_floats(params, cfg.compute_dtype)
    x = params["embed"][tokens].astype(jnp.dtype(cfg.compute_dtype))
    x = constrain_batch(x, batch_axes)
    b = tokens.shape[0]
    state0 = rwkv_init_state(cfg, b, x.dtype)

    def body(x, lp):
        y, _ = rwkv_layer_fwd(lp, x, cfg, state0)
        return constrain_batch(y, batch_axes), None

    fn = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(fn, x, params["layers"])
    x = rms_norm(x, params["final_norm"])
    return x @ params["unembed"].astype(x.dtype)


def lm_loss(cfg: LMConfig, params, tokens, labels, positions=None, **fw):
    vocab_axis = fw.pop("vocab_axis", None)
    logits = forward_train(cfg, params, tokens, positions, **fw).astype(jnp.float32)
    logits = constrain_logits(logits, fw.get("batch_axes"), vocab_axis)
    lse = jax.nn.logsumexp(logits, axis=-1)
    # vocab-parallel CE: one-hot dot stays sharded over V (take_along_axis
    # would all-gather the full logits on vocab-sharded meshes)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    gold = jnp.sum(logits * onehot, axis=-1)
    return jnp.mean(lse - gold)


def prefill(cfg: LMConfig, params, tokens, positions=None, *,
            batch_axes=None):
    """Run the prompt, returning (last-token logits, stacked final states).
    RWKV state is O(1) in prompt length — this is just forward_train that
    keeps each layer's final recurrent state."""
    params = cast_floats(params, cfg.compute_dtype)
    x = params["embed"][tokens].astype(jnp.dtype(cfg.compute_dtype))
    x = constrain_batch(x, batch_axes)
    b = tokens.shape[0]
    state0 = rwkv_init_state(cfg, b, x.dtype)

    def body(x, lp):
        y, st = rwkv_layer_fwd(lp, x, cfg, state0)
        return constrain_batch(y, batch_axes), st

    x, states = jax.lax.scan(body, x, params["layers"])
    x = rms_norm(x[:, -1:, :], params["final_norm"])
    logits = x @ params["unembed"].astype(x.dtype)
    return logits, states


def decode_step(cfg: LMConfig, params, tokens, states, positions=None):
    """One-token decode. states: stacked (L, ...) per-layer RWKV states."""
    params = cast_floats(params, cfg.compute_dtype)
    x = params["embed"][tokens].astype(jnp.dtype(cfg.compute_dtype))

    def body(x, inp):
        lp, st = inp
        y, new_st = rwkv_layer_fwd(lp, x, cfg, st)
        return y, new_st

    x, new_states = jax.lax.scan(body, x, (params["layers"], states))
    x = rms_norm(x, params["final_norm"])
    logits = x @ params["unembed"].astype(x.dtype)
    return logits, new_states
