"""Unified per-family API used by the launcher, dry-run and tests.

``family_fns(cfg)`` returns a FamilyFns bundle: init / specs / loss /
decode plumbing with one calling convention across all five model
families. All *_inputs functions produce concrete arrays for smoke tests;
``configs.shapes.input_specs`` produces the ShapeDtypeStruct versions for
the dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import encdec, hybrid, rwkv, transformer
from .config import LMConfig


@dataclasses.dataclass(frozen=True)
class FamilyFns:
    init: Callable            # (cfg, key, mesh_sizes=None) -> params
    specs: Callable           # (cfg, mesh_sizes) -> spec tree
    loss: Callable            # (cfg, params, *inputs, **fw) -> scalar
    decode_step: Callable | None      # (cfg, params, tokens, state, [pos])
    init_decode_state: Callable | None
    decode_state_specs: Callable | None
    has_positions: bool       # loss takes positions input
    positions_3d: bool        # M-RoPE (B, S, 3)
    token_input: bool         # False => float frames input (whisper)
    supports_long_context: bool


def _transformer_fns(cfg: LMConfig) -> FamilyFns:
    def init_state(c, batch, max_len, dtype=jnp.bfloat16):
        return transformer.init_cache(c, batch, max_len, dtype)

    def state_specs(c, mesh_sizes, batch_axes, seq_axis):
        return transformer.cache_specs(
            c, mesh_sizes, batch_axes=batch_axes, seq_axis=seq_axis)

    return FamilyFns(
        init=transformer.decoder_init,
        specs=transformer.decoder_specs,
        loss=transformer.lm_loss,
        decode_step=transformer.decode_step,
        init_decode_state=init_state,
        decode_state_specs=state_specs,
        has_positions=True,
        positions_3d=bool(cfg.mrope_sections),
        token_input=True,
        supports_long_context=False,
    )


def _encdec_fns(cfg: LMConfig) -> FamilyFns:
    def loss(c, params, frames, labels, positions=None, **fw):
        return encdec.lm_loss(c, params, frames, labels, **fw)

    def decode(c, params, tokens, state, positions=None):
        return encdec.decode_step(c, params, tokens, state)

    def init_state(c, batch, max_len, dtype=jnp.bfloat16):
        # encoder output stub for cache construction (frontend is a stub)
        enc_out = jnp.zeros((batch, max_len, c.d_model), dtype)
        params_needed = None  # built by caller with params; see dryrun
        raise NotImplementedError(
            "use encdec.init_cache(cfg, params, enc_out, max_len) directly")

    def state_specs(c, mesh_sizes, batch_axes, seq_axis):
        return encdec.cache_specs(
            c, mesh_sizes, batch_axes=batch_axes, seq_axis=seq_axis)

    return FamilyFns(
        init=encdec.whisper_init,
        specs=encdec.whisper_specs,
        loss=loss,
        decode_step=decode,
        init_decode_state=init_state,
        decode_state_specs=state_specs,
        has_positions=False,
        positions_3d=False,
        token_input=False,
        supports_long_context=False,
    )


def _hybrid_fns(cfg: LMConfig) -> FamilyFns:
    def init_state(c, batch, max_len, dtype=jnp.bfloat16):
        return hybrid.init_state(c, batch, max_len, dtype)

    def state_specs(c, mesh_sizes, batch_axes, seq_axis):
        return hybrid.state_specs(
            c, mesh_sizes, batch_axes=batch_axes, seq_axis=seq_axis)

    return FamilyFns(
        init=hybrid.zamba_init,
        specs=hybrid.zamba_specs,
        loss=hybrid.lm_loss,
        decode_step=hybrid.decode_step,
        init_decode_state=init_state,
        decode_state_specs=state_specs,
        has_positions=True,
        positions_3d=False,
        token_input=True,
        supports_long_context=True,
    )


def _rwkv_fns(cfg: LMConfig) -> FamilyFns:
    def loss(c, params, tokens, labels, positions=None, **fw):
        return rwkv.lm_loss(c, params, tokens, labels, **fw)

    def decode(c, params, tokens, state, positions=None):
        return rwkv.decode_step(c, params, tokens, state)

    def init_state(c, batch, max_len, dtype=jnp.bfloat16):
        del max_len  # O(1) state — independent of context length
        return rwkv.rwkv_init_states(c, batch, dtype)

    def state_specs(c, mesh_sizes, batch_axes, seq_axis):
        del seq_axis
        return rwkv.state_specs(c, batch_axes=batch_axes)

    return FamilyFns(
        init=rwkv.rwkv_init,
        specs=rwkv.rwkv_specs,
        loss=loss,
        decode_step=decode,
        init_decode_state=init_state,
        decode_state_specs=state_specs,
        has_positions=False,
        positions_3d=False,
        token_input=True,
        supports_long_context=True,
    )


def family_fns(cfg: LMConfig) -> FamilyFns:
    if cfg.family in ("dense", "moe", "vlm"):
        return _transformer_fns(cfg)
    if cfg.family == "encdec":
        return _encdec_fns(cfg)
    if cfg.family == "hybrid":
        return _hybrid_fns(cfg)
    if cfg.family == "rwkv":
        return _rwkv_fns(cfg)
    raise ValueError(f"unknown family {cfg.family!r}")
