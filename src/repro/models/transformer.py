"""Decoder-only transformer LM (dense + MoE): train / prefill / decode.

Covers llama3, gemma (GeGLU), qwen3 (qk_norm), qwen1.5 (qkv bias),
phi3.5-moe, deepseek-moe, qwen2-vl (M-RoPE via (B,S,3) positions).

Layer stacking: parameters carry a leading L axis; the forward runs
``lax.scan`` over layers with jax.checkpoint (remat) by default. NOTE for
roofline readers: XLA cost_analysis counts a scan body ONCE — the
benchmark/roofline code multiplies by the trip count (benchmarks/roofline
"analytic" column) or lowers with unroll=True where compile cost permits.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .config import LMConfig
from .layers import (
    Maker,
    cast_floats,
    constrain_batch,
    constrain_logits,
    embed_lookup,
    attention_chunked,
    attention_full,
    attn_init,
    attn_qkv,
    gated_mlp_apply,
    gated_mlp_init,
    rms_norm,
)
from .moe import moe_apply, moe_init


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _layer_init(mk: Maker, cfg: LMConfig):
    d = cfg.d_model
    p = {
        "ln1": mk.make((d,), P(None), init="ones"),
        "ln2": mk.make((d,), P(None), init="ones"),
        "attn": attn_init(
            mk, d, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim,
            qkv_bias=cfg.qkv_bias, qk_norm=cfg.qk_norm,
        ),
    }
    if cfg.is_moe:
        p["moe"] = moe_init(mk, cfg)
    else:
        p["mlp"] = gated_mlp_init(mk, d, cfg.d_ff)
    return p


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def _prepend_none(spec_tree):
    return jax.tree.map(
        lambda s: P(None, *s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def decoder_init(cfg: LMConfig, key, mesh_sizes: dict | None = None):
    """key=None -> PartitionSpec tree (same structure as params)."""
    dtype = jnp.dtype(cfg.param_dtype)
    mk = Maker(key, mesh_sizes, dtype)
    d, v = cfg.d_model, cfg.padded_vocab
    if mk.abstract:
        layer = _prepend_none(_layer_init(mk, cfg))
    else:
        layers = []
        for _ in range(cfg.num_layers):
            layers.append(_layer_init(mk, cfg))
        layer = _stack(layers)
    # V shards over 'model' ONLY where it feeds the logits matmul: a
    # ('data','model') V-sharding conflicts with batch-over-'data' logits
    # and XLA replicates the whole CE chain (gemma: +8 GiB/dev, §Perf
    # vocab-2). The input-side gather table keeps 2D sharding (untied).
    logit_vax = mk.ax("model", v) or mk.first_ax(v)
    embed_spec = (P(logit_vax, None) if cfg.tie_embeddings
                  else P(mk.first_ax(v), None))
    params = {
        "embed": mk.make((v, d), embed_spec, scale=0.02),
        "final_norm": mk.make((d,), P(None), init="ones"),
        "layers": layer,
    }
    if not cfg.tie_embeddings:
        params["unembed"] = mk.make(
            (d, v), P(None, logit_vax), scale=d**-0.5
        )
    return params


def decoder_specs(cfg: LMConfig, mesh_sizes: dict):
    return decoder_init(cfg, None, mesh_sizes)


# ---------------------------------------------------------------------------
# layer forward
# ---------------------------------------------------------------------------

def _layer_fwd(cfg: LMConfig, p, x, positions, *, attn_mode: str,
               chunk: int, cache=None, use_pallas: bool = False,
               moe_axes=None):
    """cache: None (train/prefill-no-cache) or dict(k, v, pos) for decode.

    Returns (x, new_kv) where new_kv is (k, v) in prefill mode, the
    updated cache tensors in decode mode, or None.
    """
    h = rms_norm(x, p["ln1"])
    q, k, v = attn_qkv(p["attn"], h, cfg, positions)
    new_kv = None
    if cache is not None and attn_mode == "decode":
        # insert this step's k/v at position cache["pos"]
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), cache["pos"], axis=1
        )
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), cache["pos"], axis=1
        )
        kv_len = jnp.full((x.shape[0],), cache["pos"] + 1, jnp.int32)
        out = attention_full(
            q, k_cache.astype(q.dtype), v_cache.astype(q.dtype),
            causal=False, kv_len=kv_len,
        )
        new_kv = (k_cache, v_cache)
    elif attn_mode == "chunked":
        out = attention_chunked(q, k, v, causal=True, chunk=chunk)
        new_kv = (k, v)
    else:
        out = attention_full(q, k, v, causal=True)
        new_kv = (k, v)
    b, s, _, _ = out.shape
    x = x + out.reshape(b, s, -1) @ p["attn"]["wo"]

    h2 = rms_norm(x, p["ln2"])
    if cfg.is_moe:
        x = x + moe_apply(p["moe"], h2, cfg, use_pallas=use_pallas,
                          moe_axes=moe_axes)
    else:
        x = x + gated_mlp_apply(p["mlp"], h2, cfg.activation, use_pallas)
    return x, new_kv


# ---------------------------------------------------------------------------
# public forwards
# ---------------------------------------------------------------------------

def _embed(cfg, params, tokens):
    x = params["embed"][tokens]
    return x.astype(jnp.dtype(cfg.compute_dtype))


def _unembed(cfg, params, x):
    x = rms_norm(x, params["final_norm"])
    table = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    return x @ table.astype(x.dtype)


def forward_train(cfg: LMConfig, params, tokens, positions, *,
                  attn_mode: str = "full", chunk: int = 1024,
                  remat: bool = True, unroll: bool = False,
                  use_pallas: bool = False, batch_axes=None,
                  layer_block: int | None = None, moe_axes=None):
    """tokens (B, S) -> logits (B, S, V).

    layer_block: nested-scan remat — group layers into blocks of this size
    and checkpoint per BLOCK (sqrt-style memory policy: saved carries go
    from L to L/block + block at one extra recompute). Used for the
    80-layer 110B train cell.
    """
    params = cast_floats(params, cfg.compute_dtype)
    x = constrain_batch(_embed(cfg, params, tokens), batch_axes)

    def body(x, lp):
        y, _ = _layer_fwd(cfg, lp, x, positions, attn_mode=attn_mode,
                          chunk=chunk, use_pallas=use_pallas,
                          moe_axes=moe_axes)
        return constrain_batch(y, batch_axes), None

    if unroll:
        for i in range(cfg.num_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            x, _ = body(x, lp)
    elif layer_block and cfg.num_layers % layer_block == 0:
        grouped = jax.tree.map(
            lambda a: a.reshape(
                (cfg.num_layers // layer_block, layer_block) + a.shape[1:]),
            params["layers"])

        @jax.checkpoint
        def block_fn(x, gp):
            # inner layers ALSO checkpointed: during block recompute the
            # backward holds one layer's internals, not all `layer_block`
            y, _ = jax.lax.scan(jax.checkpoint(body), x, gp)
            return y, None

        x, _ = jax.lax.scan(block_fn, x, grouped)
    else:
        fn = jax.checkpoint(body) if remat else body
        x, _ = jax.lax.scan(fn, x, params["layers"])
    return _unembed(cfg, params, x)


def lm_loss(cfg: LMConfig, params, tokens, labels, positions, **fw_kw):
    """Next-token cross-entropy (labels = tokens shifted by caller)."""
    vocab_axis = fw_kw.pop("vocab_axis", None)
    logits = forward_train(cfg, params, tokens, positions, **fw_kw)
    logits = constrain_logits(logits.astype(jnp.float32),
                              fw_kw.get("batch_axes"), vocab_axis)
    lse = jax.nn.logsumexp(logits, axis=-1)
    # vocab-parallel CE: one-hot dot stays sharded over V (take_along_axis
    # would all-gather the full logits on vocab-sharded meshes)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    gold = jnp.sum(logits * onehot, axis=-1)
    return jnp.mean(lse - gold)


def init_cache(cfg: LMConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    shape = (cfg.num_layers, batch, max_len, hkv, hd)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def cache_specs(cfg: LMConfig, mesh_sizes: dict, *, batch_axes,
                seq_axis: str | None):
    """PartitionSpecs for the KV cache: batch over DP axes; seq over
    ``seq_axis`` (sequence-parallel KV) when kv-heads can't shard."""
    mk = Maker(None, mesh_sizes)
    head_ax = mk.head_ax(cfg.num_kv_heads)
    seq = seq_axis if head_ax is None else None
    kv_spec = P(None, batch_axes, seq, head_ax, None)
    return {"k": kv_spec, "v": kv_spec, "pos": P()}


def decode_step(cfg: LMConfig, params, tokens, cache, positions, *,
                use_pallas: bool = False):
    """One-token decode. tokens (B, 1) -> (logits (B, 1, V), new cache).

    Merge-softmax decode (§Perf decode-1): the layer scan reads the stale
    cache and returns only the new token's (B,1,Hkv,D) KV per layer; the
    full cache is then updated ONCE with a donation-aliased
    dynamic-update-slice, instead of materializing a second full cache as
    the scan's stacked ys.
    """
    from .layers import attention_decode_merge

    params = cast_floats(params, cfg.compute_dtype)
    x = _embed(cfg, params, tokens)
    pos = cache["pos"]

    def body(x, inputs):
        lp, k_cache, v_cache = inputs
        h = rms_norm(x, lp["ln1"])
        q, k_new, v_new = attn_qkv(lp["attn"], h, cfg, positions)
        out = attention_decode_merge(
            q, k_cache.astype(q.dtype), v_cache.astype(q.dtype),
            k_new.astype(q.dtype), v_new.astype(q.dtype), pos)
        b, s, _, _ = out.shape
        x = x + out.reshape(b, s, -1) @ lp["attn"]["wo"]
        h2 = rms_norm(x, lp["ln2"])
        if cfg.is_moe:
            x = x + moe_apply(lp["moe"], h2, cfg, use_pallas=use_pallas)
        else:
            x = x + gated_mlp_apply(lp["mlp"], h2, cfg.activation,
                                    use_pallas)
        return x, (k_new.astype(cache["k"].dtype),
                   v_new.astype(cache["v"].dtype))

    x, (k_news, v_news) = jax.lax.scan(
        body, x, (params["layers"], cache["k"], cache["v"])
    )
    logits = _unembed(cfg, params, x)
    # one aliased update of the whole stacked cache at [:, :, pos, :, :]
    z = jnp.zeros((), jnp.int32)
    k_all = jax.lax.dynamic_update_slice(
        cache["k"], k_news, (z, z, pos, z, z))
    v_all = jax.lax.dynamic_update_slice(
        cache["v"], v_news, (z, z, pos, z, z))
    new_cache = {"k": k_all, "v": v_all, "pos": pos + 1}
    return logits, new_cache


def prefill(cfg: LMConfig, params, tokens, positions, max_len: int, *,
            chunk: int = 1024, use_pallas: bool = False,
            cache_dtype=jnp.bfloat16, batch_axes=None, moe_axes=None):
    """Prefill: forward over the prompt, build the KV cache."""
    params = cast_floats(params, cfg.compute_dtype)
    x = constrain_batch(_embed(cfg, params, tokens), batch_axes)
    b, s = tokens.shape

    def body(x, lp):
        y, (k, v) = _layer_fwd(cfg, lp, x, positions,
                               attn_mode="chunked", chunk=chunk,
                               use_pallas=use_pallas, moe_axes=moe_axes)
        return (constrain_batch(y, batch_axes),
                (k.astype(cache_dtype), v.astype(cache_dtype)))

    x, (ks, vs) = jax.lax.scan(body, x, params["layers"])
    pad = max_len - s
    if pad > 0:
        ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    logits = _unembed(cfg, params, x[:, -1:, :])
    return logits, {"k": ks, "v": vs, "pos": jnp.asarray(s, jnp.int32)}
