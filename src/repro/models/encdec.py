"""Whisper-style encoder-decoder backbone.

The conv/mel frontend is a STUB per the assignment: ``input_specs()``
provides precomputed frame embeddings (B, S_enc, d_model); the encoder is
the transformer stack on top of them (sinusoidal positions added here).
Decoder: causal self-attention + cross-attention + plain-GELU MLP
(whisper uses ungated MLPs, unlike the llama family).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .config import LMConfig
from .layers import (
    Maker, attention_chunked, attention_full, attn_init, attn_qkv,
    cast_floats, constrain_batch, constrain_logits, embed_lookup,
    plain_mlp_apply, plain_mlp_init, rms_norm,
)
from .transformer import _prepend_none, _stack


def sinusoid_pos(s: int, d: int, dtype=jnp.float32):
    pos = jnp.arange(s, dtype=jnp.float32)[:, None]
    div = jnp.exp(-jnp.arange(0, d, 2, dtype=jnp.float32) / d * jnp.log(10000.0))
    ang = pos * div
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1).astype(dtype)


def _enc_layer_init(mk: Maker, cfg: LMConfig):
    d = cfg.d_model
    return {
        "ln1": mk.make((d,), P(None), init="ones"),
        "attn": attn_init(mk, d, cfg.num_heads, cfg.num_kv_heads,
                          cfg.resolved_head_dim),
        "ln2": mk.make((d,), P(None), init="ones"),
        "mlp": plain_mlp_init(mk, d, cfg.d_ff),
    }


def _dec_layer_init(mk: Maker, cfg: LMConfig):
    p = _enc_layer_init(mk, cfg)
    p["ln_x"] = mk.make((cfg.d_model,), P(None), init="ones")
    p["cross"] = attn_init(mk, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                           cfg.resolved_head_dim)
    return p


def whisper_init(cfg: LMConfig, key, mesh_sizes: dict | None = None):
    dtype = jnp.dtype(cfg.param_dtype)
    mk = Maker(key, mesh_sizes, dtype)
    d, v = cfg.d_model, cfg.padded_vocab
    if mk.abstract:
        enc = _prepend_none(_enc_layer_init(mk, cfg))
        dec = _prepend_none(_dec_layer_init(mk, cfg))
    else:
        enc = _stack([_enc_layer_init(mk, cfg) for _ in range(cfg.num_layers)])
        dec = _stack([_dec_layer_init(mk, cfg)
                      for _ in range(cfg.num_decoder_layers)])
    return {
        "embed": mk.make((v, d), P(mk.first_ax(v), None), scale=0.02),
        "unembed": mk.make((d, v), P(None, mk.ax("model", v) or mk.first_ax(v)), scale=d**-0.5),
        "enc_final": mk.make((d,), P(None), init="ones"),
        "dec_final": mk.make((d,), P(None), init="ones"),
        "encoder": enc,
        "decoder": dec,
    }


def whisper_specs(cfg: LMConfig, mesh_sizes: dict):
    return whisper_init(cfg, None, mesh_sizes)


def encode(cfg: LMConfig, params, frames, *, attn_mode="full",
           chunk: int = 1024, remat: bool = True, batch_axes=None):
    """frames: (B, S_enc, d) precomputed embeddings (frontend stub)."""
    params = cast_floats(params, cfg.compute_dtype)
    x = frames.astype(jnp.dtype(cfg.compute_dtype))
    x = constrain_batch(x + sinusoid_pos(x.shape[1], x.shape[2], x.dtype),
                        batch_axes)

    def body(x, lp):
        h = rms_norm(x, lp["ln1"])
        q, k, v = attn_qkv(lp["attn"], h, cfg, None)
        if attn_mode == "chunked":
            out = attention_chunked(q, k, v, causal=False, chunk=chunk)
        else:
            out = attention_full(q, k, v, causal=False)
        b, s, _, _ = out.shape
        x = x + out.reshape(b, s, -1) @ lp["attn"]["wo"]
        x = x + plain_mlp_apply(lp["mlp"], rms_norm(x, lp["ln2"]))
        return constrain_batch(x, batch_axes), None

    fn = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(fn, x, params["encoder"])
    return rms_norm(x, params["enc_final"])


def _dec_layer(cfg, lp, x, enc_out, *, attn_mode, chunk, cache=None,
               pos=None):
    h = rms_norm(x, lp["ln1"])
    q, k, v = attn_qkv(lp["attn"], h, cfg, None)
    new_kv = None
    if cache is not None:  # decode: update self cache
        k_c = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), pos, 1)
        v_c = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), pos, 1)
        kv_len = jnp.full((x.shape[0],), pos + 1, jnp.int32)
        out = attention_full(q, k_c.astype(q.dtype), v_c.astype(q.dtype),
                             causal=False, kv_len=kv_len)
        new_kv = (k_c, v_c)
    elif attn_mode == "chunked":
        out = attention_chunked(q, k, v, causal=True, chunk=chunk)
    else:
        out = attention_full(q, k, v, causal=True)
    b, s, _, _ = out.shape
    x = x + out.reshape(b, s, -1) @ lp["attn"]["wo"]

    # cross attention (enc_out may be precomputed K/V in decode)
    hx = rms_norm(x, lp["ln_x"])
    qx = (hx @ lp["cross"]["wq"]).reshape(
        b, s, cfg.num_heads, cfg.resolved_head_dim)
    if cache is not None:
        outx = attention_full(qx, cache["xk"].astype(q.dtype),
                              cache["xv"].astype(q.dtype), causal=False)
    else:
        kx = (enc_out @ lp["cross"]["wk"]).reshape(
            b, enc_out.shape[1], cfg.num_kv_heads, cfg.resolved_head_dim)
        vx = (enc_out @ lp["cross"]["wv"]).reshape(
            b, enc_out.shape[1], cfg.num_kv_heads, cfg.resolved_head_dim)
        outx = attention_full(qx, kx, vx, causal=False)
    x = x + outx.reshape(b, s, -1) @ lp["cross"]["wo"]
    x = x + plain_mlp_apply(lp["mlp"], rms_norm(x, lp["ln2"]))
    return x, new_kv


def forward_train(cfg: LMConfig, params, frames, dec_tokens, *,
                  attn_mode="full", chunk: int = 1024, remat: bool = True,
                  batch_axes=None, **_unused):
    enc_out = encode(cfg, params, frames, attn_mode=attn_mode, chunk=chunk,
                     remat=remat, batch_axes=batch_axes)
    params = cast_floats(params, cfg.compute_dtype)
    x = params["embed"][dec_tokens].astype(jnp.dtype(cfg.compute_dtype))
    x = constrain_batch(x + sinusoid_pos(x.shape[1], x.shape[2], x.dtype),
                        batch_axes)

    def body(x, lp):
        y, _ = _dec_layer(cfg, lp, x, enc_out, attn_mode=attn_mode,
                          chunk=chunk)
        return constrain_batch(y, batch_axes), None

    fn = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(fn, x, params["decoder"])
    x = rms_norm(x, params["dec_final"])
    return x @ params["unembed"].astype(x.dtype)


def lm_loss(cfg: LMConfig, params, frames, labels, **fw):
    """Teacher-forced CE: decoder input = labels shifted right."""
    dec_in = jnp.pad(labels[:, :-1], ((0, 0), (1, 0)))
    vocab_axis = fw.pop("vocab_axis", None)
    logits = forward_train(cfg, params, frames, dec_in, **fw).astype(jnp.float32)
    logits = constrain_logits(logits, fw.get("batch_axes"), vocab_axis)
    lse = jax.nn.logsumexp(logits, axis=-1)
    # vocab-parallel CE: one-hot dot stays sharded over V (take_along_axis
    # would all-gather the full logits on vocab-sharded meshes)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    gold = jnp.sum(logits * onehot, axis=-1)
    return jnp.mean(lse - gold)


def init_cache(cfg: LMConfig, params, enc_out, max_len: int,
               dtype=jnp.bfloat16):
    """Self-attention cache + precomputed cross K/V from the encoder."""
    params = cast_floats(params, cfg.compute_dtype)
    b = enc_out.shape[0]
    hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    ld = cfg.num_decoder_layers

    def cross_kv(lp):
        kx = (enc_out @ lp["cross"]["wk"]).reshape(
            b, enc_out.shape[1], hkv, hd)
        vx = (enc_out @ lp["cross"]["wv"]).reshape(
            b, enc_out.shape[1], hkv, hd)
        return kx.astype(dtype), vx.astype(dtype)

    xk, xv = jax.vmap(cross_kv)(params["decoder"])  # leading L axis? no --
    # params["decoder"] leaves have leading L: vmap maps over it.
    return {
        "k": jnp.zeros((ld, b, max_len, hkv, hd), dtype),
        "v": jnp.zeros((ld, b, max_len, hkv, hd), dtype),
        "xk": xk, "xv": xv,
        "pos": jnp.zeros((), jnp.int32),
    }


def decode_step(cfg: LMConfig, params, tokens, cache):
    params = cast_floats(params, cfg.compute_dtype)
    x = params["embed"][tokens].astype(jnp.dtype(cfg.compute_dtype))
    s_abs = cache["pos"]
    d = cfg.d_model
    # gather the s_abs-th sinusoid row for the current decode position
    full = sinusoid_pos(cache["k"].shape[2], d, x.dtype)
    x = x + jax.lax.dynamic_slice_in_dim(full, s_abs, 1, axis=0)[None]

    def body(x, inp):
        lp, kc, vc, xk, xv = inp
        y, (k_new, v_new) = _dec_layer(
            cfg, lp, x, None, attn_mode="full", chunk=0,
            cache={"k": kc, "v": vc, "xk": xk, "xv": xv}, pos=s_abs,
        )
        return y, (k_new, v_new)

    x, (k_all, v_all) = jax.lax.scan(
        body, x,
        (params["decoder"], cache["k"], cache["v"], cache["xk"], cache["xv"]),
    )
    x = rms_norm(x, params["dec_final"])
    logits = x @ params["unembed"].astype(x.dtype)
    new_cache = dict(cache, k=k_all, v=v_all, pos=s_abs + 1)
    return logits, new_cache


def cache_specs(cfg: LMConfig, mesh_sizes: dict, *, batch_axes,
                seq_axis: str | None):
    mk = Maker(None, mesh_sizes)
    head_ax = mk.head_ax(cfg.num_kv_heads)
    seq = seq_axis if head_ax is None else None
    kv = P(None, batch_axes, seq, head_ax, None)
    return {"k": kv, "v": kv, "xk": kv, "xv": kv, "pos": P()}
