"""State-space models: Mamba2 (chunked SSD) and RWKV6 (Finch).

Mamba2 uses the SSD chunked formulation (matmul-heavy -> MXU-friendly, the
TPU-native adaptation): within a chunk the recurrence is evaluated as a
masked (C B^T) quadratic form; across chunks a lax.scan carries the
(heads, head_dim, state) SSM state. Decode is the O(1) single-step
recurrence with a rolling conv cache.

RWKV6 implements the data-dependent-decay WKV recurrence with a lax.scan
over time (exact), plus O(1) decode. Sharding note (DESIGN.md §4): 40 wkv
heads don't divide a 16-way model axis, so the recurrence shards over
batch ('data'); channel-mix and projections shard over 'model'.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .layers import Maker, rms_norm


# ===========================================================================
# Mamba2
# ===========================================================================

def mamba_init(mk: Maker, cfg):
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    st = cfg.ssm_state
    nh = d_in // cfg.ssm_head_dim
    kk = cfg.ssm_conv
    conv_ch = d_in + 2 * st
    return {
        "wz": mk.make((d, d_in), P(mk.ax("data", d), mk.ax("model", d_in))),
        "wx": mk.make((d, d_in), P(mk.ax("data", d), mk.ax("model", d_in))),
        "wB": mk.make((d, st), P(mk.ax("data", d), None)),
        "wC": mk.make((d, st), P(mk.ax("data", d), None)),
        "wdt": mk.make((d, nh), P(mk.ax("data", d), mk.ax("model", nh))),
        "conv_w": mk.make((kk, conv_ch), P(None, None), scale=0.5),
        "conv_b": mk.make((conv_ch,), P(None), init="zeros"),
        "A_log": mk.make((nh,), P(mk.ax("model", nh)), init="zeros"),
        "D": mk.make((nh,), P(mk.ax("model", nh)), init="ones"),
        "dt_bias": mk.make((nh,), P(mk.ax("model", nh)), init="zeros"),
        "norm": mk.make((d_in,), P(mk.ax("model", d_in)), init="ones"),
        "wo": mk.make((d_in, d), P(mk.ax("model", d_in), mk.ax("data", d))),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv over time. x: (B, S, C); w: (K, C)."""
    k = w.shape[0]
    out = b
    for i in range(k):
        shift = k - 1 - i
        xi = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, : x.shape[1], :]
        out = out + xi * w[i]
    return out


def mamba_fwd(p, x, cfg, *, chunk: int = 128, return_state: bool = False):
    """x: (B, S, d) -> (B, S, d). Chunked SSD.

    return_state=True additionally returns the final
    {ssm (B,nh,hd,st) f32, conv (B,K-1,C)} state (for prefill)."""
    b, s, d = x.shape
    d_in = cfg.ssm_expand * d
    st = cfg.ssm_state
    hd = cfg.ssm_head_dim
    nh = d_in // hd

    z = x @ p["wz"]
    xin = x @ p["wx"]
    bb = x @ p["wB"]
    cc = x @ p["wC"]
    dt = jax.nn.softplus(x @ p["wdt"] + p["dt_bias"])      # (B,S,nh)

    conv_in = jnp.concatenate([xin, bb, cc], axis=-1)
    conv_tail = conv_in[:, -(cfg.ssm_conv - 1):, :]  # rolling cache (prefill)
    conv_out = jax.nn.silu(_causal_conv(conv_in, p["conv_w"], p["conv_b"]))
    xin = conv_out[..., :d_in]
    bb = conv_out[..., d_in:d_in + st]
    cc = conv_out[..., d_in + st:]

    a = -jnp.exp(p["A_log"].astype(jnp.float32))            # (nh,) negative
    la = (dt.astype(jnp.float32) * a)                       # (B,S,nh) log-decay
    xh = xin.reshape(b, s, nh, hd) * dt[..., None].astype(xin.dtype)

    nc = s // chunk
    assert s % chunk == 0, (s, chunk)
    lac = la.reshape(b, nc, chunk, nh)
    cums = jnp.cumsum(lac, axis=2)                          # (B,nc,c,nh)
    xc = xh.reshape(b, nc, chunk, nh, hd)
    bc = bb.reshape(b, nc, chunk, st)
    ccc = cc.reshape(b, nc, chunk, st)

    # intra-chunk: y[i] = sum_{j<=i} exp(cums_i - cums_j) (C_i.B_j) xbar_j
    cb = jnp.einsum("bnis,bnjs->bnij", ccc, bc)             # (B,nc,c,c)
    li = cums[:, :, :, None, :] - cums[:, :, None, :, :]    # (B,nc,c,c,nh)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    lmat = jnp.where(mask[None, None, :, :, None], jnp.exp(li), 0.0)
    y_intra = jnp.einsum(
        "bnij,bnijh,bnjhp->bnihp", cb.astype(jnp.float32), lmat,
        xc.astype(jnp.float32),
    )

    # inter-chunk: scan carrying state (B,nh,hd,st)
    decay_out = jnp.exp(cums)                               # (B,nc,c,nh)
    decay_tot = jnp.exp(cums[:, :, -1, :])                  # (B,nc,nh)
    decay_in = jnp.exp(cums[:, :, -1:, :] - cums)           # (B,nc,c,nh)
    chunk_state = jnp.einsum(
        "bcjh,bcjhp,bcjs->bchps", decay_in, xc.astype(jnp.float32),
        bc.astype(jnp.float32),
    )                                                        # (B,nc,nh,hd,st)

    def body(state, inp):
        c_state, d_tot, c_c, d_out = inp
        # y_inter[i] = exp(cums_i) * C_i . state
        y_int = jnp.einsum("bis,bhps,bih->bihp", c_c, state, d_out)
        state = state * d_tot[..., None, None] + c_state
        return state, y_int

    state0 = jnp.zeros((b, nh, hd, st), jnp.float32)
    state_fin, y_inter = jax.lax.scan(
        body, state0,
        (chunk_state.transpose(1, 0, 2, 3, 4),
         decay_tot.transpose(1, 0, 2),
         ccc.astype(jnp.float32).transpose(1, 0, 2, 3),
         decay_out.transpose(1, 0, 2, 3)),
    )                                                        # (nc,B,c,nh,hd)
    y = y_intra + y_inter.transpose(1, 0, 2, 3, 4)
    y = y.reshape(b, s, nh, hd).astype(x.dtype)
    # D skip uses the raw (conv'd) x, not the dt-scaled xbar
    y = y + xin.reshape(b, s, nh, hd) * p["D"][None, None, :, None].astype(x.dtype)
    y = y.reshape(b, s, d_in)
    y = rms_norm(y * jax.nn.silu(z), p["norm"])
    out = y @ p["wo"]
    if return_state:
        return out, {"ssm": state_fin, "conv": conv_tail}
    return out


def mamba_init_state(cfg, batch: int, dtype=jnp.float32):
    d_in = cfg.ssm_expand * cfg.d_model
    st = cfg.ssm_state
    nh = d_in // cfg.ssm_head_dim
    conv_ch = d_in + 2 * st
    return {
        "ssm": jnp.zeros((batch, nh, cfg.ssm_head_dim, st), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), dtype),
    }


def mamba_decode_step(p, x, state, cfg):
    """x: (B, 1, d) -> (y (B,1,d), new state). O(1) in context length."""
    b, _, d = x.shape
    d_in = cfg.ssm_expand * d
    st = cfg.ssm_state
    hd = cfg.ssm_head_dim
    nh = d_in // hd

    z = x @ p["wz"]
    xin = x @ p["wx"]
    bb = x @ p["wB"]
    cc = x @ p["wC"]
    dt = jax.nn.softplus(x @ p["wdt"] + p["dt_bias"])       # (B,1,nh)

    conv_in = jnp.concatenate([xin, bb, cc], axis=-1)        # (B,1,C)
    window = jnp.concatenate([state["conv"], conv_in], axis=1)  # (B,K,C)
    conv_out = jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"]
    conv_out = jax.nn.silu(conv_out)[:, None, :]
    new_conv = window[:, 1:, :]
    xin = conv_out[..., :d_in]
    bb = conv_out[..., d_in:d_in + st]
    cc = conv_out[..., d_in + st:]

    a = -jnp.exp(p["A_log"].astype(jnp.float32))
    decay = jnp.exp(dt[:, 0].astype(jnp.float32) * a)        # (B,nh)
    xh = (xin.reshape(b, nh, hd) * dt[:, 0, :, None]).astype(jnp.float32)
    kv = jnp.einsum("bhp,bs->bhps", xh, bb[:, 0].astype(jnp.float32))
    ssm = state["ssm"] * decay[..., None, None] + kv
    y = jnp.einsum("bhps,bs->bhp", ssm, cc[:, 0].astype(jnp.float32))
    y = y.astype(x.dtype) + xin.reshape(b, nh, hd) * p["D"][None, :, None]
    y = y.reshape(b, 1, d_in)
    y = rms_norm(y * jax.nn.silu(z), p["norm"])
    return y @ p["wo"], {"ssm": ssm, "conv": new_conv}


# ===========================================================================
# RWKV6 (Finch)
# ===========================================================================

def rwkv_layer_init(mk: Maker, cfg):
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    nh = d // hd
    lora = 64
    return {
        "ln1": mk.make((d,), P(None), init="ones"),
        "ln2": mk.make((d,), P(None), init="ones"),
        # time-mix
        "mu": mk.make((5, d), P(None, None), scale=0.1),     # r,k,v,g,w shifts
        "wr": mk.make((d, d), P(mk.ax("data", d), None)),
        "wk": mk.make((d, d), P(mk.ax("data", d), None)),
        "wv": mk.make((d, d), P(mk.ax("data", d), None)),
        "wgate": mk.make((d, d), P(mk.ax("data", d), None)),
        "wo": mk.make((d, d), P(None, mk.ax("data", d))),
        "w0": mk.make((d,), P(None), init="zeros"),
        "w_lora_a": mk.make((d, lora), P(mk.ax("data", d), None)),
        "w_lora_b": mk.make((lora, d), P(None, None), scale=0.01),
        "u": mk.make((nh, hd), P(None, None), scale=0.1),    # bonus
        "gn": mk.make((d,), P(None), init="ones"),           # per-head norm
        # channel-mix
        "mu_ck": mk.make((d,), P(None), scale=0.1),
        "mu_cr": mk.make((d,), P(None), scale=0.1),
        "wck": mk.make((d, cfg.d_ff), P(mk.ax("data", d), mk.ax("model", cfg.d_ff))),
        "wcv": mk.make((cfg.d_ff, d), P(mk.ax("model", cfg.d_ff), mk.ax("data", d))),
        "wcr": mk.make((d, d), P(mk.ax("data", d), None)),
    }


def _token_shift(x, x_prev):
    """shift right by one; x_prev is the last token of the previous call
    (zeros at sequence start). x: (B,S,d), x_prev: (B,1,d)."""
    return jnp.concatenate([x_prev, x[:, :-1, :]], axis=1)


def _rwkv_decay(p, xw):
    w_raw = p["w0"] + jnp.tanh(xw @ p["w_lora_a"]) @ p["w_lora_b"]
    # data-dependent decay in (0, 1): w = exp(-exp(w_raw)), clamped
    return jnp.exp(-jnp.exp(jnp.clip(w_raw.astype(jnp.float32), -8.0, 4.0)))


def rwkv_time_mix(p, x, cfg, state, x_prev, *, time_chunk: int = 256):
    """WKV6: two-level time scan. x: (B,S,d); state: (B,H,K,V) f32.

    The recurrence is scanned over time in CHECKPOINTED chunks: the outer
    scan saves only the per-chunk state carry; per-step residuals inside a
    chunk are rematerialized during backward. Without this the backward
    pass keeps every step's (B,H,K,V) state alive (measured 270 GiB/dev on
    the train_4k cell — EXPERIMENTS.md §Perf iteration rwkv-1).
    """
    b, s, d = x.shape
    hd = cfg.rwkv_head_dim
    nh = d // hd
    xs = _token_shift(x, x_prev)
    mix = [x + (xs - x) * p["mu"][i] for i in range(5)]
    xr, xk, xv, xg, xw = mix
    r = (xr @ p["wr"]).reshape(b, s, nh, hd)
    k = (xk @ p["wk"]).reshape(b, s, nh, hd)
    v = (xv @ p["wv"]).reshape(b, s, nh, hd)
    g = jax.nn.silu(xg @ p["wgate"])
    w = _rwkv_decay(p, xw).reshape(b, s, nh, hd)            # (B,S,H,K)

    def step(st, inp):
        rt, kt, vt, wt = inp                                # (B,H,K/V)
        kv = kt[..., :, None] * vt[..., None, :]            # (B,H,K,V)
        y = jnp.einsum("bhk,bhkv->bhv", rt, st + p["u"][..., None] * kv)
        st = wt[..., None] * st + kv
        return st, y

    seq = (
        r.transpose(1, 0, 2, 3).astype(jnp.float32),
        k.transpose(1, 0, 2, 3).astype(jnp.float32),
        v.transpose(1, 0, 2, 3).astype(jnp.float32),
        w.transpose(1, 0, 2, 3),
    )
    if s % time_chunk == 0 and s > time_chunk:
        nc = s // time_chunk
        seq_c = jax.tree.map(
            lambda a: a.reshape((nc, time_chunk) + a.shape[1:]), seq)

        @jax.checkpoint
        def chunk_step(st, chunk_inp):
            return jax.lax.scan(step, st, chunk_inp)

        state, ys = jax.lax.scan(chunk_step, state, seq_c)
        ys = ys.reshape((s,) + ys.shape[2:])
    else:
        state, ys = jax.lax.scan(step, state, seq)          # ys: (S,B,H,V)
    y = ys.transpose(1, 0, 2, 3).reshape(b, s, d).astype(x.dtype)
    y = rms_norm(y.reshape(b, s, nh, hd), p["gn"].reshape(nh, hd)).reshape(b, s, d)
    out = (y * g) @ p["wo"]
    return out, state, x[:, -1:, :]


def rwkv_channel_mix(p, x, x_prev):
    xs = _token_shift(x, x_prev)
    xk = x + (xs - x) * p["mu_ck"]
    xr = x + (xs - x) * p["mu_cr"]
    k = jnp.square(jax.nn.relu(xk @ p["wck"]))
    return (k @ p["wcv"]) * jax.nn.sigmoid(xr @ p["wcr"]), x[:, -1:, :]


def rwkv_layer_fwd(p, x, cfg, state):
    """state: dict(wkv (B,H,K,V), tm_prev (B,1,d), cm_prev (B,1,d))."""
    h, wkv, tm_prev = rwkv_time_mix(
        p, rms_norm(x, p["ln1"]), cfg, state["wkv"], state["tm_prev"]
    )
    x = x + h
    h2, cm_prev = rwkv_channel_mix(p, rms_norm(x, p["ln2"]), state["cm_prev"])
    x = x + h2
    return x, {"wkv": wkv, "tm_prev": tm_prev, "cm_prev": cm_prev}


def rwkv_init_state(cfg, batch: int, dtype=jnp.float32):
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    nh = d // hd
    return {
        "wkv": jnp.zeros((batch, nh, hd, hd), jnp.float32),
        "tm_prev": jnp.zeros((batch, 1, d), dtype),
        "cm_prev": jnp.zeros((batch, 1, d), dtype),
    }
