"""LM model substrate: transformer (dense/MoE/VLM), enc-dec, SSM, hybrid."""
from .api import FamilyFns, family_fns
from .config import LMConfig, MoEConfig

__all__ = ["FamilyFns", "family_fns", "LMConfig", "MoEConfig"]
