"""Shared LM layers: norms, RoPE / M-RoPE, GQA attention, gated MLPs.

All functions are functional (params-in, value-out). Parameter creation
goes through ``Maker`` which doubles as the sharding-spec builder: with a
PRNG key it returns initialized arrays; in abstract mode it returns the
PartitionSpec for each leaf (same code path => init and specs can't drift).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


# ---------------------------------------------------------------------------
# Param builder / spec builder
# ---------------------------------------------------------------------------

class Maker:
    """Creates params (key mode) or PartitionSpecs (abstract mode).

    Sharding convention (DESIGN.md §5): 'model' = TP axis, 'data' = FSDP
    axis. A dim is sharded only if divisible by the axis size; the spec
    helper ``ax`` silently degrades to replication otherwise (e.g. gemma's
    8 q-heads on a 16-way model axis).
    """

    def __init__(self, key, mesh_sizes: dict[str, int] | None = None,
                 dtype=jnp.float32):
        self.key = key
        self.abstract = key is None
        self.mesh = mesh_sizes or {}
        self.dtype = dtype

    def ax(self, axis: str | tuple, dim: int):
        """axis name if dim divides evenly on the mesh, else None."""
        if isinstance(axis, tuple):
            size = 1
            for a in axis:
                size *= self.mesh.get(a, 1)
        else:
            size = self.mesh.get(axis, 1)
        return axis if size > 1 and dim % size == 0 else None

    def first_ax(self, dim: int, candidates=(("data", "model"), "model", "data")):
        """First candidate axis (or axis tuple) that divides ``dim``.
        Used for vocab dims where full 2D sharding may not divide evenly
        (e.g. qwen3's 151936 vocab on a 256-chip pod -> 'model' only)."""
        for cand in candidates:
            if self.ax(cand, dim) is not None:
                return cand
        return None

    def head_ax(self, num_heads: int):
        """TP axis for a fused (heads*head_dim) projection dim: shard only
        if the *head count* divides the model axis (rope/softmax are
        per-head; splitting inside a head is not supported)."""
        size = self.mesh.get("model", 1)
        return "model" if size > 1 and num_heads % size == 0 else None

    def make(self, shape, spec: P, *, scale: float | None = None,
             init: str = "normal"):
        if self.abstract:
            return spec
        if init == "zeros":
            return jnp.zeros(shape, self.dtype)
        if init == "ones":
            return jnp.ones(shape, self.dtype)
        self.key, sub = jax.random.split(self.key)
        std = scale if scale is not None else float(shape[0]) ** -0.5
        return jax.random.normal(sub, shape, self.dtype) * std


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def cast_floats(tree: Any, dtype) -> Any:
    """Cast float leaves to the compute dtype (mixed-precision forward:
    bf16 compute against f32 master params held by the optimizer)."""
    d = jnp.dtype(dtype)
    return jax.tree.map(
        lambda a: a.astype(d)
        if hasattr(a, "dtype") and jnp.issubdtype(a.dtype, jnp.floating)
        else a,
        tree,
    )


def constrain_batch(x, batch_axes):
    """Anchor the leading (batch) dim of an activation to the DP axes.

    Without this, XLA's sharding propagation on deep scans can settle on
    model-sharded/batch-REPLICATED activations (observed: +5-16x activation
    memory on train cells — EXPERIMENTS.md §Perf iteration act-shard-1).
    No-op when batch_axes is None (single-device smoke tests).
    Requires an ambient mesh (`with mesh:`) when enabled.
    """
    if batch_axes is None:
        return x
    spec = P(batch_axes, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, spec)


def constrain_logits(logits, batch_axes, vocab_axis):
    """Anchor (B, S, V) logits: batch over DP, vocab over the TP axis.
    Without the vocab anchor the CE chain (one-hot, lse, unembed grads,
    Adam states of the embedding) replicates the full vocab dim — observed
    +20 GiB/dev on qwen1.5-110b train (EXPERIMENTS.md §Perf vocab-1)."""
    if batch_axes is None and vocab_axis is None:
        return logits
    return jax.lax.with_sharding_constraint(
        logits, P(batch_axes, None, vocab_axis))


@jax.custom_vjp
def embed_lookup(table, tokens):
    """Embedding lookup with a partition-friendly backward.

    Forward: plain gather. Backward: the natural scatter-add of dtable
    triggers GSPMD "involuntary full rematerialization" on vocab-sharded
    tables (the whole (V, d) grad replicates on every chip — observed
    +14 GiB/dev on qwen1.5-110b train). Instead compute
    dtable = one_hot(tokens)^T @ dx — a matmul that partitions cleanly
    over (vocab x data). Costs 2*B*S*V*d FLOPs (~3% of a step), saves the
    replication (EXPERIMENTS.md §Perf embed-1).
    """
    return table[tokens]


def _embed_fwd(table, tokens):
    # the table rides along as a residual only to carry its static
    # shape/dtype into bwd (it is a live parameter anyway — no extra HBM)
    return table[tokens], (tokens, table)


def _embed_bwd(res, g):
    tokens, table = res
    onehot = jax.nn.one_hot(tokens, table.shape[0], dtype=g.dtype)
    dtable = jnp.einsum("...v,...d->vd", onehot, g).astype(table.dtype)
    return dtable, None


embed_lookup.defvjp(_embed_fwd, _embed_bwd)


def rms_norm(x, scale, eps=1e-5):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps).astype(x.dtype)) * scale


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------

def _inv_freqs(head_dim: int, theta: float, dtype=jnp.float32):
    return theta ** (
        -jnp.arange(0, head_dim // 2, dtype=dtype) / (head_dim // 2)
    )


def apply_rope(x, positions, theta: float):
    """x: (B, S, H, D); positions: (B, S) int -> rotated x."""
    d = x.shape[-1]
    inv = _inv_freqs(d, theta, jnp.float32)
    ang = positions[..., None].astype(jnp.float32) * inv  # (B, S, D/2)
    cos = jnp.cos(ang)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(ang)[:, :, None, :].astype(x.dtype)
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)


def apply_mrope(x, positions, sections: tuple[int, ...], theta: float):
    """Qwen2-VL M-RoPE. positions: (B, S, 3) for (t, h, w); ``sections``
    splits the D/2 frequency slots across the three position components."""
    d = x.shape[-1]
    inv = _inv_freqs(d, theta, jnp.float32)  # (D/2,)
    assert sum(sections) == d // 2, (sections, d)
    comp = []
    off = 0
    for i, sec in enumerate(sections):
        comp.append(
            positions[..., i:i + 1].astype(jnp.float32) * inv[off:off + sec]
        )
        off += sec
    ang = jnp.concatenate(comp, axis=-1)  # (B, S, D/2)
    cos = jnp.cos(ang)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(ang)[:, :, None, :].astype(x.dtype)
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)


# ---------------------------------------------------------------------------
# Attention (GQA; full / q-chunked / decode)
# ---------------------------------------------------------------------------

def _gqa_logits(q, k, scale):
    """q: (B, Sq, H, D), k: (B, Sk, Hkv, D) -> (B, H, Sq, Sk)."""
    b, sq, h, d = q.shape
    hkv = k.shape[2]
    g = h // hkv
    qg = q.reshape(b, sq, hkv, g, d)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k) * scale
    return logits.reshape(b, h, sq, k.shape[1])


def _gqa_out(probs, v):
    """probs: (B, H, Sq, Sk), v: (B, Sk, Hkv, D) -> (B, Sq, H, D)."""
    b, h, sq, sk = probs.shape
    hkv = v.shape[2]
    g = h // hkv
    pg = probs.reshape(b, hkv, g, sq, sk)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", pg, v)
    return out.reshape(b, sq, h, out.shape[-1])


def attention_full(q, k, v, *, causal: bool, q_offset: int = 0,
                   kv_len=None):
    """Materializing attention (training shapes / decode steps).

    kv_len: optional (B,) valid KV length mask for decode with a
    partially-filled cache.
    """
    scale = q.shape[-1] ** -0.5
    logits = _gqa_logits(q, k, scale)  # (B, H, Sq, Sk)
    sq, sk = logits.shape[-2], logits.shape[-1]
    neg = jnp.finfo(logits.dtype).min
    if causal and sq > 1:
        rows = jnp.arange(sq)[:, None] + q_offset
        cols = jnp.arange(sk)[None, :]
        logits = jnp.where(rows >= cols, logits, neg)
    if kv_len is not None:
        mask = jnp.arange(sk)[None, :] < kv_len[:, None]  # (B, Sk)
        logits = jnp.where(mask[:, None, None, :], logits, neg)
    probs = jax.nn.softmax(logits.astype(jnp.float32), -1).astype(q.dtype)
    return _gqa_out(probs, v)


def attention_decode_merge(q, k_cache, v_cache, k_new, v_new, pos):
    """Decode attention WITHOUT writing the new token into the cache.

    Attends over the (stale) cache masked to ``pos`` entries, then merges
    the current token's contribution with an online-softmax correction.
    This lets the decode layer-scan return only the tiny (B,1,Hkv,D) new
    KV as ys — the full cache is updated once, outside the scan, with a
    single aliased dynamic-update-slice (EXPERIMENTS.md §Perf decode-1;
    the naive in-scan update materializes a second full cache as scan ys).

    q: (B,1,H,D); k_cache/v_cache: (B,S,Hkv,D); k_new/v_new: (B,1,Hkv,D).
    """
    b, _, h, d = q.shape
    hkv = k_cache.shape[2]
    g = h // hkv
    scale = d ** -0.5
    logits_c = _gqa_logits(q, k_cache, scale)          # (B,H,1,S)
    neg = jnp.finfo(logits_c.dtype).min
    sk = k_cache.shape[1]
    mask = (jnp.arange(sk)[None, :] < pos)             # (1,S)
    logits_c = jnp.where(mask[:, None, None, :], logits_c, neg)
    logits_c = logits_c.astype(jnp.float32)

    qg = q.reshape(b, 1, hkv, g, d)
    l_s = jnp.einsum("bqhgd,bqhd->bhgq", qg, k_new) * scale
    l_s = l_s.reshape(b, h, 1).astype(jnp.float32)     # (B,H,1)

    m_c = jnp.max(logits_c, axis=-1)                   # (B,H,1)
    m = jnp.maximum(m_c, l_s)
    p_c = jnp.exp(logits_c - m[..., None])
    den_c = jnp.sum(p_c, axis=-1)                      # (B,H,1)
    num_c = _gqa_out(p_c.astype(q.dtype), v_cache)     # (B,1,H,D)
    beta = jnp.exp(l_s - m)                            # (B,H,1)
    v_rep = jnp.repeat(v_new, g, axis=2)               # (B,1,H,D)
    num = num_c + (beta.transpose(0, 2, 1)[..., None]).astype(q.dtype) * v_rep
    den = (den_c + beta).transpose(0, 2, 1)[..., None].astype(q.dtype)
    return num / jnp.maximum(den, 1e-30)


def attention_chunked(q, k, v, *, causal: bool, chunk: int = 1024):
    """Flash-style q-chunked attention: the (Sq x Sk) logits never exist
    whole; per-chunk transient is (chunk x Sk). Used for prefill_32k.
    (On real TPUs the Pallas flash kernel replaces this; the jnp version
    is what the dry-run lowers — same memory behavior class.)"""
    b, sq, h, d = q.shape
    if sq % chunk != 0 or sq == 1:
        return attention_full(q, k, v, causal=causal)
    n = sq // chunk
    qc = q.reshape(b, n, chunk, h, d).transpose(1, 0, 2, 3, 4)

    def one(carry, args):
        i, qi = args
        out = attention_full(qi, k, v, causal=causal, q_offset=i * chunk)
        return carry, out

    _, outs = jax.lax.scan(one, None, (jnp.arange(n), qc))
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, d)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def gated_mlp_apply(p, x, activation: str, use_pallas: bool = False):
    """SwiGLU / GeGLU: (act(x@wg) * (x@wu)) @ wd  (paper C4 on the LM side)."""
    if use_pallas:
        from repro.kernels import ops as kops

        shape = x.shape
        out = kops.fused_swiglu(
            x.reshape(-1, shape[-1]), p["wg"], p["wu"], p["wd"],
            activation=activation,
        )
        return out.reshape(shape)
    g = x @ p["wg"]
    u = x @ p["wu"]
    act = jax.nn.silu(g) if activation == "silu" else jax.nn.gelu(g, approximate=True)
    return (act * u) @ p["wd"]


def gated_mlp_init(mk: Maker, d: int, f: int):
    return {
        "wg": mk.make((d, f), P(mk.ax("data", d), mk.ax("model", f))),
        "wu": mk.make((d, f), P(mk.ax("data", d), mk.ax("model", f))),
        "wd": mk.make((f, d), P(mk.ax("model", f), mk.ax("data", d))),
    }


def plain_mlp_init(mk: Maker, d: int, f: int):
    return {
        "w1": mk.make((d, f), P(mk.ax("data", d), mk.ax("model", f))),
        "b1": mk.make((f,), P(mk.ax("model", f)), init="zeros"),
        "w2": mk.make((f, d), P(mk.ax("model", f), mk.ax("data", d))),
        "b2": mk.make((d,), P(None), init="zeros"),
    }


def plain_mlp_apply(p, x):
    return jax.nn.gelu(x @ p["w1"] + p["b1"], approximate=True) @ p["w2"] + p["b2"]


# ---------------------------------------------------------------------------
# Attention block params
# ---------------------------------------------------------------------------

def attn_init(mk: Maker, d: int, h: int, hkv: int, hd: int, *,
              qkv_bias: bool = False, qk_norm: bool = False):
    p = {
        "wq": mk.make((d, h * hd), P(mk.ax("data", d), mk.head_ax(h))),
        "wk": mk.make((d, hkv * hd), P(mk.ax("data", d), mk.head_ax(hkv))),
        "wv": mk.make((d, hkv * hd), P(mk.ax("data", d), mk.head_ax(hkv))),
        "wo": mk.make((h * hd, d), P(mk.head_ax(h), mk.ax("data", d))),
    }
    if qkv_bias:
        p["bq"] = mk.make((h * hd,), P(None), init="zeros")
        p["bk"] = mk.make((hkv * hd,), P(None), init="zeros")
        p["bv"] = mk.make((hkv * hd,), P(None), init="zeros")
    if qk_norm:
        p["q_norm"] = mk.make((hd,), P(None), init="ones")
        p["k_norm"] = mk.make((hd,), P(None), init="ones")
    return p


def attn_qkv(p, x, cfg, positions):
    """Project + (qk-norm) + rope. Returns q (B,S,H,D), k/v (B,S,Hkv,D)."""
    b, s, _ = x.shape
    h, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, hkv, hd)
    v = v.reshape(b, s, hkv, hd)
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    if positions is not None:
        if cfg.mrope_sections:
            q = apply_mrope(q, positions, cfg.mrope_sections, cfg.rope_theta)
            k = apply_mrope(k, positions, cfg.mrope_sections, cfg.rope_theta)
        else:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v
