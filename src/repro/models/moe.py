"""Mixture-of-Experts layer: top-k routing, sort-based capacity dispatch,
expert-parallel sharding (EP over the 'model' axis).

TPU adaptation (DESIGN.md §2): no dynamic shapes. GShard-style grouped
dispatch — tokens are grouped by sequence (the group dim shards over
'data', so routing sorts are local), each group has a static expert
capacity C = ceil(S * top_k * capacity_factor / E); overflow tokens drop
(standard on TPU). Dispatch is sort-based (argsort + one scatter + one
gather) rather than the O(T*E*C) one-hot einsum of the original GShard —
the MegaBlocks-era formulation, much cheaper at large T.

Shared experts (DeepSeek-MoE) are a dense gated MLP with ff = n_shared *
d_ff_expert, always on.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .layers import Maker, gated_mlp_apply, gated_mlp_init


def moe_init(mk: Maker, cfg):
    d = cfg.d_model
    m = cfg.moe
    e, fe = m.num_experts, m.d_ff_expert
    p = {
        "router": mk.make((d, e), P(None, mk.ax("model", e)), scale=d**-0.5),
        "we_gate": mk.make((e, d, fe), P(mk.ax("model", e), mk.ax("data", d), None)),
        "we_up": mk.make((e, d, fe), P(mk.ax("model", e), mk.ax("data", d), None)),
        "we_down": mk.make((e, fe, d), P(mk.ax("model", e), None, mk.ax("data", d))),
    }
    if m.num_shared:
        p["shared"] = gated_mlp_init(mk, d, m.num_shared * fe)
    return p


def _dispatch_group(x, gate, idx, num_experts: int, capacity: int):
    """One group's sort-based dispatch.

    x: (T, d); gate/idx: (T, k). Returns (expert_in (E, C, d), combine
    info for the gather-back).
    """
    t, k = idx.shape
    flat_e = idx.reshape(-1)                      # (T*k,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    pos = jnp.arange(t * k, dtype=jnp.int32)
    is_start = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_e[1:] != sorted_e[:-1]]
    )
    group_start = jax.lax.cummax(jnp.where(is_start, pos, 0))
    rank = pos - group_start                      # rank within expert
    keep = rank < capacity
    slot = jnp.where(keep, sorted_e * capacity + rank, num_experts * capacity)
    token = order // k                            # source token per slot
    buf = jnp.zeros((num_experts * capacity + 1, x.shape[-1]), x.dtype)
    buf = buf.at[slot].set(x[token])
    expert_in = buf[:-1].reshape(num_experts, capacity, x.shape[-1])
    gate_sorted = gate.reshape(-1)[order]
    return expert_in, (slot, token, keep, gate_sorted)


def _combine_group(expert_out, combine, t: int, k: int):
    slot, token, keep, gate_sorted = combine
    flat = expert_out.reshape(-1, expert_out.shape[-1])
    flat = jnp.concatenate([flat, jnp.zeros_like(flat[:1])], 0)
    y_sorted = flat[slot] * (gate_sorted * keep)[:, None]
    out = jnp.zeros((t, expert_out.shape[-1]), expert_out.dtype)
    return out.at[token].add(y_sorted)


def moe_apply(p, x, cfg, *, use_pallas: bool = False, moe_axes=None):
    """x: (B, S, d) -> (B, S, d). Groups = sequences (shard over data).

    moe_axes: optional (batch_axes, expert_axis) sharding anchor for the
    dispatched (B, E, C, d) buffers — without it the SPMD partitioner can
    replicate the x[token] gather across the pod (EXPERIMENTS.md §Perf
    iteration moe-1).
    """
    b, s, d = x.shape
    m = cfg.moe
    e, k = m.num_experts, m.top_k
    capacity = max(k, int(s * k * m.capacity_factor / e))

    logits = x @ p["router"]                      # (B, S, E) in f32
    probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
    gate, idx = jax.lax.top_k(probs, k)           # (B, S, k)
    gate = (gate / (gate.sum(-1, keepdims=True) + 1e-9)).astype(x.dtype)

    expert_in, combine = jax.vmap(
        lambda xx, gg, ii: _dispatch_group(xx, gg, ii, e, capacity)
    )(x, gate, idx)                                # expert_in: (B, E, C, d)
    if moe_axes is not None:
        bax, eax = moe_axes
        spec = P(bax, eax, None, None)
        expert_in = jax.lax.with_sharding_constraint(expert_in, spec)

    # expert FFN with stacked weights (einsum over the expert dim = EP)
    g = jnp.einsum("becd,edf->becf", expert_in, p["we_gate"])
    u = jnp.einsum("becd,edf->becf", expert_in, p["we_up"])
    h = jax.nn.silu(g) * u
    expert_out = jnp.einsum("becf,efd->becd", h, p["we_down"])
    if moe_axes is not None:
        bax, eax = moe_axes
        expert_out = jax.lax.with_sharding_constraint(
            expert_out, P(bax, eax, None, None))

    y = jax.vmap(lambda eo, cb: _combine_group(eo, cb, s, k))(
        expert_out, combine
    )                                              # (B, S, d)

    if m.num_shared:
        y = y + gated_mlp_apply(p["shared"], x, "silu", use_pallas)
    return y
