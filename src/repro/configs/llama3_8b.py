"""llama3-8b [dense]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256 — GQA, 128k vocab [arXiv:2407.21783]."""
from repro.models.config import LMConfig

CONFIG = LMConfig(
    name="llama3-8b", family="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=128256, activation="silu", rope_theta=500000.0,
)

SMOKE = CONFIG.with_(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=160,
    vocab_size=128, compute_dtype="float32",
)
