"""qwen1.5-110b [dense]: 80L d_model=8192 64H (GQA kv=8) d_ff=49152
vocab=152064 — QKV bias [hf:Qwen/Qwen1.5 family]."""
from repro.models.config import LMConfig

CONFIG = LMConfig(
    name="qwen1.5-110b", family="dense",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=49152, vocab_size=152064, activation="silu", qkv_bias=True,
    rope_theta=1000000.0,
)

SMOKE = CONFIG.with_(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=160,
    vocab_size=128, compute_dtype="float32",
)
