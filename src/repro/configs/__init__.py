"""Architecture registry: ``--arch <id>`` resolution.

Each module exposes CONFIG (the exact assigned configuration) and SMOKE
(a reduced same-family config for CPU tests).
"""
from __future__ import annotations

import importlib

from repro.models.config import LMConfig

_MODULES = {
    "llama3-8b": "llama3_8b",
    "gemma-2b": "gemma_2b",
    "qwen3-8b": "qwen3_8b",
    "qwen1.5-110b": "qwen15_110b",
    "phi3.5-moe-42b-a6.6b": "phi35_moe",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "whisper-medium": "whisper_medium",
    "qwen2-vl-2b": "qwen2_vl_2b",
    "zamba2-1.2b": "zamba2_1p2b",
    "rwkv6-3b": "rwkv6_3b",
}

ARCH_IDS = list(_MODULES)


def _module(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_IDS}")
    return importlib.import_module(f"repro.configs.{_MODULES[name]}")


def get_config(name: str) -> LMConfig:
    return _module(name).CONFIG


def get_smoke(name: str) -> LMConfig:
    return _module(name).SMOKE
