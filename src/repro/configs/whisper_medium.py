"""whisper-medium [audio]: 24L(enc)+24L(dec) d_model=1024 16H (MHA)
d_ff=4096 vocab=51865 — enc-dec; conv/mel frontend is a STUB
(input_specs provides precomputed frame embeddings) [arXiv:2212.04356]."""
from repro.models.config import LMConfig

CONFIG = LMConfig(
    name="whisper-medium", family="encdec",
    num_layers=24, num_decoder_layers=24, d_model=1024,
    num_heads=16, num_kv_heads=16, d_ff=4096, vocab_size=51865,
    activation="gelu",
)

SMOKE = CONFIG.with_(
    num_layers=2, num_decoder_layers=2, d_model=64, num_heads=4,
    num_kv_heads=4, d_ff=160, vocab_size=128, compute_dtype="float32",
)
