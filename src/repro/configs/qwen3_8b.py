"""qwen3-8b [dense]: 36L d_model=4096 32H (GQA kv=8) d_ff=12288
vocab=151936 — qk_norm [hf:Qwen/Qwen3-8B]."""
from repro.models.config import LMConfig

CONFIG = LMConfig(
    name="qwen3-8b", family="dense",
    num_layers=36, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=12288, vocab_size=151936, activation="silu", qk_norm=True,
    rope_theta=1000000.0,
)

SMOKE = CONFIG.with_(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=160,
    vocab_size=128, compute_dtype="float32",
)
