"""rwkv6-3b [ssm]: 32L d_model=2560 (attention-free, 40 wkv heads of 64)
d_ff=8960 vocab=65536 — Finch: data-dependent decay [arXiv:2404.05892].
O(1) decode state => long_500k runs (and is trivially cheap)."""
from repro.models.config import LMConfig

CONFIG = LMConfig(
    name="rwkv6-3b", family="rwkv",
    num_layers=32, d_model=2560, d_ff=8960, vocab_size=65536,
    num_heads=40, num_kv_heads=40, rwkv_head_dim=64,
)

SMOKE = CONFIG.with_(
    num_layers=2, d_model=64, d_ff=160, vocab_size=128, num_heads=4,
    num_kv_heads=4, rwkv_head_dim=16, compute_dtype="float32",
)
