"""Assigned input shapes × input_specs() builders for the dry-run.

Shapes (assigned to every LM arch):
    train_4k     seq=4096   global_batch=256   (training step)
    prefill_32k  seq=32768  global_batch=32    (inference prefill)
    decode_32k   seq=32768  global_batch=128   (one-token decode, full KV)
    long_500k    seq=524288 global_batch=1     (long-context decode;
                 SSM/hybrid only — skipped for pure full-attention archs)

``input_specs(cfg, shape, multi_pod)`` returns ShapeDtypeStruct stand-ins
(weak-type-correct, shardable, zero allocation) plus the matching
PartitionSpecs for every model input of the step being lowered.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.api import family_fns
from repro.models.config import LMConfig


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    kind: str       # "train" | "prefill" | "decode"
    seq: int
    batch: int


SHAPES = {
    "train_4k": Shape("train_4k", "train", 4096, 256),
    "prefill_32k": Shape("prefill_32k", "prefill", 32768, 32),
    "decode_32k": Shape("decode_32k", "decode", 32768, 128),
    "long_500k": Shape("long_500k", "decode", 524288, 1),
}


def batch_axes(multi_pod: bool):
    return ("pod", "data") if multi_pod else ("data",)


def _bax(batch: int, multi_pod: bool, mesh_sizes: dict):
    """Batch sharding axes, degraded to replication if not divisible."""
    axes = batch_axes(multi_pod)
    total = 1
    for a in axes:
        total *= mesh_sizes.get(a, 1)
    return axes if batch % total == 0 and total > 1 else None


def cell_status(cfg: LMConfig, shape: Shape) -> str:
    """'ok' or 'skip:<reason>' for this (arch x shape) cell."""
    fns = family_fns(cfg)
    if shape.name == "long_500k" and not fns.supports_long_context:
        return ("skip: pure full-attention arch — 524k dense-attention "
                "decode is defined for sub-quadratic (SSM/hybrid) archs only")
    return "ok"


def input_specs(cfg: LMConfig, shape: Shape, *, multi_pod: bool,
                mesh_sizes: dict):
    """Returns dict(kind, args=tuple[ShapeDtypeStruct-trees],
    specs=tuple[PartitionSpec-trees], donate=tuple[int indices])."""
    fns = family_fns(cfg)
    s = jax.ShapeDtypeStruct
    b, sl = shape.batch, shape.seq
    bax = _bax(b, multi_pod, mesh_sizes)
    tok_spec = P(bax, None)
    cdtype = jnp.dtype(cfg.compute_dtype)

    def positions(batch, seq):
        if not fns.has_positions:
            return None, None
        if fns.positions_3d:
            return s((batch, seq, 3), jnp.int32), P(bax, None, None)
        return s((batch, seq), jnp.int32), tok_spec

    if shape.kind == "train":
        if fns.token_input:
            x = s((b, sl), jnp.int32)
            x_spec = tok_spec
        else:  # whisper: precomputed frame embeddings (frontend stub)
            x = s((b, sl, cfg.d_model), cdtype)
            x_spec = P(bax, None, None)
        labels = s((b, sl), jnp.int32)
        pos, pos_spec = positions(b, sl)
        args = (x, labels) + ((pos,) if pos is not None else ())
        specs = (x_spec, tok_spec) + ((pos_spec,) if pos is not None else ())
        return {"kind": "train", "args": args, "specs": specs, "donate": ()}

    if shape.kind == "prefill":
        if fns.token_input:
            x = s((b, sl), jnp.int32)
            x_spec = tok_spec
        else:
            x = s((b, sl, cfg.d_model), cdtype)
            x_spec = P(bax, None, None)
        pos, pos_spec = positions(b, sl)
        args = (x,) + ((pos,) if pos is not None else ())
        specs = (x_spec,) + ((pos_spec,) if pos is not None else ())
        return {"kind": "prefill", "args": args, "specs": specs, "donate": ()}

    # decode: one new token against a seq-len KV cache / recurrent state
    tokens = s((b, 1), jnp.int32)
    pos, pos_spec = positions(b, 1)
    state_struct, state_spec = decode_state_structs(
        cfg, b, sl, multi_pod=multi_pod, mesh_sizes=mesh_sizes)
    args = (tokens, state_struct) + ((pos,) if pos is not None else ())
    specs = (P(bax, None), state_spec) + (
        (pos_spec,) if pos is not None else ())
    return {"kind": "decode", "args": args, "specs": specs, "donate": (2,)}


def decode_state_structs(cfg: LMConfig, batch: int, max_len: int, *,
                         multi_pod: bool, mesh_sizes: dict):
    """(ShapeDtypeStruct tree, PartitionSpec tree) for the decode state."""
    fns = family_fns(cfg)
    bax = _bax(batch, multi_pod, mesh_sizes)
    seq_axis = "model"  # SP fallback axis for KV when heads can't shard

    if cfg.family == "encdec":
        s = jax.ShapeDtypeStruct
        hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        ld = cfg.num_decoder_layers
        struct = {
            "k": s((ld, batch, max_len, hkv, hd), jnp.bfloat16),
            "v": s((ld, batch, max_len, hkv, hd), jnp.bfloat16),
            "xk": s((ld, batch, max_len, hkv, hd), jnp.bfloat16),
            "xv": s((ld, batch, max_len, hkv, hd), jnp.bfloat16),
            "pos": s((), jnp.int32),
        }
        spec = fns.decode_state_specs(cfg, mesh_sizes, bax, seq_axis)
        return struct, spec

    struct = jax.eval_shape(
        lambda: fns.init_decode_state(cfg, batch, max_len, jnp.bfloat16)
    )
    spec = fns.decode_state_specs(cfg, mesh_sizes, bax, seq_axis)
    return struct, spec
