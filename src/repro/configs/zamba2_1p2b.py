"""zamba2-1.2b [hybrid]: 38L d_model=2048 (Mamba2 backbone, ssm_state=64)
+ one SHARED attention block (32H MHA, d_ff=8192) applied every 6 layers
[arXiv:2411.15242]. Sub-quadratic decode => long_500k runs."""
from repro.models.config import LMConfig

CONFIG = LMConfig(
    name="zamba2-1.2b", family="hybrid",
    num_layers=38, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=32000, activation="silu",
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_conv=4, attn_every=6,
)

SMOKE = CONFIG.with_(
    num_layers=4, d_model=64, num_heads=4, num_kv_heads=4, d_ff=160,
    vocab_size=128, ssm_state=16, ssm_head_dim=16, attn_every=2,
    compute_dtype="float32",
)
