"""phi3.5-moe-42b-a6.6b [moe]: 32L d_model=4096 32H (GQA kv=8)
d_ff=6400/expert vocab=32064, 16 experts top-2
[hf:microsoft/Phi-3.5-MoE-instruct]."""
from repro.models.config import LMConfig, MoEConfig

CONFIG = LMConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=0, vocab_size=32064, activation="silu",
    moe=MoEConfig(num_experts=16, top_k=2, num_shared=0, d_ff_expert=6400),
)

SMOKE = CONFIG.with_(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, vocab_size=128,
    compute_dtype="float32",
    moe=MoEConfig(num_experts=4, top_k=2, num_shared=0, d_ff_expert=32),
)
