"""qwen2-vl-2b [vlm]: 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936 — M-RoPE (t,h,w)=(16,24,24) over head_dim/2=64; dynamic-res
vision frontend is a STUB (positions carry the 3D M-RoPE coordinates)
[arXiv:2409.12191]."""
from repro.models.config import LMConfig

CONFIG = LMConfig(
    name="qwen2-vl-2b", family="vlm",
    num_layers=28, d_model=1536, num_heads=12, num_kv_heads=2,
    d_ff=8960, vocab_size=151936, activation="silu",
    mrope_sections=(16, 24, 24), rope_theta=1000000.0,
)

SMOKE = CONFIG.with_(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=160,
    vocab_size=128, mrope_sections=(4, 2, 2), compute_dtype="float32",
)
