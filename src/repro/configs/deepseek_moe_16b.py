"""deepseek-moe-16b [moe]: 28L d_model=2048 16H (MHA kv=16) d_ff=1408/expert
vocab=102400, 2 shared + 64 routed top-6 (fine-grained) [arXiv:2401.06066].
NOTE: the real model's first layer is dense; we keep a homogeneous MoE
stack (layer-0 dense is a <2% FLOP detail at this scale)."""
from repro.models.config import LMConfig, MoEConfig

CONFIG = LMConfig(
    name="deepseek-moe-16b", family="moe",
    num_layers=28, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=0, vocab_size=102400, activation="silu",
    moe=MoEConfig(num_experts=64, top_k=6, num_shared=2, d_ff_expert=1408),
)

SMOKE = CONFIG.with_(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, vocab_size=128,
    compute_dtype="float32",
    moe=MoEConfig(num_experts=8, top_k=2, num_shared=1, d_ff_expert=32),
)
