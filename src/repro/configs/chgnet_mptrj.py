"""The paper's own model: CHGNet v0.3.0-style config for MPtrj training
(paper §IV Parameters Setting) + the FastCHGNet variants of Table I.
"""
from repro.core.chgnet import CHGNetConfig
from repro.core.losses import LossWeights

# reference CHGNet (autodiff force/stress, sequential blocks)
REFERENCE = CHGNetConfig(
    dim=64, num_rbf=31, num_fourier=31, num_blocks=3,
    r_cut_atom=6.0, r_cut_bond=3.0, envelope_p=8,
    readout="autodiff", block_variant="reference", mlp_impl="ref",
    envelope_impl="reference",
)

# FastCHGNet "w/o head": all system optimizations, physics-consistent readout
FAST_WO_HEAD = REFERENCE.with_(
    block_variant="fast", mlp_impl="packed", envelope_impl="factored",
)

# FastCHGNet "F/S head": + decoupled Force/Stress heads (paper C1)
FAST_FS_HEAD = FAST_WO_HEAD.with_(readout="direct")

# beyond Table I: + fused message-passing megakernels (DESIGN.md §3) — the
# conv/readout message paths never materialize concat or message tensors in
# HBM and recompute them in the backward (requires the §1 sorted layout,
# which every repro.batching / repro.serve batch provides)
FAST_FUSED = FAST_FS_HEAD.with_(conv_impl="fused", agg_impl="pallas")

# + end-to-end mixed precision (DESIGN.md §4): f32 master params and
# accumulation, bf16 GEMM / kernel-VMEM operands, dynamic loss scaling in
# the Trainer — the paper's "exploit GPU computation power" regime
FAST_MIXED = FAST_FS_HEAD.with_(precision="mixed")
FAST_FUSED_MIXED = FAST_FUSED.with_(precision="mixed")

# + undirected-bond redundancy bypass (DESIGN.md §5): bond geometry, the
# smooth-RBF basis, the bond-embed GEMM, and the e^a/e^b envelopes run
# once per pair (Eu = E/2); directed views via the batch mirror maps —
# the paper's redundancy-bypass contribution applied to the whole bond
# store, composing with the fused megakernels and mixed precision
FAST_HALF = FAST_FS_HEAD.with_(bond_store="undirected")
FAST_FUSED_HALF = FAST_FUSED.with_(bond_store="undirected")
FAST_FUSED_HALF_MIXED = FAST_FUSED_MIXED.with_(bond_store="undirected")

# + symmetric half-graph trunk (DESIGN.md §10): the undirected store's
# Eu/Au rows become the COMPUTE representation, not just the storage one —
# bond_conv aggregates both directed angle contributions of each pair into
# one Eu-row update and angle_update runs its swap-symmetrized f_a over Au
# rows, halving every bond/angle-level GEMM's row count end to end.
# Param shapes are unchanged (checkpoint-compatible with FAST_HALF); the
# directed view survives only at the head boundary.
FAST_SYM = FAST_HALF.with_(bond_features="undirected")
FAST_FUSED_SYM = FAST_FUSED_HALF.with_(bond_features="undirected")

# + per-bond virial stress (DESIGN.md §7): sigma from the force head's own
# n_ij — sigma = 1/(2V) sum n_ij d_ij x_hat⊗x_hat — instead of the pooled
# S-head MLP; no stress parameters, geometry-aware by construction.  In
# FAST_FUSED_VIRIAL the accumulation runs inside the force-readout
# megakernel epilogue: force + stress in ONE kernel launch, zero extra HBM
# reads of e/vec, the (E, 3, 3) outer-product tensor never materializes.
FAST_VIRIAL = FAST_FS_HEAD.with_(stress_mode="bond_virial")
FAST_FUSED_VIRIAL = FAST_FUSED.with_(stress_mode="bond_virial")

LOSS = LossWeights(energy=2.0, force=1.5, stress=0.1, magmom=0.1,
                   huber_delta=0.1)

# paper training recipe
BATCH_SIZE = 128          # reference single-GPU recipe
LARGE_BATCH = 2048        # multi-GPU recipe (Fig. 6)
EPOCHS = 30
BASE_LR = 3e-4
LR_K = 128                # Eq. 14

# multi-GPU sharding recipe (DESIGN.md §6, paper Fig. 4/9): cost-model
# LPT bin packing instead of even-count shards, with per-bucket gradient
# accumulation so mixed-size microbatches never pad to the worst bucket
# (launch/train: --balance cost --accum N)
BALANCE = "cost"
ACCUM_MICROS = 2          # microbatches per optimizer step at LARGE_BATCH
