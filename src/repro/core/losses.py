"""Multi-target Huber loss (paper §IV: prefactors E:2, F:1.5, S:0.1, M:0.1).

Energy is supervised per-atom (meV/atom convention); all reductions are
mask-aware so padding never contributes.

Precision (DESIGN.md §4): predictions and targets are upcast to f32
BEFORE the Huber/error terms, and ``_masked_mean`` reduces in f32 — so
the loss value and every reported MAE metric are comparable across
precision policies, and the long masked sums over padded capacities
never accumulate in bf16 (where the many padded-slot zeros plus rounding
would dominate the mean).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from .graph import CrystalGraphBatch


@dataclasses.dataclass(frozen=True)
class LossWeights:
    energy: float = 2.0
    force: float = 1.5
    stress: float = 0.1
    magmom: float = 0.1
    huber_delta: float = 0.1


def huber(x, delta):
    absx = jnp.abs(x)
    quad = 0.5 * x * x
    lin = delta * (absx - 0.5 * delta)
    return jnp.where(absx <= delta, quad, lin)


def _masked_mean(x, mask):
    # f32-pinned reduction: metrics stay comparable across precision
    # policies (DESIGN.md §4)
    x = x.astype(jnp.float32)
    mask = mask.astype(jnp.float32)
    return jnp.sum(x * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def _f32(x):
    return x.astype(jnp.float32)


def _error_terms(pred: dict, graph: CrystalGraphBatch):
    """Masked f32 error terms shared by the mean- and sum-reduced losses."""
    n = jnp.maximum(_f32(graph.n_atoms_per_crystal), 1.0)
    # upcast BEFORE the error terms so Huber's quadratic/linear branch
    # decision and the MAEs are taken in f32 for every policy
    e_err = (_f32(pred["energy"]) - _f32(graph.energy)) / n  # eV/atom
    f_err = _f32(pred["forces"]) - _f32(graph.forces)
    s_err = _f32(pred["stress"]) - _f32(graph.stress)
    m_err = _f32(pred["magmom"]) - _f32(graph.magmoms)

    cmask = graph.crystal_mask
    amask = graph.atom_mask
    fmask = amask[..., None] * jnp.ones_like(f_err)
    smask = cmask[:, None, None] * jnp.ones_like(s_err)
    return (e_err, cmask), (f_err, fmask), (s_err, smask), (m_err, amask)


def chgnet_loss(pred: dict, graph: CrystalGraphBatch, w: LossWeights):
    """Returns (scalar loss, metrics dict with per-target MAEs)."""
    (e_err, cmask), (f_err, fmask), (s_err, smask), (m_err, amask) = \
        _error_terms(pred, graph)

    l_e = _masked_mean(huber(e_err, w.huber_delta), cmask)
    l_f = _masked_mean(huber(f_err, w.huber_delta), fmask)
    l_s = _masked_mean(huber(s_err, w.huber_delta), smask)
    l_m = _masked_mean(huber(m_err, w.huber_delta), amask)
    loss = w.energy * l_e + w.force * l_f + w.stress * l_s + w.magmom * l_m

    metrics = {
        "loss": loss,
        "mae_e_per_atom": _masked_mean(jnp.abs(e_err), cmask),
        "mae_f": _masked_mean(jnp.abs(f_err), fmask),
        "mae_s": _masked_mean(jnp.abs(s_err), smask),
        "mae_m": _masked_mean(jnp.abs(m_err), amask),
    }
    return loss, metrics


# ---------------------------------------------------------------------------
# Global-denominator reduction for gradient accumulation (DESIGN.md §6)
# ---------------------------------------------------------------------------

def global_denominators(num_crystals: int, num_atoms: int) -> dict:
    """Loss denominators of a *global* batch with the given real counts.

    Matches ``_masked_mean``'s per-term mask totals exactly: crystals for
    energy, 3*atoms for forces, 9*crystals for stress, atoms for magmoms
    (each clamped to >= 1, like ``_masked_mean``).  Passed unchanged to
    every microbatch of one optimizer step, so the per-microbatch losses
    of :func:`chgnet_loss_sums` SUM to the single-big-batch
    :func:`chgnet_loss` — and therefore so do their gradients.
    """
    c = float(max(num_crystals, 1))
    a = float(max(num_atoms, 1))
    return {
        "energy": np.float32(c),
        "force": np.float32(3.0 * a),
        "stress": np.float32(9.0 * c),
        "magmom": np.float32(a),
    }


def chgnet_loss_sums(pred: dict, graph: CrystalGraphBatch, w: LossWeights,
                     denoms: dict):
    """Partial loss of one microbatch against GLOBAL denominators.

    Returns ``(loss, sums)``: ``loss`` is this microbatch's masked Huber
    sums divided by the step-wide ``denoms`` (see
    :func:`global_denominators`), so losses — and gradients — are exactly
    additive across the microbatches of one optimizer step regardless of
    how unevenly the balancer split it.  ``sums`` carries the unweighted
    absolute-error sums (plus the loss itself) for metric aggregation via
    :func:`metrics_from_sums`.  An all-padding shard (a device idled by
    an uneven bucket group) contributes exactly zero to both.
    """
    (e_err, cmask), (f_err, fmask), (s_err, smask), (m_err, amask) = \
        _error_terms(pred, graph)

    def msum(x, mask):
        return jnp.sum(x.astype(jnp.float32) * mask.astype(jnp.float32))

    loss = (
        w.energy * msum(huber(e_err, w.huber_delta), cmask) / denoms["energy"]
        + w.force * msum(huber(f_err, w.huber_delta), fmask) / denoms["force"]
        + w.stress * msum(huber(s_err, w.huber_delta), smask) / denoms["stress"]
        + w.magmom * msum(huber(m_err, w.huber_delta), amask) / denoms["magmom"]
    )
    sums = {
        "loss": loss,
        "abs_e": msum(jnp.abs(e_err), cmask),
        "abs_f": msum(jnp.abs(f_err), fmask),
        "abs_s": msum(jnp.abs(s_err), smask),
        "abs_m": msum(jnp.abs(m_err), amask),
    }
    return loss, sums


def metrics_from_sums(sums: dict, denoms: dict) -> dict:
    """Accumulated microbatch sums -> the ``chgnet_loss`` metrics dict."""
    return {
        "loss": sums["loss"],
        "mae_e_per_atom": sums["abs_e"] / denoms["energy"],
        "mae_f": sums["abs_f"] / denoms["force"],
        "mae_s": sums["abs_s"] / denoms["stress"],
        "mae_m": sums["abs_m"] / denoms["magmom"],
    }
