"""Multi-target Huber loss (paper §IV: prefactors E:2, F:1.5, S:0.1, M:0.1).

Energy is supervised per-atom (meV/atom convention); all reductions are
mask-aware so padding never contributes.

Precision (DESIGN.md §4): predictions and targets are upcast to f32
BEFORE the Huber/error terms, and ``_masked_mean`` reduces in f32 — so
the loss value and every reported MAE metric are comparable across
precision policies, and the long masked sums over padded capacities
never accumulate in bf16 (where the many padded-slot zeros plus rounding
would dominate the mean).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from .graph import CrystalGraphBatch


@dataclasses.dataclass(frozen=True)
class LossWeights:
    energy: float = 2.0
    force: float = 1.5
    stress: float = 0.1
    magmom: float = 0.1
    huber_delta: float = 0.1


def huber(x, delta):
    absx = jnp.abs(x)
    quad = 0.5 * x * x
    lin = delta * (absx - 0.5 * delta)
    return jnp.where(absx <= delta, quad, lin)


def _masked_mean(x, mask):
    # f32-pinned reduction: metrics stay comparable across precision
    # policies (DESIGN.md §4)
    x = x.astype(jnp.float32)
    mask = mask.astype(jnp.float32)
    return jnp.sum(x * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def _f32(x):
    return x.astype(jnp.float32)


def chgnet_loss(pred: dict, graph: CrystalGraphBatch, w: LossWeights):
    """Returns (scalar loss, metrics dict with per-target MAEs)."""
    n = jnp.maximum(_f32(graph.n_atoms_per_crystal), 1.0)
    # upcast BEFORE the error terms so Huber's quadratic/linear branch
    # decision and the MAEs are taken in f32 for every policy
    e_err = (_f32(pred["energy"]) - _f32(graph.energy)) / n  # eV/atom
    f_err = _f32(pred["forces"]) - _f32(graph.forces)
    s_err = _f32(pred["stress"]) - _f32(graph.stress)
    m_err = _f32(pred["magmom"]) - _f32(graph.magmoms)

    cmask = graph.crystal_mask
    amask = graph.atom_mask
    fmask = amask[..., None] * jnp.ones_like(f_err)
    smask = cmask[:, None, None] * jnp.ones_like(s_err)

    l_e = _masked_mean(huber(e_err, w.huber_delta), cmask)
    l_f = _masked_mean(huber(f_err, w.huber_delta), fmask)
    l_s = _masked_mean(huber(s_err, w.huber_delta), smask)
    l_m = _masked_mean(huber(m_err, w.huber_delta), amask)
    loss = w.energy * l_e + w.force * l_f + w.stress * l_s + w.magmom * l_m

    metrics = {
        "loss": loss,
        "mae_e_per_atom": _masked_mean(jnp.abs(e_err), cmask),
        "mae_f": _masked_mean(jnp.abs(f_err), fmask),
        "mae_s": _masked_mean(jnp.abs(s_err), smask),
        "mae_m": _masked_mean(jnp.abs(m_err), amask),
    }
    return loss, metrics
