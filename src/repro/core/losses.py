"""Multi-target Huber loss (paper §IV: prefactors E:2, F:1.5, S:0.1, M:0.1).

Energy is supervised per-atom (meV/atom convention); all reductions are
mask-aware so padding never contributes.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from .graph import CrystalGraphBatch


@dataclasses.dataclass(frozen=True)
class LossWeights:
    energy: float = 2.0
    force: float = 1.5
    stress: float = 0.1
    magmom: float = 0.1
    huber_delta: float = 0.1


def huber(x, delta):
    absx = jnp.abs(x)
    quad = 0.5 * x * x
    lin = delta * (absx - 0.5 * delta)
    return jnp.where(absx <= delta, quad, lin)


def _masked_mean(x, mask):
    return jnp.sum(x * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def chgnet_loss(pred: dict, graph: CrystalGraphBatch, w: LossWeights):
    """Returns (scalar loss, metrics dict with per-target MAEs)."""
    n = jnp.maximum(graph.n_atoms_per_crystal, 1.0)
    e_err = (pred["energy"] - graph.energy) / n  # eV/atom
    f_err = pred["forces"] - graph.forces
    s_err = pred["stress"] - graph.stress
    m_err = pred["magmom"] - graph.magmoms

    cmask = graph.crystal_mask
    amask = graph.atom_mask
    fmask = amask[..., None] * jnp.ones_like(f_err)
    smask = cmask[:, None, None] * jnp.ones_like(s_err)

    l_e = _masked_mean(huber(e_err, w.huber_delta), cmask)
    l_f = _masked_mean(huber(f_err, w.huber_delta), fmask)
    l_s = _masked_mean(huber(s_err, w.huber_delta), smask)
    l_m = _masked_mean(huber(m_err, w.huber_delta), amask)
    loss = w.energy * l_e + w.force * l_f + w.stress * l_s + w.magmom * l_m

    metrics = {
        "loss": loss,
        "mae_e_per_atom": _masked_mean(jnp.abs(e_err), cmask),
        "mae_f": _masked_mean(jnp.abs(f_err), fmask),
        "mae_s": _masked_mean(jnp.abs(s_err), smask),
        "mae_m": _masked_mean(jnp.abs(m_err), amask),
    }
    return loss, metrics
