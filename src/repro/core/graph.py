"""Padded, fixed-shape crystal-graph batches (device side).

JAX/XLA requires static shapes under jit; the reference CHGNet's
variable-size concat batching (paper Alg. 1/2) is replaced by
*fixed-capacity padded batches*:

  - every batch has capacities (atom_cap, bond_cap, angle_cap);
  - real entries are packed at the front, masks mark validity;
  - padded bonds/angles point at slot 0 with zeroed (masked) payloads, so
    segment-sums are unaffected.

This is the TPU-native analogue of the paper's "Parallel Computation of
Basis" (Alg. 2): all crystals in the batch are processed by one fused
program, with zero host-side per-sample Python during the step.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .neighbors import Crystal, GraphIndices


@partial(
    jax.tree_util.register_dataclass,
    data_fields=[
        "atom_z", "atom_mask", "atom_crystal", "frac_coords", "lattice",
        "crystal_mask", "bond_center", "bond_nbr", "bond_image",
        "bond_crystal", "bond_mask", "angle_ij", "angle_ik", "angle_mask",
        "energy", "forces", "stress", "magmoms", "n_atoms_per_crystal",
    ],
    meta_fields=[],
)
@dataclasses.dataclass
class CrystalGraphBatch:
    """A padded batch of B crystals, flattened atoms/bonds/angles."""

    # atoms
    atom_z: jnp.ndarray         # (atom_cap,) int32; 0 for padding
    atom_mask: jnp.ndarray      # (atom_cap,) f32
    atom_crystal: jnp.ndarray   # (atom_cap,) int32 crystal id in [0, B)
    frac_coords: jnp.ndarray    # (atom_cap, 3) f32
    # crystals
    lattice: jnp.ndarray        # (B, 3, 3) f32
    crystal_mask: jnp.ndarray   # (B,) f32
    # bonds (directed; G^a edges)
    bond_center: jnp.ndarray    # (bond_cap,) int32 -> atom index
    bond_nbr: jnp.ndarray       # (bond_cap,) int32 -> atom index
    bond_image: jnp.ndarray     # (bond_cap, 3) f32 periodic image
    bond_crystal: jnp.ndarray   # (bond_cap,) int32
    bond_mask: jnp.ndarray      # (bond_cap,) f32
    # angles (G^b edges): indices into bonds
    angle_ij: jnp.ndarray       # (angle_cap,) int32
    angle_ik: jnp.ndarray       # (angle_cap,) int32
    angle_mask: jnp.ndarray     # (angle_cap,) f32
    # labels
    energy: jnp.ndarray         # (B,) f32 total energy (eV)
    forces: jnp.ndarray         # (atom_cap, 3) f32
    stress: jnp.ndarray         # (B, 3, 3) f32
    magmoms: jnp.ndarray        # (atom_cap,) f32
    n_atoms_per_crystal: jnp.ndarray  # (B,) f32

    @property
    def num_crystals(self) -> int:
        return self.lattice.shape[0]

    @property
    def atom_cap(self) -> int:
        return self.atom_z.shape[0]

    @property
    def bond_cap(self) -> int:
        return self.bond_center.shape[0]

    @property
    def angle_cap(self) -> int:
        return self.angle_ij.shape[0]


@dataclasses.dataclass(frozen=True)
class BatchCapacities:
    atoms: int
    bonds: int
    angles: int

    def fits(self, n_atoms: int, n_bonds: int, n_angles: int) -> bool:
        return (
            n_atoms <= self.atoms
            and n_bonds <= self.bonds
            and n_angles <= self.angles
        )


def batch_crystals(
    crystals: list[Crystal],
    graphs: list[GraphIndices],
    caps: BatchCapacities,
    *,
    dtype=np.float32,
) -> CrystalGraphBatch:
    """Pack crystals + pre-built graph indices into one padded batch.

    Raises ValueError if the batch exceeds the capacities (callers should
    size capacities from dataset statistics / the bucketing policy).
    """
    b = len(crystals)
    tot_atoms = sum(c.num_atoms for c in crystals)
    tot_bonds = sum(g.num_bonds for g in graphs)
    tot_angles = sum(g.num_angles for g in graphs)
    if not caps.fits(tot_atoms, tot_bonds, tot_angles):
        raise ValueError(
            f"batch ({tot_atoms} atoms, {tot_bonds} bonds, {tot_angles} angles)"
            f" exceeds capacities {caps}"
        )

    atom_z = np.zeros((caps.atoms,), np.int32)
    atom_mask = np.zeros((caps.atoms,), dtype)
    atom_crystal = np.zeros((caps.atoms,), np.int32)
    frac = np.zeros((caps.atoms, 3), dtype)
    lattice = np.zeros((b, 3, 3), dtype)
    crystal_mask = np.zeros((b,), dtype)
    bond_center = np.zeros((caps.bonds,), np.int32)
    bond_nbr = np.zeros((caps.bonds,), np.int32)
    bond_image = np.zeros((caps.bonds, 3), dtype)
    bond_crystal = np.zeros((caps.bonds,), np.int32)
    bond_mask = np.zeros((caps.bonds,), dtype)
    angle_ij = np.zeros((caps.angles,), np.int32)
    angle_ik = np.zeros((caps.angles,), np.int32)
    angle_mask = np.zeros((caps.angles,), dtype)
    energy = np.zeros((b,), dtype)
    forces = np.zeros((caps.atoms, 3), dtype)
    stress = np.zeros((b, 3, 3), dtype)
    magmoms = np.zeros((caps.atoms,), dtype)
    n_atoms = np.zeros((b,), dtype)

    a_off = 0
    b_off = 0
    g_off = 0
    for ci, (c, g) in enumerate(zip(crystals, graphs)):
        na, nb, ng = c.num_atoms, g.num_bonds, g.num_angles
        atom_z[a_off:a_off + na] = c.atomic_numbers
        atom_mask[a_off:a_off + na] = 1.0
        atom_crystal[a_off:a_off + na] = ci
        frac[a_off:a_off + na] = c.frac_coords
        lattice[ci] = c.lattice
        crystal_mask[ci] = 1.0
        n_atoms[ci] = na
        bond_center[b_off:b_off + nb] = g.bond_center + a_off
        bond_nbr[b_off:b_off + nb] = g.bond_nbr + a_off
        bond_image[b_off:b_off + nb] = g.bond_image.astype(dtype)
        bond_crystal[b_off:b_off + nb] = ci
        bond_mask[b_off:b_off + nb] = 1.0
        angle_ij[g_off:g_off + ng] = g.angle_ij + b_off
        angle_ik[g_off:g_off + ng] = g.angle_ik + b_off
        angle_mask[g_off:g_off + ng] = 1.0
        if c.energy is not None:
            energy[ci] = c.energy
        if c.forces is not None:
            forces[a_off:a_off + na] = c.forces
        if c.stress is not None:
            stress[ci] = c.stress
        if c.magmoms is not None:
            magmoms[a_off:a_off + na] = c.magmoms
        a_off += na
        b_off += nb
        g_off += ng

    return CrystalGraphBatch(
        atom_z=jnp.asarray(atom_z),
        atom_mask=jnp.asarray(atom_mask),
        atom_crystal=jnp.asarray(atom_crystal),
        frac_coords=jnp.asarray(frac),
        lattice=jnp.asarray(lattice),
        crystal_mask=jnp.asarray(crystal_mask),
        bond_center=jnp.asarray(bond_center),
        bond_nbr=jnp.asarray(bond_nbr),
        bond_image=jnp.asarray(bond_image),
        bond_crystal=jnp.asarray(bond_crystal),
        bond_mask=jnp.asarray(bond_mask),
        angle_ij=jnp.asarray(angle_ij),
        angle_ik=jnp.asarray(angle_ik),
        angle_mask=jnp.asarray(angle_mask),
        energy=jnp.asarray(energy),
        forces=jnp.asarray(forces),
        stress=jnp.asarray(stress),
        magmoms=jnp.asarray(magmoms),
        n_atoms_per_crystal=jnp.asarray(n_atoms),
    )


def batch_input_specs(
    batch_size: int, caps: BatchCapacities, dtype=jnp.float32
) -> CrystalGraphBatch:
    """ShapeDtypeStruct stand-in batch for dry-run lowering (no allocation)."""
    s = jax.ShapeDtypeStruct
    f, i = dtype, jnp.int32
    return CrystalGraphBatch(
        atom_z=s((caps.atoms,), i),
        atom_mask=s((caps.atoms,), f),
        atom_crystal=s((caps.atoms,), i),
        frac_coords=s((caps.atoms, 3), f),
        lattice=s((batch_size, 3, 3), f),
        crystal_mask=s((batch_size,), f),
        bond_center=s((caps.bonds,), i),
        bond_nbr=s((caps.bonds,), i),
        bond_image=s((caps.bonds, 3), f),
        bond_crystal=s((caps.bonds,), i),
        bond_mask=s((caps.bonds,), f),
        angle_ij=s((caps.angles,), i),
        angle_ik=s((caps.angles,), i),
        angle_mask=s((caps.angles,), f),
        energy=s((batch_size,), f),
        forces=s((caps.atoms, 3), f),
        stress=s((batch_size, 3, 3), f),
        magmoms=s((caps.atoms,), f),
        n_atoms_per_crystal=s((batch_size,), f),
    )
