"""Padded, fixed-shape crystal-graph batches (device side).

JAX/XLA requires static shapes under jit; the reference CHGNet's
variable-size concat batching (paper Alg. 1/2) is replaced by
*fixed-capacity padded batches*:

  - every batch has capacities (atom_cap, bond_cap, angle_cap);
  - real entries are packed at the front, masks mark validity;
  - padded bonds/angles point at slot 0 with zeroed (masked) payloads, so
    segment-sums are unaffected;
  - *sorted-segment layout* (DESIGN.md §1): real bonds are sorted by
    ``bond_center`` and real angles by ``angle_ij``, with CSR row-pointer
    arrays ``bond_offsets`` / ``angle_offsets`` delimiting each segment's
    contiguous run — the invariant the deterministic tiled aggregation
    kernels (``repro.kernels.fused_segment_sum``) rely on.

This is the TPU-native analogue of the paper's "Parallel Computation of
Basis" (Alg. 2): all crystals in the batch are processed by one fused
program, with zero host-side per-sample Python during the step.

This module holds only the *device-side* pytree (and its ShapeDtypeStruct
stand-in); all host-side packing/capacity policy lives in
``repro.batching`` (``BatchCapacities``, ``batch_crystals``,
``CapacityLadder``, the compile cache).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import TYPE_CHECKING

import jax
import jax.numpy as jnp

if TYPE_CHECKING:  # host-side capacity policy, see repro.batching
    from repro.batching.capacity import BatchCapacities


@partial(
    jax.tree_util.register_dataclass,
    data_fields=[
        "atom_z", "atom_mask", "atom_crystal", "frac_coords", "lattice",
        "crystal_mask", "bond_center", "bond_nbr", "bond_image",
        "bond_crystal", "bond_mask", "angle_ij", "angle_ik", "angle_mask",
        "bond_offsets", "angle_offsets",
        "bond_pair", "bond_sign", "und_center", "und_nbr", "und_image",
        "und_crystal", "und_mask",
        "angle_pair", "und_angle_ij", "und_angle_ik", "und_angle_mask",
        "sym_dest", "sym_rep", "sym_offsets",
        "energy", "forces", "stress", "magmoms", "n_atoms_per_crystal",
    ],
    meta_fields=[],
)
@dataclasses.dataclass
class CrystalGraphBatch:
    """A padded batch of B crystals, flattened atoms/bonds/angles."""

    # atoms
    atom_z: jnp.ndarray         # (atom_cap,) int32; 0 for padding
    atom_mask: jnp.ndarray      # (atom_cap,) f32
    atom_crystal: jnp.ndarray   # (atom_cap,) int32 crystal id in [0, B)
    frac_coords: jnp.ndarray    # (atom_cap, 3) f32
    # crystals
    lattice: jnp.ndarray        # (B, 3, 3) f32
    crystal_mask: jnp.ndarray   # (B,) f32
    # bonds (directed; G^a edges)
    bond_center: jnp.ndarray    # (bond_cap,) int32 -> atom index
    bond_nbr: jnp.ndarray       # (bond_cap,) int32 -> atom index
    bond_image: jnp.ndarray     # (bond_cap, 3) f32 periodic image
    bond_crystal: jnp.ndarray   # (bond_cap,) int32
    bond_mask: jnp.ndarray      # (bond_cap,) f32
    # angles (G^b edges): indices into bonds
    angle_ij: jnp.ndarray       # (angle_cap,) int32
    angle_ik: jnp.ndarray       # (angle_cap,) int32
    angle_mask: jnp.ndarray     # (angle_cap,) f32
    # CSR row pointers of the sorted-segment layout (DESIGN.md §1):
    # real bonds [bond_offsets[i], bond_offsets[i+1]) have bond_center == i,
    # real angles [angle_offsets[j], angle_offsets[j+1]) have angle_ij == j;
    # the last entry is the real-entry count, so the padded tail is outside
    # every row.
    bond_offsets: jnp.ndarray   # (atom_cap + 1,) int32
    angle_offsets: jnp.ndarray  # (bond_cap + 1,) int32
    # undirected half-graph store (DESIGN.md §5): each i-j pair is stored
    # ONCE in the und_* arrays; directed views materialize through the
    # mirror maps (vec_dir = bond_sign ⊙ vec_und[bond_pair]).  Padded
    # directed bonds carry (pair=0, sign=0), so their expanded vectors
    # vanish; padded und rows point at atom 0 like padded bonds.
    bond_pair: jnp.ndarray      # (bond_cap,) int32 -> undirected index
    bond_sign: jnp.ndarray      # (bond_cap,) f32 ±1 (0 on padding)
    und_center: jnp.ndarray     # (und_cap,) int32 -> atom index
    und_nbr: jnp.ndarray        # (und_cap,) int32 -> atom index
    und_image: jnp.ndarray      # (und_cap, 3) f32 periodic image
    und_crystal: jnp.ndarray    # (und_cap,) int32
    und_mask: jnp.ndarray       # (und_cap,) f32
    # angle-pair dedup store: each unordered short-bond pair {ij, ik} is
    # stored ONCE (the angle cosine is symmetric under the swap), so
    # angle geometry / Fourier / angle-embed run at Au == angle_cap/2 and
    # expand via a = a_und[angle_pair].  Padded angles carry pair=0 and
    # are re-masked after expansion.
    angle_pair: jnp.ndarray     # (angle_cap,) int32 -> und angle index
    und_angle_ij: jnp.ndarray   # (und_angle_cap,) int32 -> bond index
    und_angle_ik: jnp.ndarray   # (und_angle_cap,) int32 -> bond index
    und_angle_mask: jnp.ndarray  # (und_angle_cap,) f32
    # symmetric-trunk incidence store (DESIGN.md §10): the destination-
    # sorted CSR over Eu rows that the symmetrized bond_conv scatters
    # through.  Each real dedup angle (Au row) appears exactly TWICE —
    # once per undirected bond of its pair — so the real incidence count
    # equals the real directed-angle count (sym_offsets[-1] == real
    # angles).  sym_dest[t] is the Eu row incidence t accumulates into,
    # sym_rep[t] the Au row supplying its message; padded incidences carry
    # (dest=0, rep=0) and sit past sym_offsets[-1], outside every CSR row.
    sym_dest: jnp.ndarray       # (angle_cap,) int32 -> und bond index
    sym_rep: jnp.ndarray        # (angle_cap,) int32 -> und angle index
    sym_offsets: jnp.ndarray    # (und_cap + 1,) int32 CSR row pointers
    # labels
    energy: jnp.ndarray         # (B,) f32 total energy (eV)
    forces: jnp.ndarray         # (atom_cap, 3) f32
    stress: jnp.ndarray         # (B, 3, 3) f32
    magmoms: jnp.ndarray        # (atom_cap,) f32
    n_atoms_per_crystal: jnp.ndarray  # (B,) f32

    @property
    def num_crystals(self) -> int:
        return self.lattice.shape[0]

    @property
    def atom_cap(self) -> int:
        return self.atom_z.shape[0]

    @property
    def bond_cap(self) -> int:
        return self.bond_center.shape[0]

    @property
    def angle_cap(self) -> int:
        return self.angle_ij.shape[0]

    @property
    def und_cap(self) -> int:
        return self.und_center.shape[0]

    @property
    def und_angle_cap(self) -> int:
        return self.und_angle_ij.shape[0]


def batch_input_specs(
    batch_size: int, caps: "BatchCapacities", dtype=jnp.float32
) -> CrystalGraphBatch:
    """ShapeDtypeStruct stand-in batch for dry-run lowering (no allocation)."""
    s = jax.ShapeDtypeStruct
    f, i = dtype, jnp.int32
    return CrystalGraphBatch(
        atom_z=s((caps.atoms,), i),
        atom_mask=s((caps.atoms,), f),
        atom_crystal=s((caps.atoms,), i),
        frac_coords=s((caps.atoms, 3), f),
        lattice=s((batch_size, 3, 3), f),
        crystal_mask=s((batch_size,), f),
        bond_center=s((caps.bonds,), i),
        bond_nbr=s((caps.bonds,), i),
        bond_image=s((caps.bonds, 3), f),
        bond_crystal=s((caps.bonds,), i),
        bond_mask=s((caps.bonds,), f),
        angle_ij=s((caps.angles,), i),
        angle_ik=s((caps.angles,), i),
        angle_mask=s((caps.angles,), f),
        bond_offsets=s((caps.atoms + 1,), i),
        angle_offsets=s((caps.bonds + 1,), i),
        bond_pair=s((caps.bonds,), i),
        bond_sign=s((caps.bonds,), f),
        und_center=s((caps.und_cap,), i),
        und_nbr=s((caps.und_cap,), i),
        und_image=s((caps.und_cap, 3), f),
        und_crystal=s((caps.und_cap,), i),
        und_mask=s((caps.und_cap,), f),
        angle_pair=s((caps.angles,), i),
        und_angle_ij=s((caps.und_angle_cap,), i),
        und_angle_ik=s((caps.und_angle_cap,), i),
        und_angle_mask=s((caps.und_angle_cap,), f),
        sym_dest=s((caps.angles,), i),
        sym_rep=s((caps.angles,), i),
        sym_offsets=s((caps.und_cap + 1,), i),
        energy=s((batch_size,), f),
        forces=s((caps.atoms, 3), f),
        stress=s((batch_size, 3, 3), f),
        magmoms=s((caps.atoms,), f),
        n_atoms_per_crystal=s((batch_size,), f),
    )
