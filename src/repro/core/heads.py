"""Output heads (paper §III-B, Fig. 2c/2d).

Energy / magmom heads are shared by both readout modes. The *direct*
Force/Stress heads (FastCHGNet C1) replace the reference autodiff readout:

  Force head (Eq. 7):  n_ij = MLP(e_ij) in R;  F_i = sum_j n_ij * x_hat_ij
      — n_ij must be a SCALAR per bond for the rotation-equivariance proof
      (Eq. 8) to hold: R sum n x = sum n (R x).

  Stress head (Eq. 9): sigma = sum_i (scale * MLP9(v_i)) ⊙ N(L),
      N(L) = sum_{a,b} L_a/|L_a| ⊗ L_b/|L_b|  (3x3 lattice-normal matrix).

Precision (DESIGN.md §4): head MLPs run at the feature (compute) dtype;
the per-crystal energy/stress reductions are pinned to f32 — a crystal's
site-energy sum is exactly the kind of long low-magnitude accumulation
bf16 destroys — so the heads return f32 per-crystal quantities and
``chgnet_apply`` casts everything to the policy's ``output_dtype``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .graph import CrystalGraphBatch
from .interaction import _glorot, linear_apply, linear_init, segment_aggregate


def mlp_init(key, dims, dtype=jnp.float32):
    keys = jax.random.split(key, len(dims) - 1)
    return [linear_init(k, a, b, dtype) for k, a, b in zip(keys, dims[:-1], dims[1:])]


def mlp_apply(layers, x):
    for i, p in enumerate(layers):
        x = linear_apply(p, x)
        if i < len(layers) - 1:
            x = jax.nn.silu(x)
    return x


# ------------------------------ energy ------------------------------------

def energy_head_init(key, dim=64, dtype=jnp.float32):
    return {"mlp": mlp_init(key, (dim, dim, dim, 1), dtype)}


def energy_head_apply(p, graph: CrystalGraphBatch, v):
    """Per-site energies summed per crystal -> (B,) total energies [eV].

    The per-crystal reduction is accum-pinned to f32 (DESIGN.md §4)."""
    site_e = mlp_apply(p["mlp"], v)[..., 0].astype(jnp.float32) \
        * graph.atom_mask
    return jax.ops.segment_sum(
        site_e, graph.atom_crystal, num_segments=graph.num_crystals
    )


# ------------------------------ magmom ------------------------------------

def magmom_head_init(key, dim=64, dtype=jnp.float32):
    return {"mlp": mlp_init(key, (dim, dim, 1), dtype)}


def magmom_head_apply(p, graph: CrystalGraphBatch, v):
    out = jnp.abs(mlp_apply(p["mlp"], v)[..., 0])
    return out * graph.atom_mask.astype(out.dtype)


# ------------------------------ force head --------------------------------

def force_head_init(key, dim=64, dtype=jnp.float32):
    return {"mlp": mlp_init(key, (dim, dim, 1), dtype)}


def force_head_apply(p, graph: CrystalGraphBatch, e, bond_vec, bond_dist,
                     *, agg_impl: str = "scatter",
                     conv_impl: str = "unfused"):
    """Eq. 7: F_i = sum_j n_ij * x_hat_ij (rotation equivariant).

    e: (bond_cap, D) final bond features (invariant); bond_vec/bond_dist
    from compute_geometry.  The per-atom reduction routes through the same
    aggregation engine as the convolutions (DESIGN.md §2), so the sorted /
    pallas layouts accelerate the force readout too.  With
    ``conv_impl="fused"`` the whole readout (scalar MLP -> x_hat weighting
    -> reduce) is one megakernel over the sorted CSR rows (DESIGN.md §3)
    and ``n_ij`` never reaches HBM.
    """
    # x_hat is derived from f32 geometry; cast it to the bond-feature
    # (compute) dtype at this boundary so the contrib product and the
    # reduction operands share one dtype (DESIGN.md §4)
    x_hat = (bond_vec / (bond_dist[..., None] + 1e-12)).astype(e.dtype)
    if conv_impl == "fused":
        from repro.kernels import ops as kops  # lazy: avoid import cycle

        l0, l1 = p["mlp"]  # force head is fixed at (dim -> dim -> 1)
        out = kops.fused_force_readout(
            e, x_hat, l0["w"].astype(e.dtype), l0["b"].astype(e.dtype),
            l1["w"].astype(e.dtype), l1["b"].astype(e.dtype),
            graph.bond_center, graph.bond_offsets, graph.atom_cap,
        )
        return out * graph.atom_mask[..., None].astype(out.dtype)
    n_ij = mlp_apply(p["mlp"], e)[..., 0]  # (Nb,); masked by the aggregate
    contrib = n_ij[..., None] * x_hat  # (Nb, 3)
    out = segment_aggregate(
        contrib, graph.bond_center, graph.atom_cap, graph.bond_mask,
        agg_impl, offsets=graph.bond_offsets,
    )
    return out * graph.atom_mask[..., None].astype(out.dtype)


# ------------------------------ stress head -------------------------------

def stress_head_init(key, dim=64, scale=0.1, dtype=jnp.float32):
    return {"mlp": mlp_init(key, (dim, dim, 9), dtype),
            "scale": jnp.asarray(scale, dtype)}


def stress_head_apply(p, graph: CrystalGraphBatch, v):
    """Eq. 9. Returns (B, 3, 3) stresses [GPa].

    Lattice normals stay f32 (geometry); the per-crystal reduction is
    accum-pinned to f32 (DESIGN.md §4)."""
    lat = graph.lattice  # (B, 3, 3) rows are lattice vectors
    l_hat = lat / (jnp.linalg.norm(lat, axis=-1, keepdims=True) + 1e-12)
    # N(L)_{mn} = sum_{a,b} l_hat[a, m] * l_hat[b, n] = (sum_a l_hat_a) ⊗ (..)
    s = jnp.sum(l_hat, axis=1)  # (B, 3)
    normal = jnp.einsum("bm,bn->bmn", s, s)
    per_atom = mlp_apply(p["mlp"], v).astype(jnp.float32) \
        * graph.atom_mask[..., None]  # (A, 9)
    per_crystal = jax.ops.segment_sum(
        per_atom, graph.atom_crystal, num_segments=graph.num_crystals
    ).reshape(-1, 3, 3)
    return p["scale"].astype(jnp.float32) * per_crystal * normal
