"""Output heads (paper §III-B, Fig. 2c/2d).

Energy / magmom heads are shared by both readout modes. The *direct*
Force/Stress heads (FastCHGNet C1) replace the reference autodiff readout:

  Force head (Eq. 7):  n_ij = MLP(e_ij) in R;  F_i = sum_j n_ij * x_hat_ij
      — n_ij must be a SCALAR per bond for the rotation-equivariance proof
      (Eq. 8) to hold: R sum n x = sum n (R x).

  Stress head (Eq. 9): sigma = sum_i (scale * MLP9(v_i)) ⊙ N(L),
      N(L) = sum_{a,b} L_a/|L_a| ⊗ L_b/|L_b|  (3x3 lattice-normal matrix).

  Bond-virial stress (``stress_mode="bond_virial"``, DESIGN.md §7): the
  per-bond forces of the force head assembled into the physical virial
      sigma = (1/2V) sum_ij n_ij d_ij x_hat_ij ⊗ x_hat_ij  [-> GPa],
  i.e. sigma = (1/2V) sum_ij r_ij ⊗ f_ij with f_ij = n_ij x_hat_ij — no
  stress parameters at all; forces and stress share one set of per-bond
  scalars, so the head is exact on any pair potential the forces fit
  (tests/test_virial.py).  With ``conv_impl="fused"`` the 3x3 accumulation
  runs inside the force-readout megakernel epilogue (single launch).

Precision (DESIGN.md §4): head MLPs run at the feature (compute) dtype;
the per-crystal energy/stress reductions are pinned to f32 — a crystal's
site-energy sum is exactly the kind of long low-magnitude accumulation
bf16 destroys — so the heads return f32 per-crystal quantities and
``chgnet_apply`` casts everything to the policy's ``output_dtype``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .graph import CrystalGraphBatch
from .interaction import _glorot, linear_apply, linear_init, segment_aggregate

EV_A3_TO_GPA = 160.21766  # eV/A^3 -> GPa (re-exported by core.chgnet)

# one epsilon for every unit-vector normalization in the model: heads and
# kernel wrappers must agree bit-for-bit or the fused/unfused stress tiers
# drift apart (DESIGN.md §7 tolerance budget)
_UNIT_EPS = 1e-12


def bond_unit_vectors(bond_vec, bond_dist, dtype=None):
    """x_hat = vec / (dist + eps), the ONE shared normalization.

    Geometry arrives f32; ``dtype`` (usually the bond-feature compute
    dtype) sets the cast boundary AFTER the f32 division, so every caller
    — unfused heads, kernel wrappers, oracles — sees identical values.
    """
    x_hat = bond_vec / (bond_dist[..., None] + _UNIT_EPS)
    return x_hat if dtype is None else x_hat.astype(dtype)


def mlp_init(key, dims, dtype=jnp.float32):
    keys = jax.random.split(key, len(dims) - 1)
    return [linear_init(k, a, b, dtype) for k, a, b in zip(keys, dims[:-1], dims[1:])]


def mlp_apply(layers, x):
    for i, p in enumerate(layers):
        x = linear_apply(p, x)
        if i < len(layers) - 1:
            x = jax.nn.silu(x)
    return x


# ------------------------------ energy ------------------------------------

def energy_head_init(key, dim=64, dtype=jnp.float32):
    return {"mlp": mlp_init(key, (dim, dim, dim, 1), dtype)}


def energy_head_apply(p, graph: CrystalGraphBatch, v):
    """Per-site energies summed per crystal -> (B,) total energies [eV].

    The per-crystal reduction is accum-pinned to f32 (DESIGN.md §4)."""
    site_e = mlp_apply(p["mlp"], v)[..., 0].astype(jnp.float32) \
        * graph.atom_mask
    return jax.ops.segment_sum(
        site_e, graph.atom_crystal, num_segments=graph.num_crystals
    )


# ------------------------------ magmom ------------------------------------

def magmom_head_init(key, dim=64, dtype=jnp.float32):
    return {"mlp": mlp_init(key, (dim, dim, 1), dtype)}


def magmom_head_apply(p, graph: CrystalGraphBatch, v):
    out = jnp.abs(mlp_apply(p["mlp"], v)[..., 0])
    return out * graph.atom_mask.astype(out.dtype)


# ------------------------------ force head --------------------------------

def force_head_init(key, dim=64, dtype=jnp.float32):
    return {"mlp": mlp_init(key, (dim, dim, 1), dtype)}


def force_head_apply(p, graph: CrystalGraphBatch, e, bond_vec, bond_dist,
                     *, agg_impl: str = "scatter",
                     conv_impl: str = "unfused",
                     table_residency: str = "auto"):
    """Eq. 7: F_i = sum_j n_ij * x_hat_ij (rotation equivariant).

    e: (bond_cap, D) final bond features (invariant); bond_vec/bond_dist
    from compute_geometry.  The per-atom reduction routes through the same
    aggregation engine as the convolutions (DESIGN.md §2), so the sorted /
    pallas layouts accelerate the force readout too.  With
    ``conv_impl="fused"`` the whole readout (scalar MLP -> x_hat weighting
    -> reduce) is one megakernel over the sorted CSR rows (DESIGN.md §3)
    and ``n_ij`` never reaches HBM.  ``table_residency`` selects the
    kernels' operand-residency tier (DESIGN.md §9).
    """
    # x_hat is derived from f32 geometry; cast it to the bond-feature
    # (compute) dtype at this boundary so the contrib product and the
    # reduction operands share one dtype (DESIGN.md §4)
    x_hat = bond_unit_vectors(bond_vec, bond_dist, e.dtype)
    if conv_impl == "fused":
        from repro.kernels import ops as kops  # lazy: avoid import cycle

        l0, l1 = p["mlp"]  # force head is fixed at (dim -> dim -> 1)
        out = kops.fused_force_readout(
            e, x_hat, l0["w"].astype(e.dtype), l0["b"].astype(e.dtype),
            l1["w"].astype(e.dtype), l1["b"].astype(e.dtype),
            graph.bond_center, graph.bond_offsets, graph.atom_cap,
            table_residency=table_residency,
        )
        return out * graph.atom_mask[..., None].astype(out.dtype)
    n_ij = mlp_apply(p["mlp"], e)[..., 0]  # (Nb,); masked by the aggregate
    contrib = n_ij[..., None] * x_hat  # (Nb, 3)
    out = segment_aggregate(
        contrib, graph.bond_center, graph.atom_cap, graph.bond_mask,
        agg_impl, offsets=graph.bond_offsets,
        table_residency=table_residency,
    )
    return out * graph.atom_mask[..., None].astype(out.dtype)


# ------------------------------ stress head -------------------------------

def stress_head_init(key, dim=64, scale=0.1, dtype=jnp.float32):
    return {"mlp": mlp_init(key, (dim, dim, 9), dtype),
            "scale": jnp.asarray(scale, dtype)}


def stress_head_apply(p, graph: CrystalGraphBatch, v):
    """Eq. 9. Returns (B, 3, 3) stresses [GPa].

    Lattice normals stay f32 (geometry); the per-crystal reduction is
    accum-pinned to f32 (DESIGN.md §4)."""
    lat = graph.lattice  # (B, 3, 3) rows are lattice vectors
    l_hat = lat / (jnp.linalg.norm(lat, axis=-1, keepdims=True) + 1e-12)
    # N(L)_{mn} = sum_{a,b} l_hat[a, m] * l_hat[b, n] = (sum_a l_hat_a) ⊗ (..)
    s = jnp.sum(l_hat, axis=1)  # (B, 3)
    normal = jnp.einsum("bm,bn->bmn", s, s)
    per_atom = mlp_apply(p["mlp"], v).astype(jnp.float32) \
        * graph.atom_mask[..., None]  # (A, 9)
    per_crystal = jax.ops.segment_sum(
        per_atom, graph.atom_crystal, num_segments=graph.num_crystals
    ).reshape(-1, 3, 3)
    return p["scale"].astype(jnp.float32) * per_crystal * normal


# ------------------------- bond-virial stress ------------------------------

def _per_crystal_aggregate(values, ids, num_crystals, mask, agg_impl):
    """Bond/pair -> crystal reduction through the §2 aggregation engine.

    ``ids`` are sorted over the real prefix (crystals pack sequentially,
    bonds sort by center — repro.batching.pack), so the "sorted" tier
    applies directly.  ``"pallas"`` maps to "sorted": the CSR kernel wants
    per-row offsets, which exist for atoms/bonds but not for the (tiny,
    B-row) crystal axis — a dedicated launch would cost more than the
    reduction (DESIGN.md §7).
    """
    impl = "sorted" if agg_impl == "pallas" else agg_impl
    return segment_aggregate(values, ids, num_crystals, mask, impl)


def _virial_raw_to_gpa(raw, graph: CrystalGraphBatch):
    """(B, 3, 3) accumulated sum n d x_hat⊗x_hat  ->  stress [GPa].

    sigma = (1/2V) * raw * EV_A3_TO_GPA, volume from the lattice
    determinant; padded crystal slots (identity lattices) mask to zero.
    """
    vol = jnp.abs(jnp.linalg.det(graph.lattice.astype(jnp.float32)))
    scale = EV_A3_TO_GPA / (2.0 * vol + _UNIT_EPS) * graph.crystal_mask
    return raw.astype(jnp.float32) * scale[:, None, None]


def force_virial_head_apply(p, graph: CrystalGraphBatch, e, bond_vec,
                            bond_dist, *, vec_und=None, dist_und=None,
                            agg_impl: str = "scatter",
                            conv_impl: str = "unfused",
                            bond_store: str = "directed",
                            table_residency: str = "auto"):
    """Single-pass force + bond-virial stress readout (DESIGN.md §7).

    Returns ``(forces (A, 3), stress (B, 3, 3) [GPa, f32])``.  Both come
    from ONE set of per-bond scalars n_ij = MLP(e_ij):

        F_i   = sum_j n_ij x_hat_ij                          (Eq. 7)
        sigma = (1/2V) sum_ij n_ij d_ij x_hat_ij ⊗ x_hat_ij  [* GPa]

    (n d x_hat⊗x_hat == (n/d) vec⊗vec, the per-bond virial r_ij ⊗ f_ij).
    The stress carries NO parameters of its own — it is determined by the
    force field, so it is symmetric, translation invariant, and rotates as
    sigma -> R sigma R^T for free (tests/test_virial.py).

    conv_impl="fused": one megakernel launch computes both outputs — the
    (B, 3, 3) partials accumulate in the force-readout epilogue while
    n_ij and x_hat are still in VMEM; the (E, 3, 3) outer-product tensor
    never materializes (kernels/fused_message_passing.py).

    Unfused reference: the same math through ``segment_aggregate``.  With
    ``bond_store="undirected"`` (DESIGN.md §5) the outer products are
    computed ONCE per undirected pair from ``vec_und``/``dist_und``
    (x_hat⊗x_hat is bond_sign-invariant): the directed n d weights reduce
    onto Eu rows through the ``bond_pair`` mirror map first, so the Eu
    store pays half the geometry reads here too.
    """
    x_hat = bond_unit_vectors(bond_vec, bond_dist, e.dtype)
    if conv_impl == "fused":
        from repro.kernels import ops as kops  # lazy: avoid import cycle

        l0, l1 = p["mlp"]  # force head is fixed at (dim -> dim -> 1)
        forces, raw = kops.fused_force_virial_readout(
            e, x_hat, bond_dist, l0["w"].astype(e.dtype),
            l0["b"].astype(e.dtype), l1["w"].astype(e.dtype),
            l1["b"].astype(e.dtype), graph.bond_center, graph.bond_crystal,
            graph.bond_offsets, graph.atom_cap, graph.num_crystals,
            table_residency=table_residency,
        )
        forces = forces * graph.atom_mask[..., None].astype(forces.dtype)
        return forces, _virial_raw_to_gpa(raw, graph)

    n_ij = mlp_apply(p["mlp"], e)[..., 0]  # (Nb,); masked by the aggregate
    contrib = n_ij[..., None] * x_hat  # (Nb, 3)
    forces = segment_aggregate(
        contrib, graph.bond_center, graph.atom_cap, graph.bond_mask,
        agg_impl, offsets=graph.bond_offsets,
        table_residency=table_residency,
    )
    forces = forces * graph.atom_mask[..., None].astype(forces.dtype)
    # per-bond virial weight w = n d (f32 accumulation from here on, §4)
    w = n_ij.astype(jnp.float32) * bond_dist.astype(jnp.float32) \
        * graph.bond_mask
    if bond_store == "undirected":
        # mirror-map bypass: x_hat⊗x_hat is sign-invariant, so reduce the
        # directed weights onto Eu rows (scatter: bond_pair is not sorted)
        # and build the outer products once per pair from und geometry
        w_u = jax.ops.segment_sum(
            w, graph.bond_pair, num_segments=graph.und_cap)
        xh_u = bond_unit_vectors(vec_und.astype(jnp.float32),
                                 dist_und.astype(jnp.float32))
        outer = (xh_u[:, :, None] * xh_u[:, None, :]).reshape(-1, 9)
        raw = _per_crystal_aggregate(
            w_u[:, None] * outer, graph.und_crystal, graph.num_crystals,
            graph.und_mask, agg_impl)
    else:
        xh32 = x_hat.astype(jnp.float32)
        outer = (xh32[:, :, None] * xh32[:, None, :]).reshape(-1, 9)
        raw = _per_crystal_aggregate(
            w[:, None] * outer, graph.bond_crystal, graph.num_crystals,
            graph.bond_mask, agg_impl)
    return forces, _virial_raw_to_gpa(raw.reshape(-1, 3, 3), graph)
