"""Radial / angular basis expansion (paper §II-B (2), §III-C).

Contains both the *reference* formulations and the FastCHGNet-optimized
ones so benchmarks can measure each optimization separately:

  - ``envelope_reference``  : Eq. 12 (4 independent pow() terms)
  - ``envelope_factored``   : Eq. 13, with the paper's sign typo fixed and a
                              Horner evaluation (single pow + 2 fma)
  - ``smooth_rbf``          : trainable-frequency smooth radial Bessel basis
  - ``fourier_basis``       : angle Fourier expansion [DC, cos(n t), sin(n t)]
  - ``compute_geometry``    : batched (Alg. 2) bond vectors / distances /
                              angle cosines from the padded graph, fully
                              differentiable w.r.t. positions and strain.
  - ``compute_geometry_undirected``: the same geometry on the undirected
                              half-graph store (DESIGN.md §5) — vectors
                              computed once per pair, directed views via
                              the ``bond_pair``/``bond_sign`` mirror maps.

The Pallas-fused versions live in ``repro.kernels`` and are numerically
checked against these in tests.

Precision (DESIGN.md §4): geometry and every basis expansion — including
the polynomial envelopes — are pinned to the accumulation dtype (f32)
regardless of ``CHGNetConfig.precision``; xi^p amplifies rounding and the
trainable ``rbf_freqs`` must not round-trip through bf16.  The model
casts basis *outputs* to the compute dtype at the embedding boundary
(``chgnet._trunk``), never the inputs of these functions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .graph import CrystalGraphBatch


# ---------------------------------------------------------------------------
# Polynomial envelope u(r): smooth cutoff, u(r_cut) = u'(r_cut) = u''(r_cut)=0
# ---------------------------------------------------------------------------

def envelope_reference(xi: jnp.ndarray, p: int = 8) -> jnp.ndarray:
    """Eq. 12, four separate power terms (redundant compute).

    NOTE paper typo: Eq. 12 prints the last coefficient as -p(p+2)/2, with
    which u(1) = 1 - (p+2)/2 != 0 — the envelope would not vanish at the
    cutoff. The correct smooth-cutoff coefficients (DimeNet, and CHGNet's
    actual implementation) are a=-(p+1)(p+2)/2, b=p(p+2), c=-p(p+1)/2,
    giving u(1) = u'(1) = 0. We implement the correct form.
    """
    a = -(p + 1) * (p + 2) / 2.0
    b = float(p * (p + 2))
    c = -p * (p + 1) / 2.0
    return 1.0 + a * xi**p + b * xi ** (p + 1) + c * xi ** (p + 2)


def envelope_factored(xi: jnp.ndarray, p: int = 8) -> jnp.ndarray:
    """Eq. 13 (redundancy bypass, C5): common terms factored out and the
    bracket evaluated in Horner form — ONE pow() and two fmas instead of
    three independent pow() calls:

        u = 1 - xi^p/2 * [ (p+1)(p+2) - 2p(p+2) xi + p(p+1) xi^2 ]

    Property-tested equal to ``envelope_reference`` in tests/test_basis.py
    (the paper's printed Eq. 13 additionally carries Eq. 12's coefficient
    typo and a sign typo; see envelope_reference).
    """
    inner = (p + 1.0) * (p + 2.0) + xi * (
        -2.0 * p * (p + 2.0) + xi * (p * (p + 1.0)))
    return 1.0 - 0.5 * xi**p * inner


# ---------------------------------------------------------------------------
# Smooth radial Bessel function basis (sRBF), DimeNet-style, trainable freqs
# ---------------------------------------------------------------------------

def rbf_frequencies(num_basis: int) -> jnp.ndarray:
    """Initial (trainable) frequencies n*pi, n = 1..num_basis."""
    return jnp.arange(1, num_basis + 1, dtype=jnp.float32) * jnp.pi


def smooth_rbf(
    r: jnp.ndarray,
    freqs: jnp.ndarray,
    r_cut: float,
    p: int = 8,
    *,
    envelope=envelope_factored,
) -> jnp.ndarray:
    """sRBF(r)_n = sqrt(2/rc) * sin(f_n * r/rc) / r * u(r/rc).

    r: (...,) distances;  freqs: (K,) trainable;  returns (..., K).
    Safe at r ~ 0 (padded entries): sin(f x)/r -> finite via masked divide.
    """
    # accum-pinned (DESIGN.md §4): envelope + trainable freqs stay f32
    r = r.astype(jnp.float32)
    freqs = freqs.astype(jnp.float32)
    xi = r / r_cut
    u = envelope(xi, p)
    r_safe = jnp.where(r > 1e-8, r, 1.0)
    phases = xi[..., None] * freqs  # (..., K)
    val = jnp.sqrt(2.0 / r_cut) * jnp.sin(phases) / r_safe[..., None]
    return val * u[..., None]


# ---------------------------------------------------------------------------
# Fourier expansion of the bond angle
# ---------------------------------------------------------------------------

def fourier_basis(theta: jnp.ndarray, num_basis: int = 31) -> jnp.ndarray:
    """FT(theta) -> (..., num_basis): [1/sqrt(2), cos(n t), sin(n t)]/sqrt(pi).

    num_basis = 2*L + 1 (DC + L cos + L sin). Paper sets num_basis = 31.
    """
    assert num_basis % 2 == 1, "fourier num_basis must be odd (DC + pairs)"
    theta = theta.astype(jnp.float32)  # accum-pinned (DESIGN.md §4)
    harmonics = (num_basis - 1) // 2
    n = jnp.arange(1, harmonics + 1, dtype=theta.dtype)
    ang = theta[..., None] * n  # (..., L)
    dc = jnp.full(theta.shape + (1,), 1.0 / jnp.sqrt(2.0), dtype=theta.dtype)
    feats = jnp.concatenate([dc, jnp.cos(ang), jnp.sin(ang)], axis=-1)
    return feats / jnp.sqrt(jnp.pi).astype(theta.dtype)


# ---------------------------------------------------------------------------
# Batched geometry (paper Alg. 2): one fused computation for the whole batch
# ---------------------------------------------------------------------------

def _cart_positions(graph: CrystalGraphBatch, displacement, strain):
    """Strained lattice + Cartesian positions shared by both bond stores."""
    lattice = graph.lattice
    if strain is not None:
        eye = jnp.eye(3, dtype=lattice.dtype)
        lattice = jnp.einsum("bij,bjk->bik", lattice, eye + strain)
    # Cartesian positions: (atom_cap, 3) — one batched matmul (Alg. 2 l.12)
    cart = jnp.einsum(
        "ai,aij->aj", graph.frac_coords, lattice[graph.atom_crystal]
    )
    if displacement is not None:
        cart = cart + displacement
    return cart, lattice


def _bond_vectors(cart, lattice, center, nbr, image, crystal):
    """r_ij = r_j + image @ L - r_i and its length, for any bond store."""
    shift = jnp.einsum("bi,bij->bj", image, lattice[crystal])
    vec = cart[nbr] + shift - cart[center]
    dist = jnp.sqrt(jnp.sum(vec * vec, axis=-1) + 1e-16)
    return vec, dist


def _angle_cosines(vec, dist, idx_ij, idx_ik):
    """Angle cosines between the bonds selected by two index arrays.

    The formula is *bitwise* symmetric under (idx_ij, idx_ik) swap —
    elementwise products commute and the component sum runs in the same
    order — which is what makes the angle-pair dedup store exact: the
    value at a dedup row equals the value at both directed angle rows it
    represents.
    """
    v_ij = vec[idx_ij]
    v_ik = vec[idx_ik]
    d_ij = dist[idx_ij]
    d_ik = dist[idx_ik]
    cos_t = jnp.sum(v_ij * v_ik, axis=-1) / (d_ij * d_ik + 1e-12)
    cos_t = jnp.clip(cos_t, -1.0 + 1e-7, 1.0 - 1e-7)
    return cos_t, jnp.arccos(cos_t)


def _angle_geometry(graph: CrystalGraphBatch, vec, dist):
    """Angle cosines between directed bonds ij / ik sharing a center."""
    return _angle_cosines(vec, dist, graph.angle_ij, graph.angle_ik)


def compute_geometry(
    graph: CrystalGraphBatch,
    *,
    displacement: jnp.ndarray | None = None,
    strain: jnp.ndarray | None = None,
):
    """Compute bond vectors, distances and angle cosines for a padded batch.

    displacement: (atom_cap, 3) added to Cartesian coordinates — zero at the
        evaluation point; forces are -dE/d(displacement).
    strain: (B, 3, 3) symmetric strain eps — lattice is deformed as
        L' = L @ (I + eps); stress is (1/V) dE/d(eps).

    Returns (bond_vec (Nb,3), bond_dist (Nb,), cos_theta (Na,), theta (Na,)).
    """
    cart, lattice = _cart_positions(graph, displacement, strain)
    # bond vector r_ij = r_j + image @ L - r_i  (Alg. 2 l.13-14, batched)
    vec, dist = _bond_vectors(
        cart, lattice, graph.bond_center, graph.bond_nbr, graph.bond_image,
        graph.bond_crystal,
    )
    cos_t, theta = _angle_geometry(graph, vec, dist)
    return vec, dist, cos_t, theta


def compute_geometry_undirected(
    graph: CrystalGraphBatch,
    *,
    displacement: jnp.ndarray | None = None,
    strain: jnp.ndarray | None = None,
    angle_rows: str = "directed",
):
    """Geometry on the undirected half-graph store (DESIGN.md §5).

    Bond vectors/distances are computed ONCE per undirected pair — halving
    the dominant edge-level geometry work in the forward AND in every
    derivative pass through it (forces/stress differentiate this) — and
    directed views materialize through the mirror maps:

        vec_dir  = bond_sign ⊙ vec_und[bond_pair]    (exact mirror)
        dist_dir = dist_und[bond_pair]               (length is shared)

    Padded directed bonds carry sign 0, so their expanded vectors vanish
    like the directed store's padded slot-0 bonds.

    ``angle_rows`` selects where the angle cosines are evaluated:
      - ``"directed"``: at the full ordered angle list (``angle_ij`` /
        ``angle_ik``), the reference layout;
      - ``"undirected"``: at the angle-pair dedup store
        (``und_angle_ij`` / ``und_angle_ik``, Au == Na/2 rows) — the
        cosine is bitwise swap-symmetric (see ``_angle_cosines``), so
        expanding through ``graph.angle_pair`` reproduces the directed
        values exactly while halving the angle-level geometry, Fourier,
        and embedding work.  The §10 symmetric trunk
        (``bond_features="undirected"``) consumes these Au rows
        directly — no ``angle_pair`` expansion ever happens there; the
        Fourier basis, the angle embedding, and every block's
        bond/angle GEMM stay at the halved row count.

    Returns (vec_und (Nu,3), dist_und (Nu,), vec (Nb,3), dist (Nb,),
    cos_theta, theta) — the angle outputs at Na or Au rows per
    ``angle_rows``.
    """
    cart, lattice = _cart_positions(graph, displacement, strain)
    vec_und, dist_und = _bond_vectors(
        cart, lattice, graph.und_center, graph.und_nbr, graph.und_image,
        graph.und_crystal,
    )
    vec = graph.bond_sign[..., None] * vec_und[graph.bond_pair]
    dist = dist_und[graph.bond_pair]
    if angle_rows == "undirected":
        cos_t, theta = _angle_cosines(
            vec, dist, graph.und_angle_ij, graph.und_angle_ik)
    elif angle_rows == "directed":
        cos_t, theta = _angle_geometry(graph, vec, dist)
    else:
        raise ValueError(f"unknown angle_rows {angle_rows!r}")
    return vec_und, dist_und, vec, dist, cos_t, theta
