"""Interaction block: GatedMLP, AtomConv, BondConv, AngleUpdate.

Implements BOTH block variants (paper Eq. 10 vs Eq. 11):

  - ``reference``: BondConv consumes v^{t+1}; AngleUpdate consumes v^{t+1}
    and e^{t+1} (sequential dependency chain, as in CHGNet v0.3.0).
  - ``fast``: dependency elimination (FastCHGNet C2) — BondConv and
    AngleUpdate consume the layer-t features, so the three updates are
    data-independent and XLA can schedule them concurrently.

GatedMLP phi(x) = sigmoid(LN(x@Wg+bg)) * silu(LN(x@Wc+bc))   (paper §II-B)
with three implementations:
  - ``ref``    : two separate GEMMs + two LNs (reference graph)
  - ``packed`` : one GEMM against [Wc ‖ Wg] (+ single fused epilogue),
                 the Fig. 3 packing in pure jnp — what XLA sees on TPU
  - ``pallas`` : the hand-fused Pallas kernel (repro.kernels.fused_gated_mlp)

GatedMLP parameters are STORED pre-packed (``w = [Wc ‖ Wg]``,
``b``/``ln_scale``/``ln_bias`` = ``[core ‖ gate]``): the Fig. 3(a) concat
happens once at init (or once at checkpoint load, see
``pack_gated_mlp_params``), never inside a jitted step.  ``impl="ref"``
slices the halves back out; slicing is free under XLA, re-concatenating
per step was not.

On top of the per-call-site impl choices, ``conv_impl="fused"`` (DESIGN.md
§3) replaces the whole gather -> GatedMLP -> envelope -> reduce message
path of atom_conv / bond_conv with one Pallas megakernel over the sorted
CSR rows (requires DESIGN.md §1), so the (E, 3D)/(A_ang, 4D) concats and
(E, D) messages never reach HBM and are never saved for the backward.

``bond_store="undirected"`` (DESIGN.md §5) hands the convs e^a/e^b at the
undirected capacity Eu ~ E/2; they are expanded through the batch's
``bond_pair`` mirror map — an explicit gather in the unfused path, the
mirror-indirected operand class inside the megakernels when fused.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .graph import CrystalGraphBatch


def _glorot(key, shape, dtype=jnp.float32):
    fan_in, fan_out = shape[0], shape[-1]
    scale = jnp.sqrt(2.0 / (fan_in + fan_out))
    return jax.random.normal(key, shape, dtype) * scale


def linear_init(key, d_in, d_out, dtype=jnp.float32):
    return {
        "w": _glorot(key, (d_in, d_out), dtype),
        "b": jnp.zeros((d_out,), dtype),
    }


def dot_accum(x, w, accum_dtype=jnp.float32):
    """x @ w with MXU accumulation pinned to ``accum_dtype`` and the result
    cast back to x's dtype (DESIGN.md §4 kernel-accumulator rule).  For f32
    operands this is exactly ``x @ w``."""
    out = jax.lax.dot_general(
        x, w, (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=accum_dtype)
    return out.astype(x.dtype)


def linear_apply(p, x):
    # cast-to-compute view: params are stored in param_dtype and cast to
    # the activation dtype at the use site (free under f32, DESIGN.md §4)
    return dot_accum(x, p["w"].astype(x.dtype)) + p["b"].astype(x.dtype)


def layer_norm(x, scale, bias, eps=1e-5):
    # statistics pinned to accum (f32): bf16 mean/var would lose ~2 digits
    # on the D-length reductions (DESIGN.md §4)
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# GatedMLP
# ---------------------------------------------------------------------------

def gated_mlp_init(key, d_in, d_out, dtype=jnp.float32):
    """Packed storage layout: the Fig. 3(a) concat happens HERE, once.

    Each half is glorot-initialized with its own fan-out (identical
    statistics to the legacy separate-weight layout) and packed so no step
    function ever re-concatenates parameters.
    """
    kc, kg = jax.random.split(key)
    return {
        "w": jnp.concatenate(
            [_glorot(kc, (d_in, d_out), dtype),
             _glorot(kg, (d_in, d_out), dtype)], axis=1),
        "b": jnp.zeros((2 * d_out,), dtype),
        "ln_scale": jnp.ones((2 * d_out,), dtype),
        "ln_bias": jnp.zeros((2 * d_out,), dtype),
    }


_LEGACY_GATED_KEYS = frozenset(
    ("wc", "bc", "wg", "bg",
     "ln_c_scale", "ln_c_bias", "ln_g_scale", "ln_g_bias"))


def pack_gated_mlp_params(tree):
    """Convert legacy separate-weight GatedMLP dicts into the packed layout.

    Walks an arbitrary pytree (params, Adam moments, full Trainer state)
    and packs every dict whose keys are exactly the legacy GatedMLP set —
    the checkpoint-load half of the "pack once" policy.
    """
    if isinstance(tree, dict):
        if set(tree.keys()) == _LEGACY_GATED_KEYS:
            return {
                "w": jnp.concatenate([tree["wc"], tree["wg"]], axis=1),
                "b": jnp.concatenate([tree["bc"], tree["bg"]], axis=0),
                "ln_scale": jnp.concatenate(
                    [tree["ln_c_scale"], tree["ln_g_scale"]], axis=0),
                "ln_bias": jnp.concatenate(
                    [tree["ln_c_bias"], tree["ln_g_bias"]], axis=0),
            }
        return {k: pack_gated_mlp_params(v) for k, v in tree.items()}
    if isinstance(tree, list):
        return [pack_gated_mlp_params(v) for v in tree]
    if isinstance(tree, tuple):
        return tuple(pack_gated_mlp_params(v) for v in tree)
    return tree


def gated_mlp_legacy_template(tree):
    """Packed pytree -> legacy-layout template (for restoring old
    checkpoints: restore into this, then ``pack_gated_mlp_params``)."""
    if isinstance(tree, dict):
        if set(tree.keys()) == {"w", "b", "ln_scale", "ln_bias"}:
            d = tree["w"].shape[1] // 2
            return {
                "wc": tree["w"][:, :d], "wg": tree["w"][:, d:],
                "bc": tree["b"][:d], "bg": tree["b"][d:],
                "ln_c_scale": tree["ln_scale"][:d],
                "ln_g_scale": tree["ln_scale"][d:],
                "ln_c_bias": tree["ln_bias"][:d],
                "ln_g_bias": tree["ln_bias"][d:],
            }
        return {k: gated_mlp_legacy_template(v) for k, v in tree.items()}
    if isinstance(tree, list):
        return [gated_mlp_legacy_template(v) for v in tree]
    if isinstance(tree, tuple):
        return tuple(gated_mlp_legacy_template(v) for v in tree)
    return tree


def gated_mlp_apply(p, x, impl: str = "packed"):
    d = p["w"].shape[1] // 2
    w = p["w"].astype(x.dtype)  # cast-to-compute view (DESIGN.md §4)
    b = p["b"].astype(x.dtype)
    if impl == "ref":
        core = layer_norm(dot_accum(x, w[:, :d]) + b[:d],
                          p["ln_scale"][:d], p["ln_bias"][:d])
        gate = layer_norm(dot_accum(x, w[:, d:]) + b[d:],
                          p["ln_scale"][d:], p["ln_bias"][d:])
        return jax.nn.silu(core) * jax.nn.sigmoid(gate)
    if impl == "packed":
        # Fig. 3(a): one GEMM against the pre-packed weights (packed at
        # init, not here); Fig. 3(b): shared epilogue, silu(x) =
        # x * sigmoid(x) reuses the sigmoid.
        y = dot_accum(x, w) + b
        core, gate = y[..., :d], y[..., d:]
        core = layer_norm(core, p["ln_scale"][:d], p["ln_bias"][:d])
        gate = layer_norm(gate, p["ln_scale"][d:], p["ln_bias"][d:])
        sg_core = jax.nn.sigmoid(core)
        sg_gate = jax.nn.sigmoid(gate)
        return (core * sg_core) * sg_gate
    if impl == "pallas":
        from repro.kernels import ops as kops  # lazy: avoid import cycle

        return kops.fused_gated_mlp_packed(
            x, w, b, p["ln_scale"], p["ln_bias"])
    raise ValueError(f"unknown GatedMLP impl {impl!r}")


# ---------------------------------------------------------------------------
# Aggregation engine: one masked segment sum, four implementations
# ---------------------------------------------------------------------------

def segment_aggregate(values, segment_ids, num_segments, mask, impl="scatter",
                      *, offsets=None, table_residency: str = "auto"):
    """sum_{e : seg(e)=s} values[e] * mask[e]  -> (num_segments, D).

    The one aggregation engine every reduction in the model routes through
    (atom_conv, bond_conv, the direct force head).  Implementation matrix
    in DESIGN.md §2:

    impl="scatter": jax segment_sum (scatter-add; reference).
    impl="matmul" : one-hot matmul — O(E*S) FLOPs but runs on the MXU with
        no scatter; wins for the small segment counts of CHGNet batches.
    impl="sorted" : requires real ids sorted by segment (DESIGN.md §1, no
        CSR arrays needed).  Pure-jnp: remaps the padded tail onto the
        last segment so the whole id array is non-decreasing, then lets XLA
        lower a sorted segment_sum (``indices_are_sorted=True`` — no
        unsorted-scatter fallback).
    impl="pallas" : the fused tiled reduction kernel
        (``repro.kernels.fused_segment_sum``) — deterministic, atomics-free,
        MXU-tiled over the CSR rows.

    Precision (DESIGN.md §4): the reduction ACCUMULATES in f32 regardless
    of the operand dtype — bf16 edge payloads sum into f32 partials (the
    MXU's native behavior; pinned here so scatter/sorted match on every
    backend) — and the result is cast back to the operand dtype.

    ``table_residency`` (DESIGN.md §9, impl="pallas" only): "vmem" keeps
    the edge operands whole-array resident, "hbm" streams them with
    double-buffered DMA, "auto" picks by operand bytes vs the budget.
    """
    v = values * mask[..., None].astype(values.dtype)
    if impl == "scatter":
        return jax.ops.segment_sum(
            v.astype(jnp.float32), segment_ids, num_segments=num_segments
        ).astype(values.dtype)
    if impl == "matmul":
        onehot = jax.nn.one_hot(segment_ids, num_segments, dtype=values.dtype)
        return jnp.einsum(
            "es,ed->sd", onehot, v, preferred_element_type=jnp.float32
        ).astype(values.dtype)
    if impl == "sorted":
        # padded tail ids are 0 by the padding convention; point them at
        # the last segment (their payload is masked to zero) so the full
        # array really is sorted before asserting it to XLA
        ids = jnp.where(mask > 0, segment_ids, num_segments - 1)
        return jax.ops.segment_sum(
            v.astype(jnp.float32), ids, num_segments=num_segments,
            indices_are_sorted=True
        ).astype(values.dtype)
    if impl == "pallas":
        if offsets is None:
            raise ValueError(
                'impl="pallas" needs CSR offsets (sorted-segment layout); '
                "pack batches through repro.batching to get them"
            )
        from repro.kernels import ops as kops  # lazy: avoid import cycle

        return kops.fused_segment_sum(v, segment_ids, offsets, num_segments,
                                      table_residency=table_residency)
    raise ValueError(f"unknown aggregate impl {impl!r}")


# ---------------------------------------------------------------------------
# Interaction block
# ---------------------------------------------------------------------------

def interaction_block_init(key, dim=64, dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    return {
        "atom_mlp": gated_mlp_init(ks[0], 3 * dim, dim, dtype),
        "atom_out": linear_init(ks[1], dim, dim, dtype),
        "bond_mlp": gated_mlp_init(ks[2], 4 * dim, dim, dtype),
        "bond_out": linear_init(ks[3], dim, dim, dtype),
        "angle_mlp": gated_mlp_init(ks[4], 4 * dim, dim, dtype),
    }


def atom_conv(p, graph: CrystalGraphBatch, v, e, e_a, *, mlp_impl, agg_impl,
              conv_impl: str = "unfused", bond_store: str = "directed",
              bond_features: str = "directed",
              table_residency: str = "auto"):
    """Eq. 4: v_i <- v_i + L_v[ sum_j e^a_ij * phi(v_i, v_j, e_ij) ].

    ``conv_impl="fused"`` runs the whole message path (gather -> GatedMLP
    -> envelope -> reduce) as one Pallas megakernel over the sorted CSR
    rows (DESIGN.md §3; requires §1; ``mlp_impl``/``agg_impl`` are
    subsumed).  ``"unfused"`` keeps the composable impl matrix below.

    ``bond_store="undirected"`` (DESIGN.md §5): ``e_a`` lives at the
    undirected capacity and is gathered through ``graph.bond_pair`` — in
    the unfused path explicitly, in the fused path inside the megakernel
    (the mirror-indirected operand class).  The envelope is symmetric
    (e^a_ij == e^a_ji, a function of |r_ij| only), so no sign is applied.

    ``bond_features="undirected"`` (DESIGN.md §10): ``e`` too lives at the
    undirected capacity (e_ij == e_ji in the symmetric trunk) and joins
    e^a in the mirror-indirected operand class; per-bond messages still
    run at E rows because v_i/v_j differ across the two directions.

    ``table_residency`` (DESIGN.md §9): operand-table residency tier of
    the fused/pallas kernels ("vmem" | "hbm" | "auto").
    """
    if conv_impl == "fused":
        from repro.kernels import ops as kops  # lazy: avoid import cycle

        # cast-to-compute view of the MLP params: kernel VMEM operands all
        # share the activation dtype (DESIGN.md §4); no-op under f32
        mlp = jax.tree.map(lambda t: t.astype(v.dtype), p["atom_mlp"])
        agg = kops.fused_atom_conv(
            v, e, e_a, mlp["w"], mlp["b"], mlp["ln_scale"], mlp["ln_bias"],
            graph.bond_center, graph.bond_nbr, graph.bond_offsets,
            pair=graph.bond_pair if bond_store == "undirected" else None,
            und_features=bond_features == "undirected",
            table_residency=table_residency,
        )
    elif conv_impl == "unfused":
        e_dir = e[graph.bond_pair] if bond_features == "undirected" else e
        f_v = jnp.concatenate(
            [v[graph.bond_center], v[graph.bond_nbr], e_dir], axis=-1
        )
        env = e_a[graph.bond_pair] if bond_store == "undirected" else e_a
        msg = gated_mlp_apply(p["atom_mlp"], f_v, mlp_impl) * env
        agg = segment_aggregate(
            msg, graph.bond_center, graph.atom_cap, graph.bond_mask, agg_impl,
            offsets=graph.bond_offsets, table_residency=table_residency,
        )
    else:
        raise ValueError(f"unknown conv impl {conv_impl!r}")
    mask = graph.atom_mask[..., None].astype(v.dtype)
    return v + linear_apply(p["atom_out"], agg) * mask


def bond_conv(p, graph: CrystalGraphBatch, v_in, e, a, e_b, *, mlp_impl,
              agg_impl, conv_impl: str = "unfused",
              bond_store: str = "directed",
              table_residency: str = "auto"):
    """Eq. 5: e_ij <- e_ij + L_e[ sum_k e^b_ij * e^b_ik * phi(f_e) ].

    ``v_in`` is v^{t+1} in the reference variant, v^t in the fast variant.
    ``conv_impl`` as in ``atom_conv`` (DESIGN.md §3).

    ``bond_store="undirected"`` (DESIGN.md §5): ``e_b`` lives at the
    undirected capacity; both envelope factors gather through
    ``bond_pair[angle_*]`` (explicitly here, inside the megakernel when
    fused).  Like e^a, e^b is symmetric, so no sign is applied.
    """
    center = graph.bond_center[graph.angle_ij]
    if conv_impl == "fused":
        from repro.kernels import ops as kops  # lazy: avoid import cycle

        mlp = jax.tree.map(lambda t: t.astype(e.dtype), p["bond_mlp"])
        agg = kops.fused_bond_conv(
            v_in, e, a, e_b, mlp["w"], mlp["b"], mlp["ln_scale"],
            mlp["ln_bias"], graph.angle_ij, graph.angle_ik, center,
            graph.angle_offsets,
            pair=graph.bond_pair if bond_store == "undirected" else None,
            table_residency=table_residency,
        )
    elif conv_impl == "unfused":
        f_e = jnp.concatenate(
            [v_in[center], e[graph.angle_ij], e[graph.angle_ik], a], axis=-1
        )
        msg = gated_mlp_apply(p["bond_mlp"], f_e, mlp_impl)
        if bond_store == "undirected":
            msg = msg * e_b[graph.bond_pair[graph.angle_ij]] \
                * e_b[graph.bond_pair[graph.angle_ik]]
        else:
            msg = msg * e_b[graph.angle_ij] * e_b[graph.angle_ik]
        agg = segment_aggregate(
            msg, graph.angle_ij, graph.bond_cap, graph.angle_mask, agg_impl,
            offsets=graph.angle_offsets, table_residency=table_residency,
        )
    else:
        raise ValueError(f"unknown conv impl {conv_impl!r}")
    mask = graph.bond_mask[..., None].astype(e.dtype)
    return e + linear_apply(p["bond_out"], agg) * mask


def angle_update(p, graph: CrystalGraphBatch, v_in, e_in, a, *, mlp_impl):
    """Eq. 6: a_ijk <- a_ijk + phi_a(f_a).

    Reference: f_a = [v^{t+1}, e^{t+1}, a^t]; fast: f_a = [v^t, e^t, a^t].
    """
    center = graph.bond_center[graph.angle_ij]
    f_a = jnp.concatenate(
        [v_in[center], e_in[graph.angle_ij], e_in[graph.angle_ik], a], axis=-1
    )
    upd = gated_mlp_apply(p["angle_mlp"], f_a, mlp_impl)
    return a + upd * graph.angle_mask[..., None].astype(a.dtype)


# ---------------------------------------------------------------------------
# Symmetric half-graph trunk (DESIGN.md §10, bond_features="undirected")
# ---------------------------------------------------------------------------

def _sym_inputs(graph: CrystalGraphBatch, v_in, e_in, a_u):
    """Swap-symmetrized f over Au rows: [v_center, e_s, e_s, a_u].

    e_s = e[du1] + e[du2] is invariant under swapping the pair's two
    bonds, so both directed orientations of a dedup angle produce the
    SAME feature row — the single GatedMLP evaluation stands in for
    both.  Param shapes match the directed f = [v, e_ij, e_ik, a]
    exactly (checkpoint compatible).
    """
    ctr = graph.bond_center[graph.und_angle_ij]
    du1 = graph.bond_pair[graph.und_angle_ij]
    du2 = graph.bond_pair[graph.und_angle_ik]
    e_s = e_in[du1] + e_in[du2]
    f = jnp.concatenate([v_in[ctr], e_s, e_s, a_u], axis=-1)
    return f, du1, du2


def sym_bond_conv(p, graph: CrystalGraphBatch, v_in, e, a_u, e_b, *,
                  mlp_impl, agg_impl, conv_impl: str = "unfused",
                  table_residency: str = "auto"):
    """Symmetrized Eq. 5 over Eu rows (DESIGN.md §10).

    ``e``/``e_b`` live at Eu, ``a_u`` at Au == A/2.  One message per real
    dedup angle w — phi([v_ctr, e_s, e_s, a_u]) * e^b[du1] * e^b[du2],
    swap-invariant by construction — scatters into BOTH undirected bonds
    of the pair through the dest-sorted incidence store
    (sym_dest/sym_rep/sym_offsets), replacing the A-row directed
    bond_conv with Au GatedMLP rows + Eu output rows.

    ``conv_impl="fused"`` routes through the two-launch §10 megakernel
    (Au-tiled message pass + Eu destination-tiled accumulation);
    unfused composes the impl matrix like ``bond_conv``.
    """
    if conv_impl == "fused":
        from repro.kernels import ops as kops  # lazy: avoid import cycle

        mlp = jax.tree.map(lambda t: t.astype(e.dtype), p["bond_mlp"])
        ctr = graph.bond_center[graph.und_angle_ij]
        du1 = graph.bond_pair[graph.und_angle_ij]
        du2 = graph.bond_pair[graph.und_angle_ik]
        agg = kops.fused_sym_bond_conv(
            v_in, e, a_u, e_b, mlp["w"], mlp["b"], mlp["ln_scale"],
            mlp["ln_bias"], ctr, du1, du2, graph.sym_rep, graph.sym_dest,
            graph.sym_offsets, table_residency=table_residency,
        )
    elif conv_impl == "unfused":
        f, du1, du2 = _sym_inputs(graph, v_in, e, a_u)
        msg = gated_mlp_apply(p["bond_mlp"], f, mlp_impl)
        msg = msg * e_b[du1] * e_b[du2]
        # position-based incidence validity: padded incidences carry rep=0,
        # which aliases a REAL Au row, so und_angle_mask[sym_rep] would
        # leak padded contributions
        incid_mask = (
            jnp.arange(graph.angle_cap) < graph.sym_offsets[-1]
        ).astype(e.dtype)
        agg = segment_aggregate(
            msg[graph.sym_rep], graph.sym_dest, graph.und_cap, incid_mask,
            agg_impl, offsets=graph.sym_offsets,
            table_residency=table_residency,
        )
    else:
        raise ValueError(f"unknown conv impl {conv_impl!r}")
    mask = graph.und_mask[..., None].astype(e.dtype)
    return e + linear_apply(p["bond_out"], agg) * mask


def sym_angle_update(p, graph: CrystalGraphBatch, v_in, e_in, a_u, *,
                     mlp_impl):
    """Symmetrized Eq. 6 at Au rows (DESIGN.md §10).

    The swap-symmetrized f_a makes both directed orientations of a dedup
    angle agree, so the single Au-row update stands in for both — the
    remaining angle-level GEMMs run at Au == A/2.  ``e_in`` is the
    Eu-resident bond table.
    """
    f_a, _, _ = _sym_inputs(graph, v_in, e_in, a_u)
    upd = gated_mlp_apply(p["angle_mlp"], f_a, mlp_impl)
    return a_u + upd * graph.und_angle_mask[..., None].astype(a_u.dtype)


def interaction_block_apply(
    p,
    graph: CrystalGraphBatch,
    v,
    e,
    a,
    e_a,
    e_b,
    *,
    variant: str = "fast",
    mlp_impl: str = "packed",
    agg_impl: str = "scatter",
    conv_impl: str = "unfused",
    bond_store: str = "directed",
    bond_features: str = "directed",
    table_residency: str = "auto",
    update_angles: bool = True,
):
    """One interaction block IB^t (paper Eq. 3), either variant.

    ``bond_features="undirected"`` (DESIGN.md §10) swaps in the
    symmetric-trunk updates: ``e`` is Eu-resident, ``a`` is Au-resident,
    and bond_conv/angle_update run their symmetrized forms.
    """
    sym = bond_features == "undirected"
    v_new = atom_conv(p, graph, v, e, e_a, mlp_impl=mlp_impl,
                      agg_impl=agg_impl, conv_impl=conv_impl,
                      bond_store=bond_store, bond_features=bond_features,
                      table_residency=table_residency)

    def _bond(v_in):
        if sym:
            return sym_bond_conv(
                p, graph, v_in, e, a, e_b, mlp_impl=mlp_impl,
                agg_impl=agg_impl, conv_impl=conv_impl,
                table_residency=table_residency,
            )
        return bond_conv(
            p, graph, v_in, e, a, e_b, mlp_impl=mlp_impl, agg_impl=agg_impl,
            conv_impl=conv_impl, bond_store=bond_store,
            table_residency=table_residency,
        )

    def _angle(v_in, e_in):
        if not update_angles:
            return a
        if sym:
            return sym_angle_update(p, graph, v_in, e_in, a,
                                    mlp_impl=mlp_impl)
        return angle_update(p, graph, v_in, e_in, a, mlp_impl=mlp_impl)

    if variant == "reference":
        e_new = _bond(v_new)
        a_new = _angle(v_new, e_new)
    elif variant == "fast":
        # Dependency elimination (Eq. 11): all three read layer-t features.
        e_new = _bond(v)
        a_new = _angle(v, e)
    else:
        raise ValueError(f"unknown block variant {variant!r}")
    return v_new, e_new, a_new
