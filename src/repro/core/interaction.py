"""Interaction block: GatedMLP, AtomConv, BondConv, AngleUpdate.

Implements BOTH block variants (paper Eq. 10 vs Eq. 11):

  - ``reference``: BondConv consumes v^{t+1}; AngleUpdate consumes v^{t+1}
    and e^{t+1} (sequential dependency chain, as in CHGNet v0.3.0).
  - ``fast``: dependency elimination (FastCHGNet C2) — BondConv and
    AngleUpdate consume the layer-t features, so the three updates are
    data-independent and XLA can schedule them concurrently.

GatedMLP phi(x) = sigmoid(LN(x@Wg+bg)) * silu(LN(x@Wc+bc))   (paper §II-B)
with three implementations:
  - ``ref``    : two separate GEMMs + two LNs (reference graph)
  - ``packed`` : one GEMM against [Wc ‖ Wg] (+ single fused epilogue),
                 the Fig. 3 packing in pure jnp — what XLA sees on TPU
  - ``pallas`` : the hand-fused Pallas kernel (repro.kernels.fused_gated_mlp)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .graph import CrystalGraphBatch


def _glorot(key, shape, dtype=jnp.float32):
    fan_in, fan_out = shape[0], shape[-1]
    scale = jnp.sqrt(2.0 / (fan_in + fan_out))
    return jax.random.normal(key, shape, dtype) * scale


def linear_init(key, d_in, d_out, dtype=jnp.float32):
    return {
        "w": _glorot(key, (d_in, d_out), dtype),
        "b": jnp.zeros((d_out,), dtype),
    }


def linear_apply(p, x):
    return x @ p["w"] + p["b"]


def layer_norm(x, scale, bias, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * scale + bias


# ---------------------------------------------------------------------------
# GatedMLP
# ---------------------------------------------------------------------------

def gated_mlp_init(key, d_in, d_out, dtype=jnp.float32):
    kc, kg = jax.random.split(key)
    return {
        "wc": _glorot(kc, (d_in, d_out), dtype),
        "bc": jnp.zeros((d_out,), dtype),
        "wg": _glorot(kg, (d_in, d_out), dtype),
        "bg": jnp.zeros((d_out,), dtype),
        "ln_c_scale": jnp.ones((d_out,), dtype),
        "ln_c_bias": jnp.zeros((d_out,), dtype),
        "ln_g_scale": jnp.ones((d_out,), dtype),
        "ln_g_bias": jnp.zeros((d_out,), dtype),
    }


def gated_mlp_apply(p, x, impl: str = "packed"):
    if impl == "ref":
        core = layer_norm(x @ p["wc"] + p["bc"], p["ln_c_scale"], p["ln_c_bias"])
        gate = layer_norm(x @ p["wg"] + p["bg"], p["ln_g_scale"], p["ln_g_bias"])
        return jax.nn.silu(core) * jax.nn.sigmoid(gate)
    if impl == "packed":
        # Fig. 3(a): one GEMM against packed weights; Fig. 3(b): shared
        # epilogue, silu(x) = x * sigmoid(x) reuses the sigmoid.
        d = p["wc"].shape[1]
        w = jnp.concatenate([p["wc"], p["wg"]], axis=1)
        b = jnp.concatenate([p["bc"], p["bg"]], axis=0)
        y = x @ w + b
        core, gate = y[..., :d], y[..., d:]
        core = layer_norm(core, p["ln_c_scale"], p["ln_c_bias"])
        gate = layer_norm(gate, p["ln_g_scale"], p["ln_g_bias"])
        sg_core = jax.nn.sigmoid(core)
        sg_gate = jax.nn.sigmoid(gate)
        return (core * sg_core) * sg_gate
    if impl == "pallas":
        from repro.kernels import ops as kops  # lazy: avoid import cycle

        return kops.fused_gated_mlp(
            x, p["wc"], p["bc"], p["wg"], p["bg"],
            p["ln_c_scale"], p["ln_c_bias"], p["ln_g_scale"], p["ln_g_bias"],
        )
    raise ValueError(f"unknown GatedMLP impl {impl!r}")


# ---------------------------------------------------------------------------
# Aggregation engine: one masked segment sum, four implementations
# ---------------------------------------------------------------------------

def segment_aggregate(values, segment_ids, num_segments, mask, impl="scatter",
                      *, offsets=None):
    """sum_{e : seg(e)=s} values[e] * mask[e]  -> (num_segments, D).

    The one aggregation engine every reduction in the model routes through
    (atom_conv, bond_conv, the direct force head).  Implementation matrix
    in DESIGN.md §2:

    impl="scatter": jax segment_sum (scatter-add; reference).
    impl="matmul" : one-hot matmul — O(E*S) FLOPs but runs on the MXU with
        no scatter; wins for the small segment counts of CHGNet batches.
    impl="sorted" : requires real ids sorted by segment (DESIGN.md §1, no
        CSR arrays needed).  Pure-jnp: remaps the padded tail onto the
        last segment so the whole id array is non-decreasing, then lets XLA
        lower a sorted segment_sum (``indices_are_sorted=True`` — no
        unsorted-scatter fallback).
    impl="pallas" : the fused tiled reduction kernel
        (``repro.kernels.fused_segment_sum``) — deterministic, atomics-free,
        MXU-tiled over the CSR rows.
    """
    v = values * mask[..., None]
    if impl == "scatter":
        return jax.ops.segment_sum(v, segment_ids, num_segments=num_segments)
    if impl == "matmul":
        onehot = jax.nn.one_hot(segment_ids, num_segments, dtype=values.dtype)
        return jnp.einsum("es,ed->sd", onehot, v)
    if impl == "sorted":
        # padded tail ids are 0 by the padding convention; point them at
        # the last segment (their payload is masked to zero) so the full
        # array really is sorted before asserting it to XLA
        ids = jnp.where(mask > 0, segment_ids, num_segments - 1)
        return jax.ops.segment_sum(
            v, ids, num_segments=num_segments, indices_are_sorted=True
        )
    if impl == "pallas":
        if offsets is None:
            raise ValueError(
                'impl="pallas" needs CSR offsets (sorted-segment layout); '
                "pack batches through repro.batching to get them"
            )
        from repro.kernels import ops as kops  # lazy: avoid import cycle

        return kops.fused_segment_sum(v, segment_ids, offsets, num_segments)
    raise ValueError(f"unknown aggregate impl {impl!r}")


# ---------------------------------------------------------------------------
# Interaction block
# ---------------------------------------------------------------------------

def interaction_block_init(key, dim=64, dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    return {
        "atom_mlp": gated_mlp_init(ks[0], 3 * dim, dim, dtype),
        "atom_out": linear_init(ks[1], dim, dim, dtype),
        "bond_mlp": gated_mlp_init(ks[2], 4 * dim, dim, dtype),
        "bond_out": linear_init(ks[3], dim, dim, dtype),
        "angle_mlp": gated_mlp_init(ks[4], 4 * dim, dim, dtype),
    }


def atom_conv(p, graph: CrystalGraphBatch, v, e, e_a, *, mlp_impl, agg_impl):
    """Eq. 4: v_i <- v_i + L_v[ sum_j e^a_ij * phi(v_i, v_j, e_ij) ]."""
    f_v = jnp.concatenate(
        [v[graph.bond_center], v[graph.bond_nbr], e], axis=-1
    )
    msg = gated_mlp_apply(p["atom_mlp"], f_v, mlp_impl) * e_a
    agg = segment_aggregate(
        msg, graph.bond_center, graph.atom_cap, graph.bond_mask, agg_impl,
        offsets=graph.bond_offsets,
    )
    return v + linear_apply(p["atom_out"], agg) * graph.atom_mask[..., None]


def bond_conv(p, graph: CrystalGraphBatch, v_in, e, a, e_b, *, mlp_impl, agg_impl):
    """Eq. 5: e_ij <- e_ij + L_e[ sum_k e^b_ij * e^b_ik * phi(f_e) ].

    ``v_in`` is v^{t+1} in the reference variant, v^t in the fast variant.
    """
    center = graph.bond_center[graph.angle_ij]
    f_e = jnp.concatenate(
        [v_in[center], e[graph.angle_ij], e[graph.angle_ik], a], axis=-1
    )
    msg = gated_mlp_apply(p["bond_mlp"], f_e, mlp_impl)
    msg = msg * e_b[graph.angle_ij] * e_b[graph.angle_ik]
    agg = segment_aggregate(
        msg, graph.angle_ij, graph.bond_cap, graph.angle_mask, agg_impl,
        offsets=graph.angle_offsets,
    )
    return e + linear_apply(p["bond_out"], agg) * graph.bond_mask[..., None]


def angle_update(p, graph: CrystalGraphBatch, v_in, e_in, a, *, mlp_impl):
    """Eq. 6: a_ijk <- a_ijk + phi_a(f_a).

    Reference: f_a = [v^{t+1}, e^{t+1}, a^t]; fast: f_a = [v^t, e^t, a^t].
    """
    center = graph.bond_center[graph.angle_ij]
    f_a = jnp.concatenate(
        [v_in[center], e_in[graph.angle_ij], e_in[graph.angle_ik], a], axis=-1
    )
    upd = gated_mlp_apply(p["angle_mlp"], f_a, mlp_impl)
    return a + upd * graph.angle_mask[..., None]


def interaction_block_apply(
    p,
    graph: CrystalGraphBatch,
    v,
    e,
    a,
    e_a,
    e_b,
    *,
    variant: str = "fast",
    mlp_impl: str = "packed",
    agg_impl: str = "scatter",
    update_angles: bool = True,
):
    """One interaction block IB^t (paper Eq. 3), either variant."""
    v_new = atom_conv(p, graph, v, e, e_a, mlp_impl=mlp_impl, agg_impl=agg_impl)
    if variant == "reference":
        e_new = bond_conv(
            p, graph, v_new, e, a, e_b, mlp_impl=mlp_impl, agg_impl=agg_impl
        )
        if update_angles:
            a_new = angle_update(p, graph, v_new, e_new, a, mlp_impl=mlp_impl)
        else:
            a_new = a
    elif variant == "fast":
        # Dependency elimination (Eq. 11): all three read layer-t features.
        e_new = bond_conv(
            p, graph, v, e, a, e_b, mlp_impl=mlp_impl, agg_impl=agg_impl
        )
        if update_angles:
            a_new = angle_update(p, graph, v, e, a, mlp_impl=mlp_impl)
        else:
            a_new = a
    else:
        raise ValueError(f"unknown block variant {variant!r}")
    return v_new, e_new, a_new
