"""CHGNet / FastCHGNet model (paper §II-B, §III).

Pure-JAX functional model: ``chgnet_init`` builds the parameter pytree,
``chgnet_apply`` runs the forward pass. Two readout modes:

  - readout="autodiff" (reference CHGNet): E from the energy head;
      F_i = -dE/d(x_i),  sigma = (1/V) dE/d(eps)  via jax.grad — this makes
      the *training* backward pass a second-order derivative (the cost the
      paper eliminates).
  - readout="direct" (FastCHGNet "F/S head"): Force/Stress heads (C1).

Block variant ("reference" | "fast") and GatedMLP impl ("ref" | "packed" |
"pallas") select the paper's other model-level optimizations;
``CHGNetConfig.precision`` selects the end-to-end precision policy
(DESIGN.md §4) governing param storage, compute, accumulation, and
output dtypes across the model, kernels, optimizer, and trainer.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.precision import resolve_policy

from . import basis, heads
from .graph import CrystalGraphBatch
from .interaction import (
    gated_mlp_init,
    interaction_block_apply,
    interaction_block_init,
    linear_apply,
    linear_init,
)

MAX_Z = 95  # elements supported (MPtrj has 89)
EV_A3_TO_GPA = heads.EV_A3_TO_GPA  # eV/A^3 -> GPa (defined once in heads)


@dataclasses.dataclass(frozen=True)
class CHGNetConfig:
    """Model + implementation-tier selection.

    ``precision`` selects the end-to-end :class:`repro.precision.
    PrecisionPolicy` (DESIGN.md §4): ``"f32"`` (everything float32, the
    reference), ``"mixed"`` (f32 parameter storage / accumulation, bf16
    GEMM + kernel VMEM operands — the recommended training policy), or
    ``"bf16"`` (bf16 storage too; the optimizer keeps f32 master weights,
    see ``optim.adam``).  The policy governs the cast boundaries in
    ``chgnet_apply``/``_trunk``, the LayerNorm/reduction accumulation
    dtype in ``core.interaction``/``core.heads``, and the operand dtype
    of every Pallas kernel behind ``mlp_impl``/``agg_impl``/``conv_impl``
    — it composes with all of those tier knobs.
    """

    dim: int = 64
    num_rbf: int = 31
    num_fourier: int = 31
    num_blocks: int = 3          # full interaction blocks (+1 final atom conv)
    r_cut_atom: float = 6.0
    r_cut_bond: float = 3.0
    envelope_p: int = 8
    readout: str = "direct"      # "direct" (F/S heads) | "autodiff" (reference)
    block_variant: str = "fast"  # "fast" (dep. elimination) | "reference"
    mlp_impl: str = "packed"     # "ref" | "packed" | "pallas"
    agg_impl: str = "scatter"    # "scatter" | "matmul" | "sorted" | "pallas"
    # "fused": one Pallas megakernel per conv (gather -> GatedMLP ->
    # envelope -> reduce over sorted CSR rows; also fuses the direct force
    # readout).  Requires the DESIGN.md §1 sorted-segment layout (any batch
    # from repro.batching / repro.serve); subsumes mlp_impl/agg_impl at the
    # conv call sites (angle_update and per-crystal sums still honor them).
    # See DESIGN.md §3.
    conv_impl: str = "unfused"   # "unfused" | "fused"
    # "undirected": undirected-bond redundancy bypass (DESIGN.md §5) —
    # geometry, the smooth-RBF basis, the packed bond-embed GEMM, and the
    # e^a/e^b envelope tables all run at the undirected capacity Eu ≈ E/2;
    # directed views materialize through the batch's bond_pair/bond_sign
    # mirror maps (cheap gathers; inside the megakernels when conv_impl=
    # "fused").  Composes with every other tier knob; "directed" keeps the
    # reference twice-stored layout.
    bond_store: str = "directed"  # "directed" | "undirected"
    envelope_impl: str = "factored"  # "factored" | "reference"
    # end-to-end precision policy (DESIGN.md §4), see class docstring
    precision: str = "f32"       # "f32" | "bf16" | "mixed"
    # Direct-readout stress tier (DESIGN.md §7).  "mlp": per-crystal MLP on
    # pooled atom features (FastCHGNet S head; extra stress_head params).
    # "bond_virial": physically-motivated per-bond virial
    # sigma = 1/(2V) sum_ij n_ij d_ij x_hat⊗x_hat sharing the force head's
    # n_ij — NO stress parameters; with conv_impl="fused" the accumulation
    # runs inside the force-readout megakernel epilogue (single launch).
    # Ignored under readout="autodiff" (stress comes from dE/d(strain)).
    stress_mode: str = "mlp"     # "mlp" | "bond_virial"
    stress_scale: float = 0.1
    # Operand-table residency tier of the Pallas kernels (DESIGN.md §9).
    # "vmem": tables whole-array VMEM-resident (the classic lowering);
    # "hbm": tables stay in HBM and stream through double-buffered DMA
    # ping/pong scratch — batch size becomes HBM-bounded (10k+-atom
    # structures); "auto" (default): each kernel launch estimates its
    # padded operand-table bytes against the VMEM budget
    # (kernels.ops.vmem_budget_bytes) and picks — small batches keep the
    # exact vmem lowering, oversized ones transparently stream.
    table_residency: str = "auto"  # "auto" | "vmem" | "hbm"
    # Symmetric half-graph trunk (DESIGN.md §10).  "undirected" makes the
    # undirected representation the COMPUTE representation, not just the
    # storage one: ``e`` lives at Eu ≈ E/2 rows from bond-embed through
    # every interaction block (symmetrized bond_conv scatters each Au-row
    # message to BOTH undirected destinations through the sym-incidence
    # store), and ``a`` lives at the Au == A/2 dedup rows (swap-symmetrized
    # angle_update) — halving every bond- and angle-level GEMM in the
    # trunk.  Requires ``bond_store="undirected"`` (the mirror maps ARE
    # the compute indices here); directed views of ``e`` materialize only
    # at the heads boundary.  This is a distinct model variant, not a
    # re-layout: directed bond_conv produces e_ij != e_ji, the symmetric
    # trunk by construction does not (parameter shapes are identical, so
    # checkpoints carry over).
    bond_features: str = "directed"  # "directed" | "undirected"

    def __post_init__(self):
        # dataclasses.replace (with_) re-runs this, so every derived config
        # is revalidated too
        if self.bond_features not in ("directed", "undirected"):
            raise ValueError(
                f"bond_features must be 'directed' or 'undirected', "
                f"got {self.bond_features!r}")
        if self.bond_features == "undirected" and \
                self.bond_store != "undirected":
            raise ValueError(
                'bond_features="undirected" (the symmetric half-graph '
                "trunk, DESIGN.md §10) requires the undirected bond store: "
                'pass bond_store="undirected" as well — the bond_pair / '
                "angle_pair mirror maps are its compute indices, got "
                f"bond_store={self.bond_store!r}")

    def with_(self, **kw) -> "CHGNetConfig":
        return dataclasses.replace(self, **kw)


def chgnet_init(key, cfg: CHGNetConfig, dtype=None):
    """Build the parameter pytree in ``cfg.precision``'s param dtype
    (``dtype`` overrides; pass ``jnp.float32`` explicitly for the legacy
    behavior regardless of policy)."""
    if dtype is None:
        dtype = resolve_policy(cfg.precision).param
    n_keys = 8 + cfg.num_blocks
    ks = jax.random.split(key, n_keys)
    params = {
        # Feature embedding (Eq. 2). The three bond linears are PACKED into
        # one (num_rbf -> 3*dim) weight (Fig. 3a): [e^0 | e^a | e^b].
        "atom_embed": jax.random.normal(ks[0], (MAX_Z, cfg.dim), dtype) * 0.02,
        "bond_embed": linear_init(ks[1], cfg.num_rbf, 3 * cfg.dim, dtype),
        "angle_embed": linear_init(ks[2], cfg.num_fourier, cfg.dim, dtype),
        # rbf_freqs feed the accum-pinned basis (DESIGN.md §4): they are
        # STORED at accum precision under every policy — a bf16 round-trip
        # would perturb the trainable frequencies by ~0.4% per step
        "rbf_freqs": basis.rbf_frequencies(cfg.num_rbf).astype(jnp.float32),
        "blocks": [
            interaction_block_init(ks[3 + i], cfg.dim, dtype)
            for i in range(cfg.num_blocks)
        ],
        # final block: atom conv only (CHGNet v0.3.0 has a last atom update)
        "final_block": interaction_block_init(ks[3 + cfg.num_blocks], cfg.dim, dtype),
        "energy_head": heads.energy_head_init(ks[4 + cfg.num_blocks], cfg.dim, dtype),
        "magmom_head": heads.magmom_head_init(ks[5 + cfg.num_blocks], cfg.dim, dtype),
    }
    if cfg.readout == "direct":
        params["force_head"] = heads.force_head_init(
            ks[6 + cfg.num_blocks], cfg.dim, dtype
        )
        if cfg.stress_mode == "mlp":
            params["stress_head"] = heads.stress_head_init(
                ks[7 + cfg.num_blocks], cfg.dim, cfg.stress_scale, dtype
            )
        # stress_mode="bond_virial" shares the force head's n_ij — no
        # stress parameters exist in that tier (DESIGN.md §7)
    return params


def param_count(params) -> int:
    return sum(int(p.size) for p in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# Forward trunk: embeddings + interaction blocks -> (v, e, a, geometry)
# ---------------------------------------------------------------------------

def _trunk(params, cfg: CHGNetConfig, graph: CrystalGraphBatch,
           displacement=None, strain=None):
    policy = resolve_policy(cfg.precision)
    env = (
        basis.envelope_factored
        if cfg.envelope_impl == "factored"
        else basis.envelope_reference
    )
    # bond_store="undirected" (DESIGN.md §5): geometry, RBF, and the bond
    # embedding run ONCE per undirected pair (Eu ≈ E/2); only e^0 is
    # expanded to the directed store (it seeds e, which bond_conv updates
    # per directed bond) — e^a/e^b stay at Eu for the whole trunk.
    # Angle-pair dedup rides along: theta / Fourier / angle-embed run at
    # the Au == Na/2 dedup rows and expand via angle_pair below.
    if cfg.bond_store == "undirected":
        vec_und, dist_und, vec, dist, _cos, theta = \
            basis.compute_geometry_undirected(
                graph, displacement=displacement, strain=strain,
                angle_rows="undirected",
            )
        rbf_dist = dist_und
    elif cfg.bond_store == "directed":
        vec, dist, _cos, theta = basis.compute_geometry(
            graph, displacement=displacement, strain=strain
        )
        vec_und = dist_und = None
        rbf_dist = dist
    else:
        raise ValueError(f"unknown bond store {cfg.bond_store!r}")
    if cfg.mlp_impl == "pallas":
        from repro.kernels import ops as kops

        rbf = kops.fused_rbf(
            rbf_dist, params["rbf_freqs"], cfg.r_cut_atom, cfg.envelope_p
        )
        four = kops.fused_fourier(theta, cfg.num_fourier)
    else:
        rbf = basis.smooth_rbf(
            rbf_dist, params["rbf_freqs"], cfg.r_cut_atom, cfg.envelope_p,
            envelope=env,
        )
        four = basis.fourier_basis(theta, cfg.num_fourier)

    # PRECISION BOUNDARY (DESIGN.md §4): geometry + basis above run in
    # f32 (accum-pinned); everything from the embedding GEMMs through the
    # interaction blocks runs at the policy's compute dtype.  Parameters
    # follow via the cast-to-compute views in linear/gated_mlp_apply.
    cd = policy.compute
    rbf = policy.cast_compute(rbf)
    four = policy.cast_compute(four)

    # Feature embedding (packed bond linear -> split into e0 / e_a / e_b).
    # Undirected store: the (rbf -> 3*dim) GEMM runs at Eu; e^a/e^b keep
    # that granularity (the blocks never update them), e^0 expands once.
    packed = linear_apply(params["bond_embed"], rbf)  # (Nb or Nu, 3*dim)
    e0, e_a, e_b = jnp.split(packed, 3, axis=-1)
    v = params["atom_embed"].astype(cd)[graph.atom_z] \
        * graph.atom_mask[..., None].astype(cd)
    if cfg.bond_store == "undirected":
        # angle-pair dedup: ``four`` is at the Au dedup rows — embed once
        # per unordered (ij, ik) pair, expand through angle_pair, and
        # re-mask (padded angles carry pair=0)
        a_und = linear_apply(params["angle_embed"], four) \
            * graph.und_angle_mask[..., None].astype(cd)
        umask = graph.und_mask[..., None].astype(cd)
        e_a = e_a * umask
        e_b = e_b * umask
        if cfg.bond_features == "undirected":
            # symmetric trunk (DESIGN.md §10): e stays Eu-resident and a
            # stays Au-resident for the whole trunk — the blocks consume
            # them through the mirror maps / sym-incidence store
            a = a_und
            e = e0 * umask
        else:
            a = a_und[graph.angle_pair] \
                * graph.angle_mask[..., None].astype(cd)
            e = e0[graph.bond_pair] * graph.bond_mask[..., None].astype(cd)
    else:
        a = linear_apply(params["angle_embed"], four) \
            * graph.angle_mask[..., None].astype(cd)
        e = e0 * graph.bond_mask[..., None].astype(cd)

    for blk in params["blocks"]:
        v, e, a = interaction_block_apply(
            blk, graph, v, e, a, e_a, e_b,
            variant=cfg.block_variant,
            mlp_impl=cfg.mlp_impl,
            agg_impl=cfg.agg_impl,
            conv_impl=cfg.conv_impl,
            bond_store=cfg.bond_store,
            bond_features=cfg.bond_features,
            table_residency=cfg.table_residency,
        )
    # last block updates atoms only (matches CHGNet's final atom conv)
    from .interaction import atom_conv

    v = atom_conv(
        params["final_block"], graph, v, e, e_a,
        mlp_impl=cfg.mlp_impl, agg_impl=cfg.agg_impl, conv_impl=cfg.conv_impl,
        bond_store=cfg.bond_store, bond_features=cfg.bond_features,
        table_residency=cfg.table_residency,
    )
    # vec_und/dist_und (None for the directed store) ride along for the
    # bond_virial stress tier's undirected half-geometry path (§5/§7)
    return v, e, a, vec, dist, vec_und, dist_und


def _volume(lattice):
    return jnp.abs(jnp.linalg.det(lattice))


# ---------------------------------------------------------------------------
# Public forward passes
# ---------------------------------------------------------------------------

def chgnet_apply(params, cfg: CHGNetConfig, graph: CrystalGraphBatch):
    """Full prediction: energy (B,), forces (A,3), stress (B,3,3), magmom (A,).

    readout="direct": one forward pass, no derivatives (FastCHGNet).
    readout="autodiff": forces/stress by differentiating the energy
    (reference CHGNet) — training through this is second-order.

    All outputs are cast to the precision policy's ``output_dtype``
    (f32 for every built-in policy, DESIGN.md §4) so downstream
    consumers — losses, MD integrators, serving — see one dtype
    regardless of ``cfg.precision``.
    """
    policy = resolve_policy(cfg.precision)

    def _out(d):
        return {k: policy.cast_output(x) for k, x in d.items()}

    if cfg.readout == "direct":
        v, e, a, vec, dist, vec_und, dist_und = _trunk(params, cfg, graph)
        if cfg.bond_features == "undirected":
            # heads boundary (DESIGN.md §10): the force/stress heads read
            # per-directed-bond features; expand the Eu-resident e ONCE
            e = e[graph.bond_pair] * graph.bond_mask[..., None].astype(e.dtype)
        energy = heads.energy_head_apply(params["energy_head"], graph, v)
        magmom = heads.magmom_head_apply(params["magmom_head"], graph, v)
        if cfg.stress_mode == "bond_virial":
            # single-pass force + stress (DESIGN.md §7): with conv_impl=
            # "fused" both come out of ONE megakernel launch
            forces, stress = heads.force_virial_head_apply(
                params["force_head"], graph, e, vec, dist,
                vec_und=vec_und, dist_und=dist_und,
                agg_impl=cfg.agg_impl, conv_impl=cfg.conv_impl,
                bond_store=cfg.bond_store,
                table_residency=cfg.table_residency)
        elif cfg.stress_mode == "mlp":
            forces = heads.force_head_apply(
                params["force_head"], graph, e, vec, dist,
                agg_impl=cfg.agg_impl, conv_impl=cfg.conv_impl,
                table_residency=cfg.table_residency)
            stress = heads.stress_head_apply(params["stress_head"], graph, v)
        else:
            raise ValueError(f"unknown stress mode {cfg.stress_mode!r}")
        return _out({"energy": energy, "forces": forces, "stress": stress,
                     "magmom": magmom})

    if cfg.readout == "autodiff":
        def energy_of(disp, strain):
            v = _trunk(
                params, cfg, graph, displacement=disp, strain=strain
            )[0]
            e_tot = heads.energy_head_apply(params["energy_head"], graph, v)
            return jnp.sum(e_tot), v

        disp0 = jnp.zeros_like(graph.frac_coords)
        strain0 = jnp.zeros_like(graph.lattice)
        (de_ddisp, de_dstrain), v = jax.grad(
            energy_of, argnums=(0, 1), has_aux=True
        )(disp0, strain0)
        energy = heads.energy_head_apply(params["energy_head"], graph, v)
        magmom = heads.magmom_head_apply(params["magmom_head"], graph, v)
        forces = -de_ddisp * graph.atom_mask[..., None]
        vol = _volume(graph.lattice)[:, None, None]
        stress = de_dstrain / (vol + 1e-12) * EV_A3_TO_GPA
        stress = stress * graph.crystal_mask[:, None, None]
        return _out({"energy": energy, "forces": forces, "stress": stress,
                     "magmom": magmom})

    raise ValueError(f"unknown readout {cfg.readout!r}")


@partial(jax.jit, static_argnums=(1,))
def chgnet_apply_jit(params, cfg: CHGNetConfig, graph: CrystalGraphBatch):
    return chgnet_apply(params, cfg, graph)
