"""Core CHGNet / FastCHGNet implementation (the paper's contribution)."""
from .chgnet import CHGNetConfig, chgnet_apply, chgnet_init, param_count
from .graph import CrystalGraphBatch, batch_input_specs
from .losses import LossWeights, chgnet_loss
from .neighbors import Crystal, GraphIndices, VerletNeighborList, build_graph

__all__ = [
    "CHGNetConfig", "chgnet_apply", "chgnet_init", "param_count",
    "BatchCapacities", "CrystalGraphBatch", "batch_crystals",
    "batch_input_specs", "LossWeights", "chgnet_loss",
    "Crystal", "GraphIndices", "VerletNeighborList", "build_graph",
]

# Host-side packing moved to repro.batching; keep `from repro.core import
# BatchCapacities, batch_crystals` working via lazy re-export (PEP 562) —
# an eager import here would be circular (repro.batching imports
# repro.core.graph / repro.core.neighbors).
_MOVED_TO_BATCHING = ("BatchCapacities", "batch_crystals")


def __getattr__(name):
    if name in _MOVED_TO_BATCHING:
        from repro import batching

        return getattr(batching, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
