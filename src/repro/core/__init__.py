"""Core CHGNet / FastCHGNet implementation (the paper's contribution)."""
from .chgnet import CHGNetConfig, chgnet_apply, chgnet_init, param_count
from .graph import BatchCapacities, CrystalGraphBatch, batch_crystals, batch_input_specs
from .losses import LossWeights, chgnet_loss
from .neighbors import Crystal, GraphIndices, build_graph

__all__ = [
    "CHGNetConfig", "chgnet_apply", "chgnet_init", "param_count",
    "BatchCapacities", "CrystalGraphBatch", "batch_crystals",
    "batch_input_specs", "LossWeights", "chgnet_loss",
    "Crystal", "GraphIndices", "build_graph",
]
