"""Host-side (numpy) periodic neighbor-list and bond-graph construction.

This is the "Molecular Graph Extraction" stage of CHGNet (paper §II-B (1)).
It runs on the host as part of the data pipeline (like pymatgen in the
reference implementation) and emits *index* arrays only; all differentiable
geometry (bond vectors, distances, angles) is recomputed on device inside the
model so that autodiff forces/stress (the reference readout) work.

Atom graph  G^a: directed edges (center i -> neighbor j, image n) with
                 |r_j + n@L - r_i| <= r_cut_atom   (default 6 A).
Bond graph  G^b: nodes are the G^a edges whose length <= r_cut_bond
                 (default 3 A); its edges are ordered pairs of short bonds
                 (ij, ik) sharing center i with j-image != k-image.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class Crystal:
    """One crystal structure (host side)."""

    lattice: np.ndarray      # (3, 3) rows are lattice vectors, Angstrom
    frac_coords: np.ndarray  # (N, 3) fractional coordinates in [0, 1)
    atomic_numbers: np.ndarray  # (N,) int
    # Labels (optional; filled by the dataset)
    energy: float | None = None          # eV (total)
    forces: np.ndarray | None = None     # (N, 3) eV/A
    stress: np.ndarray | None = None     # (3, 3) GPa
    magmoms: np.ndarray | None = None    # (N,) mu_B

    @property
    def num_atoms(self) -> int:
        return int(self.frac_coords.shape[0])

    def cart_coords(self) -> np.ndarray:
        return self.frac_coords @ self.lattice


@dataclasses.dataclass
class GraphIndices:
    """Pure index representation of G^a and G^b for one crystal.

    Layout invariant (DESIGN.md §1): ``bond_center`` is non-decreasing and
    ``angle_ij`` is non-decreasing — ``_graph_from_pairs`` canonicalizes
    every producer (``build_graph`` and the Verlet ``update`` refilter), so
    batch packing only has to merge already-sorted runs.

    Mirror maps (DESIGN.md §5): every directed bond (i, j, n) has a mirror
    (j, i, -n); ``bond_pair`` maps each directed bond to its *undirected*
    id, ``bond_sign`` is +1 when the directed bond shares the stored
    orientation of its undirected representative (-1 for the mirror), and
    ``und_rep`` lists, per undirected id, the directed index whose
    (center, nbr, image) triple IS the stored orientation.  Graphs whose
    pair symmetry was broken (``max_nbr_per_atom`` capping) fall back to
    singleton undirected entries, so the maps are total either way.
    ``_graph_from_pairs`` always populates them; hand-built instances may
    leave them ``None`` and let packing repair via ``build_mirror_maps``.
    """

    bond_center: np.ndarray  # (Nb,) int32 atom index i
    bond_nbr: np.ndarray     # (Nb,) int32 atom index j
    bond_image: np.ndarray   # (Nb, 3) int32 periodic image of j
    # bond-graph edges: ordered pairs of *short* bonds sharing a center
    angle_ij: np.ndarray     # (Na,) int32 index into bonds (the updated bond)
    angle_ik: np.ndarray     # (Na,) int32 index into bonds (the partner bond)
    # undirected mirror maps (DESIGN.md §5)
    bond_pair: np.ndarray | None = None  # (Nb,) int32 -> undirected id
    bond_sign: np.ndarray | None = None  # (Nb,) f32 +1 rep orientation, -1 mirror
    und_rep: np.ndarray | None = None    # (Nu,) int32 -> representative bond
    # angle-pair dedup maps: each unordered bond pair {ij, ik} appears
    # twice in the ordered angle list ((ij, ik) and (ik, ij)); the angle
    # cosine is symmetric under the swap, so geometry/Fourier/angle-embed
    # run once per unordered pair (Au == Na/2) and expand via angle_pair
    angle_pair: np.ndarray | None = None     # (Na,) int32 -> und angle id
    und_angle_rep: np.ndarray | None = None  # (Au,) int32 -> representative angle

    @property
    def num_bonds(self) -> int:
        return int(self.bond_center.shape[0])

    @property
    def num_angles(self) -> int:
        return int(self.angle_ij.shape[0])

    @property
    def num_undirected(self) -> int:
        if self.und_rep is None:
            raise ValueError("mirror maps not built; see build_mirror_maps")
        return int(self.und_rep.shape[0])

    @property
    def num_und_angles(self) -> int:
        if self.und_angle_rep is None:
            raise ValueError(
                "angle mirror maps not built; see build_angle_mirror_maps")
        return int(self.und_angle_rep.shape[0])

    def feature_count(self, num_atoms: int) -> int:
        """Paper's load metric: atoms + bonds + angles (Fig. 9)."""
        return num_atoms + self.num_bonds + self.num_angles


def _image_bounds(lattice: np.ndarray, r_cut: float) -> np.ndarray:
    """Number of periodic images needed per axis to cover r_cut.

    Uses the distance between lattice planes: h_k = 1 / ||(L^-1)[:, k]||.
    """
    inv = np.linalg.inv(lattice)
    heights = 1.0 / np.linalg.norm(inv, axis=0)  # (3,)
    return np.ceil(r_cut / heights).astype(np.int64)


def _candidate_pairs(
    lat: np.ndarray, frac: np.ndarray, r_cut: float
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """All (center, neighbor, image) pairs with distance in (0, r_cut].

    The O(N^2 * images) distance tensor here is the expensive part of graph
    construction — the Verlet skin list amortizes it across MD steps.
    Returns (ci, nj, images[int], dist).
    """
    n = frac.shape[0]
    cart = frac @ lat

    nmax = _image_bounds(lat, r_cut)
    rng = [np.arange(-m, m + 1) for m in nmax]
    images = np.stack(np.meshgrid(*rng, indexing="ij"), axis=-1).reshape(-1, 3)
    shifts = images @ lat  # (M, 3)

    # diff[i, j, m] = r_j + shift_m - r_i
    diff = cart[None, :, None, :] + shifts[None, None, :, :] - cart[:, None, None, :]
    dist = np.linalg.norm(diff, axis=-1)  # (N, N, M)

    mask = (dist <= r_cut) & (dist > 1e-8)
    ci, nj, mi = np.nonzero(mask)
    return ci, nj, images[mi], dist[ci, nj, mi]


def _build_angles(
    bond_center: np.ndarray, bond_dist: np.ndarray, r_cut_bond: float, n: int
) -> tuple[np.ndarray, np.ndarray]:
    """Ordered pairs of *short* bonds sharing a center (G^b edges)."""
    short = np.nonzero(bond_dist <= r_cut_bond)[0]  # indices into bonds
    angle_ij_list: list[np.ndarray] = []
    angle_ik_list: list[np.ndarray] = []
    if short.size > 0:
        centers_short = bond_center[short]
        order = np.argsort(centers_short, kind="stable")
        short_sorted = short[order]
        centers_sorted = centers_short[order]
        # group boundaries
        starts = np.searchsorted(centers_sorted, np.arange(n), side="left")
        ends = np.searchsorted(centers_sorted, np.arange(n), side="right")
        for a in range(n):
            grp = short_sorted[starts[a]:ends[a]]
            d = grp.shape[0]
            if d < 2:
                continue
            jj, kk = np.meshgrid(grp, grp, indexing="ij")
            off = ~np.eye(d, dtype=bool)
            angle_ij_list.append(jj[off].ravel())
            angle_ik_list.append(kk[off].ravel())
    if angle_ij_list:
        angle_ij = np.concatenate(angle_ij_list).astype(np.int32)
        angle_ik = np.concatenate(angle_ik_list).astype(np.int32)
    else:
        angle_ij = np.zeros((0,), dtype=np.int32)
        angle_ik = np.zeros((0,), dtype=np.int32)
    return angle_ij, angle_ik


def _lex_less(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Row-wise lexicographic a < b for integer (E, K) arrays."""
    res = np.zeros(a.shape[0], dtype=bool)
    decided = np.zeros(a.shape[0], dtype=bool)
    for k in range(a.shape[1]):
        lt = ~decided & (a[:, k] < b[:, k])
        gt = ~decided & (a[:, k] > b[:, k])
        res |= lt
        decided |= lt | gt
    return res


def build_mirror_maps(
    bond_center: np.ndarray,
    bond_nbr: np.ndarray,
    bond_image: np.ndarray,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Undirected mirror maps for a directed bond list (DESIGN.md §5).

    A directed bond is the tuple (i, j, n); its mirror is (j, i, -n).  The
    *canonical* form of the pair is the lexicographically smaller of the
    two tuples — i < j ordering with image canonicalization; self-image
    i-j-i bonds (i == j, n != 0) canonicalize on the image alone.  Bonds
    sharing a canonical form are matched into one undirected entry whose
    stored orientation is the canonically-oriented member's; an unmatched
    bond (pair symmetry broken by ``max_nbr_per_atom`` capping) falls back
    to a singleton entry stored in its own orientation, so the maps are
    total and exact for ANY directed bond list.

    Returns ``(bond_pair, bond_sign, und_rep)``:
      - ``bond_pair (E,) int32``: directed -> undirected id,
      - ``bond_sign (E,) f32``: +1 if the directed bond equals its
        representative's orientation, -1 if it is the mirror,
      - ``und_rep (Nu,) int32``: undirected id -> representative directed
        index (strictly increasing — undirected entries are numbered by
        first appearance of their representative, preserving the sorted
        DESIGN.md §1 locality).

    Invariants (checked by ``repro.batching.validate_layout``): every
    undirected id has exactly one sign=+1 reference and at most one
    sign=-1 reference, and ``bond_sign[und_rep] == +1``.
    """
    e_cnt = int(bond_center.shape[0])
    if e_cnt == 0:
        z = np.zeros((0,), np.int32)
        return z, np.zeros((0,), np.float32), z.copy()
    img = bond_image.astype(np.int64)
    fwd = np.column_stack(
        [bond_center.astype(np.int64), bond_nbr.astype(np.int64), img])
    rev = np.column_stack(
        [bond_nbr.astype(np.int64), bond_center.astype(np.int64), -img])
    # fwd == rev would need i == j and n == -n, i.e. the excluded zero-
    # distance self pair — so exactly one direction is canonical
    is_canon = _lex_less(fwd, rev)
    key = np.where(is_canon[:, None], fwd, rev)
    order = np.lexsort(key.T[::-1])
    ks = key[order]
    boundary = np.empty(e_cnt, dtype=bool)
    boundary[0] = True
    boundary[1:] = np.any(ks[1:] != ks[:-1], axis=1)
    gid = np.empty(e_cnt, np.int64)
    gid[order] = np.cumsum(boundary) - 1
    n_groups = int(gid[order[-1]]) + 1
    # representative: the canonically-oriented member when present (the
    # symmetric case), else the lone survivor (capped fallback)
    rep = np.full(n_groups, e_cnt, np.int64)
    canon_idx = np.nonzero(is_canon)[0]
    np.minimum.at(rep, gid[canon_idx], canon_idx)
    first = np.full(n_groups, e_cnt, np.int64)
    np.minimum.at(first, gid, np.arange(e_cnt))
    rep = np.where(rep == e_cnt, first, rep)
    # number undirected entries by representative position (ascending)
    und_order = np.argsort(rep, kind="stable")
    rank = np.empty(n_groups, np.int64)
    rank[und_order] = np.arange(n_groups)
    bond_pair = rank[gid].astype(np.int32)
    und_rep = rep[und_order].astype(np.int32)
    rep_of = rep[gid]
    same = (
        (bond_center == bond_center[rep_of])
        & (bond_nbr == bond_nbr[rep_of])
        & np.all(bond_image == bond_image[rep_of], axis=1)
    )
    bond_sign = np.where(same, 1.0, -1.0).astype(np.float32)
    return bond_pair, bond_sign, und_rep


def build_angle_mirror_maps(
    angle_ij: np.ndarray, angle_ik: np.ndarray
) -> tuple[np.ndarray, np.ndarray]:
    """Dedup maps for the ordered angle list (angle-pair mirror treatment).

    ``_build_angles`` emits every *ordered* pair of short bonds sharing a
    center, so each unordered pair {ij, ik} (ij != ik — the meshgrid
    excludes the diagonal) appears exactly twice: (ij, ik) and (ik, ij).
    The angle cosine ``sum(v_ij * v_ik) / (d_ij * d_ik + eps)`` is
    *bitwise* symmetric under the swap (elementwise products commute, the
    component sum runs in the same order), so geometry / Fourier basis /
    angle embedding need only run once per unordered pair.

    Mirrors ``build_mirror_maps``: angles sharing the canonical key
    ``(min(ij, ik), max(ij, ik))`` are matched into one undirected angle
    entry whose stored orientation is the ``ij < ik`` member's; an
    unmatched angle (hand-built asymmetric lists) falls back to a
    singleton entry, so the maps are total for ANY angle list.

    Returns ``(angle_pair, und_angle_rep)``:
      - ``angle_pair (Na,) int32``: angle row -> undirected angle id,
      - ``und_angle_rep (Au,) int32``: undirected angle id ->
        representative angle row (strictly increasing — numbered by first
        appearance, preserving the sorted DESIGN.md §1 locality).

    Invariants (checked by ``repro.batching.validate_layout``): every
    undirected angle id has exactly one same-orientation reference and at
    most one swapped reference.
    """
    a_cnt = int(angle_ij.shape[0])
    if a_cnt == 0:
        z = np.zeros((0,), np.int32)
        return z, z.copy()
    ij = angle_ij.astype(np.int64)
    ik = angle_ik.astype(np.int64)
    lo = np.minimum(ij, ik)
    hi = np.maximum(ij, ik)
    order = np.lexsort((hi, lo))
    ks = np.column_stack([lo, hi])[order]
    boundary = np.empty(a_cnt, dtype=bool)
    boundary[0] = True
    boundary[1:] = np.any(ks[1:] != ks[:-1], axis=1)
    gid = np.empty(a_cnt, np.int64)
    gid[order] = np.cumsum(boundary) - 1
    n_groups = int(np.sum(boundary))
    # representative: the (ij < ik)-oriented member when present, else the
    # first member (asymmetric fallback)
    is_canon = ij < ik
    rep = np.full(n_groups, a_cnt, np.int64)
    canon_idx = np.nonzero(is_canon)[0]
    np.minimum.at(rep, gid[canon_idx], canon_idx)
    first = np.full(n_groups, a_cnt, np.int64)
    np.minimum.at(first, gid, np.arange(a_cnt))
    rep = np.where(rep == a_cnt, first, rep)
    # number undirected entries by representative position (ascending)
    und_order = np.argsort(rep, kind="stable")
    rank = np.empty(n_groups, np.int64)
    rank[und_order] = np.arange(n_groups)
    angle_pair = rank[gid].astype(np.int32)
    und_angle_rep = rep[und_order].astype(np.int32)
    return angle_pair, und_angle_rep


def _mirror_partner(ci: np.ndarray, nj: np.ndarray,
                    images: np.ndarray) -> np.ndarray:
    """Index of each directed pair's mirror (j, i, -n) in the same list.

    Pairs whose mirror is absent (asymmetric input) map to themselves.
    Uses the same canonical-key grouping as ``build_mirror_maps``.
    """
    e_cnt = int(ci.shape[0])
    if e_cnt == 0:
        return np.zeros((0,), np.int64)
    img = images.astype(np.int64)
    fwd = np.column_stack([ci.astype(np.int64), nj.astype(np.int64), img])
    rev = np.column_stack([nj.astype(np.int64), ci.astype(np.int64), -img])
    key = np.where(_lex_less(fwd, rev)[:, None], fwd, rev)
    order = np.lexsort(key.T[::-1])
    ks = key[order]
    boundary = np.empty(e_cnt, dtype=bool)
    boundary[0] = True
    boundary[1:] = np.any(ks[1:] != ks[:-1], axis=1)
    gid = np.empty(e_cnt, np.int64)
    gid[order] = np.cumsum(boundary) - 1
    n_groups = int(np.sum(boundary))
    sums = np.zeros(n_groups, np.int64)
    counts = np.zeros(n_groups, np.int64)
    np.add.at(sums, gid, np.arange(e_cnt))
    np.add.at(counts, gid, 1)
    idx = np.arange(e_cnt)
    return np.where(counts[gid] == 2, sums[gid] - idx, idx)


def _graph_from_pairs(
    ci: np.ndarray,
    nj: np.ndarray,
    images: np.ndarray,
    dist: np.ndarray,
    *,
    n: int,
    r_cut_bond: float,
    max_nbr_per_atom: int | None = None,
    cap_mode: str = "symmetric",
) -> GraphIndices:
    """Assemble GraphIndices from pairs already filtered to r_cut_atom."""
    if cap_mode not in ("symmetric", "per_center"):
        raise ValueError(f"unknown cap_mode {cap_mode!r}")
    if max_nbr_per_atom is not None and ci.size > 0:
        # keep the closest max_nbr_per_atom neighbors per center (cap blowup)
        order = np.lexsort((dist, ci))
        ci, nj, images, dist = ci[order], nj[order], images[order], dist[order]
        counts = np.zeros(n, dtype=np.int64)
        keep = np.zeros(ci.shape[0], dtype=bool)
        for idx, c in enumerate(ci):
            if counts[c] < max_nbr_per_atom:
                keep[idx] = True
                counts[c] += 1
        if cap_mode == "symmetric":
            # symmetry-preserving cap (DESIGN.md §6): keep a directed pair
            # iff BOTH directions survived the greedy per-center pass, so
            # the capped graph stays pair-symmetric (Eu == E/2) and the
            # undirected half-graph store (§5) never needs a singleton
            # fallback.  Per-atom degree can undershoot the cap (a kept
            # slot whose mirror lost out is dropped), never overshoot.
            partner = _mirror_partner(ci, nj, images)
            keep = keep & keep[partner]
        ci, nj, images, dist = ci[keep], nj[keep], images[keep], dist[keep]

    # Sorted-segment invariant: bonds sorted by center (stable — preserves
    # the by-distance neighbor order within a center when capped above).
    # ``_candidate_pairs`` already emits centers in row-major order, so
    # this is a near-identity pass; the Verlet refilter path inherits the
    # guarantee for free since boolean keep-masks preserve order.
    if ci.size and np.any(np.diff(ci) < 0):
        order = np.argsort(ci, kind="stable")
        ci, nj, images, dist = ci[order], nj[order], images[order], dist[order]

    bond_center = ci.astype(np.int32)
    bond_nbr = nj.astype(np.int32)
    bond_image = images.astype(np.int32)

    angle_ij, angle_ik = _build_angles(bond_center, dist, r_cut_bond, n)
    # _build_angles walks centers (and within them, sorted short-bond
    # groups) in ascending order, so angle_ij is non-decreasing already;
    # assert cheaply rather than re-sorting.
    assert angle_ij.size == 0 or np.all(np.diff(angle_ij) >= 0)

    # mirror maps (DESIGN.md §5): recomputed from the filtered pairs, so
    # every producer — build_graph AND the Verlet refilter, whose boolean
    # keep-masks preserve pair symmetry exactly (|-v| == |v| bitwise) —
    # emits canonicalized maps
    bond_pair, bond_sign, und_rep = build_mirror_maps(
        bond_center, bond_nbr, bond_image)
    # angle-pair dedup maps: the ordered angle list holds each unordered
    # {ij, ik} twice — build the (angle_pair, und_angle_rep) maps so the
    # model can run angle geometry/Fourier/embed at Au == Na/2 rows
    angle_pair, und_angle_rep = build_angle_mirror_maps(angle_ij, angle_ik)

    return GraphIndices(
        bond_center=bond_center,
        bond_nbr=bond_nbr,
        bond_image=bond_image,
        angle_ij=angle_ij,
        angle_ik=angle_ik,
        bond_pair=bond_pair,
        bond_sign=bond_sign,
        und_rep=und_rep,
        angle_pair=angle_pair,
        und_angle_rep=und_angle_rep,
    )


def build_graph(
    crystal: Crystal,
    r_cut_atom: float = 6.0,
    r_cut_bond: float = 3.0,
    max_nbr_per_atom: int | None = None,
    cap_mode: str = "symmetric",
) -> GraphIndices:
    """Build G^a / G^b index arrays for one crystal (vectorized numpy).

    ``cap_mode`` governs how ``max_nbr_per_atom`` prunes:
      - ``"symmetric"`` (default): a pair is kept iff both directions
        survive the per-center closest-k pass — the capped graph stays
        pair-symmetric, so Eu == E/2 and the undirected bond store packs
        without an ``und_bonds`` override;
      - ``"per_center"``: the legacy greedy cap (exact closest-k degree
        per atom, may break pair symmetry).
    """
    lat = np.asarray(crystal.lattice, dtype=np.float64)
    frac = np.asarray(crystal.frac_coords, dtype=np.float64)
    ci, nj, images, dist = _candidate_pairs(lat, frac, r_cut_atom)
    return _graph_from_pairs(
        ci, nj, images, dist,
        n=frac.shape[0], r_cut_bond=r_cut_bond,
        max_nbr_per_atom=max_nbr_per_atom,
        cap_mode=cap_mode,
    )


class VerletNeighborList:
    """Skin-radius neighbor-list reuse for MD serving.

    Candidate pairs are built once with ``r_cut_atom + skin``; each step
    only re-measures the candidates' distances (O(Nb) instead of the
    O(N^2 * images) full image search) and re-filters them to
    ``r_cut_atom``.  A full rebuild happens only when some atom has moved
    more than ``skin / 2`` (minimum-image displacement) since the last
    rebuild — the classical Verlet-list guarantee that no pair can enter
    the cutoff unseen.  The per-step refilter keeps the result *exactly*
    equal to a from-scratch ``build_graph`` at the current positions.
    """

    def __init__(
        self,
        crystal: Crystal,
        r_cut_atom: float = 6.0,
        r_cut_bond: float = 3.0,
        skin: float = 0.5,
    ):
        if skin < 0.0:
            raise ValueError(f"skin must be >= 0, got {skin}")
        self.r_cut_atom = r_cut_atom
        self.r_cut_bond = r_cut_bond
        self.skin = skin
        self.rebuilds = 0
        self.updates = 0
        self._rebuild(crystal)

    def _rebuild(self, crystal: Crystal) -> None:
        lat = np.asarray(crystal.lattice, dtype=np.float64)
        frac = np.asarray(crystal.frac_coords, dtype=np.float64)
        ci, nj, images, _ = _candidate_pairs(
            lat, frac, self.r_cut_atom + self.skin
        )
        self._ci, self._nj, self._images = ci, nj, images
        self._ref_lat = lat.copy()
        self._ref_frac = frac.copy()
        self.rebuilds += 1

    def max_displacement(self, crystal: Crystal) -> float:
        """Max minimum-image displacement (A) since the last rebuild."""
        dfrac = np.asarray(crystal.frac_coords, np.float64) - self._ref_frac
        dfrac -= np.round(dfrac)  # wrap-safe: minimum-image convention
        disp = np.linalg.norm(dfrac @ self._ref_lat, axis=-1)
        return float(disp.max()) if disp.size else 0.0

    def needs_rebuild(self, crystal: Crystal) -> bool:
        if not np.allclose(crystal.lattice, self._ref_lat):
            return True
        return self.max_displacement(crystal) > 0.5 * self.skin

    def update(self, crystal: Crystal) -> GraphIndices:
        """Neighbor graph at the crystal's current positions."""
        self.updates += 1
        if self.needs_rebuild(crystal):
            self._rebuild(crystal)
        lat = np.asarray(crystal.lattice, dtype=np.float64)
        frac = np.asarray(crystal.frac_coords, np.float64)
        # MD drivers wrap frac coords into [0, 1) every step; the stored
        # candidate images refer to the *continuous* trajectory.  Recover
        # the integer wrap offsets (exact while displacement < cell/2,
        # guaranteed by the skin/2 rebuild trigger) and shift the images so
        # they stay consistent with the wrapped coordinates the model sees.
        wrap = np.round(frac - self._ref_frac)
        cart = (frac - wrap) @ lat  # continuous (unwrapped) positions
        vec = (cart[self._nj] + self._images @ lat - cart[self._ci])
        dist = np.linalg.norm(vec, axis=-1)
        keep = (dist <= self.r_cut_atom) & (dist > 1e-8)
        images = (
            self._images[keep]
            - wrap[self._nj[keep]].astype(np.int64)
            + wrap[self._ci[keep]].astype(np.int64)
        )
        return _graph_from_pairs(
            self._ci[keep], self._nj[keep], images, dist[keep],
            n=crystal.num_atoms, r_cut_bond=self.r_cut_bond,
        )
