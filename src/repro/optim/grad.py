"""Gradient transformations: clipping and compression (distributed tricks).

``compress_decompress``: bf16 gradient compression for the cross-device
all-reduce (halves collective bytes) with optional error-feedback state so
the quantization error is re-injected next step (keeps Adam convergence;
standard EF-SGD trick). The paper only overlaps communication; compression
is one of our beyond-paper distributed optimizations (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def tree_all_finite(tree: Any) -> jnp.ndarray:
    """Scalar bool: every element of every leaf is finite (the loss-scaler
    skip predicate, DESIGN.md §4)."""
    leaves = [jnp.all(jnp.isfinite(x)) for x in jax.tree.leaves(tree)]
    if not leaves:
        return jnp.asarray(True)
    return jnp.all(jnp.stack(leaves))


def unscale_grads(grads: Any, scale) -> Any:
    """Undo loss scaling and upcast to f32 — BEFORE clipping, so the clip
    threshold is in true-gradient units (DESIGN.md §4)."""
    inv = 1.0 / jnp.asarray(scale, jnp.float32)
    return jax.tree.map(lambda g: g.astype(jnp.float32) * inv, grads)


def global_norm(tree: Any) -> jnp.ndarray:
    return jnp.sqrt(
        sum(jnp.sum(x.astype(jnp.float32) ** 2) for x in jax.tree.leaves(tree))
    )


def clip_by_global_norm(tree: Any, max_norm: float) -> Any:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return jax.tree.map(lambda x: x * scale, tree)


def ef_init(params: Any) -> Any:
    """Error-feedback residual state (zeros like grads)."""
    return jax.tree.map(jnp.zeros_like, params)


def compress(grads: Any, ef_state: Any | None = None):
    """Quantize grads to bf16 (+error feedback). Returns (q, new_ef)."""
    if ef_state is not None:
        grads = jax.tree.map(lambda g, e: g + e, grads, ef_state)
    q = jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)
    if ef_state is not None:
        new_ef = jax.tree.map(
            lambda g, qq: g - qq.astype(g.dtype), grads, q
        )
    else:
        new_ef = None
    return q, new_ef


def decompress(q: Any, dtype=jnp.float32) -> Any:
    return jax.tree.map(lambda g: g.astype(dtype), q)
