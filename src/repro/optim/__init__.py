"""Optimizer substrate: Adam/AdamW, schedules (Eq. 14), grad transforms."""
from .adam import AdamConfig, adam_init, adam_update
from .grad import (
    clip_by_global_norm,
    compress,
    decompress,
    ef_init,
    global_norm,
    tree_all_finite,
    unscale_grads,
)
from .schedule import cosine_annealing, scaled_init_lr

__all__ = [
    "AdamConfig", "adam_init", "adam_update", "clip_by_global_norm",
    "compress", "decompress", "ef_init", "global_norm",
    "tree_all_finite", "unscale_grads",
    "cosine_annealing", "scaled_init_lr",
]
