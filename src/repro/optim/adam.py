"""Adam / AdamW in pure JAX (paper §IV uses Adam).

Functional API mirroring optax: ``init(params) -> state``,
``update(grads, state, params, lr) -> (new_params, new_state)``.
State is a plain pytree -> checkpointable with runtime.checkpoint.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0  # 0 => plain Adam


def adam_init(params: Any) -> dict:
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {
        "mu": zeros,
        "nu": jax.tree.map(jnp.zeros_like, params),
        "count": jnp.zeros((), jnp.int32),
    }


def adam_update(
    grads: Any,
    state: dict,
    params: Any,
    lr: jnp.ndarray | float,
    cfg: AdamConfig = AdamConfig(),
) -> tuple[Any, dict]:
    count = state["count"] + 1
    b1, b2 = cfg.b1, cfg.b2
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["mu"], grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state["nu"], grads)
    c = count.astype(jnp.float32)
    bc1 = 1.0 - b1**c
    bc2 = 1.0 - b2**c

    def step(p, m, v):
        upd = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        if cfg.weight_decay:
            upd = upd + cfg.weight_decay * p
        return p - lr * upd

    new_params = jax.tree.map(step, params, mu, nu)
    return new_params, {"mu": mu, "nu": nu, "count": count}
