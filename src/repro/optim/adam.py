"""Adam / AdamW in pure JAX (paper §IV uses Adam).

Functional API mirroring optax: ``init(params) -> state``,
``update(grads, state, params, lr) -> (new_params, new_state)``.
State is a plain pytree -> checkpointable with runtime.checkpoint.

Mixed precision (DESIGN.md §4): ``adam_init(params, master_dtype=...)``
grows an f32 **master copy** of low-precision parameters inside the state
(``state["master"]``); ``adam_update`` then steps the master weights (and
keeps the moments at master precision) and returns a cast-to-param-dtype
view as the new live params.  Policies whose ``param_dtype`` is already
f32 (``"f32"``, ``"mixed"``) need no master copy — the params *are* the
master weights.  Extra keys on the state dict (e.g. the trainer's
``"loss_scale"`` subtree) pass through ``adam_update`` untouched.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.precision import cast_float_tree


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0  # 0 => plain Adam


def adam_init(params: Any, *, master_dtype=None) -> dict:
    """``master_dtype`` (e.g. ``jnp.float32``) adds a master-weight copy
    for low-precision params; moments are kept at master precision."""
    ref = params if master_dtype is None \
        else cast_float_tree(params, master_dtype)
    state = {
        "mu": jax.tree.map(jnp.zeros_like, ref),
        "nu": jax.tree.map(jnp.zeros_like, ref),
        "count": jnp.zeros((), jnp.int32),
    }
    if master_dtype is not None:
        state["master"] = ref
    return state


def adam_update(
    grads: Any,
    state: dict,
    params: Any,
    lr: jnp.ndarray | float,
    cfg: AdamConfig = AdamConfig(),
) -> tuple[Any, dict]:
    master = state.get("master")
    target = params if master is None else master
    # grads arrive at whatever precision the backward produced; the moment
    # update and the step itself run at master precision
    grads = jax.tree.map(lambda g, t: g.astype(t.dtype), grads, target)
    count = state["count"] + 1
    b1, b2 = cfg.b1, cfg.b2
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["mu"], grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state["nu"], grads)
    c = count.astype(jnp.float32)
    bc1 = 1.0 - b1**c
    bc2 = 1.0 - b2**c

    def step(p, m, v):
        upd = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        if cfg.weight_decay:
            upd = upd + cfg.weight_decay * p
        return p - lr * upd

    new_target = jax.tree.map(step, target, mu, nu)
    new_state = dict(state, mu=mu, nu=nu, count=count)
    if master is None:
        return new_target, new_state
    new_state["master"] = new_target
    # live params are a cast-to-param-dtype view of the master weights
    new_params = jax.tree.map(
        lambda t, p: t.astype(p.dtype), new_target, params)
    return new_params, new_state
