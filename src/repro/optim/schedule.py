"""Learning-rate schedules (paper §III-C 'Learning Rate Schedule', Eq. 14).

- ``scaled_init_lr``: the paper's large-batch rule
      init_LR = batchsize / k * 0.0003,  k = 128.
- ``cosine_annealing``: the paper's scheduler, with optional linear warmup
  (warmup is the standard large-batch stabilizer; 0 disables it to match
  the paper exactly).
"""
from __future__ import annotations

import jax.numpy as jnp


def scaled_init_lr(batch_size: int, k: int = 128, base_lr: float = 3e-4) -> float:
    """Eq. 14: LR grows linearly with the global batch size."""
    return batch_size / k * base_lr


def cosine_annealing(
    step: jnp.ndarray,
    total_steps: int,
    init_lr: float,
    *,
    warmup_steps: int = 0,
    min_lr_ratio: float = 0.0,
):
    step_f = jnp.asarray(step, jnp.float32)
    warm = init_lr * step_f / jnp.maximum(warmup_steps, 1)
    prog = (step_f - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = init_lr * (
        min_lr_ratio + (1 - min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    )
    return jnp.where(step_f < warmup_steps, warm, cos)
