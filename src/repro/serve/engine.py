"""Batched MD serving engine (the paper's Table II workload, productionized).

Charge-informed MD is a *serving* workload: millions of one-step E/F/sigma
predictions with a direct-force readout.  Three levers over the naive
"rebuild the neighbor list and re-jit every step" loop:

  1. **Verlet skin reuse** (``repro.core.neighbors.VerletNeighborList``):
     candidate pairs are built once with ``r_cut + skin`` and only
     re-measured per step; the O(N^2 * images) image search runs only when
     an atom has moved more than ``skin/2``.
  2. **Multi-replica batching**: many independent simulations are stepped
     as *one* padded batch per capacity bucket — one device program per
     group instead of one per replica.
  3. **Persistent compiled serve step per bucket**: step functions are
     memoized in the shared ``repro.batching`` compile cache keyed on
     ``(bucket, slots, config)``, so group membership can change freely
     without re-tracing.

Every batch leaving the pack path satisfies the sorted-segment layout
(DESIGN.md §1) — the Verlet refilter preserves bond order and packing
canonicalizes + validates — so the serve step can run any
``CHGNetConfig.agg_impl`` ("scatter" | "matmul" | "sorted" | "pallas")
and ``conv_impl`` ("unfused" | "fused", the DESIGN.md §3 message-passing
megakernels) unchanged; set ``validate_layout=False`` to skip the
per-batch check in tight MD loops.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.batching import (
    BatchCapacities,
    BatchingEngine,
    CapacityLadder,
    CompileCache,
    atom_offsets,
    ladder_from_stats,
)
from repro.core.chgnet import CHGNetConfig, chgnet_apply
from repro.core.neighbors import (
    Crystal,
    GraphIndices,
    VerletNeighborList,
    build_graph,
)


def _next_pow2(k: int) -> int:
    return 1 << max(0, (k - 1).bit_length())


def structure_ladder(
    graphs: list[GraphIndices],
    crystals: list[Crystal],
    *,
    num_buckets: int = 3,
    margin: float = 1.5,
    align: int = 32,
) -> CapacityLadder:
    """Per-structure capacity ladder sized from observed structures.

    ``margin`` leaves headroom for bond/angle-count fluctuation as atoms
    move during MD (the overflow path still catches outliers without
    truncating).
    """
    atoms = np.array([c.num_atoms for c in crystals])
    bonds = np.array([g.num_bonds for g in graphs])
    angles = np.array([g.num_angles for g in graphs])
    return ladder_from_stats(
        atoms, bonds, angles, per_device_batch=1,
        num_buckets=num_buckets, margin=margin, align=align,
    )


class ServeEngine:
    """One-step E/F/sigma/magmom prediction over bucketed padded batches.

    ``precision`` overrides ``model_cfg.precision`` for serving (DESIGN.md
    §4): MD inference typically wants ``"mixed"`` — bf16 GEMM/VMEM
    operands halve the activation footprint per replica slot while the
    accum-pinned reductions keep E/F/sigma at f32 quality, and outputs
    are f32 either way (``output_dtype``), so integrators see no change.
    Params may stay f32 (training layout): the model casts per-use.
    """

    def __init__(
        self,
        params,
        model_cfg: CHGNetConfig,
        ladder: CapacityLadder,
        *,
        cache: CompileCache | None = None,
        validate_layout: bool = True,
        precision: str | None = None,
    ):
        if precision is not None:
            model_cfg = model_cfg.with_(precision=precision)
        self.params = params
        self.model_cfg = model_cfg
        self.engine = BatchingEngine(ladder, cache,
                                     validate_layout=validate_layout)

    @classmethod
    def for_structures(
        cls,
        params,
        model_cfg: CHGNetConfig,
        crystals: list[Crystal],
        graphs: list[GraphIndices] | None = None,
        validate_layout: bool = True,
        precision: str | None = None,
        **ladder_kw,
    ) -> "ServeEngine":
        graphs = graphs or [
            build_graph(c, model_cfg.r_cut_atom, model_cfg.r_cut_bond)
            for c in crystals
        ]
        return cls(params, model_cfg,
                   structure_ladder(graphs, crystals, **ladder_kw),
                   validate_layout=validate_layout, precision=precision)

    def admission_check(self, caps: BatchCapacities) -> None:
        """Refuse early (clear error) what the vmem tier cannot serve.

        Under ``table_residency="vmem"`` a batch whose operand tables
        exceed the VMEM budget would only fail deep inside kernel
        lowering (or OOM the device); check at admission instead and
        point at the fix.  ``"auto"`` (the default) and ``"hbm"`` admit
        ANY capacity — the tables stream through the DESIGN.md §9
        double-buffered DMA tier, so 10k+-atom structures pack and serve
        instead of erroring.
        """
        cfg = self.model_cfg
        if cfg.table_residency != "vmem":
            return
        from repro.kernels.ops import estimate_table_bytes, vmem_budget_bytes

        table_bytes = estimate_table_bytes(
            caps.atoms, caps.bonds, caps.angles, cfg.dim,
            num_und=caps.und_cap if cfg.bond_store == "undirected" else None,
        )
        budget = vmem_budget_bytes()
        if table_bytes > budget:
            raise ValueError(
                f"batch capacities {caps} need ~{table_bytes} operand-table "
                f"bytes, over the {budget}-byte VMEM budget; serve with "
                f"table_residency='auto' (or 'hbm') to stream tables from "
                f"HBM (DESIGN.md §9)"
            )

    def step_fn(self, caps: BatchCapacities, num_slots: int):
        """Persistent compiled serve step for (bucket, slots, config).

        The batch argument is donated (each packed batch is consumed
        exactly once), so its buffers back the outputs instead of a fresh
        allocation per MD step; params stay undonated — every replica
        group reuses them.
        """
        cfg = self.model_cfg

        def build():
            return jax.jit(lambda p, b: chgnet_apply(p, cfg, b),
                           donate_argnums=(1,))

        return self.engine.compiled("serve", caps, num_slots, cfg, build)

    def predict(
        self,
        crystals: list[Crystal],
        graphs: list[GraphIndices] | None = None,
    ) -> dict:
        """Predict E/F/sigma/magmom for a list of structures as one batch.

        Returns host-side per-structure arrays: ``energy`` (R,), ``forces``
        a list of (N_i, 3), ``stress`` (R, 3, 3), ``magmom`` list of (N_i,).
        """
        if graphs is None:
            graphs = [
                build_graph(c, self.model_cfg.r_cut_atom,
                            self.model_cfg.r_cut_bond)
                for c in crystals
            ]
        slots = _next_pow2(len(crystals))
        bucket = self.engine.ladder.bucket_for(
            max(c.num_atoms for c in crystals),
            max(g.num_bonds for g in graphs),
            max(g.num_angles for g in graphs),
        )
        caps = bucket.scaled(slots)
        self.admission_check(caps)
        batch, _ = self.engine.pack(
            crystals, graphs, caps=caps, num_crystal_slots=slots
        )
        out = self.step_fn(bucket, slots)(self.params, batch)
        jax.block_until_ready(out["forces"])
        offs = atom_offsets(crystals)
        forces = np.asarray(out["forces"])
        magmom = np.asarray(out["magmom"])
        return {
            "energy": np.asarray(out["energy"])[: len(crystals)],
            "forces": [
                forces[o:o + c.num_atoms] for o, c in zip(offs, crystals)
            ],
            "stress": np.asarray(out["stress"])[: len(crystals)],
            "magmom": [
                magmom[o:o + c.num_atoms] for o, c in zip(offs, crystals)
            ],
        }

    def stats(self) -> dict:
        return self.engine.stats()


@dataclasses.dataclass
class _Replica:
    crystal: Crystal
    velocities: np.ndarray
    nlist: VerletNeighborList
    inv_lattice: np.ndarray


class BatchedMD:
    """Multi-replica MD: independent simulations stepped as padded batches.

    Replicas are grouped per step by their capacity bucket; each group is
    packed into one batch (slots padded to a power of two so the compile
    cache stays small) and stepped by the persistent compiled serve
    function.  Integration is the toy NVE velocity update of the seed's
    ``examples/serve_md.py`` (unit masses) — the point here is the serving
    substrate, not the integrator.
    """

    def __init__(
        self,
        serve: ServeEngine,
        crystals: list[Crystal],
        *,
        dt: float = 1e-3,
        skin: float = 0.5,
        max_group: int = 16,
    ):
        self.serve = serve
        self.dt = dt
        self.max_group = max_group
        cfg = serve.model_cfg
        self.replicas = [
            _Replica(
                crystal=c,
                velocities=np.zeros((c.num_atoms, 3)),
                nlist=VerletNeighborList(
                    c, cfg.r_cut_atom, cfg.r_cut_bond, skin
                ),
                inv_lattice=np.linalg.inv(c.lattice),
            )
            for c in crystals
        ]
        self.steps_done = 0

    @property
    def num_replicas(self) -> int:
        return len(self.replicas)

    def _grouped(self, graphs: list[GraphIndices]):
        """Group replica ids by per-structure bucket, chunked to max_group."""
        ladder = self.serve.engine.ladder
        by_bucket: dict[BatchCapacities, list[int]] = {}
        for i, (r, g) in enumerate(zip(self.replicas, graphs)):
            b = ladder.bucket_for(
                r.crystal.num_atoms, g.num_bonds, g.num_angles
            )
            by_bucket.setdefault(b, []).append(i)
        for bucket, ids in by_bucket.items():
            for s in range(0, len(ids), self.max_group):
                yield bucket, ids[s:s + self.max_group]

    def step(self, n_steps: int = 1) -> dict:
        """Advance every replica ``n_steps``; returns last-step outputs."""
        last = {}
        for _ in range(n_steps):
            graphs = [r.nlist.update(r.crystal) for r in self.replicas]
            energies = np.zeros(self.num_replicas)
            forces_by_replica: list[np.ndarray | None] = [None] * self.num_replicas
            # dispatch every group first (jax dispatch is async) so device
            # compute of group k overlaps host packing of group k+1 ...
            dispatched = []
            for bucket, ids in self._grouped(graphs):
                crystals = [self.replicas[i].crystal for i in ids]
                slots = _next_pow2(len(ids))
                caps = bucket.scaled(slots)
                batch, _ = self.serve.engine.pack(
                    crystals, graphs=[graphs[i] for i in ids],
                    caps=caps, num_crystal_slots=slots,
                )
                out = self.serve.step_fn(bucket, slots)(
                    self.serve.params, batch
                )
                dispatched.append((ids, crystals, out))
            # ... then collect (np.asarray blocks per output)
            for ids, crystals, out in dispatched:
                f = np.asarray(out["forces"])
                e = np.asarray(out["energy"])
                offs = atom_offsets(crystals)
                for k, i in enumerate(ids):
                    na = crystals[k].num_atoms
                    forces_by_replica[i] = f[offs[k]:offs[k] + na]
                    energies[i] = e[k]
            # toy NVE update (unit masses) — exercises the serve path
            for r, f in zip(self.replicas, forces_by_replica):
                r.velocities += f * self.dt
                cart = r.crystal.cart_coords() + r.velocities * self.dt
                r.crystal.frac_coords = (cart @ r.inv_lattice) % 1.0
            self.steps_done += 1
            last = {"energy": energies, "forces": forces_by_replica}
        return last

    def stats(self) -> dict:
        s = self.serve.stats()
        s.update(
            steps_done=self.steps_done,
            nlist_rebuilds=sum(r.nlist.rebuilds for r in self.replicas),
            nlist_updates=sum(r.nlist.updates for r in self.replicas),
        )
        return s
