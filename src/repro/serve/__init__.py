"""MD serving subsystem: bucketed batched inference + Verlet-skin reuse.

Built on the shared ``repro.batching`` engine; see ``engine.py``.
"""
from .engine import BatchedMD, ServeEngine, structure_ladder

__all__ = ["BatchedMD", "ServeEngine", "structure_ladder"]
