"""Collective helpers used inside shard_map train steps (paper C8).

``bucketed_psum``: all-reduce the gradient pytree in size-bounded buckets.
On GPU/NCCL the paper overlaps bucketed all-reduce with the tail of the
backward pass; under XLA the latency-hiding scheduler overlaps async
collectives automatically — bucketing still matters because it bounds
each collective's exposure and lets earlier buckets start while later
gradient math is in flight (the HLO keeps them as independent all-reduces).

``compressed_psum``: bf16-compress -> psum -> decompress (halves collective
bytes; combine with optim.grad error feedback across steps).
"""
from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp


def bucketed_psum(tree: Any, axis_names: Sequence[str] | str,
                  bucket_bytes: int = 4 << 20) -> Any:
    """psum the pytree leaf-by-leaf in buckets of ~bucket_bytes."""
    leaves, treedef = jax.tree.flatten(tree)
    out: list = [None] * len(leaves)
    bucket: list[int] = []
    size = 0

    def flush():
        nonlocal bucket, size
        if not bucket:
            return
        vals = jax.lax.psum(tuple(leaves[i] for i in bucket), axis_names)
        for i, v in zip(bucket, vals):
            out[i] = v
        bucket, size = [], 0

    for i, leaf in enumerate(leaves):
        nbytes = leaf.size * leaf.dtype.itemsize
        if size + nbytes > bucket_bytes and bucket:
            flush()
        bucket.append(i)
        size += nbytes
    flush()
    return jax.tree.unflatten(treedef, out)


def compressed_psum(tree: Any, axis_names: Sequence[str] | str,
                    dtype=jnp.float32) -> Any:
    """bf16-compressed all-reduce (half the collective bytes)."""
    q = jax.tree.map(lambda g: g.astype(jnp.bfloat16), tree)
    summed = jax.lax.psum(q, axis_names)
    return jax.tree.map(lambda g: g.astype(dtype), summed)


def pmean_metrics(metrics: Any, axis_names: Sequence[str] | str) -> Any:
    return jax.lax.pmean(metrics, axis_names)
