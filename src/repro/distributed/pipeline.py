"""GPipe-style pipeline parallelism over a 'pipe' mesh axis (DESIGN.md §5).

For 1000+-node scaling a third mesh axis is warranted; this module
provides the schedule: layers are split into S stages (stage s owns the
contiguous block of L/S layers, params sharded P('pipe') on the stacked
leading dim), microbatches stream through with ``lax.ppermute`` hops.
The fill/drain bubble is the standard (S-1)/(M+S-1) fraction.

Differentiation: jax.grad through the scan+ppermute schedule yields the
reversed (drain-first) pipeline automatically — ppermute transposes to
the inverse permutation — so the same function trains.

Used inside ``shard_map(..., in_specs=(P("pipe"), P()), out_specs=P())``;
see tests/test_pipeline_parallel.py for the 4-stage device test.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def gpipe_apply(
    stage_params,
    x_microbatches: jnp.ndarray,
    stage_fn: Callable,
    *,
    axis: str = "pipe",
):
    """Run (M, mb, ...) microbatches through S pipeline stages.

    stage_params: this device's stage parameters (leading dim = layers
        of this stage) — pass through shard_map with in_spec P(axis).
    x_microbatches: (M, mb, ...) inputs, replicated across stages.
    stage_fn(stage_params, x) -> y: applies ONE stage's layers.

    Returns (M, mb, ...) outputs (replicated — psum'd off the last stage).
    """
    s = jax.lax.axis_size(axis)
    idx = jax.lax.axis_index(axis)
    # shard_map keeps the P(axis)-sharded leading dim at local size 1
    stage_params = jax.tree.map(lambda a: a[0], stage_params)
    m = x_microbatches.shape[0]
    steps = m + s - 1
    perm = [(i, (i + 1) % s) for i in range(s)]

    buf = jnp.zeros_like(x_microbatches[0])
    outputs = jnp.zeros_like(x_microbatches)

    def step(carry, t):
        buf, outputs = carry
        # stage 0 ingests microbatch t while t < M; later stages consume
        # the activation received from the previous stage
        take = jnp.clip(t, 0, m - 1)
        x_in = jnp.where(idx == 0, x_microbatches[take], buf)
        y = stage_fn(stage_params, x_in)
        # the last stage's result at step t is microbatch t-(S-1)
        out_t = t - (s - 1)
        valid = (out_t >= 0) & (out_t < m) & (idx == s - 1)
        outputs = jax.lax.cond(
            valid,
            lambda o: o.at[jnp.clip(out_t, 0, m - 1)].set(y),
            lambda o: o,
            outputs,
        )
        buf = jax.lax.ppermute(y, axis, perm)
        return (buf, outputs), None

    (_, outputs), _ = jax.lax.scan(
        step, (buf, outputs), jnp.arange(steps))
    # broadcast the last stage's outputs to all stages
    outputs = jax.lax.psum(
        jnp.where(idx == s - 1, outputs, jnp.zeros_like(outputs)), axis)
    return outputs


def split_stages(layer_params, num_stages: int):
    """Reshape stacked (L, ...) layer params into (S, L/S, ...) for
    P('pipe') sharding of the leading dim."""
    def re(a):
        l = a.shape[0]
        assert l % num_stages == 0, (l, num_stages)
        return a.reshape((num_stages, l // num_stages) + a.shape[1:])

    return jax.tree.map(re, layer_params)


def bubble_fraction(num_stages: int, num_microbatches: int) -> float:
    """GPipe fill/drain overhead: (S-1) / (M+S-1)."""
    return (num_stages - 1) / (num_microbatches + num_stages - 1)
