"""Distribution substrate: collectives, sharding rules, pipeline parallel."""
from .collectives import bucketed_psum, compressed_psum, pmean_metrics
from .pipeline import bubble_fraction, gpipe_apply, split_stages

__all__ = ["bucketed_psum", "compressed_psum", "pmean_metrics",
           "bubble_fraction", "gpipe_apply", "split_stages"]
