"""Roofline analysis from the dry-run's compiled artifacts (§Roofline).

Per (arch x shape x mesh) cell:
    compute term    = FLOPs / (chip peak FLOP/s)          [s/step/chip]
    memory term     = HBM bytes / (chip HBM bandwidth)    [s/step/chip]
    collective term = collective bytes / (chip link BW)   [s/step/chip]

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (brief-specified constants).

Sources & corrections:
  - ``cost_analysis()`` FLOPs / bytes are PER-DEVICE but count each
    ``lax.scan`` body ONCE (measured in this repo: an 8-step scanned
    matmul reports 1/8 the unrolled FLOPs). All deep stacks here are
    scanned (layers, microbatches, attention chunks), so raw HLO numbers
    underestimate by the trip products.
  - We therefore compute ANALYTIC per-device FLOPs from the architecture
    (functions below) and scale the HLO bytes / collective bytes by the
    same correction factor  corr = analytic_flops / hlo_flops  (both are
    dominated by the same per-layer body, so the first-order scaling is
    shared). Raw and corrected values are both reported.
  - collective bytes come from parsing the partitioned HLO (dryrun.py):
    per-op ring-traffic model, per chip.
  - XLA:CPU promotes some bf16 buffers to f32 (memory_analysis run on the
    CPU backend overstates those by up to 2x); noted where it matters.

MODEL_FLOPS = 6 * N * D (dense) or 6 * N_active * D (MoE), D = tokens —
the "useful" fraction MODEL_FLOPS / FLOPs catches remat/redundancy waste
(values < 1/3 here mean heavy remat; ~1/3 is one full recompute).
"""
from __future__ import annotations

import dataclasses
import json
import math

PEAK_FLOPS = 197e12   # bf16 / chip
HBM_BW = 819e9        # bytes/s / chip
LINK_BW = 50e9        # bytes/s / link (ICI)


# ---------------------------------------------------------------------------
# analytic FLOP models
# ---------------------------------------------------------------------------

def _param_counts(cfg):
    """(total, active, matmul-active-excl-embed-gather) parameter counts."""
    import jax

    from repro.models.api import family_fns

    fns = family_fns(cfg)
    tree = jax.eval_shape(lambda: fns.init(cfg, jax.random.PRNGKey(0)))
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    total = active = mm = 0
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        n = int(math.prod(leaf.shape))
        total += n
        is_embed_gather = "embed" in key and "unembed" not in key
        frac = 1.0
        if cfg.is_moe and ("we_gate" in key or "we_up" in key
                           or "we_down" in key):
            frac = (cfg.moe.top_k * cfg.moe.capacity_factor
                    / cfg.moe.num_experts)
            frac = min(1.0, frac)
        active += int(n * frac)
        if not is_embed_gather or cfg.tie_embeddings:
            mm += int(n * frac)
    return total, active, mm


def _attn_quad_flops(cfg, batch, seq, *, kv_len=None, layers=None):
    """QK^T + AV matmul FLOPs for full (masked) attention."""
    hd = cfg.resolved_head_dim
    h = cfg.num_heads
    kv = kv_len if kv_len is not None else seq
    n_layers = layers if layers is not None else cfg.num_layers
    return 4.0 * batch * seq * kv * h * hd * n_layers


def analytic_flops(cfg, shape) -> dict:
    """Global (all-chip) FLOPs for one step of this cell + MODEL_FLOPS."""
    b, s = shape.batch, shape.seq
    total, active, mm = _param_counts(cfg)

    if shape.kind == "train":
        tokens = b * s
        fwd = 2.0 * mm * tokens
        if cfg.family in ("dense", "moe", "vlm"):
            fwd += _attn_quad_flops(cfg, b, s)
        elif cfg.family == "encdec":
            fwd += _attn_quad_flops(cfg, b, s)                      # enc self
            fwd += _attn_quad_flops(cfg, b, s, layers=cfg.num_decoder_layers)
            fwd += _attn_quad_flops(cfg, b, s, layers=cfg.num_decoder_layers)
        elif cfg.family == "hybrid":
            c = 128  # ssd chunk: intra-chunk quadratic form per token ~ c
            ssd = cfg.num_layers * b * s * 2.0 * c * (
                cfg.ssm_state + cfg.ssm_head_dim)
            sites = cfg.num_layers // cfg.attn_every
            fwd += ssd + _attn_quad_flops(cfg, b, s, layers=sites)
        elif cfg.family == "rwkv":
            nh = cfg.d_model // cfg.rwkv_head_dim
            k = v = cfg.rwkv_head_dim
            fwd += 6.0 * cfg.num_layers * b * s * nh * k * v
        flops = 3.0 * fwd      # fwd + 2x bwd
        # default-policy remat: one extra forward recompute
        flops_with_remat = flops + fwd
        model = 6.0 * active * tokens
        return {"flops": flops_with_remat, "flops_noremat": flops,
                "model_flops": model}

    if shape.kind == "prefill":
        tokens = b * s
        fwd = 2.0 * mm * tokens
        if cfg.family in ("dense", "moe", "vlm"):
            fwd += _attn_quad_flops(cfg, b, s)
        elif cfg.family == "encdec":
            fwd += _attn_quad_flops(cfg, b, s)
        elif cfg.family == "hybrid":
            c = 128
            fwd += cfg.num_layers * b * s * 2.0 * c * (
                cfg.ssm_state + cfg.ssm_head_dim)
            fwd += _attn_quad_flops(cfg, b, s,
                                    layers=cfg.num_layers // cfg.attn_every)
        elif cfg.family == "rwkv":
            nh = cfg.d_model // cfg.rwkv_head_dim
            fwd += 6.0 * cfg.num_layers * b * s * nh * cfg.rwkv_head_dim ** 2
        return {"flops": fwd, "model_flops": 2.0 * active * tokens}

    # decode: one token against a seq-long state
    fwd = 2.0 * mm * b
    if cfg.family in ("dense", "moe", "vlm"):
        fwd += _attn_quad_flops(cfg, b, 1, kv_len=s)
    elif cfg.family == "encdec":
        fwd += _attn_quad_flops(cfg, b, 1, kv_len=s,
                                layers=cfg.num_decoder_layers) * 2
    elif cfg.family == "hybrid":
        d_in = cfg.ssm_expand * cfg.d_model
        nh = d_in // cfg.ssm_head_dim
        fwd += 6.0 * cfg.num_layers * b * nh * cfg.ssm_head_dim * cfg.ssm_state
        fwd += _attn_quad_flops(cfg, b, 1, kv_len=s,
                                layers=cfg.num_layers // cfg.attn_every)
    elif cfg.family == "rwkv":
        nh = cfg.d_model // cfg.rwkv_head_dim
        fwd += 6.0 * cfg.num_layers * b * nh * cfg.rwkv_head_dim ** 2
    return {"flops": fwd, "model_flops": 2.0 * active * b}


def analytic_collective_bytes(cfg, shape, *, chips, model_par, dp_total,
                              accum: int) -> float:
    """Per-chip ICI traffic model [bytes/step], leading terms only.

    train:   FSDP weight all-gathers (per pass) + grad reduce-scatter/
             all-gather (once) + TP activation all-reduces (per layer)
    prefill: TP activation all-reduces + weight gathers (once)
    decode:  TP all-reduces of the (B,1,d) residual per layer
    The HLO-parsed collective schedule (op counts/types per compiled
    module) cross-checks the *structure*; it cannot be summed across scan
    trip counts directly, hence this analytic model.
    """
    total, active, mm = _param_counts(cfg)
    b, s = shape.batch, shape.seq
    d = cfg.d_model
    layers = cfg.num_layers + cfg.num_decoder_layers
    w_shard = 2.0 * active / model_par        # bf16 weights per TP shard
    fsdp_frac = (dp_total - 1) / dp_total

    if shape.kind == "train":
        tok_chip = b * s / dp_total
        w_gather = 3.0 * accum * w_shard * fsdp_frac
        grad_sync = 2.0 * 4.0 * total / chips * fsdp_frac * 2.0
        # Megatron TP: ~2 act all-reduces/layer fwd + 2 bwd (x2 ring)
        tp_act = layers * tok_chip * d * 2.0 * 4.0 * 2.0
        return w_gather + grad_sync + tp_act

    if shape.kind == "prefill":
        tok_chip = b * s / dp_total
        tp_act = layers * tok_chip * d * 2.0 * 2.0 * 2.0
        return tp_act + w_shard * fsdp_frac

    b_chip = max(1.0, b / dp_total)
    return layers * b_chip * d * 2.0 * 2.0 * 2.0


def decode_state_bytes(cfg, batch, seq) -> float:
    """Global decode-state bytes (bf16 KV caches + recurrent states)."""
    hd = cfg.resolved_head_dim
    if cfg.family in ("dense", "moe", "vlm"):
        return cfg.num_layers * batch * seq * cfg.num_kv_heads * hd * 2 * 2
    if cfg.family == "encdec":
        return 2 * cfg.num_decoder_layers * batch * seq \
            * cfg.num_kv_heads * hd * 2 * 2
    if cfg.family == "hybrid":
        sites = cfg.num_layers // cfg.attn_every
        kv = sites * batch * seq * cfg.num_kv_heads * hd * 2 * 2
        d_in = cfg.ssm_expand * cfg.d_model
        nh = d_in // cfg.ssm_head_dim
        ssm = cfg.num_layers * batch * nh * cfg.ssm_head_dim \
            * cfg.ssm_state * 4
        return kv + ssm
    if cfg.family == "rwkv":
        nh = cfg.d_model // cfg.rwkv_head_dim
        return cfg.num_layers * batch * nh * cfg.rwkv_head_dim ** 2 * 4
    raise ValueError(cfg.family)


def analytic_bytes(cfg, shape, *, chips, model_par, dp_total,
                   accum: int) -> float:
    """Per-chip HBM traffic model [bytes/step].

    Counted flows (bf16 compute, f32 optimizer):
      - weights: each pass reads the TP-sharded bf16 weights once;
        train = accum x (fwd + bwd + remat-fwd) = 3*accum passes
      - optimizer: p/m/v f32 read + write, grads f32 read (FSDP-sharded)
      - activations: layer carries r/w per microbatch (bf16)
      - logits/CE: f32 logits + one-hot product r/w (vocab TP-sharded)
      - decode/prefill: the state/cache read (+write at prefill)
    HLO 'bytes accessed' is reported alongside but counts pre-fusion op
    operands (gross overestimate) AND undercounts scan bodies — this
    analytic model is the primary memory term.
    """
    total, active, mm = _param_counts(cfg)
    b, s = shape.batch, shape.seq
    v = cfg.padded_vocab
    d = cfg.d_model
    layers = cfg.num_layers + cfg.num_decoder_layers
    w_shard = 2.0 * active / model_par          # bf16 TP shard

    if shape.kind == "train":
        tok_chip = b * s / dp_total
        weights = 3.0 * accum * w_shard
        opt = 5.0 * total * 4.0 / chips  # p,m,v reads + p,m writes (f32)
        acts = layers * tok_chip * d * 2.0 * 2.0 * 2.0  # save+reread, bf16
        logits = tok_chip * (v / model_par) * 4.0 * 4.0
        return weights + opt + acts + logits

    if shape.kind == "prefill":
        tok_chip = b * s / dp_total
        weights = w_shard
        acts = layers * tok_chip * d * 2.0 * 2.0
        cache = decode_state_bytes(cfg, b, s) / chips
        return weights + acts + cache

    # decode: weights + full state read (+ tiny write)
    cache = decode_state_bytes(cfg, b, s) / chips
    return w_shard + cache


# ---------------------------------------------------------------------------
# roofline table
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RooflineRow:
    arch: str
    shape: str
    mesh: str
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    hlo_flops_per_chip: float
    analytic_flops_per_chip: float
    corr: float
    useful_frac: float          # MODEL_FLOPS / analytic total
    mem_gib: float
    status: str

    def bottleneck_sentence(self) -> str:
        moves = {
            "compute": "more MXU-efficient kernels / lower remat would cut it",
            "memory": "smaller dtypes, better fusion or larger per-chip "
                      "batch raises arithmetic intensity",
            "collective": "resharding to cut all-gathers (more DP, less TP) "
                          "or overlap would hide it",
        }
        return moves[self.dominant]


def build_rows(dryrun_records, get_config, shapes) -> list[RooflineRow]:
    from repro.configs.shapes import Shape
    from repro.launch.steps import CELL_OVERRIDES, default_accum_steps

    rows = []
    for rec in dryrun_records:
        if rec["status"] != "ok":
            continue
        if rec["shape"] not in shapes:
            continue  # extra cells (e.g. the chgnet production cell)
        cfg = get_config(rec["arch"])
        shape = shapes[rec["shape"]]
        multi = rec["mesh"] == "2x16x16"
        chips = 512 if multi else 256
        model_par = 16
        dp_total = chips // model_par
        accum = 1
        if shape.kind == "train":
            accum = CELL_OVERRIDES.get(
                (cfg.name, shape.name), {}).get("accum_steps") \
                or default_accum_steps(cfg, shape, dp_total)
            accum = max(1, min(accum, shape.batch // dp_total))
        ana = analytic_flops(cfg, shape)
        ana_per_chip = ana["flops"] / chips
        hlo_flops = max(rec["cost"]["flops"], 1.0)
        corr = max(1.0, ana_per_chip / hlo_flops)
        hbm_bytes = analytic_bytes(
            cfg, shape, chips=chips, model_par=model_par,
            dp_total=dp_total, accum=accum)
        coll_bytes = analytic_collective_bytes(
            cfg, shape, chips=chips, model_par=model_par,
            dp_total=dp_total, accum=accum)
        compute_s = ana_per_chip / PEAK_FLOPS
        memory_s = hbm_bytes / HBM_BW
        coll_s = coll_bytes / LINK_BW
        dom = max(
            (("compute", compute_s), ("memory", memory_s),
             ("collective", coll_s)),
            key=lambda kv: kv[1],
        )[0]
        mem = rec["memory"]
        peak = (mem["argument_bytes"] + mem["temp_bytes"]
                + mem["output_bytes"] - mem["alias_bytes"])
        rows.append(RooflineRow(
            arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
            chips=chips, compute_s=compute_s, memory_s=memory_s,
            collective_s=coll_s, dominant=dom,
            model_flops=ana["model_flops"],
            hlo_flops_per_chip=hlo_flops,
            analytic_flops_per_chip=ana_per_chip,
            corr=corr,
            useful_frac=ana["model_flops"] / max(ana["flops"], 1.0),
            mem_gib=peak / 2**30,
            status=rec["status"],
        ))
    return rows


def to_markdown(rows: list[RooflineRow]) -> str:
    out = ["| arch | shape | mesh | compute s | memory s | coll s | "
           "dominant | useful (6ND/total) | roofline frac | mem GiB |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r.arch, r.shape, r.mesh)):
        bound = max(r.compute_s, r.memory_s, r.collective_s)
        frac = r.compute_s / bound if bound > 0 else 0.0
        out.append(
            f"| {r.arch} | {r.shape} | {r.mesh} | {r.compute_s:.2e} | "
            f"{r.memory_s:.2e} | {r.collective_s:.2e} | {r.dominant} | "
            f"{r.useful_frac:.2f} | {frac:.2f} | {r.mem_gib:.1f} |")
    return "\n".join(out)


def load_and_build(dryrun_path: str):
    from repro.configs import get_config
    from repro.configs.shapes import SHAPES

    with open(dryrun_path) as f:
        recs = json.load(f)
    return build_rows(recs, get_config, SHAPES), recs
