"""Host-side packing of crystals into padded ``CrystalGraphBatch``es.

Moved out of ``repro.core.graph`` (which keeps only the device-side pytree):
packing is a host/data-plane concern and is shared by training (via
``repro.data.pipeline``) and serving (via ``repro.serve``).

Padding convention (unchanged from the seed): real entries are packed at
the front, masks mark validity, padded bonds/angles point at slot 0 with
zeroed payloads so segment-sums are unaffected.  ``num_crystal_slots``
additionally pads the *crystal* axis, so shards with unequal numbers of
structures (non-divisible global batches) still stack to one fixed shape.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import CrystalGraphBatch
from repro.core.neighbors import Crystal, GraphIndices

from .capacity import BatchCapacities


def batch_crystals(
    crystals: list[Crystal],
    graphs: list[GraphIndices],
    caps: BatchCapacities,
    *,
    num_crystal_slots: int | None = None,
    dtype=np.float32,
) -> CrystalGraphBatch:
    """Pack crystals + pre-built graph indices into one padded batch.

    Raises ValueError if the batch exceeds the capacities (callers should
    size capacities from dataset statistics / the bucketing policy).
    Padded crystal slots (``num_crystal_slots > len(crystals)``) get
    identity lattices and zero ``crystal_mask``.
    """
    b = num_crystal_slots if num_crystal_slots is not None else len(crystals)
    if len(crystals) > b:
        raise ValueError(
            f"{len(crystals)} crystals exceed {b} crystal slots"
        )
    tot_atoms = sum(c.num_atoms for c in crystals)
    tot_bonds = sum(g.num_bonds for g in graphs)
    tot_angles = sum(g.num_angles for g in graphs)
    if not caps.fits(tot_atoms, tot_bonds, tot_angles):
        raise ValueError(
            f"batch ({tot_atoms} atoms, {tot_bonds} bonds, {tot_angles} angles)"
            f" exceeds capacities {caps}"
        )

    atom_z = np.zeros((caps.atoms,), np.int32)
    atom_mask = np.zeros((caps.atoms,), dtype)
    atom_crystal = np.zeros((caps.atoms,), np.int32)
    frac = np.zeros((caps.atoms, 3), dtype)
    # identity lattices on padded slots keep det/inverse well-defined
    lattice = np.tile(np.eye(3, dtype=dtype)[None], (b, 1, 1))
    crystal_mask = np.zeros((b,), dtype)
    bond_center = np.zeros((caps.bonds,), np.int32)
    bond_nbr = np.zeros((caps.bonds,), np.int32)
    bond_image = np.zeros((caps.bonds, 3), dtype)
    bond_crystal = np.zeros((caps.bonds,), np.int32)
    bond_mask = np.zeros((caps.bonds,), dtype)
    angle_ij = np.zeros((caps.angles,), np.int32)
    angle_ik = np.zeros((caps.angles,), np.int32)
    angle_mask = np.zeros((caps.angles,), dtype)
    energy = np.zeros((b,), dtype)
    forces = np.zeros((caps.atoms, 3), dtype)
    stress = np.zeros((b, 3, 3), dtype)
    magmoms = np.zeros((caps.atoms,), dtype)
    n_atoms = np.zeros((b,), dtype)

    a_off = 0
    b_off = 0
    g_off = 0
    for ci, (c, g) in enumerate(zip(crystals, graphs)):
        na, nb, ng = c.num_atoms, g.num_bonds, g.num_angles
        atom_z[a_off:a_off + na] = c.atomic_numbers
        atom_mask[a_off:a_off + na] = 1.0
        atom_crystal[a_off:a_off + na] = ci
        frac[a_off:a_off + na] = c.frac_coords
        lattice[ci] = c.lattice
        crystal_mask[ci] = 1.0
        n_atoms[ci] = na
        bond_center[b_off:b_off + nb] = g.bond_center + a_off
        bond_nbr[b_off:b_off + nb] = g.bond_nbr + a_off
        bond_image[b_off:b_off + nb] = g.bond_image.astype(dtype)
        bond_crystal[b_off:b_off + nb] = ci
        bond_mask[b_off:b_off + nb] = 1.0
        angle_ij[g_off:g_off + ng] = g.angle_ij + b_off
        angle_ik[g_off:g_off + ng] = g.angle_ik + b_off
        angle_mask[g_off:g_off + ng] = 1.0
        if c.energy is not None:
            energy[ci] = c.energy
        if c.forces is not None:
            forces[a_off:a_off + na] = c.forces
        if c.stress is not None:
            stress[ci] = c.stress
        if c.magmoms is not None:
            magmoms[a_off:a_off + na] = c.magmoms
        a_off += na
        b_off += nb
        g_off += ng

    return CrystalGraphBatch(
        atom_z=jnp.asarray(atom_z),
        atom_mask=jnp.asarray(atom_mask),
        atom_crystal=jnp.asarray(atom_crystal),
        frac_coords=jnp.asarray(frac),
        lattice=jnp.asarray(lattice),
        crystal_mask=jnp.asarray(crystal_mask),
        bond_center=jnp.asarray(bond_center),
        bond_nbr=jnp.asarray(bond_nbr),
        bond_image=jnp.asarray(bond_image),
        bond_crystal=jnp.asarray(bond_crystal),
        bond_mask=jnp.asarray(bond_mask),
        angle_ij=jnp.asarray(angle_ij),
        angle_ik=jnp.asarray(angle_ik),
        angle_mask=jnp.asarray(angle_mask),
        energy=jnp.asarray(energy),
        forces=jnp.asarray(forces),
        stress=jnp.asarray(stress),
        magmoms=jnp.asarray(magmoms),
        n_atoms_per_crystal=jnp.asarray(n_atoms),
    )


def atom_offsets(crystals: list[Crystal]) -> np.ndarray:
    """Start offset of each crystal's atoms in the packed atom axis."""
    return np.concatenate(
        [[0], np.cumsum([c.num_atoms for c in crystals])[:-1]]
    ).astype(np.int64)


def stack_device_batches(batches: list[CrystalGraphBatch]) -> CrystalGraphBatch:
    """Stack per-device batches along a new leading axis (for shard_map)."""
    shapes = {
        tuple(x.shape for x in jax.tree.leaves(b)) for b in batches
    }
    if len(shapes) > 1:
        raise ValueError(
            "per-device batches disagree on shapes; pack them with the same "
            f"capacities and num_crystal_slots: {sorted(shapes)}"
        )
    return jax.tree.map(lambda *xs: np.stack(xs, axis=0), *batches)


def padding_waste(batch: CrystalGraphBatch) -> float:
    """Fraction of padded feature slots (atoms+bonds+angles) that are waste."""
    real = float(batch.atom_mask.sum() + batch.bond_mask.sum()
                 + batch.angle_mask.sum())
    cap = batch.atom_cap + batch.bond_cap + batch.angle_cap
    return 1.0 - real / cap if cap else 0.0
