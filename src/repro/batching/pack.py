"""Host-side packing of crystals into padded ``CrystalGraphBatch``es.

Moved out of ``repro.core.graph`` (which keeps only the device-side pytree):
packing is a host/data-plane concern and is shared by training (via
``repro.data.pipeline``) and serving (via ``repro.serve``).

Padding convention (unchanged from the seed): real entries are packed at
the front, masks mark validity, padded bonds/angles point at slot 0 with
zeroed payloads so segment-sums are unaffected.  ``num_crystal_slots``
additionally pads the *crystal* axis, so shards with unequal numbers of
structures (non-divisible global batches) still stack to one fixed shape.

Sorted-segment layout (DESIGN.md §1): on top of the padding convention,
packing canonicalizes the graph indices so that

  - real bonds are sorted by ``bond_center`` (stable, so per-center
    neighbor order is preserved),
  - real angles are sorted by ``angle_ij`` after remapping through the
    bond permutation,
  - CSR row pointers ``bond_offsets: (atom_cap+1,)`` and
    ``angle_offsets: (bond_cap+1,)`` delimit each segment's contiguous run
    (last entry == number of real entries, excluding the padded tail).

Undirected half-graph store (DESIGN.md §5): alongside the directed
arrays, packing emits a once-per-pair ``und_*`` store (capacity
``caps.und_cap`` ≈ bonds/2) plus the mirror maps ``bond_pair`` /
``bond_sign`` that materialize directed views (``vec_dir = sign ⊙
vec_und[bond_pair]``).  The directed index arrays are untouched, so the
§1 sorted-CSR invariant — and every consumer of it — is preserved.

``validate_layout`` checks both invariants cheaply (a few O(E) numpy
passes); packing validates by default so every producer — the training
pipeline, the serve engine's Verlet rebuild path — emits certified-sorted
batches that the fused aggregation kernels can consume without atomics.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import CrystalGraphBatch
from repro.core.neighbors import (
    Crystal,
    GraphIndices,
    build_angle_mirror_maps,
    build_mirror_maps,
)

from .capacity import BatchCapacities


def _csr_offsets(sorted_ids: np.ndarray, num_segments: int) -> np.ndarray:
    """Row pointers for sorted segment ids: offsets[s] = first index of s."""
    return np.searchsorted(
        sorted_ids, np.arange(num_segments + 1)
    ).astype(np.int32)


def batch_crystals(
    crystals: list[Crystal],
    graphs: list[GraphIndices],
    caps: BatchCapacities,
    *,
    num_crystal_slots: int | None = None,
    dtype=np.float32,
    validate: bool = True,
) -> CrystalGraphBatch:
    """Pack crystals + pre-built graph indices into one padded batch.

    Raises ValueError if the batch exceeds the capacities (callers should
    size capacities from dataset statistics / the bucketing policy).
    Padded crystal slots (``num_crystal_slots > len(crystals)``) get
    identity lattices and zero ``crystal_mask``.

    The result satisfies the sorted-segment layout invariant (module
    docstring / DESIGN.md §1); ``validate=False`` skips the final check
    for hot loops that trust their graph producers.
    """
    b = num_crystal_slots if num_crystal_slots is not None else len(crystals)
    if len(crystals) > b:
        raise ValueError(
            f"{len(crystals)} crystals exceed {b} crystal slots"
        )
    tot_atoms = sum(c.num_atoms for c in crystals)
    tot_bonds = sum(g.num_bonds for g in graphs)
    tot_angles = sum(g.num_angles for g in graphs)
    if not caps.fits(tot_atoms, tot_bonds, tot_angles):
        raise ValueError(
            f"batch ({tot_atoms} atoms, {tot_bonds} bonds, {tot_angles} angles)"
            f" exceeds capacities {caps}"
        )
    # undirected half-graph store (DESIGN.md §5): repair missing mirror
    # maps (hand-built GraphIndices) once, up front
    mirrors = [
        (g.bond_pair, g.bond_sign, g.und_rep)
        if g.bond_pair is not None
        else build_mirror_maps(g.bond_center, g.bond_nbr, g.bond_image)
        for g in graphs
    ]
    und_cap = caps.und_cap
    tot_und = sum(int(m[2].shape[0]) for m in mirrors)
    if tot_und > und_cap:
        raise ValueError(
            f"batch has {tot_und} undirected bonds, exceeding und_cap "
            f"{und_cap}; pair symmetry was likely broken by "
            f"max_nbr_per_atom capping — pass BatchCapacities(..., "
            f"und_bonds=...) with explicit headroom"
        )
    # angle-pair dedup store: same repair-or-reuse treatment as the bond
    # mirror maps (the angle cosine is swap-symmetric, so each unordered
    # {ij, ik} pair is stored once and expanded via angle_pair)
    a_mirrors = [
        (g.angle_pair, g.und_angle_rep)
        if g.angle_pair is not None
        else build_angle_mirror_maps(g.angle_ij, g.angle_ik)
        for g in graphs
    ]
    ua_cap = caps.und_angle_cap
    tot_ua = sum(int(m[1].shape[0]) for m in a_mirrors)
    if tot_ua > ua_cap:
        raise ValueError(
            f"batch has {tot_ua} deduplicated angles, exceeding "
            f"und_angle_cap {ua_cap}; the angle list is likely asymmetric "
            f"(hand-built) — pass BatchCapacities(..., und_angles=...) "
            f"with explicit headroom"
        )

    atom_z = np.zeros((caps.atoms,), np.int32)
    atom_mask = np.zeros((caps.atoms,), dtype)
    atom_crystal = np.zeros((caps.atoms,), np.int32)
    frac = np.zeros((caps.atoms, 3), dtype)
    # identity lattices on padded slots keep det/inverse well-defined
    lattice = np.tile(np.eye(3, dtype=dtype)[None], (b, 1, 1))
    crystal_mask = np.zeros((b,), dtype)
    bond_center = np.zeros((caps.bonds,), np.int32)
    bond_nbr = np.zeros((caps.bonds,), np.int32)
    bond_image = np.zeros((caps.bonds, 3), dtype)
    bond_crystal = np.zeros((caps.bonds,), np.int32)
    bond_mask = np.zeros((caps.bonds,), dtype)
    angle_ij = np.zeros((caps.angles,), np.int32)
    angle_ik = np.zeros((caps.angles,), np.int32)
    angle_mask = np.zeros((caps.angles,), dtype)
    bond_pair = np.zeros((caps.bonds,), np.int32)
    bond_sign = np.zeros((caps.bonds,), dtype)
    und_center = np.zeros((und_cap,), np.int32)
    und_nbr = np.zeros((und_cap,), np.int32)
    und_image = np.zeros((und_cap, 3), dtype)
    und_crystal = np.zeros((und_cap,), np.int32)
    und_mask = np.zeros((und_cap,), dtype)
    angle_pair = np.zeros((caps.angles,), np.int32)
    und_angle_ij = np.zeros((ua_cap,), np.int32)
    und_angle_ik = np.zeros((ua_cap,), np.int32)
    und_angle_mask = np.zeros((ua_cap,), dtype)
    energy = np.zeros((b,), dtype)
    forces = np.zeros((caps.atoms, 3), dtype)
    stress = np.zeros((b, 3, 3), dtype)
    magmoms = np.zeros((caps.atoms,), dtype)
    n_atoms = np.zeros((b,), dtype)

    a_off = 0
    b_off = 0
    g_off = 0
    u_off = 0
    ua_off = 0
    for ci, (c, g, (g_pair, g_sign, g_rep), (g_apair, g_arep)) in enumerate(
            zip(crystals, graphs, mirrors, a_mirrors)):
        na, nb, ng = c.num_atoms, g.num_bonds, g.num_angles
        nu = int(g_rep.shape[0])
        nua = int(g_arep.shape[0])
        atom_z[a_off:a_off + na] = c.atomic_numbers
        atom_mask[a_off:a_off + na] = 1.0
        atom_crystal[a_off:a_off + na] = ci
        frac[a_off:a_off + na] = c.frac_coords
        lattice[ci] = c.lattice
        crystal_mask[ci] = 1.0
        n_atoms[ci] = na
        bond_center[b_off:b_off + nb] = g.bond_center + a_off
        bond_nbr[b_off:b_off + nb] = g.bond_nbr + a_off
        bond_image[b_off:b_off + nb] = g.bond_image.astype(dtype)
        bond_crystal[b_off:b_off + nb] = ci
        bond_mask[b_off:b_off + nb] = 1.0
        angle_ij[g_off:g_off + ng] = g.angle_ij + b_off
        angle_ik[g_off:g_off + ng] = g.angle_ik + b_off
        angle_mask[g_off:g_off + ng] = 1.0
        bond_pair[b_off:b_off + nb] = g_pair + u_off
        bond_sign[b_off:b_off + nb] = g_sign
        und_center[u_off:u_off + nu] = g.bond_center[g_rep] + a_off
        und_nbr[u_off:u_off + nu] = g.bond_nbr[g_rep] + a_off
        und_image[u_off:u_off + nu] = g.bond_image[g_rep].astype(dtype)
        und_crystal[u_off:u_off + nu] = ci
        und_mask[u_off:u_off + nu] = 1.0
        angle_pair[g_off:g_off + ng] = g_apair + ua_off
        und_angle_ij[ua_off:ua_off + nua] = g.angle_ij[g_arep] + b_off
        und_angle_ik[ua_off:ua_off + nua] = g.angle_ik[g_arep] + b_off
        und_angle_mask[ua_off:ua_off + nua] = 1.0
        if c.energy is not None:
            energy[ci] = c.energy
        if c.forces is not None:
            forces[a_off:a_off + na] = c.forces
        if c.stress is not None:
            stress[ci] = c.stress
        if c.magmoms is not None:
            magmoms[a_off:a_off + na] = c.magmoms
        a_off += na
        b_off += nb
        g_off += ng
        u_off += nu
        ua_off += nua

    # Canonicalize to the sorted-segment layout. ``build_graph`` already
    # emits per-crystal indices sorted by center, and crystals are packed
    # in atom order, so these stable argsorts are near-identity — the cost
    # is one O(E log E) pass that certifies the invariant regardless of
    # where the graphs came from.
    perm_b = np.argsort(bond_center[:b_off], kind="stable")
    for arr in (bond_center, bond_nbr, bond_image, bond_crystal, bond_mask,
                bond_pair, bond_sign):
        arr[:b_off] = arr[perm_b]
    # angles index into bonds: remap through the bond permutation first
    inv_b = np.empty_like(perm_b)
    inv_b[perm_b] = np.arange(b_off)
    if g_off:
        angle_ij[:g_off] = inv_b[angle_ij[:g_off]]
        angle_ik[:g_off] = inv_b[angle_ik[:g_off]]
    # the dedup-angle store indexes bonds too — remap, but never re-sort
    # (it's a side table addressed through angle_pair, like the und bonds)
    if ua_off:
        und_angle_ij[:ua_off] = inv_b[und_angle_ij[:ua_off]]
        und_angle_ik[:ua_off] = inv_b[und_angle_ik[:ua_off]]
    perm_a = np.argsort(angle_ij[:g_off], kind="stable")
    for arr in (angle_ij, angle_ik, angle_mask, angle_pair):
        arr[:g_off] = arr[perm_a]
    bond_offsets = _csr_offsets(bond_center[:b_off], caps.atoms)
    angle_offsets = _csr_offsets(angle_ij[:g_off], caps.bonds)
    # symmetric-trunk incidence store (DESIGN.md §10): every real dedup
    # angle (Au row) w scatters its single message to BOTH undirected
    # bonds of its pair — incidences (bond_pair[und_angle_ij[w]], w) and
    # (bond_pair[und_angle_ik[w]], w) — so each real Au row appears
    # exactly twice.  On symmetric angle lists (everything the neighbor
    # builders emit) this equals deriving one incidence per directed
    # angle, so the real incidence count == the real directed-angle
    # count.  Built from the FINAL (canonicalized) arrays, dest-sorted so
    # every aggregation tier — including the Eu destination-tiled
    # megakernel — owns contiguous runs.
    n_incid = 2 * ua_off
    if n_incid > caps.angles:
        raise ValueError(
            f"batch needs {n_incid} symmetric incidences but angle_cap is "
            f"{caps.angles}; the angle list is likely asymmetric "
            "(hand-built, missing swapped orientations)")
    sym_dest = np.zeros((caps.angles,), np.int32)
    sym_rep = np.zeros((caps.angles,), np.int32)
    if ua_off:
        dest = np.concatenate([bond_pair[und_angle_ij[:ua_off]],
                               bond_pair[und_angle_ik[:ua_off]]])
        rep = np.concatenate([np.arange(ua_off, dtype=np.int32)] * 2)
        order = np.argsort(dest, kind="stable")
        sym_dest[:n_incid] = dest[order]
        sym_rep[:n_incid] = rep[order]
    sym_offsets = _csr_offsets(sym_dest[:n_incid], und_cap)

    if validate:
        # validate the host arrays *before* jnp.asarray — same certification
        # as validate_layout(batch) but with zero device-to-host transfers
        _validate_arrays(bond_mask, angle_mask, bond_center, angle_ij,
                         bond_offsets, angle_offsets,
                         atom_cap=caps.atoms, bond_cap=caps.bonds)
        _validate_mirror(bond_mask, bond_center, bond_nbr, bond_image,
                         bond_crystal, bond_pair, bond_sign, und_center,
                         und_nbr, und_image, und_crystal, und_mask)
        _validate_angle_mirror(angle_mask, angle_ij, angle_ik, angle_pair,
                               und_angle_ij, und_angle_ik, und_angle_mask)
        _validate_sym_incidence(bond_pair, und_angle_ij, und_angle_ik,
                                und_angle_mask, sym_dest, sym_rep,
                                sym_offsets)

    return CrystalGraphBatch(
        atom_z=jnp.asarray(atom_z),
        atom_mask=jnp.asarray(atom_mask),
        atom_crystal=jnp.asarray(atom_crystal),
        frac_coords=jnp.asarray(frac),
        lattice=jnp.asarray(lattice),
        crystal_mask=jnp.asarray(crystal_mask),
        bond_center=jnp.asarray(bond_center),
        bond_nbr=jnp.asarray(bond_nbr),
        bond_image=jnp.asarray(bond_image),
        bond_crystal=jnp.asarray(bond_crystal),
        bond_mask=jnp.asarray(bond_mask),
        angle_ij=jnp.asarray(angle_ij),
        angle_ik=jnp.asarray(angle_ik),
        angle_mask=jnp.asarray(angle_mask),
        bond_offsets=jnp.asarray(bond_offsets),
        angle_offsets=jnp.asarray(angle_offsets),
        bond_pair=jnp.asarray(bond_pair),
        bond_sign=jnp.asarray(bond_sign),
        und_center=jnp.asarray(und_center),
        und_nbr=jnp.asarray(und_nbr),
        und_image=jnp.asarray(und_image),
        und_crystal=jnp.asarray(und_crystal),
        und_mask=jnp.asarray(und_mask),
        angle_pair=jnp.asarray(angle_pair),
        und_angle_ij=jnp.asarray(und_angle_ij),
        und_angle_ik=jnp.asarray(und_angle_ik),
        und_angle_mask=jnp.asarray(und_angle_mask),
        sym_dest=jnp.asarray(sym_dest),
        sym_rep=jnp.asarray(sym_rep),
        sym_offsets=jnp.asarray(sym_offsets),
        energy=jnp.asarray(energy),
        forces=jnp.asarray(forces),
        stress=jnp.asarray(stress),
        magmoms=jnp.asarray(magmoms),
        n_atoms_per_crystal=jnp.asarray(n_atoms),
    )


def _check(cond: bool, msg: str) -> None:
    if not cond:
        raise ValueError(f"sorted-segment layout violated: {msg}")


def validate_layout(batch: CrystalGraphBatch) -> CrystalGraphBatch:
    """Cheap host-side check of the sorted-segment layout invariant.

    Verifies (a few O(E) numpy passes): masks are contiguous real-prefix
    indicators, real bonds/angles are sorted by their segment key, the
    CSR row pointers exactly describe the segment runs, and the mirror
    maps of the undirected half-graph store (DESIGN.md §5) exactly
    reconstruct every real directed bond.  Pulls the index/mask leaves to
    host, so use it on externally produced batches; the pack path
    validates its numpy arrays pre-upload instead.  Returns the batch for
    chaining; raises ValueError with the broken condition.
    """
    _validate_arrays(
        np.asarray(batch.bond_mask), np.asarray(batch.angle_mask),
        np.asarray(batch.bond_center), np.asarray(batch.angle_ij),
        np.asarray(batch.bond_offsets), np.asarray(batch.angle_offsets),
        atom_cap=batch.atom_cap, bond_cap=batch.bond_cap,
    )
    _validate_mirror(
        np.asarray(batch.bond_mask), np.asarray(batch.bond_center),
        np.asarray(batch.bond_nbr), np.asarray(batch.bond_image),
        np.asarray(batch.bond_crystal), np.asarray(batch.bond_pair),
        np.asarray(batch.bond_sign), np.asarray(batch.und_center),
        np.asarray(batch.und_nbr), np.asarray(batch.und_image),
        np.asarray(batch.und_crystal), np.asarray(batch.und_mask),
    )
    _validate_angle_mirror(
        np.asarray(batch.angle_mask), np.asarray(batch.angle_ij),
        np.asarray(batch.angle_ik), np.asarray(batch.angle_pair),
        np.asarray(batch.und_angle_ij), np.asarray(batch.und_angle_ik),
        np.asarray(batch.und_angle_mask),
    )
    _validate_sym_incidence(
        np.asarray(batch.bond_pair), np.asarray(batch.und_angle_ij),
        np.asarray(batch.und_angle_ik), np.asarray(batch.und_angle_mask),
        np.asarray(batch.sym_dest), np.asarray(batch.sym_rep),
        np.asarray(batch.sym_offsets),
    )
    return batch


def _validate_arrays(bond_mask, angle_mask, bond_center, angle_ij,
                     bond_offsets, angle_offsets, *,
                     atom_cap: int, bond_cap: int) -> None:
    _check(bond_offsets.shape == (atom_cap + 1,),
           f"bond_offsets shape {bond_offsets.shape}")
    _check(angle_offsets.shape == (bond_cap + 1,),
           f"angle_offsets shape {angle_offsets.shape}")
    for name, mask, ids, offs in (
        ("bond", bond_mask, bond_center, bond_offsets),
        ("angle", angle_mask, angle_ij, angle_offsets),
    ):
        n_real = int(mask.sum())
        _check(np.all(mask[:n_real] == 1.0) and np.all(mask[n_real:] == 0.0),
               f"{name}_mask is not a real-prefix indicator")
        _check(np.all(np.diff(ids[:n_real]) >= 0),
               f"real {name}s not sorted by segment id")
        _check(offs[0] == 0 and offs[-1] == n_real,
               f"{name}_offsets endpoints != (0, {n_real})")
        _check(np.all(np.diff(offs) >= 0),
               f"{name}_offsets not monotone")
        expect = np.searchsorted(ids[:n_real], np.arange(offs.shape[0]))
        _check(np.array_equal(offs, expect),
               f"{name}_offsets disagree with sorted {name} segment ids")


def _validate_mirror(bond_mask, bond_center, bond_nbr, bond_image,
                     bond_crystal, bond_pair, bond_sign, und_center,
                     und_nbr, und_image, und_crystal, und_mask) -> None:
    """Mirror invariant of the undirected store (DESIGN.md §5).

    For every real directed bond e with p = bond_pair[e]:
      sign=+1  =>  (center, nbr, image)[e] == (und_center, und_nbr,
                   und_image)[p]          (the stored orientation)
      sign=-1  =>  (center, nbr, image)[e] == (und_nbr, und_center,
                   -und_image)[p]         (the mirror)
    plus: crystal ids agree, each real undirected row is referenced by
    exactly one sign=+1 bond and at most one sign=-1 bond, und_mask is a
    real-prefix indicator, and padded directed bonds carry (pair=0,
    sign=0) so their expanded vectors vanish.
    """
    nb = int(bond_mask.sum())
    nu = int(und_mask.sum())
    _check(np.all(und_mask[:nu] == 1.0) and np.all(und_mask[nu:] == 0.0),
           "und_mask is not a real-prefix indicator")
    _check(np.all(bond_pair[nb:] == 0) and np.all(bond_sign[nb:] == 0.0),
           "padded directed bonds must carry (pair=0, sign=0)")
    p = bond_pair[:nb]
    s = bond_sign[:nb]
    _check(np.all((p >= 0) & (p < max(nu, 1))),
           "bond_pair out of range of the real undirected prefix")
    _check(np.all(np.abs(s) == 1.0), "real bond_sign must be ±1")
    plus, minus = s > 0, s < 0
    same = (
        (bond_center[:nb] == und_center[p])
        & (bond_nbr[:nb] == und_nbr[p])
        & np.all(bond_image[:nb] == und_image[p], axis=-1)
    )
    flip = (
        (bond_center[:nb] == und_nbr[p])
        & (bond_nbr[:nb] == und_center[p])
        & np.all(bond_image[:nb] == -und_image[p], axis=-1)
    )
    _check(np.all(same[plus]), "sign=+1 bonds disagree with their und row")
    _check(np.all(flip[minus]), "sign=-1 bonds are not exact mirrors")
    _check(np.all(bond_crystal[:nb] == und_crystal[p]),
           "bond/und crystal ids disagree")
    refs_plus = np.bincount(p[plus], minlength=nu)
    refs_minus = np.bincount(p[minus], minlength=nu)
    _check(np.all(refs_plus == 1),
           "each und row needs exactly one sign=+1 reference")
    _check(np.all(refs_minus <= 1),
           "an und row has more than one sign=-1 reference")


def _validate_angle_mirror(angle_mask, angle_ij, angle_ik, angle_pair,
                           und_angle_ij, und_angle_ik,
                           und_angle_mask) -> None:
    """Angle-pair dedup invariant (mirrors ``_validate_mirror``).

    For every real angle t with p = angle_pair[t], (angle_ij, angle_ik)[t]
    equals the stored (und_angle_ij, und_angle_ik)[p] either same-oriented
    or swapped; each real dedup row is referenced by exactly one
    same-orientation angle and at most one swapped angle; und_angle_mask
    is a real-prefix indicator; padded angles carry pair=0.
    """
    na = int(angle_mask.sum())
    nu = int(und_angle_mask.sum())
    _check(
        np.all(und_angle_mask[:nu] == 1.0)
        and np.all(und_angle_mask[nu:] == 0.0),
        "und_angle_mask is not a real-prefix indicator")
    _check(np.all(angle_pair[na:] == 0),
           "padded angles must carry angle_pair=0")
    p = angle_pair[:na]
    _check(np.all((p >= 0) & (p < max(nu, 1))),
           "angle_pair out of range of the real dedup-angle prefix")
    same = (angle_ij[:na] == und_angle_ij[p]) \
        & (angle_ik[:na] == und_angle_ik[p])
    flip = (angle_ij[:na] == und_angle_ik[p]) \
        & (angle_ik[:na] == und_angle_ij[p])
    _check(np.all(same | flip),
           "an angle disagrees with its dedup row in both orientations")
    refs_same = np.bincount(p[same], minlength=nu)
    refs_flip = np.bincount(p[flip & ~same], minlength=nu)
    _check(np.all(refs_same == 1),
           "each dedup-angle row needs exactly one same-orientation ref")
    _check(np.all(refs_flip <= 1),
           "a dedup-angle row has more than one swapped reference")


def _validate_sym_incidence(bond_pair, und_angle_ij, und_angle_ik,
                            und_angle_mask, sym_dest, sym_rep,
                            sym_offsets) -> None:
    """Symmetric-trunk incidence invariant (DESIGN.md §10).

    The incidence store must be exactly the dest-sorted multiset
    { (bond_pair[und_angle_ij[w]], w), (bond_pair[und_angle_ik[w]], w) }
    over the real dedup-angle prefix — every real Au row appears exactly
    twice, once per undirected bond of its pair (both incidences may
    share a destination for self-image bonds i->i(±L)).  sym_offsets is
    the CSR of sym_dest over Eu rows with sym_offsets[-1] == 2·Au_real,
    and padded incidences carry (dest=0, rep=0) past the real prefix.
    """
    nua = int(und_angle_mask.sum())
    ni = 2 * nua
    _check(sym_dest.shape == sym_rep.shape,
           f"sym_dest/sym_rep shapes {sym_dest.shape} != {sym_rep.shape}")
    _check(ni <= sym_dest.shape[0],
           f"{ni} symmetric incidences exceed angle_cap {sym_dest.shape[0]}")
    _check(np.all(sym_dest[ni:] == 0) and np.all(sym_rep[ni:] == 0),
           "padded symmetric incidences must carry (dest=0, rep=0)")
    _check(np.all(np.diff(sym_dest[:ni]) >= 0),
           "real symmetric incidences not sorted by destination")
    _check(sym_offsets[0] == 0 and sym_offsets[-1] == ni,
           f"sym_offsets endpoints != (0, {ni})")
    _check(np.all(np.diff(sym_offsets) >= 0), "sym_offsets not monotone")
    expect = np.searchsorted(sym_dest[:ni], np.arange(sym_offsets.shape[0]))
    _check(np.array_equal(sym_offsets, expect),
           "sym_offsets disagree with sorted incidence destinations")
    want_dest = np.concatenate([bond_pair[und_angle_ij[:nua]],
                                bond_pair[und_angle_ik[:nua]]])
    want_rep = np.concatenate(
        [np.arange(nua, dtype=np.int64)] * 2) if nua else want_dest
    order = np.lexsort((want_rep, want_dest))
    have = np.lexsort((sym_rep[:ni], sym_dest[:ni]))
    _check(
        np.array_equal(sym_dest[:ni][have], want_dest[order])
        and np.array_equal(sym_rep[:ni][have], want_rep[order]),
        "symmetric incidences disagree with the dedup-angle mirror maps")


def atom_offsets(crystals: list[Crystal]) -> np.ndarray:
    """Start offset of each crystal's atoms in the packed atom axis."""
    return np.concatenate(
        [[0], np.cumsum([c.num_atoms for c in crystals])[:-1]]
    ).astype(np.int64)


def stack_device_batches(batches: list[CrystalGraphBatch]) -> CrystalGraphBatch:
    """Stack per-device batches along a new leading axis (for shard_map)."""
    shapes = {
        tuple(x.shape for x in jax.tree.leaves(b)) for b in batches
    }
    if len(shapes) > 1:
        raise ValueError(
            "per-device batches disagree on shapes; pack them with the same "
            f"capacities and num_crystal_slots: {sorted(shapes)}"
        )
    return jax.tree.map(lambda *xs: np.stack(xs, axis=0), *batches)


def padding_waste(batch: CrystalGraphBatch) -> float:
    """Fraction of padded feature slots (atoms+bonds+angles) that are waste."""
    real = float(batch.atom_mask.sum() + batch.bond_mask.sum()
                 + batch.angle_mask.sum())
    cap = batch.atom_cap + batch.bond_cap + batch.angle_cap
    return 1.0 - real / cap if cap else 0.0
