"""Unified graph-batching engine (host side).

All host-side packing/capacity logic lives here — bucketed capacity
ladders sized from dataset statistics, padded-batch packing, and a jit
compile cache keyed on ``(bucket, batch_size, config)`` — shared by the
training data pipeline (``repro.data``) and the MD serving engine
(``repro.serve``).  The device-side ``CrystalGraphBatch`` pytree stays in
``repro.core.graph``.
"""
from .capacity import (
    BatchCapacities,
    CapacityLadder,
    capacity_for,
    capacity_from_stats,
    ladder_for,
    ladder_from_stats,
)
from .balance import (
    StepPlan,
    crystal_slots_for,
    lpt_pack,
    plan_microbatches,
    shard_cost_totals,
    straggler_ratio,
)
from .cost import DEFAULT_COST_MODEL, CostModel, fit_cost_model
from .engine import BatchingEngine, CompileCache, global_compile_cache
from .pack import (
    atom_offsets,
    batch_crystals,
    padding_waste,
    stack_device_batches,
    validate_layout,
)

__all__ = [
    "BatchCapacities", "CapacityLadder", "capacity_for",
    "capacity_from_stats", "ladder_for", "ladder_from_stats",
    "BatchingEngine", "CompileCache", "global_compile_cache",
    "atom_offsets", "batch_crystals", "padding_waste",
    "stack_device_batches", "validate_layout",
    "StepPlan", "crystal_slots_for", "lpt_pack", "plan_microbatches",
    "shard_cost_totals", "straggler_ratio",
    "CostModel", "DEFAULT_COST_MODEL", "fit_cost_model",
]
