"""The batching engine: bucket selection + a jit compile cache.

One ``BatchingEngine`` is shared by training and serving.  It owns

  - a ``CapacityLadder`` (bucket selection, never truncating), and
  - a ``CompileCache`` keyed on ``(name, bucket, batch_size, config)`` so
    each padded shape/config combination is traced exactly once per
    process, even across Trainer restarts or many serve replica groups.

``jax.jit`` already caches per *abstract shape*, but a fresh ``jit``
wrapper (e.g. a new Trainer after a fault restart, or an ad-hoc lambda per
call site) starts with an empty cache; routing construction through
``CompileCache`` makes the reuse explicit and measurable (hits/misses).
"""
from __future__ import annotations

import threading
from typing import Callable

from repro.core.neighbors import Crystal, GraphIndices

from .capacity import BatchCapacities, CapacityLadder
from .pack import batch_crystals, padding_waste


class CompileCache:
    """Process-wide memo of built (usually jitted) step functions."""

    def __init__(self):
        self._fns: dict = {}
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def get(self, key, build: Callable[[], Callable]) -> Callable:
        with self._lock:
            fn = self._fns.get(key)
            if fn is not None:
                self.hits += 1
                return fn
            self.misses += 1
        # build outside the lock (tracing can be slow); last writer wins
        fn = build()
        with self._lock:
            return self._fns.setdefault(key, fn)

    def __len__(self) -> int:
        return len(self._fns)

    def clear(self) -> None:
        with self._lock:
            self._fns.clear()
            self.hits = 0
            self.misses = 0


_GLOBAL_CACHE = CompileCache()


def global_compile_cache() -> CompileCache:
    """The default process-wide compile cache."""
    return _GLOBAL_CACHE


class BatchingEngine:
    """Packs crystal lists into bucketed padded batches + caches step fns.

    Tracks padding-waste statistics so the padding-efficiency claim
    (bucketing beats one worst-case capacity) is directly measurable.
    """

    def __init__(self, ladder: CapacityLadder,
                 cache: CompileCache | None = None,
                 *, validate_layout: bool = True):
        self.ladder = ladder
        self.cache = cache if cache is not None else global_compile_cache()
        # sorted-segment layout check on every packed batch (DESIGN.md §1);
        # a few O(E) numpy passes — serving loops that trust their graph
        # producers can turn it off
        self.validate_layout = validate_layout
        self.batches_packed = 0
        self._waste_sum = 0.0

    # -- bucket selection ---------------------------------------------------
    def select(self, crystals: list[Crystal],
               graphs: list[GraphIndices]) -> BatchCapacities:
        """Smallest ladder bucket that fits the batch totals."""
        return self.ladder.bucket_for(
            sum(c.num_atoms for c in crystals),
            sum(g.num_bonds for g in graphs),
            sum(g.num_angles for g in graphs),
        )

    # -- packing ------------------------------------------------------------
    def pack(
        self,
        crystals: list[Crystal],
        graphs: list[GraphIndices],
        *,
        caps: BatchCapacities | None = None,
        num_crystal_slots: int | None = None,
    ):
        """Pack into the smallest fitting bucket; returns (batch, bucket)."""
        caps = caps if caps is not None else self.select(crystals, graphs)
        batch = batch_crystals(
            crystals, graphs, caps, num_crystal_slots=num_crystal_slots,
            validate=self.validate_layout,
        )
        self.batches_packed += 1
        self._waste_sum += padding_waste(batch)
        return batch, caps

    # -- compiled step functions -------------------------------------------
    def compiled(self, name: str, caps: BatchCapacities, batch_size: int,
                 config_key, build: Callable[[], Callable]) -> Callable:
        """Memoized step function for ``(name, bucket, batch_size, config)``."""
        return self.cache.get((name, caps, batch_size, config_key), build)

    # -- stats --------------------------------------------------------------
    @property
    def mean_padding_waste(self) -> float:
        return self._waste_sum / self.batches_packed if self.batches_packed else 0.0

    def stats(self) -> dict:
        return {
            "batches_packed": self.batches_packed,
            "mean_padding_waste": self.mean_padding_waste,
            "compile_cache_entries": len(self.cache),
            "compile_cache_hits": self.cache.hits,
            "compile_cache_misses": self.cache.misses,
        }
