"""Capacity policies for padded crystal-graph batches (host side).

XLA needs static shapes, so batches are padded to fixed
``(atom, bond, angle)`` capacities. Two policies:

  - ``capacity_for``: one worst-case capacity sized at a quantile + safety
    margin of per-shard totals (the seed behaviour, kept for training where
    a single compiled step is preferred);
  - ``CapacityLadder``: a small ladder of capacity buckets sized from
    dataset statistics.  Each batch is packed into the *smallest* bucket
    that fits, so small batches stop paying the worst-case pad; the jit
    compile cache (``repro.batching.engine``) is keyed on the bucket, so
    the number of distinct compilations stays bounded by the ladder size.

The load-balance sampler (paper C6) keeps per-shard totals tight (low CoV),
which is what makes small buckets hit often — C6 doubles as our
padding-efficiency lever.
"""
from __future__ import annotations

import dataclasses

import numpy as np


def _align_up(raw: int, align: int) -> int:
    return max(align, ((raw + align - 1) // align) * align)


@dataclasses.dataclass(frozen=True)
class BatchCapacities:
    """Static (atom, bond, angle) capacities of one padded batch.

    ``und_bonds`` caps the *undirected* half-graph store (DESIGN.md §5).
    ``None`` (the default) derives ``ceil(bonds / 2)`` — exact for the
    pair-symmetric graphs every uncapped producer emits (Eu == E/2).
    Graphs whose symmetry was broken by ``max_nbr_per_atom`` capping fall
    back to singleton undirected entries (Eu > E/2) and need an explicit
    ``und_bonds`` override to pack.

    ``und_angles`` likewise caps the angle-pair dedup store; ``None``
    derives ``ceil(angles / 2)`` — exact for the ordered angle lists
    ``_build_angles`` emits (each unordered pair appears twice, Au ==
    A/2); hand-built asymmetric angle lists need an override.
    """

    atoms: int
    bonds: int
    angles: int
    und_bonds: int | None = None
    und_angles: int | None = None

    @property
    def und_cap(self) -> int:
        """Undirected-bond capacity (``bonds``-derived unless overridden)."""
        if self.und_bonds is not None:
            return self.und_bonds
        return self.bonds // 2 + self.bonds % 2

    @property
    def und_angle_cap(self) -> int:
        """Dedup-angle capacity (``angles``-derived unless overridden)."""
        if self.und_angles is not None:
            return self.und_angles
        return self.angles // 2 + self.angles % 2

    def fits(
        self,
        n_atoms: int,
        n_bonds: int,
        n_angles: int,
        n_und_bonds: int | None = None,
        n_und_angles: int | None = None,
    ) -> bool:
        """True iff the counts fit; und counts are checked when given
        (producers with broken pair symmetry should pass them)."""
        return (
            n_atoms <= self.atoms
            and n_bonds <= self.bonds
            and n_angles <= self.angles
            and (n_und_bonds is None or n_und_bonds <= self.und_cap)
            and (n_und_angles is None or n_und_angles <= self.und_angle_cap)
        )

    @property
    def total(self) -> int:
        """Total padded feature slots (the paper's load metric, padded)."""
        return self.atoms + self.bonds + self.angles

    def scaled(self, k: int) -> "BatchCapacities":
        """Capacities for ``k`` structures that each fit this bucket."""
        return BatchCapacities(
            self.atoms * k, self.bonds * k, self.angles * k,
            None if self.und_bonds is None else self.und_bonds * k,
            None if self.und_angles is None else self.und_angles * k)


def capacity_from_stats(
    atoms: np.ndarray,
    bonds: np.ndarray,
    angles: np.ndarray,
    per_device_batch: int,
    *,
    quantile: float = 0.99,
    margin: float = 1.3,
    align: int = 256,
) -> BatchCapacities:
    """Single worst-case capacity at quantile + margin of per-sample stats."""

    def cap(x):
        q = float(np.quantile(x, quantile))
        return _align_up(int(q * per_device_batch * margin), align)

    return BatchCapacities(atoms=cap(atoms), bonds=cap(bonds), angles=cap(angles))


def capacity_for(
    ds,
    per_device_batch: int,
    *,
    quantile: float = 0.99,
    margin: float = 1.3,
    align: int = 256,
) -> BatchCapacities:
    """Size per-device capacities from dataset statistics.

    ``ds`` is any object with ``crystals`` / ``graphs`` lists
    (``repro.data.SyntheticDataset`` in practice).
    """
    atoms = np.array([c.num_atoms for c in ds.crystals])
    bonds = np.array([g.num_bonds for g in ds.graphs])
    angles = np.array([g.num_angles for g in ds.graphs])
    return capacity_from_stats(
        atoms, bonds, angles, per_device_batch,
        quantile=quantile, margin=margin, align=align,
    )


@dataclasses.dataclass(frozen=True)
class CapacityLadder:
    """An ascending ladder of capacity buckets.

    ``bucket_for`` returns the smallest bucket that fits a batch; if even
    the top bucket is too small, an overflow bucket is synthesized by
    rounding each dimension up to ``align`` — selection therefore *never*
    truncates, it only costs one extra compilation for the rare giant.
    """

    buckets: tuple[BatchCapacities, ...]
    align: int = 64

    def __post_init__(self):
        if not self.buckets:
            raise ValueError("CapacityLadder needs at least one bucket")
        tot = [b.total for b in self.buckets]
        if sorted(tot) != tot:
            raise ValueError(f"buckets must ascend by total capacity: {tot}")

    def bucket_for(
        self, n_atoms: int, n_bonds: int, n_angles: int
    ) -> BatchCapacities:
        for b in self.buckets:
            if b.fits(n_atoms, n_bonds, n_angles):
                return b
        top = self.buckets[-1]
        bonds = _align_up(max(n_bonds, top.bonds), self.align)
        angles = _align_up(max(n_angles, top.angles), self.align)
        # explicit und overrides on the top bucket (asymmetric producers)
        # carry over, but never below the derived ceil(cap / 2) of the
        # *grown* bond/angle caps — overflow must not shrink headroom
        return BatchCapacities(
            atoms=_align_up(max(n_atoms, top.atoms), self.align),
            bonds=bonds,
            angles=angles,
            und_bonds=(None if top.und_bonds is None
                       else max(top.und_bonds, bonds // 2 + bonds % 2)),
            und_angles=(None if top.und_angles is None
                        else max(top.und_angles, angles // 2 + angles % 2)),
        )

    @property
    def top(self) -> BatchCapacities:
        return self.buckets[-1]


def ladder_from_stats(
    atoms: np.ndarray,
    bonds: np.ndarray,
    angles: np.ndarray,
    per_device_batch: int,
    *,
    num_buckets: int = 4,
    quantiles: tuple[float, ...] | None = None,
    margin: float = 1.3,
    align: int = 64,
) -> CapacityLadder:
    """Build a bucket ladder from per-sample size statistics.

    Bucket ``k`` is sized at quantile ``q_k`` of the per-sample stats times
    the batch size (plus margin); the top bucket uses the max so that any
    batch drawn from the dataset fits without the overflow path.
    """
    if quantiles is None:
        # evenly spaced interior quantiles in [0.5, 0.98]; the top bucket
        # (max-based) is added below, so num_buckets - 1 interior ones
        k = max(0, num_buckets - 1)
        quantiles = tuple(np.linspace(0.5, 0.98, k)) if k else ()

    def cap_at(x, q):
        return _align_up(
            int(float(np.quantile(x, q)) * per_device_batch * margin), align
        )

    buckets = []
    for q in quantiles:
        buckets.append(BatchCapacities(
            atoms=cap_at(atoms, q), bonds=cap_at(bonds, q),
            angles=cap_at(angles, q),
        ))
    # top bucket: fits any batch of per_device_batch samples, with the
    # same margin headroom as the interior buckets (serving callers rely
    # on it for MD size drift — without it the largest structures would
    # bounce off the ladder into per-size overflow buckets)
    buckets.append(BatchCapacities(
        atoms=_align_up(int(np.ceil(atoms.max() * margin)) * per_device_batch,
                        align),
        bonds=_align_up(int(np.ceil(bonds.max() * margin)) * per_device_batch,
                        align),
        angles=_align_up(int(np.ceil(angles.max() * margin)) * per_device_batch,
                         align),
    ))
    # enforce per-dimension monotonicity (margin-inflated interior buckets
    # may exceed a later bucket in one dim — take the running elementwise
    # max so the final bucket dominates every earlier one and the "top
    # fits any batch" guarantee survives), then deduplicate
    kept: list[BatchCapacities] = []
    for b in buckets:
        if kept:
            prev = kept[-1]
            b = BatchCapacities(
                atoms=max(b.atoms, prev.atoms),
                bonds=max(b.bonds, prev.bonds),
                angles=max(b.angles, prev.angles),
            )
            if (b.atoms, b.bonds, b.angles) == (
                    prev.atoms, prev.bonds, prev.angles):
                continue
        kept.append(b)
    return CapacityLadder(buckets=tuple(kept), align=align)


def ladder_for(
    ds,
    per_device_batch: int,
    *,
    num_buckets: int = 4,
    margin: float = 1.3,
    align: int = 64,
) -> CapacityLadder:
    """Bucket ladder sized from dataset statistics (see ``ladder_from_stats``)."""
    atoms = np.array([c.num_atoms for c in ds.crystals])
    bonds = np.array([g.num_bonds for g in ds.graphs])
    angles = np.array([g.num_angles for g in ds.graphs])
    return ladder_from_stats(
        atoms, bonds, angles, per_device_batch,
        num_buckets=num_buckets, margin=margin, align=align,
    )
