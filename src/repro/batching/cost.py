"""Per-crystal step-cost model for load-balanced sharding (DESIGN.md §6).

Crystal graphs vary wildly in bond/angle counts, so "equal sample counts
per device" leaves the slowest shard gating every step (the paper's
32-GPU headline depends on fixing exactly this).  The balancer therefore
assigns structures by *predicted compute cost*, the same measured-cost
partitioning that lets spatial MD codes scale (Plimpton 1995):

    cost(crystal) = c0 + c_atoms * atoms + c_bonds * bonds
                       + c_angles * angles

An affine model is the right shape because every hot stage of the step is
linear in one of the three feature counts: embeddings and per-atom heads
in ``atoms``, geometry/RBF/bond-conv in ``bonds``, the Fourier basis and
angle updates in ``angles`` (angles dominate on dense structures).  The
default coefficients reduce to the paper's Fig. 9 load metric
(atoms + bonds + angles); :func:`fit_cost_model` refines them from a few
profiled steps via least squares.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["CostModel", "DEFAULT_COST_MODEL", "fit_cost_model"]


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Affine per-crystal (or per-shard) step-cost predictor.

    Coefficients are unit-free: only *ratios* of predicted costs matter
    to the bin-packer, so a model fitted in seconds and the default
    feature-count model are interchangeable as balancing objectives.
    """

    c0: float = 0.0
    atoms: float = 1.0
    bonds: float = 1.0
    angles: float = 1.0

    def predict(self, n_atoms, n_bonds, n_angles) -> np.ndarray:
        """Vectorized predicted cost; accepts scalars or arrays."""
        return (
            self.c0
            + self.atoms * np.asarray(n_atoms, np.float64)
            + self.bonds * np.asarray(n_bonds, np.float64)
            + self.angles * np.asarray(n_angles, np.float64)
        )

    def predict_dataset(self, ds) -> np.ndarray:
        """Per-sample costs for any dataset with ``crystals``/``graphs``."""
        return self.predict(
            np.array([c.num_atoms for c in ds.crystals]),
            np.array([g.num_bonds for g in ds.graphs]),
            np.array([g.num_angles for g in ds.graphs]),
        )


DEFAULT_COST_MODEL = CostModel()


def fit_cost_model(
    sizes: np.ndarray,
    times: np.ndarray,
    *,
    keep_intercept: bool = True,
) -> CostModel:
    """Least-squares fit of the affine cost model from profiled steps.

    ``sizes``: (K, 3) per-step totals of (atoms, bonds, angles) —
    *real* counts, not padded capacities; ``times``: (K,) measured step
    seconds.  Negative coefficients (possible when the probe steps don't
    separate the features) are clamped to zero, so the fitted model can
    never rank a strictly larger structure as cheaper.  Needs K >= 4
    distinct step shapes for a full-rank fit; with fewer the lstsq
    minimum-norm solution still yields a usable (if degenerate) model.
    """
    sizes = np.asarray(sizes, np.float64)
    times = np.asarray(times, np.float64)
    if sizes.ndim != 2 or sizes.shape[1] != 3:
        raise ValueError(f"sizes must be (K, 3), got {sizes.shape}")
    if times.shape != (sizes.shape[0],):
        raise ValueError(
            f"times shape {times.shape} != ({sizes.shape[0]},)")
    cols = [sizes[:, 0], sizes[:, 1], sizes[:, 2]]
    if keep_intercept:
        cols.insert(0, np.ones(sizes.shape[0]))
    a_mat = np.stack(cols, axis=1)
    coef, *_ = np.linalg.lstsq(a_mat, times, rcond=None)
    coef = np.maximum(coef, 0.0)
    if keep_intercept:
        c0, ca, cb, cg = coef
    else:
        c0, (ca, cb, cg) = 0.0, coef
    return CostModel(c0=float(c0), atoms=float(ca), bonds=float(cb),
                     angles=float(cg))
