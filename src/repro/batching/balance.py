"""Cost-model bin-packing sharder + microbatch planning (DESIGN.md §6).

Replaces "split the sampler's batch evenly by count" DP sharding with
Longest-Processing-Time (LPT) bin packing over predicted per-crystal
costs (``repro.batching.cost``):

  - :func:`lpt_pack`: deterministic greedy LPT — items sorted by cost
    descending (index tiebreak), each assigned to the least-loaded bin.
    Classic 4/3-approximation of makespan; with >= num_bins items every
    bin is non-empty.
  - :func:`plan_microbatches`: splits one global batch into ``num_micro``
    *size-homogeneous* chunks (sorted by cost, contiguous slices) and
    LPT-packs each chunk across devices.  Homogeneous chunks are what
    lets each microbatch pick a *small* capacity bucket: the big-crystal
    microbatch pays the big bucket, the small-crystal ones don't — the
    gradient-accumulation path (train.trainer) then sums the per-bucket
    microbatch grads, so nothing is padded to the worst bucket.
  - :class:`StepPlan`: the packed per-step product consumed by
    ``Trainer`` — microbatches (one stacked batch per bucket group),
    global loss denominators, and the predicted shard costs that feed the
    straggler histogram in ``benchmarks/bench_scaling``.

Invariants (relied on by tests and the trainer):
  - packing is a pure function of (costs, num_bins, max_items) — same
    inputs give the same assignment on every host/process;
  - every device bin of every microbatch has <= ``max_items`` items, so
    the padded crystal-slot axis is a static shape per (global_batch,
    num_micro, num_devices) and the jit compile cache stays bounded;
  - the union of all bins is exactly the input index set (nothing
    dropped, nothing duplicated).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

__all__ = [
    "StepPlan", "lpt_pack", "plan_microbatches", "shard_cost_totals",
    "straggler_ratio", "crystal_slots_for",
]


def lpt_pack(
    costs: np.ndarray,
    num_bins: int,
    *,
    max_items: int | None = None,
) -> list[np.ndarray]:
    """Greedy LPT: sort by cost descending, assign to least-loaded bin.

    Returns ``num_bins`` index arrays (positions into ``costs``), each
    sorted ascending for stable downstream packing.  Deterministic: ties
    in cost break by original position, ties in load break by bin index.
    ``max_items`` caps the item count per bin (full bins are skipped), so
    a pile of near-zero-cost items cannot blow past the padded
    crystal-slot capacity; it must satisfy
    ``max_items * num_bins >= len(costs)``.
    """
    costs = np.asarray(costs, np.float64)
    n = costs.shape[0]
    if num_bins < 1:
        raise ValueError(f"num_bins must be >= 1, got {num_bins}")
    if max_items is not None and max_items * num_bins < n:
        raise ValueError(
            f"max_items {max_items} x {num_bins} bins < {n} items")
    # stable descending order: negate costs so argsort's ascending order
    # with index tiebreak gives (cost desc, position asc)
    order = np.argsort(-costs, kind="stable")
    loads = np.zeros(num_bins, np.float64)
    counts = np.zeros(num_bins, np.int64)
    bins: list[list[int]] = [[] for _ in range(num_bins)]
    for pos in order:
        if max_items is not None:
            open_bins = counts < max_items
            # argmin over loads with full bins masked to +inf; ties pick
            # the lowest bin index (np.argmin's first-occurrence rule)
            masked = np.where(open_bins, loads, np.inf)
        else:
            masked = loads
        b = int(np.argmin(masked))
        bins[b].append(int(pos))
        loads[b] += costs[pos]
        counts[b] += 1
    return [np.sort(np.asarray(b, np.int64)) for b in bins]


def plan_microbatches(
    costs: np.ndarray,
    num_devices: int,
    num_micro: int = 1,
    *,
    max_items: int | None = None,
) -> list[list[np.ndarray]]:
    """Partition one global batch into ``num_micro`` x ``num_devices``
    balanced bins.

    Items are sorted by cost descending and cut into ``num_micro``
    contiguous chunks (near-equal counts, remainder to the earlier =
    costlier chunks), then each chunk is LPT-packed across devices.  The
    sort makes chunks size-homogeneous, so each microbatch's shards fit a
    *small* capacity bucket; LPT inside a chunk keeps the per-device
    makespan tight, which is what sets the step time.

    Returns positions into ``costs``: ``plan[m][d]`` is device ``d``'s
    item set of microbatch ``m``.  Microbatches with fewer items than
    devices leave the trailing device bins empty (the accumulation step
    runs them as all-padding shards whose loss/grad sums are exactly
    zero).  Batches with fewer than ``num_micro * num_devices`` items get
    fewer (non-empty) microbatches instead.
    """
    costs = np.asarray(costs, np.float64)
    n = costs.shape[0]
    if num_micro < 1:
        raise ValueError(f"num_micro must be >= 1, got {num_micro}")
    num_micro = max(1, min(num_micro, n // max(num_devices, 1)) or 1)
    order = np.argsort(-costs, kind="stable")
    base, rem = divmod(n, num_micro)
    plan: list[list[np.ndarray]] = []
    start = 0
    for m in range(num_micro):
        size = base + (1 if m < rem else 0)
        chunk = order[start:start + size]
        start += size
        if chunk.size == 0:
            continue
        shards = lpt_pack(costs[chunk], num_devices, max_items=max_items)
        plan.append([chunk[s] for s in shards])
    return plan


def crystal_slots_for(global_batch: int, num_devices: int,
                      num_micro: int = 1) -> int:
    """Static crystal-slot capacity per device shard.

    LPT needs headroom beyond ``ceil(chunk / devices)`` to trade a big
    crystal on one device against several small ones on another; 2x is
    enough for any assignment LPT produces under this cap while keeping
    the padded crystal axis a fixed shape for the compile cache.
    """
    chunk = -(-global_batch // max(num_micro, 1))
    return min(chunk, 2 * -(-chunk // max(num_devices, 1)))


def shard_cost_totals(costs: np.ndarray,
                      shards: list[np.ndarray]) -> np.ndarray:
    """Total predicted cost per shard (the balancer's makespan view)."""
    return np.array([float(np.sum(costs[s])) for s in shards], np.float64)


def straggler_ratio(shard_costs: np.ndarray) -> float:
    """max/mean shard cost: 1.0 = perfectly balanced, the step-time
    multiplier the slowest shard imposes on the mesh otherwise."""
    shard_costs = np.asarray(shard_costs, np.float64)
    mean = float(np.mean(shard_costs))
    if mean <= 0.0:
        return 1.0
    return float(np.max(shard_costs)) / mean


@dataclasses.dataclass
class StepPlan:
    """One optimizer step's worth of balanced, bucketed microbatches.

    ``micro``: packed batches (stacked per-device leaves in mesh mode),
    one per bucket group; ``denoms``: the GLOBAL loss denominators
    (``repro.core.losses.chgnet_loss_sums``) that make the accumulated
    gradient exactly equal a single big-batch gradient; ``shard_costs``:
    (num_micro, num_devices) predicted costs for straggler reporting;
    ``num_real``: real crystals in the step (throughput accounting).
    """

    micro: list[Any]
    denoms: dict[str, np.ndarray]
    shard_costs: np.ndarray
    num_real: int = 0
    # (num_micro, 3) REAL atom/bond/angle totals per microbatch, filled by
    # BalancedBatchIterator.plan_step — the feature columns that pair with
    # the Trainer's measured per-microbatch wall times when it refits the
    # cost model live (cost.fit_cost_model); None when the producer does
    # not track sizes
    micro_sizes: np.ndarray | None = None

    @property
    def straggler(self) -> float:
        """max/mean predicted cost across all device shards of the step,
        treating microbatches as sequential phases (costs sum per device)."""
        per_device = self.shard_costs.sum(axis=0)
        return straggler_ratio(per_device)
