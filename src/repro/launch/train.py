"""Production training launcher.

Two modes:
  - ``--arch chgnet``: train FastCHGNet on the synthetic dataset with the
    full substrate (load-balance sampler, prefetch, checkpoint/restart,
    straggler watch) across all local devices (DP shard_map).
  - ``--arch <lm-id>``: build + run the LM train step (smoke config on
    CPU; the full config is exercised by dryrun.py).

On a real TPU pod this module is the per-host entrypoint
(``jax.distributed.initialize()`` + the production mesh); on CPU it runs
the same code paths on host devices.

    PYTHONPATH=src python -m repro.launch.train --arch chgnet --steps 50
"""
from __future__ import annotations

import argparse
import itertools
from functools import partial

import jax


def train_chgnet(args):
    from repro.batching import capacity_for, ladder_for
    from repro.configs import chgnet_mptrj as C
    from repro.data import (
        BatchIterator, Prefetcher, SyntheticConfig, make_dataset,
    )
    from repro.launch.mesh import make_host_mesh
    from repro.runtime import (
        ChaosMonkey, ChaosSchedule, GracefulShutdown, PreemptionError,
        clear_resume_marker, latest_valid_step, read_resume_marker,
        run_with_restarts,
    )
    from repro.train import TrainConfig, Trainer

    n_dev = jax.device_count()
    ds = make_dataset(SyntheticConfig(num_crystals=args.crystals, seed=0))
    # ceil: non-divisible batches put up to ceil(batch/n_dev) samples on a
    # shard, so capacities must be sized for that, not the floor
    per_dev = -(-args.batch // n_dev)
    # one worst-case capacity (single compiled step) or a bucket ladder
    # (less padding waste, <= args.buckets compiled step shapes)
    caps = (capacity_for(ds, per_dev) if args.buckets <= 1
            else ladder_for(ds, per_dev, num_buckets=args.buckets))
    mesh = make_host_mesh() if n_dev > 1 else None
    model_cfg = C.FAST_FS_HEAD if args.readout == "direct" else C.FAST_WO_HEAD
    # fused message-passing megakernels (DESIGN.md §3) — every batch from
    # repro.batching satisfies the §1 layout they require — and the
    # end-to-end precision policy (DESIGN.md §4; "mixed" = f32 master
    # params/accum, bf16 compute + dynamic loss scaling)
    model_cfg = model_cfg.with_(conv_impl=args.conv_impl,
                                precision=args.precision,
                                bond_store=args.bond_store,
                                bond_features=args.bond_features,
                                stress_mode=args.stress_mode,
                                table_residency=args.table_residency)
    train_cfg = TrainConfig(global_batch=args.batch, total_steps=args.steps,
                            loss=C.LOSS, grad_reduce=args.grad_reduce,
                            cost_refit_every=args.cost_refit_every,
                            rollback_on_divergence=args.rollback_on_divergence)
    print(f"devices={n_dev} init_lr={train_cfg.init_lr:.2e} "
          f"readout={args.readout} conv_impl={args.conv_impl} "
          f"precision={args.precision} bond_store={args.bond_store} "
          f"bond_features={args.bond_features} "
          f"stress_mode={args.stress_mode} async_ckpt={args.async_ckpt}")
    if args.ckpt:
        marker = read_resume_marker(args.ckpt)
        if marker:
            print(f"resuming after preemption at step {marker['step']} "
                  f"({marker.get('reason', '?')})")
            clear_resume_marker(args.ckpt)
    # one monkey for the whole run: `fired` persists across restarts so
    # each scheduled fault fires exactly once (DESIGN.md §8)
    monkey = None
    if args.chaos:
        monkey = ChaosMonkey(
            ChaosSchedule.parse(args.chaos, seed=args.chaos_seed),
            ckpt_dir=args.ckpt)
    shutdown = GracefulShutdown().install()

    def one_pass(tr):
        if args.balance == "cost" or args.accum > 1:
            # cost-model bin packing + gradient accumulation (DESIGN.md
            # §6): StepPlans re-bin-pack over the surviving mesh if a
            # device drops mid-run (elastic_train)
            from repro.data import BalancedBatchIterator
            from repro.runtime import elastic_train

            def batches_fn(num_devices):
                it = BalancedBatchIterator(
                    ds, args.batch, num_devices, caps,
                    num_micro=max(args.accum, 1),
                    stack=tr.mesh is not None)
                # live cost-model refits (DESIGN.md §6): the Trainer times
                # each microbatch and pushes the refit coefficients back
                # into the iterator's LPT bin packing
                tr.on_cost_model = it.update_cost_model
                tr.on_quarantine = it.add_quarantine
                stream = itertools.islice(
                    itertools.cycle(iter(it)), max(args.steps - tr.step, 0))
                if monkey is not None:
                    # wrap INSIDE the Prefetcher so transient faults hit
                    # the worker's retry/quarantine path (DESIGN.md §8)
                    stream = monkey.wrap_batches(stream, start_step=tr.step)
                return Prefetcher(stream)

            hist = elastic_train(tr, batches_fn, max_steps=args.steps,
                                 fault_injector=monkey)
        else:
            it = BatchIterator(ds, args.batch, n_dev, caps,
                               stack=n_dev > 1, load_balance=True,
                               tag_indices=args.rollback_on_divergence)
            tr.on_quarantine = it.add_quarantine
            stream = itertools.islice(
                itertools.cycle(iter(it)), args.steps - tr.step)
            if monkey is not None:
                stream = monkey.wrap_batches(stream, start_step=tr.step)
            hist = tr.train(Prefetcher(stream), fault_injector=monkey)
        return hist

    def loop(start):
        tr = Trainer(model_cfg, train_cfg, mesh=mesh, ckpt_dir=args.ckpt,
                     ckpt_every=args.ckpt_every,
                     async_ckpt=args.async_ckpt, shutdown=shutdown)
        tr.maybe_restore()
        hist = []
        while True:
            before = tr.step
            hist = one_pass(tr)
            # a divergence rollback consumes stream batches while moving
            # tr.step backwards, so an exhausted stream can leave the run
            # short of --steps: rebuild the stream and keep going as long
            # as each pass makes net progress
            if tr.step >= args.steps or tr.step <= before:
                break
        tr.save(wait=True)
        tr.close()
        if hist:
            print(f"steps {tr.step - len(hist)}..{tr.step}: "
                  f"loss {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f}; "
                  f"stragglers={tr.straggler.flags}")
        return tr.step

    try:
        # resume from the newest VALID checkpoint: a crash mid-write (or a
        # chaos ckpt_* event) leaves a corrupt newest file that restore
        # skips, so the resume step must skip it too
        return run_with_restarts(
            loop, resume_step_fn=lambda: (latest_valid_step(args.ckpt) or 0)
            if args.ckpt else 0,
            max_restarts=3)
    except PreemptionError as exc:
        print(f"preempted at step {exc.step}; checkpoint + resume marker "
              f"written to {args.ckpt}")
        return exc.step
    finally:
        shutdown.uninstall()


def train_lm(args):
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_smoke
    from repro.models.api import family_fns
    from repro.optim import adam_init, adam_update

    cfg = get_smoke(args.arch)
    fns = family_fns(cfg)
    params = fns.init(cfg, jax.random.PRNGKey(0))
    opt = adam_init(params)
    rng = np.random.default_rng(0)
    kw = dict(ssd_chunk=8) if cfg.family == "hybrid" else {}

    # donate params/opt (rebound every iteration) so the weights and
    # moments never exist twice — same contract as the CHGNet train steps
    @partial(jax.jit, donate_argnums=(0, 1))
    def step(params, opt, *batch):
        loss, grads = jax.value_and_grad(
            lambda p: fns.loss(cfg, p, *batch, **kw))(params)
        params, opt = adam_update(grads, opt, params, 1e-3)
        return params, opt, loss

    b, s = 4, 32
    for i in range(args.steps):
        if fns.token_input:
            x = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)))
        else:
            x = jnp.asarray(rng.normal(0, 1, (b, s, cfg.d_model)),
                            jnp.float32)
        labels = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)))
        batch = [x, labels]
        if fns.has_positions:
            shape = (b, s, 3) if fns.positions_3d else (b, s)
            pos = jnp.broadcast_to(
                jnp.arange(s)[None, :, None] if fns.positions_3d
                else jnp.arange(s)[None, :], shape).astype(jnp.int32)
            batch.append(pos)
        params, opt, loss = step(params, opt, *batch)
        if i % max(1, args.steps // 10) == 0:
            print(f"  step {i:3d} loss {float(loss):.4f}")
    return args.steps


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="chgnet")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--crystals", type=int, default=128)
    ap.add_argument("--readout", default="direct",
                    choices=["direct", "autodiff"])
    ap.add_argument("--conv-impl", default="unfused",
                    choices=["unfused", "fused"],
                    help="fused = message-passing megakernels (DESIGN.md §3)")
    ap.add_argument("--precision", default="f32",
                    choices=["f32", "bf16", "mixed"],
                    help="end-to-end precision policy (DESIGN.md §4); "
                         "mixed = f32 params/accum, bf16 compute")
    ap.add_argument("--bond-store", default="directed",
                    choices=["directed", "undirected"],
                    help="undirected = half-graph bond store with mirror "
                         "maps (DESIGN.md §5): geometry/RBF/embed GEMM "
                         "and e^a/e^b run once per pair (Eu = E/2)")
    ap.add_argument("--bond-features", default="directed",
                    choices=["directed", "undirected"],
                    help="trunk compute representation (DESIGN.md §10): "
                         "undirected = symmetrized bond_conv/angle_update "
                         "over Eu/Au rows (halves every bond/angle-level "
                         "GEMM; requires --bond-store undirected)")
    ap.add_argument("--stress-mode", default="mlp",
                    choices=["mlp", "bond_virial"],
                    help="direct-readout stress tier (DESIGN.md §7): mlp = "
                         "pooled S-head MLP; bond_virial = per-bond virial "
                         "from the force head's n_ij (no stress params; "
                         "fused into the force megakernel epilogue when "
                         "--conv-impl fused)")
    ap.add_argument("--table-residency", default="auto",
                    choices=["auto", "vmem", "hbm"],
                    help="operand-table residency of the Pallas kernels "
                         "(DESIGN.md §9): vmem = whole-array resident; "
                         "hbm = tables stay in HBM, streamed with "
                         "double-buffered DMA (10k+-atom structures); "
                         "auto = per-launch byte estimate vs the VMEM "
                         "budget (REPRO_VMEM_BUDGET_MB)")
    ap.add_argument("--grad-reduce", default="bucketed",
                    choices=["plain", "bucketed", "compressed"])
    ap.add_argument("--cost-refit-every", type=int, default=0,
                    help="refit the LPT cost model from live per-microbatch "
                         "step timings every K optimizer steps (0 = off; "
                         "only meaningful with --balance cost / --accum)")
    ap.add_argument("--balance", default="pair",
                    choices=["pair", "cost"],
                    help="DP sharding: pair = paper Fig. 4 "
                         "smallest+largest pairing (equal counts); cost = "
                         "LPT bin packing over the per-crystal cost model "
                         "(DESIGN.md §6), with rebalance-on-fault")
    ap.add_argument("--accum", type=int, default=1,
                    help="microbatches per optimizer step (DESIGN.md §6 "
                         "gradient accumulation across capacity buckets); "
                         ">1 implies the balanced StepPlan path")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--async-ckpt", action="store_true",
                    help="write checkpoints from a background thread "
                         "(DESIGN.md §8): the step loop only pays for the "
                         "host snapshot; serialize/fsync/prune overlap "
                         "training")
    ap.add_argument("--rollback-on-divergence", action="store_true",
                    help="NaN/loss-spike streaks restore the newest valid "
                         "checkpoint, halve the LR, and quarantine the "
                         "streak's batches (DESIGN.md §8)")
    ap.add_argument("--chaos", default=None,
                    help="fault-injection schedule, e.g. "
                         "'nan@5,sigterm@12,ckpt_bitflip@20,drop@7:0' "
                         "(runtime.chaos; kinds: crash drop sigterm "
                         "straggler ckpt_truncate ckpt_bitflip nan "
                         "transient prefetch_crash)")
    ap.add_argument("--chaos-seed", type=int, default=0)
    ap.add_argument("--buckets", type=int, default=2,
                    help="capacity buckets (1 = single worst-case pad)")
    args = ap.parse_args()
    if args.arch == "chgnet":
        train_chgnet(args)
    else:
        train_lm(args)


if __name__ == "__main__":
    main()
