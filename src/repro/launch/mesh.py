"""Production mesh definition (DESIGN.md §5).

Defined as FUNCTIONS so importing this module never touches jax device
state (jax locks the device count at first backend init — dryrun.py must
set XLA_FLAGS before any jax call).
"""
from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    # jax.sharding.AxisType only exists on newer jax; older versions get
    # the same (Auto) behaviour by omitting axis_types entirely
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod ('data', 'model'); 2 pods adds a 'pod' axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(shape=None, axes=("data",)):
    """Small mesh over whatever devices exist (tests / local runs)."""
    n = jax.device_count()
    if shape is None:
        shape = (n,)
    return _make_mesh(shape, axes)


def mesh_sizes(mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
