import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks the device count at first
# backend init). The 512 placeholder host devices exist ONLY here — smoke
# tests and benchmarks see the real single CPU device.
# (No `from __future__` here: these two lines must stay the first
# statements in the module, which Python only allows without it.)

_DOC = """Multi-pod dry-run: lower + compile EVERY (arch x shape) cell on the
single-pod 16x16 mesh and the 2x16x16 multi-pod mesh, and record
memory_analysis / cost_analysis / the collective schedule for the
roofline (EXPERIMENTS.md §Dry-run, §Roofline).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --all
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b \
        --shape train_4k --mesh single,multi
Results append to benchmarks/results/dryrun.json (one record per cell).
"""

import argparse
import json
import re
import time
import traceback

import jax

from repro.configs import ARCH_IDS, get_config
from repro.configs.shapes import SHAPES, cell_status
from repro.launch.mesh import make_production_mesh, mesh_sizes
from repro.launch.steps import build_cell

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "../../../benchmarks/results")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
)
_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|s64|u64|s32|u32|s16|u16|s8|u8|pred)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo: str) -> dict:
    """Per-chip collective traffic model from the partitioned HLO.

    For each collective op we take the LHS (result) shapes as the payload
    and apply ring-traffic factors: all-reduce 2*(g-1)/g, others (g-1)/g,
    with g = replica group size parsed from the op (fallback: 2 -> factor
    ~1). '-start' ops carry the payload; '-done' ops are skipped.
    """
    out = {"bytes": 0.0, "count": 0, "by_op": {}}
    for line in hlo.splitlines():
        m = _COLL_RE.search(line)
        if not m or "-done" in line or "= token" in line:
            continue
        if "%" not in line or "=" not in line:
            continue
        op = m.group(1)
        lhs = line.split(op)[0]
        payload = _shape_bytes(lhs)
        if payload == 0:
            continue
        g = 2
        gm = _GROUPS_RE.search(line)
        if gm:
            g = max(2, len(gm.group(1).split(",")))
        else:
            gi = _GROUPS_IOTA_RE.search(line)
            if gi:
                g = max(2, int(gi.group(2)))
        factor = 2.0 * (g - 1) / g if op == "all-reduce" else (g - 1) / g
        traffic = payload * factor
        out["bytes"] += traffic
        out["count"] += 1
        rec = out["by_op"].setdefault(op, {"bytes": 0.0, "count": 0})
        rec["bytes"] += traffic
        rec["count"] += 1
    return out


def run_chgnet_cell(multi_pod: bool, global_batch: int = 2048) -> dict:
    """The paper's own model at production scale: FastCHGNet DP training
    (shard_map) with the paper's large-batch recipe (batch 2048, Fig. 6)
    on the production mesh. Per-device padded-graph capacities are sized
    from MPtrj-like statistics (P99 + margin, see data.pipeline)."""
    import jax.numpy as jnp

    from repro.configs import chgnet_mptrj as C
    from repro.batching import BatchCapacities
    from repro.core.graph import batch_input_specs
    from repro.train.trainer import TrainConfig, make_dp_train_step
    from repro.core.chgnet import chgnet_init
    from repro.optim.adam import adam_init

    rec = {"arch": "chgnet-fastchgnet", "shape": f"train_b{global_batch}",
           "mesh": "2x16x16" if multi_pod else "16x16", "kind": "train"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    ndev = int(mesh.devices.size)
    per_dev = global_batch // ndev
    # MPtrj-like per-crystal stats: ~32 atoms, ~900 bonds, ~1100 angles
    caps = BatchCapacities(atoms=64 * per_dev, bonds=1536 * per_dev,
                           angles=2048 * per_dev)
    t0 = time.time()
    try:
        model_cfg = C.FAST_FS_HEAD
        tcfg = TrainConfig(global_batch=global_batch, total_steps=1000,
                           loss=C.LOSS)
        # flatten the mesh to one DP axis for the graph shard_map
        flat = jax.make_mesh(
            (ndev,), ("data",),
            axis_types=(jax.sharding.AxisType.Auto,),
            devices=mesh.devices.reshape(-1))
        step = make_dp_train_step(model_cfg, tcfg, flat)
        params = jax.eval_shape(
            lambda: chgnet_init(jax.random.PRNGKey(0), model_cfg))
        opt = jax.eval_shape(adam_init, params)
        one = batch_input_specs(per_dev, caps)
        batch = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((ndev,) + s.shape, s.dtype), one)
        with flat:
            lowered = step.lower(params, opt, batch,
                                 jax.ShapeDtypeStruct((), jnp.int32))
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        coll = collective_stats(compiled.as_text())
        rec.update({
            "status": "ok", "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "memory": {
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "peak_per_device_bytes": (
                    mem.argument_size_in_bytes + mem.temp_size_in_bytes
                    + mem.output_size_in_bytes - mem.alias_size_in_bytes),
            },
            "cost": {"flops": cost.get("flops", 0.0),
                     "bytes_accessed": cost.get("bytes accessed", 0.0)},
            "collectives": coll,
        })
    except Exception as exc:  # noqa: BLE001
        rec["status"] = f"error: {type(exc).__name__}: {exc}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    return rec


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             attn_chunk: int = 1024) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": shape.kind,
    }
    status = cell_status(cfg, shape)
    if status != "ok":
        rec["status"] = status
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    try:
        step, args, shardings, donate, out_shardings = build_cell(
            cfg, shape, mesh, multi_pod=multi_pod, attn_chunk=attn_chunk)
        with mesh:
            jitted = jax.jit(step, in_shardings=shardings,
                             out_shardings=out_shardings,
                             donate_argnums=donate)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        hlo = compiled.as_text()
        coll = collective_stats(hlo)
        rec.update({
            "status": "ok",
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "memory": {
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "peak_per_device_bytes": (
                    mem.argument_size_in_bytes + mem.temp_size_in_bytes
                    + mem.output_size_in_bytes - mem.alias_size_in_bytes
                ),
            },
            "cost": {
                "flops": cost.get("flops", 0.0),
                "bytes_accessed": cost.get("bytes accessed", 0.0),
            },
            "collectives": coll,
            "hlo_bytes": len(hlo),
        })
    except Exception as exc:  # noqa: BLE001 — record the failure, keep going
        rec["status"] = f"error: {type(exc).__name__}: {exc}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id or comma list")
    ap.add_argument("--shape", default=None, help="shape name or comma list")
    ap.add_argument("--mesh", default="single,multi")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--attn-chunk", type=int, default=1024)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    archs = ARCH_IDS if (args.all or not args.arch) else args.arch.split(",")
    shapes = list(SHAPES) if (args.all or not args.shape) else args.shape.split(",")
    meshes = args.mesh.split(",")
    run_chgnet = args.all or (args.arch and "chgnet" in args.arch)
    if args.arch and "chgnet" in args.arch:
        archs = [a for a in archs if a != "chgnet"]

    out_path = args.out or os.path.normpath(
        os.path.join(RESULTS_DIR, "dryrun.json"))
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    records = []
    if os.path.exists(out_path):
        with open(out_path) as f:
            records = json.load(f)

    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                multi = mesh_kind == "multi"
                key = (arch, shape, "2x16x16" if multi else "16x16")
                records = [
                    r for r in records
                    if (r["arch"], r["shape"], r["mesh"]) != key
                ]
                print(f"== {arch} x {shape} x {key[2]} ==", flush=True)
                rec = run_cell(arch, shape, multi, args.attn_chunk)
                print("   ->", rec["status"],
                      f"compile={rec.get('compile_s', '-')}s",
                      f"mem/dev={rec.get('memory', {}).get('peak_per_device_bytes', 0)/2**30:.2f}GiB"
                      if rec.get("memory") else "", flush=True)
                records.append(rec)
                with open(out_path, "w") as f:
                    json.dump(records, f, indent=1)

    if run_chgnet:
        for mesh_kind in meshes:
            multi = mesh_kind == "multi"
            key = ("chgnet-fastchgnet", "train_b2048",
                   "2x16x16" if multi else "16x16")
            records = [r for r in records
                       if (r["arch"], r["shape"], r["mesh"]) != key]
            print(f"== chgnet-fastchgnet x train_b2048 x {key[2]} ==",
                  flush=True)
            rec = run_chgnet_cell(multi)
            print("   ->", rec["status"],
                  f"compile={rec.get('compile_s', '-')}s", flush=True)
            records.append(rec)
            with open(out_path, "w") as f:
                json.dump(records, f, indent=1)
    print(f"wrote {out_path} ({len(records)} records)")


if __name__ == "__main__":
    main()
