"""LM step builders shared by the dry-run, launcher and benchmarks.

Each builder returns (step_fn, arg_structs, in_shardings, donate) ready
for ``jax.jit(step_fn, in_shardings=...).lower(*arg_structs).compile()``.
Serve steps return greedy token ids (not logits) so outputs stay small on
huge-vocab archs.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.shapes import Shape, decode_state_structs, input_specs
from repro.models import encdec, hybrid, rwkv, transformer
from repro.models.api import family_fns
from repro.models.config import LMConfig
from repro.optim.adam import AdamConfig, adam_init, adam_update
from repro.optim.grad import clip_by_global_norm


def _shard(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def _fw_kwargs(cfg: LMConfig, shape: Shape, attn_chunk: int,
               batch_axes=None):
    kw: dict[str, Any] = {}
    if cfg.family in ("dense", "moe", "vlm", "encdec", "hybrid"):
        kw["attn_mode"] = "chunked"
        kw["chunk"] = attn_chunk
    if batch_axes is not None:
        kw["batch_axes"] = batch_axes
    return kw


def param_structs(cfg: LMConfig, dtype=None):
    fns = family_fns(cfg)
    tree = jax.eval_shape(lambda: fns.init(cfg, jax.random.PRNGKey(0)))
    if dtype is not None:
        d = jnp.dtype(dtype)
        tree = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(
                s.shape, d if jnp.issubdtype(s.dtype, jnp.floating)
                else s.dtype),
            tree)
    return tree


# Per-cell memory-policy overrides discovered during the §Perf iterations
# (EXPERIMENTS.md) — nested-scan remat + deeper grad accumulation for the
# deepest/largest model.
CELL_OVERRIDES: dict[tuple[str, str], dict] = {
    ("qwen1.5-110b", "train_4k"): {"accum_steps": 16, "layer_block": 8},
    # §Perf llama-1: accum 8->4 cuts per-step FSDP weight gathers ~2x
    # (per-step collectives 27.7 -> 18.0 GB/chip est.) at 14.1 GiB peak
    ("llama3-8b", "train_4k"): {"accum_steps": 4},
}


def default_accum_steps(cfg: LMConfig, shape: Shape, dp_total: int,
                        target_tokens_per_dev: int = 8192) -> int:
    """Microbatch count: keep ~target tokens per device per microbatch
    (activation-memory control; same total FLOPs)."""
    per_dev = max(1, shape.batch // dp_total)
    want = max(1, (per_dev * shape.seq) // target_tokens_per_dev)
    accum = min(per_dev, want)
    while per_dev % accum != 0:  # must divide the per-device batch
        accum -= 1
    return max(1, accum)


def build_cell(cfg: LMConfig, shape: Shape, mesh, *, multi_pod: bool,
               attn_chunk: int = 1024, lr: float = 1e-4,
               grad_clip: float = 1.0, accum_steps: int | None = None,
               serve_dtype="bfloat16", compress_grads: bool = False):
    """Build the jit-able step for one (arch x shape) cell on a mesh.

    serve_dtype: prefill/decode weights dtype — bf16 halves the serving
    weight footprint AND the per-token weight-read time (§Perf serve-1).
    compress_grads: bf16 gradient all-reduce (paper C8 + compression).
    """
    from repro.launch.mesh import mesh_sizes as _ms

    sizes = _ms(mesh)
    fns = family_fns(cfg)
    specs = fns.specs(cfg, sizes)
    p_structs = param_structs(
        cfg, dtype=None if shape.kind == "train" else serve_dtype)
    io = input_specs(cfg, shape, multi_pod=multi_pod, mesh_sizes=sizes)
    adam_cfg = AdamConfig()
    dp_axes = ("pod", "data") if multi_pod else ("data",)
    dp_total = 1
    for a in dp_axes:
        dp_total *= sizes.get(a, 1)
    overrides = CELL_OVERRIDES.get((cfg.name, shape.name), {})
    if accum_steps is None:
        accum_steps = overrides.get("accum_steps")
    if accum_steps is None and shape.kind == "train":
        accum_steps = default_accum_steps(cfg, shape, dp_total)
    if accum_steps is not None and shape.kind == "train":
        # the microbatch must stay divisible by the DP extent, or the
        # batch anchor degrades to replication (sweep-3 regression)
        accum_steps = max(1, min(accum_steps, shape.batch // dp_total))
        while shape.batch % accum_steps != 0:
            accum_steps -= 1
    # anchor activation batch sharding iff the (micro)batch divides DP
    eff_batch = shape.batch // (accum_steps or 1) if shape.kind == "train" \
        else shape.batch
    bax = dp_axes if eff_batch % dp_total == 0 else None
    fw = _fw_kwargs(cfg, shape, attn_chunk, batch_axes=bax)
    if sizes.get("model", 1) > 1 \
            and cfg.padded_vocab % sizes["model"] == 0:
        fw["vocab_axis"] = "model"  # anchor CE chain vocab sharding
    if cfg.is_moe and sizes.get("model", 1) > 1 \
            and cfg.moe.num_experts % sizes["model"] == 0:
        fw["moe_axes"] = (bax, "model")  # EP anchor for dispatch buffers
    if "layer_block" in overrides and cfg.family in ("dense", "moe", "vlm"):
        fw["layer_block"] = overrides["layer_block"]

    if shape.kind == "train":
        opt_structs = jax.eval_shape(adam_init, p_structs)
        opt_specs = {
            "mu": specs, "nu": specs, "count": P(),
        }
        bax = None if shape.batch % dp_total else dp_axes

        def to_micro(x):
            """(B, ...) -> (K, B/K, ...), microbatch-major, DP inner."""
            k = accum_steps
            y = x.reshape((k, x.shape[0] // k) + x.shape[1:])
            spec = P(None, bax, *([None] * (y.ndim - 2)))
            return jax.lax.with_sharding_constraint(
                y, NamedSharding(mesh, spec))

        def train_step(params, opt_state, *inputs):
            micro = tuple(to_micro(x) for x in inputs)

            def mb(carry, m_inputs):
                gsum, loss_sum = carry
                loss, grads = jax.value_and_grad(
                    lambda p: fns.loss(cfg, p, *m_inputs, **fw)
                )(params)
                gsum = jax.tree.map(jnp.add, gsum, grads)
                return (gsum, loss_sum + loss), None

            gzero = jax.tree.map(jnp.zeros_like, params)
            (gsum, loss_sum), _ = jax.lax.scan(
                mb, (gzero, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: g / accum_steps, gsum)
            if compress_grads:
                # bf16 round-trip on the accumulated grads: under pjit the
                # cross-DP reduction then moves half the bytes (§Perf C8+)
                grads = jax.tree.map(
                    lambda g: g.astype(jnp.bfloat16).astype(g.dtype), grads)
            grads = clip_by_global_norm(grads, grad_clip)
            params, opt_state = adam_update(
                grads, opt_state, params, lr, adam_cfg)
            return params, opt_state, loss_sum / accum_steps

        train_step.accum_steps = accum_steps
        args = (p_structs, opt_structs) + io["args"]
        shardings = (
            _shard(mesh, specs), _shard(mesh, opt_specs),
        ) + tuple(_shard(mesh, s) for s in io["specs"])
        out_shardings = (_shard(mesh, specs), _shard(mesh, opt_specs),
                         NamedSharding(mesh, P()))
        # donate params + opt state (in-place update at scale)
        return train_step, args, shardings, (0, 1), out_shardings

    if shape.kind == "prefill":
        max_len = shape.seq

        def prefill_step(params, *inputs):
            if cfg.family in ("dense", "moe", "vlm"):
                x, pos = inputs
                logits, cache = transformer.prefill(
                    cfg, params, x, pos, max_len, chunk=attn_chunk,
                    batch_axes=bax, moe_axes=fw.get("moe_axes"))
            elif cfg.family == "encdec":
                (x,) = inputs
                enc_out = encdec.encode(cfg, params, x,
                                        attn_mode="chunked",
                                        chunk=attn_chunk, batch_axes=bax)
                cache = encdec.init_cache(cfg, params, enc_out, max_len)
                logits = enc_out[:, -1:, :1]  # placeholder readout
            elif cfg.family == "hybrid":
                x, pos = inputs
                logits, cache = hybrid.prefill(
                    cfg, params, x, pos, max_len, chunk=attn_chunk,
                    batch_axes=bax)
            elif cfg.family == "rwkv":
                (x,) = inputs
                logits, cache = rwkv.prefill(cfg, params, x, batch_axes=bax)
            else:
                raise ValueError(cfg.family)
            next_tok = jnp.argmax(logits[..., -1, :], axis=-1)
            return next_tok, cache

        args = (p_structs,) + io["args"]
        shardings = (_shard(mesh, specs),) + tuple(
            _shard(mesh, s) for s in io["specs"])
        # CRITICAL: without explicit out_shardings XLA may replicate the
        # returned KV cache across the pod (observed: whisper prefill cache
        # at 96 GiB/device). Shard outputs like the decode-state specs.
        _, state_spec = decode_state_structs(
            cfg, shape.batch, max_len, multi_pod=multi_pod,
            mesh_sizes=sizes)
        out_shardings = (NamedSharding(mesh, P()), _shard(mesh, state_spec))
        return prefill_step, args, shardings, (), out_shardings

    # decode
    def decode_step(params, tokens, state, *rest):
        logits, new_state = fns.decode_step(cfg, params, tokens, state, *rest)
        next_tok = jnp.argmax(logits, axis=-1)
        return next_tok, new_state

    args = (p_structs,) + io["args"]
    shardings = (_shard(mesh, specs),) + tuple(
        _shard(mesh, s) for s in io["specs"])
    # state out_sharding = state in_sharding (donation aliases buffers)
    state_spec = io["specs"][1]
    out_shardings = (NamedSharding(mesh, P()), _shard(mesh, state_spec))
    # donate the state (index 2 overall: params=0, tokens=1, state=2)
    return decode_step, args, shardings, (2,), out_shardings
