"""Training-step builders and the Trainer loop (paper §III-C, §V-B/C).

``make_chgnet_step_fns`` builds jitted train/eval/serve steps for any
CHGNetConfig — both readout modes, so the Fig. 8 "decoupling" speedup and
the second-order-derivative cost are directly measurable.

``make_dp_train_step`` wraps the loss in shard_map data parallelism over a
mesh axis: per-device graph shards (leading axis), gradient all-reduce via
plain / bucketed / bf16-compressed psum (paper C8 + beyond-paper
compression), replicated Adam update.

Mixed precision (DESIGN.md §4): when ``CHGNetConfig.precision`` computes
below f32, the train steps scale the loss (``TrainConfig.loss_scale``),
unscale-to-f32 BEFORE clipping, skip the update on inf/nan grads (and
halve the dynamic scale), and keep f32 master weights via ``optim.adam``.
Scaler state lives INSIDE ``opt_state`` (``opt_state["loss_scale"]``), so
step signatures, the compile cache, the DP path, and checkpoints are
unchanged; metrics gain ``loss_scale`` / ``grads_finite`` entries.  The
same applies on the DP path: the psum reduces *scaled* grads (composing
with the bf16-compressed collective), and unscale/skip runs replicated
after the all-reduce so every device takes the same decision.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.batching import CompileCache, global_compile_cache
from repro.batching.balance import StepPlan
from repro.core.chgnet import CHGNetConfig, chgnet_apply, chgnet_init
from repro.core.graph import CrystalGraphBatch
from repro.core.losses import (
    LossWeights,
    chgnet_loss,
    chgnet_loss_sums,
    metrics_from_sums,
)
from repro.distributed.collectives import bucketed_psum, compressed_psum
from repro.optim.adam import AdamConfig, adam_init, adam_update
from repro.optim.grad import (
    clip_by_global_norm,
    tree_all_finite,
    unscale_grads,
)
from repro.optim.schedule import cosine_annealing, scaled_init_lr
from repro.precision import (
    LossScaleConfig,
    cast_float_tree,
    loss_scale_init,
    loss_scale_update,
    resolve_policy,
    scale_loss,
)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    global_batch: int = 128
    total_steps: int = 1000
    warmup_steps: int = 0
    lr_k: int = 128                # Eq. 14 divisor
    base_lr: float = 3e-4
    grad_clip: float = 1.0
    grad_reduce: str = "bucketed"  # "plain" | "bucketed" | "compressed"
    adam: AdamConfig = AdamConfig()
    loss: LossWeights = LossWeights()
    # loss scaling (DESIGN.md §4): "auto" enables the dynamic scaler iff
    # the model policy computes below f32, so the f32 path is unchanged
    loss_scale: LossScaleConfig = LossScaleConfig()
    # live cost-model refits (DESIGN.md §6): every K optimizer steps the
    # Trainer refits batching/cost.fit_cost_model from measured
    # per-microbatch wall times (block_until_ready per micro — only paid
    # when enabled) and hands the result to ``Trainer.on_cost_model``
    # (the launcher wires that to BalancedBatchIterator.update_cost_model,
    # closing the predict -> pack -> measure -> refit loop).  0 = off.
    cost_refit_every: int = 0
    # optimizer steps to discard before sampling (compile-inflated timings
    # would otherwise dominate the fit) and the bounded sample window
    cost_refit_warmup: int = 2
    cost_refit_window: int = 256
    # divergence rollback (DESIGN.md §8): when on, a streak of non-finite
    # losses (divergence_nan_streak) or of losses > divergence_spike_factor
    # x the trailing-median (divergence_spike_streak over a
    # divergence_window history) restores the newest VALID checkpoint,
    # multiplies the LR by rollback_lr_factor (cumulative, rides in
    # ``opt_state["lr_scale"]`` so it checkpoints; 1.0 = keep LR), and
    # quarantines the streak's batch indices via ``Trainer.on_quarantine``.
    # Scaler-skipped steps (§4 overflow rejections) never count.  Off by
    # default: the legacy single-NaN restore-or-raise guard applies.
    rollback_on_divergence: bool = False
    divergence_nan_streak: int = 2
    divergence_spike_factor: float = 10.0
    divergence_spike_streak: int = 4
    divergence_window: int = 32
    rollback_lr_factor: float = 0.5
    max_rollbacks: int = 8

    @property
    def init_lr(self) -> float:
        return scaled_init_lr(self.global_batch, self.lr_k, self.base_lr)


def chgnet_loss_fn(params, cfg: CHGNetConfig, batch: CrystalGraphBatch,
                   weights: LossWeights):
    pred = chgnet_apply(params, cfg, batch)
    return chgnet_loss(pred, batch, weights)


def _scaled_chgnet_loss_fn(params, cfg, batch, weights, scaler):
    """Loss for value_and_grad, multiplied by the (optional) loss scale.
    Metrics carry the UNSCALED loss."""
    loss, metrics = chgnet_loss_fn(params, cfg, batch, weights)
    if scaler is not None:
        loss = scale_loss(loss, scaler)
    return loss, metrics


def _apply_grads(grads, opt_state, params, lr, train_cfg: TrainConfig,
                 scale_kind: str):
    """Shared tail of every train step: (optionally) unscale -> clip ->
    Adam -> skip-on-nonfinite -> scaler update (DESIGN.md §4).

    ``opt_state`` may carry a ``"loss_scale"`` subtree; its presence (a
    trace-time structure property) turns on the scaled path.  An
    ``opt_state["lr_scale"]`` scalar (divergence rollback, DESIGN.md §8)
    multiplies the schedule LR and passes through ``adam_update`` like any
    extra state key.  Returns (params, opt_state, extra_metrics).
    """
    lr_scale = opt_state.get("lr_scale")
    if lr_scale is not None:
        lr = lr * lr_scale
    scaler = opt_state.get("loss_scale")
    if scaler is None:
        grads = clip_by_global_norm(grads, train_cfg.grad_clip)
        params, opt_state = adam_update(grads, opt_state, params, lr,
                                        train_cfg.adam)
        extra = {} if lr_scale is None else {"lr_scale": lr_scale}
        return params, opt_state, extra

    adam_state = {k: v for k, v in opt_state.items() if k != "loss_scale"}
    # unscale to f32 BEFORE clipping so the clip threshold is in true
    # gradient units; the finite check sees the true grads too
    grads = unscale_grads(grads, scaler["scale"])
    finite = tree_all_finite(grads)
    grads = clip_by_global_norm(grads, train_cfg.grad_clip)
    new_params, new_adam = adam_update(grads, adam_state, params, lr,
                                       train_cfg.adam)
    # inf/nan grads: skip the whole update (params, moments, count) …
    keep = lambda new, old: jax.tree.map(
        lambda n, o: jnp.where(finite, n, o), new, old)
    params = keep(new_params, params)
    adam_state = keep(new_adam, adam_state)
    # … and let the scaler back off / grow
    scaler = loss_scale_update(scaler, finite, train_cfg.loss_scale,
                               scale_kind)
    opt_state = dict(adam_state, loss_scale=scaler)
    extra = {"loss_scale": scaler["scale"],
             "grads_finite": finite.astype(jnp.float32)}
    if lr_scale is not None:
        extra["lr_scale"] = lr_scale
    return params, opt_state, extra


# ---------------------------------------------------------------------------
# Single-device steps
# ---------------------------------------------------------------------------

def make_chgnet_step_fns(model_cfg: CHGNetConfig, train_cfg: TrainConfig,
                         *, cache: CompileCache | None = None,
                         donate: bool = True):
    """Returns (train_step, eval_step, serve_step), all jitted.

    With ``cache`` (a ``repro.batching.CompileCache``), the jitted wrappers
    are memoized per ``(kind, model_cfg, train_cfg, donate)`` — a new
    Trainer after a fault restart reuses the already-traced step instead
    of starting from an empty jit cache.  (Per-shape/bucket specialisation
    below the wrapper is jit's own cache; the ladder bounds how many
    shapes exist.)

    ``donate`` (default on): the train step donates ``params``/
    ``opt_state`` and the serve step donates its batch — callers must
    treat those arguments as consumed (the Trainer loop rebinds both every
    step; ``benchmarks/bench_iteration.run_donation_probe`` tracks the
    compiled-memory delta).  Eval donates nothing: eval batches are
    legitimately reused.
    """

    def lr_at(step):
        return cosine_annealing(
            step, train_cfg.total_steps, train_cfg.init_lr,
            warmup_steps=train_cfg.warmup_steps,
        )

    scale_kind = train_cfg.loss_scale.resolved_kind(model_cfg.precision)

    def build_train():
        # donate params/opt_state: the returned trees alias the input
        # buffers instead of allocating fresh copies, so the params +
        # optimizer state never exist twice.  Callers must treat the
        # passed-in params/opt_state as consumed — the Trainer loop
        # rebinds both every step.
        @partial(jax.jit, donate_argnums=(0, 1) if donate else ())
        def train_step(params, opt_state, batch, step):
            scaler = opt_state.get("loss_scale")
            (_, metrics), grads = jax.value_and_grad(
                _scaled_chgnet_loss_fn, has_aux=True
            )(params, model_cfg, batch, train_cfg.loss, scaler)
            params, opt_state, extra = _apply_grads(
                grads, opt_state, params, lr_at(step), train_cfg,
                scale_kind)
            return params, opt_state, dict(metrics, **extra)

        return train_step

    def build_eval():
        @jax.jit
        def eval_step(params, batch):
            _, metrics = chgnet_loss_fn(params, model_cfg, batch,
                                        train_cfg.loss)
            return metrics

        return eval_step

    def build_serve():
        # donate the batch (the serve step's per-call state): each packed
        # batch is consumed exactly once per prediction, so its buffers
        # can back the outputs; params are NOT donated (reused every call)
        @partial(jax.jit, donate_argnums=(1,) if donate else ())
        def serve_step(params, batch):
            """One MD step's worth of inference (Table II)."""
            return chgnet_apply(params, model_cfg, batch)

        return serve_step

    if cache is None:
        return build_train(), build_eval(), build_serve()
    # donate is part of the key: a donated and an undonated step are
    # different executables and must never satisfy each other's lookups
    key = (model_cfg, train_cfg, donate)
    return (
        cache.get(("chgnet_train",) + key, build_train),
        cache.get(("chgnet_eval",) + key, build_eval),
        cache.get(("chgnet_serve",) + key, build_serve),
    )


def make_chgnet_eval_serve_step(model_cfg: CHGNetConfig,
                                train_cfg: TrainConfig,
                                *, cache: CompileCache | None = None,
                                donate: bool = True):
    """One jitted ``(params, batch) -> (metrics, outputs)`` step that runs
    the forward ONCE and derives both the eval metrics and the serve
    outputs from it — callers that want predictions *and* MAEs (validation
    epochs that archive outputs, MD loops that log errors) previously paid
    two forwards and kept two batches resident.

    ``donate`` (default on): the batch is consumed exactly once per call,
    so its buffers may back the outputs (``tests/test_donation.py``
    asserts the aliasing survives compilation); params are NOT donated —
    they are reused every call, matching the serve-step contract.
    """

    def build():
        @partial(jax.jit, donate_argnums=(1,) if donate else ())
        def eval_serve_step(params, batch):
            out = chgnet_apply(params, model_cfg, batch)
            _, metrics = chgnet_loss(out, batch, train_cfg.loss)
            return metrics, out

        return eval_serve_step

    if cache is None:
        return build()
    return cache.get(("chgnet_eval_serve", model_cfg, train_cfg, donate),
                     build)


# ---------------------------------------------------------------------------
# Data-parallel step (shard_map over a mesh axis)
# ---------------------------------------------------------------------------

def make_dp_train_step(model_cfg: CHGNetConfig, train_cfg: TrainConfig,
                       mesh: Mesh, axis: str = "data",
                       *, cache: CompileCache | None = None,
                       donate: bool = True):
    """Train step over per-device graph shards (leading axis = devices).

    batch leaves: (num_devices, ...) sharded P(axis); params replicated.
    ``donate`` mirrors the single-device contract (params/opt_state are
    consumed) and is part of the compile-cache key.
    """
    if cache is not None:
        return cache.get(
            ("chgnet_dp_train", model_cfg, train_cfg, mesh, axis, donate),
            lambda: make_dp_train_step(model_cfg, train_cfg, mesh, axis,
                                       donate=donate),
        )

    def lr_at(step):
        return cosine_annealing(
            step, train_cfg.total_steps, train_cfg.init_lr,
            warmup_steps=train_cfg.warmup_steps,
        )

    scale_kind = train_cfg.loss_scale.resolved_kind(model_cfg.precision)

    def local_step(params, opt_state, batch, step):
        # leading device axis is 1 locally -> squeeze
        local_batch = jax.tree.map(lambda x: x[0], batch)
        scaler = opt_state.get("loss_scale")
        (_, metrics), grads = jax.value_and_grad(
            _scaled_chgnet_loss_fn, has_aux=True
        )(params, model_cfg, local_batch, train_cfg.loss, scaler)
        # the all-reduce sees SCALED grads (composes with the bf16
        # compressed psum: scaling lifts small cotangents above bf16's
        # rounding before quantization); unscale + skip run replicated
        # after it, so every device takes the same decision
        if train_cfg.grad_reduce == "plain":
            grads = jax.lax.psum(grads, axis)
        elif train_cfg.grad_reduce == "bucketed":
            grads = bucketed_psum(grads, axis)
        elif train_cfg.grad_reduce == "compressed":
            grads = compressed_psum(grads, axis)
        else:
            raise ValueError(train_cfg.grad_reduce)
        grads = jax.tree.map(lambda g: g / mesh.shape[axis], grads)
        params, opt_state, extra = _apply_grads(
            grads, opt_state, params, lr_at(step), train_cfg, scale_kind)
        metrics = jax.lax.pmean(metrics, axis)
        return params, opt_state, dict(metrics, **extra)

    batch_spec = P(axis)
    sharded = shard_map(
        local_step,
        mesh=mesh,
        in_specs=(P(), P(), batch_spec, P()),
        out_specs=(P(), P(), P()),
        check_rep=False,
    )
    # donate params/opt_state (same contract as the single-device step)
    return jax.jit(sharded, donate_argnums=(0, 1) if donate else ())


def make_dp_eval_step(model_cfg: CHGNetConfig, train_cfg: TrainConfig,
                      mesh: Mesh, axis: str = "data",
                      *, cache: CompileCache | None = None,
                      donate: bool = False):
    """Replicated-params eval over per-device graph shards -> pmean metrics.

    ``donate`` (default OFF, matching single-device eval: eval batches are
    legitimately reused) consumes the batch — opt in for one-shot eval
    sweeps where every packed batch is fresh.  Note eval outputs are
    scalar metrics, so XLA can never actually *alias* a donated batch
    buffer here — donation only releases the buffers early; the flag
    still rides the compile-cache key so donated/undonated builds never
    collide.
    """
    if cache is not None:
        return cache.get(
            ("chgnet_dp_eval", model_cfg, train_cfg, mesh, axis, donate),
            lambda: make_dp_eval_step(model_cfg, train_cfg, mesh, axis,
                                      donate=donate),
        )

    def local_eval(params, batch):
        local_batch = jax.tree.map(lambda x: x[0], batch)
        _, metrics = chgnet_loss_fn(params, model_cfg, local_batch,
                                    train_cfg.loss)
        return jax.lax.pmean(metrics, axis)

    return jax.jit(shard_map(
        local_eval, mesh=mesh,
        in_specs=(P(), P(axis)), out_specs=P(), check_rep=False,
    ), donate_argnums=(1,) if donate else ())


def make_dp_serve_step(model_cfg: CHGNetConfig, mesh: Mesh,
                       axis: str = "data",
                       *, cache: CompileCache | None = None,
                       donate: bool = True):
    """Replicated-params inference; outputs keep the leading device axis.

    ``donate`` (default on, same contract as single-device serve): each
    packed batch is consumed exactly once per prediction, so its float
    buffers can back the outputs; params are never donated.
    """
    if cache is not None:
        return cache.get(
            ("chgnet_dp_serve", model_cfg, mesh, axis, donate),
            lambda: make_dp_serve_step(model_cfg, mesh, axis,
                                       donate=donate),
        )

    def local_serve(params, batch):
        local_batch = jax.tree.map(lambda x: x[0], batch)
        out = chgnet_apply(params, model_cfg, local_batch)
        return jax.tree.map(lambda x: x[None], out)

    return jax.jit(shard_map(
        local_serve, mesh=mesh,
        in_specs=(P(), P(axis)), out_specs=P(axis), check_rep=False,
    ), donate_argnums=(1,) if donate else ())


# ---------------------------------------------------------------------------
# Gradient accumulation across uneven capacity buckets (DESIGN.md §6)
# ---------------------------------------------------------------------------

def make_chgnet_accum_step_fns(model_cfg: CHGNetConfig,
                               train_cfg: TrainConfig,
                               *, mesh: Mesh | None = None,
                               axis: str = "data",
                               cache: CompileCache | None = None,
                               donate: bool = True):
    """Returns ``(grad_step, apply_step)`` for bucketed accumulation.

    One optimizer step = several microbatches, each packed to its OWN
    (smallest-fitting) capacity bucket by the balancer
    (``repro.batching.balance.plan_microbatches``):

      - ``grad_step(params, batch, denoms, scale) -> (grads, sums)``
        computes the gradient of this microbatch's *partial* loss —
        masked Huber sums over the step-global ``denoms``
        (``losses.global_denominators``) times ``scale`` (the loss-scale
        value, 1.0 on the f32 path).  Because the denominators are
        global, microbatch losses/grads are exactly additive: summing
        them reproduces the single-big-batch gradient bit-for-bit in
        expectation and to ~1e-6 in f32 practice (reassociation only).
        In mesh mode the shard_map psum performs the *device* half of
        that same sum (no ``/num_devices`` — the global denominators
        already normalize), so idle all-padding shards add exact zeros.
      - ``apply_step(params, opt_state, grads, sums, denoms, step)``
        runs the shared update tail (unscale -> clip -> Adam ->
        skip-on-nonfinite -> scaler update).  Skip-on-inf composes across
        microbatches for free: an inf/nan in ANY microbatch poisons the
        accumulated sum, so the one finite-check in ``_apply_grads``
        rejects the whole step and backs the scale off, exactly like a
        single-batch overflow.

    ``donate``: apply_step donates params/opt_state (the Trainer rebinds
    both).  grad_step donates NOTHING: its outputs are param-shaped
    grads plus scalar sums, so no batch buffer could ever back an output
    — donating the batch would only emit unusable-donation warnings.
    """
    if cache is not None:
        key = ("chgnet_accum", model_cfg, train_cfg, mesh, axis, donate)
        return cache.get(key, lambda: make_chgnet_accum_step_fns(
            model_cfg, train_cfg, mesh=mesh, axis=axis, donate=donate))

    def lr_at(step):
        return cosine_annealing(
            step, train_cfg.total_steps, train_cfg.init_lr,
            warmup_steps=train_cfg.warmup_steps,
        )

    scale_kind = train_cfg.loss_scale.resolved_kind(model_cfg.precision)

    def local_grads(params, batch, denoms, scale):
        def loss_fn(p):
            pred = chgnet_apply(p, model_cfg, batch)
            loss, sums = chgnet_loss_sums(pred, batch, train_cfg.loss,
                                          denoms)
            return loss * scale.astype(loss.dtype), sums

        (_, sums), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        return grads, sums

    if mesh is None:
        grad_step = jax.jit(local_grads)
    else:
        def local_step(params, batch, denoms, scale):
            local_batch = jax.tree.map(lambda x: x[0], batch)
            grads, sums = local_grads(params, local_batch, denoms, scale)
            # device dimension of the global sum: psum partial grads/sums,
            # NO division — global denominators already normalize, and
            # all-padding shards (devices idled by a small microbatch)
            # contribute exact zeros
            if train_cfg.grad_reduce == "plain":
                grads = jax.lax.psum(grads, axis)
            elif train_cfg.grad_reduce == "bucketed":
                grads = bucketed_psum(grads, axis)
            elif train_cfg.grad_reduce == "compressed":
                grads = compressed_psum(grads, axis)
            else:
                raise ValueError(train_cfg.grad_reduce)
            sums = jax.lax.psum(sums, axis)
            return grads, sums

        grad_step = jax.jit(shard_map(
            local_step, mesh=mesh,
            in_specs=(P(), P(axis), P(), P()),
            out_specs=(P(), P()),
            check_rep=False,
        ))

    # donate params/opt_state only: grads' buffers can't back any output
    # (params/opt_state already alias them all), so donating them would
    # just emit unusable-donation warnings every trace
    @partial(jax.jit, donate_argnums=(0, 1) if donate else ())
    def apply_step(params, opt_state, grads, sums, denoms, step):
        params, opt_state, extra = _apply_grads(
            grads, opt_state, params, lr_at(step), train_cfg, scale_kind)
        metrics = metrics_from_sums(sums, denoms)
        return params, opt_state, dict(metrics, **extra)

    return grad_step, apply_step


def _strip_precision_state(state: dict) -> dict:
    """Trainer-state template minus the policy-dependent leaves
    (``opt_state["loss_scale"]`` / ``opt_state["master"]`` from DESIGN.md
    §4, ``opt_state["lr_scale"]`` from the §8 rollback policy) — the shape
    a checkpoint written under different flags has.  The restore path
    re-grows whichever of them this trainer wants."""
    opt = {k: v for k, v in state["opt_state"].items()
           if k not in ("loss_scale", "master", "lr_scale")}
    return dict(state, opt_state=opt)


# ---------------------------------------------------------------------------
# Trainer loop with periodic checkpoint + straggler watch
# ---------------------------------------------------------------------------

class Trainer:
    def __init__(
        self,
        model_cfg: CHGNetConfig,
        train_cfg: TrainConfig,
        *,
        seed: int = 0,
        mesh: Mesh | None = None,
        ckpt_dir: str | None = None,
        ckpt_every: int = 100,
        keep: int = 3,
        compile_cache: CompileCache | None = None,
        async_ckpt: bool = False,
        shutdown=None,
        donate: bool = True,
        donate_eval: bool = False,
    ):
        self.model_cfg = model_cfg
        self.train_cfg = train_cfg
        # buffer-donation policy, threaded through every step builder's
        # compile-cache ``donate`` flag (donated/undonated builds never
        # collide in the cache): ``donate`` covers train (params/opt_state)
        # and serve (batch); ``donate_eval`` opts the DP eval step into
        # consuming its batch — OFF by default because eval batches are
        # legitimately reused across eval passes
        self.donate = donate
        self.donate_eval = donate_eval
        self.params = chgnet_init(jax.random.PRNGKey(seed), model_cfg)
        # mixed precision (DESIGN.md §4): low-precision param storage gets
        # f32 master weights in the optimizer; low-precision compute gets
        # a loss scaler whose state rides inside opt_state (-> checkpoints
        # and the compile cache carry it with zero signature changes)
        policy = resolve_policy(model_cfg.precision)
        self.opt_state = adam_init(
            self.params,
            master_dtype=jnp.float32 if policy.needs_master_weights
            else None)
        self._scale_kind = train_cfg.loss_scale.resolved_kind(policy)
        if self._scale_kind != "none":
            self.opt_state["loss_scale"] = loss_scale_init(
                train_cfg.loss_scale)
        self.step = 0
        self.mesh = mesh
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.keep = keep
        # async checkpoints (DESIGN.md §8): snapshot on the loop thread,
        # serialize/fsync/prune on a background writer; sync mode (the
        # default) keeps the reference single-threaded path for tests
        self._ckpt_writer = None
        if async_ckpt and ckpt_dir is not None:
            from repro.runtime.async_ckpt import AsyncCheckpointWriter

            self._ckpt_writer = AsyncCheckpointWriter(ckpt_dir, keep=keep)
        # preemption (DESIGN.md §8): a runtime.fault.GracefulShutdown whose
        # flag is polled every step; on SIGTERM the loop writes a final
        # checkpoint + resume marker and raises PreemptionError
        self.shutdown = shutdown
        # step functions go through the shared repro.batching compile cache
        # so a restarted Trainer (fault tolerance path) reuses traced steps
        cache = compile_cache if compile_cache is not None \
            else global_compile_cache()
        self.compile_cache = cache
        self._build_steps()
        from repro.runtime.fault import DivergenceSentinel, StragglerWatch

        self.straggler = StragglerWatch()
        # divergence rollback (DESIGN.md §8): the sentinel trips on
        # NaN/spike streaks; lr_scale rides in opt_state so the halved LR
        # survives checkpoints; quarantine bookkeeping maps the streak
        # back to dataset indices when batches arrive tagged
        if train_cfg.rollback_on_divergence:
            self.sentinel = DivergenceSentinel(
                window=train_cfg.divergence_window,
                nan_streak=train_cfg.divergence_nan_streak,
                spike_factor=train_cfg.divergence_spike_factor,
                spike_streak=train_cfg.divergence_spike_streak)
            self.opt_state["lr_scale"] = jnp.asarray(1.0, jnp.float32)
        else:
            self.sentinel = None
        self._lr_scale = 1.0
        self.rollbacks = 0
        self.quarantined: set[int] = set()
        self.on_quarantine: Callable[[list[int]], None] | None = None
        from collections import deque

        self._recent_indices: deque = deque(maxlen=max(2 * ckpt_every, 64))
        # live cost-model refit state (TrainConfig.cost_refit_every):
        # (micro_sizes, wall_time) samples, the latest refit CostModel, and
        # the consumer callback (the launcher wires it to
        # BalancedBatchIterator.update_cost_model)
        self._cost_samples: list[tuple[Any, float]] = []
        self._profiled_plans = 0
        self.cost_model = None
        self.on_cost_model: Callable[[Any], None] | None = None

    def _build_steps(self):
        """(Re)build the step functions for the current ``self.mesh``."""
        cache, model_cfg, train_cfg = (self.compile_cache, self.model_cfg,
                                       self.train_cfg)
        if self.mesh is not None:
            # build all three steps: a mesh-mode Trainer must be able to
            # eval and serve too (previously only _train_step existed, so
            # multi-device eval/serve hit undefined attributes).  The
            # donate flags ride the compile-cache keys inside the builders.
            self._train_step = make_dp_train_step(model_cfg, train_cfg,
                                                  self.mesh, cache=cache,
                                                  donate=self.donate)
            self._eval_step = make_dp_eval_step(model_cfg, train_cfg,
                                                self.mesh, cache=cache,
                                                donate=self.donate_eval)
            self._serve_step = make_dp_serve_step(model_cfg, self.mesh,
                                                  cache=cache,
                                                  donate=self.donate)
        else:
            self._train_step, self._eval_step, self._serve_step = (
                make_chgnet_step_fns(model_cfg, train_cfg, cache=cache,
                                     donate=self.donate)
            )
        # accumulation steps are built lazily on the first StepPlan
        self._accum_fns = None

    @property
    def num_devices(self) -> int:
        return int(self.mesh.devices.size) if self.mesh is not None else 1

    def rebuild_mesh(self, mesh: Mesh | None):
        """Re-target the trainer at a (possibly shrunken) mesh.

        The elastic path (``runtime.elastic.elastic_train``) calls this
        after a device drop: params/opt_state are pulled to host first so
        nothing references the dead device's buffers, then the step
        functions are rebuilt (compile-cache keyed by mesh, so returning
        to a previously-seen mesh retraces nothing).
        """
        self.params = jax.device_get(self.params)
        self.opt_state = jax.device_get(self.opt_state)
        self.mesh = mesh
        self._build_steps()

    # -- checkpoint hooks ---------------------------------------------------
    def state(self):
        return {"params": self.params, "opt_state": self.opt_state}

    def save(self, *, wait: bool = False):
        """Checkpoint the current state (async when the Trainer was built
        with ``async_ckpt=True``; ``wait`` forces durability — used for
        final/preemption saves)."""
        if self.ckpt_dir is None:
            return
        meta = {"model_cfg": dataclasses.asdict(self.model_cfg)}
        if self._ckpt_writer is not None:
            self._ckpt_writer.save(self.step, self.state(), extra_meta=meta)
            if wait:
                self._ckpt_writer.flush()
            return
        from repro.runtime.checkpoint import save_checkpoint

        save_checkpoint(
            self.ckpt_dir, self.step, self.state(), keep=self.keep,
            extra_meta=meta,
        )

    def flush_checkpoints(self):
        """Block until every queued async checkpoint is durably written."""
        if self._ckpt_writer is not None:
            self._ckpt_writer.flush()

    def close(self):
        """Flush + stop the async checkpoint writer (idempotent)."""
        if self._ckpt_writer is not None:
            self._ckpt_writer.close()

    def maybe_restore(self) -> bool:
        if self.ckpt_dir is None:
            return False
        # land any in-flight async write first, so "newest valid" below
        # includes it; restore_checkpoint(step=None) then walks newest ->
        # oldest past corrupt/truncated files (DESIGN.md §8)
        self.flush_checkpoints()
        from repro.runtime.checkpoint import latest_step, restore_checkpoint

        if latest_step(self.ckpt_dir) is None:
            return False
        from repro.runtime.checkpoint import MissingLeafError

        # Two independent layout migrations, each applied at most once:
        #   - packed GatedMLP (PR 3): legacy separate core/gate weights are
        #     restored into the legacy-shaped template and packed ONCE here
        #     (checkpoint-load), so no jitted step re-concatenates params;
        #   - precision state (DESIGN.md §4): a legacy f32 checkpoint has
        #     no ``opt_state["loss_scale"]`` / ``opt_state["master"]``
        #     leaves — restore into a stripped template, then re-grow both
        #     from the restored params below.
        # Any other missing leaf (genuinely incompatible checkpoint) —
        # and any failure of a migration attempt — re-raises the FIRST
        # error so the real mismatch surfaces, not a misleading one.
        packed_keys = ("['w']", "['b']", "['ln_scale']", "['ln_bias']")
        precision_keys = ("['loss_scale']", "['master']", "['lr_scale']")
        from repro.core.interaction import (
            gated_mlp_legacy_template, pack_gated_mlp_params)

        wants_master = "master" in self.opt_state
        template = self.state()
        stripped = packed = False
        first_err = None
        while True:
            try:
                state, step, _ = restore_checkpoint(self.ckpt_dir, template)
                break
            except MissingLeafError as missing:
                first_err = first_err or missing
                if not stripped and any(k in missing.leaf_path
                                        for k in precision_keys):
                    template = _strip_precision_state(template)
                    stripped = True
                    continue
                if not packed and missing.leaf_path.endswith(packed_keys):
                    template = gated_mlp_legacy_template(template)
                    packed = True
                    continue
                # no migration applies: THIS leaf is genuinely missing
                # from the checkpoint (migrations only strip/rename their
                # own leaves), so it is the real mismatch to surface
                raise missing
            except (KeyError, ValueError):
                if first_err is not None:
                    raise first_err
                raise
        if packed:
            state = pack_gated_mlp_params(state)
        self.params, self.opt_state = state["params"], state["opt_state"]
        if stripped:
            # legacy-f32 -> mixed-precision migration: master weights are
            # re-grown from the restored params (exact for policies that
            # store f32 params) and the scaler restarts at init_scale
            if wants_master:
                self.opt_state["master"] = cast_float_tree(
                    self.params, jnp.float32)
            if self._scale_kind != "none":
                self.opt_state["loss_scale"] = loss_scale_init(
                    self.train_cfg.loss_scale)
            if self.train_cfg.rollback_on_divergence:
                # legacy checkpoint without lr_scale: re-grow it at the
                # trainer's CURRENT cumulative rollback factor, so a
                # post-rollback restore keeps the backed-off LR
                self.opt_state["lr_scale"] = jnp.asarray(
                    self._lr_scale, jnp.float32)
        self.step = step
        return True

    # -- eval / serve -------------------------------------------------------
    def evaluate(self, batch) -> dict:
        """Loss metrics on one batch (stacked per-device leaves in mesh mode)."""
        return {k: float(v)
                for k, v in self._eval_step(self.params, batch).items()}

    def serve(self, batch):
        """One inference step (E/F/sigma/magmom); Table II's workload."""
        return self._serve_step(self.params, batch)

    # -- gradient accumulation (DESIGN.md §6) --------------------------------
    def _get_accum_fns(self):
        if self._accum_fns is None:
            self._accum_fns = make_chgnet_accum_step_fns(
                self.model_cfg, self.train_cfg, mesh=self.mesh,
                cache=self.compile_cache, donate=self.donate)
        return self._accum_fns

    def _step_plan(self, plan: StepPlan):
        """One optimizer step over a balanced multi-bucket StepPlan:
        per-microbatch grads (global-denominator partial losses) are
        summed on device, then applied once — numerically the same update
        a single big-batch step would take (tests: test_balance)."""
        grad_step, apply_step = self._get_accum_fns()
        scaler = self.opt_state.get("loss_scale")
        scale = scaler["scale"] if scaler is not None \
            else jnp.asarray(1.0, jnp.float32)
        denoms = {k: jnp.asarray(v) for k, v in plan.denoms.items()}
        # per-microbatch timing for the live cost-model refit: only when
        # enabled (the block_until_ready sync breaks async dispatch, so
        # the default path stays fully pipelined), only past the compile
        # warmup, and only for plans that carry their real feature sizes
        profile = (self.train_cfg.cost_refit_every > 0
                   and plan.micro_sizes is not None)
        gsum = ssum = None
        for i, micro in enumerate(plan.micro):
            t0 = time.perf_counter() if profile else 0.0
            grads, sums = grad_step(self.params, micro, denoms, scale)
            if profile:
                jax.block_until_ready(grads)
                if self._profiled_plans >= self.train_cfg.cost_refit_warmup:
                    self._cost_samples.append(
                        (plan.micro_sizes[i], time.perf_counter() - t0))
            if gsum is None:
                gsum, ssum = grads, sums
            else:
                gsum = jax.tree.map(jnp.add, gsum, grads)
                ssum = jax.tree.map(jnp.add, ssum, sums)
        if profile:
            self._profiled_plans += 1
            del self._cost_samples[:-self.train_cfg.cost_refit_window]
        return apply_step(self.params, self.opt_state, gsum, ssum, denoms,
                          jnp.asarray(self.step))

    def _maybe_refit_cost_model(self):
        """Refit the LPT cost model from recorded (sizes, time) samples
        every ``cost_refit_every`` optimizer steps and push it to
        ``on_cost_model`` (DESIGN.md §6).  Needs >= 4 samples (the affine
        fit has 4 coefficients); nonneg-clamped lstsq, host-side only."""
        every = self.train_cfg.cost_refit_every
        if every <= 0 or self.step % every or len(self._cost_samples) < 4:
            return
        import numpy as np

        from repro.batching.cost import fit_cost_model

        sizes = np.asarray([s for s, _ in self._cost_samples], np.float64)
        times = np.asarray([t for _, t in self._cost_samples], np.float64)
        self.cost_model = fit_cost_model(sizes, times)
        if self.on_cost_model is not None:
            self.on_cost_model(self.cost_model)

    # -- divergence rollback / preemption (DESIGN.md §8) ---------------------
    def _rollback(self):
        """Sentinel tripped: quarantine the streak's batches, restore the
        newest valid checkpoint, and (optionally) back the LR off."""
        self.rollbacks += 1
        if self.rollbacks > self.train_cfg.max_rollbacks:
            raise FloatingPointError(
                f"divergence persists after {self.train_cfg.max_rollbacks} "
                f"rollbacks (step {self.step})")
        # the streak's batches are the prime suspects: quarantine their
        # dataset indices so the iterator skips them after the restore
        trip_len = self.sentinel.last_trip_len if self.sentinel else 0
        fresh: set[int] = set()
        for _, idx in list(self._recent_indices)[-max(trip_len, 1):]:
            fresh.update(int(i) for i in idx)
        fresh -= self.quarantined
        if fresh:
            self.quarantined |= fresh
            if self.on_quarantine is not None:
                self.on_quarantine(sorted(fresh))
        if not self.maybe_restore():
            raise FloatingPointError(
                f"divergence at step {self.step} with no checkpoint to "
                "roll back to (ckpt_dir unset or empty)")
        factor = self.train_cfg.rollback_lr_factor
        if factor < 1.0:
            self._lr_scale *= factor
            self.opt_state["lr_scale"] = jnp.asarray(
                self._lr_scale, jnp.float32)

    def _preempt(self):
        """SIGTERM (or any GracefulShutdown signal): durably checkpoint,
        drop a resume marker, and raise PreemptionError — which
        ``run_with_restarts`` never retries (handing control to the
        scheduler is the point)."""
        from repro.runtime.fault import PreemptionError, write_resume_marker

        if self.ckpt_dir is not None:
            self.save(wait=True)
            signum = self.shutdown.signum if self.shutdown else None
            write_resume_marker(self.ckpt_dir, self.step,
                                reason=f"signal {signum}")
        raise PreemptionError(self.step)

    # -- loop -----------------------------------------------------------------
    def train(self, batches, max_steps: int | None = None,
              fault_injector=None) -> list[dict]:
        history = []
        try:
            return self._train_loop(batches, history, max_steps,
                                    fault_injector)
        except Exception as exc:
            # steps completed before the failure are real progress — let
            # recovery paths (runtime.elastic.elastic_train) keep their
            # metrics instead of losing them with the raise
            exc.partial_history = history
            raise

    def _train_loop(self, batches, history, max_steps, fault_injector):
        import numpy as np

        from repro.data.pipeline import TaggedBatch

        for batch in batches:
            if max_steps is not None and self.step >= max_steps:
                break
            if self.shutdown is not None and self.shutdown.requested:
                self._preempt()
            t0 = time.perf_counter()
            if fault_injector is not None:
                fault_injector.maybe_fail(self.step)
            indices = None
            if isinstance(batch, TaggedBatch):
                indices, batch = batch.indices, batch.batch
            if isinstance(batch, StepPlan):
                self.params, self.opt_state, metrics = self._step_plan(batch)
            else:
                self.params, self.opt_state, metrics = self._train_step(
                    self.params, self.opt_state, batch,
                    jnp.asarray(self.step)
                )
            if indices is not None:
                self._recent_indices.append(
                    (self.step, np.asarray(indices)))
            loss = float(metrics["loss"])
            # a scaler-skipped overflow step (grads_finite == 0) is NOT
            # poison: the update was rejected and the scale backed off,
            # so params are untouched (DESIGN.md §4)
            skipped = not bool(metrics.get("grads_finite", 1.0))
            if self.sentinel is not None:
                if self.sentinel.record(loss, scaler_skipped=skipped):
                    self._rollback()
                    continue
            elif not jnp.isfinite(loss) and not skipped:
                # legacy NaN guard: roll back rather than poison the run
                if self.maybe_restore():
                    continue
                raise FloatingPointError(f"non-finite loss at step {self.step}")
            self.step += 1
            self.straggler.record(time.perf_counter() - t0)
            self._maybe_refit_cost_model()
            history.append({k: float(v) for k, v in metrics.items()})
            if self.ckpt_dir is not None and self.step % self.ckpt_every == 0:
                # only checkpoint states the sentinel considers healthy,
                # so every file on disk is a known-good rollback target
                if self.sentinel is None or not self.sentinel.suspicious:
                    self.save()
        return history
