"""Training-step builders and the Trainer loop (paper §III-C, §V-B/C).

``make_chgnet_step_fns`` builds jitted train/eval/serve steps for any
CHGNetConfig — both readout modes, so the Fig. 8 "decoupling" speedup and
the second-order-derivative cost are directly measurable.

``make_dp_train_step`` wraps the loss in shard_map data parallelism over a
mesh axis: per-device graph shards (leading axis), gradient all-reduce via
plain / bucketed / bf16-compressed psum (paper C8 + beyond-paper
compression), replicated Adam update.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.batching import CompileCache, global_compile_cache
from repro.core.chgnet import CHGNetConfig, chgnet_apply, chgnet_init
from repro.core.graph import CrystalGraphBatch
from repro.core.losses import LossWeights, chgnet_loss
from repro.distributed.collectives import bucketed_psum, compressed_psum
from repro.optim.adam import AdamConfig, adam_init, adam_update
from repro.optim.grad import clip_by_global_norm
from repro.optim.schedule import cosine_annealing, scaled_init_lr


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    global_batch: int = 128
    total_steps: int = 1000
    warmup_steps: int = 0
    lr_k: int = 128                # Eq. 14 divisor
    base_lr: float = 3e-4
    grad_clip: float = 1.0
    grad_reduce: str = "bucketed"  # "plain" | "bucketed" | "compressed"
    adam: AdamConfig = AdamConfig()
    loss: LossWeights = LossWeights()

    @property
    def init_lr(self) -> float:
        return scaled_init_lr(self.global_batch, self.lr_k, self.base_lr)


def chgnet_loss_fn(params, cfg: CHGNetConfig, batch: CrystalGraphBatch,
                   weights: LossWeights):
    pred = chgnet_apply(params, cfg, batch)
    return chgnet_loss(pred, batch, weights)


# ---------------------------------------------------------------------------
# Single-device steps
# ---------------------------------------------------------------------------

def make_chgnet_step_fns(model_cfg: CHGNetConfig, train_cfg: TrainConfig,
                         *, cache: CompileCache | None = None):
    """Returns (train_step, eval_step, serve_step), all jitted.

    With ``cache`` (a ``repro.batching.CompileCache``), the jitted wrappers
    are memoized per ``(kind, model_cfg, train_cfg)`` — a new Trainer after
    a fault restart reuses the already-traced step instead of starting
    from an empty jit cache.  (Per-shape/bucket specialisation below the
    wrapper is jit's own cache; the ladder bounds how many shapes exist.)
    """

    def lr_at(step):
        return cosine_annealing(
            step, train_cfg.total_steps, train_cfg.init_lr,
            warmup_steps=train_cfg.warmup_steps,
        )

    def build_train():
        @jax.jit
        def train_step(params, opt_state, batch, step):
            (_, metrics), grads = jax.value_and_grad(
                chgnet_loss_fn, has_aux=True
            )(params, model_cfg, batch, train_cfg.loss)
            grads = clip_by_global_norm(grads, train_cfg.grad_clip)
            params, opt_state = adam_update(
                grads, opt_state, params, lr_at(step), train_cfg.adam
            )
            return params, opt_state, metrics

        return train_step

    def build_eval():
        @jax.jit
        def eval_step(params, batch):
            _, metrics = chgnet_loss_fn(params, model_cfg, batch,
                                        train_cfg.loss)
            return metrics

        return eval_step

    def build_serve():
        @jax.jit
        def serve_step(params, batch):
            """One MD step's worth of inference (Table II)."""
            return chgnet_apply(params, model_cfg, batch)

        return serve_step

    if cache is None:
        return build_train(), build_eval(), build_serve()
    key = (model_cfg, train_cfg)
    return (
        cache.get(("chgnet_train",) + key, build_train),
        cache.get(("chgnet_eval",) + key, build_eval),
        cache.get(("chgnet_serve",) + key, build_serve),
    )


# ---------------------------------------------------------------------------
# Data-parallel step (shard_map over a mesh axis)
# ---------------------------------------------------------------------------

def make_dp_train_step(model_cfg: CHGNetConfig, train_cfg: TrainConfig,
                       mesh: Mesh, axis: str = "data",
                       *, cache: CompileCache | None = None):
    """Train step over per-device graph shards (leading axis = devices).

    batch leaves: (num_devices, ...) sharded P(axis); params replicated.
    """
    if cache is not None:
        return cache.get(
            ("chgnet_dp_train", model_cfg, train_cfg, mesh, axis),
            lambda: make_dp_train_step(model_cfg, train_cfg, mesh, axis),
        )

    def lr_at(step):
        return cosine_annealing(
            step, train_cfg.total_steps, train_cfg.init_lr,
            warmup_steps=train_cfg.warmup_steps,
        )

    def local_step(params, opt_state, batch, step):
        # leading device axis is 1 locally -> squeeze
        local_batch = jax.tree.map(lambda x: x[0], batch)
        (_, metrics), grads = jax.value_and_grad(
            chgnet_loss_fn, has_aux=True
        )(params, model_cfg, local_batch, train_cfg.loss)
        if train_cfg.grad_reduce == "plain":
            grads = jax.lax.psum(grads, axis)
        elif train_cfg.grad_reduce == "bucketed":
            grads = bucketed_psum(grads, axis)
        elif train_cfg.grad_reduce == "compressed":
            grads = compressed_psum(grads, axis)
        else:
            raise ValueError(train_cfg.grad_reduce)
        grads = jax.tree.map(lambda g: g / mesh.shape[axis], grads)
        grads = clip_by_global_norm(grads, train_cfg.grad_clip)
        params, opt_state = adam_update(
            grads, opt_state, params, lr_at(step), train_cfg.adam
        )
        metrics = jax.lax.pmean(metrics, axis)
        return params, opt_state, metrics

    batch_spec = P(axis)
    sharded = shard_map(
        local_step,
        mesh=mesh,
        in_specs=(P(), P(), batch_spec, P()),
        out_specs=(P(), P(), P()),
        check_rep=False,
    )
    return jax.jit(sharded)


def make_dp_eval_step(model_cfg: CHGNetConfig, train_cfg: TrainConfig,
                      mesh: Mesh, axis: str = "data",
                      *, cache: CompileCache | None = None):
    """Replicated-params eval over per-device graph shards -> pmean metrics."""
    if cache is not None:
        return cache.get(
            ("chgnet_dp_eval", model_cfg, train_cfg, mesh, axis),
            lambda: make_dp_eval_step(model_cfg, train_cfg, mesh, axis),
        )

    def local_eval(params, batch):
        local_batch = jax.tree.map(lambda x: x[0], batch)
        _, metrics = chgnet_loss_fn(params, model_cfg, local_batch,
                                    train_cfg.loss)
        return jax.lax.pmean(metrics, axis)

    return jax.jit(shard_map(
        local_eval, mesh=mesh,
        in_specs=(P(), P(axis)), out_specs=P(), check_rep=False,
    ))


def make_dp_serve_step(model_cfg: CHGNetConfig, mesh: Mesh,
                       axis: str = "data",
                       *, cache: CompileCache | None = None):
    """Replicated-params inference; outputs keep the leading device axis."""
    if cache is not None:
        return cache.get(
            ("chgnet_dp_serve", model_cfg, mesh, axis),
            lambda: make_dp_serve_step(model_cfg, mesh, axis),
        )

    def local_serve(params, batch):
        local_batch = jax.tree.map(lambda x: x[0], batch)
        out = chgnet_apply(params, model_cfg, local_batch)
        return jax.tree.map(lambda x: x[None], out)

    return jax.jit(shard_map(
        local_serve, mesh=mesh,
        in_specs=(P(), P(axis)), out_specs=P(axis), check_rep=False,
    ))


# ---------------------------------------------------------------------------
# Trainer loop with periodic checkpoint + straggler watch
# ---------------------------------------------------------------------------

class Trainer:
    def __init__(
        self,
        model_cfg: CHGNetConfig,
        train_cfg: TrainConfig,
        *,
        seed: int = 0,
        mesh: Mesh | None = None,
        ckpt_dir: str | None = None,
        ckpt_every: int = 100,
        keep: int = 3,
        compile_cache: CompileCache | None = None,
    ):
        self.model_cfg = model_cfg
        self.train_cfg = train_cfg
        self.params = chgnet_init(jax.random.PRNGKey(seed), model_cfg)
        self.opt_state = adam_init(self.params)
        self.step = 0
        self.mesh = mesh
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.keep = keep
        # step functions go through the shared repro.batching compile cache
        # so a restarted Trainer (fault tolerance path) reuses traced steps
        cache = compile_cache if compile_cache is not None \
            else global_compile_cache()
        self.compile_cache = cache
        if mesh is not None:
            # build all three steps: a mesh-mode Trainer must be able to
            # eval and serve too (previously only _train_step existed, so
            # multi-device eval/serve hit undefined attributes)
            self._train_step = make_dp_train_step(model_cfg, train_cfg, mesh,
                                                  cache=cache)
            self._eval_step = make_dp_eval_step(model_cfg, train_cfg, mesh,
                                                cache=cache)
            self._serve_step = make_dp_serve_step(model_cfg, mesh,
                                                  cache=cache)
        else:
            self._train_step, self._eval_step, self._serve_step = (
                make_chgnet_step_fns(model_cfg, train_cfg, cache=cache)
            )
        from repro.runtime.fault import StragglerWatch

        self.straggler = StragglerWatch()

    # -- checkpoint hooks ---------------------------------------------------
    def state(self):
        return {"params": self.params, "opt_state": self.opt_state}

    def save(self):
        if self.ckpt_dir is None:
            return
        from repro.runtime.checkpoint import save_checkpoint

        save_checkpoint(
            self.ckpt_dir, self.step, self.state(), keep=self.keep,
            extra_meta={"model_cfg": dataclasses.asdict(self.model_cfg)},
        )

    def maybe_restore(self) -> bool:
        if self.ckpt_dir is None:
            return False
        from repro.runtime.checkpoint import latest_step, restore_checkpoint

        if latest_step(self.ckpt_dir) is None:
            return False
        from repro.runtime.checkpoint import MissingLeafError

        try:
            state, step, _ = restore_checkpoint(self.ckpt_dir, self.state())
        except MissingLeafError as missing:
            # legacy checkpoint with separate GatedMLP core/gate weights:
            # restore into the legacy-shaped template, then pack ONCE here
            # (checkpoint-load), so no jitted step re-concatenates params.
            # Only retry when the missing leaf IS a packed-GatedMLP key —
            # and re-raise the original error if the legacy attempt also
            # fails — so genuinely incompatible checkpoints (different
            # architecture) surface their real mismatch, not a misleading
            # legacy-layout one.
            packed_keys = ("['w']", "['b']", "['ln_scale']", "['ln_bias']")
            if not missing.leaf_path.endswith(packed_keys):
                raise
            from repro.core.interaction import (
                gated_mlp_legacy_template, pack_gated_mlp_params)

            legacy = gated_mlp_legacy_template(self.state())
            try:
                state, step, _ = restore_checkpoint(self.ckpt_dir, legacy)
            except (KeyError, ValueError):
                raise missing
            state = pack_gated_mlp_params(state)
        self.params, self.opt_state = state["params"], state["opt_state"]
        self.step = step
        return True

    # -- eval / serve -------------------------------------------------------
    def evaluate(self, batch) -> dict:
        """Loss metrics on one batch (stacked per-device leaves in mesh mode)."""
        return {k: float(v)
                for k, v in self._eval_step(self.params, batch).items()}

    def serve(self, batch):
        """One inference step (E/F/sigma/magmom); Table II's workload."""
        return self._serve_step(self.params, batch)

    # -- loop -----------------------------------------------------------------
    def train(self, batches, max_steps: int | None = None,
              fault_injector=None) -> list[dict]:
        history = []
        for batch in batches:
            if max_steps is not None and self.step >= max_steps:
                break
            t0 = time.perf_counter()
            if fault_injector is not None:
                fault_injector.maybe_fail(self.step)
            self.params, self.opt_state, metrics = self._train_step(
                self.params, self.opt_state, batch, jnp.asarray(self.step)
            )
            loss = float(metrics["loss"])
            if not jnp.isfinite(loss):
                # NaN guard: roll back rather than poison the run
                if self.maybe_restore():
                    continue
                raise FloatingPointError(f"non-finite loss at step {self.step}")
            self.step += 1
            self.straggler.record(time.perf_counter() - t0)
            history.append({k: float(v) for k, v in metrics.items()})
            if self.ckpt_dir is not None and self.step % self.ckpt_every == 0:
                self.save()
        return history
