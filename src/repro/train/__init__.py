"""Training loops and step builders."""
from .trainer import (
    TrainConfig,
    Trainer,
    make_chgnet_accum_step_fns,
    make_chgnet_step_fns,
    make_dp_eval_step,
    make_dp_serve_step,
    make_dp_train_step,
)

__all__ = [
    "TrainConfig", "Trainer", "make_chgnet_accum_step_fns",
    "make_chgnet_step_fns", "make_dp_eval_step", "make_dp_serve_step",
    "make_dp_train_step",
]
