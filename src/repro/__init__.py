"""repro: FastCHGNet (CS.DC 2024) in JAX — a multi-pod TPU training and
inference framework.

Subpackages:
    core         the paper's contribution: CHGNet/FastCHGNet in JAX
    kernels      Pallas TPU kernels + jnp oracles
    precision    end-to-end PrecisionPolicy + loss scaling (DESIGN.md §4)
    data         synthetic MPtrj-like dataset, load-balance sampler
    optim        Adam, schedules (Eq. 14), grad transforms
    distributed  collectives, GPipe pipeline parallelism
    runtime      checkpoint / elastic / fault tolerance
    train        Trainer + DP shard_map steps
    models       LM substrate for the 10 assigned architectures
    configs      per-arch configs + shapes + input_specs
    launch       production mesh, multi-pod dry-run, training launcher
    analysis     roofline model
"""

__version__ = "1.0.0"
