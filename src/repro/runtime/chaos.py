"""Chaos harness: seeded, composable fault schedules (DESIGN.md §8).

A :class:`ChaosSchedule` is a deterministic list of ``(step, kind, arg)``
events — parseable from a compact string for ``launch/train --chaos`` —
and a :class:`ChaosMonkey` drives it against a training run from two
hook points:

  - ``maybe_fail(step)`` (duck-types ``fault.FaultInjector``; plug it in
    as the Trainer's ``fault_injector``) fires step-loop faults:
    ``crash`` (RuntimeError), ``drop`` (DeviceLossError -> §6 elastic
    rebalance), ``sigterm`` (real signal to this process -> preemption
    path), ``straggler`` (injected sleep), ``ckpt_truncate`` /
    ``ckpt_bitflip`` (corrupt the newest checkpoint file on disk ->
    verified-restore fallback path);
  - ``wrap_batches(iterable)`` interposes on the data path: ``nan``
    (poison every float leaf of the step's batch -> divergence sentinel),
    ``transient`` (TransientSampleError -> Prefetcher retry/quarantine),
    ``prefetch_crash`` (RuntimeError from inside the producing iterator —
    wrapped under a Prefetcher it kills the worker thread).

Every event fires at most once per monkey, so a restarted loop sharing
the monkey replays cleanly; a fresh monkey with the same schedule + seed
reproduces the identical fault sequence (the determinism contract
``tests/test_fault_recovery.py`` asserts).  The wrapper stream is
resumable: raising does not poison it, so retry/restart paths can keep
pulling from the same object.

Spec grammar (comma-separated):  ``kind@step`` or ``kind@step:arg``
    e.g. ``nan@5,nan@6,sigterm@12,drop@7:0,straggler@9:0.2,ckpt_bitflip@20``
"""
from __future__ import annotations

import dataclasses
import logging
import os
import signal as _signal
import time

import numpy as np

from .checkpoint import _ckpt_path, list_checkpoints
from .fault import DeviceLossError, TransientSampleError

log = logging.getLogger("repro.chaos")

STEP_KINDS = frozenset(
    {"crash", "drop", "sigterm", "straggler", "ckpt_truncate",
     "ckpt_bitflip"})
DATA_KINDS = frozenset({"nan", "transient", "prefetch_crash"})
KINDS = STEP_KINDS | DATA_KINDS


class ChaosError(RuntimeError):
    """An injected (non-transient) crash."""


@dataclasses.dataclass(frozen=True)
class ChaosEvent:
    step: int
    kind: str
    arg: float | None = None

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(
                f"unknown chaos kind {self.kind!r}; know {sorted(KINDS)}")

    def spec(self) -> str:
        base = f"{self.kind}@{self.step}"
        return base if self.arg is None else f"{base}:{self.arg:g}"


@dataclasses.dataclass(frozen=True)
class ChaosSchedule:
    """An ordered, seeded fault schedule (composable: just concatenate
    event tuples).  ``seed`` feeds any randomized fault payloads (e.g.
    which bits a ``ckpt_bitflip`` flips), so the whole injected fault
    sequence is a pure function of (schedule, seed)."""

    events: tuple[ChaosEvent, ...] = ()
    seed: int = 0

    @classmethod
    def parse(cls, spec: str, *, seed: int = 0) -> "ChaosSchedule":
        events = []
        for token in filter(None, (t.strip() for t in spec.split(","))):
            try:
                kind, _, rest = token.partition("@")
                step_s, _, arg_s = rest.partition(":")
                events.append(ChaosEvent(
                    step=int(step_s), kind=kind,
                    arg=float(arg_s) if arg_s else None))
            except (ValueError, TypeError) as exc:
                raise ValueError(
                    f"bad chaos token {token!r} (want kind@step[:arg]): {exc}"
                ) from exc
        return cls(events=tuple(sorted(events, key=lambda e: e.step)),
                   seed=seed)

    def spec(self) -> str:
        return ",".join(e.spec() for e in self.events)

    def at(self, step: int, kinds: frozenset) -> list[ChaosEvent]:
        return [e for e in self.events
                if e.step == step and e.kind in kinds]


# ---------------------------------------------------------------------------
# file corruption primitives (also used directly by tests/benchmarks)
# ---------------------------------------------------------------------------

def truncate_file(path: str, keep_frac: float = 0.5) -> int:
    """Truncate to ``keep_frac`` of the current size (a torn write)."""
    size = os.path.getsize(path)
    keep = int(size * keep_frac)
    with open(path, "r+b") as f:
        f.truncate(keep)
    return keep


def bitflip_file(path: str, *, seed: int = 0, nbits: int = 8) -> list[int]:
    """Flip ``nbits`` random bits in place (silent media corruption).
    Returns the flipped byte offsets."""
    size = os.path.getsize(path)
    rng = np.random.default_rng(seed)
    offsets = sorted(int(o) for o in rng.integers(0, size, size=nbits))
    with open(path, "r+b") as f:
        for off in offsets:
            f.seek(off)
            byte = f.read(1)[0]
            f.seek(off)
            f.write(bytes([byte ^ (1 << int(rng.integers(0, 8)))]))
    return offsets


def corrupt_newest_checkpoint(directory: str, mode: str = "truncate", *,
                              seed: int = 0) -> str | None:
    """Damage the newest checkpoint file; returns its path (None if no
    checkpoint exists yet)."""
    steps = list_checkpoints(directory)
    if not steps:
        return None
    path = _ckpt_path(directory, steps[-1])
    if mode == "truncate":
        truncate_file(path)
    elif mode == "bitflip":
        bitflip_file(path, seed=seed)
    else:
        raise ValueError(f"unknown corruption mode {mode!r}")
    log.warning("chaos: corrupted checkpoint %s (%s)", path, mode)
    return path


def _nanify(leaf):
    dt = getattr(leaf, "dtype", None)
    if dt is None or not np.issubdtype(np.dtype(dt), np.floating):
        return leaf
    if isinstance(leaf, np.ndarray):
        return np.full_like(leaf, np.nan)
    import jax.numpy as jnp
    return jnp.full_like(leaf, jnp.nan)


def poison_nan(item):
    """NaN-fill every float leaf of a batch / TaggedBatch / StepPlan."""
    import jax

    from repro.batching.balance import StepPlan
    if isinstance(item, StepPlan):
        return dataclasses.replace(
            item, micro=[poison_nan(m) for m in item.micro])
    return jax.tree.map(_nanify, item)


# ---------------------------------------------------------------------------
# the monkey
# ---------------------------------------------------------------------------

class ChaosMonkey:
    """Drives a :class:`ChaosSchedule` against a run (see module docs).

    ``fired`` persists across loop restarts sharing this monkey, so each
    event is injected exactly once; ``log_events`` records what actually
    fired, in order, for bench/test assertions.
    """

    def __init__(self, schedule: ChaosSchedule, *,
                 ckpt_dir: str | None = None):
        self.schedule = schedule
        self.ckpt_dir = ckpt_dir
        self.fired: set[tuple[int, str]] = set()
        self.log_events: list[tuple[str, int]] = []

    def _fire(self, ev: ChaosEvent) -> bool:
        key = (ev.step, ev.kind)
        if key in self.fired:
            return False
        self.fired.add(key)
        self.log_events.append((ev.kind, ev.step))
        log.warning("chaos: firing %s at step %d", ev.kind, ev.step)
        return True

    # FaultInjector duck type: called by the Trainer loop before each step
    def maybe_fail(self, step: int):
        for ev in self.schedule.at(step, STEP_KINDS):
            if not self._fire(ev):
                continue
            if ev.kind == "crash":
                raise ChaosError(f"injected step-loop crash at step {step}")
            if ev.kind == "drop":
                raise DeviceLossError(
                    int(ev.arg or 0), f"injected device drop at step {step}")
            if ev.kind == "sigterm":
                os.kill(os.getpid(), _signal.SIGTERM)
            elif ev.kind == "straggler":
                time.sleep(float(ev.arg) if ev.arg is not None else 0.25)
            elif ev.kind in ("ckpt_truncate", "ckpt_bitflip"):
                if self.ckpt_dir is not None:
                    corrupt_newest_checkpoint(
                        self.ckpt_dir, mode=ev.kind.removeprefix("ckpt_"),
                        seed=self.schedule.seed)

    def wrap_batches(self, iterable, *, start_step: int = 0):
        """Interpose the data-path faults on a batch stream.

        The returned iterator is RESUMABLE (a class, not a generator):
        after it raises ``transient``/``prefetch_crash``, the next
        ``__next__`` continues with the following step's batch — the
        contract the Prefetcher's retry path needs.  ``start_step``
        aligns the event counter with ``Trainer.step`` on resume.
        """
        return _ChaosBatchStream(self, iterable, start_step)


class _ChaosBatchStream:
    def __init__(self, monkey: ChaosMonkey, iterable, start_step: int):
        self._monkey = monkey
        self._it = iter(iterable)
        self.step = start_step

    def __iter__(self):
        return self

    def __next__(self):
        item = next(self._it)
        step = self.step
        # advance BEFORE raising: a retry must move on to the next step's
        # batch (the faulted one is consumed == quarantined), not refetch
        self.step += 1
        for ev in self._monkey.schedule.at(step, DATA_KINDS):
            if not self._monkey._fire(ev):
                continue
            if ev.kind == "nan":
                item = poison_nan(item)
            elif ev.kind == "transient":
                raise TransientSampleError(
                    index=step, msg=f"injected transient fault at step {step}")
            elif ev.kind == "prefetch_crash":
                raise ChaosError(f"injected prefetch crash at step {step}")
        return item
