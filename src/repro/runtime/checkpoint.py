"""Fault-tolerant checkpointing (msgpack + numpy, no external deps).

Design goals (1000+-node deployability):
  - **atomic**: write to ``<name>.tmp`` then ``os.replace`` — a crash never
    leaves a half-written "latest" checkpoint;
  - **mesh-independent**: arrays are gathered to host as full ndarrays, so
    a checkpoint written on a 256-chip mesh restores onto any device count
    (elastic scaling, runtime/elastic.py);
  - **keep-K**: bounded disk usage; ``latest_step`` scans for auto-resume;
  - arrays are stored by flattened-pytree path with dtype/shape, verified
    on restore against the template pytree: a shape mismatch raises, a
    dtype mismatch warns and CASTS to the template dtype (so e.g. a
    legacy f32 checkpoint restores into a bf16-param policy and vice
    versa, DESIGN.md §4 — never a silent bit reinterpretation);
  - extension dtypes (bfloat16 & friends, whose numpy ``.str`` is an
    opaque void like ``<V2``) are stored by NAME so they round-trip.
"""
from __future__ import annotations

import os
import re
import warnings
from typing import Any

import jax
import msgpack
import numpy as np


def _leaf_paths(tree: Any) -> list[str]:
    paths = []
    for path, _ in jax.tree_util.tree_flatten_with_path(tree)[0]:
        paths.append(jax.tree_util.keystr(path))
    return paths


def save_checkpoint(
    directory: str,
    step: int,
    tree: Any,
    *,
    keep: int = 3,
    extra_meta: dict | None = None,
) -> str:
    """Atomically write ``ckpt_<step>.msgpack``; prune to ``keep`` newest."""
    os.makedirs(directory, exist_ok=True)
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    arrays = {}
    for path, leaf in leaves_with_paths:
        arr = np.asarray(jax.device_get(leaf))
        # numpy renders extension dtypes (ml_dtypes bfloat16 etc.) as raw
        # void in ``.str`` ('<V2'), which does NOT round-trip through
        # np.dtype(); their ``.name`` ('bfloat16') does
        dtype_tag = arr.dtype.str
        if "V" in dtype_tag:
            dtype_tag = arr.dtype.name
        arrays[jax.tree_util.keystr(path)] = {
            "dtype": dtype_tag,
            "shape": list(arr.shape),
            "data": arr.tobytes(),
        }
    payload = msgpack.packb(
        {"step": step, "meta": extra_meta or {}, "arrays": arrays},
        use_bin_type=True,
    )
    final = os.path.join(directory, f"ckpt_{step:010d}.msgpack")
    tmp = final + ".tmp"
    with open(tmp, "wb") as f:
        f.write(payload)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, final)

    # prune
    ckpts = sorted(list_checkpoints(directory))
    for old in ckpts[:-keep]:
        try:
            os.remove(os.path.join(directory, f"ckpt_{old:010d}.msgpack"))
        except OSError:
            pass
    return final


def list_checkpoints(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    steps = []
    for name in os.listdir(directory):
        m = re.fullmatch(r"ckpt_(\d{10})\.msgpack", name)
        if m:
            steps.append(int(m.group(1)))
    return sorted(steps)


def latest_step(directory: str) -> int | None:
    steps = list_checkpoints(directory)
    return steps[-1] if steps else None


class MissingLeafError(KeyError):
    """A template leaf absent from the checkpoint; carries the leaf path so
    callers (e.g. layout migrations) don't parse the message text."""

    def __init__(self, leaf_path: str):
        super().__init__(f"checkpoint missing leaf {leaf_path}")
        self.leaf_path = leaf_path


def restore_checkpoint(
    directory: str,
    template: Any,
    *,
    step: int | None = None,
) -> tuple[Any, int, dict]:
    """Restore into the template's structure. Returns (tree, step, meta)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"ckpt_{step:010d}.msgpack")
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    arrays = payload["arrays"]

    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    new_leaves = []
    for kpath, leaf in leaves_with_paths:
        key = jax.tree_util.keystr(kpath)
        if key not in arrays:
            raise MissingLeafError(key)
        rec = arrays[key]
        arr = np.frombuffer(rec["data"], dtype=np.dtype(rec["dtype"]))
        arr = arr.reshape(rec["shape"])
        want_shape = tuple(np.shape(leaf))
        if tuple(arr.shape) != want_shape:
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs template {want_shape}"
            )
        # dtype is VERIFIED against the template, never silently adopted:
        # a stored-vs-template mismatch (e.g. restoring an f32 checkpoint
        # into a mixed/bf16-policy Trainer, or the reverse) casts to the
        # template dtype with a warning (DESIGN.md §4)
        want_dtype = np.dtype(getattr(leaf, "dtype", np.asarray(leaf).dtype))
        if arr.dtype != want_dtype:
            warnings.warn(
                f"checkpoint dtype mismatch for {key}: stored "
                f"{arr.dtype.name}, template {want_dtype.name}; casting",
                stacklevel=2,
            )
            arr = arr.astype(want_dtype)
        new_leaves.append(arr.copy())
    tree = jax.tree_util.tree_unflatten(treedef, new_leaves)
    return tree, payload["step"], payload.get("meta", {})
