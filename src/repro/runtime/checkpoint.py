"""Fault-tolerant verified checkpointing (msgpack + numpy, no external deps).

Design goals (1000+-node deployability, DESIGN.md §8):
  - **atomic**: write to ``<name>.tmp`` then ``os.replace``, with fsync of
    the file AND its directory — a crash never leaves a half-written
    "latest" checkpoint, and the rename itself is durable;
  - **verified**: every array carries a CRC32 checksum in a manifest
    inside the payload; ``verify_checkpoint`` / restore detect truncation
    and bit-flips instead of restoring garbage;
  - **fallback**: restore with ``step=None`` walks newest -> oldest and
    restores the newest *valid* checkpoint (``latest_valid_step``) —
    a corrupted latest file costs one checkpoint interval, not the run;
  - **mesh-independent**: arrays are gathered to host as full ndarrays, so
    a checkpoint written on a 256-chip mesh restores onto any device count
    (elastic scaling, runtime/elastic.py);
  - **keep-K**: bounded disk usage counting only checksummed-COMPLETE
    files toward K (a corrupt file must never displace a good one from
    the kept set), deleted oldest-first; ``latest_step`` scans for
    auto-resume;
  - arrays are stored by flattened-pytree path with dtype/shape, verified
    on restore against the template pytree: a shape mismatch raises, a
    dtype mismatch warns and CASTS to the template dtype (so e.g. a
    legacy f32 checkpoint restores into a bf16-param policy and vice
    versa, DESIGN.md §4 — never a silent bit reinterpretation);
  - extension dtypes (bfloat16 & friends, whose numpy ``.str`` is an
    opaque void like ``<V2``) are stored by NAME so they round-trip.

Async writes live in :mod:`repro.runtime.async_ckpt`; the sync path here
is the reference implementation and stays the default for tests.
"""
from __future__ import annotations

import os
import re
import warnings
import zlib
from typing import Any

import jax
import msgpack
import numpy as np

# payload format version: 2 added the per-array CRC32 ``manifest``;
# format-1 files (no manifest) still restore, with an "unverified" warning
CKPT_FORMAT = 2


class CheckpointCorruptError(RuntimeError):
    """A checkpoint file failed verification (truncated payload, CRC
    mismatch, or structural damage)."""


def _ckpt_path(directory: str, step: int) -> str:
    return os.path.join(directory, f"ckpt_{step:010d}.msgpack")


def _fsync_dir(directory: str) -> None:
    """fsync the directory so the ``os.replace`` rename is durable."""
    try:
        fd = os.open(directory, os.O_RDONLY)
    except OSError:
        return  # platforms that can't open directories: best effort
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _leaf_paths(tree: Any) -> list[str]:
    paths = []
    for path, _ in jax.tree_util.tree_flatten_with_path(tree)[0]:
        paths.append(jax.tree_util.keystr(path))
    return paths


def host_snapshot(tree: Any) -> Any:
    """Copy a pytree to host numpy arrays.

    Always copies (``np.array(copy=True)``) so the snapshot is isolated
    from later in-place mutation of numpy leaves — the contract the async
    writer relies on to snapshot on the caller thread and serialize later.
    """
    return jax.tree.map(
        lambda leaf: np.array(jax.device_get(leaf), copy=True), tree)


def save_checkpoint(
    directory: str,
    step: int,
    tree: Any,
    *,
    keep: int = 3,
    extra_meta: dict | None = None,
) -> str:
    """Atomically write ``ckpt_<step>.msgpack``; prune to ``keep`` newest
    VALID checkpoints (corrupt files never count toward K)."""
    os.makedirs(directory, exist_ok=True)
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    arrays = {}
    manifest = {}
    for path, leaf in leaves_with_paths:
        arr = np.asarray(jax.device_get(leaf))
        # numpy renders extension dtypes (ml_dtypes bfloat16 etc.) as raw
        # void in ``.str`` ('<V2'), which does NOT round-trip through
        # np.dtype(); their ``.name`` ('bfloat16') does
        dtype_tag = arr.dtype.str
        if "V" in dtype_tag:
            dtype_tag = arr.dtype.name
        data = arr.tobytes()
        key = jax.tree_util.keystr(path)
        arrays[key] = {
            "dtype": dtype_tag,
            "shape": list(arr.shape),
            "data": data,
        }
        manifest[key] = zlib.crc32(data)
    payload = msgpack.packb(
        {
            "format": CKPT_FORMAT,
            "step": step,
            "meta": extra_meta or {},
            "manifest": manifest,
            "arrays": arrays,
        },
        use_bin_type=True,
    )
    final = _ckpt_path(directory, step)
    tmp = final + ".tmp"
    with open(tmp, "wb") as f:
        f.write(payload)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, final)
    _fsync_dir(directory)
    prune_checkpoints(directory, keep)
    return final


def prune_checkpoints(directory: str, keep: int) -> list[int]:
    """Keep the newest ``keep`` checksummed-COMPLETE checkpoints.

    Only verified-complete files count toward K and only they (plus
    corrupt files older than the oldest kept one — useless even as a
    fallback) are deleted, oldest-first.  A corrupt *newer* file is left
    in place: it may be another writer's in-flight data or wanted for
    forensics, and restore skips it anyway.  Concurrent-restore safety is
    the restorer's job: ``restore_checkpoint(step=None)`` tolerates a
    file vanishing between selection and open by falling back to the
    next-newest valid one.  Returns the deleted steps.
    """
    steps = list_checkpoints(directory)
    valid = [s for s in steps if verify_checkpoint(_ckpt_path(directory, s))]
    kept = set(valid[-keep:]) if keep > 0 else set()
    cutoff = min(kept) if kept else None
    deleted = []
    for s in steps:
        if s in kept:
            continue
        if s in valid or (cutoff is not None and s < cutoff):
            try:
                os.remove(_ckpt_path(directory, s))
                deleted.append(s)
            except OSError:
                pass  # already gone (concurrent prune): fine
    return deleted


def list_checkpoints(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    steps = []
    for name in os.listdir(directory):
        m = re.fullmatch(r"ckpt_(\d{10})\.msgpack", name)
        if m:
            steps.append(int(m.group(1)))
    return sorted(steps)


def latest_step(directory: str) -> int | None:
    steps = list_checkpoints(directory)
    return steps[-1] if steps else None


def _read_payload(path: str, *, verify: bool = True) -> dict:
    """Read + structurally validate one checkpoint file.

    Raises :class:`CheckpointCorruptError` on truncation (msgpack can't
    unpack), structural damage (missing keys), or — for format-2 files —
    any per-array CRC32 mismatch.  Format-1 files (no manifest) pass with
    a warning: there is nothing to verify against.
    """
    with open(path, "rb") as f:
        raw = f.read()
    try:
        payload = msgpack.unpackb(raw, raw=False)
    except Exception as exc:  # msgpack raises several unrelated types
        raise CheckpointCorruptError(
            f"{path}: unreadable payload ({type(exc).__name__}: {exc})"
        ) from exc
    if not isinstance(payload, dict) or "arrays" not in payload \
            or "step" not in payload:
        raise CheckpointCorruptError(f"{path}: malformed payload structure")
    if not verify:
        return payload
    manifest = payload.get("manifest")
    if manifest is None:
        warnings.warn(
            f"{path}: legacy (format-1) checkpoint has no checksum "
            "manifest; restoring UNVERIFIED", stacklevel=3)
        return payload
    arrays = payload["arrays"]
    if set(manifest) != set(arrays):
        raise CheckpointCorruptError(
            f"{path}: manifest/array key mismatch")
    for key, crc in manifest.items():
        rec = arrays[key]
        if not isinstance(rec, dict) or "data" not in rec:
            raise CheckpointCorruptError(f"{path}: malformed record {key}")
        if zlib.crc32(rec["data"]) != crc:
            raise CheckpointCorruptError(
                f"{path}: CRC32 mismatch for {key} (bit-flip or torn write)")
    return payload


def verify_checkpoint(path: str) -> bool:
    """True iff the file parses and every array matches its checksum."""
    try:
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            _read_payload(path)
        return True
    except (CheckpointCorruptError, OSError):
        return False


def latest_valid_step(directory: str) -> int | None:
    """Newest step whose checkpoint file passes verification."""
    for s in reversed(list_checkpoints(directory)):
        if verify_checkpoint(_ckpt_path(directory, s)):
            return s
    return None


class MissingLeafError(KeyError):
    """A template leaf absent from the checkpoint; carries the leaf path so
    callers (e.g. layout migrations) don't parse the message text."""

    def __init__(self, leaf_path: str):
        super().__init__(f"checkpoint missing leaf {leaf_path}")
        self.leaf_path = leaf_path


def _materialize(payload: dict, template: Any) -> tuple[Any, int, dict]:
    """Apply a verified payload onto the template pytree."""
    arrays = payload["arrays"]
    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    new_leaves = []
    for kpath, leaf in leaves_with_paths:
        key = jax.tree_util.keystr(kpath)
        if key not in arrays:
            raise MissingLeafError(key)
        rec = arrays[key]
        arr = np.frombuffer(rec["data"], dtype=np.dtype(rec["dtype"]))
        arr = arr.reshape(rec["shape"])
        want_shape = tuple(np.shape(leaf))
        if tuple(arr.shape) != want_shape:
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs template {want_shape}"
            )
        # dtype is VERIFIED against the template, never silently adopted:
        # a stored-vs-template mismatch (e.g. restoring an f32 checkpoint
        # into a mixed/bf16-policy Trainer, or the reverse) casts to the
        # template dtype with a warning (DESIGN.md §4)
        want_dtype = np.dtype(getattr(leaf, "dtype", np.asarray(leaf).dtype))
        if arr.dtype != want_dtype:
            warnings.warn(
                f"checkpoint dtype mismatch for {key}: stored "
                f"{arr.dtype.name}, template {want_dtype.name}; casting",
                stacklevel=2,
            )
            arr = arr.astype(want_dtype)
        new_leaves.append(arr.copy())
    tree = jax.tree_util.tree_unflatten(treedef, new_leaves)
    return tree, payload["step"], payload.get("meta", {})


def restore_checkpoint(
    directory: str,
    template: Any,
    *,
    step: int | None = None,
    fallback: bool | None = None,
) -> tuple[Any, int, dict]:
    """Restore into the template's structure. Returns (tree, step, meta).

    ``step=None`` (auto-resume) walks checkpoints newest -> oldest and
    restores the newest file that passes CRC verification — a truncated or
    bit-flipped latest checkpoint is skipped with a warning instead of
    killing the restore (DESIGN.md §8).  An explicit ``step`` never falls
    back (``fallback`` overrides either default).  Template mismatches
    (:class:`MissingLeafError`, shape errors) are NOT fallback events:
    they indicate the wrong template, not a damaged file, and re-raise.
    """
    if fallback is None:
        fallback = step is None
    if step is None:
        candidates = list(reversed(list_checkpoints(directory)))
        if not candidates:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    else:
        candidates = [step]
    last_exc: Exception | None = None
    for s in candidates:
        path = _ckpt_path(directory, s)
        try:
            payload = _read_payload(path)
        except (CheckpointCorruptError, OSError) as exc:
            if not fallback:
                raise
            warnings.warn(
                f"skipping invalid checkpoint step {s}: {exc}; "
                "falling back to the next-newest valid one", stacklevel=2)
            last_exc = exc
            continue
        return _materialize(payload, template)
    raise CheckpointCorruptError(
        f"no valid checkpoint in {directory} "
        f"(tried {len(candidates)}; last error: {last_exc})")
