"""Elastic scaling: resume a run on a different device count / mesh.

Checkpoints are mesh-independent (full host arrays), so elasticity is:
  1. restore the host pytree from the checkpoint,
  2. re-shard onto the *current* mesh with the arch's sharding rules,
  3. rescale data-pipeline quantities that depend on device count
     (per-device batch = global_batch // num_devices; the global batch —
     and therefore the Eq. 14 LR — is preserved, so the optimizer
     trajectory is unchanged across scale events).
"""
from __future__ import annotations

from typing import Any, Callable

import jax
from jax.sharding import NamedSharding

from .checkpoint import restore_checkpoint


def reshard(tree: Any, mesh, spec_fn: Callable[[str, Any], Any]) -> Any:
    """Place a host pytree onto ``mesh`` using per-leaf PartitionSpecs.

    spec_fn(path_str, leaf) -> PartitionSpec (or None -> replicated).
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        spec = spec_fn(jax.tree_util.keystr(path), leaf)
        sharding = NamedSharding(mesh, spec)
        out.append(jax.device_put(leaf, sharding))
    return jax.tree_util.tree_unflatten(treedef, out)


def elastic_restore(
    directory: str,
    template: Any,
    mesh,
    spec_fn: Callable[[str, Any], Any],
    *,
    step: int | None = None,
):
    """restore + reshard in one call. Returns (sharded_tree, step, meta)."""
    tree, step, meta = restore_checkpoint(directory, template, step=step)
    return reshard(tree, mesh, spec_fn), step, meta


def per_device_batch(global_batch: int, num_devices: int) -> int:
    if global_batch % num_devices != 0:
        raise ValueError(
            f"global batch {global_batch} not divisible by {num_devices} devices"
        )
    return global_batch // num_devices
