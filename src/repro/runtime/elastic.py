"""Elastic scaling: resume a run on a different device count / mesh.

Checkpoints are mesh-independent (full host arrays), so elasticity is:
  1. restore the host pytree from the checkpoint,
  2. re-shard onto the *current* mesh with the arch's sharding rules,
  3. rescale data-pipeline quantities that depend on device count
     (per-device batch = global_batch // num_devices; the global batch —
     and therefore the Eq. 14 LR — is preserved, so the optimizer
     trajectory is unchanged across scale events).

In-run elasticity (DESIGN.md §6): a device drop surfaces as
``fault.DeviceLossError``; ``surviving_mesh`` rebuilds the mesh from the
survivors and ``elastic_train`` re-bin-packs the data over it (the
balanced iterator is a function of ``num_devices``) and keeps training —
params never touch disk, the global batch and Eq. 14 LR are preserved,
only the per-device share grows.
"""
from __future__ import annotations

from typing import Any, Callable, Iterable

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding

from .checkpoint import restore_checkpoint
from .fault import DeviceLossError


def reshard(tree: Any, mesh, spec_fn: Callable[[str, Any], Any]) -> Any:
    """Place a host pytree onto ``mesh`` using per-leaf PartitionSpecs.

    spec_fn(path_str, leaf) -> PartitionSpec (or None -> replicated).
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        spec = spec_fn(jax.tree_util.keystr(path), leaf)
        sharding = NamedSharding(mesh, spec)
        out.append(jax.device_put(leaf, sharding))
    return jax.tree_util.tree_unflatten(treedef, out)


def elastic_restore(
    directory: str,
    template: Any,
    mesh,
    spec_fn: Callable[[str, Any], Any],
    *,
    step: int | None = None,
):
    """restore + reshard in one call. Returns (sharded_tree, step, meta)."""
    tree, step, meta = restore_checkpoint(directory, template, step=step)
    return reshard(tree, mesh, spec_fn), step, meta


def per_device_batch(global_batch: int, num_devices: int) -> int:
    if global_batch % num_devices != 0:
        raise ValueError(
            f"global batch {global_batch} not divisible by {num_devices} devices"
        )
    return global_batch // num_devices


def surviving_mesh(mesh: Mesh, failed_index: int) -> Mesh:
    """1-D mesh over the survivors after losing ``failed_index``.

    Axis names are preserved; device order is otherwise unchanged, so a
    second drop can name positions in the *new* mesh.  Raises if the
    index is out of range or no device survives.
    """
    devs = list(np.asarray(mesh.devices).flatten())
    if not 0 <= failed_index < len(devs):
        raise ValueError(
            f"failed_index {failed_index} out of range for "
            f"{len(devs)}-device mesh")
    survivors = [d for i, d in enumerate(devs) if i != failed_index]
    if not survivors:
        raise ValueError("no surviving devices")
    return Mesh(np.array(survivors), mesh.axis_names)


def elastic_train(
    trainer,
    batches_fn: Callable[[int], Iterable],
    *,
    max_steps: int,
    fault_injector=None,
    max_shrinks: int | None = None,
) -> list[dict]:
    """Train to ``max_steps``, shrinking the mesh on every device drop.

    ``batches_fn(num_devices)`` must build a fresh batch iterable for
    that device count — with ``data.BalancedBatchIterator`` this is where
    the re-bin-packing over the surviving mesh happens (DESIGN.md §6
    rebalance-on-fault protocol).  On :class:`fault.DeviceLossError` the
    trainer is re-targeted via ``Trainer.rebuild_mesh`` (params pulled to
    host, step fns rebuilt from the compile cache) and the loop resumes
    at the SAME step with the same optimizer state — no checkpoint
    round-trip, no lost steps.
    """
    history: list[dict] = []
    shrinks = 0
    while trainer.step < max_steps:
        before = trainer.step
        try:
            history.extend(trainer.train(
                batches_fn(trainer.num_devices),
                max_steps=max_steps,
                fault_injector=fault_injector,
            ))
        except DeviceLossError as loss_err:
            history.extend(getattr(loss_err, "partial_history", []))
            shrinks += 1
            if max_shrinks is not None and shrinks > max_shrinks:
                raise
            if trainer.mesh is None:
                raise  # single-device runs have nothing to shrink to
            mesh = surviving_mesh(trainer.mesh, loss_err.failed_index)
            # a 1-device mesh still works under shard_map; keep it so the
            # step-fn cache stays keyed consistently
            trainer.rebuild_mesh(mesh)
            continue
        if trainer.step == before:
            break  # exhausted batches without progress: caller's epoch ended
    return history
