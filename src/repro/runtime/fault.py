"""Fault tolerance: checkpoint/restart orchestration + straggler watch.

``run_with_restarts`` wraps a training loop: on an exception (preemption,
OOM, injected fault) it restores from the newest checkpoint and replays
from there, up to ``max_restarts``. The loop function owns stepping and
periodic checkpointing; this wrapper owns recovery. Combined with atomic
checkpoints this gives at-least-once step semantics with bounded rework
(<= checkpoint_every steps).

``StragglerWatch`` tracks per-step wall times; a step slower than
``threshold``x the trailing median is flagged. On a real pod the flag
feeds the load-balance sampler (shrink the slow host's shard) — here it
surfaces in metrics and tests. NaN guards live here too: a non-finite
loss triggers rollback-to-checkpoint rather than poisoning the run.
"""
from __future__ import annotations

import logging
import time
from typing import Any, Callable

import numpy as np

log = logging.getLogger("repro.fault")


class StragglerWatch:
    def __init__(self, window: int = 32, threshold: float = 2.0):
        self.times: list[float] = []
        self.window = window
        self.threshold = threshold
        self.flags = 0

    def record(self, seconds: float) -> bool:
        """Record one step; returns True if it is a straggler step."""
        self.times.append(seconds)
        hist = self.times[-self.window:]
        if len(hist) < 8:
            return False
        med = float(np.median(hist))
        is_slow = seconds > self.threshold * med
        if is_slow:
            self.flags += 1
        return is_slow


class FaultInjector:
    """Deterministic fault injection for tests: raises at given steps."""

    def __init__(self, fail_at_steps: set[int]):
        self.fail_at = set(fail_at_steps)
        self.fired: set[int] = set()

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise RuntimeError(f"injected fault at step {step}")


class DeviceLossError(RuntimeError):
    """A device dropped out of the mesh mid-run (DESIGN.md §6).

    Carries which mesh position failed so the elastic path
    (``runtime.elastic.surviving_mesh``) can rebuild the mesh from the
    survivors and re-bin-pack the data over them — instead of the
    restart-from-checkpoint path, which assumes the same device count
    comes back.
    """

    def __init__(self, failed_index: int, msg: str | None = None):
        super().__init__(msg or f"device {failed_index} lost")
        self.failed_index = failed_index


class DeviceDropInjector:
    """Deterministic device-loss injection (duck-types FaultInjector).

    Raises :class:`DeviceLossError` once at ``fail_at_step``, naming
    ``device_index`` as the lost mesh position.
    """

    def __init__(self, fail_at_step: int, device_index: int = 0):
        self.fail_at = fail_at_step
        self.device_index = device_index
        self.fired = False

    def maybe_fail(self, step: int):
        if not self.fired and step == self.fail_at:
            self.fired = True
            raise DeviceLossError(
                self.device_index,
                f"injected loss of device {self.device_index} "
                f"at step {step}")


def run_with_restarts(
    loop_fn: Callable[[int], Any],
    *,
    resume_step_fn: Callable[[], int],
    max_restarts: int = 3,
) -> Any:
    """Run loop_fn(start_step); on failure, resume from the last checkpoint.

    loop_fn must be restartable from any checkpointed step (pure training
    state lives in checkpoints, not Python locals).
    """
    restarts = 0
    while True:
        start = resume_step_fn()
        try:
            return loop_fn(start)
        except Exception as exc:  # noqa: BLE001 - any failure -> restart
            restarts += 1
            if restarts > max_restarts:
                log.error("exceeded max_restarts=%d, giving up", max_restarts)
                raise
            log.warning(
                "step loop failed (%s); restart %d/%d from step %d",
                exc, restarts, max_restarts, resume_step_fn(),
            )
            time.sleep(0.05)
