"""Fault tolerance: checkpoint/restart orchestration, divergence + preempt
detection, straggler watch (DESIGN.md §8).

``run_with_restarts`` wraps a training loop: on a *retryable* exception
(preemption of a worker, OOM, injected fault) it restores from the newest
valid checkpoint and replays from there, up to ``max_restarts``.
Programming errors (TypeError, ValueError, missing attributes/keys …) and
graceful preemption (:class:`PreemptionError`) FAIL FAST instead of
looping through doomed restarts.  The loop function owns stepping and
periodic checkpointing; this wrapper owns recovery.  Combined with atomic
verified checkpoints this gives at-least-once step semantics with bounded
rework (<= checkpoint_every steps).

``DivergenceSentinel`` is the Trainer's loss-blow-up detector: a streak of
non-finite losses or of spikes far above the trailing median trips a
rollback to the last good checkpoint.  Steps the §4 loss scaler already
rejected (``grads_finite == 0``) are EXEMPT — the update was skipped and
the scale backed off, so params are untouched and no rollback is needed.

``GracefulShutdown`` + the resume-marker helpers implement preemption:
SIGTERM flips a flag, the Trainer writes a final checkpoint plus a
``RESUME.json`` marker and raises :class:`PreemptionError`; the next
launch resumes from that exact step.

``StragglerWatch`` tracks per-step wall times; a step slower than
``threshold``x the trailing median is flagged. On a real pod the flag
feeds the load-balance sampler (shrink the slow host's shard) — here it
surfaces in metrics and tests.
"""
from __future__ import annotations

import json
import logging
import math
import os
import signal as _signal
import time
from collections import deque
from typing import Any, Callable

import numpy as np

log = logging.getLogger("repro.fault")

RESUME_MARKER = "RESUME.json"


class StragglerWatch:
    def __init__(self, window: int = 32, threshold: float = 2.0):
        self.times: list[float] = []
        self.window = window
        self.threshold = threshold
        self.flags = 0

    def record(self, seconds: float) -> bool:
        """Record one step; returns True if it is a straggler step."""
        self.times.append(seconds)
        hist = self.times[-self.window:]
        if len(hist) < 8:
            return False
        med = float(np.median(hist))
        is_slow = seconds > self.threshold * med
        if is_slow:
            self.flags += 1
        return is_slow


class DivergenceSentinel:
    """Loss-spike / NaN-streak detector driving checkpoint rollback.

    ``record(loss, scaler_skipped=...)`` returns True when the run should
    roll back:

      - ``nan_streak`` consecutive non-finite losses, or
      - ``spike_streak`` consecutive losses above ``spike_factor`` x the
        median of the trailing ``window`` HEALTHY losses (spikes are never
        admitted into the reference window, so a blow-up can't drag the
        median up after itself).

    ``scaler_skipped`` steps (the §4 dynamic loss scaler rejected the
    update on an inf/nan gradient) are exempt: params were not touched,
    and scaler backoff is the correct response, not rollback.  A trip
    resets both streaks; ``last_trip_len`` reports how many steps the
    tripping streak spanned (the quarantine window).
    """

    def __init__(self, *, window: int = 32, nan_streak: int = 2,
                 spike_factor: float = 10.0, spike_streak: int = 4,
                 min_history: int = 8):
        self.window = window
        self.nan_streak = max(1, nan_streak)
        self.spike_factor = spike_factor
        self.spike_streak = max(1, spike_streak)
        self.min_history = min_history
        self.losses: deque[float] = deque(maxlen=window)
        self.nan_run = 0
        self.spike_run = 0
        self.trips = 0
        self.last_trip_len = 0

    @property
    def suspicious(self) -> bool:
        """A streak is building: the current params may be poisoned, so
        periodic checkpoints should be withheld until it clears."""
        return self.nan_run > 0 or self.spike_run > 0

    def record(self, loss: float, *, scaler_skipped: bool = False) -> bool:
        if scaler_skipped:
            return False  # rejected update: params untouched (DESIGN.md §4)
        if not math.isfinite(loss):
            self.nan_run += 1
            self.spike_run = 0
        else:
            self.nan_run = 0
            med = (float(np.median(self.losses))
                   if len(self.losses) >= self.min_history else None)
            if med is not None and loss > self.spike_factor * max(med, 1e-12):
                self.spike_run += 1
            else:
                self.spike_run = 0
                self.losses.append(loss)
        if (self.nan_run >= self.nan_streak
                or self.spike_run >= self.spike_streak):
            self.last_trip_len = max(self.nan_run, self.spike_run)
            self.trips += 1
            self.nan_run = self.spike_run = 0
            return True
        return False


class FaultInjector:
    """Deterministic fault injection for tests: raises at given steps."""

    def __init__(self, fail_at_steps: set[int]):
        self.fail_at = set(fail_at_steps)
        self.fired: set[int] = set()

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise RuntimeError(f"injected fault at step {step}")


class TransientSampleError(RuntimeError):
    """A transiently-bad sample/batch fetch in the data pipeline.

    Carries the offending index so ``data.pipeline.Prefetcher`` can
    quarantine it (log + skip, bounded retry-with-backoff) instead of
    killing the run.  Raisers must leave their iterator resumable — the
    retry re-enters ``__next__`` on the same object.
    """

    def __init__(self, index: int | None = None, msg: str | None = None):
        super().__init__(msg or f"transient sample failure (index={index})")
        self.index = index


class DeviceLossError(RuntimeError):
    """A device dropped out of the mesh mid-run (DESIGN.md §6).

    Carries which mesh position failed so the elastic path
    (``runtime.elastic.surviving_mesh``) can rebuild the mesh from the
    survivors and re-bin-pack the data over them — instead of the
    restart-from-checkpoint path, which assumes the same device count
    comes back.
    """

    def __init__(self, failed_index: int, msg: str | None = None):
        super().__init__(msg or f"device {failed_index} lost")
        self.failed_index = failed_index


class DeviceDropInjector:
    """Deterministic device-loss injection (duck-types FaultInjector).

    Raises :class:`DeviceLossError` once at ``fail_at_step``, naming
    ``device_index`` as the lost mesh position.
    """

    def __init__(self, fail_at_step: int, device_index: int = 0):
        self.fail_at = fail_at_step
        self.device_index = device_index
        self.fired = False

    def maybe_fail(self, step: int):
        if not self.fired and step == self.fail_at:
            self.fired = True
            raise DeviceLossError(
                self.device_index,
                f"injected loss of device {self.device_index} "
                f"at step {step}")


# ---------------------------------------------------------------------------
# Preemption (SIGTERM) handling
# ---------------------------------------------------------------------------

class PreemptionError(RuntimeError):
    """Graceful shutdown: a final checkpoint + resume marker were written
    and the process should exit NOW.  Never retried by
    ``run_with_restarts`` — the scheduler restarts the job, not us."""

    def __init__(self, step: int, msg: str | None = None):
        super().__init__(msg or f"preempted at step {step}")
        self.step = step


class GracefulShutdown:
    """Signal-to-flag preemption latch.

    ``install()`` registers handlers (default: SIGTERM) that only set
    ``requested`` — async-signal-safe, no work in the handler.  The
    Trainer polls the flag between steps, writes a final checkpoint and
    a resume marker, and raises :class:`PreemptionError`.  Usable as a
    context manager; ``uninstall()`` restores the previous handlers.
    """

    def __init__(self, signals: tuple = (_signal.SIGTERM,)):
        self.signals = tuple(signals)
        self.requested = False
        self.signum: int | None = None
        self._old: dict = {}

    def _handler(self, signum, frame):
        self.requested = True
        self.signum = signum

    def install(self) -> "GracefulShutdown":
        for s in self.signals:
            self._old[s] = _signal.signal(s, self._handler)
        return self

    def uninstall(self) -> None:
        for s, old in self._old.items():
            _signal.signal(s, old)
        self._old.clear()

    def __enter__(self) -> "GracefulShutdown":
        return self.install()

    def __exit__(self, *exc_info) -> None:
        self.uninstall()


def write_resume_marker(directory: str, step: int, *,
                        reason: str = "preempt") -> str:
    """Atomically drop ``RESUME.json`` next to the checkpoints."""
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, RESUME_MARKER)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump({"step": step, "reason": reason, "time": time.time()}, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    return path


def read_resume_marker(directory: str) -> dict | None:
    path = os.path.join(directory, RESUME_MARKER)
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError):
        return None


def clear_resume_marker(directory: str) -> None:
    try:
        os.remove(os.path.join(directory, RESUME_MARKER))
    except OSError:
        pass


# ---------------------------------------------------------------------------
# Restart orchestration
# ---------------------------------------------------------------------------

# Exceptions restarting can never fix: programming/configuration errors
# (the same code re-raises them deterministically) and graceful
# preemption (the scheduler owns the restart).  Everything else — infra
# flakes, injected faults, OOMs surfacing as RuntimeError — is retryable.
NON_RETRYABLE = (
    TypeError, ValueError, KeyError, IndexError, AttributeError,
    NameError, ImportError, NotImplementedError, AssertionError,
    PreemptionError,
)


def run_with_restarts(
    loop_fn: Callable[[int], Any],
    *,
    resume_step_fn: Callable[[], int],
    max_restarts: int = 3,
    retryable: Callable[[BaseException], bool] | None = None,
) -> Any:
    """Run loop_fn(start_step); on retryable failure, resume from the last
    checkpoint.

    loop_fn must be restartable from any checkpointed step (pure training
    state lives in checkpoints, not Python locals).  ``retryable`` is an
    optional predicate overriding the default policy (retry everything
    except :data:`NON_RETRYABLE`); note ``DeviceLossError`` is a
    RuntimeError and therefore retryable here, but the elastic path
    (``runtime.elastic.elastic_train``) normally absorbs it first.
    """
    def _should_retry(exc: BaseException) -> bool:
        if retryable is not None:
            return retryable(exc)
        return not isinstance(exc, NON_RETRYABLE)

    restarts = 0
    while True:
        start = resume_step_fn()
        try:
            return loop_fn(start)
        except Exception as exc:
            if not _should_retry(exc):
                log.error("non-retryable failure (%s: %s); failing fast",
                          type(exc).__name__, exc)
                raise
            restarts += 1
            if restarts > max_restarts:
                log.error("exceeded max_restarts=%d, giving up", max_restarts)
                raise
            log.warning(
                "step loop failed (%s); restart %d/%d from step %d",
                exc, restarts, max_restarts, resume_step_fn(),
            )
            time.sleep(0.05)
