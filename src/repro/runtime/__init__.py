"""Distributed runtime: checkpointing, elasticity, fault tolerance."""
from .checkpoint import latest_step, list_checkpoints, restore_checkpoint, save_checkpoint
from .elastic import elastic_restore, per_device_batch, reshard
from .fault import FaultInjector, StragglerWatch, run_with_restarts

__all__ = [
    "latest_step", "list_checkpoints", "restore_checkpoint", "save_checkpoint",
    "elastic_restore", "per_device_batch", "reshard",
    "FaultInjector", "StragglerWatch", "run_with_restarts",
]
