"""Distributed runtime: verified checkpointing, elasticity, fault
tolerance, chaos injection (DESIGN.md §6/§8)."""
from .async_ckpt import AsyncCheckpointWriter
from .chaos import (
    ChaosError, ChaosEvent, ChaosMonkey, ChaosSchedule, bitflip_file,
    corrupt_newest_checkpoint, poison_nan, truncate_file,
)
from .checkpoint import (
    CheckpointCorruptError, MissingLeafError, host_snapshot, latest_step,
    latest_valid_step, list_checkpoints, prune_checkpoints,
    restore_checkpoint, save_checkpoint, verify_checkpoint,
)
from .elastic import (
    elastic_restore, elastic_train, per_device_batch, reshard,
    surviving_mesh,
)
from .fault import (
    DeviceDropInjector, DeviceLossError, DivergenceSentinel, FaultInjector,
    GracefulShutdown, PreemptionError, StragglerWatch, TransientSampleError,
    clear_resume_marker, read_resume_marker, run_with_restarts,
    write_resume_marker,
)

__all__ = [
    "AsyncCheckpointWriter",
    "ChaosError", "ChaosEvent", "ChaosMonkey", "ChaosSchedule",
    "bitflip_file", "corrupt_newest_checkpoint", "poison_nan",
    "truncate_file",
    "CheckpointCorruptError", "MissingLeafError", "host_snapshot",
    "latest_step", "latest_valid_step", "list_checkpoints",
    "prune_checkpoints", "restore_checkpoint", "save_checkpoint",
    "verify_checkpoint",
    "elastic_restore", "elastic_train", "per_device_batch", "reshard",
    "surviving_mesh",
    "DeviceDropInjector", "DeviceLossError", "DivergenceSentinel",
    "FaultInjector", "GracefulShutdown", "PreemptionError",
    "StragglerWatch", "TransientSampleError", "clear_resume_marker",
    "read_resume_marker", "run_with_restarts", "write_resume_marker",
]
