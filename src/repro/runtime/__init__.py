"""Distributed runtime: checkpointing, elasticity, fault tolerance."""
from .checkpoint import latest_step, list_checkpoints, restore_checkpoint, save_checkpoint
from .elastic import (
    elastic_restore, elastic_train, per_device_batch, reshard,
    surviving_mesh,
)
from .fault import (
    DeviceDropInjector, DeviceLossError, FaultInjector, StragglerWatch,
    run_with_restarts,
)

__all__ = [
    "latest_step", "list_checkpoints", "restore_checkpoint", "save_checkpoint",
    "elastic_restore", "elastic_train", "per_device_batch", "reshard",
    "surviving_mesh",
    "DeviceDropInjector", "DeviceLossError", "FaultInjector",
    "StragglerWatch", "run_with_restarts",
]
