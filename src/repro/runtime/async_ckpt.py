"""Asynchronous checkpoint writer (DESIGN.md §8).

The step loop must not stall on serialization + fsync.  The split:

  - ``save(step, tree)`` runs on the CALLER thread and only snapshots the
    pytree to host numpy arrays (``checkpoint.host_snapshot`` — for jax
    arrays a device_get that the trailing optimizer step has usually
    already forced; always a copy, so later donation/mutation of the live
    tree can't tear the snapshot);
  - msgpack packing, CRC32 manifest, file write, fsync and pruning run on
    ONE background thread through the same :func:`checkpoint.save_checkpoint`
    used by the sync path — async and sync files are byte-identical for
    identical state, and pruning can never race another writer because
    there is only one.

State machine: idle -> (save) queued -> writing -> idle.  The queue is
bounded (default: one pending snapshot) and there is at most one write in
flight; a ``save`` arriving while the queue is full blocks the caller —
backpressure instead of unbounded snapshot memory.  A worker failure is
captured and re-raised on the next ``save``/``flush``/``close`` call.
``close`` is also registered atexit, so an exiting process flushes any
queued snapshot (flush-on-exit) instead of dropping it.

Sync mode (``runtime.checkpoint.save_checkpoint`` directly) is kept as the
default for tests and remains the reference implementation.
"""
from __future__ import annotations

import atexit
import logging
import queue
import threading
from typing import Any

from .checkpoint import host_snapshot, save_checkpoint

log = logging.getLogger("repro.ckpt")


class AsyncCheckpointWriter:
    def __init__(self, directory: str, *, keep: int = 3,
                 queue_depth: int = 1):
        self.directory = directory
        self.keep = keep
        self._q: queue.Queue = queue.Queue(maxsize=max(1, queue_depth))
        self._error: BaseException | None = None
        self._closed = False
        self._last_written: int | None = None
        self._writes = 0
        self._thread = threading.Thread(
            target=self._worker, name="ckpt-writer", daemon=True)
        self._thread.start()
        atexit.register(self.close)

    # -- background side ----------------------------------------------------
    def _worker(self):
        while True:
            item = self._q.get()
            try:
                if item is None:
                    return
                step, tree, extra_meta = item
                try:
                    save_checkpoint(self.directory, step, tree,
                                    keep=self.keep, extra_meta=extra_meta)
                    self._last_written = step
                    self._writes += 1
                except BaseException as exc:  # surfaced on the caller side
                    log.error("async checkpoint write for step %s failed: %s",
                              step, exc)
                    self._error = exc
            finally:
                self._q.task_done()

    # -- caller side --------------------------------------------------------
    def _raise_pending(self):
        if self._error is not None:
            exc, self._error = self._error, None
            raise RuntimeError(
                "async checkpoint write failed (state NOT durable past step "
                f"{self._last_written})") from exc

    def save(self, step: int, tree: Any, *,
             extra_meta: dict | None = None) -> None:
        """Snapshot now, write in the background.

        Blocks only when a previous snapshot is still queued (at-most-one
        pending; the in-flight write itself never blocks new saves).
        """
        self._raise_pending()
        if self._closed:
            raise RuntimeError("AsyncCheckpointWriter is closed")
        self._q.put((step, host_snapshot(tree), extra_meta))

    def flush(self) -> None:
        """Block until every queued snapshot is durably written."""
        self._q.join()
        self._raise_pending()

    def close(self) -> None:
        """Flush queued writes and stop the worker (idempotent)."""
        if not self._closed:
            self._closed = True
            self._q.put(None)
            self._thread.join()
            try:
                atexit.unregister(self.close)
            except Exception:
                pass
        self._raise_pending()

    def __enter__(self):
        return self

    def __exit__(self, *exc_info):
        self.close()

    @property
    def last_written_step(self) -> int | None:
        return self._last_written

    @property
    def writes(self) -> int:
        return self._writes
