"""End-to-end mixed-precision policy + loss scaling (DESIGN.md §4).

FastCHGNet's memory-footprint and throughput wins assume the hot path runs
at tensor-core-friendly precision.  This module is the single source of
truth for *which* dtype each class of value uses:

  - ``PrecisionPolicy``: a frozen, hashable 4-dtype contract
    (``param_dtype`` storage, ``compute_dtype`` GEMM/VPU operands,
    ``accum_dtype`` reductions + LayerNorm statistics + kernel
    accumulators, ``output_dtype`` public model outputs), selected by
    ``CHGNetConfig.precision`` (``"f32" | "bf16" | "mixed"``) and resolved
    via :func:`resolve_policy`.  The model, the Pallas kernel wrappers,
    the optimizer, the trainer, and the serve engine all consult the same
    policy instead of scattering ad-hoc ``astype`` calls.
  - ``LossScaleConfig`` + the functional loss scaler: static and dynamic
    variants with the standard inf/nan skip-and-halve update.  bf16
    shares float32's exponent range, so overflow is rare — but direct
    force/stress supervision makes CHGNet-style UIPs gradient-sensitive,
    and the dynamic scaler turns a bad step into a skipped step instead
    of a poisoned optimizer state.  Scaler state is a plain pytree that
    lives inside the optimizer state (``opt_state["loss_scale"]``), so it
    threads through the compile cache, the DP ``shard_map`` path, and
    ``runtime.checkpoint`` without any signature changes.

Cast-boundary discipline (enforced across layers, see DESIGN.md §4):
parameters are *stored* in ``param_dtype`` and cast to ``compute_dtype``
at their use sites (a "compute view" — free for f32, one cast for mixed);
basis functions (envelopes, RBF, Fourier) and geometry are pinned to
``accum_dtype``; every edge→node and per-crystal reduction accumulates in
``accum_dtype``; public outputs are cast to ``output_dtype``.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    """Frozen dtype contract. Dtypes are stored as *names* so the policy
    stays hashable and usable inside jit-static config dataclasses."""

    name: str = "f32"
    param_dtype: str = "float32"    # parameter storage (master weights)
    compute_dtype: str = "float32"  # GEMM / VPU operand dtype (VMEM tiles)
    accum_dtype: str = "float32"    # reductions, LN stats, kernel accums
    output_dtype: str = "float32"   # public model outputs

    # -- dtype accessors ----------------------------------------------------
    @property
    def param(self):
        return jnp.dtype(self.param_dtype)

    @property
    def compute(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def accum(self):
        return jnp.dtype(self.accum_dtype)

    @property
    def output(self):
        return jnp.dtype(self.output_dtype)

    # -- predicates ---------------------------------------------------------
    @property
    def low_precision_compute(self) -> bool:
        return self.compute != jnp.dtype(jnp.float32)

    @property
    def needs_master_weights(self) -> bool:
        """True when parameters are stored below f32 and the optimizer
        should keep an f32 master copy (``optim.adam.adam_init``)."""
        return self.param != jnp.dtype(jnp.float32)

    # -- casts --------------------------------------------------------------
    def cast_compute(self, x):
        return _cast(x, self.compute)

    def cast_output(self, x):
        return _cast(x, self.output)


def _cast(x, dtype):
    x = jnp.asarray(x)
    return x if x.dtype == dtype else x.astype(dtype)


def cast_float_tree(tree: Any, dtype) -> Any:
    """Cast every inexact (floating) leaf of a pytree; integer/bool leaves
    pass through untouched (graph indices, step counters).  The one
    tree-cast used by master-weight growth (``optim.adam``) and the
    checkpoint migration (``train.trainer``).

    Always materializes NEW float buffers, even where the cast is a no-op
    (``jnp.array`` copies; ``astype`` would return the same object): the
    result backs master weights that are donated to the train step
    alongside the params they were cast from, and donating one buffer
    through two arguments is an XLA execution error (e.g. the f32-pinned
    ``rbf_freqs`` under the bf16 policy)."""
    dtype = jnp.dtype(dtype)
    return jax.tree.map(
        lambda x: jnp.array(x, dtype=dtype)
        if jnp.issubdtype(jnp.asarray(x).dtype, jnp.inexact) else x,
        tree,
    )


F32 = PrecisionPolicy(name="f32")
# pure bf16 storage+compute; accumulation stays f32 (the MXU accumulates
# f32 natively — there is no reason to give that up)
BF16 = PrecisionPolicy(name="bf16", param_dtype="bfloat16",
                       compute_dtype="bfloat16")
# the recommended training policy: f32 master params / accumulation,
# bf16 GEMM operands (paper's "exploit GPU computation power" regime)
MIXED = PrecisionPolicy(name="mixed", compute_dtype="bfloat16")

POLICIES = {"f32": F32, "bf16": BF16, "mixed": MIXED}


def resolve_policy(precision: str | PrecisionPolicy) -> PrecisionPolicy:
    """``"f32" | "bf16" | "mixed"`` (or an explicit policy) -> policy."""
    if isinstance(precision, PrecisionPolicy):
        return precision
    try:
        return POLICIES[precision]
    except KeyError:
        raise ValueError(
            f"unknown precision {precision!r}; expected one of "
            f"{sorted(POLICIES)} or a PrecisionPolicy") from None


# ---------------------------------------------------------------------------
# Loss scaling: static and dynamic (inf/nan skip-and-halve) variants
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LossScaleConfig:
    """Loss-scaler recipe. ``kind``:

    - ``"auto"``   : dynamic when the policy computes below f32, else none
    - ``"none"``   : no scaling, no skip logic (the f32 fast path)
    - ``"static"`` : fixed ``init_scale``; non-finite grads still skip the
                     update (but the scale never moves)
    - ``"dynamic"``: skip-and-halve on inf/nan grads, double after
                     ``growth_interval`` consecutive finite steps
    """

    kind: str = "auto"
    init_scale: float = 2.0 ** 12
    growth_factor: float = 2.0
    backoff_factor: float = 0.5
    growth_interval: int = 200
    min_scale: float = 1.0
    max_scale: float = 2.0 ** 16

    def resolved_kind(self, policy: PrecisionPolicy | str) -> str:
        if self.kind != "auto":
            return self.kind
        return "dynamic" if resolve_policy(policy).low_precision_compute \
            else "none"


def loss_scale_init(cfg: LossScaleConfig) -> dict:
    """Scaler state pytree (checkpointable; lives in opt_state)."""
    return {
        "scale": jnp.asarray(cfg.init_scale, jnp.float32),
        "good_steps": jnp.zeros((), jnp.int32),
    }


def scale_loss(loss, state: dict):
    return loss * state["scale"].astype(loss.dtype)


def loss_scale_update(state: dict, grads_finite, cfg: LossScaleConfig,
                      kind: str) -> dict:
    """Skip-and-halve state machine; a no-op for the static variant."""
    if kind == "static":
        return state
    scale, good = state["scale"], state["good_steps"]
    good = jnp.where(grads_finite, good + 1, 0)
    grow = good >= cfg.growth_interval
    new_scale = jnp.where(
        grads_finite,
        jnp.where(grow,
                  jnp.minimum(scale * cfg.growth_factor, cfg.max_scale),
                  scale),
        jnp.maximum(scale * cfg.backoff_factor, cfg.min_scale),
    )
    good = jnp.where(grow, 0, good)
    return {"scale": new_scale, "good_steps": good}
