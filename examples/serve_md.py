"""Serve FastCHGNet for molecular-dynamics batched inference (Table II
scenario) through the ``repro.serve`` engine: Verlet skin-radius
neighbor-list reuse, multi-replica batched stepping, and a persistent
compiled serve step per capacity bucket.

    PYTHONPATH=src python examples/serve_md.py \
        [--steps 20] [--atoms 16] [--replicas 4]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import chgnet_mptrj as C
from repro.core.chgnet import chgnet_init
from repro.core.neighbors import Crystal
from repro.serve import BatchedMD, ServeEngine


def make_crystal(num_atoms: int, seed: int) -> Crystal:
    rng = np.random.default_rng(seed)
    a = (num_atoms * 14.0) ** (1 / 3)
    return Crystal(
        lattice=np.eye(3) * a,
        frac_coords=rng.random((num_atoms, 3)),
        atomic_numbers=rng.integers(1, 60, num_atoms),
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--atoms", type=int, default=16)
    ap.add_argument("--replicas", type=int, default=4)
    ap.add_argument("--dt", type=float, default=1e-3)
    ap.add_argument("--skin", type=float, default=0.5)
    args = ap.parse_args()

    # independent replicas of slightly different sizes — the bucket ladder
    # groups them so each group is one device program per step
    crystals = [
        make_crystal(args.atoms + 2 * (i % 3), seed=i)
        for i in range(args.replicas)
    ]

    cfg = C.FAST_FS_HEAD
    params = chgnet_init(jax.random.PRNGKey(0), cfg)
    serve = ServeEngine.for_structures(params, cfg, crystals)
    md = BatchedMD(serve, crystals, dt=args.dt, skin=args.skin)

    md.step(1)  # warm the compile cache before timing
    times = []
    for step in range(args.steps):
        t0 = time.perf_counter()
        out = md.step(1)
        times.append(time.perf_counter() - t0)
        if step % 5 == 0:
            fmax = max(float(np.abs(f).max()) for f in out["forces"])
            print(f"step {step:3d}: E0={out['energy'][0]:9.3f} eV  "
                  f"|F|max={fmax:7.3f} eV/A  t={times[-1] * 1e3:.1f} ms")

    stats = md.stats()
    rate = args.replicas * len(times) / sum(times)
    print(f"\n{args.replicas} replicas x {len(times)} steps: "
          f"{rate:.1f} replica-steps/s "
          f"({np.mean(times) * 1e3:.2f} ms/batched step)")
    print(f"padding waste {stats['mean_padding_waste']:.1%}, "
          f"compiled steps {stats['compile_cache_entries']}, "
          f"nlist rebuilds {stats['nlist_rebuilds']}/{stats['nlist_updates']}")


if __name__ == "__main__":
    main()
