"""Serve FastCHGNet for molecular-dynamics-style batched inference
(Table II scenario): repeated one-step E/F/sigma/magmom prediction while
positions evolve under velocity-Verlet-lite integration.

    PYTHONPATH=src python examples/serve_md.py [--steps 20] [--atoms 16]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import chgnet_mptrj as C
from repro.core.chgnet import chgnet_apply, chgnet_init
from repro.core.graph import BatchCapacities, batch_crystals
from repro.core.neighbors import Crystal, build_graph


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--atoms", type=int, default=16)
    ap.add_argument("--dt", type=float, default=1e-3)
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    a = (args.atoms * 14.0) ** (1 / 3)
    crystal = Crystal(
        lattice=np.eye(3) * a,
        frac_coords=rng.random((args.atoms, 3)),
        atomic_numbers=rng.integers(1, 60, args.atoms),
    )

    cfg = C.FAST_FS_HEAD
    params = chgnet_init(jax.random.PRNGKey(0), cfg)
    serve = jax.jit(lambda p, b: chgnet_apply(p, cfg, b))

    graph0 = build_graph(crystal)
    caps = BatchCapacities(args.atoms + 4,
                           int(graph0.num_bonds * 1.5) + 64,
                           int(graph0.num_angles * 2.0) + 64)

    vel = np.zeros((args.atoms, 3))
    inv_lat = np.linalg.inv(crystal.lattice)
    times = []
    for step in range(args.steps):
        graph = build_graph(crystal)
        batch = batch_crystals([crystal], [graph], caps)
        t0 = time.perf_counter()
        out = serve(params, batch)
        jax.block_until_ready(out["forces"])
        times.append(time.perf_counter() - t0)
        forces = np.asarray(out["forces"])[: args.atoms]
        # toy NVE update (unit masses) — exercises the serve path
        vel += forces * args.dt
        cart = crystal.cart_coords() + vel * args.dt
        crystal.frac_coords = (cart @ inv_lat) % 1.0
        if step % 5 == 0:
            print(f"step {step:3d}: E={float(out['energy'][0]):9.3f} eV  "
                  f"|F|max={np.abs(forces).max():7.3f} eV/A  "
                  f"t={times[-1] * 1e3:.1f} ms")
    print(f"\nmean serve latency: {np.mean(times[1:]) * 1e3:.2f} ms/step "
          f"(feature number {graph0.feature_count(args.atoms)})")


if __name__ == "__main__":
    main()
