"""End-to-end driver: train FastCHGNet (~430K params) for a few hundred
steps on the synthetic MPtrj-like dataset with the full production
substrate: load-balance sampler, prefetch, checkpoints, fault tolerance.

    PYTHONPATH=src python examples/train_chgnet_synthetic.py \
        [--steps 300] [--batch 32] [--readout direct|autodiff] \
        [--ckpt /tmp/chgnet_ckpt] [--inject-fault]
"""
import argparse
import itertools

from repro.batching import capacity_for
from repro.configs import chgnet_mptrj as C
from repro.data import (
    BatchIterator, Prefetcher, SyntheticConfig, make_dataset,
)
from repro.runtime import FaultInjector, latest_step, run_with_restarts
from repro.train import TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--crystals", type=int, default=256)
    ap.add_argument("--readout", default="direct",
                    choices=["direct", "autodiff"])
    ap.add_argument("--precision", default="f32",
                    choices=["f32", "bf16", "mixed"],
                    help="end-to-end precision policy (DESIGN.md §4)")
    ap.add_argument("--bond-store", default="directed",
                    choices=["directed", "undirected"],
                    help="undirected = half-graph bond store (DESIGN.md §5)")
    ap.add_argument("--bond-features", default="directed",
                    choices=["directed", "undirected"],
                    help="undirected = symmetric half-graph trunk "
                         "(DESIGN.md §10; requires --bond-store undirected)")
    ap.add_argument("--stress-mode", default="mlp",
                    choices=["mlp", "bond_virial"],
                    help="direct-readout stress tier (DESIGN.md §7): "
                         "bond_virial = per-bond virial from the force "
                         "head's n_ij, no stress parameters")
    ap.add_argument("--ckpt", default="/tmp/chgnet_ckpt")
    ap.add_argument("--inject-fault", action="store_true")
    args = ap.parse_args()

    ds = make_dataset(SyntheticConfig(num_crystals=args.crystals, seed=0))
    caps = capacity_for(ds, args.batch)
    model_cfg = (C.FAST_FS_HEAD if args.readout == "direct"
                 else C.FAST_WO_HEAD).with_(precision=args.precision,
                                            bond_store=args.bond_store,
                                            bond_features=args.bond_features,
                                            stress_mode=args.stress_mode)
    train_cfg = TrainConfig(global_batch=args.batch,
                            total_steps=args.steps, loss=C.LOSS)
    print(f"init LR (Eq. 14): {train_cfg.init_lr:.2e}")

    injector = FaultInjector({args.steps // 3}) if args.inject_fault else None

    def loop(start_step):
        tr = Trainer(model_cfg, train_cfg, ckpt_dir=args.ckpt,
                     ckpt_every=50)
        tr.maybe_restore()
        batches = Prefetcher(itertools.islice(
            itertools.cycle(iter(BatchIterator(ds, args.batch, 1, caps))),
            args.steps - tr.step))
        hist = tr.train(batches, fault_injector=injector)
        tr.save()
        for i in range(0, len(hist), max(1, len(hist) // 10)):
            h = hist[i]
            print(f"  step {tr.step - len(hist) + i:4d} "
                  f"loss={h['loss']:.4f} maeE={h['mae_e_per_atom']*1e3:.1f}meV"
                  f" maeF={h['mae_f']*1e3:.0f}meV/A")
        return tr

    tr = run_with_restarts(
        loop, resume_step_fn=lambda: latest_step(args.ckpt) or 0,
        max_restarts=3)
    print(f"done at step {tr.step}; straggler flags: {tr.straggler.flags}")


if __name__ == "__main__":
    main()
