"""Train a reduced LM architecture (any of the 10 assigned configs) on
synthetic tokens — exercises the exact train-step machinery the multi-pod
dry-run lowers, on CPU-sized configs.

    PYTHONPATH=src python examples/lm_pretrain_smoke.py --arch llama3-8b \
        [--steps 30] [--seq 64] [--batch 8]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_smoke
from repro.models.api import family_fns
from repro.optim import adam_init, adam_update, clip_by_global_norm, cosine_annealing


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b", choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()

    cfg = get_smoke(args.arch)
    fns = family_fns(cfg)
    params = fns.init(cfg, jax.random.PRNGKey(0))
    n = sum(int(x.size) for x in jax.tree.leaves(params))
    print(f"{args.arch} (smoke config): {n:,} params, family={cfg.family}")

    rng = np.random.default_rng(0)
    kw = dict(ssd_chunk=8) if cfg.family == "hybrid" else {}

    def make_batch():
        if fns.token_input:
            x = jnp.asarray(rng.integers(0, cfg.vocab_size,
                                         (args.batch, args.seq)))
        else:
            x = jnp.asarray(rng.normal(0, 1, (args.batch, args.seq,
                                              cfg.d_model)), jnp.float32)
        labels = jnp.asarray(rng.integers(0, cfg.vocab_size,
                                          (args.batch, args.seq)))
        extra = ()
        if fns.has_positions:
            if fns.positions_3d:
                pos = jnp.broadcast_to(jnp.arange(args.seq)[None, :, None],
                                       (args.batch, args.seq, 3))
            else:
                pos = jnp.broadcast_to(jnp.arange(args.seq)[None, :],
                                       (args.batch, args.seq))
            extra = (pos.astype(jnp.int32),)
        return (x, labels) + extra

    @jax.jit
    def step(params, opt, batch, i):
        loss, grads = jax.value_and_grad(
            lambda p: fns.loss(cfg, p, *batch, **kw))(params)
        grads = clip_by_global_norm(grads, 1.0)
        lr = cosine_annealing(i, args.steps, 3e-3, warmup_steps=5)
        params, opt = adam_update(grads, opt, params, lr)
        return params, opt, loss

    opt = adam_init(params)
    t0 = time.time()
    losses = []
    for i in range(args.steps):
        params, opt, loss = step(params, opt, make_batch(), jnp.asarray(i))
        losses.append(float(loss))
        if i % max(1, args.steps // 10) == 0:
            print(f"  step {i:3d}  loss {losses[-1]:.4f}")
    print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({(time.time() - t0) / args.steps * 1e3:.0f} ms/step)")
    assert losses[-1] < losses[0], "loss should decrease"


if __name__ == "__main__":
    main()
