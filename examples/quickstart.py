"""Quickstart: build a synthetic crystal batch, run FastCHGNet, train a
few steps, run one MD inference step.

    PYTHONPATH=src python examples/quickstart.py
"""
import itertools

import jax

from repro.batching import capacity_for
from repro.configs import chgnet_mptrj as C
from repro.core.chgnet import chgnet_apply, chgnet_init, param_count
from repro.data import BatchIterator, SyntheticConfig, make_dataset
from repro.train import TrainConfig, Trainer


def main():
    # 1. data: synthetic MPtrj-like crystals with analytic E/F/sigma/magmom
    ds = make_dataset(SyntheticConfig(num_crystals=64, max_atoms=24, seed=0))
    caps = capacity_for(ds, per_device_batch=8)
    print(f"dataset: {len(ds)} crystals, per-batch caps {caps}")

    # 2. model: FastCHGNet (direct F/S heads, fused blocks)
    cfg = C.FAST_FS_HEAD
    params = chgnet_init(jax.random.PRNGKey(0), cfg)
    print(f"FastCHGNet params: {param_count(params):,} (paper: 429.1K)")

    # 3. one forward pass
    batch = next(iter(BatchIterator(ds, 8, 1, caps)))
    out = chgnet_apply(params, cfg, batch)
    print("forward:", {k: tuple(v.shape) for k, v in out.items()})

    # 4. a few training steps (Huber loss, Adam, Eq. 14 LR)
    tr = Trainer(cfg, TrainConfig(global_batch=8, total_steps=100, loss=C.LOSS))
    hist = tr.train(itertools.islice(
        itertools.cycle(iter(BatchIterator(ds, 8, 1, caps))), 10))
    print(f"train: loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} "
          f"over {len(hist)} steps")

    # 5. MD-style serve step
    pred = chgnet_apply(tr.params, cfg, batch)
    print(f"serve: energy[0] = {float(pred['energy'][0]):.3f} eV")


if __name__ == "__main__":
    main()
