"""Collective helpers, HLO parsing, input_specs plumbing, hypothesis props."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: skip, don't die, on bare envs
from hypothesis import given, settings, strategies as st

from repro.launch.dryrun import collective_stats, _shape_bytes


def test_shape_bytes_parsing():
    assert _shape_bytes("f32[4,8]") == 128
    assert _shape_bytes("bf16[2,2]{1,0}") == 8
    assert _shape_bytes("(f32[4], bf16[8])") == 32
    assert _shape_bytes("pred[]") == 1  # scalar => product of no dims = 1


def test_collective_stats_counts_and_factors():
    hlo = """
  %ag = f32[16,128]{1,0} all-gather(f32[4,128]{1,0} %x), replica_groups={{0,1,2,3}}, dimensions={0}
  %ar = (bf16[64]{0}) all-reduce(bf16[64]{0} %y), replica_groups={{0,1}}, to_apply=%add
  %cp = f32[8]{0} collective-permute(f32[8]{0} %z), source_target_pairs={{0,1}}
  %other = f32[4]{0} add(f32[4]{0} %a, f32[4]{0} %b)
"""
    out = collective_stats(hlo)
    assert out["count"] == 3
    assert set(out["by_op"]) == {"all-gather", "all-reduce",
                                 "collective-permute"}
    # all-gather: result 16*128*4 bytes * (4-1)/4
    assert out["by_op"]["all-gather"]["bytes"] == pytest.approx(
        16 * 128 * 4 * 0.75)
    # all-reduce: 2*(g-1)/g with g=2 -> factor 1.0
    assert out["by_op"]["all-reduce"]["bytes"] == pytest.approx(64 * 2 * 1.0)


def test_input_specs_all_cells():
    from repro.configs import ARCH_IDS, get_config
    from repro.configs.shapes import SHAPES, cell_status, input_specs

    sizes = {"data": 16, "model": 16}
    n_ok = n_skip = 0
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            if cell_status(cfg, shape) != "ok":
                n_skip += 1
                continue
            io = input_specs(cfg, shape, multi_pod=False, mesh_sizes=sizes)
            assert len(io["args"]) == len(io["specs"])
            # every arg is a struct tree (no concrete arrays)
            for a in jax.tree.leaves(io["args"]):
                assert isinstance(a, jax.ShapeDtypeStruct)
            n_ok += 1
    assert n_ok == 32 and n_skip == 8  # 40 cells: 32 runnable + 8 skips


def test_long_context_skip_reasons():
    from repro.configs import get_config
    from repro.configs.shapes import SHAPES, cell_status

    assert cell_status(get_config("llama3-8b"), SHAPES["long_500k"]).startswith("skip")
    assert cell_status(get_config("rwkv6-3b"), SHAPES["long_500k"]) == "ok"
    assert cell_status(get_config("zamba2-1.2b"), SHAPES["long_500k"]) == "ok"


def test_bucketed_psum_single_device_identity():
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.distributed import bucketed_psum, compressed_psum

    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    tree = {"a": jnp.arange(4.0), "b": jnp.ones((3, 3))}

    out = shard_map(lambda t: bucketed_psum(t, "data"), mesh=mesh,
                    in_specs=(P(),), out_specs=P(), check_rep=False)(tree)
    np.testing.assert_allclose(np.asarray(out["a"]), np.asarray(tree["a"]))

    out2 = shard_map(lambda t: compressed_psum(t, "data"), mesh=mesh,
                     in_specs=(P(),), out_specs=P(), check_rep=False)(tree)
    # bf16 rounding only
    np.testing.assert_allclose(np.asarray(out2["a"]), np.asarray(tree["a"]),
                               atol=2e-2)


@given(st.integers(1, 4096), st.integers(1, 64))
@settings(max_examples=50, deadline=None)
def test_default_accum_divides_batch(batch, dp):
    from repro.configs.shapes import Shape
    from repro.launch.steps import default_accum_steps
    from repro.models.config import LMConfig

    cfg = LMConfig(name="x", family="dense")
    shape = Shape("t", "train", 4096, batch)
    a = default_accum_steps(cfg, shape, dp)
    per_dev = max(1, batch // dp)
    assert 1 <= a <= per_dev
    assert per_dev % a == 0


@given(st.floats(-10, 10, width=32), st.floats(np.float32(0.01), np.float32(1.0), width=32))
@settings(max_examples=100, deadline=None)
def test_huber_properties(x, delta):
    from repro.core.losses import huber

    h = float(huber(jnp.asarray(x), delta))
    assert h >= 0
    # upper-bounded by both branches
    assert h <= 0.5 * x * x + 1e-6
    assert h <= delta * abs(x) + 1e-6


def test_cast_floats_preserves_ints():
    from repro.models.layers import cast_floats

    tree = {"w": jnp.ones((2,), jnp.float32), "i": jnp.ones((2,), jnp.int32)}
    out = cast_floats(tree, "bfloat16")
    assert out["w"].dtype == jnp.bfloat16
    assert out["i"].dtype == jnp.int32
