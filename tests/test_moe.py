"""MoE: sort-based capacity dispatch correctness vs a naive loop oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.config import LMConfig, MoEConfig
from repro.models.layers import Maker
from repro.models.moe import moe_apply, moe_init


def naive_moe(p, x, cfg):
    """Loop-based oracle, no capacity limits (exact top-k MoE)."""
    b, s, d = x.shape
    m = cfg.moe
    logits = x @ p["router"]
    probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
    gate, idx = jax.lax.top_k(probs, m.top_k)
    gate = gate / gate.sum(-1, keepdims=True)
    out = np.zeros((b, s, d), np.float32)
    xn = np.asarray(x)
    for bi in range(b):
        for si in range(s):
            for kk in range(m.top_k):
                e = int(idx[bi, si, kk])
                h = jax.nn.silu(xn[bi, si] @ p["we_gate"][e]) * (
                    xn[bi, si] @ p["we_up"][e])
                out[bi, si] += float(gate[bi, si, kk]) * np.asarray(
                    h @ p["we_down"][e])
    return out


def _cfg(capacity_factor=8.0):
    return LMConfig(
        name="m", family="moe", num_layers=1, d_model=16, num_heads=2,
        num_kv_heads=2, vocab_size=32, compute_dtype="float32",
        moe=MoEConfig(num_experts=4, top_k=2, num_shared=0, d_ff_expert=8,
                      capacity_factor=capacity_factor),
    )


def test_moe_matches_naive_with_ample_capacity():
    cfg = _cfg(capacity_factor=8.0)  # capacity >> needed: no drops
    p = moe_init(Maker(jax.random.PRNGKey(0), None), cfg)
    x = jnp.asarray(np.random.default_rng(0).normal(0, 1, (2, 8, 16)),
                    jnp.float32)
    got = np.asarray(moe_apply(p, x, cfg))
    want = naive_moe(p, x, cfg)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)


def test_moe_capacity_drops_are_bounded():
    """With tight capacity some tokens drop; output stays finite and the
    kept fraction is >= capacity / demanded."""
    cfg = _cfg(capacity_factor=1.0)
    p = moe_init(Maker(jax.random.PRNGKey(1), None), cfg)
    x = jnp.asarray(np.random.default_rng(1).normal(0, 1, (1, 32, 16)),
                    jnp.float32)
    out = moe_apply(p, x, cfg)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_moe_shared_experts_add_dense_path():
    cfg = LMConfig(
        name="m", family="moe", num_layers=1, d_model=16, num_heads=2,
        num_kv_heads=2, vocab_size=32, compute_dtype="float32",
        moe=MoEConfig(num_experts=4, top_k=2, num_shared=2, d_ff_expert=8,
                      capacity_factor=8.0),
    )
    p = moe_init(Maker(jax.random.PRNGKey(2), None), cfg)
    assert "shared" in p
    x = jnp.asarray(np.random.default_rng(2).normal(0, 1, (2, 4, 16)),
                    jnp.float32)
    out = moe_apply(p, x, cfg)
    # shared contribution = gated-mlp(x); removing it changes output
    from repro.models.layers import gated_mlp_apply
    shared = gated_mlp_apply(p["shared"], x, "silu")
    out_wo = out - shared
    assert not bool(jnp.allclose(out, out_wo))


def test_moe_grads_flow_through_router_and_experts():
    cfg = _cfg(4.0)
    p = moe_init(Maker(jax.random.PRNGKey(3), None), cfg)
    x = jnp.asarray(np.random.default_rng(3).normal(0, 1, (2, 8, 16)),
                    jnp.float32)

    def loss(pp):
        return jnp.sum(moe_apply(pp, x, cfg) ** 2)

    g = jax.grad(loss)(p)
    assert float(jnp.abs(g["router"]).sum()) > 0
    assert float(jnp.abs(g["we_gate"]).sum()) > 0
