"""End-to-end mixed-precision subsystem (DESIGN.md §4).

Covers: bf16/mixed vs f32 forward and gradient equivalence per
(``mlp_impl``, ``agg_impl``, ``conv_impl``) tier (CPU interpret mode),
the dynamic loss-scaler halve/grow state machine on injected inf/nan
grads, f32-master-weight optimization for bf16 params, a short
loss-descent smoke under ``precision="mixed"``, and the checkpoint
dtype-verification + legacy-f32 migration paths.
"""
import dataclasses
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.batching import BatchCapacities, batch_crystals
from repro.core.chgnet import CHGNetConfig, chgnet_apply, chgnet_init
from repro.core.losses import LossWeights, chgnet_loss
from repro.core.neighbors import Crystal, build_graph
from repro.precision import (
    BF16,
    MIXED,
    LossScaleConfig,
    loss_scale_init,
    loss_scale_update,
    resolve_policy,
)

# documented §4 tolerances (test scales: unit-normal features, ~16 atoms
# per crystal): forward within 3e-2 absolute, grads within 5% relative
# global norm and cosine >= 0.999
FWD_ATOL = 3e-2
GRAD_REL = 5e-2
GRAD_COS = 0.999


def _crystal(rng, n):
    return Crystal(
        lattice=np.eye(3) * 4.4 + rng.normal(0, .05, (3, 3)),
        frac_coords=rng.random((n, 3)),
        atomic_numbers=rng.integers(1, 60, n),
        energy=float(rng.normal()),
        forces=rng.normal(0, .1, (n, 3)),
        stress=rng.normal(0, .1, (3, 3)),
        magmoms=np.abs(rng.normal(0, 1, n)),
    )


@pytest.fixture(scope="module")
def batch():
    rng = np.random.default_rng(0)
    cs = [_crystal(rng, n) for n in (5, 7, 4)]
    gs = [build_graph(c) for c in cs]
    caps = BatchCapacities(24, sum(g.num_bonds for g in gs) + 16,
                           sum(g.num_angles for g in gs) + 16)
    return batch_crystals(cs, gs, caps)


@pytest.fixture(scope="module")
def params():
    return chgnet_init(jax.random.PRNGKey(0), CHGNetConfig(),
                       dtype=jnp.float32)


# ---------------------------------------------------------------------------
# policy resolution
# ---------------------------------------------------------------------------

def test_policy_resolution():
    assert resolve_policy("mixed") is MIXED
    assert resolve_policy(BF16) is BF16
    assert MIXED.param == jnp.float32 and MIXED.compute == jnp.bfloat16
    assert MIXED.accum == jnp.float32 and MIXED.output == jnp.float32
    assert not MIXED.needs_master_weights and BF16.needs_master_weights
    with pytest.raises(ValueError):
        resolve_policy("fp8")
    # "auto" loss scaling follows the compute dtype
    auto = LossScaleConfig()
    assert auto.resolved_kind("f32") == "none"
    assert auto.resolved_kind("mixed") == "dynamic"
    assert LossScaleConfig(kind="static").resolved_kind("f32") == "static"


# ---------------------------------------------------------------------------
# forward / gradient equivalence vs f32 per implementation tier
# ---------------------------------------------------------------------------

# (mlp_impl, agg_impl, conv_impl) — the §2/§3 matrix corners; pallas/fused
# run in interpret mode (CI sets REPRO_KERNELS_INTERPRET=1; off-TPU the
# ops wrappers interpret by default)
TIERS = [
    ("packed", "scatter", "unfused"),
    ("ref", "sorted", "unfused"),
    ("packed", "matmul", "unfused"),
    ("pallas", "pallas", "unfused"),
    ("packed", "scatter", "fused"),
    ("packed", "pallas", "fused"),
]


@pytest.mark.parametrize("mlp_impl,agg_impl,conv_impl", TIERS)
def test_forward_matches_f32(batch, params, mlp_impl, agg_impl, conv_impl):
    cfg32 = CHGNetConfig(readout="direct", mlp_impl=mlp_impl,
                         agg_impl=agg_impl, conv_impl=conv_impl)
    want = chgnet_apply(params, cfg32, batch)
    for precision in ("mixed", "bf16"):
        got = chgnet_apply(params, cfg32.with_(precision=precision), batch)
        for k in want:
            assert got[k].dtype == jnp.float32, (k, precision)  # output_dtype
            np.testing.assert_allclose(
                np.asarray(got[k]), np.asarray(want[k]), atol=FWD_ATOL,
                err_msg=f"{k} {precision} {mlp_impl}/{agg_impl}/{conv_impl}")


# every tier is differentiable now — fused_rbf / fused_fourier /
# fused_gated_mlp_packed grew chunked recompute custom VJPs, so the
# mlp_impl="pallas" tier joins the gradient sweep
GRAD_TIERS = TIERS


@pytest.mark.parametrize("mlp_impl,agg_impl,conv_impl", GRAD_TIERS)
def test_gradient_matches_f32(batch, params, mlp_impl, agg_impl, conv_impl):
    cfg32 = CHGNetConfig(readout="direct", mlp_impl=mlp_impl,
                         agg_impl=agg_impl, conv_impl=conv_impl)

    def loss(p, cfg):
        return chgnet_loss(chgnet_apply(p, cfg, batch), batch,
                           LossWeights())[0]

    g32 = jax.tree.leaves(jax.grad(lambda p: loss(p, cfg32))(params))
    gmx = jax.tree.leaves(jax.grad(
        lambda p: loss(p, cfg32.with_(precision="mixed")))(params))
    # mixed grads are master-shaped: f32, same structure
    assert all(g.dtype == jnp.float32 for g in gmx)
    n32 = jnp.sqrt(sum(jnp.sum(g ** 2) for g in g32))
    nmx = jnp.sqrt(sum(jnp.sum(g ** 2) for g in gmx))
    diff = jnp.sqrt(sum(jnp.sum((a - b) ** 2) for a, b in zip(g32, gmx)))
    cos = sum(jnp.sum(a * b) for a, b in zip(g32, gmx)) / (n32 * nmx)
    assert float(diff / n32) < GRAD_REL, float(diff / n32)
    assert float(cos) > GRAD_COS, float(cos)


# ---------------------------------------------------------------------------
# op level: kernels accept bf16 VMEM operands, accumulate f32
# ---------------------------------------------------------------------------

def test_fused_segment_sum_bf16_operands():
    from repro.kernels import ops

    rng = np.random.default_rng(2)
    ids = np.sort(rng.integers(0, 12, 90)).astype(np.int32)
    seg = np.zeros(100, np.int32)
    seg[:90] = ids
    offs = np.searchsorted(ids, np.arange(13)).astype(np.int32)
    vals32 = jnp.asarray(rng.normal(0, 1, (100, 64)), jnp.float32)
    vals16 = vals32.astype(jnp.bfloat16)
    out = ops.fused_segment_sum(vals16, jnp.asarray(seg),
                                jnp.asarray(offs), 12)
    assert out.dtype == jnp.bfloat16  # operand dtype round-trips
    want = ops.fused_segment_sum(vals16.astype(jnp.float32),
                                 jnp.asarray(seg), jnp.asarray(offs), 12)
    # f32 accumulation of the SAME bf16 payloads: only the final output
    # cast separates the two
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want), rtol=1e-2, atol=1e-2)


def test_fused_gated_mlp_bf16_operands():
    from repro.kernels import ops, ref

    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(0, 1, (40, 192)), jnp.bfloat16)
    w = jnp.asarray(rng.normal(0, .1, (192, 128)), jnp.bfloat16)
    b = jnp.asarray(rng.normal(0, .1, (128,)), jnp.bfloat16)
    s = jnp.asarray(rng.uniform(.5, 1.5, (128,)), jnp.float32)
    o = jnp.asarray(rng.normal(0, .1, (128,)), jnp.float32)
    out = ops.fused_gated_mlp_packed(x, w, b, s, o)
    assert out.dtype == jnp.bfloat16
    want = ref.gated_mlp_packed_ref(x, w, b, s, o)  # same f32-accum rules
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2, atol=2e-2)


# ---------------------------------------------------------------------------
# loss scaler: unit state machine + in-step skip behavior
# ---------------------------------------------------------------------------

def test_dynamic_scaler_halves_and_grows():
    cfg = LossScaleConfig(kind="dynamic", init_scale=1024.0,
                          growth_interval=2, min_scale=1.0,
                          max_scale=4096.0)
    s = loss_scale_init(cfg)
    # non-finite grads: halve, reset the good-step counter
    s = loss_scale_update(s, jnp.asarray(False), cfg, "dynamic")
    assert float(s["scale"]) == 512.0 and int(s["good_steps"]) == 0
    # growth_interval consecutive finite steps: double, counter resets
    s = loss_scale_update(s, jnp.asarray(True), cfg, "dynamic")
    assert float(s["scale"]) == 512.0 and int(s["good_steps"]) == 1
    s = loss_scale_update(s, jnp.asarray(True), cfg, "dynamic")
    assert float(s["scale"]) == 1024.0 and int(s["good_steps"]) == 0
    # clamps
    s = {"scale": jnp.asarray(1.5, jnp.float32),
         "good_steps": jnp.zeros((), jnp.int32)}
    s = loss_scale_update(s, jnp.asarray(False), cfg, "dynamic")
    assert float(s["scale"]) == 1.0  # min_scale
    s = {"scale": jnp.asarray(4096.0, jnp.float32),
         "good_steps": jnp.asarray(1, jnp.int32)}
    s = loss_scale_update(s, jnp.asarray(True), cfg, "dynamic")
    assert float(s["scale"]) == 4096.0  # max_scale
    # static: scale never moves
    st = loss_scale_init(cfg)
    assert float(loss_scale_update(st, jnp.asarray(False), cfg,
                                   "static")["scale"]) == 1024.0


def test_train_step_skips_update_on_nonfinite_grads(batch):
    from repro.train import TrainConfig, Trainer

    cfg = CHGNetConfig(readout="direct", precision="mixed")
    tcfg = TrainConfig(global_batch=4, total_steps=10,
                       loss_scale=LossScaleConfig(kind="dynamic",
                                                  init_scale=256.0,
                                                  growth_interval=2))
    tr = Trainer(cfg, tcfg)
    assert "loss_scale" in tr.opt_state
    bad = dataclasses.replace(
        batch, energy=batch.energy.at[0].set(jnp.inf))
    # params/opt_state are DONATED by the train step: snapshot the initial
    # params to host before they are consumed
    p_init = jax.tree.map(np.asarray, tr.params)
    p2, o2, m = tr._train_step(tr.params, tr.opt_state, bad,
                               jnp.asarray(0))
    # skipped: params and Adam count untouched, scale halved
    assert float(m["grads_finite"]) == 0.0
    assert float(o2["loss_scale"]["scale"]) == 128.0
    assert int(o2["count"]) == 0
    for a, b in zip(jax.tree.leaves(p2), jax.tree.leaves(p_init)):
        np.testing.assert_array_equal(np.asarray(a), b)
    # clean batch: update applies, counter advances, scale grows after
    # growth_interval finite steps
    p3, o3, m3 = tr._train_step(p2, o2, batch, jnp.asarray(0))
    assert float(m3["grads_finite"]) == 1.0 and int(o3["count"]) == 1
    changed = any(
        not np.array_equal(np.asarray(a), b)
        for a, b in zip(jax.tree.leaves(p3), jax.tree.leaves(p_init)))
    assert changed
    _, o4, m4 = tr._train_step(p3, o3, batch, jnp.asarray(1))
    assert float(o4["loss_scale"]["scale"]) == 256.0  # 128 * 2


def test_bf16_policy_keeps_f32_master_weights(batch):
    from repro.train import TrainConfig, Trainer

    tr = Trainer(CHGNetConfig(readout="direct", precision="bf16"),
                 TrainConfig(global_batch=4, total_steps=10))
    assert "master" in tr.opt_state
    # params stored bf16 — except rbf_freqs, which feed the accum-pinned
    # basis and are stored f32 under every policy (DESIGN.md §4)
    assert tr.params["rbf_freqs"].dtype == jnp.float32
    assert all(p.dtype == jnp.bfloat16
               for path, p in
               jax.tree_util.tree_flatten_with_path(tr.params)[0]
               if jnp.issubdtype(p.dtype, jnp.inexact)
               and "rbf_freqs" not in jax.tree_util.keystr(path))
    assert all(m.dtype == jnp.float32
               for m in jax.tree.leaves(tr.opt_state["master"])
               if jnp.issubdtype(m.dtype, jnp.inexact))
    p2, o2, _ = tr._train_step(tr.params, tr.opt_state, batch,
                               jnp.asarray(0))
    # live params remain the bf16 view of the stepped f32 master
    lead = jax.tree.leaves(p2)[0]
    assert lead.dtype == jnp.bfloat16
    master_lead = jax.tree.leaves(o2["master"])[0]
    np.testing.assert_array_equal(
        np.asarray(lead), np.asarray(master_lead.astype(jnp.bfloat16)))


# ---------------------------------------------------------------------------
# training smoke: loss descends under precision="mixed"
# ---------------------------------------------------------------------------

def test_mixed_training_loss_descends():
    from repro.batching import capacity_for
    from repro.data import BatchIterator, SyntheticConfig, make_dataset
    from repro.train import TrainConfig, Trainer
    from repro.train.trainer import make_chgnet_step_fns

    ds = make_dataset(SyntheticConfig(num_crystals=32, max_atoms=12,
                                      seed=0))
    caps = capacity_for(ds, 8)
    cfg = CHGNetConfig(readout="direct", precision="mixed")
    tcfg = TrainConfig(global_batch=8, total_steps=300, lr_k=1,
                       warmup_steps=5)
    tr = Trainer(cfg, tcfg)
    _, eval_step, _ = make_chgnet_step_fns(cfg, tcfg)
    eval_batch = next(iter(BatchIterator(ds, 8, 1, caps, seed=99)))
    before = float(eval_step(tr.params, eval_batch)["loss"])
    hist = tr.train(itertools.islice(
        itertools.cycle(iter(BatchIterator(ds, 8, 1, caps))), 40))
    after = float(eval_step(tr.params, eval_batch)["loss"])
    assert after < before, (before, after)
    assert all(h["grads_finite"] == 1.0 for h in hist)


# ---------------------------------------------------------------------------
# checkpoint: non-f32 round trip, dtype verification, legacy migration
# ---------------------------------------------------------------------------

def test_checkpoint_bf16_roundtrip(tmp_path):
    pytest.importorskip("msgpack")
    from repro.runtime.checkpoint import restore_checkpoint, save_checkpoint

    tree = {"w": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3) * 0.5,
            "b": jnp.ones((4,), jnp.float32),
            "n": jnp.asarray(3, jnp.int32)}
    save_checkpoint(str(tmp_path), 7, tree)
    got, step, _ = restore_checkpoint(str(tmp_path), tree)
    assert step == 7
    for k in tree:
        assert np.asarray(got[k]).dtype == np.asarray(tree[k]).dtype, k
        np.testing.assert_array_equal(np.asarray(got[k]),
                                      np.asarray(tree[k]), err_msg=k)


def test_checkpoint_dtype_mismatch_warns_and_casts(tmp_path):
    pytest.importorskip("msgpack")
    from repro.runtime.checkpoint import restore_checkpoint, save_checkpoint

    stored = {"w": jnp.linspace(0, 1, 8, dtype=jnp.float32)}
    save_checkpoint(str(tmp_path), 1, stored)
    template = {"w": jnp.zeros((8,), jnp.bfloat16)}
    with pytest.warns(UserWarning, match="dtype mismatch"):
        got, _, _ = restore_checkpoint(str(tmp_path), template)
    assert np.asarray(got["w"]).dtype == np.asarray(template["w"]).dtype
    np.testing.assert_array_equal(
        np.asarray(got["w"]),
        np.asarray(stored["w"].astype(jnp.bfloat16)))


def test_legacy_f32_checkpoint_restores_into_mixed_trainer(tmp_path):
    """Acceptance (DESIGN.md §4): a checkpoint written by an f32 Trainer
    (no loss_scale / master leaves) restores into a mixed-precision
    Trainer via the strip-and-regrow migration."""
    pytest.importorskip("msgpack")
    from repro.train import TrainConfig, Trainer

    tcfg = TrainConfig(global_batch=4, total_steps=10)
    tr32 = Trainer(CHGNetConfig(readout="direct"), tcfg,
                   ckpt_dir=str(tmp_path), seed=3)
    assert "loss_scale" not in tr32.opt_state  # legacy layout
    tr32.step = 4
    tr32.save()

    trmx = Trainer(CHGNetConfig(readout="direct", precision="mixed"),
                   tcfg, ckpt_dir=str(tmp_path), seed=9)
    assert trmx.maybe_restore()
    assert trmx.step == 4
    # params restored exactly (both policies store f32 params) …
    for a, b in zip(jax.tree.leaves(trmx.params),
                    jax.tree.leaves(tr32.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # … and the scaler state was re-grown at init_scale
    assert "loss_scale" in trmx.opt_state
    assert float(trmx.opt_state["loss_scale"]["scale"]) == \
        tcfg.loss_scale.init_scale


def test_bf16_trainer_checkpoint_roundtrip(tmp_path):
    """Full non-f32 Trainer state (bf16 params + f32 master + scaler)
    round-trips through runtime.checkpoint."""
    pytest.importorskip("msgpack")
    from repro.train import TrainConfig, Trainer

    tcfg = TrainConfig(global_batch=4, total_steps=10)
    tr = Trainer(CHGNetConfig(readout="direct", precision="bf16"), tcfg,
                 ckpt_dir=str(tmp_path), seed=1)
    tr.step = 2
    tr.save()
    tr2 = Trainer(CHGNetConfig(readout="direct", precision="bf16"), tcfg,
                  ckpt_dir=str(tmp_path), seed=5)
    assert tr2.maybe_restore() and tr2.step == 2
    for a, b in zip(jax.tree.leaves(tr2.state()),
                    jax.tree.leaves(tr.state())):
        assert np.asarray(a).dtype == np.asarray(b).dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# serve: precision override
# ---------------------------------------------------------------------------

def test_serve_engine_precision_override(params):
    from repro.serve import ServeEngine

    rng = np.random.default_rng(4)
    cs = [_crystal(rng, n) for n in (5, 6)]
    engine = ServeEngine.for_structures(
        params, CHGNetConfig(readout="direct"), cs, precision="mixed")
    assert engine.model_cfg.precision == "mixed"
    out = engine.predict(cs)
    engine32 = ServeEngine.for_structures(
        params, CHGNetConfig(readout="direct"), cs)
    want = engine32.predict(cs)
    np.testing.assert_allclose(out["energy"], want["energy"],
                               atol=FWD_ATOL)
    for f_got, f_want in zip(out["forces"], want["forces"]):
        assert f_got.dtype == np.float32  # output_dtype
        np.testing.assert_allclose(f_got, f_want, atol=FWD_ATOL)
