"""CHGNet model: variants, physics properties, paper claims."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    BatchCapacities, Crystal, LossWeights, batch_crystals, build_graph,
    chgnet_apply, chgnet_init, chgnet_loss, param_count,
)
from repro.core.chgnet import CHGNetConfig


def _batch(seed=0, ns=(5, 7), caps=None):
    rng = np.random.default_rng(seed)
    cs = [Crystal(lattice=np.eye(3) * 4.3 + rng.normal(0, .05, (3, 3)),
                  frac_coords=rng.random((n, 3)),
                  atomic_numbers=rng.integers(1, 90, n),
                  energy=float(rng.normal()), forces=rng.normal(0, .1, (n, 3)),
                  stress=rng.normal(0, .1, (3, 3)),
                  magmoms=np.abs(rng.normal(0, 1, n)))
          for n in ns]
    gs = [build_graph(c) for c in cs]
    caps = caps or BatchCapacities(
        atoms=sum(ns) + 4, bonds=sum(g.num_bonds for g in gs) + 8,
        angles=sum(g.num_angles for g in gs) + 8)
    return batch_crystals(cs, gs, caps), cs, gs


@pytest.mark.parametrize("readout", ["direct", "autodiff"])
@pytest.mark.parametrize("variant", ["fast", "reference"])
def test_forward_shapes_no_nan(readout, variant):
    batch, _, _ = _batch()
    cfg = CHGNetConfig(readout=readout, block_variant=variant)
    params = chgnet_init(jax.random.PRNGKey(0), cfg)
    out = chgnet_apply(params, cfg, batch)
    assert out["energy"].shape == (2,)
    assert out["forces"].shape == (batch.atom_cap, 3)
    assert out["stress"].shape == (2, 3, 3)
    assert out["magmom"].shape == (batch.atom_cap,)
    for v in out.values():
        assert bool(jnp.all(jnp.isfinite(v)))


def test_param_count_near_paper():
    """Paper Table I: 429.1K (F/S head) / 412.5K (reference)."""
    direct = param_count(chgnet_init(jax.random.PRNGKey(0),
                                     CHGNetConfig(readout="direct")))
    auto = param_count(chgnet_init(jax.random.PRNGKey(0),
                                   CHGNetConfig(readout="autodiff")))
    assert abs(direct - 429_100) / 429_100 < 0.05
    assert abs(auto - 412_500) / 412_500 < 0.05
    assert direct > auto  # heads add parameters, as in the paper


def test_fast_and_reference_blocks_differ_but_are_close_at_init():
    """Dependency elimination changes the function (different inputs per
    Eq. 10 vs 11) — outputs must differ; both finite."""
    batch, _, _ = _batch()
    cfg_f = CHGNetConfig(block_variant="fast")
    cfg_r = CHGNetConfig(block_variant="reference")
    params = chgnet_init(jax.random.PRNGKey(0), cfg_f)
    e_f = chgnet_apply(params, cfg_f, batch)["energy"]
    e_r = chgnet_apply(params, cfg_r, batch)["energy"]
    assert not bool(jnp.allclose(e_f, e_r))


def test_mlp_impls_agree():
    batch, _, _ = _batch()
    params = chgnet_init(jax.random.PRNGKey(0), CHGNetConfig())
    outs = {}
    for impl in ("ref", "packed", "pallas"):
        cfg = CHGNetConfig(mlp_impl=impl)
        outs[impl] = chgnet_apply(params, cfg, batch)
    for k in outs["ref"]:
        np.testing.assert_allclose(
            np.asarray(outs["ref"][k]), np.asarray(outs["packed"][k]),
            atol=1e-5)
        np.testing.assert_allclose(
            np.asarray(outs["packed"][k]), np.asarray(outs["pallas"][k]),
            atol=2e-4)


def test_agg_impls_agree():
    batch, _, _ = _batch()
    params = chgnet_init(jax.random.PRNGKey(0), CHGNetConfig())
    a = chgnet_apply(params, CHGNetConfig(agg_impl="scatter"), batch)
    b = chgnet_apply(params, CHGNetConfig(agg_impl="matmul"), batch)
    for k in a:
        np.testing.assert_allclose(np.asarray(a[k]), np.asarray(b[k]),
                                   atol=1e-4)


def test_energy_extensive_under_padding():
    """Extra padding capacity must not change any prediction."""
    batch1, cs, gs = _batch()
    caps2 = BatchCapacities(batch1.atom_cap + 32, batch1.bond_cap + 64,
                            batch1.angle_cap + 64)
    batch2 = batch_crystals(cs, gs, caps2)
    cfg = CHGNetConfig()
    params = chgnet_init(jax.random.PRNGKey(0), cfg)
    o1 = chgnet_apply(params, cfg, batch1)
    o2 = chgnet_apply(params, cfg, batch2)
    np.testing.assert_allclose(np.asarray(o1["energy"]),
                               np.asarray(o2["energy"]), atol=1e-4)
    np.testing.assert_allclose(np.asarray(o1["stress"]),
                               np.asarray(o2["stress"]), atol=1e-4)


def test_autodiff_force_matches_finite_difference():
    """Reference readout: F = -dE/dx (centered finite differences)."""
    rng = np.random.default_rng(7)
    c = Crystal(lattice=np.eye(3) * 4.5, frac_coords=rng.random((4, 3)),
                atomic_numbers=rng.integers(1, 20, 4))
    g = build_graph(c)
    caps = BatchCapacities(8, g.num_bonds + 4, g.num_angles + 4)
    cfg = CHGNetConfig(readout="autodiff", num_blocks=1)
    params = chgnet_init(jax.random.PRNGKey(0), cfg)

    def energy_at(cart_shift):
        c2 = Crystal(lattice=c.lattice,
                     frac_coords=(c.cart_coords() + cart_shift)
                     @ np.linalg.inv(c.lattice),
                     atomic_numbers=c.atomic_numbers)
        batch = batch_crystals([c2], [g], caps)  # same topology, moved atoms
        return float(chgnet_apply(params, cfg, batch)["energy"][0])

    batch = batch_crystals([c], [g], caps)
    forces = np.asarray(chgnet_apply(params, cfg, batch)["forces"])
    eps = 1e-3
    for (i, k) in [(0, 0), (1, 2), (3, 1)]:
        dx = np.zeros((4, 3))
        dx[i, k] = eps
        f_num = -(energy_at(dx) - energy_at(-dx)) / (2 * eps)
        assert abs(f_num - forces[i, k]) < 5e-3 * max(1, abs(f_num)) + 1e-3


def test_loss_and_grads_finite_all_variants():
    batch, _, _ = _batch()
    for readout in ("direct", "autodiff"):
        cfg = CHGNetConfig(readout=readout)
        params = chgnet_init(jax.random.PRNGKey(1), cfg)

        def loss_fn(p):
            pred = chgnet_apply(p, cfg, batch)
            return chgnet_loss(pred, batch, LossWeights())[0]

        g = jax.grad(loss_fn)(params)
        assert all(bool(jnp.all(jnp.isfinite(x))) for x in jax.tree.leaves(g))
