"""GPipe pipeline parallelism: 4-stage device test (subprocess) + helpers."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.distributed.pipeline import bubble_fraction

REPO = os.path.normpath(os.path.join(os.path.dirname(__file__), ".."))

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    from repro.distributed.pipeline import gpipe_apply, split_stages

    L, D, M, MB = 8, 16, 6, 4   # layers, width, microbatches, microbatch sz
    S = 4
    rng = np.random.default_rng(0)
    ws = jnp.asarray(rng.normal(0, 0.3, (L, D, D)), jnp.float32)
    x = jnp.asarray(rng.normal(0, 1, (M, MB, D)), jnp.float32)

    def layer(w, h):
        return jnp.tanh(h @ w)

    # sequential reference
    def seq_forward(ws, x):
        h = x
        for i in range(L):
            h = layer(ws[i], h)
        return h

    ref = jax.vmap(lambda xm: seq_forward(ws, xm))(x)

    # pipelined
    mesh = jax.make_mesh((S,), ("pipe",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    staged = split_stages(ws, S)

    def stage_fn(stage_ws, h):
        def body(h, w):
            return layer(w, h), None
        h, _ = jax.lax.scan(body, h, stage_ws)
        return h

    def pipe(staged, x):
        return gpipe_apply(staged, x, stage_fn, axis="pipe")

    piped = shard_map(pipe, mesh=mesh, in_specs=(P("pipe"), P()),
                      out_specs=P(), check_rep=False)(staged, x)
    fwd_err = float(jnp.abs(piped - ref).max())

    # gradients through the pipeline == sequential gradients
    def loss_pipe(staged):
        return jnp.sum(shard_map(pipe, mesh=mesh, in_specs=(P("pipe"), P()),
                                 out_specs=P(), check_rep=False)(staged, x) ** 2)

    def loss_seq(ws):
        return jnp.sum(jax.vmap(lambda xm: seq_forward(ws, xm))(x) ** 2)

    g_pipe = jax.grad(loss_pipe)(staged)
    g_seq = jax.grad(loss_seq)(ws).reshape(S, L // S, D, D)
    g_err = float(jnp.abs(g_pipe - g_seq).max())
    print(json.dumps({"fwd_err": fwd_err, "g_err": g_err}))
""")


def test_gpipe_matches_sequential_4_stages():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", _SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["fwd_err"] < 1e-5, rec
    assert rec["g_err"] < 1e-4, rec


def test_bubble_fraction():
    assert bubble_fraction(4, 6) == pytest.approx(3 / 9)
    assert bubble_fraction(1, 8) == 0.0
    # more microbatches -> smaller bubble
    assert bubble_fraction(4, 32) < bubble_fraction(4, 8)


def test_split_stages_shapes():
    import jax.numpy as jnp

    from repro.distributed.pipeline import split_stages

    tree = {"w": jnp.zeros((8, 3, 3)), "b": jnp.zeros((8, 3))}
    out = split_stages(tree, 4)
    assert out["w"].shape == (4, 2, 3, 3)
    assert out["b"].shape == (4, 2, 3)
