"""Per-arch REDUCED smoke tests (assignment requirement (f)): instantiate a
reduced config of the same family, run one forward/train step on CPU,
assert output shapes + no NaNs. Plus decode-vs-train consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke
from repro.models import encdec, hybrid, rwkv, transformer
from repro.models.api import family_fns


def _inputs(cfg, fns, B=2, S=16, seed=0):
    rng = np.random.default_rng(seed)
    if fns.token_input:
        x = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)))
    else:
        x = jnp.asarray(rng.normal(0, 1, (B, S, cfg.d_model)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)))
    args = [x, labels]
    if fns.has_positions:
        if fns.positions_3d:
            pos = jnp.broadcast_to(
                jnp.arange(S)[None, :, None], (B, S, 3)).astype(jnp.int32)
        else:
            pos = jnp.broadcast_to(
                jnp.arange(S)[None, :], (B, S)).astype(jnp.int32)
        args.append(pos)
    return args


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke(arch)
    assert cfg.family == get_config(arch).family  # same family as full
    fns = family_fns(cfg)
    params = fns.init(cfg, jax.random.PRNGKey(0))
    args = _inputs(cfg, fns)
    kw = dict(ssd_chunk=8) if cfg.family == "hybrid" else {}
    loss, grads = jax.value_and_grad(
        lambda p: fns.loss(cfg, p, *args, **kw))(params)
    assert np.isfinite(float(loss))
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in jax.tree.leaves(grads))
    # one SGD step moves the loss
    params2 = jax.tree.map(lambda p, g: p - 1e-2 * g, params, grads)
    loss2 = fns.loss(cfg, params2, *args, **kw)
    assert float(loss2) < float(loss)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """The CONFIG files carry the exact assigned hyperparameters."""
    cfg = get_config(arch)
    expected = {
        "llama3-8b": (32, 4096, 32, 8, 14336, 128256),
        "gemma-2b": (18, 2048, 8, 1, 16384, 256000),
        "qwen3-8b": (36, 4096, 32, 8, 12288, 151936),
        "qwen1.5-110b": (80, 8192, 64, 8, 49152, 152064),
        "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
        "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
        "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
        "qwen2-vl-2b": (28, 1536, 12, 2, 8960, 151936),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "rwkv6-3b": (32, 2560, 40, 40, 8960, 65536),
    }[arch]
    layers, d, h, kv, ff, vocab = expected
    assert cfg.num_layers == layers
    assert cfg.d_model == d
    assert cfg.num_heads == h
    assert cfg.num_kv_heads == kv
    ff_actual = cfg.moe.d_ff_expert if cfg.is_moe else cfg.d_ff
    assert ff_actual == ff
    assert cfg.vocab_size == vocab


def test_transformer_decode_matches_forward():
    cfg = get_smoke("qwen3-8b")
    fns = family_fns(cfg)
    params = fns.init(cfg, jax.random.PRNGKey(0))
    B, S = 2, 12
    tok = jnp.asarray(np.random.default_rng(0).integers(0, cfg.vocab_size, (B, S)))
    pos = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S)).astype(jnp.int32)
    full = transformer.forward_train(cfg, params, tok, pos)
    _, cache = transformer.prefill(cfg, params, tok[:, :6], pos[:, :6],
                                   max_len=S, chunk=3,
                                   cache_dtype=jnp.float32)
    errs = []
    for i in range(6, S):
        lg, cache = transformer.decode_step(cfg, params, tok[:, i:i + 1],
                                            cache, pos[:, i:i + 1])
        errs.append(float(jnp.abs(lg[:, 0] - full[:, i]).max()))
    assert max(errs) < 1e-4


def test_rwkv_decode_matches_forward():
    cfg = get_smoke("rwkv6-3b")
    params = rwkv.rwkv_init(cfg, jax.random.PRNGKey(0))
    B, S = 2, 10
    tok = jnp.asarray(np.random.default_rng(1).integers(0, cfg.vocab_size, (B, S)))
    full = rwkv.forward_train(cfg, params, tok)
    st = rwkv.rwkv_init_states(cfg, B)
    errs = []
    for i in range(S):
        lg, st = rwkv.decode_step(cfg, params, tok[:, i:i + 1], st)
        errs.append(float(jnp.abs(lg[:, 0] - full[:, i]).max()))
    assert max(errs) < 1e-4


def test_zamba_decode_matches_forward():
    cfg = get_smoke("zamba2-1.2b")
    params = hybrid.zamba_init(cfg, jax.random.PRNGKey(0))
    B, S = 2, 16
    tok = jnp.asarray(np.random.default_rng(2).integers(0, cfg.vocab_size, (B, S)))
    pos = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S)).astype(jnp.int32)
    full = hybrid.forward_train(cfg, params, tok, pos, ssd_chunk=8)
    st = hybrid.init_state(cfg, B, S, dtype=jnp.float32)
    errs = []
    for i in range(S):
        lg, st = hybrid.decode_step(cfg, params, tok[:, i:i + 1], st,
                                    pos[:, i:i + 1])
        errs.append(float(jnp.abs(lg[:, 0] - full[:, i]).max()))
    assert max(errs) < 1e-3


def test_zamba_prefill_matches_decode_path():
    cfg = get_smoke("zamba2-1.2b")
    params = hybrid.zamba_init(cfg, jax.random.PRNGKey(0))
    B, S = 2, 16
    tok = jnp.asarray(np.random.default_rng(3).integers(0, cfg.vocab_size, (B, S)))
    pos = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S)).astype(jnp.int32)
    full = hybrid.forward_train(cfg, params, tok, pos, ssd_chunk=8)
    logits, st = hybrid.prefill(cfg, params, tok[:, :8], pos[:, :8],
                                max_len=S, chunk=4, ssd_chunk=4,
                                cache_dtype=jnp.float32)
    assert float(jnp.abs(logits[:, 0] - full[:, 7]).max()) < 1e-3
    lg, st = hybrid.decode_step(cfg, params, tok[:, 8:9], st, pos[:, 8:9])
    assert float(jnp.abs(lg[:, 0] - full[:, 8]).max()) < 1e-3


def test_whisper_decode_matches_forward():
    cfg = get_smoke("whisper-medium")
    params = encdec.whisper_init(cfg, jax.random.PRNGKey(0))
    B, Se, Sd = 2, 20, 8
    rng = np.random.default_rng(4)
    frames = jnp.asarray(rng.normal(0, 1, (B, Se, cfg.d_model)), jnp.float32)
    dtok = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, Sd)))
    full = encdec.forward_train(cfg, params, frames, dtok)
    enc_out = encdec.encode(cfg, params, frames)
    cache = encdec.init_cache(cfg, params, enc_out, max_len=Sd,
                              dtype=jnp.float32)
    errs = []
    for i in range(Sd):
        lg, cache = encdec.decode_step(cfg, params, dtok[:, i:i + 1], cache)
        errs.append(float(jnp.abs(lg[:, 0] - full[:, i]).max()))
    assert max(errs) < 1e-4


def test_mrope_norm_preserving():
    from repro.models.layers import apply_mrope, apply_rope

    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(0, 1, (2, 8, 4, 16)), jnp.float32)
    pos3 = jnp.asarray(rng.integers(0, 50, (2, 8, 3)), jnp.int32)
    y = apply_mrope(x, pos3, (4, 2, 2), 10000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-4)
    pos = jnp.asarray(rng.integers(0, 50, (2, 8)), jnp.int32)
    y2 = apply_rope(x, pos, 10000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y2), axis=-1), rtol=1e-4)
