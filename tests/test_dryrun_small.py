"""Dry-run machinery on a tiny mesh (subprocess: needs >1 host device).

The full 512-device production dry-run is exercised by
``python -m repro.launch.dryrun`` (results in benchmarks/results/); here we
verify the same machinery lowers+compiles on an 8-device (2,2,2) pod-data-
model mesh with reduced configs, inside this test session via subprocess.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.normpath(os.path.join(os.path.dirname(__file__), ".."))

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, sys
    import jax
    from repro.configs import get_smoke
    from repro.configs.shapes import Shape, input_specs
    from repro.launch.steps import build_cell
    from repro.launch.dryrun import collective_stats

    arch, kind = sys.argv[1], sys.argv[2]
    cfg = get_smoke(arch)
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 3)
    shape = Shape("t", kind, 64, 8)
    step, args, shardings, donate, outs = build_cell(
        cfg, shape, mesh, multi_pod=True, attn_chunk=32)
    with mesh:
        comp = jax.jit(step, in_shardings=shardings, out_shardings=outs,
                       donate_argnums=donate).lower(*args).compile()
    mem = comp.memory_analysis()
    cost = comp.cost_analysis()
    coll = collective_stats(comp.as_text())
    print(json.dumps({
        "flops": cost.get("flops", 0.0),
        "temp": mem.temp_size_in_bytes,
        "coll_count": coll["count"],
        "coll_bytes": coll["bytes"],
    }))
""")


def _run(arch, kind):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT, arch, kind],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.parametrize("arch,kind", [
    ("llama3-8b", "train"),
    ("phi3.5-moe-42b-a6.6b", "train"),
    ("rwkv6-3b", "decode"),
    ("zamba2-1.2b", "decode"),
    ("whisper-medium", "train"),
])
def test_small_mesh_dryrun_compiles(arch, kind):
    rec = _run(arch, kind)
    assert rec["flops"] >= 0
    # data parallelism must produce at least one collective (grad psum)
    if kind == "train":
        assert rec["coll_count"] > 0
        assert rec["coll_bytes"] > 0


def test_production_dryrun_results_exist_and_pass():
    """The committed full-mesh dry-run results: every non-skip cell ok,
    both meshes present for every arch x shape."""
    path = os.path.join(REPO, "benchmarks", "results", "dryrun.json")
    if not os.path.exists(path):
        pytest.skip("run `python -m repro.launch.dryrun --all` first")
    recs = json.load(open(path))
    seen = {(r["arch"], r["shape"], r["mesh"]) for r in recs}
    from repro.configs import ARCH_IDS

    assert len(seen) >= 10 * 4 * 2  # 40 cells x 2 meshes
    bad = [r for r in recs if r["status"].startswith("error")]
    assert not bad, [(r["arch"], r["shape"], r["status"]) for r in bad[:5]]
    for arch in ARCH_IDS:
        for mesh in ("16x16", "2x16x16"):
            assert (arch, "train_4k", mesh) in seen
