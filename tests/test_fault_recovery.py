"""Chaos matrix for the DESIGN.md §8 resilience layer.

End-to-end scenarios (tiny CHGNet, per-step-seeded batches so an
interrupted run sees the SAME data as an uninterrupted one):

  - SIGTERM preemption: checkpoint + resume marker at the exact step,
    resumed run finishes BIT-identical to an uninterrupted reference;
  - corrupt-newest checkpoint: restore falls back to the next-newest
    valid file; pruning never counts corrupt files against keep-K;
  - NaN-streak divergence: sentinel trips, the run rolls back to the
    last good checkpoint, quarantines the streak's batches, and the
    loss still descends;
  - determinism: the same seed + chaos schedule reproduces the
    identical metric history.

Plus unit coverage of the building blocks: verified checkpoints, the
async writer, the divergence sentinel, Prefetcher retry/shutdown, the
chaos schedule grammar, and the restart allowlist.
"""
import itertools
import os

import jax
import numpy as np
import pytest

from repro.batching import capacity_for
from repro.core.chgnet import CHGNetConfig
from repro.data import (
    BatchIterator, Prefetcher, SyntheticConfig, TaggedBatch,
    TransientSampleError, make_dataset,
)
from repro.runtime import (
    AsyncCheckpointWriter, ChaosMonkey, ChaosSchedule, CheckpointCorruptError,
    DivergenceSentinel, GracefulShutdown, PreemptionError,
    corrupt_newest_checkpoint, latest_step, latest_valid_step,
    list_checkpoints, read_resume_marker, restore_checkpoint,
    run_with_restarts, save_checkpoint, verify_checkpoint,
)
from repro.runtime.checkpoint import _ckpt_path
from repro.train import TrainConfig, Trainer

BATCH = 4


@pytest.fixture(scope="module")
def setup():
    ds = make_dataset(SyntheticConfig(num_crystals=16, max_atoms=10, seed=0))
    return ds, capacity_for(ds, BATCH), CHGNetConfig(dim=16, num_blocks=1)


def _step_batches(ds, caps, start, stop, *, tag=False):
    """Batch for step s is a pure function of s — an interrupted run
    resumed at step k replays the identical data an uninterrupted run saw."""
    for s in range(start, stop):
        it = BatchIterator(ds, BATCH, 1, caps, seed=s, tag_indices=tag)
        yield next(iter(it))


def _tcfg(steps, **kw):
    return TrainConfig(global_batch=BATCH, total_steps=steps, **kw)


# ---------------------------------------------------------------------------
# verified checkpoints
# ---------------------------------------------------------------------------

def _tree(val, n=4096):
    return {"w": np.full(n, val, np.float32),
            "b": np.arange(8, dtype=np.float32) * val}


def test_corrupt_newest_falls_back(tmp_path):
    d = str(tmp_path)
    for step in (1, 2, 3):
        save_checkpoint(d, step, _tree(step), keep=5)
    corrupt_newest_checkpoint(d, mode="truncate")
    assert latest_step(d) == 3  # the file exists ...
    assert latest_valid_step(d) == 2  # ... but is not a restore target
    assert not verify_checkpoint(_ckpt_path(d, 3))
    state, step, _ = restore_checkpoint(d, _tree(0.0))
    assert step == 2
    np.testing.assert_array_equal(state["w"], _tree(2)["w"])


def test_bitflip_detected_by_manifest(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, _tree(1.0), keep=5)
    corrupt_newest_checkpoint(d, mode="bitflip", seed=0)
    # 4096 floats dominate the payload, so a seeded 8-bit flip lands in
    # array data; the CRC manifest must catch what msgpack can't
    assert not verify_checkpoint(_ckpt_path(d, 1))
    with pytest.raises(CheckpointCorruptError):
        restore_checkpoint(d, _tree(0.0), fallback=False)


def test_explicit_step_restore_never_falls_back(tmp_path):
    d = str(tmp_path)
    for step in (1, 2):
        save_checkpoint(d, step, _tree(step), keep=5)
    corrupt_newest_checkpoint(d, mode="truncate")
    with pytest.raises(CheckpointCorruptError):
        restore_checkpoint(d, _tree(0.0), step=2)


def test_prune_counts_only_valid_checkpoints(tmp_path):
    d = str(tmp_path)
    for step in (1, 2, 3):
        save_checkpoint(d, step, _tree(step), keep=10)
    corrupt_newest_checkpoint(d, mode="truncate")  # step 3 invalid
    # keep=2 over VALID files: 1 and 2 both survive (3 doesn't count)
    save_checkpoint(d, 4, _tree(4), keep=2)
    steps = list_checkpoints(d)
    assert 2 in steps and 4 in steps
    assert latest_valid_step(d) == 4
    assert 1 not in steps  # oldest valid beyond keep-K is gone


def test_all_corrupt_raises(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, _tree(1.0), keep=5)
    corrupt_newest_checkpoint(d, mode="truncate")
    with pytest.raises(CheckpointCorruptError):
        restore_checkpoint(d, _tree(0.0))


# ---------------------------------------------------------------------------
# async writer
# ---------------------------------------------------------------------------

def test_async_writer_matches_sync_bytes(tmp_path):
    sync_d, async_d = str(tmp_path / "s"), str(tmp_path / "a")
    for step in (1, 2, 3):
        save_checkpoint(sync_d, step, _tree(step), keep=2)
    with AsyncCheckpointWriter(async_d, keep=2) as w:
        for step in (1, 2, 3):
            w.save(step, _tree(step))
        w.flush()
        assert w.last_written_step == 3
        assert w.writes == 3
    assert list_checkpoints(sync_d) == list_checkpoints(async_d) == [2, 3]
    for step in (2, 3):
        a = open(_ckpt_path(sync_d, step), "rb").read()
        b = open(_ckpt_path(async_d, step), "rb").read()
        assert a == b  # same serializer, same bytes: one restore path


def test_async_writer_snapshot_isolation(tmp_path):
    # mutating the tree after save() must not leak into the file
    tree = {"w": np.zeros(16, np.float32)}
    with AsyncCheckpointWriter(str(tmp_path)) as w:
        w.save(1, tree)
        tree["w"] += 999.0
        w.flush()
    state, _, _ = restore_checkpoint(str(tmp_path), {"w": np.zeros(16,
                                                                   np.float32)})
    np.testing.assert_array_equal(state["w"], np.zeros(16, np.float32))


def test_async_writer_surfaces_worker_error(tmp_path):
    blocked = tmp_path / "not_a_dir"
    blocked.write_text("occupied")  # directory path is taken by a file
    w = AsyncCheckpointWriter(str(blocked))
    w.save(1, _tree(1.0))
    with pytest.raises(RuntimeError, match="NOT durable"):
        w.flush()
    w.close()  # error was consumed by flush: close is clean


# ---------------------------------------------------------------------------
# divergence sentinel
# ---------------------------------------------------------------------------

def test_sentinel_nan_streak_trips():
    s = DivergenceSentinel(nan_streak=2)
    assert not s.record(float("nan"))
    assert s.suspicious
    assert s.record(float("nan"))
    assert s.last_trip_len == 2
    assert not s.suspicious  # trip resets the streaks


def test_sentinel_scaler_skipped_exempt():
    s = DivergenceSentinel(nan_streak=1)
    for _ in range(10):
        assert not s.record(float("nan"), scaler_skipped=True)
    assert not s.suspicious


def test_sentinel_spike_streak_trips_and_median_uncontaminated():
    s = DivergenceSentinel(spike_factor=10.0, spike_streak=3, min_history=4)
    for _ in range(8):
        assert not s.record(1.0)
    assert not s.record(50.0)
    assert not s.record(50.0)
    assert s.record(50.0)  # 3rd consecutive spike
    # spikes never entered the reference window: 50x is still a spike
    for _ in range(2):
        assert not s.record(50.0)
    assert s.record(50.0)


def test_sentinel_isolated_spike_no_trip():
    s = DivergenceSentinel(spike_streak=2, min_history=4)
    for _ in range(6):
        s.record(1.0)
    assert not s.record(100.0)
    assert not s.record(1.0)  # streak broken
    assert not s.record(100.0)


# ---------------------------------------------------------------------------
# prefetcher retry / shutdown
# ---------------------------------------------------------------------------

class _FlakySource:
    """Resumable source raising TransientSampleError at given positions."""

    def __init__(self, n, fail_at=(), always_fail=False):
        self.n, self.i = n, 0
        self.fail_at = set(fail_at)
        self.always_fail = always_fail

    def __iter__(self):
        return self

    def __next__(self):
        if self.i >= self.n:
            raise StopIteration
        i = self.i
        self.i += 1
        if self.always_fail or i in self.fail_at:
            raise TransientSampleError(index=i)
        return i


def test_prefetcher_quarantines_transient_and_continues():
    pf = Prefetcher(_FlakySource(6, fail_at={2, 4}), backoff=0.001)
    assert list(pf) == [0, 1, 3, 5]
    assert pf.quarantined == [2, 4]


def test_prefetcher_escalates_after_max_retries():
    pf = Prefetcher(_FlakySource(6, always_fail=True), max_retries=2,
                    backoff=0.001)
    with pytest.raises(TransientSampleError):
        list(pf)


def test_prefetcher_early_break_joins_worker():
    # infinite source + tiny queue: the worker WILL be blocked on put
    pf = Prefetcher(itertools.count(), depth=1)
    for x in pf:
        if x >= 1:
            break  # consumer leaves early; close() runs via finally
    pf.thread.join(5.0)
    assert not pf.thread.is_alive()


def test_prefetcher_worker_crash_reraised_in_consumer():
    def boom():
        yield 1
        raise RuntimeError("worker died")

    pf = Prefetcher(boom())
    with pytest.raises(RuntimeError, match="worker died"):
        list(pf)
    assert not pf.thread.is_alive()


# ---------------------------------------------------------------------------
# chaos schedule / restart allowlist
# ---------------------------------------------------------------------------

def test_chaos_schedule_parse_roundtrip():
    spec = "nan@5,sigterm@12,drop@7:0,straggler@9:0.2"
    sched = ChaosSchedule.parse(spec, seed=3)
    assert sched.spec() == "nan@5,drop@7:0,straggler@9:0.2,sigterm@12"
    assert ChaosSchedule.parse(sched.spec(), seed=3) == sched
    assert [e.kind for e in sched.at(7, frozenset({"drop"}))] == ["drop"]


def test_chaos_schedule_rejects_bad_tokens():
    with pytest.raises(ValueError):
        ChaosSchedule.parse("frobnicate@3")
    with pytest.raises(ValueError):
        ChaosSchedule.parse("nan@notastep")


def test_run_with_restarts_fails_fast_on_programming_errors():
    calls = []

    def loop(start):
        calls.append(start)
        raise ValueError("config typo")

    with pytest.raises(ValueError):
        run_with_restarts(loop, resume_step_fn=lambda: 0, max_restarts=5)
    assert len(calls) == 1  # no doomed retries


def test_run_with_restarts_never_retries_preemption():
    calls = []

    def loop(start):
        calls.append(start)
        raise PreemptionError(7)

    with pytest.raises(PreemptionError):
        run_with_restarts(loop, resume_step_fn=lambda: 0, max_restarts=5)
    assert len(calls) == 1


# ---------------------------------------------------------------------------
# end-to-end chaos scenarios
# ---------------------------------------------------------------------------

def test_sigterm_resume_bit_identical(setup, tmp_path):
    ds, caps, cfg = setup
    steps, d = 6, str(tmp_path)
    # uninterrupted reference
    ref = Trainer(cfg, _tcfg(steps))
    ref.train(_step_batches(ds, caps, 0, steps))
    # interrupted at step 3 (real SIGTERM via the chaos monkey)
    monkey = ChaosMonkey(ChaosSchedule.parse("sigterm@3"))
    with GracefulShutdown() as shutdown:
        tr = Trainer(cfg, _tcfg(steps), ckpt_dir=d, ckpt_every=100,
                     shutdown=shutdown)
        with pytest.raises(PreemptionError):
            tr.train(_step_batches(ds, caps, 0, steps),
                     fault_injector=monkey)
        marker = read_resume_marker(d)
        assert marker is not None and marker["step"] == tr.step == 4
        assert latest_valid_step(d) == 4  # final save is durable + valid
        shutdown.requested = False
        res = Trainer(cfg, _tcfg(steps), ckpt_dir=d, shutdown=shutdown)
        assert res.maybe_restore() and res.step == 4
        res.train(_step_batches(ds, caps, res.step, steps))
    assert res.step == steps
    for a, b in zip(jax.tree.leaves(ref.params), jax.tree.leaves(res.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _chaos_run(ds, caps, cfg, d, *, steps=8, ckpt_every=2,
               chaos="nan@3,nan@4", max_attempts=6):
    """Launcher-style restart loop under a chaos schedule; returns
    (trainer, full metric history, stats aggregated across attempts —
    each attempt builds a fresh Trainer, as a relaunched process would)."""
    monkey = ChaosMonkey(ChaosSchedule.parse(chaos), ckpt_dir=d)
    history, attempts = [], 0
    stats = {"rollbacks": 0, "quarantined": set()}
    while True:
        attempts += 1
        assert attempts <= max_attempts
        tr = Trainer(cfg, _tcfg(steps, rollback_on_divergence=True,
                                divergence_nan_streak=2),
                     ckpt_dir=d, ckpt_every=ckpt_every)
        tr.maybe_restore()
        stream = monkey.wrap_batches(
            _step_batches(ds, caps, tr.step, steps, tag=True),
            start_step=tr.step)
        try:
            history.extend(tr.train(stream, fault_injector=monkey))
        except PreemptionError:
            raise
        except Exception as exc:  # injected crash: restart
            history.extend(getattr(exc, "partial_history", []))
            tr.close()
            continue
        finally:
            stats["rollbacks"] += tr.rollbacks
            stats["quarantined"] |= tr.quarantined
        if tr.step >= steps:
            return tr, history, stats


def test_nan_rollback_quarantines_and_descends(setup, tmp_path):
    ds, caps, cfg = setup
    tr, history, stats = _chaos_run(ds, caps, cfg, str(tmp_path))
    assert tr.step == 8
    assert stats["rollbacks"] == 1
    assert stats["quarantined"]  # the streak's batch indices are blacklisted
    finite = [h["loss"] for h in history if np.isfinite(h["loss"])]
    assert np.isfinite(history[-1]["loss"])
    assert finite[-1] < finite[0]  # still learning after the rollback
    # every surviving checkpoint passes verification (healthy-only saves)
    d = str(tmp_path)
    assert all(verify_checkpoint(_ckpt_path(d, s))
               for s in list_checkpoints(d))


def test_same_seed_and_schedule_identical_history(setup, tmp_path):
    ds, caps, cfg = setup
    _, h1, _ = _chaos_run(ds, caps, cfg, str(tmp_path / "run1"))
    _, h2, _ = _chaos_run(ds, caps, cfg, str(tmp_path / "run2"))
    assert len(h1) == len(h2)
    # bit-identical metric dicts, replayed faults & all (NaN == NaN here)
    np.testing.assert_equal(h1, h2)


def test_crash_recovery_bounded_rework(setup, tmp_path):
    ds, caps, cfg = setup
    tr, history, _ = _chaos_run(ds, caps, cfg, str(tmp_path),
                                chaos="crash@5", ckpt_every=2)
    assert tr.step == 8
    # rework = executed - final: crash at 5, restore at 4 -> exactly 1
    assert len(history) - tr.step <= 2


def test_tagged_batches_reach_trainer(setup):
    ds, caps, _ = setup
    batch = next(_step_batches(ds, caps, 0, 1, tag=True))
    assert isinstance(batch, TaggedBatch)
    assert len(np.asarray(batch.indices)) == BATCH
    # TaggedBatch is a pytree: chaos poisoning and device_put must recurse
    leaves = jax.tree.leaves(batch)
    assert len(leaves) > 1
