"""Aggregation engine: the four ``segment_aggregate`` impls must agree on
random masked graphs (scatter / matmul / sorted / pallas-in-interpret), the
sorted-segment layout must hold everywhere batches are packed, and the
force head must stay rotation-equivariant under the sorted layout."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.batching import BatchCapacities, batch_crystals, validate_layout
from repro.core.chgnet import CHGNetConfig, chgnet_apply, chgnet_init
from repro.core.interaction import segment_aggregate
from repro.core.neighbors import Crystal, build_graph

IMPLS = ("scatter", "matmul", "sorted", "pallas")


def _random_sorted_layout(rng, num_edges, num_segments, dim, n_real):
    """Raw arrays in the sorted-segment layout (padding convention incl.)."""
    ids = np.sort(rng.integers(0, num_segments, n_real)).astype(np.int32)
    seg = np.zeros(num_edges, np.int32)
    seg[:n_real] = ids
    offsets = np.searchsorted(ids, np.arange(num_segments + 1)).astype(np.int32)
    mask = np.zeros(num_edges, np.float32)
    mask[:n_real] = 1.0
    values = rng.normal(0, 1, (num_edges, dim)).astype(np.float32)
    return (jnp.asarray(values), jnp.asarray(seg), jnp.asarray(mask),
            jnp.asarray(offsets))


@pytest.mark.parametrize("num_edges,num_segments,dim,n_real", [
    (256, 32, 64, 200),
    (100, 17, 8, 100),   # no padding
    (64, 9, 33, 0),      # all padding
    (513, 200, 64, 400),  # many empty segments
])
def test_impls_agree_on_random_layouts(num_edges, num_segments, dim, n_real):
    rng = np.random.default_rng(num_edges + n_real)
    v, seg, mask, offs = _random_sorted_layout(
        rng, num_edges, num_segments, dim, n_real)
    want = segment_aggregate(v, seg, num_segments, mask, "scatter")
    for impl in IMPLS[1:]:
        got = segment_aggregate(v, seg, num_segments, mask, impl,
                                offsets=offs)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5, err_msg=impl)


def test_pallas_impl_requires_offsets():
    v = jnp.zeros((8, 4))
    seg = jnp.zeros((8,), jnp.int32)
    mask = jnp.ones((8,))
    with pytest.raises(ValueError, match="offsets"):
        segment_aggregate(v, seg, 4, mask, "pallas")
    # "sorted" only needs sorted ids, not the CSR arrays
    assert segment_aggregate(v, seg, 4, mask, "sorted").shape == (4, 4)


def test_pallas_gradient_matches_scatter():
    rng = np.random.default_rng(3)
    v, seg, mask, offs = _random_sorted_layout(rng, 128, 16, 32, 100)

    def total(vv, impl):
        out = segment_aggregate(vv, seg, 16, mask, impl, offsets=offs)
        return jnp.sum(out * jnp.cos(out))

    g_ref = jax.grad(lambda vv: total(vv, "scatter"))(v)
    for impl in ("sorted", "pallas"):
        g = jax.grad(lambda vv: total(vv, impl))(v)
        np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref),
                                   rtol=1e-5, atol=1e-5, err_msg=impl)


# ---------------------------------------------------------------------------
# property-based sweep (optional dep, like the other hypothesis suites)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(
        num_segments=st.integers(1, 40),
        dim=st.integers(1, 80),
        n_real=st.integers(0, 120),
        pad=st.integers(0, 50),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_impls_agree_property(num_segments, dim, n_real, pad, seed):
        rng = np.random.default_rng(seed)
        v, seg, mask, offs = _random_sorted_layout(
            rng, n_real + pad + 1, num_segments, dim, n_real)
        want = segment_aggregate(v, seg, num_segments, mask, "scatter")
        for impl in IMPLS[1:]:
            got = segment_aggregate(v, seg, num_segments, mask, impl,
                                    offsets=offs)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=1e-5, atol=1e-5, err_msg=impl)
except ImportError:  # pragma: no cover - bare envs skip the property sweep
    pass


# ---------------------------------------------------------------------------
# end-to-end: packed crystal batches
# ---------------------------------------------------------------------------

def _crystal(rng, n):
    return Crystal(lattice=np.eye(3) * 4.4 + rng.normal(0, .05, (3, 3)),
                   frac_coords=rng.random((n, 3)),
                   atomic_numbers=rng.integers(1, 60, n))


def _packed_batch(seed=0, sizes=(5, 7, 4), pad=(8, 32, 48)):
    rng = np.random.default_rng(seed)
    cs = [_crystal(rng, n) for n in sizes]
    gs = [build_graph(c) for c in cs]
    caps = BatchCapacities(sum(sizes) + pad[0],
                           sum(g.num_bonds for g in gs) + pad[1],
                           sum(g.num_angles for g in gs) + pad[2])
    return batch_crystals(cs, gs, caps), cs, gs


def test_packed_batch_satisfies_layout():
    batch, _, _ = _packed_batch()
    validate_layout(batch)  # raises on violation


def test_validate_layout_rejects_unsorted():
    batch, _, _ = _packed_batch()
    bc = np.asarray(batch.bond_center).copy()
    n_real = int(np.asarray(batch.bond_mask).sum())
    # swap a first-crystal bond with a last-crystal bond: centers differ,
    # so the real prefix is no longer non-decreasing
    bc[0], bc[n_real - 1] = bc[n_real - 1], bc[0]
    broken = dataclasses.replace(batch, bond_center=jnp.asarray(bc))
    with pytest.raises(ValueError, match="layout"):
        validate_layout(broken)


def test_validate_layout_rejects_bad_offsets():
    batch, _, _ = _packed_batch()
    offs = np.asarray(batch.bond_offsets).copy()
    offs[1] += 1
    broken = dataclasses.replace(batch, bond_offsets=jnp.asarray(offs))
    with pytest.raises(ValueError, match="offsets"):
        validate_layout(broken)


@pytest.mark.parametrize("impl", IMPLS[1:])
def test_chgnet_apply_matches_across_agg_impls(impl):
    """Acceptance: end-to-end outputs match scatter to <= 1e-5."""
    batch, _, _ = _packed_batch()
    params = chgnet_init(jax.random.PRNGKey(0), CHGNetConfig())
    want = chgnet_apply(params, CHGNetConfig(agg_impl="scatter"), batch)
    got = chgnet_apply(params, CHGNetConfig(agg_impl=impl), batch)
    for k in want:
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(want[k]),
                                   atol=1e-5, err_msg=f"{impl}:{k}")


# ---------------------------------------------------------------------------
# force-head rotation equivariance under the sorted layout
# ---------------------------------------------------------------------------

def _random_rotation(rng):
    q, r = np.linalg.qr(rng.normal(size=(3, 3)))
    q *= np.sign(np.diag(r))
    if np.linalg.det(q) < 0:
        q[:, 0] *= -1
    return q


@pytest.mark.parametrize("impl", ["sorted", "pallas"])
def test_force_rotation_equivariance_sorted_layout(impl):
    """Eq. 8 must survive the layout refactor: F(Rx) = R F(x)."""
    rng = np.random.default_rng(7)
    c = _crystal(rng, 5)
    rot = _random_rotation(rng)
    g = build_graph(c)
    caps = BatchCapacities(8, g.num_bonds + 4, g.num_angles + 4)
    cfg = CHGNetConfig(readout="direct", agg_impl=impl)
    params = chgnet_init(jax.random.PRNGKey(0), cfg)

    f1 = np.asarray(chgnet_apply(params, cfg,
                                 batch_crystals([c], [g], caps))["forces"])
    c2 = Crystal(lattice=c.lattice @ rot.T, frac_coords=c.frac_coords,
                 atomic_numbers=c.atomic_numbers)
    g2 = build_graph(c2)
    f2 = np.asarray(chgnet_apply(params, cfg,
                                 batch_crystals([c2], [g2], caps))["forces"])
    n = c.num_atoms
    np.testing.assert_allclose(f2[:n], f1[:n] @ rot.T, atol=2e-4)
