"""End-to-end behaviour: CHGNet training converges, checkpoint/restart
under injected faults, DP parity, serve step."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.chgnet import CHGNetConfig
from repro.data import BatchIterator, SyntheticConfig, capacity_for, make_dataset
from repro.runtime import FaultInjector
from repro.train import TrainConfig, Trainer


@pytest.fixture(scope="module")
def ds():
    return make_dataset(SyntheticConfig(num_crystals=96, max_atoms=20, seed=0))


@pytest.fixture(scope="module")
def caps(ds):
    return capacity_for(ds, 8)


def _batches(ds, caps, n_epochs=50, **kw):
    def gen():
        for _ in range(n_epochs):
            yield from BatchIterator(ds, global_batch=8, num_devices=1,
                                     caps=caps, **kw)
    return gen()


def test_training_reduces_loss(ds, caps):
    """Held-out-batch loss drops substantially after 60 steps.

    (Running-loss comparisons are too noisy: the synthetic element-offset
    energies put early training in Huber's linear regime. A fixed eval
    batch with lr_k=1 — LR=2.4e-3 — shows a >2x improvement.)"""
    from repro.train.trainer import make_chgnet_step_fns

    cfg = CHGNetConfig(readout="direct")
    tcfg = TrainConfig(global_batch=8, total_steps=300, lr_k=1,
                       warmup_steps=5)
    tr = Trainer(cfg, tcfg)
    _, eval_step, _ = make_chgnet_step_fns(cfg, tcfg)
    eval_batch = next(iter(BatchIterator(ds, 8, 1, caps, seed=99)))
    before = float(eval_step(tr.params, eval_batch)["loss"])
    tr.train(itertools.islice(_batches(ds, caps), 60))
    after = float(eval_step(tr.params, eval_batch)["loss"])
    assert after < 0.6 * before, (before, after)


def test_fault_injection_restart_resumes(tmp_path, ds, caps):
    """Injected fault at step 5 -> restart resumes from the checkpoint."""
    ckpt = str(tmp_path / "ckpt")
    cfg = CHGNetConfig(readout="direct")
    tcfg = TrainConfig(global_batch=8, total_steps=100)

    def run_loop(start_step):
        tr = Trainer(cfg, tcfg, ckpt_dir=ckpt, ckpt_every=2)
        tr.maybe_restore()
        assert tr.step == start_step
        fi = FaultInjector({5}) if start_step == 0 else None
        tr.train(itertools.islice(_batches(ds, caps), 10 - tr.step),
                 fault_injector=fi)
        tr.save()
        return tr.step

    from repro.runtime import latest_step, run_with_restarts

    def resume():
        return latest_step(ckpt) or 0

    final = run_with_restarts(run_loop, resume_step_fn=resume,
                              max_restarts=2)
    assert final >= 9  # completed despite the injected fault
    assert latest_step(ckpt) is not None


def test_dp_shard_map_matches_single_device(ds, caps):
    """1-device shard_map DP step == plain step (same data, same seed)."""
    import repro.data.pipeline as pl

    cfg = CHGNetConfig(readout="direct")
    tcfg = TrainConfig(global_batch=8, total_steps=100, grad_reduce="plain")
    tr_a = Trainer(cfg, tcfg, seed=3)
    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    tr_b = Trainer(cfg, tcfg, seed=3, mesh=mesh)

    it_a = BatchIterator(ds, 8, 1, caps, seed=7)
    it_b = BatchIterator(ds, 8, 1, caps, seed=7, stack=True)
    h_a = tr_a.train(itertools.islice(iter(it_a), 3))
    h_b = tr_b.train(itertools.islice(iter(it_b), 3))
    for a, b in zip(h_a, h_b):
        assert a["loss"] == pytest.approx(b["loss"], rel=1e-4)


def test_mesh_trainer_builds_eval_and_serve_steps(ds, caps):
    """Mesh-mode Trainer must eval/serve too, through the compile cache.

    (Regression: __init__ only built _train_step in mesh mode, so eval or
    serve on a multi-device run raised AttributeError.)"""
    from jax.sharding import Mesh

    from repro.batching import CompileCache

    cfg = CHGNetConfig(readout="direct")
    tcfg = TrainConfig(global_batch=8)
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    cache = CompileCache()
    tr = Trainer(cfg, tcfg, mesh=mesh, compile_cache=cache)
    batch = next(iter(BatchIterator(ds, 8, 1, caps, stack=True)))

    metrics = tr.evaluate(batch)
    assert np.isfinite(metrics["loss"])
    out = tr.serve(batch)
    assert set(out) == {"energy", "forces", "stress", "magmom"}
    # leading device axis preserved on served outputs
    assert out["forces"].shape[0] == 1

    # plain (non-mesh) Trainer exposes the same API
    tr2 = Trainer(cfg, tcfg)
    batch2 = next(iter(BatchIterator(ds, 8, 1, caps)))
    assert np.isfinite(tr2.evaluate(batch2)["loss"])

    # a second mesh Trainer reuses all three cached step builders
    misses = cache.misses
    Trainer(cfg, tcfg, mesh=mesh, compile_cache=cache)
    assert cache.misses == misses and cache.hits >= 3


def test_serve_step_md_inference(ds, caps):
    """Table II scenario: one-step MD inference returns all properties."""
    from repro.train.trainer import make_chgnet_step_fns

    cfg = CHGNetConfig(readout="direct")
    tcfg = TrainConfig(global_batch=8)
    _, _, serve = make_chgnet_step_fns(cfg, tcfg)
    tr = Trainer(cfg, tcfg)
    batch = next(iter(BatchIterator(ds, 8, 1, caps)))
    out = serve(tr.params, batch)
    assert set(out) == {"energy", "forces", "stress", "magmom"}
    assert all(bool(jnp.all(jnp.isfinite(v))) for v in out.values())


def test_step_donation_survives_compile_cache(ds, caps):
    """params/opt_state donation must ride the compile-cache key: a second
    builder call returns the SAME jitted step (cache hit), and its lowered
    module still carries the input->output aliasing annotations."""
    from repro.batching import CompileCache
    from repro.train.trainer import make_chgnet_step_fns

    cfg = CHGNetConfig(readout="direct")
    tcfg = TrainConfig(global_batch=8)
    cache = CompileCache()
    t1, e1, s1 = make_chgnet_step_fns(cfg, tcfg, cache=cache)
    t2, e2, s2 = make_chgnet_step_fns(cfg, tcfg, cache=cache)
    assert t1 is t2 and e1 is e2 and s1 is s2  # hits, not rebuilds
    tr = Trainer(cfg, tcfg)
    batch = next(iter(BatchIterator(ds, 8, 1, caps)))
    # donated params/opt_state show up as aliased outputs in the lowering
    txt = t2.lower(tr.params, tr.opt_state, batch, jnp.asarray(0)).as_text()
    assert "tf.aliasing_output" in txt
    # the serve step donates its per-call state (the batch)
    stxt = s2.lower(tr.params, batch).as_text()
    assert "tf.aliasing_output" in stxt
    # eval donates nothing (batches are reused across evals)
    etxt = e2.lower(tr.params, batch).as_text()
    assert "tf.aliasing_output" not in etxt
    # end-to-end: stepping with donation and rebinding works
    p2, o2, m = t2(tr.params, tr.opt_state, batch, jnp.asarray(0))
    assert np.isfinite(float(m["loss"]))


def test_checkpoint_restore_trainer_roundtrip(tmp_path, ds, caps):
    ckpt = str(tmp_path / "c2")
    cfg = CHGNetConfig()
    tr = Trainer(cfg, TrainConfig(global_batch=8), ckpt_dir=ckpt,
                 ckpt_every=1)
    tr.train(itertools.islice(_batches(ds, caps), 2))
    tr.save()
    tr2 = Trainer(cfg, TrainConfig(global_batch=8), ckpt_dir=ckpt)
    assert tr2.maybe_restore()
    assert tr2.step == tr.step
    a = jax.tree.leaves(tr.params)[0]
    b = jax.tree.leaves(tr2.params)[0]
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
