"""Basis functions: envelope equivalence (paper Eq. 12 vs 13), sRBF, Fourier."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: skip, don't die, on bare envs
from hypothesis import given, settings, strategies as st

from repro.core import basis

jax.config.update("jax_platform_name", "cpu")


@given(st.lists(st.floats(0.0, 1.0, width=32), min_size=1, max_size=64),
       st.sampled_from([4, 6, 8, 12]))
@settings(max_examples=200, deadline=None)
def test_envelope_factored_equals_reference(xs, p):
    """Paper C5: Eq. 13 (factored, corrected sign) == Eq. 12 exactly."""
    xi = jnp.asarray(xs, jnp.float32)
    ref = basis.envelope_reference(xi, p)
    fac = basis.envelope_factored(xi, p)
    # f32 pow() reassociation noise scales with the O(p^2) coefficients
    # (p=12 -> ~182 * f32-eps ~ 2e-5); forms are algebraically identical
    np.testing.assert_allclose(np.asarray(ref), np.asarray(fac),
                               rtol=1e-4, atol=2e-4)


def test_envelope_smooth_cutoff():
    """u(1) = u'(1) = 0 (smooth cutoff at r_cut)."""
    for p in (6, 8):
        u = basis.envelope_factored(jnp.asarray(1.0), p)
        du = jax.grad(lambda x: basis.envelope_factored(x, p))(jnp.asarray(1.0))
        assert abs(float(u)) < 1e-5
        assert abs(float(du)) < 1e-4
    assert abs(float(basis.envelope_factored(jnp.asarray(0.0), 8)) - 1.0) < 1e-6


@pytest.mark.parametrize("n", [1, 31, 64])
def test_smooth_rbf_shapes_and_finiteness(n):
    r = jnp.linspace(0.1, 6.0, 57)
    freqs = basis.rbf_frequencies(n)
    out = basis.smooth_rbf(r, freqs, 6.0, 8)
    assert out.shape == (57, n)
    assert bool(jnp.all(jnp.isfinite(out)))
    # vanishes at the cutoff
    edge = basis.smooth_rbf(jnp.asarray([6.0]), freqs, 6.0, 8)
    assert float(jnp.abs(edge).max()) < 1e-5


def test_smooth_rbf_padded_zero_distance_safe():
    out = basis.smooth_rbf(jnp.asarray([0.0, 3.0]), basis.rbf_frequencies(8), 6.0)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_fourier_basis_values():
    th = jnp.asarray([0.3, 1.2])
    out = basis.fourier_basis(th, 31)
    assert out.shape == (2, 31)
    # DC term
    np.testing.assert_allclose(
        np.asarray(out[:, 0]), 1 / np.sqrt(2) / np.sqrt(np.pi), rtol=1e-6)
    # first cosine / sine harmonics
    np.testing.assert_allclose(
        np.asarray(out[:, 1]), np.cos(np.asarray(th)) / np.sqrt(np.pi), rtol=1e-5)
    np.testing.assert_allclose(
        np.asarray(out[:, 16]), np.sin(np.asarray(th)) / np.sqrt(np.pi), rtol=1e-5)


def test_geometry_differentiable_and_consistent():
    from repro.core import BatchCapacities, Crystal, batch_crystals, build_graph

    rng = np.random.default_rng(3)
    c = Crystal(lattice=np.eye(3) * 4.5, frac_coords=rng.random((4, 3)),
                atomic_numbers=rng.integers(1, 10, 4))
    g = build_graph(c)
    batch = batch_crystals([c], [g], BatchCapacities(8, 512, 2048))
    vec, dist, cos_t, theta = basis.compute_geometry(batch)
    # distances match numpy recomputation
    cart = c.cart_coords()
    v0 = (cart[g.bond_nbr] + g.bond_image @ c.lattice - cart[g.bond_center])
    np.testing.assert_allclose(
        np.asarray(dist[:g.num_bonds]), np.linalg.norm(v0, axis=-1), rtol=1e-4)
    # strain derivative exists
    def e(strain):
        _, d, _, _ = basis.compute_geometry(batch, strain=strain)
        return jnp.sum(d)
    gs = jax.grad(e)(jnp.zeros((1, 3, 3), jnp.float32))
    assert bool(jnp.all(jnp.isfinite(gs)))
