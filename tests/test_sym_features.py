"""Symmetric half-graph trunk (DESIGN.md §10): bond_features="undirected"
keeps bond features Eu-resident and angle features Au-resident through
every interaction block, halving the bond/angle-level GEMM row counts.

Covered here: op-level agreement of sym_bond_conv / sym_angle_update with
a directed-layout reference of the same symmetric math, tier
self-consistency (mlp x agg x conv x residency, forward + param grads),
the autodiff readout on top of the symmetric trunk, a training smoke, and
config validation.  All run on CPU via REPRO_KERNELS_INTERPRET=1.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.batching import BatchCapacities, batch_crystals
from repro.core.chgnet import CHGNetConfig, chgnet_apply, chgnet_init
from repro.core.interaction import (
    gated_mlp_apply,
    linear_apply,
    sym_angle_update,
    sym_bond_conv,
)
from repro.core.losses import LossWeights, chgnet_loss
from repro.core.neighbors import Crystal, build_graph


def _crystal(rng, n, labels=True, scale=4.0):
    kw = {}
    if labels:
        kw = dict(energy=float(rng.normal()),
                  forces=rng.normal(0, .1, (n, 3)),
                  stress=rng.normal(0, .1, (3, 3)),
                  magmoms=np.abs(rng.normal(0, 1, n)))
    return Crystal(
        lattice=np.eye(3) * scale + rng.normal(0, .05, (3, 3)),
        frac_coords=rng.random((n, 3)),
        atomic_numbers=rng.integers(1, 60, n),
        **kw,
    )


def _batch(rng, sizes=(5, 7, 4), **kw):
    cs = [_crystal(rng, n, **kw) for n in sizes]
    gs = [build_graph(c) for c in cs]
    caps = BatchCapacities(sum(sizes) + 8,
                           sum(g.num_bonds for g in gs) + 16,
                           sum(g.num_angles for g in gs) + 16)
    return batch_crystals(cs, gs, caps)


@pytest.fixture(scope="module")
def batch():
    return _batch(np.random.default_rng(0))


@pytest.fixture(scope="module")
def params():
    # parameter shapes are bond_features-independent (the symmetric trunk
    # reuses the directed MLPs verbatim — checkpoint compatible)
    return chgnet_init(jax.random.PRNGKey(0), CHGNetConfig(),
                       dtype=jnp.float32)


SYM = dict(bond_store="undirected", bond_features="undirected")


def _assert_close(got, want, atol, msg):
    scale = max(1.0, float(np.max(np.abs(np.asarray(want)))))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=atol * scale, err_msg=msg)


# ---------------------------------------------------------------------------
# op level: Eu/Au-resident compute == the same math in the directed layout
# ---------------------------------------------------------------------------

def _sym_op_inputs(batch, d=24):
    rng = np.random.default_rng(3)
    v = jnp.asarray(rng.normal(size=(batch.atom_cap, d)), jnp.float32)
    e_u = jnp.asarray(rng.normal(size=(batch.und_cap, d)), jnp.float32) \
        * batch.und_mask[:, None]
    a_u = jnp.asarray(
        rng.normal(size=(batch.und_angle_ij.shape[0], d)), jnp.float32) \
        * batch.und_angle_mask[:, None]
    e_b = jnp.asarray(rng.normal(size=(batch.und_cap, d)), jnp.float32) \
        * batch.und_mask[:, None]
    from repro.core.interaction import interaction_block_init
    p = interaction_block_init(jax.random.PRNGKey(7), d, jnp.float32)
    return p, v, e_u, a_u, e_b


def _directed_sym_message(p, batch, v, e_u, a_u, e_b):
    """The §10 message evaluated per DIRECTED angle: expand the Eu/Au
    tables through the mirror maps, feed the swap-symmetric e_s into both
    e slots."""
    e_dir = e_u[batch.bond_pair]
    eb_dir = e_b[batch.bond_pair]
    ctr = batch.bond_center[batch.angle_ij]
    e_s = e_dir[batch.angle_ij] + e_dir[batch.angle_ik]
    x = jnp.concatenate([v[ctr], e_s, e_s, a_u[batch.angle_pair]], axis=-1)
    msg = gated_mlp_apply(p["bond_mlp"], x, "packed") \
        * eb_dir[batch.angle_ij] * eb_dir[batch.angle_ik]
    return msg * batch.angle_mask[:, None]


def test_sym_bond_conv_matches_directed_layout(batch):
    """agg[u] over the sym-incidence store == the directed-angle
    aggregation of the identical swap-symmetric message, mapped through
    bond_pair — the §10 claim that the half-graph scatter loses nothing."""
    p, v, e_u, a_u, e_b = _sym_op_inputs(batch)
    got = sym_bond_conv(p, batch, v, e_u, a_u, e_b, mlp_impl="packed",
                        agg_impl="scatter", conv_impl="unfused")
    msg = _directed_sym_message(p, batch, v, e_u, a_u, e_b)
    agg = jax.ops.segment_sum(msg, batch.bond_pair[batch.angle_ij],
                              num_segments=batch.und_cap)
    want = e_u + linear_apply(p["bond_out"], agg) \
        * batch.und_mask[:, None]
    _assert_close(got, want, 1e-5, "sym_bond_conv vs directed layout")


def test_sym_angle_update_matches_directed_layout(batch):
    """Every directed angle's f_a update equals its dedup row's update —
    swap symmetry makes the two orientations agree, so the single Au row
    carries both."""
    p, v, e_u, a_u, e_b = _sym_op_inputs(batch)
    a_new = sym_angle_update(p, batch, v, e_u, a_u, mlp_impl="packed")
    e_dir = e_u[batch.bond_pair]
    ctr = batch.bond_center[batch.angle_ij]
    e_s = e_dir[batch.angle_ij] + e_dir[batch.angle_ik]
    x = jnp.concatenate([v[ctr], e_s, e_s, a_u[batch.angle_pair]], axis=-1)
    upd = gated_mlp_apply(p["angle_mlp"], x, "packed")
    want_dir = a_u[batch.angle_pair] + upd
    mask = np.asarray(batch.angle_mask) > 0
    _assert_close(np.asarray(a_new[batch.angle_pair])[mask],
                  np.asarray(want_dir)[mask], 1e-5,
                  "sym_angle_update vs directed layout")


# ---------------------------------------------------------------------------
# model level: tier self-consistency, fwd + param grads
# ---------------------------------------------------------------------------

# the §2/§3 matrix corners (same set as tests/test_bond_store.py)
TIERS = [
    ("packed", "scatter", "unfused", "auto"),
    ("ref", "sorted", "unfused", "auto"),
    ("packed", "matmul", "unfused", "auto"),
    ("pallas", "pallas", "unfused", "auto"),
    ("packed", "scatter", "fused", "vmem"),
    ("packed", "pallas", "fused", "hbm"),
]


def _base_cfg():
    return CHGNetConfig(readout="direct", **SYM)


@pytest.mark.parametrize("mlp_impl,agg_impl,conv_impl,residency", TIERS)
def test_sym_tiers_agree_forward(batch, params, mlp_impl, agg_impl,
                                 conv_impl, residency):
    want = chgnet_apply(params, _base_cfg(), batch)
    got = chgnet_apply(
        params,
        _base_cfg().with_(mlp_impl=mlp_impl, agg_impl=agg_impl,
                          conv_impl=conv_impl, table_residency=residency),
        batch)
    for k in want:
        _assert_close(got[k], want[k], 1e-5,
                      f"{k} {mlp_impl}/{agg_impl}/{conv_impl}/{residency}")


@pytest.mark.parametrize("mlp_impl,agg_impl,conv_impl,residency", TIERS)
def test_sym_tiers_agree_gradients(batch, params, mlp_impl, agg_impl,
                                   conv_impl, residency):
    def loss(p, c):
        return chgnet_loss(chgnet_apply(p, c, batch), batch,
                           LossWeights())[0]

    g_ref = jax.jit(jax.grad(loss), static_argnums=1)(params, _base_cfg())
    g_got = jax.jit(jax.grad(loss), static_argnums=1)(
        params,
        _base_cfg().with_(mlp_impl=mlp_impl, agg_impl=agg_impl,
                          conv_impl=conv_impl, table_residency=residency))
    for (path, a), (_, b) in zip(
            jax.tree_util.tree_flatten_with_path(g_ref)[0],
            jax.tree_util.tree_flatten_with_path(g_got)[0]):
        _assert_close(b, a, 1e-5,
                      f"{jax.tree_util.keystr(path)} "
                      f"{mlp_impl}/{agg_impl}/{conv_impl}/{residency}")


def test_sym_autodiff_readout_matches_direct_energy(batch, params):
    """The autodiff readout differentiates the symmetric trunk through
    the Eu geometry; its energies must match the direct tier's and its
    forces/stress must be finite."""
    direct = chgnet_apply(params, _base_cfg(), batch)
    auto = chgnet_apply(params, CHGNetConfig(readout="autodiff", **SYM),
                        batch)
    _assert_close(auto["energy"], direct["energy"], 1e-5, "energy")
    for k in ("forces", "stress"):
        assert np.all(np.isfinite(np.asarray(auto[k]))), k


def test_sym_block_variant_reference_runs(batch, params):
    out = chgnet_apply(
        params, CHGNetConfig(readout="direct", block_variant="reference",
                             **SYM), batch)
    for k, t in out.items():
        assert np.all(np.isfinite(np.asarray(t))), k


def test_sym_training_smoke(batch, params):
    cfg = CHGNetConfig(readout="direct", conv_impl="fused", **SYM)

    @jax.jit
    def step(p):
        def loss(q):
            return chgnet_loss(chgnet_apply(q, cfg, batch), batch,
                               LossWeights())[0]
        l, g = jax.value_and_grad(loss)(p)
        return l, jax.tree.map(lambda a, b: a - 1e-3 * b, p, g)

    p = params
    losses = []
    for _ in range(3):
        l, p = step(p)
        losses.append(float(l))
    assert all(np.isfinite(l) for l in losses)
    assert losses[-1] < losses[0]


# ---------------------------------------------------------------------------
# serve path: Verlet updates re-emit the dedup-angle maps end to end
# ---------------------------------------------------------------------------

def test_sym_serve_engine_end_to_end():
    """ServeEngine + BatchedMD on the symmetric trunk: every per-step
    Verlet graph re-emits valid angle_pair / und_angle_* maps (the packs
    run validate_layout, which certifies the §10 sym-incidence store
    too), and forces stay finite across MD steps."""
    from repro.serve import BatchedMD, ServeEngine

    rng = np.random.default_rng(5)
    crystals = [_crystal(rng, n, labels=False) for n in (4, 5)]
    cfg = CHGNetConfig(readout="direct", **SYM)
    params = chgnet_init(jax.random.PRNGKey(1), cfg)
    serve = ServeEngine.for_structures(params, cfg, crystals,
                                       validate_layout=True)
    md = BatchedMD(serve, crystals, dt=1e-3)
    out = md.step(3)
    assert md.steps_done == 3
    for f in out["forces"]:
        assert np.all(np.isfinite(f))
    for r in md.replicas:
        g = r.nlist.update(r.crystal)
        # update() must rebuild the dedup-angle maps the §10 trunk needs
        assert g.angle_pair is not None and g.und_angle_rep is not None
        assert 2 * g.und_angle_rep.shape[0] == g.num_angles
        ap, rep = g.angle_pair, g.und_angle_rep
        # representatives round-trip and every dedup row has both
        # directed orientations
        assert np.array_equal(ap[rep], np.arange(rep.shape[0]))
        assert np.all(np.bincount(ap, minlength=rep.shape[0]) == 2)
        # swap-closure: the partner orientation maps to the same dedup row
        order = np.lexsort((g.angle_ik, g.angle_ij))
        swap = np.lexsort((g.angle_ij, g.angle_ik))
        assert np.array_equal(ap[order], ap[swap])


# ---------------------------------------------------------------------------
# config validation
# ---------------------------------------------------------------------------

def test_bond_features_requires_undirected_store():
    with pytest.raises(ValueError, match="bond_store"):
        CHGNetConfig(bond_features="undirected")


def test_bond_features_rejects_unknown_value():
    with pytest.raises(ValueError, match="bond_features"):
        CHGNetConfig(bond_features="half", bond_store="undirected")
