"""Load-balance sampler (paper C6, Fig. 9) + data pipeline."""
import numpy as np
import pytest

from repro.data import (
    BatchIterator, DefaultSampler, LoadBalanceSampler, Prefetcher,
    SyntheticConfig, capacity_for, cov_of_device_loads, device_loads,
    make_dataset,
)


@pytest.fixture(scope="module")
def ds():
    return make_dataset(SyntheticConfig(num_crystals=128, max_atoms=48, seed=0))


def test_long_tail_distribution(ds):
    counts = ds.feature_counts()
    # long tail (Fig. 5): max >> median
    assert counts.max() > 3 * np.median(counts)


def test_cov_reduction_matches_paper(ds):
    """Paper Fig. 9: CoV 0.186 -> 0.064 (batch 32, 4 devices)."""
    counts = ds.feature_counts()
    cov_d, cov_lb = [], []
    for (_, sd), (_, slb) in zip(
        DefaultSampler(counts, 0).epoch(32, 4),
        LoadBalanceSampler(counts, 0).epoch(32, 4),
    ):
        cov_d.append(cov_of_device_loads(device_loads(counts, sd)))
        cov_lb.append(cov_of_device_loads(device_loads(counts, slb)))
    assert np.mean(cov_lb) < 0.5 * np.mean(cov_d), (
        f"balanced CoV {np.mean(cov_lb):.3f} vs default {np.mean(cov_d):.3f}")
    assert np.mean(cov_lb) < 0.12  # paper reports 0.064


def test_sampler_partitions_batch_exactly(ds):
    counts = ds.feature_counts()
    lb = LoadBalanceSampler(counts, 1)
    for idx, shards in lb.epoch(32, 4):
        got = np.sort(np.concatenate(shards))
        np.testing.assert_array_equal(got, np.sort(idx))
        assert all(len(s) == 8 for s in shards)
        break


@pytest.mark.parametrize("sampler_cls", [DefaultSampler, LoadBalanceSampler])
def test_drop_last_flag(ds, sampler_cls):
    """drop_last=False yields the tail partial batch; True (default) drops it."""
    counts = ds.feature_counts()[:10]  # n=10: 3 full batches of 3 + tail 1
    sampler = sampler_cls(counts, seed=0)

    dropped = list(sampler.epoch(3, 1))
    assert [len(idx) for idx, _ in dropped] == [3, 3, 3]

    kept = list(sampler_cls(counts, seed=0).epoch(3, 1, drop_last=False))
    assert [len(idx) for idx, _ in kept] == [3, 3, 3, 1]
    seen = np.sort(np.concatenate([idx for idx, _ in kept]))
    np.testing.assert_array_equal(seen, np.arange(10))  # nothing dropped
    for idx, shards in kept:
        np.testing.assert_array_equal(np.sort(np.concatenate(shards)),
                                      np.sort(idx))

    # a tail smaller than num_devices still can't be dealt to every device
    tail_2dev = list(sampler_cls(counts, seed=0).epoch(3, 3, drop_last=False))
    assert [len(idx) for idx, _ in tail_2dev] == [3, 3, 3]


def test_batch_iterator_drop_last(ds):
    """BatchIterator passes drop_last through; shards still stack."""
    counts_n = 10
    sub = type(ds)(crystals=ds.crystals[:counts_n],
                   graphs=ds.graphs[:counts_n], cfg=ds.cfg)
    caps = capacity_for(sub, per_device_batch=4)
    batches = list(BatchIterator(sub, global_batch=4, num_devices=2,
                                 caps=caps, drop_last=False))
    assert len(batches) == 3  # 4 + 4 + tail 2
    tail = batches[-1]
    assert tail.atom_z.shape[0] == 2  # stacked per-device leaves
    assert float(tail.crystal_mask.sum()) == 2.0  # one real crystal per shard


def test_capacity_and_batches(ds):
    caps = capacity_for(ds, per_device_batch=8)
    it = BatchIterator(ds, global_batch=16, num_devices=2, caps=caps)
    n = 0
    for batch in it:
        # stacked leading device axis
        assert batch.atom_z.shape[0] == 2
        assert float(batch.atom_mask.sum()) > 0
        n += 1
        if n >= 2:
            break
    assert n == 2


def test_prefetcher_yields_everything():
    items = list(range(7))
    got = list(Prefetcher(iter(items), depth=2))
    assert got == items


def test_prefetcher_propagates_all_despite_slow_consumer():
    import time

    def gen():
        for i in range(5):
            yield i

    pf = Prefetcher(gen(), depth=1)
    out = []
    for x in pf:
        time.sleep(0.01)
        out.append(x)
    assert out == [0, 1, 2, 3, 4]
