"""Load-balance sampler (paper C6, Fig. 9) + data pipeline."""
import numpy as np
import pytest

from repro.data import (
    BatchIterator, DefaultSampler, LoadBalanceSampler, Prefetcher,
    SyntheticConfig, capacity_for, cov_of_device_loads, device_loads,
    make_dataset,
)


@pytest.fixture(scope="module")
def ds():
    return make_dataset(SyntheticConfig(num_crystals=128, max_atoms=48, seed=0))


def test_long_tail_distribution(ds):
    counts = ds.feature_counts()
    # long tail (Fig. 5): max >> median
    assert counts.max() > 3 * np.median(counts)


def test_cov_reduction_matches_paper(ds):
    """Paper Fig. 9: CoV 0.186 -> 0.064 (batch 32, 4 devices)."""
    counts = ds.feature_counts()
    cov_d, cov_lb = [], []
    for (_, sd), (_, slb) in zip(
        DefaultSampler(counts, 0).epoch(32, 4),
        LoadBalanceSampler(counts, 0).epoch(32, 4),
    ):
        cov_d.append(cov_of_device_loads(device_loads(counts, sd)))
        cov_lb.append(cov_of_device_loads(device_loads(counts, slb)))
    assert np.mean(cov_lb) < 0.5 * np.mean(cov_d), (
        f"balanced CoV {np.mean(cov_lb):.3f} vs default {np.mean(cov_d):.3f}")
    assert np.mean(cov_lb) < 0.12  # paper reports 0.064


def test_sampler_partitions_batch_exactly(ds):
    counts = ds.feature_counts()
    lb = LoadBalanceSampler(counts, 1)
    for idx, shards in lb.epoch(32, 4):
        got = np.sort(np.concatenate(shards))
        np.testing.assert_array_equal(got, np.sort(idx))
        assert all(len(s) == 8 for s in shards)
        break


def test_capacity_and_batches(ds):
    caps = capacity_for(ds, per_device_batch=8)
    it = BatchIterator(ds, global_batch=16, num_devices=2, caps=caps)
    n = 0
    for batch in it:
        # stacked leading device axis
        assert batch.atom_z.shape[0] == 2
        assert float(batch.atom_mask.sum()) > 0
        n += 1
        if n >= 2:
            break
    assert n == 2


def test_prefetcher_yields_everything():
    items = list(range(7))
    got = list(Prefetcher(iter(items), depth=2))
    assert got == items


def test_prefetcher_propagates_all_despite_slow_consumer():
    import time

    def gen():
        for i in range(5):
            yield i

    pf = Prefetcher(gen(), depth=1)
    out = []
    for x in pf:
        time.sleep(0.01)
        out.append(x)
    assert out == [0, 1, 2, 3, 4]
