"""HBM-resident operand tables (DESIGN.md §9).

Covers the ``table_residency`` tier end to end:

  - vmem/hbm numerical parity (forward AND param-grads, ≤1e-5) over a
    pairwise-covering sweep of the mlp × agg × conv × bond_store tiers —
    every axis value is exercised against both conv tiers and both bond
    stores, so every residency-sensitive kernel path (fused_segment_sum,
    fused_atom_conv / fused_bond_conv, both force readouts, plus the
    trivially-residency-free pure-jnp tiers) is compared under both
    residencies in interpret mode;
  - the auto-selection heuristic (``_resolve_residency`` against the
    ``REPRO_VMEM_BUDGET_MB`` budget) and the table-size estimator;
  - training end to end with operand tables over the VMEM budget
    (tiny budget forces ``"auto"`` -> streaming);
  - the headline unlock: a 10k-atom synthetic crystal packs, runs
    forward + param-grad under ``table_residency="hbm"`` matching the
    unfused reference, and ``ServeEngine`` ADMITS it instead of raising
    (admission only refuses under an explicit over-budget "vmem" tier).

All run on CPU via REPRO_KERNELS_INTERPRET=1.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.batching import BatchCapacities, batch_crystals
from repro.core.chgnet import CHGNetConfig, chgnet_apply, chgnet_init
from repro.core.neighbors import Crystal, GraphIndices, build_graph
from repro.kernels.ops import (
    estimate_table_bytes,
    resident_vmem_estimate,
    vmem_budget_bytes,
)


def _crystal(rng, n, scale=3.4):
    return Crystal(
        lattice=np.eye(3) * scale + rng.normal(0, .05, (3, 3)),
        frac_coords=rng.random((n, 3)),
        atomic_numbers=rng.integers(1, 60, n),
    )


@pytest.fixture(scope="module")
def batch():
    rng = np.random.default_rng(0)
    cs = [_crystal(rng, 3), _crystal(rng, 4)]
    gs = [build_graph(c) for c in cs]
    caps = BatchCapacities(sum(c.num_atoms for c in cs) + 4,
                           sum(g.num_bonds for g in gs) + 8,
                           sum(g.num_angles for g in gs) + 8)
    return batch_crystals(cs, gs, caps)


def _cfg(mlp, agg, conv, store, residency, **kw):
    return CHGNetConfig(dim=16, num_blocks=1, readout="direct",
                        mlp_impl=mlp, agg_impl=agg, conv_impl=conv,
                        bond_store=store, table_residency=residency, **kw)


def _fwd_grad(cfg, params, batch):
    def loss(p):
        out = chgnet_apply(p, cfg, batch)
        return out["energy"].sum() + out["forces"].sum(), out

    (val, out), grads = jax.value_and_grad(loss, has_aux=True)(params)
    return out, grads


# pairwise-covering sweep: every mlp value and every agg value meet both
# conv tiers, and both bond stores meet both conv tiers (full product at
# model level is minutes of interpret-mode tracing for zero extra kernel
# coverage — mlp/agg only interact with residency through the pallas
# tiers, which appear on both sides below)
TIERS = [
    ("ref", "scatter", "unfused", "directed"),
    ("packed", "matmul", "unfused", "undirected"),
    ("pallas", "sorted", "unfused", "directed"),
    ("ref", "pallas", "unfused", "undirected"),
    ("pallas", "pallas", "fused", "directed"),
    ("packed", "scatter", "fused", "undirected"),
    ("ref", "matmul", "fused", "directed"),
    ("pallas", "sorted", "fused", "undirected"),
]


@pytest.mark.parametrize("mlp,agg,conv,store", TIERS)
def test_hbm_matches_vmem_fwd_and_grads(batch, mlp, agg, conv, store):
    """hbm == vmem ≤1e-5 on forward outputs AND every param-grad leaf."""
    cfg_v = _cfg(mlp, agg, conv, store, "vmem")
    cfg_h = cfg_v.with_(table_residency="hbm")
    params = chgnet_init(jax.random.PRNGKey(0), cfg_v, dtype=jnp.float32)
    out_v, g_v = _fwd_grad(cfg_v, params, batch)
    out_h, g_h = _fwd_grad(cfg_h, params, batch)
    for k in ("energy", "forces", "stress", "magmom"):
        np.testing.assert_allclose(out_h[k], out_v[k], atol=1e-5, rtol=0,
                                   err_msg=k)
    leaves_v, tree = jax.tree.flatten(g_v)
    leaves_h, _ = jax.tree.flatten(g_h)
    for lv, lh in zip(leaves_v, leaves_h):
        np.testing.assert_allclose(lh, lv, atol=1e-5, rtol=0)


def test_estimator_and_auto_selection(monkeypatch):
    """auto == vmem when tables fit, hbm when they exceed the budget."""
    from repro.kernels.ops import _resolve_residency

    tb = estimate_table_bytes(64, 512, 1024, 64)
    # deterministic closed form: the max resident working set is a small
    # multiple of the largest per-table row block; the exact value is an
    # implementation detail, but it must scale with the inputs and be
    # positive
    assert tb > 0
    assert estimate_table_bytes(64, 4096, 8192, 64) > tb
    assert estimate_table_bytes(64, 512, 1024, 256) > tb
    assert _resolve_residency("auto", vmem_budget_bytes() + 1) == "hbm"
    assert _resolve_residency("auto", vmem_budget_bytes()) == "vmem"
    assert _resolve_residency("vmem", 10**12) == "vmem"
    assert _resolve_residency("hbm", 1) == "hbm"
    with pytest.raises(ValueError):
        _resolve_residency("dram", 1)
    # env override (what tests/CI use to force streaming)
    monkeypatch.setenv("REPRO_VMEM_BUDGET_MB", "1")
    assert vmem_budget_bytes() == 1 << 20
    # the hbm tier's resident estimate must undercut vmem's once tables
    # dominate (this is the bench_iteration enforced bar, kept honest here)
    big = dict(num_atoms=4096, num_bonds=65536, num_angles=131072, dim=64)
    assert (resident_vmem_estimate("hbm", **big)
            < resident_vmem_estimate("vmem", **big))


def test_trains_over_budget_tables(batch, monkeypatch):
    """End-to-end train step with operand tables exceeding the budget.

    A 1 KiB budget makes ANY batch over-budget; ``"auto"`` must resolve
    to streaming and the step must still produce finite loss and grads.
    """
    from repro.train import TrainConfig
    from repro.train.trainer import make_chgnet_step_fns
    from repro.train.trainer import Trainer

    monkeypatch.setenv("REPRO_VMEM_BUDGET_MB", "0.001")
    cfg = _cfg("pallas", "pallas", "fused", "undirected", "auto")
    assert estimate_table_bytes(
        batch.atom_cap, batch.bond_cap, batch.angle_cap, cfg.dim,
        num_und=batch.und_cap) > vmem_budget_bytes()
    tr = Trainer(cfg, TrainConfig(global_batch=2, total_steps=10))
    params, opt_state, metrics = tr._train_step(
        tr.params, tr.opt_state, batch, 0)
    assert np.isfinite(float(metrics["loss"]))
    # donated params were consumed; the returned tree is the live one
    leaf = jax.tree.leaves(params)[0]
    assert np.all(np.isfinite(np.asarray(leaf)))


# ---------------------------------------------------------------------------
# the unlock: 10k-atom structures pack, train and serve
# ---------------------------------------------------------------------------

def _ring_structure(n, spacing=2.0):
    """Hand-built n-atom ring chain (build_graph is O(N^2 * images)).

    Atom i bonds to i±1 (periodic along x), spacing < r_cut_bond so both
    bonds are "short" -> 2 bonds and 2 ordered angle pairs per center;
    bond/angle lists are emitted center-sorted (DESIGN.md §1) and mirror
    maps are left None — packing repairs them (bond AND angle-pair).
    """
    lat = np.diag([n * spacing, 8.0, 8.0])
    frac = np.zeros((n, 3))
    frac[:, 0] = np.arange(n) / n
    frac[:, 1:] = 0.5
    z = (np.arange(n) % 60) + 1
    crystal = Crystal(lattice=lat, frac_coords=frac,
                      atomic_numbers=z.astype(np.int64))
    bc, bn, im = [], [], []
    for i in range(n):
        jm, jp = (i - 1) % n, (i + 1) % n
        bc += [i, i]
        bn += [jm, jp]
        im += [[-1, 0, 0] if i == 0 else [0, 0, 0],
               [1, 0, 0] if i == n - 1 else [0, 0, 0]]
    a_ij, a_ik = [], []
    for i in range(n):
        a_ij += [2 * i, 2 * i + 1]
        a_ik += [2 * i + 1, 2 * i]
    graph = GraphIndices(np.asarray(bc, np.int32), np.asarray(bn, np.int32),
                         np.asarray(im, np.int32),
                         np.asarray(a_ij, np.int32),
                         np.asarray(a_ik, np.int32))
    return crystal, graph


@pytest.fixture(scope="module")
def giant():
    return _ring_structure(10_000)


def test_10k_atoms_pack_forward_grad_hbm(giant):
    """10k-atom crystal packs and fwd+grads under hbm ≈ unfused reference."""
    crystal, graph = giant
    caps = BatchCapacities(crystal.num_atoms + 16, graph.num_bonds + 16,
                           graph.num_angles + 16)
    batch = batch_crystals([crystal], [graph], caps)
    # tables genuinely exceed the default VMEM budget at production dim
    assert estimate_table_bytes(caps.atoms, caps.bonds, caps.angles,
                                64) > vmem_budget_bytes()
    cfg = _cfg("pallas", "pallas", "fused", "directed", "hbm")
    params = chgnet_init(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    out_h, g_h = _fwd_grad(cfg, params, batch)
    cfg_ref = cfg.with_(mlp_impl="ref", agg_impl="scatter",
                        conv_impl="unfused", table_residency="vmem")
    out_r, g_r = _fwd_grad(cfg_ref, params, batch)
    np.testing.assert_allclose(out_h["forces"], out_r["forces"],
                               atol=1e-5, rtol=0)
    # energy is a 10k-atom sum — compare per-atom
    e_h = float(out_h["energy"][0]) / crystal.num_atoms
    e_r = float(out_r["energy"][0]) / crystal.num_atoms
    assert abs(e_h - e_r) <= 1e-5, (e_h, e_r)
    for lh, lr in zip(jax.tree.leaves(g_h), jax.tree.leaves(g_r)):
        np.testing.assert_allclose(
            np.asarray(lh) / crystal.num_atoms,
            np.asarray(lr) / crystal.num_atoms, atol=1e-5, rtol=0)


def test_10k_atoms_serve_admission(giant, monkeypatch):
    """ServeEngine admits the 10k-atom structure; only an explicit
    over-budget vmem tier refuses (early, with an actionable error)."""
    from repro.serve.engine import ServeEngine

    crystal, graph = giant
    monkeypatch.setenv("REPRO_VMEM_BUDGET_MB", "1")
    cfg = _cfg("pallas", "pallas", "fused", "directed", "auto")
    params = chgnet_init(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    eng = ServeEngine.for_structures(params, cfg, [crystal], graphs=[graph])
    caps = eng.engine.ladder.bucket_for(
        crystal.num_atoms, graph.num_bonds, graph.num_angles)
    assert caps.fits(crystal.num_atoms, graph.num_bonds, graph.num_angles)
    # "auto" (and "hbm") admit any capacity — tables stream from HBM
    eng.admission_check(caps)
    eng_hbm = ServeEngine.for_structures(
        params, cfg.with_(table_residency="hbm"), [crystal], graphs=[graph])
    eng_hbm.admission_check(caps)
    # the pinned vmem tier refuses at admission (NOT deep in lowering)
    eng_vmem = ServeEngine.for_structures(
        params, cfg.with_(table_residency="vmem"), [crystal], graphs=[graph])
    with pytest.raises(ValueError, match="table_residency"):
        eng_vmem.predict([crystal], graphs=[graph])


def test_streamed_gather_oracle_matches_take():
    """The §9 windowed-one-hot table walk == whole-array gather, for any
    tile that divides the table rows."""
    from repro.kernels.ref import streamed_gather_ref

    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.normal(size=(512, 128)).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, 512, size=200).astype(np.int32))
    want = np.asarray(table)[np.asarray(ids)]
    for tile in (64, 128, 512):
        got = streamed_gather_ref(ids, table, tile)
        np.testing.assert_array_equal(np.asarray(got), want)


def test_small_structures_unaffected_by_admission(monkeypatch):
    """Zero regression on CI-small shapes: vmem tier still serves batches
    whose tables fit the budget."""
    from repro.serve.engine import ServeEngine

    rng = np.random.default_rng(3)
    cs = [_crystal(rng, 4), _crystal(rng, 5)]
    cfg = CHGNetConfig(dim=16, num_blocks=1, readout="direct",
                       table_residency="vmem")
    params = chgnet_init(jax.random.PRNGKey(1), cfg, dtype=jnp.float32)
    eng = ServeEngine.for_structures(params, cfg, cs)
    out = eng.predict(cs)
    assert np.all(np.isfinite(out["energy"]))
    assert out["forces"][0].shape == (4, 3)
