"""Per-bond virial stress tier (DESIGN.md §7): fused kernel vs oracle,
fused-vs-unfused model equivalence across implementation tiers, physics
(rotation covariance, translation invariance, exact-virial recovery on
the analytic pair-potential labels), and the single-launch guarantee.
All run on CPU via REPRO_KERNELS_INTERPRET=1."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.batching import BatchCapacities, batch_crystals
from repro.core import basis, heads
from repro.core.chgnet import CHGNetConfig, chgnet_apply, chgnet_init
from repro.core.interaction import segment_aggregate
from repro.core.losses import LossWeights, chgnet_loss
from repro.core.neighbors import Crystal, build_graph
from repro.kernels import ops, ref


# ---------------------------------------------------------------------------
# op level: fused force+virial kernel vs oracle on raw sorted layouts
# ---------------------------------------------------------------------------

def _virial_op_inputs(rng, a, b_crys, e_rows, d, n_real):
    ids = np.sort(rng.integers(0, a, n_real)).astype(np.int32)
    seg = np.zeros(e_rows, np.int32)
    seg[:n_real] = ids
    offs = np.searchsorted(ids, np.arange(a + 1)).astype(np.int32)
    cry = np.zeros(e_rows, np.int32)
    cry[:n_real] = rng.integers(0, b_crys, n_real)
    e = jnp.asarray(rng.normal(0, 1, (e_rows, d)), jnp.float32)
    xh = rng.normal(0, 1, (e_rows, 3)).astype(np.float32)
    xh /= np.maximum(np.linalg.norm(xh, axis=1, keepdims=True), 1e-6)
    dist = jnp.asarray(rng.uniform(0.5, 4.0, e_rows), jnp.float32)
    w1 = jnp.asarray(rng.normal(0, .1, (d, d)), jnp.float32)
    b1 = jnp.asarray(rng.normal(0, .1, (d,)), jnp.float32)
    w2 = jnp.asarray(rng.normal(0, .1, (d, 1)), jnp.float32)
    b2 = jnp.asarray(rng.normal(0, .1, (1,)), jnp.float32)
    return (e, jnp.asarray(xh), dist, w1, b1, w2, b2,
            jnp.asarray(seg), jnp.asarray(cry), jnp.asarray(offs), a, b_crys)


@pytest.mark.parametrize("a,b_crys,e_rows,n_real", [
    (16, 4, 300, 260),   # padded tail
    (9, 3, 64, 64),      # no padding, unaligned rows
    (8, 2, 32, 0),       # all edges padded
    (14, 1, 180, 150),   # single crystal
])
def test_fused_force_virial_matches_oracle(a, b_crys, e_rows, n_real):
    rng = np.random.default_rng(a + n_real)
    args = _virial_op_inputs(rng, a, b_crys, e_rows, 32, n_real)
    f_k, s_k = ops.fused_force_virial_readout(*args)
    f_r, s_r = ref.fused_force_virial_readout_ref(*args)
    np.testing.assert_allclose(np.asarray(f_k), np.asarray(f_r),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r),
                               rtol=1e-5, atol=1e-5)
    # the stress output is symmetric by construction (x_hat ⊗ x_hat)
    np.testing.assert_allclose(np.asarray(s_k),
                               np.transpose(np.asarray(s_k), (0, 2, 1)),
                               atol=1e-6)


def test_fused_force_virial_gradients_match_oracle():
    """Dual-cotangent backward: grads w.r.t. every differentiable operand
    (e, x_hat, dist, all four MLP params) through BOTH outputs."""
    rng = np.random.default_rng(11)
    e, xh, dist, w1, b1, w2, b2, seg, cry, offs, a, b_crys = \
        _virial_op_inputs(rng, 12, 3, 160, 32, 130)
    cot_f = jnp.asarray(rng.normal(0, 1, (a, 3)), jnp.float32)
    cot_s = jnp.asarray(rng.normal(0, 1, (b_crys, 3, 3)), jnp.float32)

    def loss(fn, e_, xh_, d_, w1_, b1_, w2_, b2_):
        f, s = fn(e_, xh_, d_, w1_, b1_, w2_, b2_, seg, cry, offs, a, b_crys)
        return jnp.vdot(f, cot_f) + jnp.vdot(s, cot_s)

    argnums = tuple(range(1, 8))
    g_k = jax.grad(loss, argnums=argnums)(
        ops.fused_force_virial_readout, e, xh, dist, w1, b1, w2, b2)
    g_r = jax.grad(loss, argnums=argnums)(
        ref.fused_force_virial_readout_ref, e, xh, dist, w1, b1, w2, b2)
    for got, want in zip(g_k, g_r):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


try:
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=10, deadline=None)
    @given(
        num_atoms=st.integers(1, 24),
        num_crystals=st.integers(1, 6),
        n_real=st.integers(0, 90),
        pad=st.integers(0, 40),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_fused_force_virial_ragged_property(num_atoms, num_crystals,
                                                n_real, pad, seed):
        rng = np.random.default_rng(seed)
        args = _virial_op_inputs(rng, num_atoms, num_crystals,
                                 n_real + pad + 1, 16, n_real)
        f_k, s_k = ops.fused_force_virial_readout(*args)
        f_r, s_r = ref.fused_force_virial_readout_ref(*args)
        np.testing.assert_allclose(np.asarray(f_k), np.asarray(f_r),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r),
                                   rtol=1e-5, atol=1e-5)
except ImportError:  # pragma: no cover - bare envs skip the property sweep
    pass


# ---------------------------------------------------------------------------
# model level: stress_mode="bond_virial" across implementation tiers
# ---------------------------------------------------------------------------

def _crystal(rng, n, **labels):
    return Crystal(lattice=np.eye(3) * 4.4 + rng.normal(0, .05, (3, 3)),
                   frac_coords=rng.random((n, 3)),
                   atomic_numbers=rng.integers(1, 60, n), **labels)


def _packed_batch(seed=0, sizes=(5, 7, 4), pad=(8, 32, 48)):
    rng = np.random.default_rng(seed)
    cs = [_crystal(rng, n, energy=float(rng.normal()),
                   forces=rng.normal(0, .1, (n, 3)),
                   stress=rng.normal(0, .1, (3, 3)),
                   magmoms=np.abs(rng.normal(0, 1, n))) for n in sizes]
    gs = [build_graph(c) for c in cs]
    caps = BatchCapacities(sum(sizes) + pad[0],
                           sum(g.num_bonds for g in gs) + pad[1],
                           sum(g.num_angles for g in gs) + pad[2])
    return batch_crystals(cs, gs, caps)


BASE = CHGNetConfig(stress_mode="bond_virial")

TIERS = [
    dict(conv_impl="fused"),
    dict(conv_impl="fused", agg_impl="pallas"),
    dict(conv_impl="unfused", agg_impl="sorted"),
    dict(conv_impl="unfused", agg_impl="matmul"),
    dict(conv_impl="unfused", bond_store="undirected"),
    dict(conv_impl="fused", bond_store="undirected", agg_impl="pallas"),
]


@pytest.mark.parametrize("tier", TIERS,
                         ids=lambda t: "-".join(f"{k}={v}"
                                                for k, v in t.items()))
def test_bond_virial_tiers_match_reference_forward(tier):
    """Acceptance: every agg/conv/bond_store tier of the bond-virial path
    matches the scatter-aggregated directed reference <= 1e-5."""
    batch = _packed_batch()
    params = chgnet_init(jax.random.PRNGKey(0), BASE)
    want = chgnet_apply(params, BASE, batch)
    got = chgnet_apply(params, BASE.with_(**tier), batch)
    for k in want:
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(want[k]),
                                   atol=1e-5, err_msg=f"{tier}/{k}")


@pytest.mark.parametrize("tier", [
    dict(conv_impl="fused"),
    dict(conv_impl="unfused", bond_store="undirected"),
])
def test_bond_virial_param_gradients_match_reference(tier):
    """Acceptance: training gradients through the fused dual-output custom
    VJP (and the undirected half-geometry path) match autodiff through the
    unfused directed graph <= 1e-5."""
    batch = _packed_batch()
    params = chgnet_init(jax.random.PRNGKey(0), BASE)

    def loss(p, cfg):
        pred = chgnet_apply(p, cfg, batch)
        return chgnet_loss(pred, batch, LossWeights())[0]

    g_ref = jax.grad(loss)(params, BASE)
    g_got = jax.grad(loss)(params, BASE.with_(**tier))
    for path, got, want in zip(
            jax.tree_util.tree_flatten_with_path(g_got)[0],
            jax.tree.leaves(g_got), jax.tree.leaves(g_ref)):
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5,
            err_msg=f"{tier}/{jax.tree_util.keystr(path[0])}")


def test_bond_virial_has_no_stress_params():
    params = chgnet_init(jax.random.PRNGKey(0), BASE)
    assert "stress_head" not in params
    assert "stress_head" in chgnet_init(jax.random.PRNGKey(0),
                                        BASE.with_(stress_mode="mlp"))


def test_bond_virial_single_kernel_launch():
    """Acceptance: stress_mode="bond_virial" + conv_impl="fused" adds ZERO
    kernel launches over the mlp stress tier — the virial rides the force
    readout's epilogue, so the jaxpr pallas_call count is identical."""
    batch = _packed_batch()
    fused_mlp = BASE.with_(conv_impl="fused", stress_mode="mlp")
    fused_vir = BASE.with_(conv_impl="fused")

    def count(cfg):
        params = chgnet_init(jax.random.PRNGKey(0), cfg)
        jaxpr = jax.make_jaxpr(
            lambda p, b: chgnet_apply(p, cfg, b))(params, batch)
        return str(jaxpr).count("pallas_call")

    n_mlp, n_vir = count(fused_mlp), count(fused_vir)
    assert n_vir > 0, "fused path must lower to pallas_call"
    assert n_vir == n_mlp, (n_vir, n_mlp)


# ---------------------------------------------------------------------------
# physics: covariance, invariance, exact-virial recovery
# ---------------------------------------------------------------------------

def _random_rotation(rng) -> np.ndarray:
    a = rng.normal(size=(3, 3))
    q, r = np.linalg.qr(a)
    q *= np.sign(np.diag(r))
    if np.linalg.det(q) < 0:
        q[:, 0] *= -1
    return q


def _single_batch(c):
    g = build_graph(c)
    caps = BatchCapacities(c.num_atoms + 3, g.num_bonds + 4,
                           g.num_angles + 4)
    return batch_crystals([c], [g], caps), g


@pytest.mark.parametrize("seed", [0, 1])
def test_bond_virial_rotation_covariance(seed):
    """sigma(R x) = R sigma(x) R^T — exact for the per-bond virial because
    n_ij is a rotation-invariant scalar and x_hat rotates with the frame."""
    rng = np.random.default_rng(seed)
    c = _crystal(rng, 6)
    rot = _random_rotation(rng)
    cfg = BASE
    params = chgnet_init(jax.random.PRNGKey(0), cfg)
    batch, g = _single_batch(c)
    s1 = np.asarray(chgnet_apply(params, cfg, batch)["stress"])[0]
    c2 = Crystal(lattice=c.lattice @ rot.T, frac_coords=c.frac_coords,
                 atomic_numbers=c.atomic_numbers)
    batch2, g2 = _single_batch(c2)
    assert g2.num_bonds == g.num_bonds  # rotation preserves topology
    s2 = np.asarray(chgnet_apply(params, cfg, batch2)["stress"])[0]
    # cart' = cart @ rot.T (row vectors) -> column-form sigma' = R sigma R^T
    np.testing.assert_allclose(s2, rot @ s1 @ rot.T, atol=2e-4)


def test_bond_virial_translation_invariance():
    """Rigid translation (with PBC wrap) leaves the stress unchanged."""
    rng = np.random.default_rng(3)
    c = _crystal(rng, 6)
    cfg = BASE
    params = chgnet_init(jax.random.PRNGKey(0), cfg)
    batch, g = _single_batch(c)
    s1 = np.asarray(chgnet_apply(params, cfg, batch)["stress"])[0]
    c2 = Crystal(lattice=c.lattice,
                 frac_coords=(c.frac_coords + 0.23) % 1.0,
                 atomic_numbers=c.atomic_numbers)
    batch2, g2 = _single_batch(c2)
    assert g2.num_bonds == g.num_bonds
    s2 = np.asarray(chgnet_apply(params, cfg, batch2)["stress"])[0]
    np.testing.assert_allclose(s2, s1, atol=2e-4)


def test_exact_virial_recovery_on_synthetic_labels():
    """With n_ij = phi'(d_ij), the bond-virial formula reproduces the
    analytic stress labels of the pair-potential fixture exactly — the
    sign/scale convention check for the whole tier."""
    from repro.data.synthetic import SyntheticConfig, _morse_dr, make_dataset

    ds = make_dataset(SyntheticConfig(num_crystals=3, max_atoms=12, seed=0))
    gs = ds.graphs
    caps = BatchCapacities(sum(c.num_atoms for c in ds.crystals) + 4,
                           sum(g.num_bonds for g in gs) + 8,
                           sum(g.num_angles for g in gs) + 8)
    batch = batch_crystals(ds.crystals, gs, caps)
    vec, dist, _cos, _theta = basis.compute_geometry(batch)
    # ideal per-bond scalar: the analytic pair force magnitude phi'(d)
    n_ij = jnp.asarray(_morse_dr(np.asarray(dist, np.float64)), jnp.float32)
    x_hat = heads.bond_unit_vectors(vec, dist)
    w = n_ij * dist * batch.bond_mask
    outer = (x_hat[:, :, None] * x_hat[:, None, :]).reshape(-1, 9)
    raw = segment_aggregate(w[:, None] * outer, batch.bond_crystal,
                            batch.num_crystals, batch.bond_mask, "scatter")
    sigma = np.asarray(heads._virial_raw_to_gpa(
        raw.reshape(-1, 3, 3), batch))
    want = np.asarray(batch.stress)
    np.testing.assert_allclose(sigma, want, rtol=1e-3, atol=1e-4)


def test_virial_raw_to_gpa_masks_padded_crystals():
    batch = _packed_batch()
    raw = jnp.ones((batch.num_crystals, 3, 3), jnp.float32)
    out = np.asarray(heads._virial_raw_to_gpa(raw, batch))
    mask = np.asarray(batch.crystal_mask)
    assert np.all(out[mask == 0] == 0)
    assert np.all(np.isfinite(out))
