"""Paper §III-B claims: Force-head rotation equivariance (Eq. 8), energy
rotation invariance, and synthetic-label physical consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # optional dep: skip only the property sweeps, not the whole module
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on bare envs
    HAS_HYPOTHESIS = False

    def given(*a, **k):  # no-op decorators so the module still imports
        return lambda fn: fn

    def settings(*a, **k):
        return lambda fn: fn

    class _StubStrategies:  # st.foo(...) evaluates inside @given at import
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StubStrategies()
needs_hypothesis = pytest.mark.skipif(not HAS_HYPOTHESIS,
                                      reason="hypothesis not installed")

from repro.core import BatchCapacities, Crystal, batch_crystals, build_graph, chgnet_apply, chgnet_init
from repro.core.chgnet import CHGNetConfig


def random_rotation(rng) -> np.ndarray:
    a = rng.normal(size=(3, 3))
    q, r = np.linalg.qr(a)
    q *= np.sign(np.diag(r))
    if np.linalg.det(q) < 0:
        q[:, 0] *= -1
    return q


def _crystal(rng, n=5):
    return Crystal(lattice=np.eye(3) * 4.4 + rng.normal(0, .05, (3, 3)),
                   frac_coords=rng.random((n, 3)),
                   atomic_numbers=rng.integers(1, 60, n))


def _rotate(c: Crystal, rot: np.ndarray) -> Crystal:
    # rotate the lattice; frac coords unchanged -> cart coords rotate
    return Crystal(lattice=c.lattice @ rot.T, frac_coords=c.frac_coords,
                   atomic_numbers=c.atomic_numbers)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_force_head_rotation_equivariance(seed):
    """F(R x) = R F(x) — the paper's Eq. 8, exact up to float error."""
    rng = np.random.default_rng(seed)
    c = _crystal(rng)
    rot = random_rotation(rng)
    g = build_graph(c)
    caps = BatchCapacities(8, g.num_bonds + 4, g.num_angles + 4)
    cfg = CHGNetConfig(readout="direct")
    params = chgnet_init(jax.random.PRNGKey(0), cfg)

    f1 = np.asarray(chgnet_apply(params, cfg,
                                 batch_crystals([c], [g], caps))["forces"])
    c_rot = _rotate(c, rot)
    g_rot = build_graph(c_rot)
    assert g_rot.num_bonds == g.num_bonds  # rotation preserves topology
    f2 = np.asarray(chgnet_apply(params, cfg,
                                 batch_crystals([c_rot], [g_rot], caps))["forces"])
    n = c.num_atoms
    np.testing.assert_allclose(f2[:n], f1[:n] @ rot.T, atol=2e-4)


@pytest.mark.parametrize("readout", ["direct", "autodiff"])
def test_energy_rotation_invariance(readout):
    rng = np.random.default_rng(4)
    c = _crystal(rng)
    rot = random_rotation(rng)
    g = build_graph(c)
    caps = BatchCapacities(8, g.num_bonds + 4, g.num_angles + 4)
    cfg = CHGNetConfig(readout=readout)
    params = chgnet_init(jax.random.PRNGKey(0), cfg)
    e1 = chgnet_apply(params, cfg, batch_crystals([c], [g], caps))["energy"]
    c2 = _rotate(c, rot)
    e2 = chgnet_apply(params, cfg,
                      batch_crystals([c2], [build_graph(c2)], caps))["energy"]
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2), atol=5e-4)


def test_autodiff_forces_rotation_equivariant():
    """The conservative readout is equivariant by construction — check."""
    rng = np.random.default_rng(5)
    c = _crystal(rng, n=4)
    rot = random_rotation(rng)
    g = build_graph(c)
    caps = BatchCapacities(8, g.num_bonds + 4, g.num_angles + 4)
    cfg = CHGNetConfig(readout="autodiff", num_blocks=1)
    params = chgnet_init(jax.random.PRNGKey(0), cfg)
    f1 = np.asarray(chgnet_apply(params, cfg,
                                 batch_crystals([c], [g], caps))["forces"])
    c2 = _rotate(c, rot)
    f2 = np.asarray(chgnet_apply(params, cfg,
                                 batch_crystals([c2], [build_graph(c2)], caps))["forces"])
    n = c.num_atoms
    np.testing.assert_allclose(f2[:n], f1[:n] @ rot.T, atol=2e-4)


# ---------------------------------------------------------------------------
# symmetric half-graph trunk (DESIGN.md §10)
# ---------------------------------------------------------------------------

SYM = dict(bond_store="undirected", bond_features="undirected")


@pytest.mark.parametrize("readout", ["direct", "autodiff"])
def test_sym_trunk_forces_rotation_equivariant(readout):
    """F(R x) = R F(x) holds on the Eu/Au-resident symmetric trunk: the
    swap-symmetrized features are built from rotation-invariant geometry,
    so equivariance is carried entirely by the readout — check it
    survives the half-graph compute path."""
    rng = np.random.default_rng(7)
    c = _crystal(rng)
    rot = random_rotation(rng)
    g = build_graph(c)
    caps = BatchCapacities(8, g.num_bonds + 4, g.num_angles + 4)
    cfg = CHGNetConfig(readout=readout, num_blocks=1, **SYM)
    params = chgnet_init(jax.random.PRNGKey(0), cfg)
    f1 = np.asarray(chgnet_apply(params, cfg,
                                 batch_crystals([c], [g], caps))["forces"])
    c2 = _rotate(c, rot)
    g2 = build_graph(c2)
    assert g2.num_bonds == g.num_bonds
    f2 = np.asarray(chgnet_apply(params, cfg,
                                 batch_crystals([c2], [g2], caps))["forces"])
    n = c.num_atoms
    np.testing.assert_allclose(f2[:n], f1[:n] @ rot.T, atol=2e-4)


def test_sym_trunk_energy_and_forces_translation_invariant():
    """Rigid translation (with periodic wrap) relabels bond images but
    must leave the symmetric trunk's energy and per-atom forces alone."""
    rng = np.random.default_rng(8)
    c = _crystal(rng)
    g = build_graph(c)
    caps = BatchCapacities(8, g.num_bonds + 4, g.num_angles + 4)
    cfg = CHGNetConfig(readout="direct", **SYM)
    params = chgnet_init(jax.random.PRNGKey(0), cfg)
    out1 = chgnet_apply(params, cfg, batch_crystals([c], [g], caps))
    c2 = Crystal(lattice=c.lattice,
                 frac_coords=(c.frac_coords + rng.random(3)) % 1.0,
                 atomic_numbers=c.atomic_numbers)
    g2 = build_graph(c2)
    assert g2.num_bonds == g.num_bonds
    out2 = chgnet_apply(params, cfg, batch_crystals([c2], [g2], caps))
    np.testing.assert_allclose(np.asarray(out2["energy"]),
                               np.asarray(out1["energy"]), atol=2e-4)
    n = c.num_atoms
    np.testing.assert_allclose(np.asarray(out2["forces"])[:n],
                               np.asarray(out1["forces"])[:n], atol=2e-4)


@needs_hypothesis
@settings(max_examples=12, deadline=None)
@given(sizes=st.lists(st.integers(3, 8), min_size=1, max_size=3),
       max_nbr=st.integers(4, 10),
       seed=st.integers(0, 2**31 - 1))
def test_symmetric_capped_graphs_keep_half_counts(sizes, max_nbr, seed):
    """Ragged sweep over cap_mode="symmetric" capped graphs: Eu == E/2
    and Au == A/2 hold per graph AND survive packing + validate_layout
    (which certifies the §10 sym-incidence store on the packed batch)."""
    from repro.batching.pack import validate_layout

    rng = np.random.default_rng(seed)
    cs = [_crystal(rng, n) for n in sizes]
    gs = [build_graph(c, max_nbr_per_atom=max_nbr, cap_mode="symmetric")
          for c in cs]
    for g in gs:
        assert 2 * g.num_undirected == g.num_bonds
        assert 2 * g.und_angle_rep.shape[0] == g.num_angles
    caps = BatchCapacities(sum(sizes) + 4,
                           sum(g.num_bonds for g in gs) + 8,
                           sum(g.num_angles for g in gs) + 8)
    batch = batch_crystals(cs, gs, caps)
    validate_layout(batch)
    e_real = int(np.asarray(batch.bond_mask).sum())
    eu_real = int(np.asarray(batch.und_mask).sum())
    a_real = int(np.asarray(batch.angle_mask).sum())
    au_real = int(np.asarray(batch.und_angle_mask).sum())
    assert 2 * eu_real == e_real
    assert 2 * au_real == a_real
    # the incidence count equals the directed-angle count (§10)
    assert int(np.asarray(batch.sym_offsets)[-1]) == a_real


# ---------------------------------------------------------------------------
# synthetic label physics (the training target is physically consistent)
# ---------------------------------------------------------------------------

def test_synthetic_forces_are_exact_gradients():
    from repro.data.synthetic import SyntheticConfig, make_dataset, _morse

    ds = make_dataset(SyntheticConfig(num_crystals=2, max_atoms=10, seed=0))
    c, g = ds.crystals[0], ds.graphs[0]
    cart = c.cart_coords()
    inv = np.linalg.inv(c.lattice)
    eps = 1e-5

    def pair_energy(cart_pos):
        c2 = Crystal(lattice=c.lattice, frac_coords=cart_pos @ inv,
                     atomic_numbers=c.atomic_numbers)
        g2 = build_graph(c2)
        cart2 = c2.cart_coords()
        v = cart2[g2.bond_nbr] + g2.bond_image @ c.lattice - cart2[g2.bond_center]
        return 0.5 * np.sum(_morse(np.linalg.norm(v, axis=-1)))

    for i in range(min(3, c.num_atoms)):
        for k in range(3):
            dp = cart.copy(); dp[i, k] += eps
            dm = cart.copy(); dm[i, k] -= eps
            f_num = -(pair_energy(dp) - pair_energy(dm)) / (2 * eps)
            assert abs(f_num - c.forces[i, k]) < 1e-5 * max(1.0, abs(f_num))


def test_synthetic_magmoms_nonnegative_and_finite():
    from repro.data.synthetic import SyntheticConfig, make_dataset

    ds = make_dataset(SyntheticConfig(num_crystals=4, seed=1))
    for c in ds.crystals:
        assert np.all(np.isfinite(c.magmoms))
        assert np.all(c.magmoms >= 0)
