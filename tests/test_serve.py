"""MD serving engine: Verlet-skin correctness over a toy MD run, bucket
selection safety, multi-replica batched stepping."""
import jax
import numpy as np
import pytest

from repro.batching import batch_crystals
from repro.configs import chgnet_mptrj as C
from repro.core.chgnet import chgnet_apply, chgnet_init
from repro.core.neighbors import Crystal, VerletNeighborList, build_graph
from repro.serve import BatchedMD, ServeEngine, structure_ladder

CFG = C.FAST_FS_HEAD


def make_crystal(n, seed=0):
    rng = np.random.default_rng(seed)
    a = (n * 14.0) ** (1 / 3)
    return Crystal(lattice=np.eye(3) * a, frac_coords=rng.random((n, 3)),
                   atomic_numbers=rng.integers(1, 60, n))


@pytest.fixture(scope="module")
def params():
    return chgnet_init(jax.random.PRNGKey(0), CFG)


def _bond_set(g):
    return set(zip(g.bond_center.tolist(), g.bond_nbr.tolist(),
                   map(tuple, g.bond_image.tolist())))


def test_verlet_skin_matches_full_rebuild_over_md_run(params):
    """50-step toy MD: the skin-reused graph equals a from-scratch rebuild
    every step, and the model forces agree within float tolerance."""
    crystal = make_crystal(12, seed=3)
    nlist = VerletNeighborList(crystal, CFG.r_cut_atom, CFG.r_cut_bond,
                               skin=0.4)
    serve = jax.jit(lambda p, b: chgnet_apply(p, CFG, b))
    g0 = build_graph(crystal)
    from repro.batching import BatchCapacities
    caps = BatchCapacities(crystal.num_atoms + 4,
                           int(g0.num_bonds * 1.5) + 64,
                           int(g0.num_angles * 2.0) + 64)

    vel = np.zeros((crystal.num_atoms, 3))
    inv_lat = np.linalg.inv(crystal.lattice)
    dt = 2e-3
    checked_forces = 0
    for step in range(50):
        g_skin = nlist.update(crystal)
        g_full = build_graph(crystal, CFG.r_cut_atom, CFG.r_cut_bond)
        # graph topology identical every step
        assert _bond_set(g_skin) == _bond_set(g_full), f"step {step}"
        assert g_skin.num_angles == g_full.num_angles

        out = serve(params, batch_crystals([crystal], [g_skin], caps))
        f = np.asarray(out["forces"])[: crystal.num_atoms]
        if step % 10 == 0:
            out_full = serve(params, batch_crystals([crystal], [g_full], caps))
            f_full = np.asarray(out_full["forces"])[: crystal.num_atoms]
            np.testing.assert_allclose(f, f_full, rtol=1e-4, atol=1e-5)
            checked_forces += 1
        vel += f * dt
        cart = crystal.cart_coords() + vel * dt
        crystal.frac_coords = (cart @ inv_lat) % 1.0
    assert checked_forces == 5
    assert nlist.updates == 50
    # the point of the skin: most steps reuse the candidate list
    assert nlist.rebuilds < nlist.updates


def test_verlet_rebuild_triggers_on_large_move():
    crystal = make_crystal(8, seed=1)
    nlist = VerletNeighborList(crystal, skin=0.5)
    assert nlist.rebuilds == 1
    # displace one atom by more than skin/2 (in cartesian A)
    inv_lat = np.linalg.inv(crystal.lattice)
    crystal.frac_coords = crystal.frac_coords.copy()
    crystal.frac_coords[0] += (np.array([0.6, 0.0, 0.0]) @ inv_lat)
    assert nlist.needs_rebuild(crystal)
    nlist.update(crystal)
    assert nlist.rebuilds == 2


def test_verlet_wrap_safe_displacement():
    """Wrapping frac coords across the boundary is not a large move."""
    crystal = make_crystal(8, seed=2)
    nlist = VerletNeighborList(crystal, skin=0.5)
    crystal.frac_coords = (crystal.frac_coords + 0.999) % 1.0
    # every atom moved by ~0.001 frac (minimum image), far below skin/2
    assert nlist.max_displacement(crystal) < 0.05
    assert not nlist.needs_rebuild(crystal)


def test_verlet_graph_correct_after_boundary_wrap():
    """Regression: an atom drifting across the periodic boundary (and
    being wrapped by the MD driver) must not invalidate reused candidate
    images — the returned graph must equal a from-scratch rebuild."""
    n = 6
    a = (n * 14.0) ** (1 / 3)
    rng = np.random.default_rng(0)
    frac = rng.random((n, 3)) * 0.5 + 0.25  # keep the rest interior
    frac[0] = [0.995, 0.5, 0.5]
    crystal = Crystal(lattice=np.eye(3) * a, frac_coords=frac,
                      atomic_numbers=rng.integers(1, 60, n))
    nlist = VerletNeighborList(crystal, skin=0.8)
    nlist.update(crystal)
    # tiny physical move that crosses the cell boundary -> wrapped coords
    frac2 = frac.copy()
    frac2[0, 0] = 1.004
    crystal.frac_coords = frac2 % 1.0  # atom 0 now at 0.004
    assert not nlist.needs_rebuild(crystal)  # ~0.04 A actual displacement
    g_skin = nlist.update(crystal)
    g_full = build_graph(crystal)
    assert _bond_set(g_skin) == _bond_set(g_full)
    assert g_skin.num_angles == g_full.num_angles


def test_serve_engine_matches_direct_apply(params):
    """Bucketed/padded engine prediction == direct single-structure apply."""
    crystals = [make_crystal(n, seed=n) for n in (6, 9, 14)]
    serve = ServeEngine.for_structures(params, CFG, crystals)
    out = serve.predict(crystals)
    for c, f_eng, e_eng in zip(crystals, out["forces"], out["energy"]):
        g = build_graph(c, CFG.r_cut_atom, CFG.r_cut_bond)
        from repro.batching import BatchCapacities
        caps = BatchCapacities(c.num_atoms, g.num_bonds, g.num_angles)
        ref = chgnet_apply(params, CFG, batch_crystals([c], [g], caps))
        np.testing.assert_allclose(
            f_eng, np.asarray(ref["forces"]), rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(
            e_eng, float(ref["energy"][0]), rtol=1e-4, atol=1e-5)


def test_bucket_selection_never_truncates_random_structures(params):
    """Property-style: the engine packs random crystal sizes without ever
    raising a capacity error, including sizes far beyond the ladder."""
    rng = np.random.default_rng(0)
    seed_crystals = [make_crystal(n, seed=n) for n in (6, 8, 10)]
    serve = ServeEngine.for_structures(params, CFG, seed_crystals)
    for trial in range(8):
        n = int(rng.integers(2, 30))
        c = make_crystal(n, seed=100 + trial)
        out = serve.predict([c])
        assert out["forces"][0].shape == (n, 3)
        assert np.isfinite(out["energy"][0])


def test_batched_md_replicas_are_independent(params):
    """A replica stepped inside a batch evolves identically to the same
    replica stepped alone (padding/batching leaks nothing)."""
    import copy

    mk = lambda: [make_crystal(10, seed=5), make_crystal(13, seed=6)]
    serve = ServeEngine.for_structures(params, CFG, mk())

    md_pair = BatchedMD(serve, mk(), dt=1e-3, skin=0.5)
    out_pair = md_pair.step(5)

    md_solo = BatchedMD(serve, [mk()[0]], dt=1e-3, skin=0.5)
    out_solo = md_solo.step(5)

    np.testing.assert_allclose(
        out_pair["energy"][0], out_solo["energy"][0], rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(
        out_pair["forces"][0], out_solo["forces"][0], rtol=1e-3, atol=1e-5)


def test_structure_ladder_and_compile_cache_reuse(params):
    crystals = [make_crystal(n, seed=n) for n in (6, 8, 10, 12)]
    graphs = [build_graph(c) for c in crystals]
    lad = structure_ladder(graphs, crystals)
    for c, g in zip(crystals, graphs):
        assert lad.bucket_for(
            c.num_atoms, g.num_bonds, g.num_angles
        ).fits(c.num_atoms, g.num_bonds, g.num_angles)

    from repro.batching import CompileCache
    serve = ServeEngine(params, CFG, lad, cache=CompileCache())
    md = BatchedMD(serve, crystals, dt=1e-3, skin=0.5)
    md.step(4)
    stats = md.stats()
    # compiled once per (bucket, slots); later steps are cache hits
    assert stats["compile_cache_hits"] > 0
    assert stats["compile_cache_entries"] <= len(lad.buckets) * 3
