"""Load-balanced sharding + gradient accumulation (DESIGN.md §6):
cost model fit, LPT bin-packer determinism, accumulated-update ==
single-big-batch equivalence at f32, mixed-precision skip-on-inf across
microbatches, donation aliasing on the accum/DP steps, and the
rebalance-on-fault protocol (subprocess, 2 forced host devices)."""
import dataclasses
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.batching import ladder_for
from repro.batching.balance import (
    StepPlan,
    crystal_slots_for,
    lpt_pack,
    plan_microbatches,
    shard_cost_totals,
    straggler_ratio,
)
from repro.batching.cost import CostModel, DEFAULT_COST_MODEL, fit_cost_model
from repro.core.chgnet import CHGNetConfig
from repro.data import (
    BalancedBatchIterator,
    BatchIterator,
    SyntheticConfig,
    make_dataset,
)
from repro.data.sampler import CostBalanceSampler
from repro.train import TrainConfig, Trainer


@pytest.fixture(scope="module")
def ds():
    return make_dataset(SyntheticConfig(num_crystals=48, max_atoms=14,
                                        seed=0))


@pytest.fixture(scope="module")
def caps(ds):
    return ladder_for(ds, 8)


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------

def test_cost_model_fit_recovers_affine_coefficients():
    rng = np.random.default_rng(0)
    counts = rng.integers(1, 200, size=(64, 3)).astype(np.float64)
    true = CostModel(c0=3.0, atoms=0.5, bonds=1.5, angles=0.25)
    times = (true.c0 + counts @ np.array([true.atoms, true.bonds,
                                          true.angles]))
    fit = fit_cost_model(counts, times)
    np.testing.assert_allclose(
        [fit.c0, fit.atoms, fit.bonds, fit.angles],
        [true.c0, true.atoms, true.bonds, true.angles], atol=1e-6)


def test_cost_model_fit_clamps_nonnegative():
    counts = np.array([[1.0, 10.0, 5.0], [2.0, 20.0, 9.0],
                       [3.0, 30.0, 2.0], [4.0, 40.0, 7.0]])
    # times anti-correlated with angles -> unconstrained lstsq would go
    # negative there; a cost model must never predict negative marginal cost
    times = counts[:, 1] * 2.0 - counts[:, 2] * 5.0 + 100.0
    fit = fit_cost_model(counts, times)
    assert fit.atoms >= 0 and fit.bonds >= 0 and fit.angles >= 0


def test_default_cost_model_is_feature_count(ds):
    # paper Fig. 9 load metric: atoms + bonds + angles
    costs = DEFAULT_COST_MODEL.predict_dataset(ds)
    expect = np.array([c.num_atoms for c in ds.crystals], np.float64)
    expect += np.array(
        [g.num_bonds for g in ds.graphs], np.float64)
    expect += np.array(
        [g.num_angles for g in ds.graphs], np.float64)
    np.testing.assert_allclose(costs, expect)


# ---------------------------------------------------------------------------
# LPT bin packing
# ---------------------------------------------------------------------------

def test_lpt_pack_partition_and_determinism():
    rng = np.random.default_rng(1)
    costs = rng.lognormal(2.0, 1.0, size=37)
    a = lpt_pack(costs, 4, max_items=12)
    b = lpt_pack(costs, 4, max_items=12)
    # deterministic: identical shards on identical input
    assert all(np.array_equal(x, y) for x, y in zip(a, b))
    # exact partition: every index once, no shard over max_items
    flat = np.sort(np.concatenate(a))
    np.testing.assert_array_equal(flat, np.arange(37))
    assert max(len(s) for s in a) <= 12
    # beats the naive contiguous even split on straggler ratio
    naive = np.array_split(np.arange(37), 4)
    assert (straggler_ratio(shard_cost_totals(costs, list(a)))
            <= straggler_ratio(shard_cost_totals(costs, naive)))


def test_cost_balance_sampler_seeded_determinism():
    rng = np.random.default_rng(2)
    costs = rng.lognormal(2.0, 1.0, size=64)
    runs = []
    for _ in range(2):
        sampler = CostBalanceSampler(costs, seed=7, max_items=10)
        runs.append([
            (idx.tolist(), [s.tolist() for s in shards])
            for idx, shards in sampler.epoch(16, 4)
        ])
    assert runs[0] == runs[1]
    # a different seed permutes differently (content, not contract)
    other = CostBalanceSampler(costs, seed=8, max_items=10)
    alt = [(i.tolist(), [s.tolist() for s in sh])
           for i, sh in other.epoch(16, 4)]
    assert alt != runs[0]


def test_plan_microbatches_invariants():
    rng = np.random.default_rng(3)
    costs = rng.lognormal(2.0, 1.0, size=24)
    slots = crystal_slots_for(24, 2, num_micro=3)
    plan = plan_microbatches(costs, 2, 3, max_items=slots)
    assert len(plan) == 3
    seen = np.sort(np.concatenate([np.concatenate(m) for m in plan]))
    np.testing.assert_array_equal(seen, np.arange(24))
    for micro in plan:
        assert len(micro) == 2
        assert max(len(s) for s in micro) <= slots


def test_step_plan_straggler_property():
    plan = StepPlan(micro=[], denoms={},
                    shard_costs=np.array([[3.0, 1.0], [2.0, 2.0]]),
                    num_real=4)
    # micros are sequential phases: per-device totals are summed over
    # micros first, then max/mean
    assert plan.straggler == pytest.approx(5.0 / 4.0)


def test_batch_iterator_cost_mode(ds, caps):
    it = BatchIterator(ds, 8, 1, caps, load_balance="cost")
    batch = next(iter(it))
    assert float(jnp.sum(batch.crystal_mask)) == 8.0
    assert bool(jnp.all(jnp.isfinite(batch.energy)))


# ---------------------------------------------------------------------------
# accumulation == single big batch (f32)
# ---------------------------------------------------------------------------

def test_accum_matches_single_big_batch_f32(ds, caps):
    """ISSUE §6 bar: accumulated grads over num_micro buckets produce the
    same update as one big-batch step to <=1e-6 at f32 (global-denominator
    partial losses are exactly additive; only f32 reassociation differs)."""
    cfg = CHGNetConfig(readout="direct", dim=16, num_blocks=1)
    tcfg = TrainConfig(global_batch=8, total_steps=100)
    idx = np.arange(8)
    plan_one = BalancedBatchIterator(ds, 8, 1, caps,
                                     num_micro=1).plan_step(idx)
    plan_two = BalancedBatchIterator(ds, 8, 1, caps,
                                     num_micro=2).plan_step(idx)
    assert len(plan_one.micro) == 1 and len(plan_two.micro) == 2

    tr_a = Trainer(cfg, tcfg, seed=0)
    tr_b = Trainer(cfg, tcfg, seed=0)
    h_a = tr_a.train([plan_one])
    h_b = tr_b.train([plan_two])

    assert abs(h_a[0]["loss"] - h_b[0]["loss"]) <= 1e-6
    diffs = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), tr_a.params,
        tr_b.params)
    assert max(jax.tree.leaves(diffs)) <= 1e-6, diffs


def test_accum_mixed_precision_skips_on_inf_micro(ds, caps):
    """Skip-on-inf composes across microbatches: an inf in ONE micro
    poisons the accumulated grad sum, so the single finite-check skips
    the whole step and backs the loss scale off (DESIGN.md §4 + §6)."""
    cfg = CHGNetConfig(readout="direct", dim=16, num_blocks=1,
                       precision="mixed")
    tcfg = TrainConfig(global_batch=8, total_steps=100)
    it = BalancedBatchIterator(ds, 8, 1, caps, num_micro=2)
    plan = it.plan_step(np.arange(8))
    bad = dataclasses.replace(
        plan.micro[1],
        energy=jnp.full_like(plan.micro[1].energy, jnp.inf))
    poisoned = StepPlan(micro=[plan.micro[0], bad], denoms=plan.denoms,
                        shard_costs=plan.shard_costs,
                        num_real=plan.num_real)

    tr = Trainer(cfg, tcfg, seed=0)
    scale0 = float(tr.opt_state["loss_scale"]["scale"])
    before = jax.device_get(tr.params)
    hist = tr.train([poisoned])
    assert hist[0]["grads_finite"] == 0.0
    # whole step skipped: params bit-identical, dynamic scale halved
    after = jax.device_get(tr.params)
    assert all(np.array_equal(a, b) for a, b in
               zip(jax.tree.leaves(before), jax.tree.leaves(after)))
    assert float(tr.opt_state["loss_scale"]["scale"]) == scale0 / 2
    # a clean plan then updates normally at the reduced scale
    hist2 = tr.train([it.plan_step(np.arange(8, 16))])
    assert hist2[0]["grads_finite"] == 1.0


# ---------------------------------------------------------------------------
# buffer donation
# ---------------------------------------------------------------------------

def test_accum_steps_donation_aliasing(ds, caps):
    """apply_step donates params/opt_state (the Trainer rebinds both) and
    the donate flag rides the compile-cache key; grad_step donates
    nothing — its outputs are param-shaped grads + scalar sums, so no
    batch buffer could ever back an output."""
    from repro.batching import CompileCache
    from repro.train import make_chgnet_accum_step_fns

    cfg = CHGNetConfig(readout="direct", dim=16, num_blocks=1)
    tcfg = TrainConfig(global_batch=8)
    cache = CompileCache()
    g1, a1 = make_chgnet_accum_step_fns(cfg, tcfg, cache=cache)
    g2, a2 = make_chgnet_accum_step_fns(cfg, tcfg, cache=cache)
    assert g1 is g2 and a1 is a2  # cache hit
    g0, a0 = make_chgnet_accum_step_fns(cfg, tcfg, cache=cache,
                                        donate=False)
    assert a0 is not a1  # donate is part of the key

    tr = Trainer(cfg, tcfg)
    plan = BalancedBatchIterator(ds, 8, 1, caps).plan_step(np.arange(8))
    denoms = {k: jnp.asarray(v) for k, v in plan.denoms.items()}
    scale = jnp.asarray(1.0, jnp.float32)
    micro = plan.micro[0]
    # no donation on the grad step: nothing could alias
    txt = g1.lower(tr.params, micro, denoms, scale).as_text()
    assert "tf.aliasing_output" not in txt
    grads, sums = g0(tr.params, micro, denoms, scale)
    args = (tr.params, tr.opt_state, grads, sums, denoms, jnp.asarray(0))
    # params/opt_state donation aliases the updated trees
    assert "tf.aliasing_output" in a1.lower(*args).as_text()
    assert "tf.aliasing_output" not in a0.lower(*args).as_text()


def test_dp_eval_serve_donation_flags(ds, caps):
    """DP eval/serve donation is opt-in/opt-out and keyed in the cache:
    eval defaults OFF (batches are reused across evals), serve defaults
    ON (each packed batch is consumed once)."""
    from jax.sharding import Mesh

    from repro.batching import CompileCache
    from repro.train.trainer import make_dp_eval_step, make_dp_serve_step

    cfg = CHGNetConfig(readout="direct", dim=16, num_blocks=1)
    tcfg = TrainConfig(global_batch=8)
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    cache = CompileCache()
    tr = Trainer(cfg, tcfg, mesh=mesh)
    batch = next(iter(BatchIterator(ds, 8, 1, caps, stack=True)))

    e_off = make_dp_eval_step(cfg, tcfg, mesh, cache=cache)
    e_on = make_dp_eval_step(cfg, tcfg, mesh, cache=cache, donate=True)
    assert e_off is not e_on  # donate rides the cache key
    assert e_off is make_dp_eval_step(cfg, tcfg, mesh, cache=cache)
    # eval outputs are scalar metrics: donation releases batch buffers
    # early but can never alias them into an output
    assert "tf.aliasing_output" not in e_off.lower(
        tr.params, batch).as_text()

    # serve outputs ARE batch-shaped (forces/magmoms per atom slot), so
    # the donated batch visibly backs them
    s_on = make_dp_serve_step(cfg, mesh, cache=cache)
    assert "tf.aliasing_output" in s_on.lower(tr.params, batch).as_text()
    s_off = make_dp_serve_step(cfg, mesh, cache=cache, donate=False)
    assert s_off is not s_on
    assert "tf.aliasing_output" not in s_off.lower(
        tr.params, batch).as_text()


# ---------------------------------------------------------------------------
# rebalance on fault (subprocess: 2 forced host devices)
# ---------------------------------------------------------------------------

_FAULT_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    os.environ.setdefault("JAX_PLATFORM_NAME", "cpu")
    import json
    import jax, numpy as np
    from jax.sharding import Mesh
    from repro.batching import ladder_for
    from repro.core.chgnet import CHGNetConfig
    from repro.data import (BalancedBatchIterator, SyntheticConfig,
                            make_dataset)
    from repro.runtime import DeviceDropInjector, elastic_train
    from repro.train import TrainConfig, Trainer

    ds = make_dataset(SyntheticConfig(num_crystals=32, max_atoms=12,
                                      seed=0))
    caps = ladder_for(ds, 8)
    mesh = Mesh(np.array(jax.devices()), ("data",))
    assert mesh.devices.size == 2
    cfg = CHGNetConfig(readout="direct", dim=16, num_blocks=1)
    tcfg = TrainConfig(global_batch=8, total_steps=64, lr_k=1)
    tr = Trainer(cfg, tcfg, mesh=mesh)

    # held-out eval batch on a plain single-device step: running losses
    # are too noisy (batch composition changes every step) to show
    # descent over a short run
    from repro.data import BatchIterator
    from repro.train.trainer import make_chgnet_step_fns
    _, eval_step, _ = make_chgnet_step_fns(cfg, tcfg)
    eval_batch = next(iter(BatchIterator(ds, 8, 1, caps, seed=99)))
    before = float(eval_step(jax.device_get(tr.params),
                             eval_batch)["loss"])

    import itertools
    def batches_fn(num_devices):
        it = BalancedBatchIterator(ds, 8, num_devices, caps,
                                   stack=tr.mesh is not None, seed=5)
        return itertools.islice(itertools.cycle(iter(it)), 20)

    hist = elastic_train(
        tr, batches_fn, max_steps=20,
        fault_injector=DeviceDropInjector(fail_at_step=5))
    after = float(eval_step(jax.device_get(tr.params),
                            eval_batch)["loss"])
    print(json.dumps({
        "steps": tr.step,
        "history": len(hist),
        "devices": tr.num_devices,
        "before": before,
        "after": after,
    }))
""")


def test_device_drop_rebalances_and_loss_descends():
    """ISSUE §6 fault protocol: drop a device at step 5 on a 2-device
    mesh; training re-bin-packs over the 1 survivor and keeps
    descending, with no lost steps and no checkpoint round-trip."""
    env = dict(os.environ, PYTHONPATH="src", JAX_PLATFORM_NAME="cpu",
               REPRO_KERNELS_INTERPRET="1")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", _FAULT_SCRIPT], capture_output=True,
        text=True, env=env, cwd=os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), timeout=900)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["steps"] == 20            # finished despite the drop
    assert res["history"] == 20          # pre-drop steps kept (no loss)
    assert res["devices"] == 1           # mesh shrank 2 -> 1
    assert res["after"] < res["before"]  # still learning after rebalance
